//! Fleet-client walkthrough for the plan-serving coordinator.
//!
//! Plays the role of a fleet of MCU devices against `mcu-reorder
//! plan-serve`: discovers the board profiles and the model zoo, asks for
//! a reorder+split+elide plan, uploads a real `.tflite` model and plans
//! it for every board, downloads one full plan document, and reads the
//! cache statistics back. The coordinator is started in-process on an
//! OS-chosen port so the example runs anywhere; every line it sends
//! behaves identically when typed over `nc` against a standalone
//! `mcu-reorder plan-serve --port 7879`.
//!
//! ```text
//! cargo run --release --example fleet_client
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc;

use mcu_reorder::coordinator::{serve_plans_tcp, PlanServeConfig, PlanService};
use mcu_reorder::mcu::boards;
use mcu_reorder::split::SplitOptions;
use mcu_reorder::tflite::fixtures;
use mcu_reorder::util::json::Json;

/// One protocol round-trip: send a line, read the one-line reply.
fn send(writer: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> String {
    writer.write_all(line.as_bytes()).expect("send line");
    writer.write_all(b"\n").expect("send newline");
    let mut reply = String::new();
    reader.read_line(&mut reply).expect("recv reply");
    reply
}

fn num(doc: &Json, key: &str) -> f64 {
    doc.get(key).as_f64().unwrap_or(f64::NAN)
}

fn main() {
    // In production this is `mcu-reorder plan-serve`; the walkthrough
    // starts the identical service in-process.
    let svc = PlanService::start(PlanServeConfig {
        workers: 2,
        split: SplitOptions::quick(),
        ..Default::default()
    });
    let (addr_tx, addr_rx) = mpsc::channel();
    let server = {
        let svc = svc.clone();
        std::thread::spawn(move || {
            serve_plans_tcp(svc, "127.0.0.1:0", Some(1), move |a| {
                let _ = addr_tx.send(a);
            })
            .expect("plan server")
        })
    };
    let addr = addr_rx.recv().expect("server address");
    println!("plan server listening on {addr}\n");

    let stream = TcpStream::connect(addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone stream");
    let mut reader = BufReader::new(stream);

    // --- 1. Discovery: what can this coordinator plan for? ---
    let reply = send(&mut writer, &mut reader, "BOARDS");
    println!("BOARDS → {}", reply.trim_end());
    let reply = send(&mut writer, &mut reader, "MODELS");
    println!("MODELS → {}\n", reply.trim_end());

    // --- 2. A zoo model on one device's board, default budget (the
    //        board's SRAM). The summary is a single JSON line. ---
    let reply = send(&mut writer, &mut reader, "PLAN streamnet NUCLEO-F446RE");
    let summary = Json::parse(reply.trim_start_matches("OK ").trim()).expect("summary json");
    println!(
        "streamnet @ NUCLEO-F446RE: peak {:.0} B (reorder-only {:.0} B), \
         {:.0} segment(s), budget_met={}",
        num(&summary, "peak"),
        num(&summary, "reordered"),
        num(&summary, "segments"),
        summary.get("budget_met").as_bool().unwrap_or(false),
    );

    // --- 3. Upload a real TFLite model; the returned content hash is the
    //        model reference every device in the fleet can plan against. ---
    let path = fixtures::ensure(fixtures::INT8_FIXTURE).expect("tflite fixture");
    let bytes = std::fs::read(path).expect("reading fixture");
    writer
        .write_all(format!("UPLOAD cnn_int8.tflite {}\n", bytes.len()).as_bytes())
        .expect("upload header");
    writer.write_all(&bytes).expect("upload body");
    let mut reply = String::new();
    reader.read_line(&mut reply).expect("upload reply");
    let hash = reply.trim().strip_prefix("OK ").expect("upload accepted").to_string();
    println!("\nuploaded cnn_int8.tflite ({} B) → hash:{hash}", bytes.len());

    // --- 4. Plan the uploaded model for every board profile. Repeat
    //        requests are cache hits — bit-identical, served instantly. ---
    for board in boards::ALL_BOARDS {
        let reply = send(&mut writer, &mut reader, &format!("PLAN hash:{hash} {}", board.name));
        let doc = Json::parse(reply.trim_start_matches("OK ").trim()).expect("summary json");
        println!(
            "  {:>16}: {:>7.0} B SRAM budget, peak {:>6.0} B, fits_sram={}",
            board.name,
            board.sram_bytes as f64,
            num(&doc, "peak"),
            doc.get("fits_sram").as_bool().unwrap_or(false),
        );
    }

    // --- 5. GET downloads the full plan document (execution order, split
    //        steps, planner telemetry) for the device to apply. ---
    let reply = send(&mut writer, &mut reader, &format!("GET hash:{hash} SparkFun-Edge"));
    let plan = Json::parse(reply.trim_start_matches("OK ").trim()).expect("plan json");
    println!(
        "\nGET full plan: {} B of JSON, schema_version {:.0}, model {:?}",
        reply.trim_end().len(),
        num(&plan, "schema_version"),
        plan.get("model").as_str().unwrap_or("?"),
    );

    // --- 6. Service telemetry: cache hit/miss/eviction counters. ---
    let reply = send(&mut writer, &mut reader, "STATS");
    let stats = Json::parse(reply.trim_start_matches("OK ").trim()).expect("stats json");
    let cache = stats.get("cache");
    println!(
        "STATS: served {:.0}, cache {:.0} hit / {:.0} miss / {:.0} evicted",
        num(&stats, "served"),
        num(cache, "hits"),
        num(cache, "misses"),
        num(cache, "evictions"),
    );

    send(&mut writer, &mut reader, "QUIT");
    server.join().expect("server thread");
    svc.shutdown();
    println!("\nfleet-client walkthrough complete.");
}

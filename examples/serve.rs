//! End-to-end serving driver (the repo's full-stack validation).
//!
//! Loads the AOT-compiled MobileNet person-detection artifact (JAX/Pallas →
//! HLO text → PJRT CPU), starts the Layer-3 coordinator (router, batcher,
//! worker pool), fires a few hundred synthetic image requests at it over
//! both the in-process API and the TCP front-end, and reports latency
//! percentiles and throughput. Every response is cross-checked against the
//! pure-Rust micro-interpreter on the same weights.
//!
//! ```text
//! make artifacts && cargo run --release --example serve
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::{mpsc, Arc};
use std::time::Instant;

use mcu_reorder::coordinator::{self, Coordinator, ServeConfig};
use mcu_reorder::graph::DType;
use mcu_reorder::interp::{ExecConfig, Interpreter, TensorData, WeightStore};
use mcu_reorder::models;

const MODEL: &str = "mobilenet";
const REQUESTS: usize = 200;

fn main() {
    let artifacts = Path::new("artifacts");
    if !artifacts.join(format!("{MODEL}.hlo.txt")).exists() {
        eprintln!("artifacts/{MODEL}.hlo.txt missing — run `make artifacts` first");
        std::process::exit(2);
    }

    let g = models::by_name(MODEL, DType::F32).unwrap();
    let n_in = g.tensors[g.inputs[0]].elems();

    // Reference outputs from the micro-interpreter (same seeded weights the
    // AOT pipeline baked into the artifact).
    let ws = WeightStore::seeded_f32(&g, 42);
    let interp = Interpreter::new(&g, ws, ExecConfig::with_capacity(1 << 24));

    // Start the coordinator on the PJRT engine (one client per worker).
    let workers = 4;
    println!("starting coordinator: model={MODEL}, {workers} PJRT workers …");
    let t0 = Instant::now();
    let coord = Arc::new(
        Coordinator::start(
            ServeConfig { workers, ..Default::default() },
            coordinator::pjrt_engine_factory(MODEL.to_string(), artifacts.to_path_buf()),
        )
        .expect("coordinator start"),
    );
    println!(
        "workers ready in {:.2}s (artifact compiled per worker)\n",
        t0.elapsed().as_secs_f64()
    );

    // Synthetic camera frames: deterministic per request id.
    let frame = |req: usize| -> Vec<f32> {
        (0..n_in).map(|i| (((i * 31 + req * 97) % 255) as f32 / 127.5) - 1.0).collect()
    };

    // Phase 1: in-process load test.
    let t = Instant::now();
    let mut pending = Vec::with_capacity(REQUESTS);
    for r in 0..REQUESTS {
        pending.push((r, coord.submit(frame(r)).expect("queue accepts")));
    }
    let mut checked = 0usize;
    for (r, rx) in pending {
        let probs = rx.recv().unwrap().expect("inference ok");
        assert_eq!(probs.len(), 2);
        // Cross-check a sample of responses against the interpreter.
        if r % 20 == 0 {
            let reference = interp
                .run(&[TensorData::F32(frame(r))])
                .unwrap();
            let ref_probs = reference.outputs[0].as_f32().unwrap().to_vec();
            for (a, b) in probs.iter().zip(&ref_probs) {
                assert!((a - b).abs() < 1e-4, "req {r}: pjrt={a} interp={b}");
            }
            checked += 1;
        }
    }
    let wall = t.elapsed().as_secs_f64();
    let m = coord.metrics();
    println!("phase 1 — in-process: {REQUESTS} requests in {wall:.2}s");
    println!("  throughput : {:.1} req/s", REQUESTS as f64 / wall);
    println!(
        "  latency    : mean {:.1} ms, p50 {:.1} ms, p95 {:.1} ms, p99 {:.1} ms",
        m.mean_e2e_us / 1e3,
        m.p50_e2e_us / 1e3,
        m.p95_e2e_us / 1e3,
        m.p99_e2e_us / 1e3
    );
    println!(
        "  exec {:.1} ms mean, queue {:.1} ms mean, batch {:.1} req/drain, {checked} responses cross-checked vs interpreter ✓",
        m.mean_exec_us / 1e3,
        m.mean_queue_us / 1e3,
        m.mean_batch
    );

    // Phase 2: TCP front-end.
    let (addr_tx, addr_rx) = mpsc::channel();
    {
        let coord = coord.clone();
        std::thread::spawn(move || {
            coordinator::serve_tcp(coord, "127.0.0.1:0", Some(1), move |a| {
                let _ = addr_tx.send(a);
            })
        });
    }
    let addr = addr_rx.recv().unwrap();
    let mut stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let t = Instant::now();
    let tcp_requests = 10;
    for r in 0..tcp_requests {
        let csv: Vec<String> = frame(r).iter().map(|v| format!("{v}")).collect();
        stream.write_all(format!("{}\n", csv.join(",")).as_bytes()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK "), "tcp reply: {line}");
    }
    stream.write_all(b"QUIT\n").unwrap();
    println!(
        "\nphase 2 — TCP front-end: {tcp_requests} request/response round-trips in {:.2}s ✓",
        t.elapsed().as_secs_f64()
    );

    println!("\nserve example complete: all layers (Pallas kernels → JAX model → HLO text →");
    println!("PJRT runtime → coordinator → TCP) validated on one workload.");
}

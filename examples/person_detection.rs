//! Table 1, right half: MobileNet-v1 person detection with static vs
//! dynamic tensor allocation.
//!
//! Runs the int8 person-detection model inside the byte-accurate arena with
//! the paper's compact-after-every-operator defragmenter, measures the
//! actual compaction traffic, and feeds it to the calibrated Cortex-M7 cost
//! model — reproducing the 241KB → 55KB memory saving at sub-1% time and
//! energy overhead. Also ablates the §6 offline best-fit plan.
//!
//! ```text
//! cargo run --release --example person_detection
//! ```

use mcu_reorder::alloc::{AllocStats, StaticPlan};
use mcu_reorder::graph::DType;
use mcu_reorder::interp::{calibrate, ExecConfig, Interpreter, TensorData, WeightStore};
use mcu_reorder::mcu::{CostModel, NUCLEO_F767ZI};
use mcu_reorder::models;
use mcu_reorder::util::bench::Table;

fn main() {
    let g_i8 = models::mobilenet_v1_025(DType::I8);
    let g_f32 = models::mobilenet_v1_025(DType::F32);
    println!(
        "MobileNet-v1 0.25 96×96 person detection: {} ops, {:.0}KB params, {:.1}M MACs\n",
        g_i8.n_ops(),
        g_i8.model_size() as f64 / 1000.0,
        g_i8.total_macs() as f64 / 1e6
    );

    // Calibrate int8 quantization from one f32 run (synthetic "image").
    let ws_f32 = WeightStore::seeded_f32(&g_f32, 42);
    let n = g_f32.tensors[g_f32.inputs[0]].elems();
    let image: Vec<f32> = (0..n).map(|i| ((i * 31 % 255) as f32 / 127.5) - 1.0).collect();
    let ranges = calibrate(&g_f32, &ws_f32, &[TensorData::F32(image.clone())], 1 << 24)
        .expect("calibration");
    let ws_i8 = WeightStore::quantize_from(&g_i8, &ws_f32, &ranges);
    let in_q = ws_i8.qparams[&g_i8.inputs[0]];
    let qimage = TensorData::I8(in_q.quantize(&image));

    // Dynamic allocation: run in a 64KB arena (!) with defragmentation.
    let run = Interpreter::new(&g_i8, ws_i8, ExecConfig::with_capacity(64 * 1024))
        .run(&[qimage])
        .expect("fits in 64KB thanks to dynamic allocation");
    let person_prob = mcu_reorder::interp::quant::softmax_out_qparams()
        .dequantize(run.outputs[0].as_i8().unwrap());
    println!(
        "int8 inference inside a 64KB arena: P(person) = {:.3}, {} compactions moved {:.0}KB",
        person_prob[1],
        run.alloc.compactions,
        run.alloc.bytes_moved as f64 / 1000.0
    );

    // Static allocation baseline (old TFLM: every tensor pre-allocated).
    let static_plan = StaticPlan::no_reuse(&g_i8);
    let static_stats =
        AllocStats { high_water: static_plan.arena_bytes, ..AllocStats::default() };

    // Cost model calibrated to the paper's measured static row.
    let board = &NUCLEO_F767ZI;
    let model = CostModel::calibrated(&g_i8, &static_stats, board, 1.316, 728.0);
    let est_static = model.estimate(&g_i8, &static_stats, board);
    let est_dynamic = model.estimate(&g_i8, &run.alloc, board);

    let kb = |b: usize| format!("{:.0}KB", b as f64 / 1000.0);
    let mut t = Table::new(&["", "static alloc", "dynamic alloc", "paper"]);
    t.row(&[
        "peak memory (excl. overheads)".into(),
        kb(static_stats.high_water),
        kb(run.alloc.high_water),
        "241KB / 55KB (↓186KB)".into(),
    ]);
    t.row(&[
        "execution time".into(),
        format!("{:.0} ms", est_static.millis()),
        format!(
            "{:.0} ms (+{:.2}%)",
            est_dynamic.millis(),
            100.0 * (est_dynamic.seconds / est_static.seconds - 1.0)
        ),
        "1316 / 1325 ms (+0.68%)".into(),
    ]);
    t.row(&[
        "energy use".into(),
        format!("{:.0} mJ", est_static.energy_mj),
        format!(
            "{:.0} mJ (+{:.2}%)",
            est_dynamic.energy_mj,
            100.0 * (est_dynamic.energy_mj / est_static.energy_mj - 1.0)
        ),
        "728 / 735 mJ (+0.97%)".into(),
    ]);
    t.print();

    // §6 extension: offline lifetime-aware placement removes run-time
    // compaction entirely.
    let planned = StaticPlan::best_fit(&g_i8, &g_i8.default_order());
    println!(
        "\n§6 offline best-fit plan: {} (no run-time compaction, 0 bytes moved)",
        kb(planned.arena_bytes)
    );
}

//! Table 1, left half: deploying the SwiftNet-style cell network onto a
//! 512KB-SRAM MCU is only possible with the optimal operator order.
//!
//! Walks the exact flow of §5: analyze the model, compute the optimal
//! schedule with Algorithm 1, add the framework overhead, and check both
//! schedules against the NUCLEO-F767ZI's SRAM. Then proves it on real
//! buffers: the default order OOMs inside the budgeted arena, the optimal
//! order completes.
//!
//! ```text
//! cargo run --release --example deploy_swiftnet
//! ```

use mcu_reorder::graph::DType;
use mcu_reorder::interp::{ExecConfig, Interpreter, TensorData, WeightStore};
use mcu_reorder::mcu::{CostModel, DeployReport, OverheadModel, NUCLEO_F767ZI};
use mcu_reorder::models;
use mcu_reorder::sched;
use mcu_reorder::util::bench::Table;

fn main() {
    let g = models::swiftnet_cell(DType::I8);
    println!(
        "SwiftNet-style cell network: {} ops, {} tensors, {:.0}KB parameters\n",
        g.n_ops(),
        g.n_tensors(),
        g.model_size() as f64 / 1000.0
    );

    let default_peak = sched::peak_of(&g, &g.default_order());
    let t0 = std::time::Instant::now();
    let (opt, stats) = sched::optimal(&g).expect("schedulable");
    let solve_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "Algorithm 1 solved in {solve_ms:.1} ms ({} memo states, {} expansions)\n",
        stats.states, stats.expansions
    );

    let overhead = OverheadModel::default();
    let board = &NUCLEO_F767ZI;
    let rep_d = DeployReport::new(&g, default_peak, board, &overhead);
    let rep_o = DeployReport::new(&g, opt.peak_bytes, board, &overhead);

    let kb = |b: usize| format!("{:.0}KB", b as f64 / 1000.0);
    let mut t = Table::new(&["", "default order", "optimal order", "paper"]);
    t.row(&[
        "peak memory (excl. overheads)".into(),
        kb(default_peak),
        kb(opt.peak_bytes),
        "351KB / 301KB".into(),
    ]);
    t.row(&[
        "framework overhead".into(),
        kb(rep_d.overhead_bytes),
        kb(rep_o.overhead_bytes),
        "≈200KB".into(),
    ]);
    t.row(&[
        format!("fits {} ({}KB SRAM)?", board.name, board.sram_bytes / 1024),
        if rep_d.fits_sram { "yes" } else { "NO" }.into(),
        if rep_o.fits_sram { "yes" } else { "NO" }.into(),
        "no / yes".into(),
    ]);
    t.print();

    // Modeled execution time/energy for the optimal order (the default
    // order cannot run at all — the paper reports N/A).
    let stats_alloc = mcu_reorder::alloc::AllocStats::default();
    let mnet = models::mobilenet_v1_025(DType::I8);
    let model = CostModel::calibrated(&mnet, &stats_alloc, board, 1.316, 728.0);
    let est = model.estimate(&g, &stats_alloc, board);
    println!(
        "\nmodeled execution: {:.0} ms, {:.0} mJ  (paper: 10243 ms, 8775 mJ)",
        est.millis(),
        est.energy_mj
    );

    // Prove it on real buffers at the real SRAM budget (f32 exec = 4× i8).
    let arena = (board.sram_bytes - rep_o.overhead_bytes) * 4;
    let g32 = models::swiftnet_cell(DType::F32);
    let ws = WeightStore::seeded_f32(&g32, 42);
    let n = g32.tensors[g32.inputs[0]].elems();
    let input = TensorData::F32((0..n).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect());

    let default_run = Interpreter::new(&g32, ws.clone(), ExecConfig::with_capacity(arena))
        .run(&[input.clone()]);
    match default_run {
        Err(e) => println!("\ndefault order in the SRAM-budget arena: OOM as expected ({e})"),
        Ok(_) => println!("\nunexpected: default order fit"),
    }
    let cfg = ExecConfig { order: Some(opt.order), ..ExecConfig::with_capacity(arena) };
    let run = Interpreter::new(&g32, ws, cfg).run(&[input]).expect("optimal order fits");
    let probs = run.outputs[0].as_f32().unwrap();
    println!("optimal order in the same arena: completed, probs = {probs:?}");
}

//! Quickstart: the paper's Figure-1 example graph end to end.
//!
//! Builds the 7-operator graph, prints the Appendix-A working-set tables for
//! the default and optimal operator orders (Figures 2 and 3), and executes
//! both schedules in the byte-accurate SRAM arena to show the outputs are
//! identical while the memory bottleneck drops 5216 B → 4960 B.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use mcu_reorder::interp::{ExecConfig, Interpreter, TensorData, WeightStore};
use mcu_reorder::models;
use mcu_reorder::sched;

fn main() {
    let g = models::figure1();
    println!("== {} ({} operators) ==\n", g.name, g.n_ops());

    // Figure 2: the default (as-built) order.
    let default_trace = sched::simulate(&g, &g.default_order());
    println!("-- default operator order (Figure 2) --");
    print!("{}", default_trace.render_table(&g));

    // Algorithm 1: find the optimal order.
    let (optimal, stats) = sched::optimal(&g).expect("schedulable");
    println!(
        "\nAlgorithm 1: {} memo states, {} expansions → optimal order (1-based): {:?}",
        stats.states,
        stats.expansions,
        optimal.order.iter().map(|o| o + 1).collect::<Vec<_>>()
    );

    // Figure 3: the optimised order.
    let optimal_trace = sched::simulate(&g, &optimal.order);
    println!("\n-- optimal operator order (Figure 3) --");
    print!("{}", optimal_trace.render_table(&g));

    println!(
        "\npeak memory: {} B (default) → {} B (optimal), saving {} B ({:.1}%)",
        default_trace.peak_bytes,
        optimal_trace.peak_bytes,
        default_trace.peak_bytes - optimal_trace.peak_bytes,
        100.0 * (1.0 - optimal_trace.peak_bytes as f64 / default_trace.peak_bytes as f64)
    );

    // Execute both schedules on real buffers: same bytes out, smaller arena.
    let input = TensorData::U8((0..1568).map(|i| (i % 251) as u8).collect());
    let run_default = Interpreter::new(&g, WeightStore::default(), ExecConfig::with_capacity(8192))
        .run(&[input.clone()])
        .expect("default run");
    let cfg = ExecConfig { order: Some(optimal.order.clone()), ..ExecConfig::with_capacity(8192) };
    let run_optimal = Interpreter::new(&g, WeightStore::default(), cfg)
        .run(&[input])
        .expect("optimal run");
    assert_eq!(run_default.outputs, run_optimal.outputs);
    println!(
        "\nexecuted both schedules: outputs identical; arena high water {} B vs {} B",
        run_default.alloc.high_water, run_optimal.alloc.high_water
    );

    // The optimised schedule runs in an arena of exactly its peak:
    let cfg = ExecConfig {
        order: Some(optimal.order),
        ..ExecConfig::with_capacity(optimal_trace.peak_bytes)
    };
    let tight = Interpreter::new(&g, WeightStore::default(), cfg)
        .run(&[TensorData::U8((0..1568).map(|i| (i % 251) as u8).collect())])
        .expect("fits exactly in the optimal peak");
    assert_eq!(tight.alloc.high_water, optimal_trace.peak_bytes);
    println!("re-ran in an arena of exactly {} B — fits.", optimal_trace.peak_bytes);

    println!("\n(`mcu-reorder dot --model figure1 | dot -Tpng` draws Figure 1)");
}

//! Bench: partial execution (operator splitting along rows / columns /
//! channels) composed with operator reordering across the model zoo.
//!
//! For every model: peak SRAM under (a) the as-built default order, (b)
//! reorder-only (Algorithm 1 — the paper's result), (c) the best
//! *row-only* plan (the same beam planner restricted to the row axis),
//! (d) the PR-3 beam planner over all (segment, factor, axis) moves with
//! materialized `ConcatSlices` joins, and (e) the full planner with
//! streaming concat elision (write-through slices, no join copy), plus
//! which axes the winning plan uses and the halo-recompute overhead it
//! pays. Results are written machine-readably to
//! `BENCH_partial_exec.json` so the trajectory is tracked across PRs and
//! gated in CI (tools/bench_compare).

use mcu_reorder::graph::{DType, Graph};
use mcu_reorder::mcu::{CostModel, SplitOverhead, NUCLEO_F767ZI};
use mcu_reorder::models;
use mcu_reorder::sched;
use mcu_reorder::split::{self, SplitOptions};
use mcu_reorder::util::bench::{black_box, write_json_report, Bencher, Table};
use mcu_reorder::util::rng::Rng;

fn main() {
    let mut zoo: Vec<(String, Graph)> = vec![
        ("figure1".into(), models::figure1()),
        ("mobilenet".into(), models::mobilenet_v1_025(DType::I8)),
        ("swiftnet".into(), models::swiftnet_cell(DType::I8)),
        ("resnet".into(), models::resnet_micro(DType::I8)),
        ("audionet".into(), models::audionet(DType::I8)),
        ("streamnet".into(), models::streamnet(DType::I8)),
        ("tiny".into(), models::tiny_cnn(DType::I8)),
    ];
    // Synthetic DAGs: their operators are cost-model nodes without spatial
    // shape, so splitting cannot apply — they are included to show the
    // search degrades gracefully to reorder-only, not to flatter it.
    let mut rng = Rng::new(2025);
    for i in 0..2 {
        zoo.push((format!("synth-sp{i}"), models::synth::series_parallel(&mut rng, 3, 2)));
    }

    let opts = SplitOptions::default();
    let cost = CostModel::cortex_m7_reference();
    let kb = |b: usize| format!("{:.1}KB", b as f64 / 1000.0);
    let mut table = Table::new(&[
        "model",
        "default",
        "reorder-only",
        "rows-only",
        "beam (PR-3)",
        "elided",
        "axes",
        "vs beam",
        "recompute",
    ]);
    let mut metrics: Vec<(String, f64)> = Vec::new();
    let mut beam_wins = 0usize;
    let mut elide_wins = 0usize;

    for (name, g) in &zoo {
        let default_peak = sched::peak_of(g, &g.default_order());
        let rows = split::optimize(g, &opts.clone().rows_only().materialized())
            .expect("rows-only search");
        let mat = split::optimize(g, &opts.clone().materialized()).expect("PR-3 beam search");
        let outcome = split::optimize(g, &opts).expect("elided beam search");
        let reorder_peak = outcome.base_peak;
        let rows_peak = rows.schedule.peak_bytes;
        let mat_peak = mat.schedule.peak_bytes;
        let elided_peak = outcome.schedule.peak_bytes;
        let ov = SplitOverhead::measure(&cost, g, &outcome.graph, &NUCLEO_F767ZI);
        let axes = if outcome.steps.is_empty() {
            "-".to_string()
        } else {
            outcome
                .axes_used()
                .iter()
                .map(|a| a.name())
                .collect::<Vec<_>>()
                .join("+")
        };
        let vs_mat = 100.0 * (1.0 - elided_peak as f64 / mat_peak as f64);
        if mat_peak < rows_peak {
            beam_wins += 1;
        }
        if elided_peak < mat_peak {
            elide_wins += 1;
        }
        table.row(&[
            name.clone(),
            kb(default_peak),
            kb(reorder_peak),
            kb(rows_peak),
            kb(mat_peak),
            kb(elided_peak),
            axes,
            format!("-{vs_mat:.1}%"),
            format!("+{:.1}% MACs", 100.0 * ov.recompute_frac()),
        ]);
        for (key, v) in [
            ("default_peak", default_peak as f64),
            ("reorder_peak", reorder_peak as f64),
            ("rows_only_peak", rows_peak as f64),
            ("split_reorder_peak", mat_peak as f64),
            ("elided_peak", elided_peak as f64),
            ("segments", outcome.steps.len() as f64),
            ("elided_segments", outcome.elided_steps() as f64),
            ("recompute_frac", ov.recompute_frac()),
            ("weight_traffic_ratio", ov.weight_traffic_ratio()),
            ("elided_join_bytes", ov.elided_join_bytes as f64),
        ] {
            metrics.push((format!("{name}.{key}"), v));
        }
    }
    println!("=== partial execution × reordering: peak SRAM per split axis ===\n");
    table.print();
    println!(
        "\n(reorder-only = the paper's Algorithm 1; rows-only = the same beam planner \
         restricted to the row axis; beam (PR-3) = all axes with materialized \
         ConcatSlices joins; elided = the full planner, which also streams joins \
         away through write-through slices when that lowers the peak)"
    );
    println!("beam plan strictly beats the best row-only plan on {beam_wins} model(s)");
    println!("join elision strictly beats the PR-3 beam plan on {elide_wins} model(s)");

    // Timings of the search itself.
    let mut bch = Bencher::quick();
    let mnet = models::mobilenet_v1_025(DType::I8);
    let audio = models::audionet(DType::I8);
    bch.bench("partial_exec/mobilenet-split-search", || {
        black_box(split::optimize(&mnet, &SplitOptions::quick()).unwrap())
    });
    bch.bench("partial_exec/audionet-beam-search", || {
        black_box(
            split::optimize(&audio, &SplitOptions { max_rounds: 2, ..SplitOptions::quick() })
                .unwrap(),
        )
    });
    bch.summary();

    match write_json_report("partial_exec", &metrics, bch.results()) {
        Ok(p) => println!("\nwrote {p}"),
        Err(e) => eprintln!("could not write JSON report: {e}"),
    }
}

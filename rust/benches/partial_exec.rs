//! Bench: partial execution (spatial operator splitting) composed with
//! operator reordering across the model zoo.
//!
//! For every model: peak SRAM under (a) the as-built default order, (b)
//! reorder-only (Algorithm 1 — the paper's result), (c) split-only (the
//! split graph in its as-built order), and (d) split+reorder (the full
//! co-optimization). Also reports the halo-recompute overhead the split
//! pays. Results are written machine-readably to `BENCH_partial_exec.json`
//! so the trajectory is tracked across PRs.

use mcu_reorder::graph::{DType, Graph};
use mcu_reorder::mcu::{CostModel, SplitOverhead, NUCLEO_F767ZI};
use mcu_reorder::models;
use mcu_reorder::sched;
use mcu_reorder::split::{self, SplitOptions};
use mcu_reorder::util::bench::{black_box, write_json_report, Bencher, Table};
use mcu_reorder::util::rng::Rng;

fn main() {
    let mut zoo: Vec<(String, Graph)> = vec![
        ("figure1".into(), models::figure1()),
        ("mobilenet".into(), models::mobilenet_v1_025(DType::I8)),
        ("swiftnet".into(), models::swiftnet_cell(DType::I8)),
        ("resnet".into(), models::resnet_micro(DType::I8)),
        ("tiny".into(), models::tiny_cnn(DType::I8)),
    ];
    // Synthetic DAGs: their operators are cost-model nodes without spatial
    // shape, so splitting cannot apply — they are included to show the
    // search degrades gracefully to reorder-only, not to flatter it.
    let mut rng = Rng::new(2025);
    for i in 0..2 {
        zoo.push((format!("synth-sp{i}"), models::synth::series_parallel(&mut rng, 3, 2)));
    }

    let opts = SplitOptions::default();
    let cost = CostModel::cortex_m7_reference();
    let kb = |b: usize| format!("{:.1}KB", b as f64 / 1000.0);
    let mut table = Table::new(&[
        "model",
        "default",
        "reorder-only",
        "split-only",
        "split+reorder",
        "vs reorder",
        "recompute",
    ]);
    let mut metrics: Vec<(String, f64)> = Vec::new();

    for (name, g) in &zoo {
        let default_peak = sched::peak_of(g, &g.default_order());
        let outcome = split::optimize(g, &opts).expect("split search");
        let reorder_peak = outcome.base_peak;
        let split_only = sched::peak_of(&outcome.graph, &outcome.graph.default_order());
        let both = outcome.schedule.peak_bytes;
        let ov = SplitOverhead::measure(&cost, g, &outcome.graph, &NUCLEO_F767ZI);
        let saving = 100.0 * (1.0 - both as f64 / reorder_peak as f64);
        table.row(&[
            name.clone(),
            kb(default_peak),
            kb(reorder_peak),
            kb(split_only),
            kb(both),
            format!("-{saving:.1}%"),
            format!("+{:.1}% MACs", 100.0 * ov.recompute_frac()),
        ]);
        for (key, v) in [
            ("default_peak", default_peak as f64),
            ("reorder_peak", reorder_peak as f64),
            ("split_only_peak", split_only as f64),
            ("split_reorder_peak", both as f64),
            ("segments", outcome.steps.len() as f64),
            ("recompute_frac", ov.recompute_frac()),
        ] {
            metrics.push((format!("{name}.{key}"), v));
        }
    }
    println!("=== partial execution × reordering: peak SRAM ===\n");
    table.print();
    println!("\n(reorder-only = the paper's Algorithm 1; split+reorder breaks its single-operator floor)");

    // Timings of the search itself.
    let mut bch = Bencher::quick();
    let mnet = models::mobilenet_v1_025(DType::I8);
    let swift = models::swiftnet_cell(DType::I8);
    bch.bench("partial_exec/mobilenet-split-search", || {
        black_box(split::optimize(&mnet, &SplitOptions::quick()).unwrap())
    });
    bch.bench("partial_exec/swiftnet-split-search", || {
        black_box(split::optimize(&swift, &SplitOptions::quick()).unwrap())
    });
    bch.summary();

    match write_json_report("partial_exec", &metrics, bch.results()) {
        Ok(p) => println!("\nwrote {p}"),
        Err(e) => eprintln!("could not write JSON report: {e}"),
    }
}

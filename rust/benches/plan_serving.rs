//! Bench: the plan-serving coordinator under a simulated fleet.
//!
//! Phase A drives the service in-process: every zoo model plus the
//! imported int8 TFLite fixture across all four board profiles (32
//! distinct plan keys), first as a full coverage sweep, then under a
//! zipf-distributed request stream (rank r drawn with weight 1/(r+1))
//! against an LRU plan cache that is deliberately smaller than the
//! working set. Because the cache uses a strictly-increasing recency
//! tick and the draw sequence is a fixed xoshiro256** stream, the
//! hit/miss/eviction counters are exactly reproducible — the Python
//! mirror (tools/schedule_mirror --serving-baseline) simulates the same
//! stream and CI cross-checks the counts.
//!
//! Phase B serves the same workload over the TCP front-end (UPLOAD +
//! PLAN lines from concurrent clients) and reports plans/sec and
//! p50/p99 round-trip latency. Phase C exercises admission control on a
//! paused service (bounded queue, explicit shed). Cached-vs-fresh
//! bit-identity and service-vs-direct-API bit-identity are asserted on
//! a separate service so they cannot disturb the mirrored counters.
//!
//! Results land in `BENCH_serving.json`; tools/bench_compare gates the
//! `_floor` metrics (served plans, zipf hits, coverage, sheds).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{mpsc, Arc};
use std::time::Instant;

use mcu_reorder::api::{ModelSource, OptimizeRequest};
use mcu_reorder::coordinator::{
    serve_plans_tcp, ModelRef, PlanRequest, PlanServeConfig, PlanService, Submission,
};
use mcu_reorder::graph::DType;
use mcu_reorder::mcu::boards;
use mcu_reorder::models;
use mcu_reorder::split::SplitOptions;
use mcu_reorder::util::bench::{write_json_report, BenchResult, Table};
use mcu_reorder::util::rng::Rng;
use mcu_reorder::util::stats;

/// Seed shared with the Python mirror (arXiv:1910.05110 backwards).
const SEED: u64 = 19_100_511;
/// Cache capacity — deliberately smaller than the 32-key working set.
const CACHE_CAP: usize = 24;
const ZIPF_DRAWS: usize = 400;
const TCP_CLIENTS: usize = 4;
const TCP_REQS_PER_CLIENT: usize = 100;

fn cfg(workers: usize) -> PlanServeConfig {
    PlanServeConfig {
        workers,
        cache_cap: CACHE_CAP,
        queue_cap: 64,
        split: SplitOptions::quick(),
        ..Default::default()
    }
}

/// The fleet's model set: the full zoo plus the uploaded TFLite fixture.
fn model_refs(upload_hash: u64) -> Vec<ModelRef> {
    let mut refs: Vec<ModelRef> =
        models::MODEL_NAMES.iter().map(|n| ModelRef::Zoo(n.to_string())).collect();
    refs.push(ModelRef::Uploaded(upload_hash));
    refs
}

/// Rank r maps to (model r % n_models, board r / n_models), budget = board
/// SRAM. Each rank is a distinct plan-cache key.
fn req_for(refs: &[ModelRef], rank: usize) -> PlanRequest {
    PlanRequest {
        model: refs[rank % refs.len()].clone(),
        board: boards::ALL_BOARDS[rank / refs.len()],
        budget: None,
    }
}

/// Integer zipf(1) weights, identical to the Python mirror: w_r = 1e6/(r+1).
fn zipf_weights(n: usize) -> Vec<u64> {
    (0..n).map(|r| 1_000_000 / (r as u64 + 1)).collect()
}

fn zipf_rank(rng: &mut Rng, weights: &[u64]) -> usize {
    let total: u64 = weights.iter().sum();
    let mut draw = rng.below(total);
    for (r, w) in weights.iter().enumerate() {
        if draw < *w {
            return r;
        }
        draw -= w;
    }
    weights.len() - 1
}

fn main() {
    let fixture = mcu_reorder::tflite::fixtures::ensure(mcu_reorder::tflite::fixtures::INT8_FIXTURE)
        .expect("tflite fixture generation (python3 required)");
    let fixture_bytes = std::fs::read(&fixture).expect("reading tflite fixture");
    let mut metrics: Vec<(String, f64)> = Vec::new();

    // --- Phase A: fleet coverage + zipf stream, in-process. ---
    let svc = PlanService::start(cfg(1));
    let hash = svc
        .upload("cnn_int8.tflite".to_string(), fixture_bytes.clone())
        .expect("fixture upload");
    let refs = model_refs(hash);
    let n_ranks = refs.len() * boards::ALL_BOARDS.len();

    println!("=== plan serving: fleet coverage (zoo + tflite × all boards) ===\n");
    let mut table =
        Table::new(&["model", "board", "budget", "peak", "reordered", "segments", "fits"]);
    for rank in 0..n_ranks {
        let plan = svc.plan(&req_for(&refs, rank)).expect("coverage plan");
        table.row(&[
            plan.model.clone(),
            plan.board.to_string(),
            format!("{}", plan.budget),
            format!("{}", plan.peak_bytes),
            format!("{}", plan.reordered_peak),
            format!("{}", plan.segments),
            format!("{}", plan.fits),
        ]);
    }
    table.print();
    let s1 = svc.stats();
    assert_eq!(s1.served as usize, n_ranks, "every coverage request must be served");
    assert_eq!(s1.cache.misses as usize, n_ranks, "coverage keys are all distinct");
    assert_eq!(
        s1.cache.evictions as usize,
        n_ranks - CACHE_CAP,
        "working set exceeds the cache by exactly n_ranks - cap"
    );

    let weights = zipf_weights(n_ranks);
    let mut rng = Rng::new(SEED);
    for _ in 0..ZIPF_DRAWS {
        let rank = zipf_rank(&mut rng, &weights);
        svc.plan(&req_for(&refs, rank)).expect("zipf plan");
    }
    let s2 = svc.stats();
    svc.shutdown();
    let zipf_hits = s2.cache.hits - s1.cache.hits;
    let zipf_misses = ZIPF_DRAWS as u64 - zipf_hits;
    let hit_rate = zipf_hits as f64 / ZIPF_DRAWS as f64;
    println!(
        "\nzipf stream: {ZIPF_DRAWS} draws over {n_ranks} ranks, cache {CACHE_CAP} → \
         {zipf_hits} hits / {zipf_misses} misses ({:.1}% hit rate), {} evictions",
        100.0 * hit_rate,
        s2.cache.evictions
    );
    assert_eq!(s2.served as usize, n_ranks + ZIPF_DRAWS);
    assert!(hit_rate >= 0.8, "zipf hit rate {hit_rate:.3} below the 0.8 acceptance floor");

    metrics.push(("fleet.plans_served_floor".into(), s2.served as f64));
    metrics.push(("fleet.zipf_hits_floor".into(), zipf_hits as f64));
    metrics.push(("fleet.zipf_hit_rate_pct".into(), 100.0 * hit_rate));
    metrics.push(("fleet.zipf_misses".into(), zipf_misses as f64));
    metrics.push(("fleet.coverage_models_floor".into(), refs.len() as f64));
    metrics.push(("fleet.coverage_boards_floor".into(), boards::ALL_BOARDS.len() as f64));
    metrics.push(("fleet.cache_evictions".into(), s2.cache.evictions as f64));
    metrics.push(("fleet.cache_entries".into(), s2.cache.entries as f64));

    // --- Cached == fresh bit-identity, on a separate service so the
    //     mirrored counters above stay untouched. ---
    let svc2 = PlanService::start(cfg(1));
    let h2 = svc2
        .upload("cnn_int8.tflite".to_string(), fixture_bytes.clone())
        .expect("fixture re-upload");
    assert_eq!(h2, hash, "content hash must be a pure function of the bytes");
    for rank in [0usize, 9, 7] {
        let req = req_for(&refs, rank);
        let fresh = svc2.plan(&req).expect("fresh plan");
        let cached = svc2.plan(&req).expect("cached plan");
        assert_eq!(*fresh.json, *cached.json, "rank {rank}: cached JSON must be bit-identical");
        assert_eq!(*fresh.summary, *cached.summary, "rank {rank}: cached summary must match");
    }
    // Service plan == direct API facade call, byte for byte.
    let board = boards::ALL_BOARDS[1];
    let via_service = svc2
        .plan(&PlanRequest {
            model: ModelRef::Zoo("mobilenet".to_string()),
            board,
            budget: None,
        })
        .expect("service plan");
    let direct = OptimizeRequest {
        source: ModelSource::Zoo { name: "mobilenet".to_string(), dtype: DType::I8 },
        budget: Some(board.sram_bytes),
        board,
        split: Some(SplitOptions::quick()),
        compare_materialized: false,
        trace: false,
    }
    .run()
    .expect("direct optimize");
    assert_eq!(
        direct.to_json().to_string(),
        *via_service.json,
        "service plans must be byte-identical to direct api::OptimizeRequest runs"
    );
    svc2.shutdown();
    println!("bit-identity: cached == fresh == direct API (3 ranks + mobilenet probe)");

    // --- Phase B: the TCP front-end under concurrent clients. ---
    let svc3 = PlanService::start(cfg(2));
    let (addr_tx, addr_rx) = mpsc::channel();
    let srv = svc3.clone();
    let server = std::thread::spawn(move || {
        serve_plans_tcp(srv, "127.0.0.1:0", Some(TCP_CLIENTS), move |a| {
            let _ = addr_tx.send(a);
        })
        .expect("plan server");
    });
    let addr = addr_rx.recv().expect("server address");

    let t0 = Instant::now();
    let mut clients = Vec::new();
    for c in 0..TCP_CLIENTS {
        let bytes = fixture_bytes.clone();
        clients.push(std::thread::spawn(move || -> Vec<f64> {
            let stream = TcpStream::connect(addr).expect("connect");
            let mut writer = stream.try_clone().expect("clone stream");
            let mut reader = BufReader::new(stream);
            let mut line = String::new();

            writer
                .write_all(format!("UPLOAD cnn_int8.tflite {}\n", bytes.len()).as_bytes())
                .expect("upload header");
            writer.write_all(&bytes).expect("upload body");
            reader.read_line(&mut line).expect("upload reply");
            let hash = line.trim().strip_prefix("OK ").expect("upload accepted").to_string();

            let refs = model_refs(u64::from_str_radix(&hash, 16).expect("upload hash"));
            let weights = zipf_weights(refs.len() * boards::ALL_BOARDS.len());
            let mut rng = Rng::new(SEED ^ (c as u64 + 1));
            let mut lat_us = Vec::with_capacity(TCP_REQS_PER_CLIENT);
            for _ in 0..TCP_REQS_PER_CLIENT {
                let rank = zipf_rank(&mut rng, &weights);
                let req = req_for(&refs, rank);
                let model = match &req.model {
                    ModelRef::Zoo(name) => name.clone(),
                    ModelRef::Uploaded(h) => format!("hash:{h:016x}"),
                };
                let t = Instant::now();
                writer
                    .write_all(format!("PLAN {model} {}\n", req.board.name).as_bytes())
                    .expect("plan request");
                line.clear();
                reader.read_line(&mut line).expect("plan reply");
                lat_us.push(t.elapsed().as_secs_f64() * 1e6);
                assert!(line.starts_with("OK "), "PLAN failed: {line}");
            }
            writer.write_all(b"QUIT\n").expect("quit");
            lat_us
        }));
    }
    server.join().expect("server thread");
    let mut lat_us: Vec<f64> = Vec::new();
    for c in clients {
        lat_us.extend(c.join().expect("client thread"));
    }
    let wall = t0.elapsed().as_secs_f64();
    let s3 = svc3.stats();
    svc3.shutdown();
    let total_reqs = (TCP_CLIENTS * TCP_REQS_PER_CLIENT) as f64;
    let plans_per_sec = total_reqs / wall;
    let p50 = stats::percentile(&lat_us, 50.0);
    let p99 = stats::percentile(&lat_us, 99.0);
    println!(
        "\ntcp: {TCP_CLIENTS} clients × {TCP_REQS_PER_CLIENT} reqs → {:.0} plans/sec, \
         p50 {:.0} µs, p99 {:.0} µs ({} coalesced, cache {}/{} hit/miss)",
        plans_per_sec, p50, p99, s3.coalesced, s3.cache.hits, s3.cache.misses
    );
    assert_eq!(s3.served, total_reqs as u64, "every TCP request must be served");
    metrics.push(("tcp.plans_per_sec".into(), plans_per_sec));
    metrics.push(("tcp.p50_us".into(), p50));
    metrics.push(("tcp.p99_us".into(), p99));
    metrics.push(("tcp.coalesced".into(), s3.coalesced as f64));

    // --- Phase C: admission control on a paused service. ---
    let svc4 = PlanService::start_paused(PlanServeConfig { queue_cap: 8, ..cfg(1) });
    let mut shed = 0usize;
    let mut pending = Vec::new();
    for i in 0..12usize {
        let req = PlanRequest {
            model: ModelRef::Zoo("figure1".to_string()),
            board: boards::ALL_BOARDS[0],
            budget: Some(4_000_000 + i),
        };
        match svc4.submit(&req).expect("submit") {
            Submission::Shed { .. } => shed += 1,
            Submission::Pending(rx) => pending.push(rx),
            Submission::Ready(_) => unreachable!("paused service cannot have cached plans"),
        }
    }
    svc4.shutdown();
    for rx in pending {
        let reply = rx.recv().expect("queued jobs must be failed on shutdown, not dropped");
        assert!(reply.is_err(), "a paused service cannot have produced a plan");
    }
    println!("admission control: 12 submits into queue_cap 8 → {shed} shed");
    assert_eq!(shed, 4, "queue_cap 8 must shed exactly the 4 overflow requests");
    metrics.push(("fleet.shed_floor".into(), shed as f64));

    let timings = [BenchResult {
        name: "serving/tcp-plan-roundtrip".into(),
        iters: lat_us.len() as u64,
        mean_ns: stats::mean(&lat_us) * 1e3,
        stddev_ns: stats::stddev(&lat_us) * 1e3,
        min_ns: stats::min(&lat_us) * 1e3,
        max_ns: stats::max(&lat_us) * 1e3,
    }];
    match write_json_report("serving", &metrics, &timings) {
        Ok(p) => println!("\nwrote {p}"),
        Err(e) => eprintln!("could not write JSON report: {e}"),
    }
}

//! Bench + regeneration of Figure 1 / Figure 2 / Figure 3 (Appendix A).
//!
//! Prints the per-operator working-set tables for the default and optimal
//! orders of the example graph and times the analysis primitives
//! (simulation, Algorithm 1, exhaustive enumeration).

use mcu_reorder::models;
use mcu_reorder::sched;
use mcu_reorder::util::bench::{black_box, write_json_report, Bencher, Table};

fn main() {
    let g = models::figure1();

    println!("=== Figure 2: default operator order ===");
    let fig2 = sched::simulate(&g, &g.default_order());
    print!("{}", fig2.render_table(&g));

    let (opt, stats) = sched::optimal(&g).unwrap();
    println!("\n=== Figure 3: optimal operator order (Algorithm 1) ===");
    let fig3 = sched::simulate(&g, &opt.order);
    print!("{}", fig3.render_table(&g));

    let bf = sched::bruteforce(&g, usize::MAX).unwrap();
    let mut t = Table::new(&["quantity", "reproduction", "paper"]);
    t.row(&["default-order peak".into(), format!("{} B", fig2.peak_bytes), "5216 B".into()]);
    t.row(&["optimal-order peak".into(), format!("{} B", fig3.peak_bytes), "4960 B".into()]);
    t.row(&["worst-order peak".into(), format!("{} B", bf.worst.peak_bytes), "—".into()]);
    t.row(&["topological orders".into(), format!("{}", bf.orders_enumerated), "—".into()]);
    t.row(&["DP memo states".into(), format!("{}", stats.states), "—".into()]);
    println!();
    t.print();
    println!();

    let mut b = Bencher::new();
    b.bench("figure1/simulate-default", || black_box(sched::simulate(&g, &g.default_order())));
    b.bench("figure1/peak_of-default", || black_box(sched::peak_of(&g, &g.default_order())));
    b.bench("figure1/optimal-dp", || black_box(sched::optimal(&g).unwrap()));
    b.bench("figure1/optimal-bnb", || black_box(sched::optimal_bnb(&g).unwrap()));
    b.bench("figure1/bruteforce", || black_box(sched::bruteforce(&g, usize::MAX).unwrap()));
    b.summary();

    let metrics = vec![
        ("default_peak".to_string(), fig2.peak_bytes as f64),
        ("optimal_peak".to_string(), fig3.peak_bytes as f64),
        ("worst_peak".to_string(), bf.worst.peak_bytes as f64),
        ("orders_enumerated".to_string(), bf.orders_enumerated as f64),
        ("dp_states".to_string(), stats.states as f64),
    ];
    match write_json_report("figure1", &metrics, b.results()) {
        Ok(p) => println!("\nwrote {p}"),
        Err(e) => eprintln!("could not write JSON report: {e}"),
    }
}

//! Bench: the AOT C codegen backend over the deployment zoo.
//!
//! Lowers every zoo model (in each dtype the audit pipeline prepares it
//! for) plus the imported int8 TFLite fixture through the reorder-only
//! pipeline into a deployable C artifact, and reports two fully
//! deterministic size metrics per artifact:
//!
//!   - `{label}.arena_bytes`  — the static `.bss` arena the emitted C
//!     declares (== the certified best-fit plan arena; `generate`
//!     refuses to emit if they disagree)
//!   - `{label}.rodata_bytes` — the `static const` weight tables baked
//!     into the source
//!
//! Reorder-only plans are used on purpose: the DP order and the best-fit
//! placement are bit-reproducible by the independent Python mirror
//! (`tools/schedule_mirror/mirror.py --codegen-baseline`), which is what
//! lets CI gate these numbers without trusting this binary. The
//! `tflitecnn_i8` arena is the one exception — the importer and the
//! mirror assign different tensor ids, which legitimately changes
//! best-fit placement order — so only its rodata is mirrored; its arena
//! rides along ungated until a confirmed value lands in the baseline.
//!
//! Results land in `BENCH_codegen.json`; `tools/bench_compare` gates
//! every `*_bytes` metric (lower is better) against
//! `BENCH_baseline/codegen.json`.

use std::time::Instant;

use mcu_reorder::api::{ModelSource, OptimizeRequest};
use mcu_reorder::codegen::{generate, weights_for_report};
use mcu_reorder::graph::DType;
use mcu_reorder::models;
use mcu_reorder::tflite::fixtures;
use mcu_reorder::trace::audit;
use mcu_reorder::util::bench::{write_json_report, BenchResult, Table};
use mcu_reorder::util::stats;

fn main() {
    let mut cases: Vec<(String, ModelSource)> = Vec::new();
    for name in models::MODEL_NAMES {
        for p in audit::prepare_zoo(name).expect("prepare zoo") {
            let dtype = DType::from_name(p.dtype).expect("zoo dtype");
            cases.push((
                format!("{name}_{}", p.dtype),
                ModelSource::Zoo { name: name.to_string(), dtype },
            ));
        }
    }
    let fixture = fixtures::ensure(fixtures::INT8_FIXTURE).expect("fixture");
    cases.push((
        "tflitecnn_i8".to_string(),
        ModelSource::TflitePath(fixture.display().to_string()),
    ));

    let mut metrics: Vec<(String, f64)> = Vec::new();
    let mut table =
        Table::new(&["artifact", "dtype", "ops", "arena B", "rodata B", "gen ms"]);
    let mut gen_us: Vec<f64> = Vec::new();

    for (label, source) in cases {
        let report = OptimizeRequest::reorder_only(source)
            .run()
            .unwrap_or_else(|e| panic!("{label}: optimize: {e}"));
        let ws = weights_for_report(&report)
            .unwrap_or_else(|e| panic!("{label}: weights: {e}"));
        let t0 = Instant::now();
        let art = generate(&report, &ws, &label)
            .unwrap_or_else(|e| panic!("{label}: codegen: {e}"));
        let dt = t0.elapsed();
        gen_us.push(dt.as_secs_f64() * 1e6);
        metrics.push((format!("{label}.arena_bytes"), art.arena_bytes as f64));
        metrics.push((format!("{label}.rodata_bytes"), art.rodata_bytes as f64));
        table.row(&[
            label.clone(),
            art.dtype.to_string(),
            art.n_ops.to_string(),
            art.arena_bytes.to_string(),
            art.rodata_bytes.to_string(),
            format!("{:.2}", dt.as_secs_f64() * 1e3),
        ]);
    }
    table.print();

    let timings = [BenchResult {
        name: "codegen/generate".into(),
        iters: gen_us.len() as u64,
        mean_ns: stats::mean(&gen_us) * 1e3,
        stddev_ns: stats::stddev(&gen_us) * 1e3,
        min_ns: stats::min(&gen_us) * 1e3,
        max_ns: stats::max(&gen_us) * 1e3,
    }];
    match write_json_report("codegen", &metrics, &timings) {
        Ok(p) => println!("\nwrote {p}"),
        Err(e) => eprintln!("could not write JSON report: {e}"),
    }
}

//! Ablation: scheduler quality and runtime scaling (not a paper table —
//! DESIGN.md §6 design-choice ablations).
//!
//! Sweeps series-parallel DAGs of growing size and compares:
//! - Algorithm 1 (memoized DP) — optimal;
//! - branch-and-bound with dominance memo — optimal, different constants;
//! - greedy min-increase / depth-first — heuristics (optimality gap);
//! - exhaustive enumeration — ground truth (small sizes only).

use mcu_reorder::models::synth;
use mcu_reorder::sched;
use mcu_reorder::util::bench::{black_box, write_json_report, Bencher, Table};
use mcu_reorder::util::rng::Rng;
use mcu_reorder::util::stats;

fn main() {
    println!("=== scheduler ablation: optimality gap (peak / optimal peak) ===\n");
    let mut quality =
        Table::new(&["graph", "ops", "orders", "default", "greedy", "dfs", "optimal=1.0"]);
    let mut rng = Rng::new(2024);
    for (depth, width) in [(2, 2), (2, 3), (3, 2), (3, 3)] {
        let g = synth::series_parallel(&mut rng, depth, width);
        let (opt, _) = sched::optimal(&g).unwrap();
        let bf = sched::bruteforce(&g, 5_000_000);
        assert!(bf.as_ref().map_or(true, |b| b.best.peak_bytes == opt.peak_bytes));
        let ratio = |p: usize| format!("{:.3}", p as f64 / opt.peak_bytes as f64);
        quality.row(&[
            format!("sp-{depth}x{width}"),
            format!("{}", g.n_ops()),
            bf.as_ref().map_or("—".into(), |b| format!("{}", b.orders_enumerated)),
            ratio(sched::peak_of(&g, &g.default_order())),
            ratio(sched::greedy_min_increase(&g).peak_bytes),
            ratio(sched::greedy_depth_first(&g).peak_bytes),
            "1.000".into(),
        ]);
    }
    quality.print();

    println!("\n=== average optimality gap over 50 random sp-2x3 graphs ===\n");
    let mut rng = Rng::new(7);
    let mut gaps_default = Vec::new();
    let mut gaps_greedy = Vec::new();
    for _ in 0..50 {
        let g = synth::series_parallel(&mut rng, 2, 3);
        let (opt, _) = sched::optimal(&g).unwrap();
        gaps_default.push(sched::peak_of(&g, &g.default_order()) as f64 / opt.peak_bytes as f64);
        gaps_greedy.push(sched::greedy_min_increase(&g).peak_bytes as f64 / opt.peak_bytes as f64);
    }
    println!(
        "default order : mean {:.3}× optimal (max {:.3}×)",
        stats::mean(&gaps_default),
        stats::max(&gaps_default)
    );
    println!(
        "greedy        : mean {:.3}× optimal (max {:.3}×)",
        stats::mean(&gaps_greedy),
        stats::max(&gaps_greedy)
    );

    println!("\n=== §6 in-place accumulation ablation (residual nets) ===\n");
    {
        use mcu_reorder::graph::DType;
        use mcu_reorder::sched::Opts;
        let g = mcu_reorder::models::resnet_micro(DType::I8);
        let mut t = Table::new(&["schedule", "plain peak", "in-place peak", "saving"]);
        let d_plain = sched::peak_of(&g, &g.default_order());
        let d_inp = sched::peak_of_opts(&g, &g.default_order(), Opts::INPLACE);
        let (o_plain, _) = sched::optimal(&g).unwrap();
        let (o_inp, _) = sched::optimal_opts(&g, Opts::INPLACE).unwrap();
        let row = |name: &str, a: usize, b: usize| {
            [
                name.to_string(),
                format!("{:.1}KB", a as f64 / 1000.0),
                format!("{:.1}KB", b as f64 / 1000.0),
                format!("{:.1}%", 100.0 * (1.0 - b as f64 / a as f64)),
            ]
        };
        t.row(&row("default order", d_plain, d_inp));
        t.row(&row("optimal order", o_plain.peak_bytes, o_inp.peak_bytes));
        t.print();
    }

    println!("\n=== runtime scaling ===\n");
    let mut b = Bencher::quick();
    let mut rng = Rng::new(99);
    for (depth, width) in [(2, 2), (3, 2), (3, 3), (4, 3)] {
        let g = synth::series_parallel(&mut rng, depth, width);
        let n = g.n_ops();
        b.bench(&format!("optimal-dp/sp-{depth}x{width} ({n} ops)"), || {
            black_box(sched::optimal(&g).unwrap())
        });
        b.bench(&format!("optimal-bnb/sp-{depth}x{width} ({n} ops)"), || {
            black_box(sched::optimal_bnb(&g).unwrap())
        });
        b.bench(&format!("greedy/sp-{depth}x{width} ({n} ops)"), || {
            black_box(sched::greedy_min_increase(&g))
        });
    }
    // The real networks.
    use mcu_reorder::graph::DType;
    let swift = mcu_reorder::models::swiftnet_cell(DType::I8);
    b.bench("optimal-dp/swiftnet (53 ops)", || black_box(sched::optimal(&swift).unwrap()));
    let mnet = mcu_reorder::models::mobilenet_v1_025(DType::I8);
    b.bench("optimal-dp/mobilenet (30 ops)", || black_box(sched::optimal(&mnet).unwrap()));
    b.summary();

    let metrics = vec![
        ("default_gap_mean".to_string(), stats::mean(&gaps_default)),
        ("greedy_gap_mean".to_string(), stats::mean(&gaps_greedy)),
    ];
    match write_json_report("scheduler_scaling", &metrics, b.results()) {
        Ok(p) => println!("\nwrote {p}"),
        Err(e) => eprintln!("could not write JSON report: {e}"),
    }
}

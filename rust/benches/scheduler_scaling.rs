//! Ablation: scheduler quality and runtime scaling (not a paper table —
//! DESIGN.md §6 design-choice ablations).
//!
//! Sweeps series-parallel DAGs of growing size and compares:
//! - Algorithm 1 (memoized DP) — optimal;
//! - branch-and-bound with dominance memo — optimal, different constants;
//! - greedy min-increase / depth-first — heuristics (optimality gap);
//! - exhaustive enumeration — ground truth (small sizes only).
//!
//! Plus the planner-scaling section: full beam split searches over
//! deterministic `synth::layered` graphs at 100/300/1000 ops. The
//! layered peaks are gated against `BENCH_baseline/scheduler_scaling.json`
//! (computed independently by `tools/schedule_mirror/mirror.py
//! --scaling-baseline`); wall-times and work counters are reported but
//! not gated. Hard in-bench acceptance: the 1000-op graph must plan in
//! under 5 s and spend ≥ 10× fewer full-schedule evaluations than the
//! naive strategy would on the same candidate stream.

use std::time::Instant;

use mcu_reorder::models::synth;
use mcu_reorder::sched;
use mcu_reorder::split::{optimize, SplitOptions};
use mcu_reorder::util::bench::{black_box, write_json_report, Bencher, Table};
use mcu_reorder::util::rng::Rng;
use mcu_reorder::util::stats;

fn main() {
    println!("=== scheduler ablation: optimality gap (peak / optimal peak) ===\n");
    let mut quality =
        Table::new(&["graph", "ops", "orders", "default", "greedy", "dfs", "optimal=1.0"]);
    let mut rng = Rng::new(2024);
    for (depth, width) in [(2, 2), (2, 3), (3, 2), (3, 3)] {
        let g = synth::series_parallel(&mut rng, depth, width);
        let (opt, _) = sched::optimal(&g).unwrap();
        let bf = sched::bruteforce(&g, 5_000_000);
        assert!(bf.as_ref().map_or(true, |b| b.best.peak_bytes == opt.peak_bytes));
        let ratio = |p: usize| format!("{:.3}", p as f64 / opt.peak_bytes as f64);
        quality.row(&[
            format!("sp-{depth}x{width}"),
            format!("{}", g.n_ops()),
            bf.as_ref().map_or("—".into(), |b| format!("{}", b.orders_enumerated)),
            ratio(sched::peak_of(&g, &g.default_order())),
            ratio(sched::greedy_min_increase(&g).peak_bytes),
            ratio(sched::greedy_depth_first(&g).peak_bytes),
            "1.000".into(),
        ]);
    }
    quality.print();

    println!("\n=== average optimality gap over 50 random sp-2x3 graphs ===\n");
    let mut rng = Rng::new(7);
    let mut gaps_default = Vec::new();
    let mut gaps_greedy = Vec::new();
    for _ in 0..50 {
        let g = synth::series_parallel(&mut rng, 2, 3);
        let (opt, _) = sched::optimal(&g).unwrap();
        gaps_default.push(sched::peak_of(&g, &g.default_order()) as f64 / opt.peak_bytes as f64);
        gaps_greedy.push(sched::greedy_min_increase(&g).peak_bytes as f64 / opt.peak_bytes as f64);
    }
    println!(
        "default order : mean {:.3}× optimal (max {:.3}×)",
        stats::mean(&gaps_default),
        stats::max(&gaps_default)
    );
    println!(
        "greedy        : mean {:.3}× optimal (max {:.3}×)",
        stats::mean(&gaps_greedy),
        stats::max(&gaps_greedy)
    );

    println!("\n=== §6 in-place accumulation ablation (residual nets) ===\n");
    {
        use mcu_reorder::graph::DType;
        use mcu_reorder::sched::Opts;
        let g = mcu_reorder::models::resnet_micro(DType::I8);
        let mut t = Table::new(&["schedule", "plain peak", "in-place peak", "saving"]);
        let d_plain = sched::peak_of(&g, &g.default_order());
        let d_inp = sched::peak_of_opts(&g, &g.default_order(), Opts::INPLACE);
        let (o_plain, _) = sched::optimal(&g).unwrap();
        let (o_inp, _) = sched::optimal_opts(&g, Opts::INPLACE).unwrap();
        let row = |name: &str, a: usize, b: usize| {
            [
                name.to_string(),
                format!("{:.1}KB", a as f64 / 1000.0),
                format!("{:.1}KB", b as f64 / 1000.0),
                format!("{:.1}%", 100.0 * (1.0 - b as f64 / a as f64)),
            ]
        };
        t.row(&row("default order", d_plain, d_inp));
        t.row(&row("optimal order", o_plain.peak_bytes, o_inp.peak_bytes));
        t.print();
    }

    println!("\n=== runtime scaling ===\n");
    let mut b = Bencher::quick();
    let mut rng = Rng::new(99);
    for (depth, width) in [(2, 2), (3, 2), (3, 3), (4, 3)] {
        let g = synth::series_parallel(&mut rng, depth, width);
        let n = g.n_ops();
        b.bench(&format!("optimal-dp/sp-{depth}x{width} ({n} ops)"), || {
            black_box(sched::optimal(&g).unwrap())
        });
        b.bench(&format!("optimal-bnb/sp-{depth}x{width} ({n} ops)"), || {
            black_box(sched::optimal_bnb(&g).unwrap())
        });
        b.bench(&format!("greedy/sp-{depth}x{width} ({n} ops)"), || {
            black_box(sched::greedy_min_increase(&g))
        });
    }
    // The real networks.
    use mcu_reorder::graph::DType;
    let swift = mcu_reorder::models::swiftnet_cell(DType::I8);
    b.bench("optimal-dp/swiftnet (53 ops)", || black_box(sched::optimal(&swift).unwrap()));
    let mnet = mcu_reorder::models::mobilenet_v1_025(DType::I8);
    b.bench("optimal-dp/mobilenet (30 ops)", || black_box(sched::optimal(&mnet).unwrap()));
    b.summary();

    let mut metrics = vec![
        ("default_gap_mean".to_string(), stats::mean(&gaps_default)),
        ("greedy_gap_mean".to_string(), stats::mean(&gaps_greedy)),
    ];

    println!("\n=== planner scaling: layered graphs, incremental fast path ===\n");
    let mut scaling = Table::new(&[
        "graph", "default", "reorder", "planned", "wall", "scored", "dedup", "full-DP",
        "cache h/m", "÷naive",
    ]);
    for n in [100usize, 300, 1000] {
        let g = synth::layered(&mut Rng::new(n as u64), n);
        assert_eq!(g.n_ops(), n);
        let default_peak = sched::peak_of(&g, &g.default_order());
        let (opt, _) = sched::optimal(&g).unwrap();
        // layered100 runs the small preset the Python mirror re-plans
        // with naive full-DP scoring (its planned peak is gated against
        // the mirror); the bigger sizes run the full default search,
        // which only the incremental fast path makes tractable.
        let opts = if n == 100 {
            SplitOptions {
                max_factor: 2,
                max_rounds: 2,
                max_candidates: 8,
                beam_width: 2,
                ..SplitOptions::default()
            }
        } else {
            SplitOptions::default()
        }
        .with_threads(4);
        let t0 = Instant::now();
        let out = optimize(&g, &opts).unwrap();
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let st = out.stats;
        if n == 100 {
            // The incremental path must reproduce the naive reference
            // bit for bit — same plan, same schedule, same peak.
            let t1 = Instant::now();
            let naive = optimize(&g, &opts.clone().naive()).unwrap();
            let naive_ms = t1.elapsed().as_secs_f64() * 1e3;
            assert_eq!(naive.schedule, out.schedule);
            assert_eq!(naive.steps, out.steps);
            metrics.push((format!("layered{n}.naive_wall_ms"), naive_ms));
        }
        if n == 1000 {
            assert!(wall_ms < 5_000.0, "layered1000 planned in {wall_ms:.0} ms (budget 5 s)");
            assert!(
                st.naive_evals() >= 10 * st.full_evals.max(1),
                "eval ratio {:.1} below the 10× acceptance floor ({} naive-equivalent vs {} full)",
                st.eval_ratio(),
                st.naive_evals(),
                st.full_evals
            );
        }
        scaling.row(&[
            format!("layered{n}"),
            format!("{default_peak}"),
            format!("{}", opt.peak_bytes),
            format!("{}", out.schedule.peak_bytes),
            format!("{wall_ms:.0}ms"),
            format!("{}", st.scored),
            format!("{}", st.deduped),
            format!("{}", st.full_evals),
            format!("{}/{}", st.cache_hits, st.cache_misses),
            format!("{:.0}×", st.eval_ratio()),
        ]);
        metrics.push((format!("layered{n}.default_peak"), default_peak as f64));
        metrics.push((format!("layered{n}.reorder_peak"), opt.peak_bytes as f64));
        metrics.push((format!("layered{n}.planned_peak"), out.schedule.peak_bytes as f64));
        metrics.push((format!("layered{n}.plan_wall_ms"), wall_ms));
        metrics.push((format!("layered{n}.candidates_scored"), st.scored as f64));
        metrics.push((format!("layered{n}.deduped"), st.deduped as f64));
        metrics.push((format!("layered{n}.full_evals"), st.full_evals as f64));
        metrics.push((format!("layered{n}.cache_hits"), st.cache_hits as f64));
        metrics.push((format!("layered{n}.cache_misses"), st.cache_misses as f64));
        metrics.push((format!("layered{n}.eval_ratio"), st.eval_ratio()));
    }
    scaling.print();

    match write_json_report("scheduler_scaling", &metrics, b.results()) {
        Ok(p) => println!("\nwrote {p}"),
        Err(e) => eprintln!("could not write JSON report: {e}"),
    }
}

//! Ablation: allocation strategies and defragmentation policies
//! (DESIGN.md §6; supports Table 1's overhead rows and the §6 discussion).
//!
//! Replays the MobileNet execution trace through every policy and reports
//! arena requirement, compaction traffic, and the modeled MCU overhead; then
//! micro-benchmarks the allocator hot paths.

use mcu_reorder::alloc::{AllocError, AllocStats, BufId, CompactPolicy, DynamicArena, StaticPlan};
use mcu_reorder::graph::{DType, Graph};
use mcu_reorder::mcu::{CostModel, NUCLEO_F767ZI};
use mcu_reorder::models;
use mcu_reorder::sched;
use mcu_reorder::util::bench::{black_box, write_json_report, Bencher, Table};

/// Replay a schedule's alloc/free pattern through an arena (no kernel
/// execution — pure allocator behaviour).
fn replay(g: &Graph, order: &[usize], arena: &mut DynamicArena) -> Result<AllocStats, AllocError> {
    let n = g.tensors.len();
    let mut handles: Vec<Option<BufId>> = vec![None; n];
    let mut remaining = vec![0usize; n];
    for op in &g.ops {
        for &t in &op.inputs {
            remaining[t] += 1;
        }
    }
    for &t in &g.inputs {
        handles[t] = Some(arena.alloc(g.tensors[t].bytes())?);
    }
    for &opid in order {
        let op = &g.ops[opid];
        handles[op.output] = Some(arena.alloc(g.tensors[op.output].bytes())?);
        for &t in &op.inputs {
            remaining[t] -= 1;
            if remaining[t] == 0 && !g.outputs.contains(&t) {
                arena.free(handles[t].take().unwrap())?;
            }
        }
        arena.after_op();
    }
    Ok(arena.stats().clone())
}

fn main() {
    let g = models::mobilenet_v1_025(DType::I8);
    let order = g.default_order();
    let peak = sched::peak_of(&g, &order);
    let board = &NUCLEO_F767ZI;

    let static_stats =
        AllocStats { high_water: g.activation_total(), ..AllocStats::default() };
    let model = CostModel::calibrated(&g, &static_stats, board, 1.316, 728.0);
    let base = model.estimate(&g, &static_stats, board);

    println!("=== allocation-strategy ablation (MobileNet trace) ===\n");
    let mut t = Table::new(&[
        "strategy",
        "arena needed",
        "bytes moved",
        "compactions",
        "time overhead",
        "energy overhead",
    ]);

    // Static no-reuse.
    t.row(&[
        "static no-reuse (old TFLM)".into(),
        format!("{:.0}KB", g.activation_total() as f64 / 1000.0),
        "0".into(),
        "0".into(),
        "0% (baseline)".into(),
        "0% (baseline)".into(),
    ]);

    // Dynamic policies.
    for (name, policy) in [
        ("dynamic + compact every op (paper)", CompactPolicy::EveryOp),
        ("dynamic + compact on demand", CompactPolicy::OnDemand),
        ("dynamic, never compact", CompactPolicy::Never),
    ] {
        // Find the smallest arena (KB granularity) that completes.
        let mut lo = peak;
        let mut hi = g.activation_total();
        let fits = |cap: usize| {
            let mut a = DynamicArena::new(cap, policy);
            replay(&g, &order, &mut a).is_ok()
        };
        if fits(lo) {
            hi = lo;
        } else {
            while hi - lo > 256 {
                let mid = (lo + hi) / 2;
                if fits(mid) {
                    hi = mid;
                } else {
                    lo = mid;
                }
            }
        }
        let mut a = DynamicArena::new(hi, policy);
        let stats = replay(&g, &order, &mut a).unwrap();
        let est = model.estimate(&g, &stats, board);
        t.row(&[
            name.into(),
            format!("{:.0}KB", hi as f64 / 1000.0),
            format!("{:.0}KB", stats.bytes_moved as f64 / 1000.0),
            format!("{}", stats.compactions),
            format!("+{:.2}%", 100.0 * (est.seconds / base.seconds - 1.0)),
            format!("+{:.2}%", 100.0 * (est.energy_mj / base.energy_mj - 1.0)),
        ]);
    }

    // Offline best-fit plan (§6).
    let plan = StaticPlan::best_fit(&g, &order);
    t.row(&[
        "offline best-fit plan (§6)".into(),
        format!("{:.0}KB", plan.arena_bytes as f64 / 1000.0),
        "0".into(),
        "0".into(),
        "+0.00%".into(),
        "+0.00%".into(),
    ]);
    t.print();
    println!(
        "\nworking-set peak (lower bound for any strategy): {:.0}KB; paper: 241KB static → 55KB dynamic\n",
        peak as f64 / 1000.0
    );

    // --- allocator hot-path micro-benchmarks -------------------------------
    let mut b = Bencher::new();
    b.bench("arena/replay-mobilenet-everyop", || {
        let mut a = DynamicArena::new(64 * 1024, CompactPolicy::EveryOp);
        black_box(replay(&g, &order, &mut a).unwrap())
    });
    b.bench("arena/replay-mobilenet-ondemand", || {
        let mut a = DynamicArena::new(64 * 1024, CompactPolicy::OnDemand);
        black_box(replay(&g, &order, &mut a).unwrap())
    });
    b.bench("arena/alloc-free-churn", || {
        let mut a = DynamicArena::new(1 << 20, CompactPolicy::OnDemand);
        let mut live = Vec::new();
        for i in 0..256 {
            live.push(a.alloc(512 + (i % 7) * 128).unwrap());
            if i % 3 == 0 {
                a.free(live.remove(0)).unwrap();
            }
        }
        black_box(a.stats().allocs)
    });
    b.bench("planner/best-fit-mobilenet", || black_box(StaticPlan::best_fit(&g, &order)));
    let swift = models::swiftnet_cell(DType::I8);
    let sorder = sched::optimal(&swift).unwrap().0.order;
    b.bench("planner/best-fit-swiftnet", || black_box(StaticPlan::best_fit(&swift, &sorder)));
    b.summary();

    let metrics = vec![
        ("mobilenet_static_bytes".to_string(), g.activation_total() as f64),
        ("mobilenet_peak".to_string(), peak as f64),
        ("mobilenet_bestfit_bytes".to_string(), plan.arena_bytes as f64),
    ];
    match write_json_report("allocator", &metrics, b.results()) {
        Ok(p) => println!("\nwrote {p}"),
        Err(e) => eprintln!("could not write JSON report: {e}"),
    }
}

//! Bench + regeneration of Table 1 (the paper's headline results).
//!
//! Left half: SwiftNet-style cell network, default vs optimal operator
//! order (peak memory; modeled time/energy for the order that fits).
//! Right half: MobileNet person detection, static vs dynamic allocation
//! (peak memory exact; time/energy from the cost model fed with the real
//! compaction traffic of an arena execution).

use mcu_reorder::alloc::{AllocStats, StaticPlan};
use mcu_reorder::graph::DType;
use mcu_reorder::interp::{calibrate, ExecConfig, Interpreter, TensorData, WeightStore};
use mcu_reorder::mcu::{CostModel, DeployReport, OverheadModel, NUCLEO_F767ZI};
use mcu_reorder::models;
use mcu_reorder::sched;
use mcu_reorder::util::bench::{black_box, write_json_report, Bencher, Table};

fn ramp(n: usize) -> Vec<f32> {
    (0..n).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect()
}

fn main() {
    // --- SwiftNet columns -------------------------------------------------
    let swift = models::swiftnet_cell(DType::I8);
    let swift_default = sched::peak_of(&swift, &swift.default_order());
    let (swift_opt, _) = sched::optimal(&swift).unwrap();
    let overhead = OverheadModel::default();
    let fits_d = DeployReport::new(&swift, swift_default, &NUCLEO_F767ZI, &overhead).fits_sram;
    let fits_o =
        DeployReport::new(&swift, swift_opt.peak_bytes, &NUCLEO_F767ZI, &overhead).fits_sram;

    // --- MobileNet columns -------------------------------------------------
    let mnet = models::mobilenet_v1_025(DType::I8);
    let static_bytes = StaticPlan::no_reuse(&mnet).arena_bytes;
    let g_f32 = models::mobilenet_v1_025(DType::F32);
    let ws_f32 = WeightStore::seeded_f32(&g_f32, 42);
    let input = TensorData::F32(ramp(g_f32.tensors[g_f32.inputs[0]].elems()));
    let ranges = calibrate(&g_f32, &ws_f32, &[input.clone()], 1 << 24).unwrap();
    let ws_i8 = WeightStore::quantize_from(&mnet, &ws_f32, &ranges);
    let in_q = ws_i8.qparams[&mnet.inputs[0]];
    let qin = TensorData::I8(in_q.quantize(input.as_f32().unwrap()));
    let interp = Interpreter::new(&mnet, ws_i8.clone(), ExecConfig::with_capacity(256 * 1024));
    let run = interp.run(&[qin.clone()]).unwrap();

    let static_stats = AllocStats { high_water: static_bytes, ..AllocStats::default() };
    let model = CostModel::calibrated(&mnet, &static_stats, &NUCLEO_F767ZI, 1.316, 728.0);
    let est_static = model.estimate(&mnet, &static_stats, &NUCLEO_F767ZI);
    let est_dyn = model.estimate(&mnet, &run.alloc, &NUCLEO_F767ZI);
    let est_swift = model.estimate(&swift, &run.alloc, &NUCLEO_F767ZI);

    let kb = |b: usize| format!("{:.0}KB", b as f64 / 1000.0);
    println!("=== Table 1 reproduction ===\n");
    let mut t = Table::new(&[
        "",
        "SwiftNet default",
        "SwiftNet optimal",
        "MobileNet static",
        "MobileNet dynamic",
    ]);
    t.row(&[
        "Peak memory (excl. overheads)".into(),
        kb(swift_default),
        kb(swift_opt.peak_bytes),
        kb(static_bytes),
        kb(run.alloc.high_water),
    ]);
    t.row(&[
        "Fits 512KB SRAM (+overhead)?".into(),
        if fits_d { "yes" } else { "NO" }.into(),
        if fits_o { "yes" } else { "NO" }.into(),
        "—".into(),
        "—".into(),
    ]);
    t.row(&[
        "Execution time".into(),
        "N/A".into(),
        format!("{:.0} ms", est_swift.millis()),
        format!("{:.0} ms", est_static.millis()),
        format!(
            "{:.0} ms (+{:.2}%)",
            est_dyn.millis(),
            100.0 * (est_dyn.seconds / est_static.seconds - 1.0)
        ),
    ]);
    t.row(&[
        "Energy use".into(),
        "N/A".into(),
        format!("{:.0} mJ", est_swift.energy_mj),
        format!("{:.0} mJ", est_static.energy_mj),
        format!(
            "{:.0} mJ (+{:.2}%)",
            est_dyn.energy_mj,
            100.0 * (est_dyn.energy_mj / est_static.energy_mj - 1.0)
        ),
    ]);
    t.print();
    println!("\npaper: 351KB/301KB (no/yes) · 241KB/55KB · 1316/1325ms (+0.68%) · 728/735mJ (+0.97%)\n");

    // --- timings of the pieces that generate the table ---------------------
    let mut b = Bencher::quick();
    b.bench("table1/swiftnet-optimal-schedule", || black_box(sched::optimal(&swift).unwrap()));
    b.bench("table1/swiftnet-default-peak", || {
        black_box(sched::peak_of(&swift, &swift.default_order()))
    });
    b.bench("table1/mobilenet-static-plan", || black_box(StaticPlan::no_reuse(&mnet)));
    b.bench("table1/mobilenet-i8-arena-inference", || {
        let interp = Interpreter::new(&mnet, ws_i8.clone(), ExecConfig::with_capacity(256 * 1024));
        black_box(interp.run(std::slice::from_ref(&qin)).unwrap())
    });
    b.summary();

    let metrics = vec![
        ("swiftnet_default_peak".to_string(), swift_default as f64),
        ("swiftnet_optimal_peak".to_string(), swift_opt.peak_bytes as f64),
        ("mobilenet_static_bytes".to_string(), static_bytes as f64),
        ("mobilenet_dynamic_peak".to_string(), run.alloc.high_water as f64),
        ("mobilenet_time_overhead".to_string(), est_dyn.seconds / est_static.seconds - 1.0),
        (
            "mobilenet_energy_overhead".to_string(),
            est_dyn.energy_mj / est_static.energy_mj - 1.0,
        ),
    ];
    match write_json_report("table1", &metrics, b.results()) {
        Ok(p) => println!("\nwrote {p}"),
        Err(e) => eprintln!("could not write JSON report: {e}"),
    }
}

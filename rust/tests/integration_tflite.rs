//! End-to-end tests of the TFLite flatbuffer frontend.
//!
//! Golden fixtures: `tools/tflite_fixtures/gen.py` builds two tiny CNN
//! models (`cnn_f32.tflite`, `cnn_int8.tflite`) through a *hand-rolled
//! Python flatbuffer builder* with formula-defined weights. The tests
//! reconstruct the same network through [`GraphBuilder`] from the same
//! integer formulas and require the imported model to interpret
//! **bit-exactly** against it — every activation tensor, not just the
//! output. Two independent flatbuffer implementations and two independent
//! graph constructions agreeing byte-for-byte is the import contract.
//!
//! Also covered: import → export → import round-trip stability (buffers
//! byte-identical, serialization deterministic), the reorder exporter,
//! split/elide planning on the imported graph (the paper's end-to-end
//! flow), and CLI error paths on malformed files.

use std::collections::HashMap;

use mcu_reorder::graph::{Act, DType, Graph, GraphBuilder, Padding};
use mcu_reorder::interp::quant::QuantParams;
use mcu_reorder::interp::{ExecConfig, Interpreter, TensorData, WeightStore};
use mcu_reorder::sched;
use mcu_reorder::split::{self, SplitOptions};
use mcu_reorder::tflite::{self, fixtures};

// ---------------------------------------------------------------------------
// the fixture spec, re-derived (mirrors tools/tflite_fixtures/gen.py)
// ---------------------------------------------------------------------------

/// Deterministic int8 weight stream: `((i*mul + add) % 253) - 126`.
fn wq(i: usize, mul: usize, add: usize) -> i64 {
    ((i * mul + add) % 253) as i64 - 126
}

/// Deterministic small bias stream: `((i*mul) % 21) - 10`.
fn bq(i: usize, mul: usize) -> i64 {
    ((i * mul) % 21) as i64 - 10
}

/// Conv filter in the IR's HWIO layout, from the fixture's OHWI stream.
fn conv_w(mul: usize, add: usize, cout: usize, kh: usize, kw: usize, cin: usize) -> Vec<i64> {
    let n = cout * kh * kw * cin;
    let mut hwio = vec![0i64; n];
    for oc in 0..cout {
        for y in 0..kh {
            for x in 0..kw {
                for ic in 0..cin {
                    hwio[((y * kw + x) * cin + ic) * cout + oc] =
                        wq(((oc * kh + y) * kw + x) * cin + ic, mul, add);
                }
            }
        }
    }
    hwio
}

/// Dense filter `[in, out]` from the fixture's `[out, in]` stream.
fn dense_w(mul: usize, add: usize, out: usize, inp: usize) -> Vec<i64> {
    let mut w = vec![0i64; out * inp];
    for o in 0..out {
        for i in 0..inp {
            w[i * out + o] = wq(o * inp + i, mul, add);
        }
    }
    w
}

/// Depthwise filter `[kh, kw, c]` (fixture layout `[1, kh, kw, c]` is the
/// same stream).
fn dw_w(mul: usize, add: usize, n: usize) -> Vec<i64> {
    (0..n).map(|i| wq(i, mul, add)).collect()
}

/// (name, weight values, bias values) per layer, in IR layout.
fn fixture_filters() -> Vec<(&'static str, Vec<i64>, Vec<i64>)> {
    vec![
        ("conv1.preact", conv_w(37, 11, 8, 3, 3, 2), (0..8).map(|i| bq(i, 19)).collect()),
        ("dw1.preact", dw_w(53, 7, 3 * 3 * 8), (0..8).map(|i| bq(i, 5)).collect()),
        ("pwa.preact", conv_w(71, 3, 8, 1, 1, 8), (0..8).map(|i| bq(i, 13)).collect()),
        ("fc", dense_w(89, 5, 4, 16), (0..4).map(|i| bq(i, 7)).collect()),
    ]
}

/// Activation quantization of the int8 fixture: (tensor name, scale, zp).
/// De-fused preact tensors share their activation output's parameters.
const QPARAMS: &[(&str, f32, i32)] = &[
    ("input", 0.0625, 1),
    ("conv1.preact", 0.046875, -10),
    ("conv1", 0.046875, -10),
    ("dw1.preact", 0.03125, 4),
    ("dw1", 0.03125, 4),
    ("pwa.preact", 0.0625, 0),
    ("pwa", 0.0625, 0),
    ("add1", 0.0625, 0),
    ("cat", 0.0625, 0),
    ("pool", 0.0625, 0),
    ("mean", 0.0625, 0),
    ("reshape", 0.0625, 0),
    ("fc", 0.125, 3),
    ("softmax", 0.00390625, -128),
];

const W_SCALE: f32 = 0.015625;

/// All activation tensor names of the de-fused graph, in producer order.
const ACTIVATIONS: &[&str] = &[
    "input", "conv1.preact", "conv1", "dw1.preact", "dw1", "pwa.preact", "pwa", "add1", "cat",
    "pool", "mean", "reshape", "fc", "softmax",
];

/// The builder-constructed twin of the de-fused import.
fn builder_twin(dtype: DType) -> Graph {
    let mut b = GraphBuilder::new("tflitecnn");
    let x = b.input("input", &[1, 16, 16, 2], dtype);
    let c1p = b.conv2d("conv1.preact", x, 8, (3, 3), (1, 1), Padding::Same, Act::Linear);
    let c1 = b.relu6("conv1", c1p);
    let dwp = b.dwconv2d("dw1.preact", c1, (3, 3), (2, 2), Padding::Same, Act::Linear);
    let dw = b.relu6("dw1", dwp);
    let pwp = b.conv2d("pwa.preact", dw, 8, (1, 1), (1, 1), Padding::Same, Act::Linear);
    let pw = b.relu("pwa", pwp);
    let a = b.add("add1", dw, pw);
    let c = b.concat("cat", &[a, pw]);
    let p = b.maxpool("pool", c, (2, 2), (2, 2), Padding::Valid);
    let m = b.global_avgpool("mean", p);
    let r = b.reshape("reshape", m, &[1, 16]);
    let f = b.dense("fc", r, 4, Act::Linear);
    let s = b.softmax("softmax", f);
    b.output(s);
    b.finish().expect("twin validates")
}

fn twin_weights(g: &Graph, dtype: DType) -> WeightStore {
    let mut ws = WeightStore::default();
    for (layer, w, bias) in fixture_filters() {
        let wt = g.tensor_by_name(&format!("{layer}.w")).expect("weight tensor");
        let bt = g.tensor_by_name(&format!("{layer}.b")).expect("bias tensor");
        match dtype {
            DType::F32 => {
                ws.data.insert(
                    wt.id,
                    TensorData::F32(w.iter().map(|&v| v as f32 / 128.0).collect()),
                );
                ws.data.insert(
                    bt.id,
                    TensorData::F32(bias.iter().map(|&v| v as f32 / 16.0).collect()),
                );
            }
            DType::I8 => {
                ws.data.insert(wt.id, TensorData::I8(w.iter().map(|&v| v as i8).collect()));
                ws.data
                    .insert(bt.id, TensorData::I32(bias.iter().map(|&v| v as i32).collect()));
                ws.qparams.insert(wt.id, QuantParams::new(W_SCALE, 0));
            }
            _ => unreachable!(),
        }
    }
    if dtype == DType::I8 {
        for &(name, scale, zp) in QPARAMS {
            let t = g.tensor_by_name(name).expect("activation tensor");
            ws.qparams.insert(t.id, QuantParams::new(scale, zp));
        }
    }
    ws
}

fn fixture_input(dtype: DType) -> TensorData {
    let n = 16 * 16 * 2;
    let vals: Vec<i64> = (0..n).map(|i| ((i * 29 + 3) % 255) as i64 - 127).collect();
    match dtype {
        DType::F32 => TensorData::F32(vals.iter().map(|&v| v as f32 / 128.0).collect()),
        DType::I8 => TensorData::I8(vals.iter().map(|&v| v as i8).collect()),
        _ => unreachable!(),
    }
}

/// Run one inference capturing every activation, keyed by tensor name.
fn run_named(g: &Graph, ws: WeightStore, input: TensorData) -> HashMap<String, TensorData> {
    let interp = Interpreter::new(g, ws, ExecConfig::with_capacity(1 << 20));
    let (_, captured) = interp.run_capture(&[input]).expect("run");
    captured
        .into_iter()
        .enumerate()
        .filter_map(|(i, d)| d.map(|d| (g.tensors[i].name.clone(), d)))
        .collect()
}

fn load_fixture(name: &str) -> tflite::Imported {
    let path = fixtures::ensure(name).expect("fixture generation (needs python3 on PATH)");
    tflite::load(path.to_str().unwrap()).expect("fixture imports")
}

// ---------------------------------------------------------------------------
// golden import tests
// ---------------------------------------------------------------------------

fn golden_bit_exact(fixture: &str, dtype: DType) {
    let imp = load_fixture(fixture);
    let g = &imp.graph;
    assert_eq!(g.n_ops(), 13, "10 operators, 3 de-fused activations");
    assert_eq!(g.name, "tflitecnn");

    let twin = builder_twin(dtype);
    assert_eq!(g.n_ops(), twin.n_ops());
    for (a, b) in g.ops.iter().zip(&twin.ops) {
        assert_eq!(a.kind, b.kind, "op {} kind drifted from the twin", a.name);
    }

    let got = run_named(g, imp.weights.clone(), fixture_input(dtype));
    let want = run_named(&twin, twin_weights(&twin, dtype), fixture_input(dtype));
    for &name in ACTIVATIONS {
        let a = got.get(name).unwrap_or_else(|| panic!("import missing tensor {name}"));
        let b = want.get(name).unwrap_or_else(|| panic!("twin missing tensor {name}"));
        assert_eq!(a, b, "tensor {name} is not bit-exact vs the builder twin");
    }
}

#[test]
fn f32_fixture_imports_and_interprets_bit_exact() {
    golden_bit_exact(fixtures::F32_FIXTURE, DType::F32);
}

#[test]
fn int8_fixture_imports_and_interprets_bit_exact() {
    golden_bit_exact(fixtures::INT8_FIXTURE, DType::I8);
}

#[test]
fn int8_quantization_maps_onto_qparams() {
    let imp = load_fixture(fixtures::INT8_FIXTURE);
    let g = &imp.graph;
    for &(name, scale, zp) in QPARAMS {
        if name.ends_with(".preact") {
            continue; // synthesized tensors, checked via their source below
        }
        let t = g.tensor_by_name(name).unwrap_or_else(|| panic!("missing {name}"));
        let q = imp.weights.qparams.get(&t.id).unwrap_or_else(|| panic!("no qparams for {name}"));
        assert_eq!((q.scale, q.zero_point), (scale, zp), "qparams of {name}");
    }
    // De-fused preact tensors share their output's parameters.
    for pre in ["conv1.preact", "dw1.preact", "pwa.preact"] {
        let base = pre.strip_suffix(".preact").unwrap();
        let tp = g.tensor_by_name(pre).unwrap();
        let tb = g.tensor_by_name(base).unwrap();
        assert_eq!(imp.weights.qparams[&tp.id], imp.weights.qparams[&tb.id]);
    }
}

// ---------------------------------------------------------------------------
// export / round-trip
// ---------------------------------------------------------------------------

#[test]
fn export_roundtrip_is_byte_stable_and_buffer_identical() {
    let path = fixtures::ensure(fixtures::INT8_FIXTURE).expect("fixtures");
    let original = tflite::read_model(path.to_str().unwrap()).expect("parse");
    let imp = tflite::import(&original).expect("import");

    let (opt, _) = sched::optimal(&imp.graph).expect("schedule");
    let order = imp.operator_order(&opt.order);
    let reordered = tflite::reorder(&original, &order).expect("reorder");

    // Buffers byte-identical through the rewrite.
    assert_eq!(reordered.buffers, original.buffers);

    // import → export → import: the model survives unchanged (modulo
    // operator order), and serialization is deterministic (byte-stable).
    let bytes1 = reordered.serialize();
    let back = tflite::Model::parse(&bytes1).expect("reparse");
    assert_eq!(back, reordered);
    assert_eq!(back.serialize(), bytes1, "export → import → export must be byte-stable");

    // The reordered model still imports and computes the same outputs.
    let imp2 = tflite::import(&back).expect("reimport");
    let out1 = run_named(&imp.graph, imp.weights.clone(), fixture_input(DType::I8));
    let out2 = run_named(&imp2.graph, imp2.weights.clone(), fixture_input(DType::I8));
    assert_eq!(out1["softmax"], out2["softmax"], "reordering must not change outputs");
}

#[test]
fn operator_order_contracts_defused_ops() {
    let imp = load_fixture(fixtures::F32_FIXTURE);
    // Graph order = default (13 ops incl. de-fused); operator order must
    // contract to the 10 original operators, in file order.
    let order = imp.operator_order(&imp.graph.default_order());
    assert_eq!(order, (0..10).collect::<Vec<_>>());
}

// ---------------------------------------------------------------------------
// optimize: reorder vs split vs elided on the imported model
// ---------------------------------------------------------------------------

#[test]
fn split_breaks_the_reorder_floor_on_the_imported_model() {
    let imp = load_fixture(fixtures::INT8_FIXTURE);
    let g = &imp.graph;
    let default_peak = sched::peak_of(g, &g.default_order());
    let (opt, _) = sched::optimal(g).expect("schedule");
    let outcome = split::optimize(g, &SplitOptions::default()).expect("split search");

    // The fixture's conv chain is linear: reordering alone cannot beat the
    // de-fused conv1 working set, but splitting can (acceptance criterion:
    // split/elided peak strictly below the reorder-only peak). Exact values
    // are gated against the DP mirror in BENCH_baseline/partial_exec.json.
    assert_eq!(opt.peak_bytes, default_peak, "reordering alone is stuck on a chain");
    assert!(
        outcome.schedule.peak_bytes < opt.peak_bytes,
        "split peak {} must beat reorder-only {}",
        outcome.schedule.peak_bytes,
        opt.peak_bytes
    );

    // The split graph still computes bit-exactly (channel/row slices are
    // exact by construction; validated end-to-end here).
    let ws2 = outcome.remap_weights(&imp.weights);
    let cfg = ExecConfig {
        arena_bytes: 1 << 20,
        policy: mcu_reorder::alloc::CompactPolicy::EveryOp,
        order: Some(outcome.schedule.order.clone()),
    };
    let split_run = Interpreter::new(&outcome.graph, ws2, cfg)
        .run(&[fixture_input(DType::I8)])
        .expect("split graph runs");
    let base_run =
        Interpreter::new(g, imp.weights.clone(), ExecConfig::with_capacity(1 << 20))
            .run(&[fixture_input(DType::I8)])
            .expect("base graph runs");
    assert_eq!(split_run.outputs, base_run.outputs, "splitting must not change outputs");
}

// ---------------------------------------------------------------------------
// CLI robustness: malformed inputs exit nonzero with a clean error
// ---------------------------------------------------------------------------

fn run_cli(args: &[&str]) -> (i32, String, String) {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_mcu-reorder"))
        .args(args)
        .output()
        .expect("spawn mcu-reorder");
    (
        out.status.code().unwrap_or(-1),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn cli_rejects_malformed_models_without_panicking() {
    let dir = std::env::temp_dir().join(format!("mcu-reorder-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // Truncated flatbuffer.
    let fixture = fixtures::ensure(fixtures::F32_FIXTURE).expect("fixtures");
    let bytes = std::fs::read(&fixture).unwrap();
    let trunc = dir.join("trunc.tflite");
    std::fs::write(&trunc, &bytes[..bytes.len() / 3]).unwrap();
    // Garbage flatbuffer.
    let garbage = dir.join("garbage.tflite");
    std::fs::write(&garbage, b"definitely not a flatbuffer").unwrap();
    // Malformed JSON model.
    let badjson = dir.join("bad.json");
    std::fs::write(&badjson, "{\"format\": \"mcu-reorder/v1\", \"tensors\": [").unwrap();
    // Missing file.
    let missing = dir.join("nope.tflite");

    for (args, what) in [
        (vec!["import", trunc.to_str().unwrap()], "truncated flatbuffer"),
        (vec!["import", garbage.to_str().unwrap()], "garbage flatbuffer"),
        (vec!["optimize", trunc.to_str().unwrap(), "-o", "/dev/null"], "optimize truncated"),
        (vec!["import", missing.to_str().unwrap()], "missing file"),
        (vec!["analyze", "--file", badjson.to_str().unwrap()], "malformed JSON"),
    ] {
        let (code, stdout, stderr) = run_cli(&args);
        assert_eq!(code, 1, "{what}: expected exit 1, got {code}\nstdout: {stdout}");
        assert!(stderr.contains("error:"), "{what}: stderr should explain: {stderr}");
        assert!(
            !stderr.contains("panicked"),
            "{what}: must fail cleanly, not panic: {stderr}"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_optimize_writes_a_reordered_model() {
    let fixture = fixtures::ensure(fixtures::INT8_FIXTURE).expect("fixtures");
    let dir = std::env::temp_dir().join(format!("mcu-reorder-opt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let out = dir.join("reordered.tflite");

    let (code, stdout, stderr) = run_cli(&[
        "optimize",
        fixture.to_str().unwrap(),
        "-o",
        out.to_str().unwrap(),
        "--budget",
        "3000",
    ]);
    assert_eq!(code, 0, "optimize failed: {stderr}");
    assert!(stdout.contains("reorder-only optimal"), "report missing: {stdout}");
    assert!(stdout.contains("elided"), "elided peak missing: {stdout}");
    assert!(stdout.contains("budget"), "budget verdict missing: {stdout}");
    // The written model parses, its buffers match the input's, and the
    // converter-style metadata survives the rewrite.
    let a = tflite::read_model(fixture.to_str().unwrap()).unwrap();
    let b = tflite::read_model(out.to_str().unwrap()).unwrap();
    assert_eq!(a.buffers, b.buffers, "weight buffers must survive byte-identically");
    assert_eq!(a.metadata, b.metadata, "metadata must survive the rewrite");
    assert_eq!(a.metadata[0].name, "min_runtime_version");

    // A trailing path flag is a loud usage error, not a silent write to
    // a file named "true".
    for flag in ["-o", "--out"] {
        let (code, _, stderr) = run_cli(&["optimize", fixture.to_str().unwrap(), flag]);
        assert_eq!(code, 1, "trailing {flag} must fail");
        assert!(stderr.contains("-o/--out needs a path"), "{flag}: {stderr}");
    }
    let (code, _, stderr) = run_cli(&["import", fixture.to_str().unwrap(), "--json"]);
    assert_eq!(code, 1, "trailing --json must fail");
    assert!(stderr.contains("--json needs a path"), "{stderr}");
    std::fs::remove_dir_all(&dir).ok();
}

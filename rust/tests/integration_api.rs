//! Integration: the `api` facade vs the CLI.
//!
//! The PR-8 redesign rebuilt `analyze`/`import`/`optimize`/`split` on
//! `api::OptimizeRequest` → `OptimizeReport`, with the CLI reduced to
//! flag parsing plus the api renderers. These tests pin the contract:
//! the CLI's stdout is **byte-identical** to the corresponding
//! `api::render_*` call, `--json` output is byte-identical to the
//! corresponding `api::*_json` builder, and every structured document
//! carries `schema_version` (README "Output stability").

use std::path::PathBuf;

use mcu_reorder::api::{self, ModelSource, OptimizeRequest};
use mcu_reorder::graph::DType;
use mcu_reorder::mcu::NUCLEO_F767ZI;
use mcu_reorder::split::SplitOptions;
use mcu_reorder::tflite::fixtures;
use mcu_reorder::util::json::Json;

fn run_cli(args: &[&str]) -> (i32, String, String) {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_mcu-reorder"))
        .args(args)
        .output()
        .expect("spawn mcu-reorder");
    (
        out.status.code().unwrap_or(-1),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mcu-reorder-api-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn zoo(name: &str) -> ModelSource {
    ModelSource::Zoo { name: name.to_string(), dtype: DType::I8 }
}

/// The exact request `optimize MODEL.tflite` builds (no budget, no -o).
fn tflite_request(path: &str) -> OptimizeRequest {
    OptimizeRequest {
        source: ModelSource::TflitePath(path.to_string()),
        budget: None,
        board: &NUCLEO_F767ZI,
        split: Some(SplitOptions::default()),
        compare_materialized: true,
        trace: false,
    }
}

#[test]
fn cli_optimize_model_text_is_the_api_renderer() {
    let dir = tmp_dir("opt-model");
    let out = dir.join("fig.json");
    let out_str = out.to_str().unwrap();

    let (code, stdout, stderr) =
        run_cli(&["optimize", "--model", "figure1", "--out", out_str]);
    assert_eq!(code, 0, "optimize failed: {stderr}");
    let report = OptimizeRequest::reorder_only(zoo("figure1")).run().unwrap();
    assert_eq!(stdout, api::render_optimize_model(&report, out_str));
    // Figure 1's peaks, pinned to the paper: 5216 B default, 4960 B optimal.
    assert!(stdout.contains("peak 5216 B → 4960 B"), "paper peaks missing: {stdout}");

    // The written model round-trips with the reordered schedule embedded.
    let mf = mcu_reorder::graph::serde::ModelFile::from_json(
        &std::fs::read_to_string(&out).unwrap(),
    )
    .unwrap();
    assert_eq!(mf.execution_order, Some(report.reordered.order.clone()));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_optimize_model_json_matches_builder_and_schema() {
    let dir = tmp_dir("opt-json");
    let out = dir.join("fig.json");
    let out_str = out.to_str().unwrap();

    let (code, stdout, stderr) =
        run_cli(&["optimize", "--model", "figure1", "--out", out_str, "--json"]);
    assert_eq!(code, 0, "optimize --json failed: {stderr}");
    let report = OptimizeRequest::reorder_only(zoo("figure1")).run().unwrap();
    let doc = api::optimize_model_json(&report, out_str);
    assert_eq!(stdout, format!("{}\n", doc.to_pretty()), "CLI JSON must be the api builder's");

    let parsed = Json::parse(&stdout).expect("valid JSON");
    assert_eq!(parsed.get("schema_version").as_f64(), Some(1.0));
    assert_eq!(parsed.get("peaks").get("default").as_f64(), Some(5216.0));
    assert_eq!(parsed.get("peaks").get("reordered").as_f64(), Some(4960.0));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_import_text_is_the_api_renderer() {
    let fixture = fixtures::ensure(fixtures::INT8_FIXTURE).expect("fixtures");
    let path = fixture.to_str().unwrap();

    let (code, stdout, stderr) = run_cli(&["import", path]);
    assert_eq!(code, 0, "import failed: {stderr}");
    let report =
        OptimizeRequest::reorder_only(ModelSource::TflitePath(path.to_string())).run().unwrap();
    assert_eq!(stdout, api::render_import(&report));
}

#[test]
fn cli_optimize_tflite_text_is_the_api_renderer() {
    let fixture = fixtures::ensure(fixtures::INT8_FIXTURE).expect("fixtures");
    let path = fixture.to_str().unwrap();
    let report = tflite_request(path).run().unwrap();

    // Without -o: the renderer plus the nothing-written notice.
    let (code, stdout, stderr) = run_cli(&["optimize", path]);
    assert_eq!(code, 0, "optimize failed: {stderr}");
    let expected =
        format!("{}\n(no -o/--out given: nothing written)\n", api::render_optimize_tflite(&report));
    assert_eq!(stdout, expected);

    // With -o: the renderer plus the wrote-line.
    let dir = tmp_dir("opt-tfl");
    let out = dir.join("reordered.tflite");
    let out_str = out.to_str().unwrap();
    let (code, stdout, stderr) = run_cli(&["optimize", path, "-o", out_str]);
    assert_eq!(code, 0, "optimize -o failed: {stderr}");
    let expected = format!(
        "{}\nwrote {out_str}: operator order embedded, peak {} B → {} B \
         (buffers byte-identical)\n",
        api::render_optimize_tflite(&report),
        report.default_peak,
        report.reordered.peak_bytes
    );
    assert_eq!(stdout, expected);
    assert!(out.exists(), "reordered flatbuffer must be written");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_optimize_tflite_json_matches_builder() {
    let fixture = fixtures::ensure(fixtures::INT8_FIXTURE).expect("fixtures");
    let path = fixture.to_str().unwrap();

    let (code, stdout, stderr) = run_cli(&["optimize", path, "--json"]);
    assert_eq!(code, 0, "optimize --json failed: {stderr}");
    let report = tflite_request(path).run().unwrap();
    let doc = api::optimize_tflite_json(&report, None);
    assert_eq!(stdout, format!("{}\n", doc.to_pretty()));

    let parsed = Json::parse(&stdout).expect("valid JSON");
    assert_eq!(parsed.get("schema_version").as_f64(), Some(1.0));
    assert!(parsed.get("peaks").get("file").as_f64().is_some());
    assert!(parsed.get("peaks").get("elided").as_f64().is_some());
}

#[test]
fn cli_split_text_is_the_api_renderer() {
    let (code, stdout, stderr) = run_cli(&["split", "--model", "audionet"]);
    assert_eq!(code, 0, "split failed: {stderr}");

    let report = OptimizeRequest {
        source: zoo("audionet"),
        budget: None,
        board: &NUCLEO_F767ZI,
        split: Some(SplitOptions::default()),
        compare_materialized: false,
        trace: false,
    }
    .run()
    .unwrap();
    // The search wall-time is the single run-dependent value; recover the
    // printed figure and re-render with it — everything else must agree
    // byte for byte.
    let end = stdout.find("s search)").expect("search-time line present");
    let start = stdout[..end].rfind(", ").expect("elapsed delimiter") + 2;
    let elapsed: f64 = stdout[start..end].parse().expect("elapsed parses");
    assert_eq!(stdout, api::render_split(&report, elapsed));
}

#[test]
fn cli_analyze_and_errors_survive_the_facade_port() {
    // analyze (rebuilt on api::ModelSource resolution) still reports the
    // paper's Figure 1 peak.
    let (code, stdout, stderr) = run_cli(&["analyze", "--model", "figure1"]);
    assert_eq!(code, 0, "analyze failed: {stderr}");
    assert!(stdout.contains("peak working set : 5216 B"), "figure1 peak missing: {stdout}");

    // Unknown zoo model: clean one-line error listing the alternatives.
    let (code, _, stderr) = run_cli(&["analyze", "--model", "nope"]);
    assert_eq!(code, 1);
    assert!(stderr.contains("unknown model \"nope\""), "{stderr}");
    assert!(stderr.contains("figure1"), "error should list the zoo: {stderr}");
    assert!(!stderr.contains("panicked"), "must fail cleanly: {stderr}");
}

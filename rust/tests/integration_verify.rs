//! Integration: the independent static verifier (`verify`) against the
//! whole optimize stack — the PR-9 proof-carrying-plans acceptance suite.
//!
//! Three claims are pinned here:
//!
//! 1. **Teeth** — a mutation harness applies deliberate corruptions to
//!    schedules, arenas, split rewrites, quantization maps and exported
//!    flatbuffers; every one must be *rejected*, each with its own
//!    precise `family/code` diagnostic (no catch-all errors).
//! 2. **No false alarms** — every plan the real pipeline produces (all
//!    zoo models and the `cnn_int8.tflite` fixture, reorder-only /
//!    materialized-split / elided-split, across all four boards)
//!    verifies clean, and the recomputed peaks agree with the Python
//!    exact-schedule mirror.
//! 3. **Uniform CLI failure contract** — every subcommand exits 2 with
//!    a one-line `usage error:` for bad invocations and 1 for runtime
//!    or verification failures (golden-tested via `CARGO_BIN_EXE`).

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;

use mcu_reorder::alloc::StaticPlan;
use mcu_reorder::api::{ModelSource, OptimizeRequest};
use mcu_reorder::graph::{Act, DType, Graph, GraphBuilder, OpKind, Padding, SplitAxis};
use mcu_reorder::interp::quant::QuantParams;
use mcu_reorder::mcu::boards::ALL_BOARDS;
use mcu_reorder::models;
use mcu_reorder::sched;
use mcu_reorder::split::{self, SegmentSplit, SplitOptions};
use mcu_reorder::tflite::{self, fixtures};
use mcu_reorder::trace::Event;
use mcu_reorder::util::json::Json;
use mcu_reorder::verify::{
    certify_report, verify_arena, verify_export, verify_operator_order, verify_peak,
    verify_quant, verify_schedule, verify_split,
};

fn run_cli(args: &[&str]) -> (i32, String, String) {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_mcu-reorder"))
        .args(args)
        .output()
        .expect("spawn mcu-reorder");
    (
        out.status.code().unwrap_or(-1),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("mcu-reorder-verify-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn zoo(name: &str) -> ModelSource {
    ModelSource::Zoo { name: name.to_string(), dtype: DType::I8 }
}

/// 9×9 conv→relu chain: factor-3 row bands are an even 3 rows each, so
/// the rewrite's band offsets (0, 3, 6) are known in advance.
fn conv_relu_chain() -> Graph {
    let mut b = GraphBuilder::new("vchain");
    let x = b.input("x", &[1, 9, 9, 2], DType::I8);
    let c1 = b.conv2d("c1", x, 8, (3, 3), (1, 1), Padding::Same, Act::Linear);
    let r1 = b.relu("r1", c1);
    let gap = b.global_avgpool("gap", r1);
    let fc = b.dense("fc", gap, 3, Act::Linear);
    b.output(fc);
    b.finish().unwrap()
}

fn split_chain(elide: bool, factor: usize, axis: SplitAxis) -> (Graph, split::SplitResult) {
    let g = conv_relu_chain();
    let seg = SegmentSplit {
        ops: vec![g.op_by_name("c1").unwrap().id, g.op_by_name("r1").unwrap().id],
        factor,
        axis,
        elide,
    };
    let res = split::apply_segment(&g, &seg).unwrap();
    (g, res)
}

/// The `PartialInto` writer whose band starts at `offset`.
fn writer_at(g: &Graph, want: usize) -> usize {
    g.ops
        .iter()
        .find(|o| matches!(o.kind, OpKind::PartialInto { offset, .. } if offset == want))
        .unwrap_or_else(|| panic!("no write-through band at offset {want}"))
        .id
}

/// Rewrite the band geometry of a `Partial`/`PartialInto` op in place.
fn set_band(g: &mut Graph, op: usize, off: Option<usize>, length: Option<usize>, p: Option<isize>) {
    match &mut g.ops[op].kind {
        OpKind::PartialInto { offset, len, pad, .. } => {
            if let Some(o) = off {
                *offset = o;
            }
            if let Some(l) = length {
                *len = l;
            }
            if let Some(pp) = p {
                *pad = pp;
            }
        }
        OpKind::Partial { offset, pad, .. } => {
            if let Some(o) = off {
                *offset = o;
            }
            if let Some(pp) = p {
                *pad = pp;
            }
        }
        other => panic!("op {op} is not a slice: {other:?}"),
    }
}

fn qp(scale: f32, zero_point: i32) -> QuantParams {
    QuantParams { scale, zero_point }
}

// ---------------------------------------------------------------------------
// 1. Mutation harness: every corruption rejected, each with its own code.
// ---------------------------------------------------------------------------

/// Every diagnostic the harness below provokes. Pinned as a list so a
/// refactor collapsing two corruptions into one catch-all code fails
/// loudly here rather than silently blunting the verifier's teeth.
const EXPECTED_CODES: [&str; 24] = [
    // family: schedule
    "order-length",
    "order-out-of-range",
    "order-duplicate",
    "order-not-topological",
    "peak-mismatch",
    // family: arena
    "slot-missing",
    "slot-out-of-bounds",
    "slot-overlap",
    "alias-without-chain",
    "alias-misaligned",
    "alias-band-overlap",
    // family: split
    "provenance-length",
    "band-gap",
    "band-overlap",
    "band-extent",
    "halo-mismatch",
    "slab-shape",
    "slice-kind",
    "concat-cover",
    "weight-partition",
    // family: quant
    "qparams-scale",
    "qparams-mismatch",
    // (qparams-missing and qparams-softmax are asserted too; see below)
    // family: export
    "export-count",
    "export-buffers-differ",
];

#[test]
fn mutation_codes_are_distinct_and_cover_the_issue_floor() {
    let set: HashSet<&str> = EXPECTED_CODES.iter().copied().collect();
    assert_eq!(set.len(), EXPECTED_CODES.len(), "duplicate diagnostic code");
    assert!(EXPECTED_CODES.len() >= 15, "the issue demands ~15 distinct corruptions");
}

#[test]
fn mutated_schedules_are_rejected() {
    let g = models::figure1();
    let order = g.default_order();

    let e = verify_schedule(&g, &order[..order.len() - 1]).unwrap_err();
    assert_eq!((e.family, e.code), ("schedule", "order-length"));

    let mut o = order.clone();
    *o.last_mut().unwrap() = g.n_ops();
    assert_eq!(verify_schedule(&g, &o).unwrap_err().code, "order-out-of-range");

    let mut o = order.clone();
    *o.last_mut().unwrap() = o[0];
    assert_eq!(verify_schedule(&g, &o).unwrap_err().code, "order-duplicate");

    let mut o = order.clone();
    o.reverse();
    assert_eq!(verify_schedule(&g, &o).unwrap_err().code, "order-not-topological");

    // A planner lying about its peak is caught with both numbers named.
    let e = verify_peak(&g, &order, 1, "default order").unwrap_err();
    assert_eq!(e.code, "peak-mismatch");
    assert!(e.msg.contains("5216"), "diagnostic must carry the recomputed peak: {e}");

    // The honest artifacts pass (paper reference values).
    assert_eq!(verify_peak(&g, &order, 5216, "default order").unwrap().peak_bytes, 5216);
    let (opt, _) = sched::optimal(&g).unwrap();
    assert_eq!(verify_peak(&g, &opt.order, 4960, "reordered").unwrap().peak_bytes, 4960);
}

#[test]
fn mutated_arena_plans_are_rejected() {
    let mut b = GraphBuilder::new("vrelu");
    let x = b.input("x", &[1, 4, 4, 2], DType::I8);
    let r1 = b.relu("r1", x);
    let r2 = b.relu("r2", r1);
    b.output(r2);
    let g = b.finish().unwrap();
    let facts = verify_schedule(&g, &g.default_order()).unwrap();
    // x and r1 are live together at step 0, r1 and r2 at step 1; each
    // tensor is 32 B.
    let plan = |slots: &[(usize, usize)], arena_bytes: usize| StaticPlan {
        offsets: slots.iter().copied().collect(),
        arena_bytes,
        strategy: "doctored",
    };

    let e = verify_arena(&g, &facts, &plan(&[(x, 0), (r2, 64)], 4096)).unwrap_err();
    assert_eq!((e.family, e.code), ("arena", "slot-missing"));
    assert!(e.msg.contains("r1"), "diagnostic must name the unplaced tensor: {e}");

    let p = plan(&[(x, 0), (r1, 32), (r2, 64)], 64);
    assert_eq!(verify_arena(&g, &facts, &p).unwrap_err().code, "slot-out-of-bounds");

    let p = plan(&[(x, 0), (r1, 1), (r2, 100)], 4096);
    assert_eq!(verify_arena(&g, &facts, &p).unwrap_err().code, "slot-overlap");

    // Same slot + same size while both live, but no accumulator chain
    // licenses the aliasing.
    let p = plan(&[(x, 0), (r1, 0), (r2, 100)], 4096);
    assert_eq!(verify_arena(&g, &facts, &p).unwrap_err().code, "alias-without-chain");

    // The tightest honest placement (r2 reuses x's slot) passes.
    verify_arena(&g, &facts, &plan(&[(x, 0), (r1, 32), (r2, 0)], 64)).unwrap();
}

#[test]
fn mutated_accumulator_chains_are_rejected() {
    let (_g, res) = split_chain(true, 3, SplitAxis::Rows);
    let sg = res.graph.clone();
    let (opt, _) = sched::optimal(&sg).unwrap();
    let facts = verify_schedule(&sg, &opt.order).unwrap();

    // alias-misaligned: a chained write-through slice placed one byte
    // off its accumulator's slot. Everything else parks far away so the
    // chain pair is the only colliding one.
    let chained = sg
        .ops
        .iter()
        .find(|o| matches!(o.kind, OpKind::PartialInto { .. }) && o.inputs.len() == 2)
        .expect("a chained write-through slice");
    let (out, acc) = (chained.output, chained.inputs[1]);
    assert_eq!(facts.find(out), facts.find(acc), "writer must share its accumulator's buffer");
    let mut offsets: HashMap<usize, usize> = HashMap::new();
    let mut far = 1 << 16;
    for t in 0..sg.tensors.len() {
        if !facts.counted[t] {
            continue;
        }
        if t == acc {
            offsets.insert(t, 0);
        } else if t == out {
            offsets.insert(t, 1);
        } else {
            offsets.insert(t, far);
            far += 1 << 16;
        }
    }
    let plan = StaticPlan { offsets, arena_bytes: far + (1 << 16), strategy: "doctored" };
    let e = verify_arena(&sg, &facts, &plan).unwrap_err();
    assert_eq!((e.family, e.code), ("arena", "alias-misaligned"));

    // alias-band-overlap: the middle writer rebanded onto [0, 3) — two
    // writers of one shared buffer now scribble the same rows.
    let mut mg = sg.clone();
    set_band(&mut mg, writer_at(&sg, 3), Some(0), None, None);
    let mfacts = verify_schedule(&mg, &opt.order).unwrap();
    let mplan = StaticPlan::best_fit(&mg, &opt.order);
    assert_eq!(verify_arena(&mg, &mfacts, &mplan).unwrap_err().code, "alias-band-overlap");

    // The unmutated rewrite passes with its real best-fit placement.
    verify_arena(&sg, &facts, &StaticPlan::best_fit(&sg, &opt.order)).unwrap();
}

#[test]
fn mutated_split_rewrites_are_rejected() {
    let (g, res) = split_chain(true, 3, SplitAxis::Rows);
    let sg = &res.graph;
    verify_split(&g, sg, &res.sources).unwrap();

    let e = verify_split(&g, sg, &res.sources[..res.sources.len() - 1]).unwrap_err();
    assert_eq!((e.family, e.code), ("split", "provenance-length"));

    let mid = writer_at(sg, 3);
    // band-gap: rows [3, 4) of the join written by nobody.
    let mut m = sg.clone();
    set_band(&mut m, mid, Some(4), None, None);
    let e = verify_split(&g, &m, &res.sources).unwrap_err();
    assert_eq!(e.code, "band-gap");
    assert!(e.msg.contains("[3, 4)"), "diagnostic must name the hole: {e}");

    // band-overlap: rows [2, 3) double-covered.
    let mut m = sg.clone();
    set_band(&mut m, mid, Some(2), None, None);
    assert_eq!(verify_split(&g, &m, &res.sources).unwrap_err().code, "band-overlap");

    // band-extent: the last band pushed past the join's 9 rows.
    let mut m = sg.clone();
    set_band(&mut m, writer_at(sg, 6), Some(7), None, None);
    assert_eq!(verify_split(&g, &m, &res.sources).unwrap_err().code, "band-extent");

    // halo-mismatch (pointwise): a phantom pad on a 1:1 relu band.
    let mut m = sg.clone();
    set_band(&mut m, mid, None, None, Some(1));
    assert_eq!(verify_split(&g, &m, &res.sources).unwrap_err().code, "halo-mismatch");

    // halo-mismatch (windowed): the conv head's recorded pad shifted,
    // so its slab no longer holds the band's receptive field.
    let conv_mid = sg
        .ops
        .iter()
        .find(|o| matches!(&o.kind, OpKind::Partial { offset, .. } if *offset == 3))
        .unwrap()
        .id;
    let mut m = sg.clone();
    if let OpKind::Partial { pad, .. } = &mut m.ops[conv_mid].kind {
        *pad += 1;
    }
    assert_eq!(verify_split(&g, &m, &res.sources).unwrap_err().code, "halo-mismatch");

    // slab-shape: a slice output widened along a non-band dim.
    let slab_op = sg.ops.iter().find(|o| matches!(o.kind, OpKind::Partial { .. })).unwrap().id;
    let mut m = sg.clone();
    let slab_t = m.ops[slab_op].output;
    m.tensors[slab_t].shape[2] += 1;
    assert_eq!(verify_split(&g, &m, &res.sources).unwrap_err().code, "slab-shape");

    // slice-kind: an op that has no banded-slice semantics at all.
    let mut m = sg.clone();
    if let OpKind::Partial { inner, .. } = &mut m.ops[slab_op].kind {
        *inner = Box::new(OpKind::GlobalAvgPool);
    }
    assert_eq!(verify_split(&g, &m, &res.sources).unwrap_err().code, "slice-kind");

    // concat-cover: a materialized join missing one slab.
    let (g2, res2) = split_chain(false, 3, SplitAxis::Rows);
    verify_split(&g2, &res2.graph, &res2.sources).unwrap();
    let mut m = res2.graph.clone();
    let cat =
        m.ops.iter().find(|o| matches!(o.kind, OpKind::ConcatSlices { .. })).unwrap().id;
    m.ops[cat].inputs.pop();
    assert_eq!(verify_split(&g2, &m, &res2.sources).unwrap_err().code, "concat-cover");

    // weight-partition: a channel split whose weight matrix lost a
    // column — the second head now reads columns that do not exist.
    let (g3, res3) = split_chain(false, 2, SplitAxis::Channels);
    verify_split(&g3, &res3.graph, &res3.sources).unwrap();
    let mut m = res3.graph.clone();
    let w = m
        .ops
        .iter()
        .find(|o| {
            matches!(&o.kind,
                OpKind::Partial { inner, offset, .. }
                    if matches!(inner.as_ref(), OpKind::Conv2D { .. }) && *offset == 4)
        })
        .expect("second conv projection head")
        .weights[0];
    *m.tensors[w].shape.last_mut().unwrap() -= 1;
    assert_eq!(verify_split(&g3, &m, &res3.sources).unwrap_err().code, "weight-partition");
}

#[test]
fn mutated_quantization_maps_are_rejected() {
    let mut b = GraphBuilder::new("vquant");
    let x = b.input("x", &[1, 8], DType::I8);
    let r = b.relu("r", x);
    let s = b.softmax("s", r);
    b.output(s);
    let g = b.finish().unwrap();
    let (x, r, s) = (x, g.op_by_name("r").unwrap().output, g.op_by_name("s").unwrap().output);
    let map = |entries: &[(usize, QuantParams)]| -> HashMap<usize, QuantParams> {
        entries.iter().copied().collect()
    };

    let e = verify_quant(&g, &map(&[(x, qp(0.0, 0))])).unwrap_err();
    assert_eq!((e.family, e.code), ("quant", "qparams-scale"));

    // Relu must keep its input's domain.
    let m = map(&[(x, qp(0.5, 0)), (r, qp(0.25, 3)), (s, qp(1.0 / 256.0, -128))]);
    assert_eq!(verify_quant(&g, &m).unwrap_err().code, "qparams-mismatch");

    // Quantized input, unquantized output: a half-quantized graph.
    let m = map(&[(x, qp(0.5, 0)), (s, qp(1.0 / 256.0, -128))]);
    assert_eq!(verify_quant(&g, &m).unwrap_err().code, "qparams-missing");

    // i8 softmax must write the conventional (1/256, -128) domain.
    let m = map(&[(x, qp(0.5, 0)), (r, qp(0.5, 0)), (s, qp(0.5, 0))]);
    assert_eq!(verify_quant(&g, &m).unwrap_err().code, "qparams-softmax");

    // The importer's real flow rules pass.
    let m = map(&[(x, qp(0.5, 0)), (r, qp(0.5, 0)), (s, qp(1.0 / 256.0, -128))]);
    verify_quant(&g, &m).unwrap();
}

#[test]
fn mutated_exports_are_rejected() {
    let path = fixtures::ensure(fixtures::INT8_FIXTURE).expect("fixtures");
    let src = tflite::read_model(path.to_str().unwrap()).unwrap();

    let mut m = src.clone();
    m.subgraph.operators.pop();
    let e = verify_export(&src, &m).unwrap_err();
    assert_eq!((e.family, e.code), ("export", "export-count"));

    let mut m = src.clone();
    let buf = m.buffers.iter().position(|b| !b.is_empty()).unwrap();
    m.buffers[buf][0] ^= 0xFF;
    let e = verify_export(&src, &m).unwrap_err();
    assert_eq!(e.code, "export-buffers-differ");
    assert!(e.msg.contains(&format!("buffer {buf}")), "must name the buffer: {e}");

    let mut m = src.clone();
    m.operator_codes[0].version += 1;
    assert_eq!(verify_export(&src, &m).unwrap_err().code, "export-tensors-differ");

    let mut m = src.clone();
    assert_ne!(m.subgraph.operators[0], m.subgraph.operators[1]);
    m.subgraph.operators[0] = m.subgraph.operators[1].clone();
    assert_eq!(verify_export(&src, &m).unwrap_err().code, "export-not-permutation");

    assert_eq!(verify_operator_order(&[0, 0], 2).unwrap_err().code, "export-order-not-bijective");

    // Any true permutation of the operator vector passes.
    let mut m = src.clone();
    m.subgraph.operators.reverse();
    let perm = verify_export(&src, &m).unwrap();
    assert_eq!(perm.len(), src.subgraph.operators.len());
}

// ---------------------------------------------------------------------------
// 2. No false alarms: real plans verify clean everywhere.
// ---------------------------------------------------------------------------

#[test]
fn zoo_plans_verify_clean_across_modes_and_boards() {
    for name in models::MODEL_NAMES {
        for board in ALL_BOARDS {
            let modes: [Option<SplitOptions>; 3] = [
                None,                                       // reorder-only
                Some(SplitOptions::quick().materialized()), // split, joins kept
                Some(SplitOptions::quick()),                // split, joins elided
            ];
            for split in modes {
                let tag = format!("{name} on {}", board.name);
                let report = OptimizeRequest {
                    source: zoo(name),
                    budget: None,
                    board,
                    split,
                    compare_materialized: false,
                    trace: false,
                }
                .run()
                .unwrap_or_else(|e| panic!("{tag}: {e}"));
                assert!(report.verified, "{tag}: report left unverified");
                let cert = certify_report(&report).unwrap_or_else(|e| panic!("{tag}: {e}"));
                assert!(
                    cert.checks.iter().filter(|c| c.status == "ok").count() >= 2,
                    "{tag}: schedule + arena must always be proven"
                );
            }
        }
    }
}

#[test]
fn tflite_fixture_verifies_clean_with_quant_and_export_families_proven() {
    let path = fixtures::ensure(fixtures::INT8_FIXTURE).expect("fixtures");
    let path = path.to_str().unwrap();
    for board in ALL_BOARDS {
        for split in [None, Some(SplitOptions::quick())] {
            let report = OptimizeRequest {
                source: ModelSource::TflitePath(path.to_string()),
                budget: None,
                board,
                split,
                compare_materialized: false,
                trace: false,
            }
            .run()
            .unwrap_or_else(|e| panic!("{path} on {}: {e}", board.name));
            assert!(report.verified);
            let cert = certify_report(&report).unwrap();
            for fam in ["quant", "export"] {
                assert!(
                    cert.checks.iter().any(|c| c.family == fam && c.status == "ok"),
                    "{fam} must be proven (not skipped) on an int8 .tflite source"
                );
            }
        }
    }
}

/// The verifier's recomputed peaks agree with the Python exact-schedule
/// mirror — a third, independent implementation of the accounting.
#[test]
fn verifier_peaks_match_the_python_mirror() {
    let script = concat!(env!("CARGO_MANIFEST_DIR"), "/tools/schedule_mirror/mirror.py");
    for model in ["figure1", "mobilenet", "streamnet"] {
        for order_kind in ["default", "optimal"] {
            let out = match std::process::Command::new("python3")
                .args([script, "--trace", model, "--order", order_kind])
                .output()
            {
                Ok(o) if o.status.success() => o,
                Ok(o) => panic!(
                    "mirror failed on {model}/{order_kind}: {}",
                    String::from_utf8_lossy(&o.stderr)
                ),
                Err(_) => {
                    eprintln!("python3 unavailable; skipping the mirror cross-check");
                    return;
                }
            };
            let csv = String::from_utf8_lossy(&out.stdout).into_owned();
            let mirror_peak = csv
                .lines()
                .skip(1)
                .map(|l| l.split(',').nth(2).unwrap().parse::<usize>().unwrap())
                .max()
                .unwrap();
            let g = models::by_name(model, DType::I8).unwrap();
            let order = match order_kind {
                "default" => g.default_order(),
                _ => sched::optimal(&g).unwrap().0.order,
            };
            let facts = verify_schedule(&g, &order).unwrap();
            assert_eq!(
                facts.peak_bytes, mirror_peak,
                "{model}/{order_kind}: verifier vs python mirror"
            );
        }
    }
}

/// Tracing a request surfaces the certification as a `verify` event.
#[test]
fn traced_reports_carry_one_verify_event() {
    let report = OptimizeRequest::reorder_only(zoo("figure1")).with_trace(true).run().unwrap();
    let verifies: Vec<_> =
        report.events.iter().filter(|e| matches!(e, Event::Verify { .. })).collect();
    assert_eq!(verifies.len(), 1, "exactly one certification per run");
    if let Event::Verify { model, checks, peak_bytes, ok } = verifies[0] {
        assert!(*ok, "run() only returns certified reports");
        assert_eq!(model, "figure1");
        assert!(*checks >= 4, "all five families must be visited");
        assert_eq!(*peak_bytes, 4960, "the certificate pins the reordered peak");
    }
}

// ---------------------------------------------------------------------------
// 3. CLI: the verify subcommand and the uniform exit-code contract.
// ---------------------------------------------------------------------------

#[test]
fn cli_verify_prints_certificates_and_json() {
    let (code, stdout, stderr) = run_cli(&["verify", "--model", "figure1", "--reorder-only"]);
    assert_eq!(code, 0, "verify failed: {stderr}");
    assert!(stdout.starts_with("verified: figure1"), "certificate header: {stdout}");
    assert!(stdout.contains("peak 4960 B"), "paper peak missing: {stdout}");

    // Positional zoo-name dispatch, with the full split pipeline.
    let (code, stdout, stderr) = run_cli(&["verify", "figure1"]);
    assert_eq!(code, 0, "verify figure1 failed: {stderr}");
    assert!(stdout.starts_with("verified: figure1"));

    // --json: a parseable certificate with every family listed.
    let (code, stdout, _) =
        run_cli(&["verify", "--model", "figure1", "--reorder-only", "--json"]);
    assert_eq!(code, 0);
    let doc = Json::parse(&stdout).expect("valid certificate JSON");
    assert_eq!(doc.get("verified").as_bool(), Some(true));
    assert_eq!(doc.get("peak_bytes").as_f64(), Some(4960.0));
    let checks = doc.get("checks").as_arr().expect("checks array");
    let families: Vec<&str> =
        checks.iter().filter_map(|c| c.get("family").as_str()).collect();
    assert_eq!(families, ["schedule", "arena", "split", "quant", "export"]);

    // --json FILE writes the same document.
    let dir = tmp_dir("json");
    let out = dir.join("cert.json");
    let (code, _, _) = run_cli(&[
        "verify",
        "--model",
        "figure1",
        "--reorder-only",
        "--json",
        out.to_str().unwrap(),
    ]);
    assert_eq!(code, 0);
    let written = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
    assert_eq!(written.get("verified").as_bool(), Some(true));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_verify_proves_exported_flatbuffers() {
    let fixture = fixtures::ensure(fixtures::INT8_FIXTURE).expect("fixtures");
    let path = fixture.to_str().unwrap();
    let dir = tmp_dir("export");
    let out = dir.join("reordered.tflite");
    let out_str = out.to_str().unwrap();

    let (code, _, stderr) = run_cli(&["optimize", path, "-o", out_str]);
    assert_eq!(code, 0, "optimize failed: {stderr}");

    let (code, stdout, stderr) =
        run_cli(&["verify", path, "--reorder-only", "--reordered", out_str]);
    assert_eq!(code, 0, "verify --reordered failed: {stderr}");
    assert!(stdout.contains("export ok"), "{stdout}");
    assert!(stdout.contains("verified:"), "{stdout}");

    // A truncated export is refused (exit 1, one-line error).
    let bytes = std::fs::read(&out).unwrap();
    let garbled = dir.join("garbled.tflite");
    std::fs::write(&garbled, &bytes[..bytes.len() / 2]).unwrap();
    let (code, _, stderr) =
        run_cli(&["verify", path, "--reorder-only", "--reordered", garbled.to_str().unwrap()]);
    assert_eq!(code, 1, "truncated export must fail verification: {stderr}");
    assert!(!stderr.contains("panicked"), "must fail cleanly: {stderr}");

    // --reordered against a zoo model is a usage error: there is no
    // source flatbuffer to compare with.
    let (code, _, stderr) = run_cli(&[
        "verify",
        "--model",
        "figure1",
        "--reorder-only",
        "--reordered",
        out_str,
    ]);
    assert_eq!(code, 2, "{stderr}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_exit_codes_are_uniform_across_subcommands() {
    // Usage errors → exit 2, prefixed "usage error:".
    let usage_cases: &[&[&str]] = &[
        &["frobnicate"],
        &["verify"],
        &["verify", "--model", "figure1", "--budget", "abc"],
        &["verify", "--model", "figure1", "--dtype", "bogus"],
        &["verify", "--model", "figure1", "--board", "nope"],
        &["analyze", "--model", "figure1", "--dtype", "bogus"],
        &["split", "--model", "figure1", "--axes", "bogus"],
    ];
    for args in usage_cases {
        let (code, _, stderr) = run_cli(args);
        assert_eq!(code, 2, "{args:?}: want exit 2, stderr: {stderr}");
        assert!(stderr.starts_with("error: usage error: "), "{args:?}: {stderr}");
    }

    // Runtime failures → exit 1 with a one-line error.
    let runtime_cases: &[&[&str]] = &[
        &["verify", "--model", "nope"],
        &["verify", "/nonexistent/model.tflite"],
        &["analyze", "--model", "nope"],
    ];
    for args in runtime_cases {
        let (code, _, stderr) = run_cli(args);
        assert_eq!(code, 1, "{args:?}: want exit 1, stderr: {stderr}");
        assert!(stderr.starts_with("error: "), "{args:?}: {stderr}");
        assert!(!stderr.contains("panicked"), "{args:?} must fail cleanly: {stderr}");
    }
    let (_, _, stderr) = run_cli(&["verify", "--model", "nope"]);
    assert_eq!(stderr.lines().count(), 1, "one-line error contract: {stderr}");
}

//! Cross-layer integration of the trace subsystem: event-stream
//! invariants, analytic-vs-measured equality, zero-cost-when-off
//! structural equality, and the Chrome export contract.
//!
//! The acceptance properties live here:
//! - the scheduler's event stream is *balanced*: every `TensorAlloc` has
//!   exactly one `TensorFree`, frees never precede allocs, and the
//!   residual live set is released at `step == order.len()`;
//! - the traced simulation equals the untraced one field-for-field (the
//!   `NullSink` paths are the production paths);
//! - traced peak == `peak_of` across the zoo × {default, reordered,
//!   split, elided} × {f32, i8};
//! - the audit (measured interpreter high-water == analytic peak at an
//!   exact-capacity arena) passes on representative models — CI runs the
//!   full zoo through `mcu-reorder trace --audit`;
//! - the Chrome trace-event export is valid JSON with the documented
//!   event shapes for every zoo model;
//! - the best-fit planner's `SlotPlaced` events reproduce the plan.

use mcu_reorder::alloc::StaticPlan;
use mcu_reorder::graph::DType;
use mcu_reorder::interp::WeightStore;
use mcu_reorder::models;
use mcu_reorder::sched;
use mcu_reorder::split::{self, SplitOptions};
use mcu_reorder::trace::{self, audit, Event, NullSink, VecSink};
use mcu_reorder::util::json::Json;

use std::collections::HashMap;

/// Per-tensor alloc/free bookkeeping over one event stream.
fn balance_of(events: &[Event]) -> HashMap<usize, (Vec<usize>, Vec<usize>)> {
    let mut per: HashMap<usize, (Vec<usize>, Vec<usize>)> = HashMap::new();
    for ev in events {
        match ev {
            Event::TensorAlloc { step, tensor, .. } => {
                per.entry(*tensor).or_default().0.push(*step)
            }
            Event::TensorFree { step, tensor, .. } => {
                per.entry(*tensor).or_default().1.push(*step)
            }
            _ => {}
        }
    }
    per
}

#[test]
fn event_stream_is_balanced_on_every_zoo_model() {
    for name in models::MODEL_NAMES {
        let g = models::by_name(name, DType::I8).unwrap();
        for order in [g.default_order(), sched::optimal(&g).unwrap().0.order] {
            let mut sink = VecSink::new();
            let mt = sched::simulate_traced(&g, &order, sched::Opts::default(), &mut sink);

            assert_eq!(sink.count("op"), order.len(), "{name}: one OpExec per step");
            let n_end_frees = sink
                .events
                .iter()
                .filter(|e| matches!(e, Event::TensorFree { step, .. } if *step == order.len()))
                .count();
            assert!(n_end_frees >= g.outputs.len(), "{name}: outputs freed at the end");

            for (tensor, (allocs, frees)) in balance_of(&sink.events) {
                assert_eq!(
                    allocs.len(),
                    frees.len(),
                    "{name}: tensor {tensor} has {} allocs but {} frees",
                    allocs.len(),
                    frees.len()
                );
                assert_eq!(allocs.len(), 1, "{name}: tensor {tensor} allocated once");
                assert!(
                    allocs[0] <= frees[0],
                    "{name}: tensor {tensor} freed before allocated"
                );
            }

            // The stream reproduces the trace's byte accounting.
            let exec_bytes: Vec<usize> = sink
                .events
                .iter()
                .filter_map(|e| match e {
                    Event::OpExec { bytes, .. } => Some(*bytes),
                    _ => None,
                })
                .collect();
            let step_bytes: Vec<usize> = mt.steps.iter().map(|s| s.bytes).collect();
            assert_eq!(exec_bytes, step_bytes, "{name}");
        }
    }
}

/// The NullSink path IS the production path: traced and untraced
/// simulation must agree on every field.
#[test]
fn nullsink_simulation_is_structurally_identical() {
    for name in models::MODEL_NAMES {
        let g = models::by_name(name, DType::I8).unwrap();
        let order = g.default_order();
        let a = sched::simulate_opts(&g, &order, sched::Opts::default());
        let b = sched::simulate_traced(&g, &order, sched::Opts::default(), &mut NullSink);
        assert_eq!(a.peak_bytes, b.peak_bytes, "{name}");
        assert_eq!(a.peak_step, b.peak_step, "{name}");
        assert_eq!(a.order, b.order, "{name}");
        assert_eq!(a.steps.len(), b.steps.len(), "{name}");
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            assert_eq!(sa.op, sb.op, "{name}");
            assert_eq!(sa.bytes, sb.bytes, "{name}");
            assert_eq!(sa.resident, sb.resident, "{name}");
        }
    }
}

#[test]
fn traced_peak_matches_peak_of_across_zoo_and_dtypes() {
    for name in models::MODEL_NAMES {
        for dtype in [DType::I8, DType::F32] {
            let g = models::by_name(name, dtype).unwrap();
            for order in [g.default_order(), sched::optimal(&g).unwrap().0.order] {
                let mut sink = VecSink::new();
                let mt = sched::simulate_traced(&g, &order, sched::Opts::default(), &mut sink);
                assert_eq!(
                    mt.peak_bytes,
                    sched::peak_of(&g, &order),
                    "{name}/{}",
                    dtype.name()
                );
            }
        }
    }
}

/// Split and elided rewrites flow through the traced simulation with the
/// same accounting the planner promised.
#[test]
fn traced_peak_matches_schedule_on_split_and_elided_graphs() {
    for name in ["mobilenet", "audionet", "tiny"] {
        for dtype in [DType::I8, DType::F32] {
            let g = models::by_name(name, dtype).unwrap();
            for opts in [SplitOptions::quick(), SplitOptions::quick().materialized()] {
                let out = split::optimize(&g, &opts).unwrap();
                let mt = sched::simulate(&out.graph, &out.schedule.order);
                assert_eq!(
                    mt.peak_bytes,
                    out.schedule.peak_bytes,
                    "{name}/{} elide={}",
                    dtype.name(),
                    opts.elide
                );
            }
        }
    }
}

/// The audit's core claim on representative models: the interpreter,
/// running at an arena of exactly the analytic peak, measures a
/// high-water equal to it, for all four modes and every dtype the model
/// supports. CI gates the full zoo (release build) via
/// `mcu-reorder trace --audit`.
#[test]
fn audit_passes_on_representative_models() {
    for name in ["figure1", "tiny", "streamnet"] {
        let entries = audit::audit_zoo_model(name).unwrap();
        assert!(
            audit::all_ok(&entries),
            "audit failed for {name}:\n{}",
            audit::render(&entries)
        );
    }
}

#[test]
fn optimize_traced_telemetry_is_consistent() {
    let g = models::by_name("mobilenet", DType::I8).unwrap();
    let opts = SplitOptions::quick();
    let mut sink = VecSink::new();
    let traced = split::optimize_traced(&g, &opts, &mut sink).unwrap();
    let untraced = split::optimize(&g, &opts).unwrap();
    assert_eq!(traced.schedule.peak_bytes, untraced.schedule.peak_bytes);
    assert_eq!(traced.schedule.order, untraced.schedule.order);

    assert!(sink.count("phase") >= 2, "baseline + at least one round phase");
    assert!(sink.count("candidate") > 0);
    assert_eq!(sink.count("round"), 1, "quick() runs one beam round");

    // The round summary agrees with the per-candidate events.
    let kept_candidates = sink
        .events
        .iter()
        .filter(|e| matches!(e, Event::Candidate { kept: true, .. }))
        .count();
    let scored_candidates = sink.count("candidate");
    match sink.events.iter().find(|e| matches!(e, Event::SearchRound { .. })) {
        Some(Event::SearchRound { scored, kept, best_peak, .. }) => {
            assert_eq!(*scored, scored_candidates);
            assert_eq!(*kept, kept_candidates);
            assert_eq!(*best_peak, traced.schedule.peak_bytes);
        }
        _ => unreachable!(),
    }
    // Every kept candidate strictly improved something: its peak is below
    // the reorder-only baseline of its state.
    for ev in &sink.events {
        if let Event::Candidate { kept: true, peak, reason, .. } = ev {
            assert_eq!(*reason, "improved");
            assert!(peak.unwrap() < traced.base_peak);
        }
    }
}

#[test]
fn chrome_export_is_valid_for_every_zoo_model() {
    for name in models::MODEL_NAMES {
        let g = models::by_name(name, DType::I8).unwrap();
        let order = g.default_order();
        let mt = sched::simulate(&g, &order);
        let doc = trace::chrome::chrome_trace(&g, &mt, None);
        let j = Json::parse(&doc.to_pretty()).unwrap_or_else(|e| {
            panic!("{name}: chrome export is not valid JSON: {e:?}")
        });
        let evs = j.get("traceEvents").as_arr().unwrap();
        assert_eq!(evs.len(), 2 + 2 * mt.steps.len() + 1, "{name}");
        assert_eq!(
            j.get("otherData").get("peak_bytes").as_f64(),
            Some(mt.peak_bytes as f64),
            "{name}"
        );
        // Counter samples reproduce the analytic byte series.
        let counters: Vec<usize> = evs
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("C"))
            .map(|e| e.get("args").get("bytes").as_f64().unwrap() as usize)
            .collect();
        let series: Vec<usize> = mt.steps.iter().map(|s| s.bytes).collect();
        assert_eq!(counters, series, "{name}");
    }
}

#[test]
fn best_fit_traced_slot_events_reproduce_the_plan() {
    let g = models::by_name("swiftnet", DType::I8).unwrap();
    let order = sched::optimal(&g).unwrap().0.order;
    let mut sink = VecSink::new();
    let plan = StaticPlan::best_fit_traced(&g, &order, &mut sink);
    let untraced = StaticPlan::best_fit(&g, &order);
    assert_eq!(plan.arena_bytes, untraced.arena_bytes);

    let n_act = g.tensors.iter().filter(|t| !t.is_weight).count();
    assert_eq!(sink.count("slot"), n_act, "one SlotPlaced per activation tensor");
    for ev in &sink.events {
        if let Event::SlotPlaced { tensor, offset, bytes, .. } = ev {
            assert_eq!(plan.offsets[tensor], *offset);
            assert!(offset + bytes <= plan.arena_bytes);
        }
    }
}

#[test]
fn run_traced_arena_series_hits_the_analytic_peak() {
    let g = models::by_name("tiny", DType::F32).unwrap();
    let ws = WeightStore::seeded_f32(&g, 42);
    let order = sched::optimal(&g).unwrap().0.order;
    let series = audit::measured_series(&g, &ws, &order).unwrap();
    assert_eq!(series.len(), g.n_ops());
    assert_eq!(*series.last().unwrap(), sched::peak_of(&g, &order));
}

/// `schedule_diff` + `live_csv` smoke over a real model (their exact
/// formats are pinned by unit tests; this checks they stay usable on a
/// big graph and agree on the peak).
#[test]
fn diff_and_csv_render_on_mobilenet() {
    let g = models::by_name("mobilenet", DType::I8).unwrap();
    let a = sched::simulate(&g, &g.default_order());
    let b = sched::simulate(&g, &sched::optimal(&g).unwrap().0.order);
    let d = trace::schedule_diff(&g, &a, &b);
    assert!(d.contains(&format!("peak: A = {} B", a.peak_bytes)));
    let csv = trace::live_csv(&g, &a);
    assert_eq!(csv.lines().count(), a.steps.len() + 1);
    assert!(csv.lines().nth(1).unwrap().starts_with("0,conv1,"));
}

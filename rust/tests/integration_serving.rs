//! Integration: the plan-serving coordinator end to end.
//!
//! Covers the ISSUE-8 acceptance criteria: the service plans the full
//! zoo plus the imported int8 TFLite fixture across every board
//! profile, cache hits (and post-eviction recomputations) are
//! bit-identical to fresh plans, and the TCP front-end survives every
//! protocol error — malformed commands, unknown models/boards/uploads,
//! bad budgets, oversized lines, infeasible explicit budgets, garbage
//! uploads — with a clean `ERR`/`SHED` reply and a connection that
//! keeps serving. The `ARTIFACT` download path (reordered `.tflite` /
//! generated C for a cached plan) is covered the same way: happy-path
//! byte round-trips plus abuse with unknown kinds and uncached keys.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{mpsc, Arc};

use mcu_reorder::coordinator::{ModelRef, PlanRequest, PlanServeConfig, PlanService};
use mcu_reorder::mcu::boards;
use mcu_reorder::models;
use mcu_reorder::split::SplitOptions;
use mcu_reorder::tflite::fixtures;
use mcu_reorder::util::json::Json;

fn quick_cfg() -> PlanServeConfig {
    PlanServeConfig { workers: 1, split: SplitOptions::quick(), ..Default::default() }
}

fn serve(svc: Arc<PlanService>, conns: usize) -> SocketAddr {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        mcu_reorder::coordinator::serve_plans_tcp(svc, "127.0.0.1:0", Some(conns), move |a| {
            let _ = tx.send(a);
        })
        .expect("plan server")
    });
    rx.recv().expect("server address")
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        Client { reader: BufReader::new(stream.try_clone().expect("clone stream")), writer: stream }
    }

    fn send(&mut self, line: &str) -> String {
        self.writer.write_all(line.as_bytes()).expect("send line");
        self.writer.write_all(b"\n").expect("send newline");
        self.recv()
    }

    fn recv(&mut self) -> String {
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("recv line");
        line
    }

    /// Send an `ARTIFACT` line; `Ok(bytes)` for an `OK <n>` reply with its
    /// binary body, `Err(reply)` for anything else.
    fn artifact(&mut self, line: &str) -> Result<Vec<u8>, String> {
        let reply = self.send(line);
        match reply.trim().strip_prefix("OK ") {
            Some(n) => {
                let n: usize = n.parse().unwrap_or_else(|_| panic!("bad byte count: {reply:?}"));
                let mut bytes = vec![0u8; n];
                self.reader.read_exact(&mut bytes).expect("artifact body");
                Ok(bytes)
            }
            None => Err(reply),
        }
    }
}

fn fixture_bytes() -> Vec<u8> {
    let path = fixtures::ensure(fixtures::INT8_FIXTURE).expect("tflite fixture");
    std::fs::read(path).expect("reading tflite fixture")
}

// ---------------------------------------------------------------------------
// In-process: coverage + bit-identity
// ---------------------------------------------------------------------------

#[test]
fn serves_full_zoo_and_tflite_across_all_boards_bit_stably() {
    let svc = PlanService::start(quick_cfg());
    let hash = svc.upload("cnn_int8.tflite".to_string(), fixture_bytes()).expect("upload");

    let mut refs: Vec<ModelRef> =
        models::MODEL_NAMES.iter().map(|n| ModelRef::Zoo(n.to_string())).collect();
    refs.push(ModelRef::Uploaded(hash));

    let mut served = 0usize;
    for model in &refs {
        for board in boards::ALL_BOARDS {
            let req = PlanRequest { model: model.clone(), board, budget: None };
            let fresh = svc.plan(&req).expect("fresh plan");
            let cached = svc.plan(&req).expect("cached plan");
            assert_eq!(
                *fresh.json,
                *cached.json,
                "{}/{}: cache must be bit-identical",
                fresh.model,
                board.name
            );
            assert_eq!(*fresh.summary, *cached.summary);
            assert!(fresh.peak_bytes <= fresh.reordered_peak, "splitting can only help");
            assert!(fresh.budget_met == (fresh.peak_bytes <= board.sram_bytes));
            let doc = Json::parse(&fresh.summary).expect("summary parses");
            assert_eq!(doc.get("schema_version").as_f64(), Some(1.0));
            assert_eq!(doc.get("board").as_str(), Some(board.name));
            served += 2;
        }
    }
    let n_keys = refs.len() * boards::ALL_BOARDS.len();
    let s = svc.stats();
    assert_eq!(s.served as usize, served);
    assert_eq!(s.cache.misses as usize, n_keys, "each key computed exactly once");
    assert_eq!(s.cache.hits as usize, n_keys, "each key hit exactly once");
    assert_eq!(s.shed, 0);
    assert_eq!(s.errors, 0);
    svc.shutdown();
}

#[test]
fn recomputation_after_eviction_is_bit_identical() {
    let svc = PlanService::start(PlanServeConfig { cache_cap: 1, ..quick_cfg() });
    let fig = PlanRequest {
        model: ModelRef::Zoo("figure1".to_string()),
        board: boards::ALL_BOARDS[0],
        budget: None,
    };
    let tiny = PlanRequest { model: ModelRef::Zoo("tiny".to_string()), ..fig.clone() };

    let first = svc.plan(&fig).expect("first plan");
    svc.plan(&tiny).expect("evicting plan"); // cap 1: evicts figure1
    let recomputed = svc.plan(&fig).expect("recomputed plan");
    assert_eq!(*first.json, *recomputed.json, "recomputation must be bit-identical");
    assert_eq!(*first.summary, *recomputed.summary);
    let s = svc.stats();
    assert_eq!(s.cache.evictions, 2, "cap-1 cache evicts on every new key");
    assert_eq!(s.cache.hits, 0);
    svc.shutdown();
}

// ---------------------------------------------------------------------------
// TCP protocol error paths
// ---------------------------------------------------------------------------

#[test]
fn tcp_protocol_errors_do_not_kill_the_connection() {
    let svc = PlanService::start(quick_cfg());
    let addr = serve(svc.clone(), 1);
    let mut c = Client::connect(addr);

    for (line, expect) in [
        ("FROB", "ERR unknown command"),
        ("PLAN", "ERR usage: PLAN <model> <board> [budget]"),
        ("PLAN nope NUCLEO-F767ZI", "ERR unknown model"),
        ("PLAN figure1 no-such-board", "ERR unknown board"),
        ("PLAN figure1 NUCLEO-F767ZI twelve", "ERR bad budget"),
        ("PLAN hash:xyz NUCLEO-F767ZI", "ERR bad model hash"),
        ("PLAN hash:00000000deadbeef NUCLEO-F767ZI", "ERR unknown upload"),
        ("UPLOAD junk notanum", "ERR bad byte count"),
    ] {
        let reply = c.send(line);
        assert!(reply.starts_with(expect), "{line:?} → {reply:?} (wanted {expect:?})");
    }

    // An oversized line is reported and drained; the connection survives.
    let long = "A".repeat(svc.config().max_line_bytes + 100);
    let reply = c.send(&long);
    assert!(reply.starts_with("ERR line too long"), "{reply:?}");

    let reply = c.send("MODELS");
    assert!(reply.starts_with("OK ") && reply.contains("figure1"), "{reply:?}");
    let reply = c.send("BOARDS");
    assert!(reply.contains("NUCLEO-F767ZI") && reply.contains("sram_bytes"), "{reply:?}");
    let reply = c.send("STATS");
    assert!(reply.starts_with("OK {") && reply.contains("\"schema_version\""), "{reply:?}");

    // After all that abuse, the connection still serves real plans.
    let reply = c.send("PLAN figure1 NUCLEO-F767ZI");
    assert!(reply.starts_with("OK {"), "{reply:?}");
    let reply = c.send("GET figure1 nucleo-f767zi"); // board lookup is case-insensitive
    assert!(reply.starts_with("OK {"), "{reply:?}");
    let doc = Json::parse(reply.trim_start_matches("OK ").trim()).expect("GET returns JSON");
    assert_eq!(doc.get("schema_version").as_f64(), Some(1.0));
    assert_eq!(doc.get("model").as_str(), Some("figure1"));

    // QUIT closes cleanly.
    assert_eq!(c.send("QUIT"), "", "QUIT must close the connection");
    svc.shutdown();
}

#[test]
fn tcp_infeasible_budget_is_clean_and_connection_survives() {
    let svc = PlanService::start(quick_cfg());
    let addr = serve(svc.clone(), 1);
    let mut c = Client::connect(addr);

    let reply = c.send("PLAN mobilenet NUCLEO-F767ZI 16");
    assert!(reply.starts_with("ERR infeasible:"), "{reply:?}");
    assert!(reply.contains("budget 16 B"), "{reply:?}");

    // The same model under the board's own SRAM still plans fine.
    let reply = c.send("PLAN mobilenet NUCLEO-F767ZI");
    assert!(reply.starts_with("OK {"), "{reply:?}");
    let s = svc.stats();
    assert_eq!(s.infeasible, 1);
    svc.shutdown();
}

#[test]
fn tcp_sheds_when_the_queue_is_full() {
    // Paused service (no workers) with a zero-length queue: every uncached
    // request must be shed with an explicit SHED reply, never an error.
    let svc = PlanService::start_paused(PlanServeConfig { queue_cap: 0, ..quick_cfg() });
    let addr = serve(svc.clone(), 1);
    let mut c = Client::connect(addr);

    let reply = c.send("PLAN figure1 NUCLEO-F767ZI");
    assert!(reply.starts_with("SHED queue full"), "{reply:?}");
    let reply = c.send("PLAN tiny SparkFun-Edge");
    assert!(reply.starts_with("SHED queue full"), "{reply:?}");
    assert_eq!(svc.stats().shed, 2);
    svc.shutdown();
}

// ---------------------------------------------------------------------------
// ARTIFACT downloads
// ---------------------------------------------------------------------------

#[test]
fn tcp_artifact_serves_cached_plan_bytes() {
    let svc = PlanService::start(quick_cfg());
    let addr = serve(svc.clone(), 1);
    let mut c = Client::connect(addr);

    // Upload the fixture and plan it (board-default budget) to populate
    // the cache, then download both artifact kinds for the same key.
    let bytes = fixture_bytes();
    c.writer.write_all(format!("UPLOAD cnn_int8.tflite {}\n", bytes.len()).as_bytes()).unwrap();
    c.writer.write_all(&bytes).unwrap();
    let hash = c.recv().trim().strip_prefix("OK ").expect("upload accepted").to_string();
    let reply = c.send(&format!("PLAN hash:{hash} NUCLEO-F767ZI"));
    assert!(reply.starts_with("OK {"), "{reply:?}");

    let tfl = c
        .artifact(&format!("ARTIFACT TFLITE hash:{hash} NUCLEO-F767ZI"))
        .expect("tflite artifact");
    assert!(!tfl.is_empty(), "artifact body present");
    mcu_reorder::tflite::Model::parse(&tfl).expect("downloaded artifact is a loadable .tflite");

    let c_src = c
        .artifact(&format!("ARTIFACT C hash:{hash} NUCLEO-F767ZI"))
        .expect("C artifact");
    let c_text = String::from_utf8(c_src).expect("C artifact is UTF-8");
    assert!(c_text.contains("_invoke(") && c_text.contains("_ARENA_BYTES"), "single-file C");
    assert!(!c_text.contains("#include \""), "single-file C has the header inlined");

    // Zoo plans have no flatbuffer source but do have a C artifact.
    let reply = c.send("PLAN figure1 NUCLEO-F767ZI");
    assert!(reply.starts_with("OK {"), "{reply:?}");
    let err = c.artifact("ARTIFACT TFLITE figure1 NUCLEO-F767ZI").unwrap_err();
    assert!(err.starts_with("ERR no .tflite source"), "{err:?}");
    let fig = c.artifact("ARTIFACT C figure1 NUCLEO-F767ZI").expect("zoo C artifact");
    assert!(String::from_utf8(fig).unwrap().contains("figure1_invoke"), "zoo C artifact");

    c.send("QUIT");
    svc.shutdown();
}

#[test]
fn tcp_artifact_abuse_unknown_and_uncached_keys() {
    let svc = PlanService::start(quick_cfg());
    let addr = serve(svc.clone(), 1);
    let mut c = Client::connect(addr);

    for (line, expect) in [
        ("ARTIFACT", "ERR usage: ARTIFACT <TFLITE|C> <model> <board> [budget]"),
        ("ARTIFACT PDF figure1 NUCLEO-F767ZI", "ERR unknown artifact kind"),
        ("ARTIFACT C nope NUCLEO-F767ZI", "ERR unknown model"),
        ("ARTIFACT C figure1 no-such-board", "ERR unknown board"),
        ("ARTIFACT C hash:xyz NUCLEO-F767ZI", "ERR bad model hash"),
        ("ARTIFACT C hash:00000000deadbeef NUCLEO-F767ZI", "ERR unknown upload"),
        // Download-only: an uncached key must never trigger planning.
        ("ARTIFACT C figure1 NUCLEO-F767ZI", "ERR plan not cached"),
        ("ARTIFACT TFLITE figure1 NUCLEO-F767ZI", "ERR plan not cached"),
    ] {
        let reply = c.artifact(line).expect_err("abuse must not yield bytes");
        assert!(reply.starts_with(expect), "{line:?} → {reply:?} (wanted {expect:?})");
    }

    // A cached plan under one budget is not served under another key.
    let reply = c.send("PLAN figure1 NUCLEO-F767ZI");
    assert!(reply.starts_with("OK {"), "{reply:?}");
    let err = c.artifact("ARTIFACT C figure1 NUCLEO-F767ZI 123456").unwrap_err();
    assert!(err.starts_with("ERR plan not cached"), "{err:?}");

    // No planning jobs ran beyond the single explicit PLAN (downloads
    // never enqueue work or hand out plans).
    assert_eq!(svc.stats().served, 1, "ARTIFACT must never plan");

    // The connection survives the abuse and still serves downloads.
    let ok = c.artifact("ARTIFACT C figure1 NUCLEO-F767ZI").expect("cached C artifact");
    assert!(!ok.is_empty());
    c.send("QUIT");
    svc.shutdown();
}

#[test]
fn tcp_upload_roundtrip_garbage_and_size_cap() {
    let svc = PlanService::start(quick_cfg());
    let addr = serve(svc.clone(), 2);
    let mut c = Client::connect(addr);

    // Garbage bytes: parse error, connection survives.
    let body = b"not a flatbuffer!";
    c.writer.write_all(format!("UPLOAD junk.tflite {}\n", body.len()).as_bytes()).unwrap();
    c.writer.write_all(body).unwrap();
    let reply = c.recv();
    assert!(
        reply.starts_with("ERR") && reply.contains("not a loadable TFLite model"),
        "{reply:?}"
    );

    // Real fixture: accepted, hash usable as a model reference.
    let bytes = fixture_bytes();
    c.writer.write_all(format!("UPLOAD cnn_int8.tflite {}\n", bytes.len()).as_bytes()).unwrap();
    c.writer.write_all(&bytes).unwrap();
    let reply = c.recv();
    let hash = reply.trim().strip_prefix("OK ").expect("upload accepted").to_string();
    assert_eq!(hash.len(), 16, "hash is 16 hex digits: {hash:?}");
    let reply = c.send(&format!("PLAN hash:{hash} NUCLEO-F446RE"));
    assert!(reply.starts_with("OK {"), "{reply:?}");
    let doc = Json::parse(reply.trim_start_matches("OK ").trim()).expect("summary parses");
    assert_eq!(doc.get("board").as_str(), Some("NUCLEO-F446RE"));

    // A declared size over the cap is refused before the body is read,
    // and the connection is closed (the body cannot be skipped).
    let max = svc.config().max_upload_bytes;
    let reply = c.send(&format!("UPLOAD huge.tflite {}", max + 1));
    assert!(reply.starts_with("ERR upload too large"), "{reply:?}");
    assert_eq!(c.recv(), "", "oversized upload closes the connection");

    // A fresh connection still works (the service itself is unharmed).
    let mut c2 = Client::connect(addr);
    let reply = c2.send(&format!("PLAN hash:{hash} SparkFun-Edge"));
    assert!(reply.starts_with("OK {"), "{reply:?}");
    assert_eq!(svc.stats().uploads, 1, "only the valid upload counts");
    svc.shutdown();
}

//! Integration: the AOT C codegen backend (`codegen`) — the PR-10
//! acceptance suite.
//!
//! Three claims are pinned here:
//!
//! 1. **Golden equivalence** — for zoo models and the `cnn_int8.tflite`
//!    fixture, the emitted freestanding C99 (compiled with the host `cc`
//!    at `-std=c99 -Wall -Werror`) produces bit-identical outputs to the
//!    Rust interpreter via the generated self-checking harness, and the
//!    declared arena size equals the certified plan peak. (CI runs the
//!    same contract over the *whole* zoo through the CLI; here a
//!    representative subset keeps the suite fast. Tests that need a C
//!    compiler skip politely when `cc` is absent.)
//! 2. **Band loops under stress** — split plans with odd spatial sizes,
//!    stride-2 SAME convolutions and non-trivial halos lower to
//!    `Partial`/`PartialInto` band loops that stay bit-exact, in f32 and
//!    in i8 (requant rounding parity across band boundaries).
//! 3. **CLI failure contract** — `codegen` exits 2 with a one-line
//!    `usage error:` for bad invocations and 1 for runtime failures,
//!    matching the PR-9 convention (golden-tested via `CARGO_BIN_EXE`).

use std::path::{Path, PathBuf};
use std::process::Command;

use mcu_reorder::api::{ModelSource, OptimizeRequest};
use mcu_reorder::codegen::{generate, sanitize_symbol, weights_for_report, Artifact};
use mcu_reorder::graph::{Act, DType, Graph, GraphBuilder, OpKind, Padding};
use mcu_reorder::interp::WeightStore;
use mcu_reorder::split::SplitOptions;
use mcu_reorder::tflite::fixtures;
use mcu_reorder::trace::audit;
use mcu_reorder::verify::certify_report;

fn run_cli(args: &[&str]) -> (i32, String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_mcu-reorder"))
        .args(args)
        .output()
        .expect("spawn mcu-reorder");
    (
        out.status.code().unwrap_or(-1),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("mcu-reorder-codegen-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Host C compiler, if one exists (CI always has one; a bare dev box may
/// not, so compile-and-run tests degrade to emit-only checks).
fn have_cc() -> bool {
    Command::new("cc")
        .arg("--version")
        .output()
        .map(|o| o.status.success())
        .unwrap_or(false)
}

/// Compile `artifact` + its harness under the strict flag set the ISSUE
/// contract names, run the harness, and require exit 0 (the harness
/// byte-compares the C output against the interpreter's expectation).
fn compile_and_run(dir: &Path, art: &Artifact) {
    let src = dir.join(format!("{}.c", art.symbol));
    let hdr = dir.join(&art.header_name);
    let main_c = dir.join(format!("{}_main.c", art.symbol));
    let bin = dir.join(format!("{}_bin", art.symbol));
    std::fs::write(&src, &art.source).unwrap();
    std::fs::write(&hdr, &art.header).unwrap();
    std::fs::write(&main_c, &art.harness).unwrap();
    let cc = Command::new("cc")
        .args(["-std=c99", "-Wall", "-Werror", "-O1"])
        .arg(&src)
        .arg(&main_c)
        .arg("-o")
        .arg(&bin)
        .arg("-lm")
        .output()
        .expect("spawn cc");
    assert!(
        cc.status.success(),
        "cc -std=c99 -Wall -Werror failed for {}:\n{}",
        art.symbol,
        String::from_utf8_lossy(&cc.stderr)
    );
    let run = Command::new(&bin).output().expect("run harness");
    assert!(
        run.status.success(),
        "golden harness mismatch for {}:\nstdout: {}\nstderr: {}",
        art.symbol,
        String::from_utf8_lossy(&run.stdout),
        String::from_utf8_lossy(&run.stderr)
    );
}

/// Emit-side invariants that hold with or without a C compiler.
fn check_artifact(art: &Artifact, report: &mcu_reorder::api::OptimizeReport) {
    let cert = certify_report(report).expect("report must certify before codegen");
    assert_eq!(
        art.arena_bytes, cert.arena_bytes,
        "{}: declared arena must equal the certified plan arena",
        art.symbol
    );
    let up = art.symbol.to_uppercase();
    assert!(
        art.header.contains(&format!("#define {up}_ARENA_BYTES {}u", art.arena_bytes)),
        "{}: header must pin the arena size",
        art.symbol
    );
    assert!(
        art.source.contains(&format!("void {}_invoke(", art.symbol)),
        "{}: source must define the invoke entry point",
        art.symbol
    );
    assert!(
        art.harness.contains(&format!("{up}_ARENA_BYTES == {}u", art.arena_bytes)),
        "{}: harness must compile-time-check the arena size",
        art.symbol
    );
    let single = art.single_file();
    assert!(
        !single.contains("#include \""),
        "{}: single_file must inline the header (no local includes)",
        art.symbol
    );
    assert!(art.n_ops > 0 && art.input_elems > 0 && art.output_elems > 0);
}

fn zoo_report(name: &str, dtype: DType, split: Option<SplitOptions>) -> mcu_reorder::api::OptimizeReport {
    OptimizeRequest::new(ModelSource::Zoo { name: name.to_string(), dtype })
        .with_split(split)
        .run()
        .unwrap_or_else(|e| panic!("optimize {name}: {e}"))
}

// ---------------------------------------------------------------------
// 1. Golden equivalence
// ---------------------------------------------------------------------

/// Every zoo model, in every dtype the audit pipeline prepares it for,
/// lowers to a certifiable artifact with the emit-side invariants intact.
/// No C compiler needed; CI compiles the same set through the CLI.
#[test]
fn every_zoo_model_emits_certified_artifact() {
    for name in mcu_reorder::models::MODEL_NAMES {
        for p in audit::prepare_zoo(name).unwrap() {
            let dtype = DType::from_name(p.dtype).unwrap();
            let report = zoo_report(name, dtype, Some(SplitOptions::quick()));
            let ws = weights_for_report(&report).unwrap();
            let sym = sanitize_symbol(&format!("{name}_{}", p.dtype));
            let art = generate(&report, &ws, &sym)
                .unwrap_or_else(|e| panic!("codegen {name} {}: {e}", p.dtype));
            check_artifact(&art, &report);
            if name == "figure1" {
                assert_eq!(art.rodata_bytes, 0, "figure1 has no weight tensors");
                assert_eq!(art.dtype, "u8");
            }
        }
    }
}

/// Representative zoo subset, compiled with the host `cc` and driven by
/// the generated harness: C output must be byte-identical to the
/// interpreter in f32, i8 and u8.
#[test]
fn golden_zoo_c_is_bit_exact() {
    let dir = tmp_dir("golden-zoo");
    let cases =
        [("tiny", DType::F32, "tiny_f32"), ("tiny", DType::I8, "tiny_i8"), ("figure1", DType::U8, "figure1_u8")];
    for (name, dtype, sym) in cases {
        let report = zoo_report(name, dtype, Some(SplitOptions::quick()));
        let ws = weights_for_report(&report).unwrap();
        let art = generate(&report, &ws, sym).unwrap();
        check_artifact(&art, &report);
        if !have_cc() {
            eprintln!("cc unavailable; skipping compile-and-run for {sym}");
            continue;
        }
        compile_and_run(&dir, &art);
    }
}

/// The int8 TFLite fixture end to end: flatbuffer import → optimize →
/// codegen → host cc → harness. This is the i8 requant-rounding parity
/// gate: every conv/dense in the fixture requantizes through the fixed
/// multiplier, and one ulp of divergence fails the byte compare.
#[test]
fn golden_tflite_fixture_is_bit_exact() {
    let path = fixtures::ensure(fixtures::INT8_FIXTURE).unwrap();
    let report = OptimizeRequest::new(ModelSource::TflitePath(path.display().to_string()))
        .with_split(Some(SplitOptions::quick()))
        .run()
        .unwrap();
    let ws = weights_for_report(&report).unwrap();
    let art = generate(&report, &ws, "cnn_int8").unwrap();
    check_artifact(&art, &report);
    assert_eq!(art.dtype, "i8");
    // Requant parity starts with shape: one fixed-point requant call per
    // accumulating i8 op, all routed through the single shared helper.
    let n_acc = report
        .graph
        .ops
        .iter()
        .filter(|o| {
            matches!(
                o.kind,
                OpKind::Conv2D { .. } | OpKind::DepthwiseConv2D { .. } | OpKind::Dense { .. }
            )
        })
        .count();
    assert!(n_acc > 0, "fixture must exercise accumulating i8 ops");
    let calls = art.source.matches(&format!("{}_requant(", art.symbol)).count();
    // One helper definition + one call site per accumulating op (split
    // bands may add more call sites, never fewer).
    assert!(
        calls >= n_acc + 1,
        "expected >= {} requant sites, found {calls}",
        n_acc + 1
    );
    if !have_cc() {
        eprintln!("cc unavailable; skipping compile-and-run for the fixture");
        return;
    }
    compile_and_run(&tmp_dir("golden-fixture"), &art);
}

// ---------------------------------------------------------------------
// 2. Band loops: odd sizes, stride-2 SAME halos, i8 requant across bands
// ---------------------------------------------------------------------

/// 17×17 input (odd), stride-2 SAME conv expanding to 16 channels, 1×1
/// compression, odd-kernel valid pool: a chain where splitting the
/// expansion segment is the only way below the reordered floor, so the
/// planner must commit row bands whose halos land on odd boundaries.
fn oddnet() -> Graph {
    let mut b = GraphBuilder::new("oddnet");
    let x = b.input("x", &[1, 17, 17, 3], DType::F32);
    let c1 = b.conv2d("c1", x, 16, (3, 3), (2, 2), Padding::Same, Act::Relu);
    let c2 = b.conv2d("c2", c1, 4, (1, 1), (1, 1), Padding::Valid, Act::Linear);
    let p = b.maxpool("p", c2, (3, 3), (2, 2), Padding::Valid);
    let gap = b.global_avgpool("gap", p);
    let fc = b.dense("fc", gap, 5, Act::Linear);
    let sm = b.softmax("sm", fc);
    b.output(sm);
    b.finish().unwrap()
}

/// Run `g` through the full pipeline with a budget 80% of its reordered
/// peak — tight enough that the beam search must split — and return the
/// report. Panics if no split was committed (the graphs used here are
/// constructed so splitting strictly improves the peak).
fn split_report(g: Graph, label: &str) -> mcu_reorder::api::OptimizeReport {
    let base = OptimizeRequest::reorder_only(ModelSource::Graph(g.clone()))
        .run()
        .unwrap()
        .best_peak();
    let budget = base * 4 / 5;
    let report = OptimizeRequest::new(ModelSource::Graph(g))
        .with_budget(Some(budget))
        .run()
        .unwrap();
    let split = report.split.as_ref().unwrap_or_else(|| panic!("{label}: split search must run"));
    assert!(
        !split.outcome.steps.is_empty(),
        "{label}: budget {budget} (80% of reordered {base}) must force a split"
    );
    assert!(
        split
            .outcome
            .graph
            .ops
            .iter()
            .any(|o| matches!(o.kind, OpKind::Partial { .. } | OpKind::PartialInto { .. })),
        "{label}: committed split must lower to Partial band ops"
    );
    report
}

#[test]
fn split_bands_odd_stride2_same_f32_bit_exact() {
    let g = oddnet();
    let report = split_report(g.clone(), "oddnet");
    let ws = WeightStore::seeded_f32(&g, 7);
    let art = generate(&report, &ws, "oddnet").unwrap();
    check_artifact(&art, &report);
    // The stride-2 SAME conv must be inside a band (a Partial/PartialInto
    // wrapper), otherwise the halo arithmetic is not exercised.
    let banded_stride2 = report.split.as_ref().unwrap().outcome.graph.ops.iter().any(|o| {
        match &o.kind {
            OpKind::Partial { inner, .. } | OpKind::PartialInto { inner, .. } => {
                matches!(**inner, OpKind::Conv2D { stride: (2, 2), padding: Padding::Same, .. })
            }
            _ => false,
        }
    });
    assert!(banded_stride2, "oddnet split must band the stride-2 SAME conv");
    if !have_cc() {
        eprintln!("cc unavailable; skipping compile-and-run for oddnet");
        return;
    }
    compile_and_run(&tmp_dir("oddnet"), &art);
}

/// streamnet i8 under budget: the zoo's split-friendly model quantized,
/// so band boundaries cut through requantizing convs — i8 rounding must
/// agree with the interpreter on every band, including halo rows.
#[test]
fn split_bands_i8_requant_bit_exact() {
    let base = OptimizeRequest::reorder_only(ModelSource::Zoo {
        name: "streamnet".to_string(),
        dtype: DType::I8,
    })
    .run()
    .unwrap()
    .best_peak();
    let report = OptimizeRequest::new(ModelSource::Zoo {
        name: "streamnet".to_string(),
        dtype: DType::I8,
    })
    .with_budget(Some(base * 4 / 5))
    .run()
    .unwrap();
    let split = report.split.as_ref().expect("split search must run");
    assert!(!split.outcome.steps.is_empty(), "streamnet i8 must split under 80% budget");
    let ws = weights_for_report(&report).unwrap();
    let art = generate(&report, &ws, "streamnet_i8").unwrap();
    check_artifact(&art, &report);
    if !have_cc() {
        eprintln!("cc unavailable; skipping compile-and-run for streamnet_i8");
        return;
    }
    compile_and_run(&tmp_dir("streamnet-i8"), &art);
}

// ---------------------------------------------------------------------
// 3. CLI failure contract (exit 2 usage / exit 1 runtime, PR-9 style)
// ---------------------------------------------------------------------

#[test]
fn codegen_cli_exit_codes() {
    let dir = tmp_dir("cli");
    let out_c = dir.join("t.c");
    let out_c = out_c.to_str().unwrap();

    // Usage errors: exit 2, one-line "usage error:" on stderr.
    let usage_cases: &[&[&str]] = &[
        &["codegen"],                                            // no source
        &["codegen", "tiny"],                                    // missing -o
        &["codegen", "tiny", "-o"],                              // dangling -o
        &["codegen", "tiny", "-o", out_c, "--dtype", "f16"],     // bad dtype
        &["codegen", "tiny", "-o", out_c, "--board", "nope"],    // bad board
        &["codegen", "tiny", "-o", out_c, "--budget", "lots"],   // bad number
    ];
    for args in usage_cases {
        let (code, _, err) = run_cli(args);
        assert_eq!(code, 2, "{args:?} must exit 2, stderr: {err}");
        assert!(err.starts_with("error: usage error: "), "{args:?} stderr: {err}");
        assert_eq!(err.lines().count(), 1, "{args:?} must fail with one line: {err}");
    }

    // Runtime errors: exit 1.
    let runtime_cases: &[&[&str]] = &[
        &["codegen", "nope", "-o", out_c],             // unknown zoo model
        &["codegen", "missing.tflite", "-o", out_c],   // unreadable file
    ];
    for args in runtime_cases {
        let (code, _, err) = run_cli(args);
        assert_eq!(code, 1, "{args:?} must exit 1, stderr: {err}");
        assert!(!err.contains("usage error:"), "{args:?} is a runtime failure: {err}");
    }
}

#[test]
fn codegen_cli_happy_path_writes_sources() {
    let dir = tmp_dir("cli-ok");
    let out_c = dir.join("tiny.c");
    let main_c = dir.join("tiny_main.c");
    let (code, out, err) = run_cli(&[
        "codegen",
        "tiny",
        "--dtype",
        "f32",
        "-o",
        out_c.to_str().unwrap(),
        "--harness",
        main_c.to_str().unwrap(),
    ]);
    assert_eq!(code, 0, "stderr: {err}");
    assert!(out.contains("arena"), "summary must report the arena size: {out}");
    let hdr = out_c.with_extension("h");
    for p in [&out_c, &hdr, &main_c] {
        assert!(p.exists(), "{} must be written", p.display());
    }
    let src = std::fs::read_to_string(&out_c).unwrap();
    assert!(src.contains("tiny_invoke("), "entry symbol comes from the output stem");
    if !have_cc() {
        eprintln!("cc unavailable; skipping compile of the CLI-written sources");
        return;
    }
    let bin = dir.join("tiny_bin");
    let cc = Command::new("cc")
        .args(["-std=c99", "-Wall", "-Werror", "-O1"])
        .arg(&out_c)
        .arg(&main_c)
        .arg("-o")
        .arg(&bin)
        .arg("-lm")
        .output()
        .expect("spawn cc");
    assert!(cc.status.success(), "cc failed:\n{}", String::from_utf8_lossy(&cc.stderr));
    let run = Command::new(&bin).output().expect("run harness");
    assert!(run.status.success(), "harness mismatch: {}", String::from_utf8_lossy(&run.stdout));
}

//! Planner fast-path equivalence: the incremental, memoized, parallel
//! evaluation pipeline (frontier dedup → admissible bound → region-memo
//! peak → deferred ordering → thread-striped scoring) must return results
//! *byte-identical* to the naive serial reference — same plan, same
//! steps, same schedule, same rewritten graph — on every zoo model, every
//! axis preset, every beam width and any thread count. The work counters
//! it reports must reconcile exactly (every scored candidate lands in one
//! outcome bucket; every cache lookup is a hit or a miss).

use mcu_reorder::graph::DType;
use mcu_reorder::models::{self, synth};
use mcu_reorder::split::{self, PlannerStats, SplitOptions, SplitOutcome};
use mcu_reorder::util::rng::Rng;

/// Everything the caller can observe must match; only `stats` (how the
/// answer was computed) is allowed to differ between strategies.
fn assert_identical(naive: &SplitOutcome, fast: &SplitOutcome, label: &str) {
    assert_eq!(naive.schedule, fast.schedule, "{label}: schedule diverged");
    assert_eq!(naive.steps, fast.steps, "{label}: steps diverged");
    assert_eq!(naive.plan, fast.plan, "{label}: plan diverged");
    assert_eq!(naive.graph, fast.graph, "{label}: rewritten graph diverged");
    assert_eq!(naive.sources, fast.sources, "{label}: tensor provenance diverged");
    assert_eq!(naive.base_peak, fast.base_peak, "{label}: base peak diverged");
}

fn assert_reconciled(st: &PlannerStats, label: &str) {
    assert_eq!(
        st.scored,
        st.improved + st.no_improve + st.bounded + st.apply_failed + st.schedule_failed,
        "{label}: outcome buckets must sum to scored ({st:?})"
    );
    assert_eq!(
        st.cache_lookups,
        st.cache_hits + st.cache_misses,
        "{label}: cache counters must reconcile ({st:?})"
    );
}

/// The whole zoo × {rows-only, all axes} × beam widths {1, 2, 3} ×
/// threads {1, 2}: the fast path is indistinguishable from the naive
/// reference everywhere.
#[test]
fn fast_path_matches_naive_reference_across_the_zoo() {
    for name in models::MODEL_NAMES {
        let g = models::by_name(name, DType::I8).unwrap();
        for rows_only in [false, true] {
            for beam_width in [1usize, 2, 3] {
                let base =
                    SplitOptions { beam_width, max_rounds: 2, ..SplitOptions::quick() };
                let base = if rows_only { base.rows_only() } else { base };
                let label = format!("{name} rows_only={rows_only} beam={beam_width}");
                let naive = split::optimize(&g, &base.clone().naive()).unwrap();
                assert_reconciled(&naive.stats, &label);
                // Naive scoring never consults the bound or the cache.
                assert_eq!(naive.stats.bounded, 0, "{label}: naive must not bound");
                assert_eq!(naive.stats.cache_lookups, 0, "{label}: naive must not cache");
                for threads in [1usize, 2] {
                    let fast =
                        split::optimize(&g, &base.clone().with_threads(threads)).unwrap();
                    assert_identical(&naive, &fast, &format!("{label} threads={threads}"));
                    assert_reconciled(&fast.stats, &format!("{label} threads={threads}"));
                    assert_eq!(fast.stats.threads, threads);
                }
            }
        }
    }
}

/// The imported TFLite fixture (the real-model path the issue's scaling
/// work targets) takes the same gate at the full default search.
#[test]
fn fast_path_matches_naive_on_imported_tflite() {
    let fixture =
        mcu_reorder::tflite::fixtures::ensure(mcu_reorder::tflite::fixtures::INT8_FIXTURE)
            .expect("tflite fixture generation (python3 required)");
    let g = mcu_reorder::tflite::load(fixture.to_str().unwrap()).expect("tflite import").graph;
    let opts = SplitOptions::default();
    let naive = split::optimize(&g, &opts.clone().naive()).unwrap();
    for threads in [1usize, 4] {
        let fast = split::optimize(&g, &opts.clone().with_threads(threads)).unwrap();
        assert_identical(&naive, &fast, &format!("tflitecnn threads={threads}"));
        assert_reconciled(&fast.stats, "tflitecnn");
    }
}

/// The synthetic layered graphs of the scaling bench, at the exact preset
/// the Python mirror re-plans with naive full-DP scoring. Beyond
/// bit-identity, the fast path must demonstrably *work less*: fewer full
/// Algorithm-1 runs than the reference (the 10× acceptance floor at 1000
/// ops lives in the scaling bench; this guards the mechanism at test
/// sizes).
#[test]
fn fast_path_matches_naive_on_layered_graphs_and_saves_full_evals() {
    for n in [40usize, 100] {
        let g = synth::layered(&mut Rng::new(n as u64), n);
        assert_eq!(g.n_ops(), n);
        let opts = SplitOptions {
            max_factor: 2,
            max_rounds: 2,
            max_candidates: 8,
            beam_width: 2,
            ..SplitOptions::default()
        };
        let naive = split::optimize(&g, &opts.clone().naive()).unwrap();
        // The naive reference pays one full DP per candidate surviving
        // apply — its counters are the definition of `naive_evals`.
        assert_eq!(naive.stats.full_evals, naive.stats.naive_evals());
        let fast = split::optimize(&g, &opts.clone().with_threads(3)).unwrap();
        assert_identical(&naive, &fast, &format!("layered{n}"));
        assert_reconciled(&fast.stats, &format!("layered{n}"));
        assert!(
            fast.stats.full_evals < naive.stats.full_evals,
            "layered{n}: fast path ran {} full DPs vs naive {}",
            fast.stats.full_evals,
            naive.stats.full_evals
        );
        assert!(fast.stats.cache_hits > 0, "layered{n}: region memo never hit");
    }
}

/// Budget-driven early stopping keys off intermediate peaks; both
/// strategies must stop at the same point with the same plan.
#[test]
fn budgeted_search_stops_identically_across_strategies() {
    let g = models::mobilenet_v1_025(DType::I8);
    let unconstrained = split::optimize(&g, &SplitOptions::quick()).unwrap();
    let budget = (unconstrained.schedule.peak_bytes + unconstrained.base_peak) / 2;
    let opts =
        SplitOptions { sram_budget: Some(budget), max_rounds: 4, ..SplitOptions::quick() };
    let naive = split::optimize(&g, &opts.clone().naive()).unwrap();
    let fast = split::optimize(&g, &opts.clone().with_threads(2)).unwrap();
    assert_identical(&naive, &fast, "budgeted mobilenet");
    assert!(fast.schedule.peak_bytes <= budget, "budget {budget} not met");
}

/// Join-elision on and off (streamnet's winning plan hinges on elision;
/// audionet's on the channel axis): the strategies agree in both modes.
#[test]
fn materialized_and_elided_presets_take_the_same_gate() {
    for name in ["streamnet", "audionet"] {
        let g = models::by_name(name, DType::I8).unwrap();
        for materialized in [false, true] {
            let opts = if materialized {
                SplitOptions::default().materialized()
            } else {
                SplitOptions::default()
            };
            let naive = split::optimize(&g, &opts.clone().naive()).unwrap();
            let fast = split::optimize(&g, &opts.clone().with_threads(2)).unwrap();
            assert_identical(&naive, &fast, &format!("{name} materialized={materialized}"));
            assert_reconciled(&fast.stats, &format!("{name} materialized={materialized}"));
        }
    }
}

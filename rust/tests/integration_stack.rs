//! Cross-layer integration tests that do not need AOT artifacts:
//! scheduler ↔ allocator ↔ interpreter ↔ model zoo ↔ serde ↔ mcu model.

use mcu_reorder::alloc::StaticPlan;
use mcu_reorder::graph::serde::ModelFile;
use mcu_reorder::graph::DType;
use mcu_reorder::interp::{calibrate, ExecConfig, Interpreter, TensorData, WeightStore};
use mcu_reorder::mcu::{CostModel, DeployReport, OverheadModel, NUCLEO_F767ZI};
use mcu_reorder::models;
use mcu_reorder::sched;
use mcu_reorder::util::prop;
use mcu_reorder::util::rng::Rng;

fn ramp(n: usize) -> Vec<f32> {
    (0..n).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect()
}

/// Paper Figure 2 + Figure 3: the full working-set tables byte-for-byte.
#[test]
fn appendix_a_tables_reproduce() {
    let g = models::figure1();
    let fig2 = sched::simulate(&g, &g.default_order());
    assert_eq!(
        fig2.steps.iter().map(|s| s.bytes).collect::<Vec<_>>(),
        vec![4704, 4704, 5216, 4160, 1280, 1024, 1024]
    );
    let fig3 = sched::simulate(&g, &[0, 3, 5, 1, 2, 4, 6]);
    assert_eq!(
        fig3.steps.iter().map(|s| s.bytes).collect::<Vec<_>>(),
        vec![4704, 3648, 3904, 4960, 2336, 1024, 1024]
    );
    let (opt, _) = sched::optimal(&g).unwrap();
    assert_eq!(opt.peak_bytes, 4960);
}

/// Full tool flow: zoo model → optimize → embed order → reload → the
/// embedded order beats the default in the interpreter's real arena.
#[test]
fn optimize_embed_reload_execute() {
    let g = models::swiftnet_cell(DType::I8);
    let (opt, _) = sched::optimal(&g).unwrap();
    let mf = ModelFile { graph: g, execution_order: Some(opt.order.clone()) };
    let json = mf.to_json();
    let back = ModelFile::from_json(&json).unwrap();
    assert_eq!(back.effective_order(), opt.order);
    let peak_embedded = sched::peak_of(&back.graph, &back.effective_order());
    let peak_default = sched::peak_of(&back.graph, &back.graph.default_order());
    assert!(peak_embedded < peak_default);
    assert_eq!(peak_embedded, 304_128);
}

/// The paper's deployment story end-to-end on the arena: with 512KB SRAM
/// minus framework overhead, the default order OOMs and the optimal order
/// completes (f32 execution at i8-scaled arena budget).
#[test]
fn swiftnet_arena_oom_vs_fit() {
    // Execute the f32 graph but give the arena exactly the i8 budget × 4
    // (f32 tensors are 4× the i8 accounting).
    let g = models::swiftnet_cell(DType::F32);
    let overhead = OverheadModel::default().bytes(&models::swiftnet_cell(DType::I8));
    let budget_i8 = NUCLEO_F767ZI.sram_bytes - overhead;
    let arena = budget_i8 * 4;
    let ws = WeightStore::seeded_f32(&g, 42);
    let input = TensorData::F32(ramp(g.tensors[g.inputs[0]].elems()));

    let default = Interpreter::new(&g, ws.clone(), ExecConfig::with_capacity(arena))
        .run(&[input.clone()]);
    assert!(default.is_err(), "default order should exceed the SRAM budget");

    let (opt, _) = sched::optimal(&g).unwrap();
    let cfg = ExecConfig { order: Some(opt.order), ..ExecConfig::with_capacity(arena) };
    let optimal = Interpreter::new(&g, ws, cfg).run(&[input]).unwrap();
    assert_eq!(optimal.outputs[0].as_f32().unwrap().len(), 2);
}

/// Reordering never changes numerics: for random branchy graphs, every
/// valid execution order produces identical bytes.
#[test]
fn reordering_is_output_invariant() {
    prop::check_sized("order-invariance", 25, 4, 9, |rng, n| {
        let g = models::synth::random_dag(rng, n);
        let input = TensorData::U8((0..g.tensors[g.inputs[0]].elems())
            .map(|i| (i % 251) as u8)
            .collect());
        let ws = WeightStore::default();
        let base = Interpreter::new(&g, ws.clone(), ExecConfig::with_capacity(1 << 22))
            .run(&[input.clone()])
            .unwrap();
        let (opt, _) = sched::optimal(&g).unwrap();
        let cfg = ExecConfig { order: Some(opt.order), ..ExecConfig::with_capacity(1 << 22) };
        let reordered = Interpreter::new(&g, ws, cfg).run(&[input]).unwrap();
        assert_eq!(base.outputs, reordered.outputs);
        assert!(reordered.alloc.high_water <= base.alloc.high_water);
    });
}

/// Arena high-water equals the analytic scheduler peak for every zoo model
/// and both orders (the accounting and the allocator agree byte-for-byte).
#[test]
fn arena_matches_analytics_across_zoo() {
    for name in ["tiny", "mobilenet", "swiftnet", "resnet"] {
        let g = models::by_name(name, DType::F32).unwrap();
        let ws = WeightStore::seeded_f32(&g, 1);
        let input = TensorData::F32(ramp(g.tensors[g.inputs[0]].elems()));
        for order in [g.default_order(), sched::optimal(&g).unwrap().0.order] {
            let analytic = sched::peak_of(&g, &order);
            let cfg = ExecConfig { order: Some(order), ..ExecConfig::with_capacity(1 << 24) };
            let run = Interpreter::new(&g, ws.clone(), cfg).run(&[input.clone()]).unwrap();
            assert_eq!(run.alloc.high_water, analytic, "{name}");
        }
    }
}

/// Table 1 MobileNet memory cells + overhead model + deploy verdicts.
#[test]
fn table1_memory_cells() {
    let mnet = models::mobilenet_v1_025(DType::I8);
    assert_eq!(StaticPlan::no_reuse(&mnet).arena_bytes, 241_028);
    assert_eq!(sched::peak_of(&mnet, &mnet.default_order()), 55_296);

    let swift = models::swiftnet_cell(DType::I8);
    let d = sched::peak_of(&swift, &swift.default_order());
    let (o, _) = sched::optimal(&swift).unwrap();
    let ov = OverheadModel::default();
    assert!(!DeployReport::new(&swift, d, &NUCLEO_F767ZI, &ov).fits_sram);
    assert!(DeployReport::new(&swift, o.peak_bytes, &NUCLEO_F767ZI, &ov).fits_sram);
}

/// Table 1 time/energy overhead: the defrag traffic measured on the real
/// arena run keeps both overheads under 1.5% (paper: +0.68% / +0.97%).
#[test]
fn table1_overheads_under_1_5_percent() {
    let mnet_i8 = models::mobilenet_v1_025(DType::I8);
    let g_f32 = models::mobilenet_v1_025(DType::F32);
    let ws_f32 = WeightStore::seeded_f32(&g_f32, 42);
    let input = TensorData::F32(ramp(g_f32.tensors[g_f32.inputs[0]].elems()));
    let ranges = calibrate(&g_f32, &ws_f32, &[input.clone()], 1 << 24).unwrap();
    let ws_i8 = WeightStore::quantize_from(&mnet_i8, &ws_f32, &ranges);
    let in_q = ws_i8.qparams[&mnet_i8.inputs[0]];
    let qin = TensorData::I8(in_q.quantize(input.as_f32().unwrap()));
    let run = Interpreter::new(&mnet_i8, ws_i8, ExecConfig::with_capacity(256 * 1024))
        .run(&[qin])
        .unwrap();
    assert!(run.alloc.bytes_moved > 0, "compaction should move something");

    let static_stats = mcu_reorder::alloc::AllocStats {
        high_water: mnet_i8.activation_total(),
        ..Default::default()
    };
    let model = CostModel::calibrated(&mnet_i8, &static_stats, &NUCLEO_F767ZI, 1.316, 728.0);
    let st = model.estimate(&mnet_i8, &static_stats, &NUCLEO_F767ZI);
    let dy = model.estimate(&mnet_i8, &run.alloc, &NUCLEO_F767ZI);
    let dt = dy.seconds / st.seconds - 1.0;
    let de = dy.energy_mj / st.energy_mj - 1.0;
    assert!(dt > 0.0 && dt < 0.015, "time overhead {dt}");
    assert!(de > dt && de < 0.015, "energy overhead {de}");
}

/// Offline best-fit planning (§6) removes the need for run-time compaction
/// while staying within ~the working-set peak.
#[test]
fn offline_plan_close_to_peak_on_zoo() {
    for name in ["tiny", "mobilenet", "swiftnet", "resnet"] {
        let g = models::by_name(name, DType::I8).unwrap();
        let (opt, _) = sched::optimal(&g).unwrap();
        let plan = StaticPlan::best_fit(&g, &opt.order);
        plan.check_no_overlap(&g, &opt.order).unwrap();
        let peak = opt.peak_bytes;
        assert!(plan.arena_bytes >= peak);
        assert!(
            plan.arena_bytes <= peak + peak / 3,
            "{name}: plan {} vs peak {peak}",
            plan.arena_bytes
        );
    }
}

/// Random-graph fuzz of the whole pipeline: schedule, plan, execute.
#[test]
fn pipeline_fuzz() {
    let mut rng = Rng::new(0xABCD);
    for _ in 0..15 {
        let g = models::synth::series_parallel(&mut rng, 3, 2);
        let (opt, _) = sched::optimal(&g).unwrap();
        g.check_order(&opt.order).unwrap();
        let plan = StaticPlan::best_fit(&g, &opt.order);
        plan.check_no_overlap(&g, &opt.order).unwrap();
        let input = TensorData::U8(vec![7; g.tensors[g.inputs[0]].elems()]);
        let cfg = ExecConfig { order: Some(opt.order), ..ExecConfig::with_capacity(1 << 22) };
        let run = Interpreter::new(&g, WeightStore::default(), cfg).run(&[input]).unwrap();
        assert_eq!(run.alloc.high_water, opt.peak_bytes);
    }
}

//! Cross-layer integration of the partial-execution subsystem:
//! splitter ↔ scheduler ↔ interpreter ↔ allocator/planner ↔ transforms.
//!
//! The acceptance properties of the subsystem live here:
//! - split+reorder achieves *strictly* lower peak SRAM than reorder-only
//!   on a zoo model (MobileNet — a pure chain, where reordering alone is
//!   provably useless);
//! - split-graph execution matches the unsplit graph bit-exactly for int8
//!   and within 1e-5 for f32;
//! - split graphs flow through the offline planner and the dynamic arena
//!   with byte-exact accounting;
//! - fused/BN-folded graphs round-trip through splitting numerically.

use mcu_reorder::alloc::StaticPlan;
use mcu_reorder::graph::{transform, Act, DType, Graph, GraphBuilder, Padding, SplitAxis};
use mcu_reorder::interp::{calibrate, ExecConfig, Interpreter, TensorData, WeightStore};
use mcu_reorder::models;
use mcu_reorder::sched;
use mcu_reorder::split::{self, SegmentSplit, SplitOptions};
use mcu_reorder::util::prop;
use mcu_reorder::util::rng::Rng;

fn ramp(n: usize) -> Vec<f32> {
    (0..n).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect()
}

/// MobileNet is sequential, so Algorithm 1 cannot improve on the default
/// order (asserted in the seed tests). Splitting must break that floor.
#[test]
fn split_plus_reorder_beats_reorder_only_on_mobilenet() {
    let g = models::mobilenet_v1_025(DType::I8);
    let reorder_only = sched::optimal(&g).unwrap().0.peak_bytes;
    assert_eq!(reorder_only, 55_296, "baseline drifted");

    let out = split::optimize(&g, &SplitOptions::quick()).unwrap();
    assert!(
        out.schedule.peak_bytes < reorder_only,
        "split+reorder {} must be strictly below reorder-only {reorder_only}",
        out.schedule.peak_bytes
    );
    // The broken floor is substantial, not epsilon.
    assert!(
        out.schedule.peak_bytes <= reorder_only * 95 / 100,
        "expected >=5% saving, got {} vs {reorder_only}",
        out.schedule.peak_bytes
    );
    out.graph.validate().unwrap();
    out.graph.check_order(&out.schedule.order).unwrap();
}

/// f32: the full split pipeline (search → rewrite → remap → execute)
/// reproduces the unsplit outputs within 1e-5 (they are bit-equal in
/// practice — the slice kernels take identical taps in identical order).
#[test]
fn split_mobilenet_f32_matches_unsplit() {
    let g = models::mobilenet_v1_025(DType::F32);
    let ws = WeightStore::seeded_f32(&g, 42);
    let input = TensorData::F32(ramp(g.tensors[g.inputs[0]].elems()));

    let base = Interpreter::new(&g, ws.clone(), ExecConfig::with_capacity(1 << 24))
        .run(&[input.clone()])
        .unwrap();

    let out = split::optimize(&g, &SplitOptions::quick()).unwrap();
    assert!(!out.steps.is_empty(), "expected at least one split on mobilenet f32");
    let ws_split = out.remap_weights(&ws);
    let cfg = ExecConfig {
        order: Some(out.schedule.order.clone()),
        ..ExecConfig::with_capacity(1 << 24)
    };
    let split_run = Interpreter::new(&out.graph, ws_split, cfg).run(&[input]).unwrap();

    let a = base.outputs[0].as_f32().unwrap();
    let b = split_run.outputs[0].as_f32().unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert!((x - y).abs() < 1e-5, "f32 drift: {x} vs {y}");
    }
}

/// int8: quantize the unsplit graph, split it, remap weights + qparams —
/// outputs must be bit-exact.
#[test]
fn split_mobilenet_i8_is_bit_exact() {
    let g_f32 = models::mobilenet_v1_025(DType::F32);
    let ws_f32 = WeightStore::seeded_f32(&g_f32, 42);
    let input_f = TensorData::F32(ramp(g_f32.tensors[g_f32.inputs[0]].elems()));
    let ranges = calibrate(&g_f32, &ws_f32, &[input_f.clone()], 1 << 24).unwrap();

    let g_i8 = models::mobilenet_v1_025(DType::I8);
    let ws_i8 = WeightStore::quantize_from(&g_i8, &ws_f32, &ranges);
    let in_q = ws_i8.qparams[&g_i8.inputs[0]];
    let input_q = TensorData::I8(in_q.quantize(input_f.as_f32().unwrap()));

    let base = Interpreter::new(&g_i8, ws_i8.clone(), ExecConfig::with_capacity(1 << 22))
        .run(&[input_q.clone()])
        .unwrap();

    let out = split::optimize(&g_i8, &SplitOptions::quick()).unwrap();
    assert!(!out.steps.is_empty());
    let ws_split = out.remap_weights(&ws_i8);
    let cfg = ExecConfig {
        order: Some(out.schedule.order.clone()),
        ..ExecConfig::with_capacity(1 << 22)
    };
    let split_run =
        Interpreter::new(&out.graph, ws_split, cfg).run(&[input_q]).unwrap();

    assert_eq!(base.outputs, split_run.outputs, "int8 split output must be bit-exact");
    // And the arena agrees with the analytic accounting on the split graph.
    assert_eq!(split_run.alloc.high_water, out.schedule.peak_bytes);
    assert!(split_run.alloc.high_water < base.alloc.high_water);
}

/// The split graph's slice tensors flow through the §6 offline best-fit
/// planner: no overlaps, arena between the peak and the activation total.
#[test]
fn planner_places_slice_tensors() {
    let g = models::mobilenet_v1_025(DType::I8);
    let out = split::optimize(&g, &SplitOptions::quick()).unwrap();
    let plan = StaticPlan::best_fit(&out.graph, &out.schedule.order);
    plan.check_no_overlap(&out.graph, &out.schedule.order).unwrap();
    assert!(plan.arena_bytes >= out.schedule.peak_bytes);
    assert!(plan.arena_bytes <= out.graph.activation_total());
}

/// Budget-driven search: ask for a budget between split+reorder and
/// reorder-only; the search must meet it and then stop splitting.
#[test]
fn budget_driven_search_meets_target() {
    let g = models::mobilenet_v1_025(DType::I8);
    let unconstrained = split::optimize(&g, &SplitOptions::quick()).unwrap();
    let budget = (unconstrained.schedule.peak_bytes + unconstrained.base_peak) / 2;
    let opts = SplitOptions { sram_budget: Some(budget), max_rounds: 4, ..SplitOptions::quick() };
    let out = split::optimize(&g, &opts).unwrap();
    assert!(
        out.schedule.peak_bytes <= budget,
        "budget {budget} not met: {}",
        out.schedule.peak_bytes
    );
}

/// Satellite: graph::transform + split interaction. A conv→bn→relu network
/// is BN-folded and activation-fused first, then split; the composed
/// pipeline must stay numerically equivalent end to end.
#[test]
fn folded_fused_graphs_split_equivalently() {
    let mut b = GraphBuilder::new("bnspy");
    let x = b.input("x", &[1, 10, 10, 3], DType::F32);
    let c1 = b.conv2d("c1", x, 8, (3, 3), (1, 1), Padding::Same, Act::Linear);
    let bn1 = b.batchnorm("bn1", c1, 1e-3);
    let r1 = b.relu6("r1", bn1);
    let c2 = b.conv2d("c2", r1, 4, (3, 3), (2, 2), Padding::Same, Act::Linear);
    let bn2 = b.batchnorm("bn2", c2, 1e-3);
    let r2 = b.relu("r2", bn2);
    let gap = b.global_avgpool("gap", r2);
    let fc = b.dense("fc", gap, 3, Act::Linear);
    b.output(fc);
    let g = b.finish().unwrap();

    let ws = WeightStore::seeded_f32(&g, 31);
    let input = TensorData::F32(ramp(300));
    let base = Interpreter::new(&g, ws.clone(), ExecConfig::with_capacity(1 << 20))
        .run(&[input.clone()])
        .unwrap();

    // Fold BN, fuse activations (the converter pipeline)…
    let (folded, tmap1, folds, n_bn) = transform::fold_batchnorm(&g);
    assert_eq!(n_bn, 2);
    let mut ws_folded = transform::remap_weights(&ws, &tmap1);
    transform::fold_batchnorm_weights(&folded, &mut ws_folded, &ws, &folds);
    let (fused, tmap2, n_act) = transform::fuse_activations(&folded);
    assert_eq!(n_act, 2);
    let ws_fused = transform::remap_weights(&ws_folded, &tmap2);

    // …then split the fused chain c1→c2.
    let seg = SegmentSplit {
        ops: vec![
            fused.op_by_name("c1").unwrap().id,
            fused.op_by_name("c2").unwrap().id,
        ],
        factor: 2,
        axis: SplitAxis::Rows,
        elide: false,
    };
    let res = split::apply_segment(&fused, &seg).unwrap();
    let ws_split = split::remap_weight_store(&ws_fused, &res);
    let out = Interpreter::new(&res.graph, ws_split, ExecConfig::with_capacity(1 << 20))
        .run(&[input.clone()])
        .unwrap();

    let a = base.outputs[0].as_f32().unwrap();
    let c = out.outputs[0].as_f32().unwrap();
    for (x, y) in a.iter().zip(c) {
        assert!((x - y).abs() < 1e-4, "fold+fuse+split drift: {x} vs {y}");
    }

    // The fused split graph must also beat the unsplit fused graph's
    // reorder-only peak (it is a pure chain).
    let base_peak = sched::optimal(&fused).unwrap().0.peak_bytes;
    let split_peak = sched::optimal(&res.graph).unwrap().0.peak_bytes;
    assert!(split_peak < base_peak, "{split_peak} vs {base_peak}");
}

/// SwiftNet: splitting composes with a branchy graph where reordering
/// already helps — the combination must not be worse than reorder-only.
#[test]
fn swiftnet_split_never_hurts() {
    let g = models::swiftnet_cell(DType::I8);
    let out = split::optimize(&g, &SplitOptions::quick()).unwrap();
    assert!(out.schedule.peak_bytes <= out.base_peak);
    out.graph.validate().unwrap();
}

/// Random conv→dw chain over small shapes (odd sizes included, strides 1
/// and 2, SAME and VALID padding).
fn random_chain(rng: &mut Rng) -> Graph {
    let h = rng.range(5, 10);
    let w = rng.range(5, 10);
    let cin = *rng.pick(&[2usize, 3, 4]);
    let cout = *rng.pick(&[4usize, 6, 8]);
    let kh = *rng.pick(&[2usize, 3, 5]);
    let kw = *rng.pick(&[2usize, 3]);
    let s1 = rng.range(1, 3);
    let s2 = rng.range(1, 3);
    let pad = if rng.chance(0.5) { Padding::Same } else { Padding::Valid };
    let mut b = GraphBuilder::new("prop-chain");
    let x = b.input("x", &[1, h, w, cin], DType::F32);
    let c1 = b.conv2d("c1", x, cout, (kh, kw), (s1, s1), pad, Act::Relu6);
    let dw = b.dwconv2d("dw", c1, (3, 3), (s2, s2), Padding::Same, Act::Relu6);
    let gap = b.global_avgpool("gap", dw);
    let fc = b.dense("fc", gap, 3, Act::Linear);
    b.output(fc);
    b.finish().unwrap()
}

/// Satellite: property test — split-then-execute is BIT-exact (assert_eq,
/// not tolerance) against the unsplit graph for all three axes, across
/// random small shapes including odd sizes, stride 2 and SAME padding.
#[test]
fn prop_split_execute_bit_exact_on_every_axis() {
    prop::check("split-exec-bit-exact", 40, |rng| {
        let g = random_chain(rng);
        let ws = WeightStore::seeded_f32(&g, rng.next_u64());
        let n_in = g.tensors[g.inputs[0]].elems();
        let input = TensorData::F32((0..n_in).map(|i| ((i % 13) as f32 - 6.0) / 5.0).collect());
        let base = Interpreter::new(&g, ws.clone(), ExecConfig::with_capacity(1 << 20))
            .run(&[input.clone()])
            .unwrap();
        let seg_ops =
            vec![g.op_by_name("c1").unwrap().id, g.op_by_name("dw").unwrap().id];
        for axis in SplitAxis::ALL {
            let extent = g.tensor_by_name("dw").unwrap().shape[axis.dim()];
            for factor in [2usize, 3] {
                if factor > extent {
                    continue;
                }
                for elide in [false, true] {
                    let seg = SegmentSplit { ops: seg_ops.clone(), factor, axis, elide };
                    let res = split::apply_segment(&g, &seg).unwrap();
                    let ws2 = split::remap_weight_store(&ws, &res);
                    let out =
                        Interpreter::new(&res.graph, ws2, ExecConfig::with_capacity(1 << 20))
                            .run(&[input.clone()])
                            .unwrap();
                    assert_eq!(
                        base.outputs, out.outputs,
                        "axis {:?} factor {factor} elide {elide} drifted",
                        axis
                    );
                }
            }
        }
    });
}

/// Satellite companion: the int8 path on the satellite's named corner —
/// odd spatial sizes, a stride-2 SAME head — exhaustively over the three
/// axes and factors 2/3, bit-exact.
#[test]
fn split_i8_bit_exact_odd_sizes_stride2_same_all_axes() {
    let build = |dtype: DType| {
        let mut b = GraphBuilder::new("odd");
        let x = b.input("x", &[1, 7, 9, 3], dtype);
        let c1 = b.conv2d("c1", x, 6, (3, 3), (2, 2), Padding::Same, Act::Relu6);
        let dw = b.dwconv2d("dw", c1, (3, 3), (1, 1), Padding::Same, Act::Relu6);
        let gap = b.global_avgpool("gap", dw);
        let fc = b.dense("fc", gap, 3, Act::Linear);
        b.output(fc);
        b.finish().unwrap()
    };
    let g_f32 = build(DType::F32);
    let ws_f32 = WeightStore::seeded_f32(&g_f32, 77);
    let input_f = TensorData::F32(ramp(g_f32.tensors[g_f32.inputs[0]].elems()));
    let ranges = calibrate(&g_f32, &ws_f32, &[input_f.clone()], 1 << 20).unwrap();

    let g_i8 = build(DType::I8);
    let ws_i8 = WeightStore::quantize_from(&g_i8, &ws_f32, &ranges);
    let in_q = ws_i8.qparams[&g_i8.inputs[0]];
    let input_q = TensorData::I8(in_q.quantize(input_f.as_f32().unwrap()));
    let base = Interpreter::new(&g_i8, ws_i8.clone(), ExecConfig::with_capacity(1 << 20))
        .run(&[input_q.clone()])
        .unwrap();

    let seg_ops =
        vec![g_i8.op_by_name("c1").unwrap().id, g_i8.op_by_name("dw").unwrap().id];
    for axis in SplitAxis::ALL {
        let extent = g_i8.tensor_by_name("dw").unwrap().shape[axis.dim()];
        for factor in [2usize, 3] {
            if factor > extent {
                continue;
            }
            for elide in [false, true] {
                let seg = SegmentSplit { ops: seg_ops.clone(), factor, axis, elide };
                let res = split::apply_segment(&g_i8, &seg).unwrap();
                let ws2 = split::remap_weight_store(&ws_i8, &res);
                let out = Interpreter::new(&res.graph, ws2, ExecConfig::with_capacity(1 << 20))
                    .run(&[input_q.clone()])
                    .unwrap();
                assert_eq!(
                    base.outputs, out.outputs,
                    "i8 axis {:?} factor {factor} elide {elide}",
                    axis
                );
            }
        }
    }
}

/// Acceptance: on audionet the beam planner's multi-axis plan beats the
/// best row-only plan, and the winning (channel-bearing) plan still
/// executes numerically clean end to end.
#[test]
fn audionet_multi_axis_plan_beats_rows_and_executes() {
    let g = models::audionet(DType::F32);
    let rows = split::optimize(&g, &SplitOptions::default().rows_only()).unwrap();
    let out = split::optimize(&g, &SplitOptions::default()).unwrap();
    assert!(out.improved());
    assert!(
        out.schedule.peak_bytes < rows.schedule.peak_bytes,
        "all-axes {} vs rows-only {}",
        out.schedule.peak_bytes,
        rows.schedule.peak_bytes
    );

    let ws = WeightStore::seeded_f32(&g, 42);
    let input = TensorData::F32(ramp(g.tensors[g.inputs[0]].elems()));
    let base = Interpreter::new(&g, ws.clone(), ExecConfig::with_capacity(1 << 22))
        .run(&[input.clone()])
        .unwrap();
    let ws_split = out.remap_weights(&ws);
    let cfg = ExecConfig {
        order: Some(out.schedule.order.clone()),
        ..ExecConfig::with_capacity(1 << 22)
    };
    let split_run = Interpreter::new(&out.graph, ws_split, cfg).run(&[input]).unwrap();
    let a = base.outputs[0].as_f32().unwrap();
    let b = split_run.outputs[0].as_f32().unwrap();
    for (x, y) in a.iter().zip(b) {
        assert!((x - y).abs() < 1e-5, "audionet split drift: {x} vs {y}");
    }
    // The arena agrees with the analytic accounting on the split graph.
    assert_eq!(split_run.alloc.high_water, out.schedule.peak_bytes);
}

/// Tentpole acceptance (streaming concat elision): on `streamnet` — a zoo
/// model whose fat stride-1 stack leaves *every* materialized split plan
/// stuck at the 2×output join floor — the elided plan breaks the floor:
/// strictly below the best PR-3 (materialized-join) plan and below
/// 2×(join output bytes) + inputs. The planned peak equals the value the
/// exact-schedule DP mirror (tools/schedule_mirror/mirror.py) computes
/// independently: input + one c1 channel slab + the streamed join buffer.
#[test]
fn streamnet_elision_breaks_the_join_floor() {
    let g = models::streamnet(DType::I8);
    let reorder_only = sched::optimal(&g).unwrap().0.peak_bytes;
    assert_eq!(reorder_only, 65_536, "baseline drifted");

    let pr3 = split::optimize(&g, &SplitOptions::default().materialized()).unwrap();
    assert_eq!(
        pr3.schedule.peak_bytes, reorder_only,
        "every materialized plan re-pays the 32KB join next to its slabs"
    );

    let out = split::optimize(&g, &SplitOptions::default()).unwrap();
    assert!(out.elided_steps() > 0, "winning plan must elide a join: {:?}", out.steps);
    assert!(out.schedule.peak_bytes < pr3.schedule.peak_bytes);
    let join_bytes = g.tensor_by_name("d1").unwrap().bytes();
    let input_bytes = g.tensors[g.inputs[0]].bytes();
    assert!(
        out.schedule.peak_bytes < 2 * join_bytes + input_bytes,
        "{} must undercut the 2x-join-plus-inputs floor {}",
        out.schedule.peak_bytes,
        2 * join_bytes + input_bytes
    );
    // DP-mirror value: input (2048) + c1#s3 slab (8 channels, 8192) +
    // the write-through join buffer (32768).
    assert_eq!(out.schedule.peak_bytes, 2_048 + 8_192 + 32_768);
}

/// The elided streamnet plan executes: f32 within 1e-5 and int8 bit-exact
/// against the unsplit graph, with the measured arena high-water equal to
/// the analytic peak (the interpreter's write-through handle reuse is what
/// makes the elision real, not just planned).
#[test]
fn streamnet_elided_execution_is_exact_and_measured_at_the_analytic_peak() {
    // f32 reference path.
    let g_f32 = models::streamnet(DType::F32);
    let ws_f32 = WeightStore::seeded_f32(&g_f32, 42);
    let input_f = TensorData::F32(ramp(g_f32.tensors[g_f32.inputs[0]].elems()));
    let base_f32 = Interpreter::new(&g_f32, ws_f32.clone(), ExecConfig::with_capacity(1 << 22))
        .run(&[input_f.clone()])
        .unwrap();
    let out_f32 = split::optimize(&g_f32, &SplitOptions::default()).unwrap();
    assert!(out_f32.elided_steps() > 0);
    let cfg = ExecConfig {
        order: Some(out_f32.schedule.order.clone()),
        ..ExecConfig::with_capacity(1 << 22)
    };
    let run_f32 = Interpreter::new(&out_f32.graph, out_f32.remap_weights(&ws_f32), cfg)
        .run(&[input_f.clone()])
        .unwrap();
    let a = base_f32.outputs[0].as_f32().unwrap();
    let b = run_f32.outputs[0].as_f32().unwrap();
    for (x, y) in a.iter().zip(b) {
        assert!((x - y).abs() < 1e-5, "f32 elided drift: {x} vs {y}");
    }
    assert_eq!(run_f32.alloc.high_water, out_f32.schedule.peak_bytes);

    // int8: quantize, split with elision, run — bit-exact.
    let ranges = calibrate(&g_f32, &ws_f32, &[input_f.clone()], 1 << 22).unwrap();
    let g_i8 = models::streamnet(DType::I8);
    let ws_i8 = WeightStore::quantize_from(&g_i8, &ws_f32, &ranges);
    let in_q = ws_i8.qparams[&g_i8.inputs[0]];
    let input_q = TensorData::I8(in_q.quantize(input_f.as_f32().unwrap()));
    let base_i8 = Interpreter::new(&g_i8, ws_i8.clone(), ExecConfig::with_capacity(1 << 20))
        .run(&[input_q.clone()])
        .unwrap();
    let out_i8 = split::optimize(&g_i8, &SplitOptions::default()).unwrap();
    assert!(out_i8.elided_steps() > 0);
    let cfg = ExecConfig {
        order: Some(out_i8.schedule.order.clone()),
        ..ExecConfig::with_capacity(1 << 20)
    };
    let run_i8 = Interpreter::new(&out_i8.graph, out_i8.remap_weights(&ws_i8), cfg)
        .run(&[input_q])
        .unwrap();
    assert_eq!(base_i8.outputs, run_i8.outputs, "i8 elided output must be bit-exact");
    assert_eq!(run_i8.alloc.high_water, out_i8.schedule.peak_bytes);
    assert!(run_i8.alloc.high_water < base_i8.alloc.high_water);
}

/// The structural in-place accounting stays *exact*: on randomly elided
/// split chains, Algorithm 1's peak equals exhaustive enumeration over
/// all topological orders, and the branch-and-bound scheduler agrees.
#[test]
fn prop_elided_dp_matches_enumeration() {
    prop::check("elided-dp==enum", 15, |rng| {
        let g = random_chain(rng);
        let axis = *rng.pick(&SplitAxis::ALL);
        let extent = g.tensor_by_name("dw").unwrap().shape[axis.dim()];
        let factor = rng.range(2, 4);
        if factor > extent {
            return;
        }
        let seg = SegmentSplit {
            ops: vec![g.op_by_name("c1").unwrap().id, g.op_by_name("dw").unwrap().id],
            factor,
            axis,
            elide: true,
        };
        let Ok(res) = split::apply_segment(&g, &seg) else { return };
        let orders = sched::all_orders(&res.graph, 500_000).expect("small graph");
        let best =
            orders.iter().map(|o| sched::peak_of(&res.graph, o)).min().unwrap();
        let (dp, _) = sched::optimal(&res.graph).unwrap();
        assert_eq!(dp.peak_bytes, best, "DP vs enumeration on elided graph");
        let (bnb, _) = sched::optimal_bnb(&res.graph).unwrap();
        assert_eq!(bnb.peak_bytes, best, "BnB vs enumeration on elided graph");
    });
}

/// The split CLI surface: a split model file round-trips with its embedded
/// schedule and reproduces the same peak.
#[test]
fn split_model_file_roundtrip() {
    let g = models::mobilenet_v1_025(DType::I8);
    let out = split::optimize(&g, &SplitOptions::quick()).unwrap();
    let mf = mcu_reorder::graph::serde::ModelFile {
        graph: out.graph.clone(),
        execution_order: Some(out.schedule.order.clone()),
    };
    let back = mcu_reorder::graph::serde::ModelFile::from_json(&mf.to_json()).unwrap();
    assert_eq!(back.effective_order(), out.schedule.order);
    assert_eq!(
        sched::peak_of(&back.graph, &back.effective_order()),
        out.schedule.peak_bytes
    );
}

//! Integration over the AOT artifacts: PJRT execution vs the
//! micro-interpreter, and the serving coordinator on the PJRT engine.
//!
//! These tests need `make artifacts` to have run; they are skipped (with a
//! message) when artifacts/ is absent so `cargo test` stays green in a
//! fresh checkout.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use mcu_reorder::coordinator::{self, Coordinator, ServeConfig};
use mcu_reorder::graph::DType;
use mcu_reorder::interp::{ExecConfig, Interpreter, TensorData, WeightStore};
use mcu_reorder::models;
use mcu_reorder::runtime::Runtime;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("tiny.hlo.txt").exists().then_some(dir)
}

fn ramp(n: usize) -> Vec<f32> {
    (0..n).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect()
}

macro_rules! need_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

#[test]
fn pjrt_matches_interpreter_on_all_models() {
    let dir = need_artifacts!();
    let mut rt = Runtime::cpu().unwrap();
    for name in ["tiny", "mobilenet", "swiftnet", "resnet"] {
        if !dir.join(format!("{name}.hlo.txt")).exists() {
            continue;
        }
        rt.load_artifact(name, &dir).unwrap();
        let g = models::by_name(name, DType::F32).unwrap();
        rt.get(name).unwrap().manifest.check_against(&g).unwrap();

        let input = ramp(g.tensors[g.inputs[0]].elems());
        let pjrt_out = rt.execute_f32(name, &[input.clone()]).unwrap();

        let ws = WeightStore::seeded_f32(&g, 42);
        let interp = Interpreter::new(&g, ws, ExecConfig::with_capacity(1 << 24));
        let r = interp.run(&[TensorData::F32(input)]).unwrap();
        let reference = r.outputs[0].as_f32().unwrap();

        assert_eq!(pjrt_out[0].len(), reference.len(), "{name}");
        for (a, b) in pjrt_out[0].iter().zip(reference) {
            assert!((a - b).abs() < 1e-4, "{name}: pjrt={a} interp={b}");
        }
    }
}

#[test]
fn pjrt_rejects_wrong_input_size() {
    let dir = need_artifacts!();
    let mut rt = Runtime::cpu().unwrap();
    rt.load_artifact("tiny", &dir).unwrap();
    assert!(rt.execute_f32("tiny", &[vec![0.0; 3]]).is_err());
    assert!(rt.execute_f32("nope", &[vec![0.0; 128]]).is_err());
}

#[test]
fn coordinator_serves_pjrt_engine() {
    let dir = need_artifacts!();
    let factory = coordinator::pjrt_engine_factory("tiny".into(), dir);
    let c = Arc::new(
        Coordinator::start(ServeConfig { workers: 2, ..Default::default() }, factory).unwrap(),
    );
    let g = models::tiny_cnn(DType::F32);
    let input = ramp(g.tensors[g.inputs[0]].elems());
    let mut rxs = Vec::new();
    for _ in 0..32 {
        rxs.push(c.submit(input.clone()).unwrap());
    }
    for rx in rxs {
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out.len(), 3);
        assert!((out.iter().sum::<f32>() - 1.0).abs() < 1e-4);
    }
    let m = c.metrics();
    assert_eq!(m.completed, 32);
    assert!(m.p99_e2e_us >= m.p50_e2e_us);
}

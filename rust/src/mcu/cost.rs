//! First-order execution-time and energy model.
//!
//! Cycle model (per operator, summed over the schedule):
//!
//! ```text
//! cycles(op)   = macs(op) · cycles_per_mac
//!              + bytes_touched(op) · cycles_per_byte
//!              + op_overhead
//! cycles(run)  = Σ cycles(op)
//!              + bytes_moved · cycles_per_defrag_byte     (compaction memcpy)
//!              + compactions · compact_overhead            (free-list walk)
//! time         = cycles / f_clk
//! ```
//!
//! Energy model:
//!
//! ```text
//! energy = P_core · time + e_mem · (bytes_touched + 2 · bytes_moved)
//! ```
//!
//! `cycles_per_mac` and `e_mem` carry one calibration degree of freedom
//! each, fitted via [`CostModel::calibrated`] against the paper's measured
//! MobileNet point (1316 ms, 728 mJ on the F767ZI). Everything else is
//! datasheet-grade: a Cortex-M7 without SIMD retires an int8 MAC in a
//! multi-cycle load/mul/acc sequence, a naive byte-loop memcpy costs ~8
//! cycles/byte, and defragmentation traffic is charged a read + a write per
//! byte of extra memory energy.
//! The *relative* Table-1 claims (sub-1% overhead of dynamic allocation)
//! come out of the model rather than going into it.

use super::Board;
use crate::alloc::AllocStats;
use crate::graph::Graph;

/// Cost-model constants.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    pub cycles_per_mac: f64,
    pub cycles_per_byte: f64,
    /// Fixed dispatch cost per operator (kernel prologue, re-quant setup).
    pub op_overhead: f64,
    /// memcpy cost of compaction, cycles per byte moved.
    pub cycles_per_defrag_byte: f64,
    /// Free-list walk + handle-table update per compaction pass.
    pub compact_overhead: f64,
    /// Memory access energy, nanojoule per byte (effective, amortized over
    /// SRAM + Flash traffic).
    pub e_mem_nj_per_byte: f64,
    /// Fraction of board active power attributed to the core+clock tree
    /// (the rest rides the `e_mem` term).
    pub core_power_frac: f64,
}

impl CostModel {
    /// Reference constants for an unoptimized int8 reference-kernel build
    /// on a Cortex-M7 (no SIMD/DSP — the paper notes latency "can be
    /// reduced with operator implementations that leverage SIMD/DSP").
    pub fn cortex_m7_reference() -> CostModel {
        CostModel {
            cycles_per_mac: 38.0, // load-pair/mul/acc + loop bookkeeping, scalar C
            cycles_per_byte: 4.0,
            op_overhead: 2_000.0,
            // The paper's defragmenter is a straightforward byte-loop
            // memcpy (a quick custom allocator, not the DSP-optimized
            // CMSIS copy): ~8 cycles/byte on an M7 without alignment
            // tricks.
            cycles_per_defrag_byte: 8.0,
            compact_overhead: 600.0,
            e_mem_nj_per_byte: 6.0,
            core_power_frac: 0.995,
        }
    }

    /// Calibrate `cycles_per_mac` and `e_mem` so that `graph` (executed
    /// with `stats`) reproduces `target_s` seconds and `target_mj`
    /// millijoules on `board`. This pins the two absolute degrees of
    /// freedom to the paper's measured MobileNet static-allocator row; all
    /// other rows are then *predictions*.
    pub fn calibrated(
        graph: &Graph,
        stats: &AllocStats,
        board: &Board,
        target_s: f64,
        target_mj: f64,
    ) -> CostModel {
        let mut m = CostModel::cortex_m7_reference();
        let macs = graph.total_macs() as f64;
        let bytes: f64 = graph.ops.iter().map(|o| o.bytes_touched(graph) as f64).sum();
        let fixed = bytes * m.cycles_per_byte
            + graph.n_ops() as f64 * m.op_overhead
            + stats.bytes_moved as f64 * m.cycles_per_defrag_byte
            + stats.compactions as f64 * m.compact_overhead;
        let target_cycles = target_s * board.clock_hz as f64;
        m.cycles_per_mac = ((target_cycles - fixed) / macs).max(0.1);

        // Energy: solve e_mem from the residual after core power.
        let est = m.estimate(graph, stats, board);
        let core_mj = board.active_power_mw * m.core_power_frac * est.seconds;
        let traffic = bytes + 2.0 * stats.bytes_moved as f64;
        m.e_mem_nj_per_byte = (((target_mj - core_mj) / traffic) * 1.0e6).max(0.0);
        m
    }

    /// Estimate time/energy of executing `graph` once with the allocator
    /// behaviour summarized by `stats`.
    pub fn estimate(&self, graph: &Graph, stats: &AllocStats, board: &Board) -> Estimate {
        let macs = graph.total_macs() as f64;
        let bytes: f64 = graph.ops.iter().map(|o| o.bytes_touched(graph) as f64).sum();
        let mac_cycles = macs * self.cycles_per_mac;
        let mem_cycles = bytes * self.cycles_per_byte;
        let dispatch_cycles = graph.n_ops() as f64 * self.op_overhead;
        let defrag_cycles = stats.bytes_moved as f64 * self.cycles_per_defrag_byte
            + stats.compactions as f64 * self.compact_overhead;
        let cycles = mac_cycles + mem_cycles + dispatch_cycles + defrag_cycles;
        let seconds = cycles / board.clock_hz as f64;
        let traffic = bytes + 2.0 * stats.bytes_moved as f64;
        let energy_mj = board.active_power_mw * self.core_power_frac * seconds
            + self.e_mem_nj_per_byte * traffic / 1.0e6;
        Estimate {
            seconds,
            energy_mj,
            breakdown: CostBreakdown {
                mac_cycles,
                mem_cycles,
                dispatch_cycles,
                defrag_cycles,
            },
        }
    }
}

/// Execution-cost overhead of a split (partially-executed) graph relative
/// to its unsplit baseline: halo rows recomputed by adjacent slices and
/// the extra activation traffic of re-read inputs and the row-concat join.
/// Memory is what splitting buys; this is what it pays.
#[derive(Clone, Copy, Debug)]
pub struct SplitOverhead {
    pub base_macs: u64,
    pub split_macs: u64,
    pub base_bytes: u64,
    pub split_bytes: u64,
    /// Modeled execution-time ratio (split / base) under `model`/`board`,
    /// with identical allocator stats for both sides.
    pub time_ratio: f64,
}

impl SplitOverhead {
    /// Compare a split graph against its unsplit baseline.
    pub fn measure(
        model: &CostModel,
        base: &Graph,
        split: &Graph,
        board: &Board,
    ) -> SplitOverhead {
        let stats = AllocStats::default();
        let est_base = model.estimate(base, &stats, board);
        let est_split = model.estimate(split, &stats, board);
        SplitOverhead {
            base_macs: base.total_macs(),
            split_macs: split.total_macs(),
            base_bytes: base.ops.iter().map(|o| o.bytes_touched(base)).sum(),
            split_bytes: split.ops.iter().map(|o| o.bytes_touched(split)).sum(),
            time_ratio: est_split.seconds / est_base.seconds,
        }
    }

    /// Fraction of MACs recomputed (0.0 = no halo overlap).
    pub fn recompute_frac(&self) -> f64 {
        if self.base_macs == 0 {
            return 0.0;
        }
        self.split_macs as f64 / self.base_macs as f64 - 1.0
    }
}

/// Cycle breakdown of an estimate.
#[derive(Clone, Copy, Debug, Default)]
pub struct CostBreakdown {
    pub mac_cycles: f64,
    pub mem_cycles: f64,
    pub dispatch_cycles: f64,
    pub defrag_cycles: f64,
}

impl CostBreakdown {
    pub fn total(&self) -> f64 {
        self.mac_cycles + self.mem_cycles + self.dispatch_cycles + self.defrag_cycles
    }
}

/// Modeled execution time and energy of one inference.
#[derive(Clone, Copy, Debug)]
pub struct Estimate {
    pub seconds: f64,
    pub energy_mj: f64,
    pub breakdown: CostBreakdown,
}

impl Estimate {
    pub fn millis(&self) -> f64 {
        self.seconds * 1.0e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, GraphBuilder};
    use crate::mcu::NUCLEO_F767ZI;

    fn g_with_macs() -> Graph {
        let mut b = GraphBuilder::new("g");
        let mut t = b.input("x", &[4096], DType::U8);
        for i in 0..4 {
            t = b.synthetic(&format!("s{i}"), &[t], 4096, 1_000_000);
        }
        b.output(t);
        b.finish().unwrap()
    }

    #[test]
    fn estimate_is_monotone_in_defrag_traffic() {
        let g = g_with_macs();
        let m = CostModel::cortex_m7_reference();
        let no_moves = AllocStats::default();
        let mut with_moves = AllocStats::default();
        with_moves.bytes_moved = 1_000_000;
        with_moves.compactions = 100;
        let a = m.estimate(&g, &no_moves, &NUCLEO_F767ZI);
        let b = m.estimate(&g, &with_moves, &NUCLEO_F767ZI);
        assert!(b.seconds > a.seconds);
        assert!(b.energy_mj > a.energy_mj);
        // Defrag is charged extra energy per byte, so the energy overhead
        // ratio exceeds the time overhead ratio (paper: 0.97% vs 0.68%).
        let dt = (b.seconds - a.seconds) / a.seconds;
        let de = (b.energy_mj - a.energy_mj) / a.energy_mj;
        assert!(de > dt, "energy overhead {de} should exceed time overhead {dt}");
    }

    #[test]
    fn calibration_reproduces_targets() {
        let g = g_with_macs();
        let stats = AllocStats::default();
        let m = CostModel::calibrated(&g, &stats, &NUCLEO_F767ZI, 1.316, 728.0);
        let est = m.estimate(&g, &stats, &NUCLEO_F767ZI);
        assert!((est.seconds - 1.316).abs() < 1e-6, "seconds={}", est.seconds);
        assert!((est.energy_mj - 728.0).abs() < 0.01, "mj={}", est.energy_mj);
    }

    #[test]
    fn split_overhead_counts_recompute() {
        use crate::graph::{Act, Padding};
        use crate::split::{apply_segment, SegmentSplit};
        let mut b = GraphBuilder::new("g");
        let x = b.input("x", &[1, 16, 16, 4], DType::I8);
        let c1 = b.conv2d("c1", x, 8, (3, 3), (1, 1), Padding::Same, Act::Relu6);
        let c2 = b.conv2d("c2", c1, 8, (3, 3), (1, 1), Padding::Same, Act::Relu6);
        b.output(c2);
        let g = b.finish().unwrap();
        let res = apply_segment(&g, &SegmentSplit { ops: vec![0, 1], factor: 4 }).unwrap();
        let m = CostModel::cortex_m7_reference();
        let ov = SplitOverhead::measure(&m, &g, &res.graph, &NUCLEO_F767ZI);
        // Halo rows of c1 are recomputed by adjacent slices…
        assert!(ov.split_macs > ov.base_macs);
        assert!(ov.recompute_frac() > 0.0 && ov.recompute_frac() < 0.5);
        // …and the chain input is re-read per slice, so time goes up.
        assert!(ov.split_bytes > ov.base_bytes);
        assert!(ov.time_ratio > 1.0);
    }

    #[test]
    fn breakdown_sums_to_total_time() {
        let g = g_with_macs();
        let m = CostModel::cortex_m7_reference();
        let est = m.estimate(&g, &AllocStats::default(), &NUCLEO_F767ZI);
        let t = est.breakdown.total() / NUCLEO_F767ZI.clock_hz as f64;
        assert!((t - est.seconds).abs() < 1e-12);
    }
}

//! First-order execution-time and energy model.
//!
//! Cycle model (per operator, summed over the schedule):
//!
//! ```text
//! cycles(op)   = macs(op) · cycles_per_mac
//!              + bytes_touched(op) · cycles_per_byte
//!              + op_overhead
//! cycles(run)  = Σ cycles(op)
//!              + bytes_moved · cycles_per_defrag_byte     (compaction memcpy)
//!              + compactions · compact_overhead            (free-list walk)
//! time         = cycles / f_clk
//! ```
//!
//! Energy model:
//!
//! ```text
//! energy = P_core · time + e_mem · (bytes_touched + 2 · bytes_moved)
//! ```
//!
//! `cycles_per_mac` and `e_mem` carry one calibration degree of freedom
//! each, fitted via [`CostModel::calibrated`] against the paper's measured
//! MobileNet point (1316 ms, 728 mJ on the F767ZI). Everything else is
//! datasheet-grade: a Cortex-M7 without SIMD retires an int8 MAC in a
//! multi-cycle load/mul/acc sequence, a naive byte-loop memcpy costs ~8
//! cycles/byte, and defragmentation traffic is charged a read + a write per
//! byte of extra memory energy.
//! The *relative* Table-1 claims (sub-1% overhead of dynamic allocation)
//! come out of the model rather than going into it.

use std::collections::HashMap;

use super::Board;
use crate::alloc::AllocStats;
use crate::graph::{Graph, OpKind, SplitAxis};

/// Cost-model constants.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    pub cycles_per_mac: f64,
    pub cycles_per_byte: f64,
    /// Fixed dispatch cost per operator (kernel prologue, re-quant setup).
    pub op_overhead: f64,
    /// memcpy cost of compaction, cycles per byte moved.
    pub cycles_per_defrag_byte: f64,
    /// Free-list walk + handle-table update per compaction pass.
    pub compact_overhead: f64,
    /// Memory access energy, nanojoule per byte (effective, amortized over
    /// SRAM + Flash traffic).
    pub e_mem_nj_per_byte: f64,
    /// Fraction of board active power attributed to the core+clock tree
    /// (the rest rides the `e_mem` term).
    pub core_power_frac: f64,
}

impl CostModel {
    /// Reference constants for an unoptimized int8 reference-kernel build
    /// on a Cortex-M7 (no SIMD/DSP — the paper notes latency "can be
    /// reduced with operator implementations that leverage SIMD/DSP").
    pub fn cortex_m7_reference() -> CostModel {
        CostModel {
            cycles_per_mac: 38.0, // load-pair/mul/acc + loop bookkeeping, scalar C
            cycles_per_byte: 4.0,
            op_overhead: 2_000.0,
            // The paper's defragmenter is a straightforward byte-loop
            // memcpy (a quick custom allocator, not the DSP-optimized
            // CMSIS copy): ~8 cycles/byte on an M7 without alignment
            // tricks.
            cycles_per_defrag_byte: 8.0,
            compact_overhead: 600.0,
            e_mem_nj_per_byte: 6.0,
            core_power_frac: 0.995,
        }
    }

    /// Calibrate `cycles_per_mac` and `e_mem` so that `graph` (executed
    /// with `stats`) reproduces `target_s` seconds and `target_mj`
    /// millijoules on `board`. This pins the two absolute degrees of
    /// freedom to the paper's measured MobileNet static-allocator row; all
    /// other rows are then *predictions*.
    pub fn calibrated(
        graph: &Graph,
        stats: &AllocStats,
        board: &Board,
        target_s: f64,
        target_mj: f64,
    ) -> CostModel {
        let mut m = CostModel::cortex_m7_reference();
        let macs = graph.total_macs() as f64;
        let bytes: f64 = graph.ops.iter().map(|o| o.bytes_touched(graph) as f64).sum();
        let fixed = bytes * m.cycles_per_byte
            + graph.n_ops() as f64 * m.op_overhead
            + stats.bytes_moved as f64 * m.cycles_per_defrag_byte
            + stats.compactions as f64 * m.compact_overhead;
        let target_cycles = target_s * board.clock_hz as f64;
        m.cycles_per_mac = ((target_cycles - fixed) / macs).max(0.1);

        // Energy: solve e_mem from the residual after core power.
        let est = m.estimate(graph, stats, board);
        let core_mj = board.active_power_mw * m.core_power_frac * est.seconds;
        let traffic = bytes + 2.0 * stats.bytes_moved as f64;
        m.e_mem_nj_per_byte = (((target_mj - core_mj) / traffic) * 1.0e6).max(0.0);
        m
    }

    /// Estimate time/energy of executing `graph` once with the allocator
    /// behaviour summarized by `stats`.
    pub fn estimate(&self, graph: &Graph, stats: &AllocStats, board: &Board) -> Estimate {
        let macs = graph.total_macs() as f64;
        let bytes: f64 = graph.ops.iter().map(|o| o.bytes_touched(graph) as f64).sum();
        let mac_cycles = macs * self.cycles_per_mac;
        let mem_cycles = bytes * self.cycles_per_byte;
        let dispatch_cycles = graph.n_ops() as f64 * self.op_overhead;
        let defrag_cycles = stats.bytes_moved as f64 * self.cycles_per_defrag_byte
            + stats.compactions as f64 * self.compact_overhead;
        let cycles = mac_cycles + mem_cycles + dispatch_cycles + defrag_cycles;
        let seconds = cycles / board.clock_hz as f64;
        let traffic = bytes + 2.0 * stats.bytes_moved as f64;
        let energy_mj = board.active_power_mw * self.core_power_frac * seconds
            + self.e_mem_nj_per_byte * traffic / 1.0e6;
        Estimate {
            seconds,
            energy_mj,
            breakdown: CostBreakdown {
                mac_cycles,
                mem_cycles,
                dispatch_cycles,
                defrag_cycles,
            },
        }
    }
}

/// Execution-cost overhead of a split (partially-executed) graph relative
/// to its unsplit baseline: halo elements recomputed by adjacent slices,
/// weight tensors re-read per spatial slice, and the extra activation
/// traffic of re-read inputs and the concat joins. Memory is what
/// splitting buys; this is what it pays — and it pays differently per
/// axis: `Rows`/`Cols` slices overlap (recompute) and re-read full
/// weights, while `Channels` slices partition work and weight columns
/// exactly (zero recompute, no extra weight traffic).
#[derive(Clone, Copy, Debug)]
pub struct SplitOverhead {
    pub base_macs: u64,
    pub split_macs: u64,
    pub base_bytes: u64,
    pub split_bytes: u64,
    /// Flash weight traffic of one inference, unsplit vs split.
    pub base_weight_bytes: u64,
    pub split_weight_bytes: u64,
    /// Bytes written by the `ConcatSlices` joins (the price of
    /// re-materializing each split segment's output).
    pub join_bytes: u64,
    /// Join-copy bytes *removed* by streaming concat elision: the bands
    /// that `PartialInto` slices write through into the join tensor
    /// directly, instead of materializing slabs and copying them. They
    /// appear here for the report, not in `join_bytes` — an elided join
    /// costs no copy.
    pub elided_join_bytes: u64,
    /// Extra MACs attributable to each axis's slices (halo recompute),
    /// indexed `[Rows, Cols, Channels]`.
    pub recompute_by_axis: [u64; 3],
    /// Modeled execution-time ratio (split / base) under `model`/`board`,
    /// with identical allocator stats for both sides.
    pub time_ratio: f64,
}

fn axis_index(axis: SplitAxis) -> usize {
    match axis {
        SplitAxis::Rows => 0,
        SplitAxis::Cols => 1,
        SplitAxis::Channels => 2,
    }
}

impl SplitOverhead {
    /// Compare a split graph against its unsplit baseline.
    pub fn measure(
        model: &CostModel,
        base: &Graph,
        split: &Graph,
        board: &Board,
    ) -> SplitOverhead {
        let stats = AllocStats::default();
        let est_base = model.estimate(base, &stats, board);
        let est_split = model.estimate(split, &stats, board);

        // Attribute slice MACs back to the original op by name (slices are
        // "<orig>#s<j>"; split artifacts are never re-split, so all slices
        // of an op share one axis). The excess over the original op's MACs
        // is that axis's halo recompute.
        let mut per_op: HashMap<(&str, SplitAxis), u64> = HashMap::new();
        let mut join_bytes = 0u64;
        let mut elided_join_bytes = 0u64;
        for op in &split.ops {
            match &op.kind {
                OpKind::Partial { axis, .. } => {
                    if let Some((orig, _)) = op.name.split_once("#s") {
                        *per_op.entry((orig, *axis)).or_insert(0) += op.macs(split);
                    }
                }
                OpKind::PartialInto { axis, .. } => {
                    if let Some((orig, _)) = op.name.split_once("#s") {
                        *per_op.entry((orig, *axis)).or_insert(0) += op.macs(split);
                    }
                    // The band this slice writes through is exactly the
                    // join copy the elision removed; summed over a chain
                    // it is the full join tensor.
                    elided_join_bytes +=
                        (op.band_elems(split) * split.tensors[op.output].dtype.size()) as u64;
                }
                OpKind::ConcatSlices { .. } => {
                    join_bytes += split.tensors[op.output].bytes() as u64;
                }
                _ => {}
            }
        }
        let mut recompute_by_axis = [0u64; 3];
        for ((orig, axis), macs) in per_op {
            if let Some(op) = base.op_by_name(orig) {
                recompute_by_axis[axis_index(axis)] += macs.saturating_sub(op.macs(base));
            }
        }

        SplitOverhead {
            base_macs: base.total_macs(),
            split_macs: split.total_macs(),
            base_bytes: base.ops.iter().map(|o| o.bytes_touched(base)).sum(),
            split_bytes: split.ops.iter().map(|o| o.bytes_touched(split)).sum(),
            base_weight_bytes: base.ops.iter().map(|o| o.weight_bytes(base)).sum(),
            split_weight_bytes: split.ops.iter().map(|o| o.weight_bytes(split)).sum(),
            join_bytes,
            elided_join_bytes,
            recompute_by_axis,
            time_ratio: est_split.seconds / est_base.seconds,
        }
    }

    /// Fraction of MACs recomputed (0.0 = no halo overlap).
    pub fn recompute_frac(&self) -> f64 {
        if self.base_macs == 0 {
            return 0.0;
        }
        self.split_macs as f64 / self.base_macs as f64 - 1.0
    }

    /// Extra MACs of one axis's slices as a fraction of the base MACs.
    pub fn recompute_frac_of(&self, axis: SplitAxis) -> f64 {
        if self.base_macs == 0 {
            return 0.0;
        }
        self.recompute_by_axis[axis_index(axis)] as f64 / self.base_macs as f64
    }

    /// Flash weight-traffic ratio (split / base): > 1 when spatial slices
    /// re-read weights, 1.0 for pure channel plans.
    pub fn weight_traffic_ratio(&self) -> f64 {
        if self.base_weight_bytes == 0 {
            return 1.0;
        }
        self.split_weight_bytes as f64 / self.base_weight_bytes as f64
    }
}

/// Cycle breakdown of an estimate.
#[derive(Clone, Copy, Debug, Default)]
pub struct CostBreakdown {
    pub mac_cycles: f64,
    pub mem_cycles: f64,
    pub dispatch_cycles: f64,
    pub defrag_cycles: f64,
}

impl CostBreakdown {
    pub fn total(&self) -> f64 {
        self.mac_cycles + self.mem_cycles + self.dispatch_cycles + self.defrag_cycles
    }
}

/// Modeled execution time and energy of one inference.
#[derive(Clone, Copy, Debug)]
pub struct Estimate {
    pub seconds: f64,
    pub energy_mj: f64,
    pub breakdown: CostBreakdown,
}

impl Estimate {
    pub fn millis(&self) -> f64 {
        self.seconds * 1.0e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, GraphBuilder};
    use crate::mcu::NUCLEO_F767ZI;

    fn g_with_macs() -> Graph {
        let mut b = GraphBuilder::new("g");
        let mut t = b.input("x", &[4096], DType::U8);
        for i in 0..4 {
            t = b.synthetic(&format!("s{i}"), &[t], 4096, 1_000_000);
        }
        b.output(t);
        b.finish().unwrap()
    }

    #[test]
    fn estimate_is_monotone_in_defrag_traffic() {
        let g = g_with_macs();
        let m = CostModel::cortex_m7_reference();
        let no_moves = AllocStats::default();
        let with_moves =
            AllocStats { bytes_moved: 1_000_000, compactions: 100, ..AllocStats::default() };
        let a = m.estimate(&g, &no_moves, &NUCLEO_F767ZI);
        let b = m.estimate(&g, &with_moves, &NUCLEO_F767ZI);
        assert!(b.seconds > a.seconds);
        assert!(b.energy_mj > a.energy_mj);
        // Defrag is charged extra energy per byte, so the energy overhead
        // ratio exceeds the time overhead ratio (paper: 0.97% vs 0.68%).
        let dt = (b.seconds - a.seconds) / a.seconds;
        let de = (b.energy_mj - a.energy_mj) / a.energy_mj;
        assert!(de > dt, "energy overhead {de} should exceed time overhead {dt}");
    }

    #[test]
    fn calibration_reproduces_targets() {
        let g = g_with_macs();
        let stats = AllocStats::default();
        let m = CostModel::calibrated(&g, &stats, &NUCLEO_F767ZI, 1.316, 728.0);
        let est = m.estimate(&g, &stats, &NUCLEO_F767ZI);
        assert!((est.seconds - 1.316).abs() < 1e-6, "seconds={}", est.seconds);
        assert!((est.energy_mj - 728.0).abs() < 0.01, "mj={}", est.energy_mj);
    }

    #[test]
    fn split_overhead_counts_recompute() {
        use crate::graph::{Act, Padding};
        use crate::split::{apply_segment, SegmentSplit};
        let mut b = GraphBuilder::new("g");
        let x = b.input("x", &[1, 16, 16, 4], DType::I8);
        let c1 = b.conv2d("c1", x, 8, (3, 3), (1, 1), Padding::Same, Act::Relu6);
        let c2 = b.conv2d("c2", c1, 8, (3, 3), (1, 1), Padding::Same, Act::Relu6);
        b.output(c2);
        let g = b.finish().unwrap();
        let seg = SegmentSplit { ops: vec![0, 1], factor: 4, axis: SplitAxis::Rows, elide: false };
        let res = apply_segment(&g, &seg).unwrap();
        let m = CostModel::cortex_m7_reference();
        let ov = SplitOverhead::measure(&m, &g, &res.graph, &NUCLEO_F767ZI);
        // Halo rows of c1 are recomputed by adjacent slices…
        assert!(ov.split_macs > ov.base_macs);
        assert!(ov.recompute_frac() > 0.0 && ov.recompute_frac() < 0.5);
        // …attributed to the row axis…
        assert_eq!(
            ov.recompute_by_axis[0],
            ov.split_macs - ov.base_macs,
            "recompute must be attributed to Rows"
        );
        assert_eq!(ov.recompute_by_axis[1], 0);
        assert_eq!(ov.recompute_by_axis[2], 0);
        // …each slice re-reads the full weights from flash…
        assert_eq!(ov.split_weight_bytes, ov.base_weight_bytes * 4);
        assert!(ov.weight_traffic_ratio() > 3.9);
        // …the join re-materializes the segment output…
        assert_eq!(ov.join_bytes as usize, g.tensors[g.op_by_name("c2").unwrap().output].bytes());
        // …and the chain input is re-read per slice, so time goes up.
        assert!(ov.split_bytes > ov.base_bytes);
        assert!(ov.time_ratio > 1.0);
    }

    #[test]
    fn channel_split_overhead_is_recompute_free() {
        use crate::graph::{Act, Padding};
        use crate::split::{apply_segment, SegmentSplit};
        let mut b = GraphBuilder::new("g");
        let x = b.input("x", &[1, 16, 16, 4], DType::I8);
        let c1 = b.conv2d("c1", x, 8, (3, 3), (1, 1), Padding::Same, Act::Relu6);
        let d1 = b.dwconv2d("d1", c1, (3, 3), (2, 2), Padding::Same, Act::Relu6);
        b.output(d1);
        let g = b.finish().unwrap();
        let seg =
            SegmentSplit { ops: vec![0, 1], factor: 4, axis: SplitAxis::Channels, elide: false };
        let res = apply_segment(&g, &seg).unwrap();
        let m = CostModel::cortex_m7_reference();
        let ov = SplitOverhead::measure(&m, &g, &res.graph, &NUCLEO_F767ZI);
        // Channel slices partition the work and the weight columns exactly.
        assert_eq!(ov.split_macs, ov.base_macs);
        assert_eq!(ov.recompute_by_axis, [0, 0, 0]);
        assert_eq!(ov.split_weight_bytes, ov.base_weight_bytes);
        assert!((ov.weight_traffic_ratio() - 1.0).abs() < 1e-12);
        // The input is still re-read per slice and the join still copies.
        assert!(ov.split_bytes > ov.base_bytes);
        assert!(ov.join_bytes > 0);
    }

    /// Elided joins pay no copy: `join_bytes` drops to zero, the removed
    /// copy shows up in `elided_join_bytes`, recompute attribution is
    /// unchanged, and the modeled time is strictly below the
    /// materialized-join split.
    #[test]
    fn elided_split_drops_join_copy_bytes() {
        use crate::graph::{Act, Padding};
        use crate::split::{apply_segment, SegmentSplit};
        let mut b = GraphBuilder::new("g");
        let x = b.input("x", &[1, 16, 16, 4], DType::I8);
        let c1 = b.conv2d("c1", x, 8, (3, 3), (1, 1), Padding::Same, Act::Relu6);
        let c2 = b.conv2d("c2", c1, 8, (3, 3), (1, 1), Padding::Same, Act::Relu6);
        b.output(c2);
        let g = b.finish().unwrap();
        let seg = SegmentSplit { ops: vec![0, 1], factor: 4, axis: SplitAxis::Rows, elide: false };
        let mat = apply_segment(&g, &seg).unwrap();
        let eli = apply_segment(&g, &SegmentSplit { elide: true, ..seg }).unwrap();
        let m = CostModel::cortex_m7_reference();
        let ov_mat = SplitOverhead::measure(&m, &g, &mat.graph, &NUCLEO_F767ZI);
        let ov_eli = SplitOverhead::measure(&m, &g, &eli.graph, &NUCLEO_F767ZI);
        let out_bytes = g.tensors[g.op_by_name("c2").unwrap().output].bytes() as u64;
        // Same recompute (identical bands), same weight traffic…
        assert_eq!(ov_eli.split_macs, ov_mat.split_macs);
        assert_eq!(ov_eli.recompute_by_axis, ov_mat.recompute_by_axis);
        assert_eq!(ov_eli.split_weight_bytes, ov_mat.split_weight_bytes);
        // …but the join copy is gone, accounted as elided.
        assert_eq!(ov_mat.join_bytes, out_bytes);
        assert_eq!(ov_mat.elided_join_bytes, 0);
        assert_eq!(ov_eli.join_bytes, 0);
        assert_eq!(ov_eli.elided_join_bytes, out_bytes);
        // The write-through slices also skip the slab write + join read,
        // so the elided split touches strictly fewer bytes.
        assert!(ov_eli.split_bytes < ov_mat.split_bytes);
        assert!(ov_eli.time_ratio < ov_mat.time_ratio);
    }

    #[test]
    fn breakdown_sums_to_total_time() {
        let g = g_with_macs();
        let m = CostModel::cortex_m7_reference();
        let est = m.estimate(&g, &AllocStats::default(), &NUCLEO_F767ZI);
        let t = est.breakdown.total() / NUCLEO_F767ZI.clock_hz as f64;
        assert!((t - est.seconds).abs() < 1e-12);
    }
}

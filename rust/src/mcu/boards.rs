//! Board profiles (STM32 catalogue values [1], §2.2).

/// A microcontroller development-board profile.
#[derive(Clone, Copy, Debug)]
pub struct Board {
    pub name: &'static str,
    /// Core family (for reports).
    pub core: &'static str,
    pub clock_hz: u64,
    /// Read-write on-chip SRAM available to the application.
    pub sram_bytes: usize,
    /// NOR-Flash for code + weights.
    pub flash_bytes: usize,
    /// Active power while running the NN workload, in milliwatts.
    /// Calibrated for the F767ZI from the paper's MobileNet row:
    /// 728mJ / 1.316s ≈ 553mW. Other boards use datasheet-typical values.
    pub active_power_mw: f64,
}

/// The paper's evaluation board: NUCLEO-F767ZI [36].
pub const NUCLEO_F767ZI: Board = Board {
    name: "NUCLEO-F767ZI",
    core: "Cortex-M7",
    clock_hz: 216_000_000,
    sram_bytes: 512 * 1024,
    flash_bytes: 2 * 1024 * 1024,
    active_power_mw: 553.0,
};

/// A mid-range Cortex-M4 part (tighter SRAM).
pub const STM32F446RE: Board = Board {
    name: "NUCLEO-F446RE",
    core: "Cortex-M4",
    clock_hz: 180_000_000,
    sram_bytes: 128 * 1024,
    flash_bytes: 512 * 1024,
    active_power_mw: 280.0,
};

/// A high-end Cortex-M7 part (the roomiest realistic target).
pub const STM32H743ZI: Board = Board {
    name: "NUCLEO-H743ZI",
    core: "Cortex-M7",
    clock_hz: 480_000_000,
    sram_bytes: 1024 * 1024,
    flash_bytes: 2 * 1024 * 1024,
    active_power_mw: 720.0,
};

/// The TinyML-summit-era ultra-low-power board (Ambiq Apollo3).
pub const SPARKFUN_EDGE: Board = Board {
    name: "SparkFun-Edge",
    core: "Cortex-M4F",
    clock_hz: 48_000_000,
    sram_bytes: 384 * 1024,
    flash_bytes: 1024 * 1024,
    active_power_mw: 6.0,
};

/// All profiles (CLI listing / sweeps).
pub const ALL_BOARDS: [&Board; 4] =
    [&NUCLEO_F767ZI, &STM32F446RE, &STM32H743ZI, &SPARKFUN_EDGE];

/// Look a board up by its catalogue name (case-insensitive) — the handle
/// fleet plan requests use.
pub fn by_name(name: &str) -> Option<&'static Board> {
    ALL_BOARDS.iter().copied().find(|b| b.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_board_is_512kb_216mhz() {
        assert_eq!(NUCLEO_F767ZI.sram_bytes, 512 * 1024);
        assert_eq!(NUCLEO_F767ZI.clock_hz, 216_000_000);
    }

    #[test]
    fn by_name_is_case_insensitive_and_total() {
        for b in ALL_BOARDS {
            let found = by_name(&b.name.to_ascii_lowercase()).unwrap();
            assert_eq!(found.name, b.name);
        }
        assert!(by_name("no-such-board").is_none());
    }

    #[test]
    fn boards_have_sane_profiles() {
        for b in ALL_BOARDS {
            assert!(b.clock_hz >= 10_000_000);
            assert!(b.sram_bytes >= 64 * 1024);
            assert!(b.flash_bytes >= b.sram_bytes);
            assert!(b.active_power_mw > 0.0);
        }
    }
}

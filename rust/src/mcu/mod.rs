//! MCU hardware models: boards, cycle cost, energy (§2.2, §5).
//!
//! The paper measures a NUCLEO-F767ZI (Cortex-M7 @216MHz, 512KB SRAM). We
//! don't have the board, so time and energy are *first-order models* whose
//! constants are calibrated against the paper's measured MobileNet point
//! (1316ms, 728mJ). Peak-memory numbers never go through these models —
//! they are exact byte accounting. The models are used only for the
//! *relative* claims Table 1 makes: the dynamic allocator's sub-1% time and
//! energy overheads, which depend on the ratio of defragmentation traffic
//! to compute, not on absolute calibration.

pub mod boards;
mod cost;

pub use boards::{Board, NUCLEO_F767ZI, SPARKFUN_EDGE, STM32F446RE, STM32H743ZI};
pub use cost::{CostBreakdown, CostModel, Estimate, SplitOverhead};

use crate::graph::Graph;

/// Interpreter framework overhead model (the "≈200KB for SwiftNet Cell,
/// proportional to the number of tensors" in §5).
///
/// TFLite-Micro keeps per-tensor `TfLiteTensor` structs, per-op registration
/// and scratch state in SRAM alongside the tensor arena. We model it as a
/// base plus a per-tensor and per-op cost, fitted so a SwiftNet-sized graph
/// (~110 tensors incl. weights) lands near the paper's ≈200KB and small
/// graphs get proportionally little.
#[derive(Clone, Copy, Debug)]
pub struct OverheadModel {
    pub base_bytes: usize,
    pub per_tensor_bytes: usize,
    pub per_op_bytes: usize,
}

impl Default for OverheadModel {
    fn default() -> Self {
        // Fit: the SwiftNet-style cell net (models::swiftnet_cell — 142
        // tensors, 53 ops) lands at 199,960 B ≈ the paper's "≈200KB,
        // proportional to the number of tensors". The magnitudes are
        // TFLM-era plausible: TfLiteTensor + quant params + name strings
        // per tensor, node registration + scratch per op.
        OverheadModel { base_bytes: 24 * 1024, per_tensor_bytes: 1044, per_op_bytes: 512 }
    }
}

impl OverheadModel {
    /// Estimated SRAM the framework itself consumes for `g` (everything
    /// that is not tensor data).
    pub fn bytes(&self, g: &Graph) -> usize {
        self.base_bytes
            + self.per_tensor_bytes * g.n_tensors()
            + self.per_op_bytes * g.n_ops()
    }
}

/// Deployment verdict for a (model, schedule-peak, board) triple — the
/// paper's bottom line: does the model fit in SRAM at all?
#[derive(Clone, Debug)]
pub struct DeployReport {
    pub model: String,
    pub board: &'static str,
    /// Peak tensor working set (excl. overheads), bytes.
    pub peak_bytes: usize,
    /// Framework overhead estimate, bytes.
    pub overhead_bytes: usize,
    /// Flash needed for parameters + code.
    pub flash_bytes: usize,
    pub fits_sram: bool,
    pub fits_flash: bool,
}

impl DeployReport {
    pub fn new(g: &Graph, peak_bytes: usize, board: &Board, overhead: &OverheadModel) -> Self {
        let overhead_bytes = overhead.bytes(g);
        // Code footprint: TFLM core + kernels, ~60KB of Flash.
        const CODE_FLASH: usize = 60 * 1024;
        let flash_bytes = g.model_size() + CODE_FLASH;
        DeployReport {
            model: g.name.clone(),
            board: board.name,
            peak_bytes,
            overhead_bytes,
            flash_bytes,
            fits_sram: peak_bytes + overhead_bytes <= board.sram_bytes,
            fits_flash: flash_bytes <= board.flash_bytes,
        }
    }

    pub fn total_sram(&self) -> usize {
        self.peak_bytes + self.overhead_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, GraphBuilder};

    fn small_graph(n_ops: usize) -> Graph {
        let mut b = GraphBuilder::new("g");
        let mut t = b.input("x", &[256], DType::U8);
        for i in 0..n_ops {
            t = b.synthetic(&format!("s{i}"), &[t], 256, 1000);
        }
        b.output(t);
        b.finish().unwrap()
    }

    #[test]
    fn overhead_scales_with_tensor_count() {
        let m = OverheadModel::default();
        let small = m.bytes(&small_graph(4));
        let large = m.bytes(&small_graph(40));
        assert!(large > small);
        assert_eq!(large - small, 36 * (m.per_tensor_bytes + m.per_op_bytes));
    }

    /// The paper's headline deployment story: with the default order
    /// SwiftNet does NOT fit the F767ZI's 512KB SRAM; with the optimal
    /// order it does.
    #[test]
    fn swiftnet_fits_only_with_optimal_order() {
        use crate::graph::DType;
        let g = crate::models::swiftnet_cell(DType::I8);
        let overhead = OverheadModel::default();
        assert!(
            (195_000..205_000).contains(&overhead.bytes(&g)),
            "overhead = {}",
            overhead.bytes(&g)
        );
        let default_peak = crate::sched::peak_of(&g, &g.default_order());
        let (opt, _) = crate::sched::optimal(&g).unwrap();
        let default_report = DeployReport::new(&g, default_peak, &NUCLEO_F767ZI, &overhead);
        let optimal_report = DeployReport::new(&g, opt.peak_bytes, &NUCLEO_F767ZI, &overhead);
        assert!(
            !default_report.fits_sram,
            "default order must NOT fit ({}B)",
            default_report.total_sram()
        );
        assert!(
            optimal_report.fits_sram,
            "optimal order must fit ({}B)",
            optimal_report.total_sram()
        );
        assert!(default_report.fits_flash && optimal_report.fits_flash);
    }

    #[test]
    fn deploy_report_fits_logic() {
        let g = small_graph(4);
        let report = DeployReport::new(&g, 100 * 1024, &NUCLEO_F767ZI, &OverheadModel::default());
        assert!(report.fits_sram);
        assert!(report.fits_flash);
        let report2 = DeployReport::new(&g, 600 * 1024, &NUCLEO_F767ZI, &OverheadModel::default());
        assert!(!report2.fits_sram);
    }
}

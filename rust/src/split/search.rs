//! `SplitPlan` search: co-optimize split factors and execution order.
//!
//! The outer loop is greedy and bottleneck-driven. Each round: simulate
//! the current optimal schedule, anchor candidate chain segments at the
//! operators touching the peak step, try every factor up to
//! [`SplitOptions::max_factor`], score each rewrite by re-running
//! Algorithm 1 ([`crate::sched::optimal`]) on the rewritten graph, and
//! commit the strictly best improvement. Rounds stop when the SRAM budget
//! is met, no candidate improves the peak, or `max_rounds` is reached.
//! Scoring by the *scheduler's* optimum on the *whole* graph is the
//! co-optimization: a split only survives if it helps after reordering.

use super::rewrite::{apply_segment, SegmentSplit, SplitPlan, SplitResult};
use super::SplitError;
use crate::graph::{Graph, OpId, OpKind, TensorId};
use crate::sched::{self, MemTrace, Schedule};

/// Knobs for the greedy split search.
#[derive(Clone, Debug)]
pub struct SplitOptions {
    /// Largest slice count tried per segment.
    pub max_factor: usize,
    /// Longest chain segment (in ops) considered.
    pub max_segment: usize,
    /// Stop as soon as the optimal peak fits this many bytes
    /// (`None` = squeeze as far as the rounds allow).
    pub sram_budget: Option<usize>,
    /// Greedy rounds (= maximum number of segments introduced).
    pub max_rounds: usize,
    /// Cap on candidate segments scored per round.
    pub max_candidates: usize,
}

impl Default for SplitOptions {
    fn default() -> Self {
        SplitOptions {
            max_factor: 4,
            max_segment: 4,
            sram_budget: None,
            max_rounds: 3,
            max_candidates: 48,
        }
    }
}

impl SplitOptions {
    /// Cheaper preset for tests and quick CLI runs.
    pub fn quick() -> Self {
        SplitOptions { max_factor: 3, max_rounds: 1, max_candidates: 24, ..Self::default() }
    }
}

/// One committed greedy round.
#[derive(Clone, Debug)]
pub struct SplitStep {
    /// Names of the segment's ops at the time of the split.
    pub segment: Vec<String>,
    pub factor: usize,
    pub peak_before: usize,
    pub peak_after: usize,
}

/// Result of the split search.
#[derive(Clone, Debug)]
pub struct SplitOutcome {
    /// The rewritten graph (identical to the input when no split helped).
    pub graph: Graph,
    /// Tensor provenance back to the *original* graph (see
    /// [`SplitResult::sources`]).
    pub sources: Vec<TensorId>,
    /// Optimal schedule of `graph`.
    pub schedule: Schedule,
    /// Reorder-only optimal peak of the input graph (the baseline).
    pub base_peak: usize,
    pub steps: Vec<SplitStep>,
    /// The committed plan (op ids are per intermediate graph; replay with
    /// [`super::apply_plan`]).
    pub plan: SplitPlan,
}

impl SplitOutcome {
    /// Did splitting beat reorder-only scheduling?
    pub fn improved(&self) -> bool {
        self.schedule.peak_bytes < self.base_peak
    }

    /// Carry a weight store of the *original* graph onto the split graph
    /// (see [`super::remap_weight_store`]).
    pub fn remap_weights(&self, ws: &crate::interp::WeightStore) -> crate::interp::WeightStore {
        super::rewrite::remap_weights_by_sources(ws, &self.sources)
    }
}

fn is_windowed(kind: &OpKind) -> bool {
    matches!(
        kind,
        OpKind::Conv2D { .. }
            | OpKind::DepthwiseConv2D { .. }
            | OpKind::MaxPool2D { .. }
            | OpKind::AvgPool2D { .. }
    )
}

fn is_pointwise(kind: &OpKind) -> bool {
    matches!(kind, OpKind::Relu | OpKind::Relu6 | OpKind::BatchNorm { .. })
}

fn nhwc1(shape: &[usize]) -> bool {
    shape.len() == 4 && shape[0] == 1
}

/// Can `o` sit inside a row-split chain?
fn sliceable(g: &Graph, o: OpId) -> bool {
    let op = &g.ops[o];
    op.inputs.len() == 1
        && (is_windowed(&op.kind) || is_pointwise(&op.kind))
        && nhwc1(&g.tensors[op.inputs[0]].shape)
        && nhwc1(&g.tensors[op.output].shape)
}

/// The unique activation consumer of `t`, unless `t` is a graph output.
fn sole_consumer(g: &Graph, t: TensorId) -> Option<OpId> {
    if g.outputs.contains(&t) {
        return None;
    }
    let mut it = g.tensors[t].consumers.iter().filter(|&&c| g.ops[c].inputs.contains(&t));
    let first = *it.next()?;
    if it.next().is_some() {
        return None;
    }
    Some(first)
}

/// Maximal sliceable single-consumer chain through `anchor`, in execution
/// order. Empty if `anchor` itself is not sliceable.
fn chain_through(g: &Graph, anchor: OpId) -> Vec<OpId> {
    if !sliceable(g, anchor) {
        return Vec::new();
    }
    let mut chain = vec![anchor];
    loop {
        let head = chain[0];
        let input = g.ops[head].inputs[0];
        let Some(prev) = g.tensors[input].producer else { break };
        if !sliceable(g, prev) || sole_consumer(g, g.ops[prev].output) != Some(head) {
            break;
        }
        chain.insert(0, prev);
    }
    loop {
        let tail = *chain.last().unwrap();
        let Some(next) = sole_consumer(g, g.ops[tail].output) else { break };
        if !sliceable(g, next) {
            break;
        }
        chain.push(next);
    }
    chain
}

/// All maximal sliceable chains of `g` (each op appears in at most one).
pub fn find_chains(g: &Graph) -> Vec<Vec<OpId>> {
    let mut seen = vec![false; g.ops.len()];
    let mut out = Vec::new();
    for o in 0..g.ops.len() {
        if seen[o] || !sliceable(g, o) {
            continue;
        }
        let chain = chain_through(g, o);
        for &c in &chain {
            seen[c] = true;
        }
        out.push(chain);
    }
    out
}

/// Sub-segments (windowed head, length ≤ `max_segment`) of the chain
/// through `anchor` that contain `anchor`.
fn segments_around(g: &Graph, anchor: OpId, max_segment: usize) -> Vec<Vec<OpId>> {
    let chain = chain_through(g, anchor);
    let Some(pos) = chain.iter().position(|&o| o == anchor) else {
        return Vec::new();
    };
    let mut segs = Vec::new();
    for s in 0..=pos {
        if !is_windowed(&g.ops[chain[s]].kind) {
            continue;
        }
        for e in pos..chain.len() {
            if e + 1 - s > max_segment {
                break;
            }
            segs.push(chain[s..=e].to_vec());
        }
    }
    segs
}

/// Candidate segments for one greedy round: chains anchored at the ops
/// touching the peak step of `trace` (the op executing there, plus the
/// producers and consumers of every tensor resident there), and every
/// splittable `Dense`.
pub fn candidate_segments(
    g: &Graph,
    trace: &MemTrace,
    opts: &SplitOptions,
) -> Vec<Vec<OpId>> {
    let step = &trace.steps[trace.peak_step];
    let mut anchors: Vec<OpId> = vec![step.op];
    for &t in &step.resident {
        if let Some(p) = g.tensors[t].producer {
            anchors.push(p);
        }
        for &c in &g.tensors[t].consumers {
            if g.ops[c].inputs.contains(&t) {
                anchors.push(c);
            }
        }
    }
    anchors.sort_unstable();
    anchors.dedup();

    let mut segs: Vec<Vec<OpId>> = Vec::new();
    for a in anchors {
        for s in segments_around(g, a, opts.max_segment) {
            if !segs.contains(&s) {
                segs.push(s);
            }
        }
    }
    // The cap applies to the combinatorial chain segments only; Dense
    // candidates (at most one per dense op) are always scored.
    segs.truncate(opts.max_candidates);
    for op in &g.ops {
        if let OpKind::Dense { .. } = op.kind {
            let out = &g.tensors[op.output].shape;
            if out.len() == 2 && out[1] >= 2 {
                let s = vec![op.id];
                if !segs.contains(&s) {
                    segs.push(s);
                }
            }
        }
    }
    segs
}

/// Greedy split search (see module docs). The outcome's `graph` equals the
/// input graph when no split strictly improves the reorder-only peak.
pub fn optimize(g: &Graph, opts: &SplitOptions) -> Result<SplitOutcome, SplitError> {
    let (base, _) = sched::optimal(g).map_err(|e| SplitError::Schedule(e.to_string()))?;
    let base_peak = base.peak_bytes;

    let mut cur_graph = g.clone();
    let mut cur_sources: Vec<TensorId> = (0..g.tensors.len()).collect();
    let mut cur_sched = base;
    let mut steps: Vec<SplitStep> = Vec::new();
    let mut plan = SplitPlan::default();

    for _round in 0..opts.max_rounds {
        if let Some(budget) = opts.sram_budget {
            if cur_sched.peak_bytes <= budget {
                break;
            }
        }
        let trace = sched::simulate(&cur_graph, &cur_sched.order);
        let mut best: Option<(SplitResult, Schedule, SegmentSplit)> = None;
        for seg_ops in candidate_segments(&cur_graph, &trace, opts) {
            for factor in 2..=opts.max_factor {
                let seg = SegmentSplit { ops: seg_ops.clone(), factor };
                let Ok(res) = apply_segment(&cur_graph, &seg) else { continue };
                let Ok((s, _)) = sched::optimal(&res.graph) else { continue };
                let to_beat =
                    best.as_ref().map_or(cur_sched.peak_bytes, |(_, b, _)| b.peak_bytes);
                if s.peak_bytes < to_beat {
                    best = Some((res, s, seg));
                }
            }
        }
        let Some((res, s, seg)) = best else { break };
        steps.push(SplitStep {
            segment: seg.ops.iter().map(|&o| cur_graph.ops[o].name.clone()).collect(),
            factor: seg.factor,
            peak_before: cur_sched.peak_bytes,
            peak_after: s.peak_bytes,
        });
        plan.steps.push(seg);
        let composed: Vec<TensorId> =
            res.sources.iter().map(|&mid| cur_sources[mid]).collect();
        cur_sources = composed;
        cur_graph = res.graph;
        cur_sched = s;
    }

    Ok(SplitOutcome {
        graph: cur_graph,
        sources: cur_sources,
        schedule: cur_sched,
        base_peak,
        steps,
        plan,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DType;
    use crate::models;

    #[test]
    fn mobilenet_is_one_long_chain() {
        let g = models::mobilenet_v1_025(DType::I8);
        let chains = find_chains(&g);
        // conv1 .. pw13 — everything except gap/fc/softmax.
        assert_eq!(chains.len(), 1);
        assert_eq!(chains[0].len(), 27);
        assert_eq!(chains[0][0], g.op_by_name("conv1").unwrap().id);
    }

    #[test]
    fn swiftnet_chains_follow_branches() {
        let g = models::swiftnet_cell(DType::I8);
        let chains = find_chains(&g);
        // Branch a of cell 1 (a1→a2→a3) is one chain.
        let a1 = g.op_by_name("c1.a1").unwrap().id;
        let a3 = g.op_by_name("c1.a3").unwrap().id;
        let chain = chains.iter().find(|c| c.contains(&a1)).unwrap();
        assert!(chain.contains(&a3));
        // Chains never cross the concat.
        let cat = g.op_by_name("c1.cat").unwrap().id;
        assert!(!chain.contains(&cat));
    }

    #[test]
    fn segments_have_windowed_heads_and_contain_anchor() {
        let g = models::mobilenet_v1_025(DType::I8);
        let anchor = g.op_by_name("pw1").unwrap().id;
        let segs = segments_around(&g, anchor, 4);
        assert!(!segs.is_empty());
        for s in &segs {
            assert!(s.len() <= 4);
            assert!(s.contains(&anchor));
            assert!(is_windowed(&g.ops[s[0]].kind));
        }
    }

    #[test]
    fn optimize_beats_reorder_only_on_mobilenet() {
        let g = models::mobilenet_v1_025(DType::I8);
        let out = optimize(&g, &SplitOptions::quick()).unwrap();
        assert!(
            out.improved(),
            "split+reorder {} should beat reorder-only {}",
            out.schedule.peak_bytes,
            out.base_peak
        );
        assert!(!out.steps.is_empty());
        out.graph.validate().unwrap();
        out.graph.check_order(&out.schedule.order).unwrap();
    }

    #[test]
    fn optimize_respects_budget_and_stops() {
        let g = models::mobilenet_v1_025(DType::I8);
        // Budget already met by reorder-only → no splits.
        let lax = SplitOptions { sram_budget: Some(1 << 20), ..SplitOptions::quick() };
        let out = optimize(&g, &lax).unwrap();
        assert!(out.steps.is_empty());
        assert_eq!(out.schedule.peak_bytes, out.base_peak);
    }

    #[test]
    fn optimize_leaves_unsplittable_graphs_alone() {
        let g = models::figure1();
        let out = optimize(&g, &SplitOptions::quick()).unwrap();
        assert!(out.steps.is_empty());
        assert_eq!(out.schedule.peak_bytes, out.base_peak);
        assert_eq!(out.graph.n_ops(), g.n_ops());
    }
}

//! `SplitPlan` search: co-optimize split segments, factors, axes and the
//! execution order.
//!
//! The planner is a beam search over candidate rewrites. A *move* is a
//! `(segment, factor, axis)` tuple: a sliceable chain anchored at the
//! current schedule's peak step, a slice count, and the axis to band
//! (`Rows`, `Cols` or `Channels`). Each move is scored by re-running
//! Algorithm 1 ([`crate::sched::optimal`]) on the rewritten graph — a
//! split only survives if it helps *after* reordering, which is the
//! co-optimization. Each round every surviving state expands its moves,
//! and the pool (parents included, so stopping early is always allowed)
//! is pruned to [`SplitOptions::beam_width`] states by
//! `(peak SRAM, total MACs)` — the MAC tiebreak prefers plans with less
//! halo recompute, which is where the channel axis shines (channel slices
//! partition work and weights exactly, zero overlap).
//!
//! Beam width 1 degenerates to the greedy bottleneck-round search of the
//! row-only splitter; wider beams keep the runner-up *improving* rewrites
//! alive, so a move that helps less right now (e.g. a smaller-factor or
//! different-axis split that leaves a better-shaped bottleneck) can still
//! win after later rounds — the deployment-configuration search spirit of
//! MCUNet applied to (segment, factor, axis). Moves that do not strictly
//! lower their state's peak are pruned at generation, so every kept state
//! is monotonically improving.
//!
//! # Scaling beyond the zoo
//!
//! Scoring a candidate with a full DP run is fine at 10 ops and hopeless
//! at 1000. The planner therefore evaluates candidates through a layered
//! fast path ([`EvalStrategy::Incremental`], the default) that keeps the
//! selected plans bit-identical to the naive reference:
//!
//! 1. **frontier dedup** — duplicate `(parent graph, segment, factor,
//!    axis, join form)` candidates reached through different rewrite
//!    interleavings are dropped before any scoring;
//! 2. **admissible bound** — [`crate::sched::peak_lower_bound`] prunes
//!    candidates that provably cannot beat their parent's peak without
//!    touching the scheduler;
//! 3. **incremental, memoized peak** — [`crate::sched::fast_optimal_peak`]
//!    series-decomposes the rewritten graph into regions and re-solves
//!    only regions whose structure is new; unchanged regions (everything
//!    the rewrite didn't touch) hit the [`crate::sched::RegionCache`];
//! 4. **deferred ordering** — the exact execution *order* is materialized
//!    only for the states that survive beam pruning, so full-DP runs per
//!    round collapse from `O(candidates)` to `O(beam width)`;
//! 5. **parallel scoring** — candidate evaluations are independent pure
//!    functions; [`SplitOptions::threads`] stripes them across a
//!    `std::thread::scope` and merges results in job order, so any thread
//!    count yields bit-identical plans.
//!
//! [`PlannerStats`] counts every pruning layer and is surfaced through
//! [`Event::PlannerStats`] telemetry and [`SplitOutcome::stats`].

use std::collections::HashSet;

use super::band::{slice_geom, SliceGeom};
use super::rewrite::{apply_segment, SegmentSplit, SplitPlan, SplitResult};
use super::SplitError;
use crate::graph::{Graph, OpId, OpKind, SplitAxis, TensorId};
use crate::sched::{self, MemTrace, RegionCache, Schedule};
use crate::trace::{Event, NullSink, TraceSink};

/// How the planner scores a candidate rewrite.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EvalStrategy {
    /// Re-run the full Algorithm-1 DP for every candidate (the reference
    /// path; what PRs 1–6 always did).
    Naive,
    /// Admissible-bound early cut, then series-decomposed region DP with
    /// a structural memo, then a full DP only for beam survivors.
    /// Selected plans and peaks are identical to [`EvalStrategy::Naive`].
    #[default]
    Incremental,
}

/// Knobs for the beam split search.
#[derive(Clone, Debug)]
pub struct SplitOptions {
    /// Largest slice count tried per segment.
    pub max_factor: usize,
    /// Longest chain segment (in ops) considered.
    pub max_segment: usize,
    /// Stop as soon as the optimal peak fits this many bytes
    /// (`None` = squeeze as far as the rounds allow).
    pub sram_budget: Option<usize>,
    /// Search rounds (= maximum number of segments in a plan).
    pub max_rounds: usize,
    /// Cap on candidate segments scored per axis, per state, per round
    /// (`Dense` candidates are always scored on top). Per-axis so that
    /// enabling more axes never shrinks any one axis's search space.
    pub max_candidates: usize,
    /// States kept per round. 1 = greedy bottleneck rounds.
    pub beam_width: usize,
    /// Axes the planner may slice along.
    pub axes: Vec<SplitAxis>,
    /// Score join-elided variants of every move (streaming concat
    /// elision: the final slice of each pipeline writes its band directly
    /// into the join tensor, so the join copy — and its 2×output floor —
    /// disappears). Both forms are scored, because eliding fixes the
    /// slice order and can lose when the chain input outlives the join
    /// output. `false` reproduces the PR-3 materialized-join planner.
    pub elide: bool,
    /// Worker threads scoring the candidate frontier (1 = serial).
    /// Results are bit-identical at any thread count: jobs are built
    /// serially, striped across threads, and merged back in job order.
    pub threads: usize,
    /// Candidate evaluation strategy (see [`EvalStrategy`]).
    pub eval: EvalStrategy,
}

impl Default for SplitOptions {
    fn default() -> Self {
        SplitOptions {
            max_factor: 4,
            max_segment: 4,
            sram_budget: None,
            max_rounds: 3,
            max_candidates: 48,
            beam_width: 2,
            axes: SplitAxis::ALL.to_vec(),
            elide: true,
            threads: 1,
            eval: EvalStrategy::Incremental,
        }
    }
}

impl SplitOptions {
    /// Cheaper preset for tests and quick CLI runs.
    pub fn quick() -> Self {
        SplitOptions {
            max_factor: 3,
            max_rounds: 1,
            max_candidates: 24,
            beam_width: 1,
            ..Self::default()
        }
    }

    /// Restrict the planner to the spatial row axis, keeping every other
    /// knob (beam width, rounds, factors) unchanged — the axis-ablation
    /// baseline the benches compare multi-axis plans against.
    pub fn rows_only(self) -> Self {
        SplitOptions { axes: vec![SplitAxis::Rows], ..self }
    }

    /// Disable join elision — every committed split keeps its
    /// `ConcatSlices` copy, reproducing the PR-3 planner (the ablation
    /// baseline the benches compare elided plans against).
    pub fn materialized(self) -> Self {
        SplitOptions { elide: false, ..self }
    }

    /// Score every candidate with the full DP (the reference evaluation
    /// path; the equivalence tests and benches compare against it).
    pub fn naive(self) -> Self {
        SplitOptions { eval: EvalStrategy::Naive, ..self }
    }

    /// Stripe candidate scoring across `n` threads.
    pub fn with_threads(self, n: usize) -> Self {
        SplitOptions { threads: n.max(1), ..self }
    }
}

/// One committed split of a plan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SplitStep {
    /// Names of the segment's ops at the time of the split.
    pub segment: Vec<String>,
    pub factor: usize,
    pub axis: SplitAxis,
    /// Whether the join was elided (slices write through into the join
    /// tensor; no `ConcatSlices` copy).
    pub elided: bool,
    pub peak_before: usize,
    pub peak_after: usize,
}

/// Planner work counters for one [`optimize`] run. Every scored
/// candidate lands in exactly one of the outcome buckets, so
/// `scored == improved + no_improve + bounded + apply_failed +
/// schedule_failed`; `cache_lookups == cache_hits + cache_misses` by
/// construction. Surfaced on [`SplitOutcome::stats`] and, when tracing,
/// as a single [`Event::PlannerStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlannerStats {
    /// Candidates evaluated (after frontier dedup).
    pub scored: usize,
    /// Duplicate candidates dropped before scoring.
    pub deduped: usize,
    /// Candidates kept (strictly improving).
    pub improved: usize,
    /// Candidates whose exact peak did not beat their parent.
    pub no_improve: usize,
    /// Candidates pruned by the admissible lower bound alone.
    pub bounded: usize,
    /// Candidates whose rewrite failed to apply.
    pub apply_failed: usize,
    /// Candidates whose rewritten graph the scheduler rejected.
    pub schedule_failed: usize,
    /// Full Algorithm-1 DP runs (candidate scoring fallbacks + beam
    /// survivor order materialization). The naive strategy pays one per
    /// candidate surviving `apply_segment`; see [`Self::naive_evals`].
    pub full_evals: usize,
    /// Region-memo lookups (one per region per fast-path evaluation).
    pub cache_lookups: usize,
    pub cache_hits: usize,
    pub cache_misses: usize,
    /// Scoring threads used.
    pub threads: usize,
}

impl PlannerStats {
    /// Full-DP evaluations the naive strategy would have spent on the
    /// same candidate stream: one per candidate that survives
    /// `apply_segment`.
    pub fn naive_evals(&self) -> usize {
        self.scored - self.apply_failed
    }

    /// How many times fewer full-schedule evaluations this run performed
    /// than the naive strategy would have (≥ 1.0; the acceptance target
    /// at 1000 ops is ≥ 10×).
    pub fn eval_ratio(&self) -> f64 {
        self.naive_evals() as f64 / self.full_evals.max(1) as f64
    }
}

/// Result of the split search.
#[derive(Clone, Debug)]
pub struct SplitOutcome {
    /// The rewritten graph (identical to the input when no split helped).
    pub graph: Graph,
    /// Tensor provenance back to the *original* graph (see
    /// [`super::SplitResult::sources`]).
    pub sources: Vec<TensorId>,
    /// Optimal schedule of `graph`.
    pub schedule: Schedule,
    /// Reorder-only optimal peak of the input graph (the baseline).
    pub base_peak: usize,
    pub steps: Vec<SplitStep>,
    /// The committed plan (op ids are per intermediate graph; replay with
    /// [`super::apply_plan`]).
    pub plan: SplitPlan,
    /// Planner work counters (scored / pruned / cached / threaded).
    pub stats: PlannerStats,
}

impl SplitOutcome {
    /// Did splitting beat reorder-only scheduling?
    pub fn improved(&self) -> bool {
        self.schedule.peak_bytes < self.base_peak
    }

    /// Number of committed splits whose join was elided (streamed through
    /// the accumulator chain instead of a `ConcatSlices` copy).
    pub fn elided_steps(&self) -> usize {
        self.steps.iter().filter(|s| s.elided).count()
    }

    /// The distinct axes the committed plan slices along.
    pub fn axes_used(&self) -> Vec<SplitAxis> {
        let mut axes: Vec<SplitAxis> = Vec::new();
        for s in &self.steps {
            if !axes.contains(&s.axis) {
                axes.push(s.axis);
            }
        }
        axes
    }

    /// Carry a weight store of the *original* graph onto the split graph
    /// (see [`super::remap_weight_store`]).
    pub fn remap_weights(&self, ws: &crate::interp::WeightStore) -> crate::interp::WeightStore {
        super::rewrite::remap_weights_by_sources(ws, &self.sources)
    }
}

/// Can `o` sit at an interior (non-head) position of a chain along `axis`?
fn interior_sliceable(g: &Graph, o: OpId, axis: SplitAxis) -> bool {
    matches!(
        slice_geom(g, &g.ops[o], axis),
        Some(SliceGeom::Windowed { .. } | SliceGeom::Pointwise | SliceGeom::ChanParallel)
    )
}

/// Can `o` head a segment along `axis`? (Spatial axes: windowed ops;
/// channel axis: a `Conv2D` projection.)
fn head_sliceable(g: &Graph, o: OpId, axis: SplitAxis) -> bool {
    matches!(
        slice_geom(g, &g.ops[o], axis),
        Some(SliceGeom::Windowed { .. } | SliceGeom::ChanProject)
    )
}

/// The unique activation consumer of `t`, unless `t` is a graph output.
fn sole_consumer(g: &Graph, t: TensorId) -> Option<OpId> {
    if g.outputs.contains(&t) {
        return None;
    }
    let mut it = g.tensors[t].consumers.iter().filter(|&&c| g.ops[c].inputs.contains(&t));
    let first = *it.next()?;
    if it.next().is_some() {
        return None;
    }
    Some(first)
}

/// Maximal sliceable single-consumer chain through `anchor` along `axis`,
/// in execution order. Empty if `anchor` itself is not sliceable. A
/// head-only op (`Conv2D` on the channel axis) terminates the upward
/// extension, so it can only appear at position 0.
fn chain_through(g: &Graph, anchor: OpId, axis: SplitAxis) -> Vec<OpId> {
    if !interior_sliceable(g, anchor, axis) && !head_sliceable(g, anchor, axis) {
        return Vec::new();
    }
    let mut chain = vec![anchor];
    loop {
        let head = chain[0];
        if !interior_sliceable(g, head, axis) {
            break; // head-only op: nothing can sit above it
        }
        let input = g.ops[head].inputs[0];
        let Some(prev) = g.tensors[input].producer else { break };
        if sole_consumer(g, g.ops[prev].output) != Some(head) {
            break;
        }
        if interior_sliceable(g, prev, axis) || head_sliceable(g, prev, axis) {
            chain.insert(0, prev);
        } else {
            break;
        }
    }
    loop {
        let tail = *chain.last().unwrap();
        let Some(next) = sole_consumer(g, g.ops[tail].output) else { break };
        if !interior_sliceable(g, next, axis) {
            break;
        }
        chain.push(next);
    }
    chain
}

/// All maximal sliceable chains of `g` along `axis` (each op appears in at
/// most one).
pub fn find_chains_along(g: &Graph, axis: SplitAxis) -> Vec<Vec<OpId>> {
    let mut seen = vec![false; g.ops.len()];
    let mut out = Vec::new();
    for o in 0..g.ops.len() {
        if seen[o] {
            continue;
        }
        let chain = chain_through(g, o, axis);
        if chain.is_empty() {
            continue;
        }
        for &c in &chain {
            seen[c] = true;
        }
        out.push(chain);
    }
    out
}

/// Row-axis chains (the original splitter's view of the graph).
pub fn find_chains(g: &Graph) -> Vec<Vec<OpId>> {
    find_chains_along(g, SplitAxis::Rows)
}

/// Sub-segments (sliceable head, length ≤ `max_segment`) of the chain
/// through `anchor` along `axis` that contain `anchor`.
fn segments_around(g: &Graph, anchor: OpId, axis: SplitAxis, max_segment: usize) -> Vec<Vec<OpId>> {
    let chain = chain_through(g, anchor, axis);
    let Some(pos) = chain.iter().position(|&o| o == anchor) else {
        return Vec::new();
    };
    let mut segs = Vec::new();
    for s in 0..=pos {
        if !head_sliceable(g, chain[s], axis) {
            continue;
        }
        for e in pos..chain.len() {
            if e + 1 - s > max_segment {
                break;
            }
            segs.push(chain[s..=e].to_vec());
        }
    }
    segs
}

/// Candidate moves for one search round: segments anchored at the ops
/// touching the peak step of `trace` (the op executing there, plus the
/// producers and consumers of every tensor resident there), enumerated
/// per axis, and every splittable `Dense` (always scored).
pub fn candidate_moves(
    g: &Graph,
    trace: &MemTrace,
    opts: &SplitOptions,
) -> Vec<(Vec<OpId>, SplitAxis)> {
    let step = &trace.steps[trace.peak_step];
    let mut anchors: Vec<OpId> = vec![step.op];
    for &t in &step.resident {
        if let Some(p) = g.tensors[t].producer {
            anchors.push(p);
        }
        for &c in &g.tensors[t].consumers {
            if g.ops[c].inputs.contains(&t) {
                anchors.push(c);
            }
        }
    }
    anchors.sort_unstable();
    anchors.dedup();

    // The candidate cap applies per axis, so enabling more axes never
    // shrinks any single axis's search space — an all-axes run explores a
    // strict superset of a rows-only run's moves each round. (Dense
    // candidates, at most one per dense op, are always scored on top.)
    let mut moves: Vec<(Vec<OpId>, SplitAxis)> = Vec::new();
    for &axis in &opts.axes {
        let mut n_axis = 0usize;
        'anchors: for &a in &anchors {
            for s in segments_around(g, a, axis, opts.max_segment) {
                let mv = (s, axis);
                if !moves.contains(&mv) {
                    moves.push(mv);
                    n_axis += 1;
                    if n_axis >= opts.max_candidates {
                        break 'anchors;
                    }
                }
            }
        }
    }
    for op in &g.ops {
        if let OpKind::Dense { .. } = op.kind {
            let out = &g.tensors[op.output].shape;
            if out.len() == 2 && out[1] >= 2 {
                let mv = (vec![op.id], SplitAxis::Channels);
                if !moves.contains(&mv) {
                    moves.push(mv);
                }
            }
        }
    }
    moves
}

/// One beam state: a (possibly already split) graph, its optimal peak,
/// and the plan that produced it. The execution `order` is deferred:
/// candidates scored through the incremental fast path know their exact
/// peak long before anyone needs their order, so it is only materialized
/// (one full DP) for states that survive beam pruning.
#[derive(Clone)]
struct BeamState {
    graph: Graph,
    sources: Vec<TensorId>,
    peak: usize,
    order: Option<Vec<OpId>>,
    macs: u64,
    steps: Vec<SplitStep>,
    plan: SplitPlan,
}

/// One deduped unit of scoring work: which beam state to rewrite, and how.
struct Job {
    parent: usize,
    seg: SegmentSplit,
}

/// Serially enumerate the round's candidate frontier with duplicates
/// removed. Two beam states with structurally identical graphs (the same
/// rewrites reached through different interleavings) enumerate identical
/// moves; the dedup key maps each parent to its first identical beam slot
/// so only the first copy generates jobs. Returns the jobs in the exact
/// order the pre-dedup serial planner would have scored them, plus the
/// number of duplicates dropped.
fn build_jobs(
    beam: &[BeamState],
    opts: &SplitOptions,
    met: impl Fn(usize) -> bool,
) -> (Vec<Job>, usize) {
    let canon: Vec<usize> = (0..beam.len())
        .map(|i| (0..i).find(|&j| beam[j].graph == beam[i].graph).unwrap_or(i))
        .collect();
    // Every (factor, join form) variant of a segment move; the elided
    // form streams the join away, the materialized form keeps the PR-3
    // `ConcatSlices` copy. Both are scored — see [`SplitOptions::elide`].
    let mut variants: Vec<(usize, bool)> = Vec::new();
    for factor in 2..=opts.max_factor {
        variants.push((factor, false));
        if opts.elide {
            variants.push((factor, true));
        }
    }
    let mut jobs = Vec::new();
    let mut deduped = 0usize;
    let mut seen: HashSet<(usize, Vec<OpId>, usize, SplitAxis, bool)> = HashSet::new();
    for (pi, st) in beam.iter().enumerate() {
        if met(st.peak) {
            continue;
        }
        let order = st.order.as_ref().expect("beam states have materialized orders");
        let trace = sched::simulate(&st.graph, order);
        for (seg_ops, axis) in candidate_moves(&st.graph, &trace, opts) {
            for &(factor, elide) in &variants {
                if !seen.insert((canon[pi], seg_ops.clone(), factor, axis, elide)) {
                    deduped += 1;
                    continue;
                }
                jobs.push(Job {
                    parent: pi,
                    seg: SegmentSplit { ops: seg_ops.clone(), factor, axis, elide },
                });
            }
        }
    }
    (jobs, deduped)
}

/// What scoring one job concluded.
enum Outcome {
    ApplyFailed,
    /// The admissible bound already meets the parent peak: the exact peak
    /// can only be ≥ the bound, so the candidate provably cannot improve.
    Bounded(usize),
    ScheduleFailed,
    NoImprove(usize),
    Improved { res: SplitResult, peak: usize, order: Option<Vec<OpId>> },
}

struct Scored {
    outcome: Outcome,
    /// Whether a full Algorithm-1 DP ran for this candidate.
    full_eval: bool,
}

/// Score one candidate. Pure: reads the parent state, the options and
/// the shared region memo — safe to run on any thread in any order.
///
/// The incremental path decides improvement from the *exact* region-
/// decomposed peak, so its kept/pruned classification matches the naive
/// full-DP path candidate for candidate. (Known, deliberate divergence:
/// a graph whose region DP succeeds but whose whole-graph DP would blow
/// the state limit — unreachable at the default 4M-state limit for any
/// graph family the planner handles — would here be kept with its order
/// deferred, while the naive path would have dropped it.)
fn eval_job(
    parent: &BeamState,
    seg: &SegmentSplit,
    eval: EvalStrategy,
    cache: &RegionCache,
) -> Scored {
    let Ok(res) = apply_segment(&parent.graph, seg) else {
        return Scored { outcome: Outcome::ApplyFailed, full_eval: false };
    };
    if eval == EvalStrategy::Incremental {
        let lb = sched::peak_lower_bound(&res.graph);
        if lb >= parent.peak {
            return Scored { outcome: Outcome::Bounded(lb), full_eval: false };
        }
        match sched::fast_optimal_peak(&res.graph, cache) {
            Ok(peak) if peak >= parent.peak => {
                return Scored { outcome: Outcome::NoImprove(peak), full_eval: false };
            }
            Ok(peak) => {
                return Scored {
                    outcome: Outcome::Improved { res, peak, order: None },
                    full_eval: false,
                };
            }
            // Region DP state blowup: fall back to the full scheduler.
            Err(_) => {}
        }
    }
    let Ok((s, _)) = sched::optimal(&res.graph) else {
        return Scored { outcome: Outcome::ScheduleFailed, full_eval: true };
    };
    if s.peak_bytes >= parent.peak {
        return Scored { outcome: Outcome::NoImprove(s.peak_bytes), full_eval: true };
    }
    Scored {
        outcome: Outcome::Improved { res, peak: s.peak_bytes, order: Some(s.order) },
        full_eval: true,
    }
}

/// Score `jobs` with `threads` workers. Jobs are striped `idx % threads`
/// and results merged back by index, so the returned vector is in job
/// order regardless of scheduling — the source of the planner's
/// bit-identical-at-any-thread-count guarantee.
fn score_jobs(
    jobs: &[Job],
    beam: &[BeamState],
    opts: &SplitOptions,
    cache: &RegionCache,
) -> Vec<Scored> {
    let threads = opts.threads.max(1).min(jobs.len().max(1));
    if threads <= 1 {
        return jobs.iter().map(|j| eval_job(&beam[j.parent], &j.seg, opts.eval, cache)).collect();
    }
    let mut slots: Vec<Option<Scored>> = Vec::with_capacity(jobs.len());
    slots.resize_with(jobs.len(), || None);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|tid| {
                scope.spawn(move || {
                    jobs.iter()
                        .enumerate()
                        .filter(|(idx, _)| idx % threads == tid)
                        .map(|(idx, j)| {
                            (idx, eval_job(&beam[j.parent], &j.seg, opts.eval, cache))
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for h in handles {
            for (idx, scored) in h.join().expect("planner worker panicked") {
                slots[idx] = Some(scored);
            }
        }
    });
    slots.into_iter().map(|s| s.expect("every job scored exactly once")).collect()
}

/// Beam split search (see module docs). The outcome's `graph` equals the
/// input graph when no split strictly improves the reorder-only peak.
pub fn optimize(g: &Graph, opts: &SplitOptions) -> Result<SplitOutcome, SplitError> {
    optimize_traced(g, opts, &mut NullSink)
}

/// [`optimize`] with planner telemetry: emits one [`Event::Candidate`]
/// per scored `(segment, factor, axis, join form)` variant (with the
/// prune reason — `apply-failed`, `bounded`, `schedule-failed`,
/// `no-improvement` — or `improved`), one [`Event::SearchRound`] summary
/// per beam round, [`Event::Phase`] wall-clock marks for the baseline
/// reorder and each round, and one final [`Event::PlannerStats`] with
/// the run's work counters (the measurement substrate for the
/// `scheduler_scaling` bench).
pub fn optimize_traced(
    g: &Graph,
    opts: &SplitOptions,
    sink: &mut dyn TraceSink,
) -> Result<SplitOutcome, SplitError> {
    let traced = sink.enabled();
    let t_base = std::time::Instant::now();
    let (base, _) = sched::optimal(g).map_err(|e| SplitError::Schedule(e.to_string()))?;
    let base_peak = base.peak_bytes;
    if traced {
        sink.record(Event::Phase {
            name: "baseline-reorder".to_string(),
            wall_ms: t_base.elapsed().as_secs_f64() * 1e3,
        });
    }

    let cache = RegionCache::new();
    let mut stats = PlannerStats { threads: opts.threads.max(1), ..PlannerStats::default() };
    let mut beam: Vec<BeamState> = vec![BeamState {
        graph: g.clone(),
        sources: (0..g.tensors.len()).collect(),
        peak: base.peak_bytes,
        order: Some(base.order),
        macs: g.total_macs(),
        steps: Vec::new(),
        plan: SplitPlan::default(),
    }];
    let met = |peak: usize| opts.sram_budget.is_some_and(|b| peak <= b);

    for round in 0..opts.max_rounds {
        if met(beam[0].peak) {
            break;
        }
        let t_round = std::time::Instant::now();
        let (jobs, deduped) = build_jobs(&beam, opts, met);
        stats.deduped += deduped;
        let results = score_jobs(&jobs, &beam, opts, &cache);

        // Merge serially, in job order: telemetry and the pool are built
        // exactly as the serial planner would, whatever scored the jobs.
        // Parents survive into the pool: a state that stops splitting
        // early is itself a candidate plan.
        let mut pool: Vec<BeamState> = beam.clone();
        let mut n_kept = 0usize;
        let mut grew = false;
        for (job, scored) in jobs.iter().zip(results) {
            let st = &beam[job.parent];
            stats.scored += 1;
            if scored.full_eval {
                stats.full_evals += 1;
            }
            let (peak, kept, reason) = match &scored.outcome {
                Outcome::ApplyFailed => (None, false, "apply-failed"),
                Outcome::Bounded(lb) => (Some(*lb), false, "bounded"),
                Outcome::ScheduleFailed => (None, false, "schedule-failed"),
                Outcome::NoImprove(p) => (Some(*p), false, "no-improvement"),
                Outcome::Improved { peak, .. } => (Some(*peak), true, "improved"),
            };
            if traced {
                // Candidate telemetry: the segment by op names (ids are
                // per intermediate graph and meaningless downstream).
                sink.record(Event::Candidate {
                    round,
                    segment: job.seg.ops.iter().map(|&o| st.graph.ops[o].name.clone()).collect(),
                    factor: job.seg.factor,
                    axis: job.seg.axis.name(),
                    elided: job.seg.elide,
                    peak,
                    kept,
                    reason,
                });
            }
            let outcome = scored.outcome;
            match outcome {
                Outcome::ApplyFailed => stats.apply_failed += 1,
                Outcome::Bounded(_) => stats.bounded += 1,
                Outcome::ScheduleFailed => stats.schedule_failed += 1,
                Outcome::NoImprove(_) => stats.no_improve += 1,
                Outcome::Improved { res, peak, order } => {
                    stats.improved += 1;
                    n_kept += 1;
                    let mut steps = st.steps.clone();
                    steps.push(SplitStep {
                        segment: job
                            .seg
                            .ops
                            .iter()
                            .map(|&o| st.graph.ops[o].name.clone())
                            .collect(),
                        factor: job.seg.factor,
                        axis: job.seg.axis,
                        elided: job.seg.elide,
                        peak_before: st.peak,
                        peak_after: peak,
                    });
                    let mut plan = st.plan.clone();
                    plan.steps.push(job.seg.clone());
                    let sources: Vec<TensorId> =
                        res.sources.iter().map(|&mid| st.sources[mid]).collect();
                    let macs = res.graph.total_macs();
                    pool.push(BeamState { graph: res.graph, sources, peak, order, macs, steps, plan });
                    grew = true;
                }
            }
        }
        // Prune by (peak SRAM, recompute): lower peak first, fewer total
        // MACs on ties — the cheapest plan among equally-small ones wins.
        pool.sort_by_key(|s| (s.peak, s.macs));
        if traced {
            sink.record(Event::SearchRound {
                round,
                scored: jobs.len(),
                kept: n_kept,
                pool: pool.len(),
                best_peak: pool[0].peak,
            });
        }
        pool.truncate(opts.beam_width.max(1));
        beam = pool;
        // Deferred ordering: only now that the round's survivors are
        // known does anyone need an execution order, so the full DP runs
        // O(beam width) times instead of once per kept candidate.
        for st in beam.iter_mut() {
            if st.order.is_none() {
                let (s, _) =
                    sched::optimal(&st.graph).map_err(|e| SplitError::Schedule(e.to_string()))?;
                debug_assert_eq!(
                    s.peak_bytes, st.peak,
                    "region-decomposed peak diverged from the full DP"
                );
                st.order = Some(s.order);
                stats.full_evals += 1;
            }
        }
        if traced {
            sink.record(Event::Phase {
                name: format!("round-{round}"),
                wall_ms: t_round.elapsed().as_secs_f64() * 1e3,
            });
        }
        if !grew {
            break;
        }
    }

    stats.cache_lookups = cache.lookups();
    stats.cache_hits = cache.hits();
    stats.cache_misses = cache.misses();
    if traced {
        sink.record(Event::PlannerStats {
            scored: stats.scored,
            deduped: stats.deduped,
            improved: stats.improved,
            no_improve: stats.no_improve,
            bounded: stats.bounded,
            apply_failed: stats.apply_failed,
            schedule_failed: stats.schedule_failed,
            full_evals: stats.full_evals,
            cache_lookups: stats.cache_lookups,
            cache_hits: stats.cache_hits,
            cache_misses: stats.cache_misses,
            threads: stats.threads,
        });
    }

    let best = beam.swap_remove(0);
    Ok(SplitOutcome {
        graph: best.graph,
        sources: best.sources,
        schedule: Schedule {
            order: best.order.expect("beam states have materialized orders"),
            peak_bytes: best.peak,
        },
        base_peak,
        steps: best.steps,
        plan: best.plan,
        stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DType;
    use crate::models;

    #[test]
    fn mobilenet_is_one_long_chain() {
        let g = models::mobilenet_v1_025(DType::I8);
        let chains = find_chains(&g);
        // conv1 .. pw13 — everything except gap/fc/softmax.
        assert_eq!(chains.len(), 1);
        assert_eq!(chains[0].len(), 27);
        assert_eq!(chains[0][0], g.op_by_name("conv1").unwrap().id);
    }

    #[test]
    fn mobilenet_channel_chains_are_conv_headed() {
        let g = models::mobilenet_v1_025(DType::I8);
        let chains = find_chains_along(&g, SplitAxis::Channels);
        // Channel chains cannot cross a pointwise Conv2D (it reads all
        // input channels), so the long row chain shatters into
        // [conv, dw] pairs plus the tail pw.
        assert!(chains.len() > 10, "got {} chains", chains.len());
        for chain in &chains {
            assert!(chain.len() <= 2);
            // Any multi-op chain starts at a Conv2D projection head.
            if chain.len() == 2 {
                assert!(head_sliceable(&g, chain[0], SplitAxis::Channels));
            }
        }
    }

    #[test]
    fn swiftnet_chains_follow_branches() {
        let g = models::swiftnet_cell(DType::I8);
        let chains = find_chains(&g);
        // Branch a of cell 1 (a1→a2→a3) is one chain.
        let a1 = g.op_by_name("c1.a1").unwrap().id;
        let a3 = g.op_by_name("c1.a3").unwrap().id;
        let chain = chains.iter().find(|c| c.contains(&a1)).unwrap();
        assert!(chain.contains(&a3));
        // Chains never cross the concat.
        let cat = g.op_by_name("c1.cat").unwrap().id;
        assert!(!chain.contains(&cat));
    }

    #[test]
    fn segments_have_sliceable_heads_and_contain_anchor() {
        let g = models::mobilenet_v1_025(DType::I8);
        let anchor = g.op_by_name("pw1").unwrap().id;
        for axis in SplitAxis::ALL {
            let segs = segments_around(&g, anchor, axis, 4);
            for s in &segs {
                assert!(s.len() <= 4);
                assert!(s.contains(&anchor));
                assert!(head_sliceable(&g, s[0], axis));
            }
        }
        assert!(!segments_around(&g, anchor, SplitAxis::Rows, 4).is_empty());
    }

    #[test]
    fn optimize_beats_reorder_only_on_mobilenet() {
        let g = models::mobilenet_v1_025(DType::I8);
        let out = optimize(&g, &SplitOptions::quick()).unwrap();
        assert!(
            out.improved(),
            "split+reorder {} should beat reorder-only {}",
            out.schedule.peak_bytes,
            out.base_peak
        );
        assert!(!out.steps.is_empty());
        out.graph.validate().unwrap();
        out.graph.check_order(&out.schedule.order).unwrap();
    }

    #[test]
    fn wider_beam_is_never_worse() {
        let g = models::mobilenet_v1_025(DType::I8);
        let narrow = optimize(&g, &SplitOptions::quick()).unwrap();
        let wide =
            optimize(&g, &SplitOptions { beam_width: 3, ..SplitOptions::quick() }).unwrap();
        assert!(wide.schedule.peak_bytes <= narrow.schedule.peak_bytes);
    }

    #[test]
    fn beam_prefers_channel_axis_on_expand_dw_chain() {
        // audionet's front block is a channel-split showcase: the fat c1
        // intermediate is consumed by a tall-kernel (12×3) depthwise, so
        // row slabs carry a 10-row halo while channel slabs carry none.
        let g = models::audionet(DType::I8);
        let rows = optimize(&g, &SplitOptions::default().rows_only()).unwrap();
        let all = optimize(&g, &SplitOptions::default()).unwrap();
        assert!(
            all.schedule.peak_bytes < rows.schedule.peak_bytes,
            "all-axes {} should beat rows-only {}",
            all.schedule.peak_bytes,
            rows.schedule.peak_bytes
        );
        assert!(
            all.steps.iter().any(|s| s.axis != SplitAxis::Rows),
            "winning plan should use a non-row axis: {:?}",
            all.steps
        );
    }

    #[test]
    fn optimize_respects_budget_and_stops() {
        let g = models::mobilenet_v1_025(DType::I8);
        // Budget already met by reorder-only → no splits.
        let lax = SplitOptions { sram_budget: Some(1 << 20), ..SplitOptions::quick() };
        let out = optimize(&g, &lax).unwrap();
        assert!(out.steps.is_empty());
        assert_eq!(out.schedule.peak_bytes, out.base_peak);
    }

    #[test]
    fn optimize_leaves_unsplittable_graphs_alone() {
        let g = models::figure1();
        let out = optimize(&g, &SplitOptions::quick()).unwrap();
        assert!(out.steps.is_empty());
        assert_eq!(out.schedule.peak_bytes, out.base_peak);
        assert_eq!(out.graph.n_ops(), g.n_ops());
    }

    fn root_state(g: &Graph) -> BeamState {
        let (base, _) = crate::sched::optimal(g).unwrap();
        BeamState {
            graph: g.clone(),
            sources: (0..g.tensors.len()).collect(),
            peak: base.peak_bytes,
            order: Some(base.order),
            macs: g.total_macs(),
            steps: Vec::new(),
            plan: SplitPlan::default(),
        }
    }

    #[test]
    fn duplicate_beam_states_generate_unique_jobs() {
        let g = models::mobilenet_v1_025(DType::I8);
        let opts = SplitOptions::default();
        let st = root_state(&g);
        let (solo, solo_dedup) = build_jobs(&[st.clone()], &opts, |_| false);
        assert!(!solo.is_empty());
        assert_eq!(solo_dedup, 0);
        // A structurally identical twin state (same graph reached via a
        // different interleaving) must contribute nothing new.
        let (dup, dup_dedup) = build_jobs(&[st.clone(), st.clone()], &opts, |_| false);
        assert_eq!(dup.len(), solo.len());
        assert_eq!(dup_dedup, solo.len());
        assert!(dup.iter().all(|j| j.parent == 0));
        // Job keys are globally unique after dedup.
        let mut keys = std::collections::HashSet::new();
        for j in &dup {
            assert!(keys.insert((
                j.parent,
                j.seg.ops.clone(),
                j.seg.factor,
                j.seg.axis,
                j.seg.elide
            )));
        }
    }

    fn assert_same_outcome(a: &SplitOutcome, b: &SplitOutcome) {
        assert_eq!(a.schedule, b.schedule);
        assert_eq!(a.base_peak, b.base_peak);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.plan, b.plan);
        assert_eq!(a.graph, b.graph);
        assert_eq!(a.sources, b.sources);
    }

    #[test]
    fn incremental_matches_naive_on_mobilenet() {
        let g = models::mobilenet_v1_025(DType::I8);
        for opts in [SplitOptions::quick(), SplitOptions::default()] {
            let naive = optimize(&g, &opts.clone().naive()).unwrap();
            let fast = optimize(&g, &opts).unwrap();
            assert_same_outcome(&fast, &naive);
            assert!(fast.stats.full_evals <= naive.stats.full_evals);
        }
    }

    #[test]
    fn parallel_scoring_is_bit_identical() {
        let g = models::audionet(DType::I8);
        let serial = optimize(&g, &SplitOptions::default()).unwrap();
        for threads in [2, 5] {
            let par = optimize(&g, &SplitOptions::default().with_threads(threads)).unwrap();
            assert_same_outcome(&par, &serial);
            assert_eq!(par.stats.threads, threads);
        }
    }

    #[test]
    fn planner_stats_reconcile() {
        let g = models::audionet(DType::I8);
        let mut sink = crate::trace::VecSink::new();
        let out = optimize_traced(&g, &SplitOptions::default(), &mut sink).unwrap();
        let st = out.stats;
        assert_eq!(st.scored, sink.count("candidate"));
        assert_eq!(
            st.scored,
            st.improved + st.no_improve + st.bounded + st.apply_failed + st.schedule_failed
        );
        assert_eq!(st.cache_lookups, st.cache_hits + st.cache_misses);
        assert_eq!(sink.count("planner"), 1);
        assert!(out.improved());
        assert!(st.full_evals > 0);
        assert!(
            st.full_evals <= st.naive_evals(),
            "fast path did more DP work ({}) than naive would ({})",
            st.full_evals,
            st.naive_evals()
        );
        assert!(st.eval_ratio() >= 1.0);
    }
}

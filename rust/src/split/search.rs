//! `SplitPlan` search: co-optimize split segments, factors, axes and the
//! execution order.
//!
//! The planner is a beam search over candidate rewrites. A *move* is a
//! `(segment, factor, axis)` tuple: a sliceable chain anchored at the
//! current schedule's peak step, a slice count, and the axis to band
//! (`Rows`, `Cols` or `Channels`). Each move is scored by re-running
//! Algorithm 1 ([`crate::sched::optimal`]) on the rewritten graph — a
//! split only survives if it helps *after* reordering, which is the
//! co-optimization. Each round every surviving state expands its moves,
//! and the pool (parents included, so stopping early is always allowed)
//! is pruned to [`SplitOptions::beam_width`] states by
//! `(peak SRAM, total MACs)` — the MAC tiebreak prefers plans with less
//! halo recompute, which is where the channel axis shines (channel slices
//! partition work and weights exactly, zero overlap).
//!
//! Beam width 1 degenerates to the greedy bottleneck-round search of the
//! row-only splitter; wider beams keep the runner-up *improving* rewrites
//! alive, so a move that helps less right now (e.g. a smaller-factor or
//! different-axis split that leaves a better-shaped bottleneck) can still
//! win after later rounds — the deployment-configuration search spirit of
//! MCUNet applied to (segment, factor, axis). Moves that do not strictly
//! lower their state's peak are pruned at generation, so every kept state
//! is monotonically improving.

use super::band::{slice_geom, SliceGeom};
use super::rewrite::{apply_segment, SegmentSplit, SplitPlan};
use super::SplitError;
use crate::graph::{Graph, OpId, OpKind, SplitAxis, TensorId};
use crate::sched::{self, MemTrace, Schedule};
use crate::trace::{Event, NullSink, TraceSink};

/// Knobs for the beam split search.
#[derive(Clone, Debug)]
pub struct SplitOptions {
    /// Largest slice count tried per segment.
    pub max_factor: usize,
    /// Longest chain segment (in ops) considered.
    pub max_segment: usize,
    /// Stop as soon as the optimal peak fits this many bytes
    /// (`None` = squeeze as far as the rounds allow).
    pub sram_budget: Option<usize>,
    /// Search rounds (= maximum number of segments in a plan).
    pub max_rounds: usize,
    /// Cap on candidate segments scored per axis, per state, per round
    /// (`Dense` candidates are always scored on top). Per-axis so that
    /// enabling more axes never shrinks any one axis's search space.
    pub max_candidates: usize,
    /// States kept per round. 1 = greedy bottleneck rounds.
    pub beam_width: usize,
    /// Axes the planner may slice along.
    pub axes: Vec<SplitAxis>,
    /// Score join-elided variants of every move (streaming concat
    /// elision: the final slice of each pipeline writes its band directly
    /// into the join tensor, so the join copy — and its 2×output floor —
    /// disappears). Both forms are scored, because eliding fixes the
    /// slice order and can lose when the chain input outlives the join
    /// output. `false` reproduces the PR-3 materialized-join planner.
    pub elide: bool,
}

impl Default for SplitOptions {
    fn default() -> Self {
        SplitOptions {
            max_factor: 4,
            max_segment: 4,
            sram_budget: None,
            max_rounds: 3,
            max_candidates: 48,
            beam_width: 2,
            axes: SplitAxis::ALL.to_vec(),
            elide: true,
        }
    }
}

impl SplitOptions {
    /// Cheaper preset for tests and quick CLI runs.
    pub fn quick() -> Self {
        SplitOptions {
            max_factor: 3,
            max_rounds: 1,
            max_candidates: 24,
            beam_width: 1,
            ..Self::default()
        }
    }

    /// Restrict the planner to the spatial row axis, keeping every other
    /// knob (beam width, rounds, factors) unchanged — the axis-ablation
    /// baseline the benches compare multi-axis plans against.
    pub fn rows_only(self) -> Self {
        SplitOptions { axes: vec![SplitAxis::Rows], ..self }
    }

    /// Disable join elision — every committed split keeps its
    /// `ConcatSlices` copy, reproducing the PR-3 planner (the ablation
    /// baseline the benches compare elided plans against).
    pub fn materialized(self) -> Self {
        SplitOptions { elide: false, ..self }
    }
}

/// One committed split of a plan.
#[derive(Clone, Debug)]
pub struct SplitStep {
    /// Names of the segment's ops at the time of the split.
    pub segment: Vec<String>,
    pub factor: usize,
    pub axis: SplitAxis,
    /// Whether the join was elided (slices write through into the join
    /// tensor; no `ConcatSlices` copy).
    pub elided: bool,
    pub peak_before: usize,
    pub peak_after: usize,
}

/// Result of the split search.
#[derive(Clone, Debug)]
pub struct SplitOutcome {
    /// The rewritten graph (identical to the input when no split helped).
    pub graph: Graph,
    /// Tensor provenance back to the *original* graph (see
    /// [`super::SplitResult::sources`]).
    pub sources: Vec<TensorId>,
    /// Optimal schedule of `graph`.
    pub schedule: Schedule,
    /// Reorder-only optimal peak of the input graph (the baseline).
    pub base_peak: usize,
    pub steps: Vec<SplitStep>,
    /// The committed plan (op ids are per intermediate graph; replay with
    /// [`super::apply_plan`]).
    pub plan: SplitPlan,
}

impl SplitOutcome {
    /// Did splitting beat reorder-only scheduling?
    pub fn improved(&self) -> bool {
        self.schedule.peak_bytes < self.base_peak
    }

    /// Number of committed splits whose join was elided (streamed through
    /// the accumulator chain instead of a `ConcatSlices` copy).
    pub fn elided_steps(&self) -> usize {
        self.steps.iter().filter(|s| s.elided).count()
    }

    /// The distinct axes the committed plan slices along.
    pub fn axes_used(&self) -> Vec<SplitAxis> {
        let mut axes: Vec<SplitAxis> = Vec::new();
        for s in &self.steps {
            if !axes.contains(&s.axis) {
                axes.push(s.axis);
            }
        }
        axes
    }

    /// Carry a weight store of the *original* graph onto the split graph
    /// (see [`super::remap_weight_store`]).
    pub fn remap_weights(&self, ws: &crate::interp::WeightStore) -> crate::interp::WeightStore {
        super::rewrite::remap_weights_by_sources(ws, &self.sources)
    }
}

/// Can `o` sit at an interior (non-head) position of a chain along `axis`?
fn interior_sliceable(g: &Graph, o: OpId, axis: SplitAxis) -> bool {
    matches!(
        slice_geom(g, &g.ops[o], axis),
        Some(SliceGeom::Windowed { .. } | SliceGeom::Pointwise | SliceGeom::ChanParallel)
    )
}

/// Can `o` head a segment along `axis`? (Spatial axes: windowed ops;
/// channel axis: a `Conv2D` projection.)
fn head_sliceable(g: &Graph, o: OpId, axis: SplitAxis) -> bool {
    matches!(
        slice_geom(g, &g.ops[o], axis),
        Some(SliceGeom::Windowed { .. } | SliceGeom::ChanProject)
    )
}

/// The unique activation consumer of `t`, unless `t` is a graph output.
fn sole_consumer(g: &Graph, t: TensorId) -> Option<OpId> {
    if g.outputs.contains(&t) {
        return None;
    }
    let mut it = g.tensors[t].consumers.iter().filter(|&&c| g.ops[c].inputs.contains(&t));
    let first = *it.next()?;
    if it.next().is_some() {
        return None;
    }
    Some(first)
}

/// Maximal sliceable single-consumer chain through `anchor` along `axis`,
/// in execution order. Empty if `anchor` itself is not sliceable. A
/// head-only op (`Conv2D` on the channel axis) terminates the upward
/// extension, so it can only appear at position 0.
fn chain_through(g: &Graph, anchor: OpId, axis: SplitAxis) -> Vec<OpId> {
    if !interior_sliceable(g, anchor, axis) && !head_sliceable(g, anchor, axis) {
        return Vec::new();
    }
    let mut chain = vec![anchor];
    loop {
        let head = chain[0];
        if !interior_sliceable(g, head, axis) {
            break; // head-only op: nothing can sit above it
        }
        let input = g.ops[head].inputs[0];
        let Some(prev) = g.tensors[input].producer else { break };
        if sole_consumer(g, g.ops[prev].output) != Some(head) {
            break;
        }
        if interior_sliceable(g, prev, axis) || head_sliceable(g, prev, axis) {
            chain.insert(0, prev);
        } else {
            break;
        }
    }
    loop {
        let tail = *chain.last().unwrap();
        let Some(next) = sole_consumer(g, g.ops[tail].output) else { break };
        if !interior_sliceable(g, next, axis) {
            break;
        }
        chain.push(next);
    }
    chain
}

/// All maximal sliceable chains of `g` along `axis` (each op appears in at
/// most one).
pub fn find_chains_along(g: &Graph, axis: SplitAxis) -> Vec<Vec<OpId>> {
    let mut seen = vec![false; g.ops.len()];
    let mut out = Vec::new();
    for o in 0..g.ops.len() {
        if seen[o] {
            continue;
        }
        let chain = chain_through(g, o, axis);
        if chain.is_empty() {
            continue;
        }
        for &c in &chain {
            seen[c] = true;
        }
        out.push(chain);
    }
    out
}

/// Row-axis chains (the original splitter's view of the graph).
pub fn find_chains(g: &Graph) -> Vec<Vec<OpId>> {
    find_chains_along(g, SplitAxis::Rows)
}

/// Sub-segments (sliceable head, length ≤ `max_segment`) of the chain
/// through `anchor` along `axis` that contain `anchor`.
fn segments_around(g: &Graph, anchor: OpId, axis: SplitAxis, max_segment: usize) -> Vec<Vec<OpId>> {
    let chain = chain_through(g, anchor, axis);
    let Some(pos) = chain.iter().position(|&o| o == anchor) else {
        return Vec::new();
    };
    let mut segs = Vec::new();
    for s in 0..=pos {
        if !head_sliceable(g, chain[s], axis) {
            continue;
        }
        for e in pos..chain.len() {
            if e + 1 - s > max_segment {
                break;
            }
            segs.push(chain[s..=e].to_vec());
        }
    }
    segs
}

/// Candidate moves for one search round: segments anchored at the ops
/// touching the peak step of `trace` (the op executing there, plus the
/// producers and consumers of every tensor resident there), enumerated
/// per axis, and every splittable `Dense` (always scored).
pub fn candidate_moves(
    g: &Graph,
    trace: &MemTrace,
    opts: &SplitOptions,
) -> Vec<(Vec<OpId>, SplitAxis)> {
    let step = &trace.steps[trace.peak_step];
    let mut anchors: Vec<OpId> = vec![step.op];
    for &t in &step.resident {
        if let Some(p) = g.tensors[t].producer {
            anchors.push(p);
        }
        for &c in &g.tensors[t].consumers {
            if g.ops[c].inputs.contains(&t) {
                anchors.push(c);
            }
        }
    }
    anchors.sort_unstable();
    anchors.dedup();

    // The candidate cap applies per axis, so enabling more axes never
    // shrinks any single axis's search space — an all-axes run explores a
    // strict superset of a rows-only run's moves each round. (Dense
    // candidates, at most one per dense op, are always scored on top.)
    let mut moves: Vec<(Vec<OpId>, SplitAxis)> = Vec::new();
    for &axis in &opts.axes {
        let mut n_axis = 0usize;
        'anchors: for &a in &anchors {
            for s in segments_around(g, a, axis, opts.max_segment) {
                let mv = (s, axis);
                if !moves.contains(&mv) {
                    moves.push(mv);
                    n_axis += 1;
                    if n_axis >= opts.max_candidates {
                        break 'anchors;
                    }
                }
            }
        }
    }
    for op in &g.ops {
        if let OpKind::Dense { .. } = op.kind {
            let out = &g.tensors[op.output].shape;
            if out.len() == 2 && out[1] >= 2 {
                let mv = (vec![op.id], SplitAxis::Channels);
                if !moves.contains(&mv) {
                    moves.push(mv);
                }
            }
        }
    }
    moves
}

/// One beam state: a (possibly already split) graph, its optimal
/// schedule, and the plan that produced it.
#[derive(Clone)]
struct BeamState {
    graph: Graph,
    sources: Vec<TensorId>,
    sched: Schedule,
    macs: u64,
    steps: Vec<SplitStep>,
    plan: SplitPlan,
}

/// Beam split search (see module docs). The outcome's `graph` equals the
/// input graph when no split strictly improves the reorder-only peak.
pub fn optimize(g: &Graph, opts: &SplitOptions) -> Result<SplitOutcome, SplitError> {
    optimize_traced(g, opts, &mut NullSink)
}

/// [`optimize`] with planner telemetry: emits one [`Event::Candidate`]
/// per scored `(segment, factor, axis, join form)` variant (with the
/// prune reason — `apply-failed`, `schedule-failed`, `no-improvement` —
/// or `improved`), one [`Event::SearchRound`] summary per beam round,
/// and [`Event::Phase`] wall-clock marks for the baseline reorder and
/// each round (the measurement substrate for planner-scaling work).
pub fn optimize_traced(
    g: &Graph,
    opts: &SplitOptions,
    sink: &mut dyn TraceSink,
) -> Result<SplitOutcome, SplitError> {
    let traced = sink.enabled();
    let t_base = std::time::Instant::now();
    let (base, _) = sched::optimal(g).map_err(|e| SplitError::Schedule(e.to_string()))?;
    let base_peak = base.peak_bytes;
    if traced {
        sink.record(Event::Phase {
            name: "baseline-reorder".to_string(),
            wall_ms: t_base.elapsed().as_secs_f64() * 1e3,
        });
    }

    let mut beam: Vec<BeamState> = vec![BeamState {
        graph: g.clone(),
        sources: (0..g.tensors.len()).collect(),
        sched: base,
        macs: g.total_macs(),
        steps: Vec::new(),
        plan: SplitPlan::default(),
    }];
    let met = |peak: usize| opts.sram_budget.is_some_and(|b| peak <= b);

    for round in 0..opts.max_rounds {
        if met(beam[0].sched.peak_bytes) {
            break;
        }
        let t_round = std::time::Instant::now();
        let mut n_scored = 0usize;
        let mut n_kept = 0usize;
        // Parents survive into the pool: a state that stops splitting
        // early is itself a candidate plan.
        let mut pool: Vec<BeamState> = beam.clone();
        let mut grew = false;
        for st in &beam {
            if met(st.sched.peak_bytes) {
                continue;
            }
            let trace = sched::simulate(&st.graph, &st.sched.order);
            // Every (factor, join form) variant of a segment move; the
            // elided form streams the join away, the materialized form
            // keeps the PR-3 `ConcatSlices` copy. Both are scored — see
            // [`SplitOptions::elide`].
            let mut variants: Vec<(usize, bool)> = Vec::new();
            for factor in 2..=opts.max_factor {
                variants.push((factor, false));
                if opts.elide {
                    variants.push((factor, true));
                }
            }
            for (seg_ops, axis) in candidate_moves(&st.graph, &trace, opts) {
                for &(factor, elide) in &variants {
                    n_scored += 1;
                    // Candidate telemetry: the segment by op names (ids are
                    // per intermediate graph and meaningless downstream).
                    let mut candidate = |peak: Option<usize>,
                                         kept: bool,
                                         reason: &'static str,
                                         sink: &mut dyn TraceSink| {
                        sink.record(Event::Candidate {
                            round,
                            segment: seg_ops
                                .iter()
                                .map(|&o| st.graph.ops[o].name.clone())
                                .collect(),
                            factor,
                            axis: axis.name(),
                            elided: elide,
                            peak,
                            kept,
                            reason,
                        });
                    };
                    let seg = SegmentSplit { ops: seg_ops.clone(), factor, axis, elide };
                    let Ok(res) = apply_segment(&st.graph, &seg) else {
                        if traced {
                            candidate(None, false, "apply-failed", sink);
                        }
                        continue;
                    };
                    let Ok((s, _)) = sched::optimal(&res.graph) else {
                        if traced {
                            candidate(None, false, "schedule-failed", sink);
                        }
                        continue;
                    };
                    if s.peak_bytes >= st.sched.peak_bytes {
                        if traced {
                            candidate(Some(s.peak_bytes), false, "no-improvement", sink);
                        }
                        continue; // only strictly improving rewrites survive
                    }
                    n_kept += 1;
                    if traced {
                        candidate(Some(s.peak_bytes), true, "improved", sink);
                    }
                    let mut steps = st.steps.clone();
                    steps.push(SplitStep {
                        segment: seg
                            .ops
                            .iter()
                            .map(|&o| st.graph.ops[o].name.clone())
                            .collect(),
                        factor,
                        axis,
                        elided: elide,
                        peak_before: st.sched.peak_bytes,
                        peak_after: s.peak_bytes,
                    });
                    let mut plan = st.plan.clone();
                    plan.steps.push(seg);
                    let sources: Vec<TensorId> =
                        res.sources.iter().map(|&mid| st.sources[mid]).collect();
                    let macs = res.graph.total_macs();
                    pool.push(BeamState {
                        graph: res.graph,
                        sources,
                        sched: s,
                        macs,
                        steps,
                        plan,
                    });
                    grew = true;
                }
            }
        }
        // Prune by (peak SRAM, recompute): lower peak first, fewer total
        // MACs on ties — the cheapest plan among equally-small ones wins.
        pool.sort_by_key(|s| (s.sched.peak_bytes, s.macs));
        if traced {
            sink.record(Event::SearchRound {
                round,
                scored: n_scored,
                kept: n_kept,
                pool: pool.len(),
                best_peak: pool[0].sched.peak_bytes,
            });
            sink.record(Event::Phase {
                name: format!("round-{round}"),
                wall_ms: t_round.elapsed().as_secs_f64() * 1e3,
            });
        }
        pool.truncate(opts.beam_width.max(1));
        beam = pool;
        if !grew {
            break;
        }
    }

    let best = beam.swap_remove(0);
    Ok(SplitOutcome {
        graph: best.graph,
        sources: best.sources,
        schedule: best.sched,
        base_peak,
        steps: best.steps,
        plan: best.plan,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DType;
    use crate::models;

    #[test]
    fn mobilenet_is_one_long_chain() {
        let g = models::mobilenet_v1_025(DType::I8);
        let chains = find_chains(&g);
        // conv1 .. pw13 — everything except gap/fc/softmax.
        assert_eq!(chains.len(), 1);
        assert_eq!(chains[0].len(), 27);
        assert_eq!(chains[0][0], g.op_by_name("conv1").unwrap().id);
    }

    #[test]
    fn mobilenet_channel_chains_are_conv_headed() {
        let g = models::mobilenet_v1_025(DType::I8);
        let chains = find_chains_along(&g, SplitAxis::Channels);
        // Channel chains cannot cross a pointwise Conv2D (it reads all
        // input channels), so the long row chain shatters into
        // [conv, dw] pairs plus the tail pw.
        assert!(chains.len() > 10, "got {} chains", chains.len());
        for chain in &chains {
            assert!(chain.len() <= 2);
            // Any multi-op chain starts at a Conv2D projection head.
            if chain.len() == 2 {
                assert!(head_sliceable(&g, chain[0], SplitAxis::Channels));
            }
        }
    }

    #[test]
    fn swiftnet_chains_follow_branches() {
        let g = models::swiftnet_cell(DType::I8);
        let chains = find_chains(&g);
        // Branch a of cell 1 (a1→a2→a3) is one chain.
        let a1 = g.op_by_name("c1.a1").unwrap().id;
        let a3 = g.op_by_name("c1.a3").unwrap().id;
        let chain = chains.iter().find(|c| c.contains(&a1)).unwrap();
        assert!(chain.contains(&a3));
        // Chains never cross the concat.
        let cat = g.op_by_name("c1.cat").unwrap().id;
        assert!(!chain.contains(&cat));
    }

    #[test]
    fn segments_have_sliceable_heads_and_contain_anchor() {
        let g = models::mobilenet_v1_025(DType::I8);
        let anchor = g.op_by_name("pw1").unwrap().id;
        for axis in SplitAxis::ALL {
            let segs = segments_around(&g, anchor, axis, 4);
            for s in &segs {
                assert!(s.len() <= 4);
                assert!(s.contains(&anchor));
                assert!(head_sliceable(&g, s[0], axis));
            }
        }
        assert!(!segments_around(&g, anchor, SplitAxis::Rows, 4).is_empty());
    }

    #[test]
    fn optimize_beats_reorder_only_on_mobilenet() {
        let g = models::mobilenet_v1_025(DType::I8);
        let out = optimize(&g, &SplitOptions::quick()).unwrap();
        assert!(
            out.improved(),
            "split+reorder {} should beat reorder-only {}",
            out.schedule.peak_bytes,
            out.base_peak
        );
        assert!(!out.steps.is_empty());
        out.graph.validate().unwrap();
        out.graph.check_order(&out.schedule.order).unwrap();
    }

    #[test]
    fn wider_beam_is_never_worse() {
        let g = models::mobilenet_v1_025(DType::I8);
        let narrow = optimize(&g, &SplitOptions::quick()).unwrap();
        let wide =
            optimize(&g, &SplitOptions { beam_width: 3, ..SplitOptions::quick() }).unwrap();
        assert!(wide.schedule.peak_bytes <= narrow.schedule.peak_bytes);
    }

    #[test]
    fn beam_prefers_channel_axis_on_expand_dw_chain() {
        // audionet's front block is a channel-split showcase: the fat c1
        // intermediate is consumed by a tall-kernel (12×3) depthwise, so
        // row slabs carry a 10-row halo while channel slabs carry none.
        let g = models::audionet(DType::I8);
        let rows = optimize(&g, &SplitOptions::default().rows_only()).unwrap();
        let all = optimize(&g, &SplitOptions::default()).unwrap();
        assert!(
            all.schedule.peak_bytes < rows.schedule.peak_bytes,
            "all-axes {} should beat rows-only {}",
            all.schedule.peak_bytes,
            rows.schedule.peak_bytes
        );
        assert!(
            all.steps.iter().any(|s| s.axis != SplitAxis::Rows),
            "winning plan should use a non-row axis: {:?}",
            all.steps
        );
    }

    #[test]
    fn optimize_respects_budget_and_stops() {
        let g = models::mobilenet_v1_025(DType::I8);
        // Budget already met by reorder-only → no splits.
        let lax = SplitOptions { sram_budget: Some(1 << 20), ..SplitOptions::quick() };
        let out = optimize(&g, &lax).unwrap();
        assert!(out.steps.is_empty());
        assert_eq!(out.schedule.peak_bytes, out.base_peak);
    }

    #[test]
    fn optimize_leaves_unsplittable_graphs_alone() {
        let g = models::figure1();
        let out = optimize(&g, &SplitOptions::quick()).unwrap();
        assert!(out.steps.is_empty());
        assert_eq!(out.schedule.peak_bytes, out.base_peak);
        assert_eq!(out.graph.n_ops(), g.n_ops());
    }
}

//! Graph rewriting: evaluate a chain of operators in `k` slices along a
//! chosen axis.
//!
//! A segment `o_1 → … → o_m` (each interior output consumed only by the
//! next op) is replaced by `k` slice pipelines plus a
//! [`OpKind::ConcatSlices`] join producing the original output tensor. The
//! chain head reads its full, unsliced input (kept live across slices and
//! reclaimed by the scheduler after the last head slice); every other
//! slice op reads the slab the previous slice op produced.
//!
//! Along the spatial axes (`Rows`/`Cols`) interior slabs include halo
//! rows/columns, so adjacent slices recompute the overlap — that cost is
//! visible in `Op::macs`, not hidden. Along `Channels` there is no halo:
//! slices partition the output channels and the weight columns exactly
//! (zero recompute), at the price that a regular `Conv2D` can only *head*
//! a channel segment (it reads all input channels), while depthwise
//! convs, pooling and pointwise ops compose channel-parallel behind it.
//!
//! A single-op segment whose op is `Dense` splits along output features
//! (the weight matrix columns partition; the input is read whole by every
//! slice) — the degenerate channel-axis case.
//!
//! With [`SegmentSplit::elide`] the join is streamed away entirely: the
//! final op of every slice pipeline becomes an [`OpKind::PartialInto`]
//! writing its band directly into the join tensor's buffer, threaded
//! through the slices as an accumulator chain (`…#w0 → …#w1 → join`), and
//! no [`OpKind::ConcatSlices`] op is emitted. The schedulers see the
//! sharing through [`crate::sched::elided_accumulators`]; the interpreter
//! reuses the accumulator's arena handle, so the measured peak matches
//! the analytic one byte-exactly.
//!
//! Every rewrite this module emits is independently re-proven by
//! [`crate::verify::verify_split`] — band tiling, halo/receptive-field
//! coverage and weight partitions re-derived from the graph pair alone,
//! with none of this module's geometry code.

use super::band::{in_band, pad_eff, partition, slice_geom, Band, SliceGeom};
use super::SplitError;
use crate::graph::{DType, Graph, Op, OpId, OpKind, SplitAxis, Tensor, TensorId};
use crate::interp::WeightStore;

/// One split instruction: a chain of ops (in execution order) to evaluate
/// in `factor` slices along `axis`.
///
/// With `elide`, the join is streamed away: the final op of every slice
/// pipeline becomes an [`OpKind::PartialInto`] that writes its output band
/// directly into the join tensor's buffer (threaded through the slices as
/// an accumulator chain), so the slice outputs are never materialized next
/// to a [`OpKind::ConcatSlices`] copy — peak SRAM at the join drops from
/// 2×output to 1×output. Always legal: the join tensor itself still
/// materializes exactly once, so consumers that read the full tensor
/// (e.g. a `Conv2D` that reads all channels after a channel split) are
/// unaffected. The cost is a fixed slice order (the accumulator chain
/// serializes the pipelines), which can lose to the materialized form
/// when the chain *input* dominates the join output — the planner scores
/// both forms.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SegmentSplit {
    pub ops: Vec<OpId>,
    pub factor: usize,
    pub axis: SplitAxis,
    pub elide: bool,
}

/// A sequence of segment splits applied one after another. Op ids in step
/// `i` refer to the graph produced by steps `0..i`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SplitPlan {
    pub steps: Vec<SegmentSplit>,
}

/// A rewritten graph plus the provenance of every tensor.
#[derive(Clone, Debug)]
pub struct SplitResult {
    pub graph: Graph,
    /// `sources[new_tensor_id]` is the tensor of the *input* graph this
    /// tensor derives from: itself for untouched tensors and weights, the
    /// full tensor a slab is a band of otherwise. Used to remap weight
    /// stores and quantization parameters (slabs share their source's
    /// qparams, which is what makes the int8 path bit-exact).
    pub sources: Vec<TensorId>,
}

fn err(m: impl Into<String>) -> SplitError {
    SplitError::InvalidSegment(m.into())
}

fn activation_consumers(g: &Graph, t: TensorId) -> usize {
    g.tensors[t].consumers.iter().filter(|&&c| g.ops[c].inputs.contains(&t)).count()
}

/// Incremental construction of the rewritten graph.
struct Builder {
    ng: Graph,
    sources: Vec<TensorId>,
    tmap: Vec<Option<TensorId>>,
}

impl Builder {
    /// Copy every tensor of `g` except the dropped ones (interior chain
    /// outputs), preserving order, names and shapes.
    fn new(g: &Graph, dropped: &[bool]) -> Builder {
        let mut ng = Graph::new(g.name.clone());
        let mut sources = Vec::new();
        let mut tmap = vec![None; g.tensors.len()];
        for t in &g.tensors {
            if dropped[t.id] {
                continue;
            }
            let id = ng.tensors.len();
            tmap[t.id] = Some(id);
            sources.push(t.id);
            ng.tensors.push(Tensor {
                id,
                name: t.name.clone(),
                shape: t.shape.clone(),
                dtype: t.dtype,
                producer: None,
                consumers: Vec::new(),
                is_weight: t.is_weight,
            });
        }
        Builder { ng, sources, tmap }
    }

    fn map(&self, t: TensorId) -> TensorId {
        self.tmap[t].expect("tensor was kept by the rewrite")
    }

    /// New slab tensor banded out of old tensor `source`.
    fn slab(
        &mut self,
        name: String,
        shape: Vec<usize>,
        dtype: DType,
        source: TensorId,
    ) -> TensorId {
        let id = self.ng.tensors.len();
        self.sources.push(source);
        self.ng.tensors.push(Tensor {
            id,
            name,
            shape,
            dtype,
            producer: None,
            consumers: Vec::new(),
            is_weight: false,
        });
        id
    }

    fn op(
        &mut self,
        name: String,
        kind: OpKind,
        inputs: Vec<TensorId>,
        weights: Vec<TensorId>,
        output: TensorId,
    ) {
        let id = self.ng.ops.len();
        self.ng.tensors[output].producer = Some(id);
        for &t in inputs.iter().chain(&weights) {
            self.ng.tensors[t].consumers.push(id);
        }
        self.ng.ops.push(Op { id, name, kind, inputs, weights, output });
    }

    fn copy_op(&mut self, op: &Op) {
        let inputs: Vec<TensorId> = op.inputs.iter().map(|&t| self.map(t)).collect();
        let weights: Vec<TensorId> = op.weights.iter().map(|&t| self.map(t)).collect();
        let output = self.map(op.output);
        self.op(op.name.clone(), op.kind.clone(), inputs, weights, output);
    }

    fn finish(mut self, g: &Graph) -> Result<SplitResult, SplitError> {
        self.ng.inputs = g.inputs.iter().map(|&t| self.map(t)).collect();
        self.ng.outputs = g.outputs.iter().map(|&t| self.map(t)).collect();
        self.ng
            .validate()
            .map_err(|e| err(format!("rewrite produced an invalid graph: {e}")))?;
        Ok(SplitResult { graph: self.ng, sources: self.sources })
    }
}

/// Split one chain segment of `g` into `seg.factor` slices along
/// `seg.axis`.
pub fn apply_segment(g: &Graph, seg: &SegmentSplit) -> Result<SplitResult, SplitError> {
    let m = seg.ops.len();
    let k = seg.factor;
    if m == 0 {
        return Err(err("empty segment"));
    }
    if k < 2 {
        return Err(err("split factor must be >= 2"));
    }
    for &o in &seg.ops {
        if o >= g.ops.len() {
            return Err(err(format!("op {o} out of range")));
        }
        if matches!(
            g.ops[o].kind,
            OpKind::Partial { .. } | OpKind::ConcatSlices { .. } | OpKind::PartialInto { .. }
        ) {
            return Err(err(format!("op {} is already a split artifact", g.ops[o].name)));
        }
    }
    let head = &g.ops[seg.ops[0]];
    if head.inputs.len() != 1 {
        return Err(err(format!("segment head {} must have one activation input", head.name)));
    }
    for w in seg.ops.windows(2) {
        let out = g.ops[w[0]].output;
        let next = &g.ops[w[1]];
        if next.inputs.len() != 1 || next.inputs[0] != out {
            return Err(err(format!(
                "ops {} -> {} are not chained",
                g.ops[w[0]].name, next.name
            )));
        }
        if activation_consumers(g, out) != 1 || g.outputs.contains(&out) {
            return Err(err(format!(
                "interior tensor {} must have exactly one consumer",
                g.tensors[out].name
            )));
        }
    }
    if let OpKind::Dense { .. } = head.kind {
        if m != 1 {
            return Err(err("dense split must be a single-op segment"));
        }
        return apply_dense(g, seg.ops[0], k, seg.elide);
    }
    apply_chain(g, seg)
}

fn apply_chain(g: &Graph, seg: &SegmentSplit) -> Result<SplitResult, SplitError> {
    let m = seg.ops.len();
    let k = seg.factor;
    let axis = seg.axis;

    let mut geoms: Vec<SliceGeom> = Vec::with_capacity(m);
    for (i, &oid) in seg.ops.iter().enumerate() {
        let op = &g.ops[oid];
        let geom = slice_geom(g, op, axis).ok_or_else(|| {
            SplitError::Unsupported(format!(
                "op {} ({}) cannot be sliced along {}",
                op.name,
                op.kind.name(),
                axis.name()
            ))
        })?;
        match geom {
            SliceGeom::Pointwise | SliceGeom::ChanParallel if i == 0 => {
                return Err(SplitError::Unsupported(format!(
                    "segment head {} must anchor the band (windowed spatial op or \
                     Conv2D channel projection)",
                    op.name
                )));
            }
            SliceGeom::ChanProject if i > 0 => {
                return Err(SplitError::Unsupported(format!(
                    "op {} reads all input channels; Conv2D can only head a channel split",
                    op.name
                )));
            }
            _ => {}
        }
        geoms.push(geom);
    }

    let d = axis.dim();
    let dim_in: Vec<usize> =
        seg.ops.iter().map(|&o| g.tensors[g.ops[o].inputs[0]].shape[d]).collect();
    let last_old = *seg.ops.last().unwrap();
    let n_out_last = g.tensors[g.ops[last_old].output].shape[d];
    if k > n_out_last {
        return Err(err(format!(
            "factor {k} exceeds the {n_out_last} output {} of the segment",
            axis.name()
        )));
    }

    // bands[j][i]: output band of segment op i in slice j, propagated
    // backwards from an even partition of the final output along the axis.
    let mut bands: Vec<Vec<Band>> = Vec::with_capacity(k);
    for part in partition(n_out_last, k) {
        let mut row = vec![part; m];
        for i in (1..m).rev() {
            row[i - 1] = in_band(geoms[i], dim_in[i], row[i]);
            if row[i - 1].rows() == 0 {
                // The band's receptive field lies entirely in the padding
                // (kernel larger than the slice's share of the input) — a
                // pad-only slab. Refuse explicitly rather than fabricate a
                // 1-element band the operator never reads.
                return Err(err(format!(
                    "slice band [{}, {}) of {} needs no real input along {} \
                     (receptive field entirely in padding); reduce the factor",
                    row[i].start,
                    row[i].end,
                    g.ops[seg.ops[i]].name,
                    axis.name()
                )));
            }
        }
        bands.push(row);
    }

    let mut dropped = vec![false; g.tensors.len()];
    for &o in &seg.ops[..m - 1] {
        dropped[g.ops[o].output] = true;
    }
    let mut in_seg = vec![false; g.ops.len()];
    for &o in &seg.ops {
        in_seg[o] = true;
    }
    let first = seg.ops[0];

    let mut b = Builder::new(g, &dropped);
    for op in &g.ops {
        if in_seg[op.id] {
            if op.id != first {
                continue;
            }
            // Emit the k slice pipelines, then the join, in place of the
            // chain head (the old id order was topological, so everything
            // the pipelines read is already emitted). With `elide` there
            // is no join op: the final op of each pipeline writes its band
            // through an accumulator chain that ends in the join tensor.
            let chain_in = b.map(g.ops[first].inputs[0]);
            let join_out = b.map(g.ops[last_old].output);
            let full_join = &g.tensors[g.ops[last_old].output];
            let join_shape = full_join.shape.clone();
            let join_dtype = full_join.dtype;
            let mut slabs: Vec<TensorId> = Vec::with_capacity(k);
            let mut acc: Option<TensorId> = None;
            for (j, band_row) in bands.iter().enumerate() {
                let mut cur = chain_in;
                let mut cur_start = 0usize; // logical first index held by `cur`
                for (i, &oid) in seg.ops.iter().enumerate() {
                    let o = &g.ops[oid];
                    let band = band_row[i];
                    let pad = pad_eff(geoms[i], band.start, cur_start);
                    let name = format!("{}#s{j}", o.name);
                    let weights: Vec<TensorId> = o.weights.iter().map(|&t| b.map(t)).collect();
                    if seg.elide && i == m - 1 {
                        // Write-through slice: band [start, end) of the
                        // join tensor, carried forward as an accumulator.
                        let out = if j == k - 1 {
                            join_out
                        } else {
                            b.slab(
                                format!("{}#w{j}", o.name),
                                join_shape.clone(),
                                join_dtype,
                                o.output,
                            )
                        };
                        let kind = OpKind::PartialInto {
                            inner: Box::new(o.kind.clone()),
                            axis,
                            pad,
                            offset: band.start,
                            len: band.rows(),
                        };
                        let mut inputs = vec![cur];
                        inputs.extend(acc);
                        b.op(name, kind, inputs, weights, out);
                        acc = Some(out);
                    } else {
                        let full_out = &g.tensors[o.output];
                        let mut shape = full_out.shape.clone();
                        shape[d] = band.rows();
                        let kind = OpKind::Partial {
                            inner: Box::new(o.kind.clone()),
                            axis,
                            pad,
                            offset: band.start,
                        };
                        let slab = b.slab(name.clone(), shape, full_out.dtype, o.output);
                        b.op(name, kind, vec![cur], weights, slab);
                        cur = slab;
                    }
                    cur_start = band.start;
                }
                if !seg.elide {
                    slabs.push(cur);
                }
            }
            if !seg.elide {
                b.op(
                    format!("{}#cat", g.ops[last_old].name),
                    OpKind::ConcatSlices { axis },
                    slabs,
                    vec![],
                    join_out,
                );
            }
            continue;
        }
        b.copy_op(op);
    }
    b.finish(g)
}

fn apply_dense(g: &Graph, oid: OpId, k: usize, elide: bool) -> Result<SplitResult, SplitError> {
    let op = &g.ops[oid];
    let out_t = &g.tensors[op.output];
    if out_t.shape.len() != 2 || out_t.shape[0] != 1 {
        return Err(SplitError::Unsupported(format!(
            "dense output shape {:?} is not [1, n]",
            out_t.shape
        )));
    }
    let n = out_t.shape[1];
    if k > n {
        return Err(err(format!("factor {k} exceeds the {n} output features")));
    }
    let act = match op.kind {
        OpKind::Dense { act } => act,
        _ => unreachable!("apply_dense called on a non-dense op"),
    };

    let dropped = vec![false; g.tensors.len()];
    let mut b = Builder::new(g, &dropped);
    for o in &g.ops {
        if o.id != oid {
            b.copy_op(o);
            continue;
        }
        let cur = b.map(op.inputs[0]);
        let join_out = b.map(op.output);
        let mut slabs: Vec<TensorId> = Vec::with_capacity(k);
        let mut acc: Option<TensorId> = None;
        for (j, band) in partition(n, k).iter().enumerate() {
            let name = format!("{}#s{j}", op.name);
            let weights: Vec<TensorId> = op.weights.iter().map(|&t| b.map(t)).collect();
            if elide {
                let out = if j == k - 1 {
                    join_out
                } else {
                    b.slab(format!("{}#w{j}", op.name), vec![1, n], out_t.dtype, op.output)
                };
                let kind = OpKind::PartialInto {
                    inner: Box::new(OpKind::Dense { act }),
                    axis: SplitAxis::Channels,
                    pad: 0,
                    offset: band.start,
                    len: band.rows(),
                };
                let mut inputs = vec![cur];
                inputs.extend(acc);
                b.op(name, kind, inputs, weights, out);
                acc = Some(out);
            } else {
                let slab = b.slab(name.clone(), vec![1, band.rows()], out_t.dtype, op.output);
                b.op(
                    name,
                    OpKind::Partial {
                        inner: Box::new(OpKind::Dense { act }),
                        axis: SplitAxis::Channels,
                        pad: 0,
                        offset: band.start,
                    },
                    vec![cur],
                    weights,
                    slab,
                );
                slabs.push(slab);
            }
        }
        if !elide {
            b.op(
                format!("{}#cat", op.name),
                OpKind::ConcatSlices { axis: SplitAxis::Channels },
                slabs,
                vec![],
                join_out,
            );
        }
    }
    b.finish(g)
}

/// Apply a sequence of segment splits, composing tensor provenance back to
/// the original graph.
pub fn apply_plan(g: &Graph, plan: &SplitPlan) -> Result<SplitResult, SplitError> {
    let mut cur = SplitResult { graph: g.clone(), sources: (0..g.tensors.len()).collect() };
    for step in &plan.steps {
        let next = apply_segment(&cur.graph, step)?;
        let sources = next.sources.iter().map(|&mid| cur.sources[mid]).collect();
        cur = SplitResult { graph: next.graph, sources };
    }
    Ok(cur)
}

/// Carry a weight store across a split: weights keep their payloads,
/// activation slabs inherit the quantization parameters of the full tensor
/// they are a band of. (Channel slices address their weight-column band by
/// offset, so weight payloads are shared, not sliced.)
pub fn remap_weight_store(ws: &WeightStore, res: &SplitResult) -> WeightStore {
    remap_weights_by_sources(ws, &res.sources)
}

pub(crate) fn remap_weights_by_sources(ws: &WeightStore, sources: &[TensorId]) -> WeightStore {
    let mut out = WeightStore::default();
    for (new_id, &src) in sources.iter().enumerate() {
        if let Some(d) = ws.data.get(&src) {
            out.data.insert(new_id, d.clone());
        }
        if let Some(q) = ws.qparams.get(&src) {
            out.qparams.insert(new_id, *q);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Act, GraphBuilder, Padding};
    use crate::interp::{ExecConfig, Interpreter, TensorData};
    use crate::sched;

    fn chain_cnn() -> Graph {
        let mut b = GraphBuilder::new("chain-cnn");
        let x = b.input("x", &[1, 12, 12, 2], DType::F32);
        let c1 = b.conv2d("c1", x, 6, (3, 3), (1, 1), Padding::Same, Act::Relu6);
        let dw = b.dwconv2d("dw", c1, (3, 3), (2, 2), Padding::Same, Act::Relu6);
        let pw = b.conv2d("pw", dw, 4, (1, 1), (1, 1), Padding::Same, Act::Relu6);
        let gap = b.global_avgpool("gap", pw);
        let fc = b.dense("fc", gap, 3, Act::Linear);
        b.output(fc);
        b.finish().unwrap()
    }

    fn seg_of(g: &Graph, names: &[&str], factor: usize, axis: SplitAxis) -> SegmentSplit {
        SegmentSplit {
            ops: names.iter().map(|n| g.op_by_name(n).unwrap().id).collect(),
            factor,
            axis,
            elide: false,
        }
    }

    fn seg_elided(g: &Graph, names: &[&str], factor: usize, axis: SplitAxis) -> SegmentSplit {
        SegmentSplit { elide: true, ..seg_of(g, names, factor, axis) }
    }

    #[test]
    fn split_graph_is_valid_and_shapes_cover() {
        let g = chain_cnn();
        let res =
            apply_segment(&g, &seg_of(&g, &["c1", "dw", "pw"], 3, SplitAxis::Rows)).unwrap();
        let ng = &res.graph;
        ng.validate().unwrap();
        // 3 slices × 3 ops + join replace the 3 chain ops.
        assert_eq!(ng.n_ops(), g.n_ops() - 3 + 3 * 3 + 1);
        // The final output tensor survives with its name and full shape.
        let pw = ng.tensor_by_name("pw").unwrap();
        assert_eq!(pw.shape, vec![1, 6, 6, 4]);
        // Slice output rows of the last segment op partition the full rows.
        let rows: usize = (0..3)
            .map(|j| ng.tensor_by_name(&format!("pw#s{j}")).unwrap().shape[1])
            .sum();
        assert_eq!(rows, 6);
        // Default order of the rewritten graph stays topological.
        ng.check_order(&ng.default_order()).unwrap();
    }

    #[test]
    fn col_split_banding_is_mirrored() {
        let g = chain_cnn();
        let res =
            apply_segment(&g, &seg_of(&g, &["c1", "dw", "pw"], 3, SplitAxis::Cols)).unwrap();
        let ng = &res.graph;
        ng.validate().unwrap();
        // Slice output cols of the last segment op partition the full cols.
        let cols: usize = (0..3)
            .map(|j| ng.tensor_by_name(&format!("pw#s{j}")).unwrap().shape[2])
            .sum();
        assert_eq!(cols, 6);
        // Column slabs keep the full height.
        for j in 0..3 {
            assert_eq!(ng.tensor_by_name(&format!("c1#s{j}")).unwrap().shape[1], 12);
        }
    }

    #[test]
    fn channel_split_has_no_halo() {
        let g = chain_cnn();
        // c1 (Conv2D head) + dw (channel-parallel): 6 channels into 3.
        let res =
            apply_segment(&g, &seg_of(&g, &["c1", "dw"], 3, SplitAxis::Channels)).unwrap();
        let ng = &res.graph;
        ng.validate().unwrap();
        // Channel bands partition exactly — no halo, so the summed slice
        // MACs equal the unsplit MACs (zero recompute).
        assert_eq!(ng.total_macs(), g.total_macs());
        for j in 0..3 {
            assert_eq!(ng.tensor_by_name(&format!("c1#s{j}")).unwrap().shape[3], 2);
            assert_eq!(ng.tensor_by_name(&format!("dw#s{j}")).unwrap().shape[3], 2);
        }
    }

    fn assert_split_matches_f32(g: &Graph, seg: &SegmentSplit, seed: u64) {
        let ws = crate::interp::WeightStore::seeded_f32(g, seed);
        let n_in = g.tensors[g.inputs[0]].elems();
        let input =
            TensorData::F32((0..n_in).map(|i| ((i % 23) as f32 - 11.0) / 7.0).collect());
        let base = Interpreter::new(g, ws.clone(), ExecConfig::with_capacity(1 << 20))
            .run(&[input.clone()])
            .unwrap();
        let res = apply_segment(g, seg).unwrap();
        let ws2 = remap_weight_store(&ws, &res);
        let out = Interpreter::new(&res.graph, ws2, ExecConfig::with_capacity(1 << 20))
            .run(&[input])
            .unwrap();
        assert_eq!(base.outputs, out.outputs, "axis {:?}", seg.axis);
    }

    #[test]
    fn split_execution_matches_unsplit_f32() {
        let g = chain_cnn();
        for factor in [2, 3] {
            for axis in [SplitAxis::Rows, SplitAxis::Cols] {
                assert_split_matches_f32(&g, &seg_of(&g, &["c1", "dw", "pw"], factor, axis), 11);
                assert_split_matches_f32(
                    &g,
                    &seg_elided(&g, &["c1", "dw", "pw"], factor, axis),
                    11,
                );
            }
            assert_split_matches_f32(
                &g,
                &seg_of(&g, &["c1", "dw"], factor, SplitAxis::Channels),
                11,
            );
            assert_split_matches_f32(
                &g,
                &seg_elided(&g, &["c1", "dw"], factor, SplitAxis::Channels),
                11,
            );
        }
    }

    #[test]
    fn dense_split_matches_unsplit_f32() {
        let g = chain_cnn();
        assert_split_matches_f32(&g, &seg_of(&g, &["fc"], 3, SplitAxis::Channels), 5);
        assert_split_matches_f32(&g, &seg_elided(&g, &["fc"], 3, SplitAxis::Channels), 5);
    }

    /// Elided rewrite structure: no `ConcatSlices`, one write-through
    /// slice per band forming an accumulator chain that ends in the
    /// original join tensor, and the schedulers see the sharing.
    #[test]
    fn elided_split_builds_an_accumulator_chain() {
        let g = chain_cnn();
        let res =
            apply_segment(&g, &seg_elided(&g, &["c1", "dw", "pw"], 3, SplitAxis::Rows)).unwrap();
        let ng = &res.graph;
        ng.validate().unwrap();
        // 3 slices x 3 ops, no join, replace the 3 chain ops.
        assert_eq!(ng.n_ops(), g.n_ops() - 3 + 3 * 3);
        assert!(!ng.ops.iter().any(|o| matches!(o.kind, OpKind::ConcatSlices { .. })));
        // The write-through slices carry the full join shape and chain
        // through intermediate accumulators into the original tensor.
        let pw = ng.tensor_by_name("pw").unwrap();
        assert_eq!(pw.shape, vec![1, 6, 6, 4]);
        for j in 0..2 {
            let w = ng.tensor_by_name(&format!("pw#w{j}")).unwrap();
            assert_eq!(w.shape, pw.shape);
            assert_eq!(res.sources[w.id], g.tensor_by_name("pw").unwrap().id);
        }
        let mut lens = Vec::new();
        for op in &ng.ops {
            if let OpKind::PartialInto { len, axis, .. } = op.kind {
                assert_eq!(axis, SplitAxis::Rows);
                lens.push(len);
            }
        }
        assert_eq!(lens, vec![2, 2, 2], "three write-through bands partitioning 6 rows");
        // Structural in-place: slices 1 and 2 share their accumulator's
        // buffer; slice 0 allocates the join tensor.
        let accs = crate::sched::elided_accumulators(ng);
        assert_eq!(accs.iter().filter(|a| a.is_some()).count(), 2);
        ng.check_order(&ng.default_order()).unwrap();
    }

    /// On a join-dominated chain the elided form must beat the
    /// materialized form after reordering: the slabs never sit next to
    /// the join copy, so the 2×output floor at the join is gone.
    #[test]
    fn elided_join_breaks_the_two_x_output_floor() {
        let mut b = GraphBuilder::new("joiny");
        let x = b.input("x", &[1, 8, 8, 2], DType::I8);
        let c1 = b.conv2d("c1", x, 16, (3, 3), (1, 1), Padding::Same, Act::Relu6);
        let dw = b.dwconv2d("dw", c1, (3, 3), (1, 1), Padding::Same, Act::Relu6);
        b.output(dw);
        let g = b.finish().unwrap();
        let seg = seg_of(&g, &["c1", "dw"], 4, SplitAxis::Rows);
        let mat = apply_segment(&g, &seg).unwrap();
        let eli = apply_segment(&g, &SegmentSplit { elide: true, ..seg }).unwrap();
        let (mat_s, _) = sched::optimal(&mat.graph).unwrap();
        let (eli_s, _) = sched::optimal(&eli.graph).unwrap();
        let join_bytes = g.tensor_by_name("dw").unwrap().bytes();
        assert!(mat_s.peak_bytes >= 2 * join_bytes, "materialized pays the join floor");
        assert!(
            eli_s.peak_bytes < mat_s.peak_bytes,
            "elided {} vs materialized {}",
            eli_s.peak_bytes,
            mat_s.peak_bytes
        );
        assert!(
            eli_s.peak_bytes < 2 * join_bytes,
            "elided peak {} must undercut 2x join output {}",
            eli_s.peak_bytes,
            2 * join_bytes
        );
    }

    /// The elided slices are themselves split artifacts.
    #[test]
    fn elided_artifacts_cannot_be_resplit() {
        let g = chain_cnn();
        let res = apply_segment(&g, &seg_elided(&g, &["c1", "dw"], 2, SplitAxis::Rows)).unwrap();
        let slice = res.graph.op_by_name("dw#s0").unwrap().id;
        let e = apply_segment(
            &res.graph,
            &SegmentSplit { ops: vec![slice], factor: 2, axis: SplitAxis::Rows, elide: false },
        );
        assert!(e.is_err());
    }

    #[test]
    fn split_lowers_peak_on_a_fat_chain() {
        // A chain whose middle tensor dominates: splitting it must beat
        // reorder-only (which cannot help a pure chain at all).
        let mut b = GraphBuilder::new("fat");
        let x = b.input("x", &[1, 16, 16, 4], DType::I8);
        let c1 = b.conv2d("c1", x, 16, (3, 3), (1, 1), Padding::Same, Act::Relu6);
        let c2 = b.conv2d("c2", c1, 4, (3, 3), (2, 2), Padding::Same, Act::Relu6);
        b.output(c2);
        let g = b.finish().unwrap();
        let (base, _) = sched::optimal(&g).unwrap();
        let res = apply_segment(&g, &seg_of(&g, &["c1", "c2"], 4, SplitAxis::Rows)).unwrap();
        let (split_sched, _) = sched::optimal(&res.graph).unwrap();
        assert!(
            split_sched.peak_bytes < base.peak_bytes,
            "split {} vs reorder-only {}",
            split_sched.peak_bytes,
            base.peak_bytes
        );
    }

    #[test]
    fn rejects_bad_segments() {
        let g = chain_cnn();
        let rows = SplitAxis::Rows;
        // Not chained (c1 -> pw skips dw).
        assert!(apply_segment(&g, &seg_of(&g, &["c1", "pw"], 2, rows)).is_err());
        // Factor 1 is not a split.
        assert!(apply_segment(&g, &seg_of(&g, &["c1"], 1, rows)).is_err());
        // Factor exceeding output rows.
        assert!(apply_segment(&g, &seg_of(&g, &["dw"], 7, rows)).is_err());
        // Non-sliceable op.
        assert!(apply_segment(&g, &seg_of(&g, &["gap"], 2, rows)).is_err());
        // Dense must be single-op.
        assert!(apply_segment(&g, &seg_of(&g, &["gap", "fc"], 2, rows)).is_err());
        // Empty.
        assert!(apply_segment(
            &g,
            &SegmentSplit { ops: vec![], factor: 2, axis: rows, elide: false }
        )
        .is_err());
    }

    #[test]
    fn rejects_bad_channel_segments() {
        let g = chain_cnn();
        let chans = SplitAxis::Channels;
        // dw cannot head a channel split (needs an input channel offset).
        assert!(apply_segment(&g, &seg_of(&g, &["dw"], 2, chans)).is_err());
        // Conv2D (pw) cannot sit inside a channel chain.
        assert!(apply_segment(&g, &seg_of(&g, &["c1", "dw", "pw"], 2, chans)).is_err());
        // Factor exceeding the channel count.
        assert!(apply_segment(&g, &seg_of(&g, &["c1", "dw"], 7, chans)).is_err());
    }

    #[test]
    fn double_split_is_rejected() {
        let g = chain_cnn();
        let res = apply_segment(&g, &seg_of(&g, &["c1", "dw"], 2, SplitAxis::Rows)).unwrap();
        let ng = &res.graph;
        let slice = ng.op_by_name("c1#s0").unwrap().id;
        let e = apply_segment(
            ng,
            &SegmentSplit { ops: vec![slice], factor: 2, axis: SplitAxis::Rows, elide: false },
        );
        assert!(e.is_err());
    }

    #[test]
    fn plan_composes_sources_to_the_original_graph() {
        let g = chain_cnn();
        let plan = SplitPlan { steps: vec![seg_of(&g, &["c1", "dw"], 2, SplitAxis::Rows)] };
        let res = apply_plan(&g, &plan).unwrap();
        assert_eq!(res.sources.len(), res.graph.n_tensors());
        // Every slab of dw maps back to the original dw tensor.
        let old_dw = g.tensor_by_name("dw").unwrap().id;
        for j in 0..2 {
            let slab = res.graph.tensor_by_name(&format!("dw#s{j}")).unwrap();
            assert_eq!(res.sources[slab.id], old_dw);
        }
        // Untouched weights map to themselves by name.
        let old_w = g.tensor_by_name("pw.w").unwrap().id;
        let new_w = res.graph.tensor_by_name("pw.w").unwrap();
        assert_eq!(res.sources[new_w.id], old_w);
    }

    #[test]
    fn serde_roundtrips_split_graphs() {
        let g = chain_cnn();
        let segs = [
            seg_of(&g, &["c1", "dw", "pw"], 2, SplitAxis::Rows),
            seg_of(&g, &["c1", "dw", "pw"], 2, SplitAxis::Cols),
            seg_of(&g, &["c1", "dw"], 3, SplitAxis::Channels),
            seg_elided(&g, &["c1", "dw", "pw"], 2, SplitAxis::Rows),
            seg_elided(&g, &["c1", "dw"], 3, SplitAxis::Channels),
            seg_elided(&g, &["fc"], 3, SplitAxis::Channels),
        ];
        for seg in &segs {
            let res = apply_segment(&g, seg).unwrap();
            let mf = crate::graph::serde::ModelFile::new(res.graph.clone());
            let back = crate::graph::serde::ModelFile::from_json(&mf.to_json()).unwrap();
            assert_eq!(back.graph.n_ops(), res.graph.n_ops());
            for (a, b) in res.graph.ops.iter().zip(&back.graph.ops) {
                assert_eq!(a.kind, b.kind, "op {} ({:?})", a.name, seg.axis);
            }
            assert_eq!(
                sched::peak_of(&back.graph, &back.graph.default_order()),
                sched::peak_of(&res.graph, &res.graph.default_order())
            );
        }
    }
}

//! Partial-execution subsystem: operator splitting along rows, columns or
//! output channels, co-optimized with operator reordering.
//!
//! Operator reordering (§4 of the paper) cannot push peak SRAM below the
//! working set of the single largest operator — the input and output of
//! that operator must coexist. Partial execution breaks that floor: an
//! eligible operator chain is split along a [`crate::graph::SplitAxis`]
//! into `k` slice operators plus a [`crate::graph::OpKind::ConcatSlices`]
//! join — or, with streaming concat elision, into write-through slices
//! ([`crate::graph::OpKind::PartialInto`]) that stream each band directly
//! into the join tensor's buffer, so not even the join copy's 2×output
//! floor is paid — so only a band of the big intermediates is ever
//! resident. This is
//! the scheduling move behind Pex (Liberis & Lane, 2022), Unlu's
//! multi-axis layer splitting, and MCUNet's patch-based inference, and it
//! composes orthogonally with Algorithm 1: the split graph is an ordinary
//! [`crate::graph::Graph`], so [`crate::sched::optimal`] reorders the
//! slice pipelines for free.
//!
//! The three axes trade differently:
//!
//! - `Rows`/`Cols` slice the spatial extent. Windowed operators overlap at
//!   band boundaries (halo), so adjacent slices recompute the overlap and
//!   every slice re-reads the full weight tensor from flash.
//! - `Channels` slices the output-channel extent. Slices partition the
//!   work *and* the weight columns exactly — zero halo, zero recompute —
//!   but a regular `Conv2D` can only *head* a channel segment (it reads
//!   all input channels), so channel chains are shorter.
//!
//! The subsystem has three layers:
//!
//! - [`band`]-level geometry (internal): byte-exact per-slice index ranges
//!   with halo/overlap accounting for strided and kernelled operators.
//!   A slice's input band includes every real row/column its taps touch,
//!   and the slice op carries the *effective* padding for its slab, so
//!   slice outputs are bit-identical to the corresponding band of the
//!   unsplit operator (both f32 and int8 — validated in tests).
//! - [`apply_segment`] / [`apply_plan`] — graph rewriting: evaluate a
//!   single-consumer *chain* of operators in `k` slices. Splitting a
//!   chain rather than one operator is what makes the transform profitable:
//!   the chain's big intermediates are only ever materialized one band at a
//!   time, while the join only re-materializes the (smaller) chain output.
//!   [`remap_weight_store`] carries weights and quantization parameters
//!   onto the rewritten graph (slabs inherit the qparams of the tensor
//!   they are a band of).
//! - [`optimize`] — the `SplitPlan` search: a beam search over
//!   `(segment, factor, axis)` moves anchored at the current schedule's
//!   peak step, scoring each rewrite by re-running Algorithm 1 and pruning
//!   the beam by `(peak SRAM, recompute)` — see [`search`] module docs.
//!
//! Recompute overhead is not hidden: halo rows are re-evaluated by
//! adjacent slices, which shows up in [`crate::graph::Op::macs`] and
//! therefore in the [`crate::mcu::CostModel`] (see
//! [`crate::mcu::SplitOverhead`]).

mod band;
mod rewrite;
mod search;

pub use band::{partition, Band};
pub use rewrite::{
    apply_plan, apply_segment, remap_weight_store, SegmentSplit, SplitPlan, SplitResult,
};
pub use search::{
    candidate_moves, find_chains, find_chains_along, optimize, optimize_traced, EvalStrategy,
    PlannerStats, SplitOptions, SplitOutcome, SplitStep,
};

use crate::graph::SplitAxis;

/// Parse a `--axes` CLI spec: comma-separated axis names
/// (`rows|cols|channels`, with `h|w|c` aliases). Unknown, duplicate and
/// empty tokens are hard errors — a silently dropped token would quietly
/// shrink the planner's search space.
pub fn parse_axes(spec: &str) -> Result<Vec<SplitAxis>, String> {
    let mut axes: Vec<SplitAxis> = Vec::new();
    for part in spec.split(',') {
        let token = part.trim();
        if token.is_empty() {
            return Err(format!(
                "--axes {spec:?}: empty axis token (want rows|cols|channels)"
            ));
        }
        let axis = SplitAxis::from_name(token)
            .ok_or_else(|| format!("unknown axis {token:?} (rows|cols|channels)"))?;
        if axes.contains(&axis) {
            return Err(format!("duplicate axis {token:?} in --axes {spec:?}"));
        }
        axes.push(axis);
    }
    if axes.is_empty() {
        return Err("--axes needs at least one of rows|cols|channels".into());
    }
    Ok(axes)
}

/// Why a split could not be applied or searched.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SplitError {
    /// The segment is not a splittable chain (linkage, factor, shape…).
    InvalidSegment(String),
    /// An operator kind the splitter does not handle.
    Unsupported(String),
    /// The scheduler failed on the rewritten graph.
    Schedule(String),
}

impl std::fmt::Display for SplitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SplitError::InvalidSegment(m) => write!(f, "invalid split segment: {m}"),
            SplitError::Unsupported(m) => write!(f, "unsupported split: {m}"),
            SplitError::Schedule(m) => write!(f, "scheduling split graph failed: {m}"),
        }
    }
}

impl std::error::Error for SplitError {}

#[cfg(test)]
mod tests {
    use super::parse_axes;
    use crate::graph::SplitAxis;

    #[test]
    fn parse_axes_accepts_names_and_aliases() {
        assert_eq!(
            parse_axes("rows,cols,channels").unwrap(),
            vec![SplitAxis::Rows, SplitAxis::Cols, SplitAxis::Channels]
        );
        assert_eq!(parse_axes("h,w,c").unwrap(), SplitAxis::ALL.to_vec());
        assert_eq!(parse_axes(" rows , cols ").unwrap(), vec![SplitAxis::Rows, SplitAxis::Cols]);
        assert_eq!(parse_axes("channels").unwrap(), vec![SplitAxis::Channels]);
    }

    /// Regression (PR-4 satellite): unknown and duplicate tokens used to
    /// be silently ignored, quietly shrinking the search space.
    #[test]
    fn parse_axes_rejects_bad_tokens() {
        assert!(parse_axes("rows,bogus").unwrap_err().contains("unknown axis"));
        assert!(parse_axes("rows,rows").unwrap_err().contains("duplicate axis"));
        assert!(parse_axes("rows,h").unwrap_err().contains("duplicate axis"));
        assert!(parse_axes("rows,,cols").unwrap_err().contains("empty axis token"));
        assert!(parse_axes("rows,").unwrap_err().contains("empty axis token"));
        assert!(parse_axes("").unwrap_err().contains("empty axis token"));
    }
}

//! Band geometry along a split axis: which input slice a band needs
//! (halo/overlap accounting for the spatial axes) and the effective
//! padding its slab executes with.
//!
//! The invariant (cross-checked numerically in the interpreter tests):
//! executing an output band `[a, b)` along a spatial axis against an input
//! slab that starts at logical index `in_start` with effective padding
//! `pad_eff = pad_full − a·stride + in_start` takes *exactly* the taps the
//! full operator takes for those rows/columns — out-of-slab taps coincide
//! with the full operator's out-of-image (zero-padding) taps, because the
//! slab covers every real element the band touches.
//!
//! The channel axis has no tap geometry at all: a channel band of the
//! output maps 1:1 onto the same channel band of the input (depthwise
//! conv, pooling, pointwise) or onto a column band of the weight tensor
//! (a `Conv2D`/`Dense` projection head) — no halo, no recompute.

use crate::graph::{Graph, Op, OpKind, SplitAxis};
use crate::interp::ops::pad_amounts;

/// A contiguous index range `[start, end)` along the split axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Band {
    pub start: usize,
    pub end: usize,
}

impl Band {
    pub fn rows(&self) -> usize {
        self.end - self.start
    }
}

/// Partition `n` indices into `k` near-equal contiguous bands (the leading
/// `n % k` bands get the extra element). Requires `1 <= k <= n`.
pub fn partition(n: usize, k: usize) -> Vec<Band> {
    assert!((1..=n).contains(&k), "cannot partition {n} rows into {k} bands");
    let base = n / k;
    let rem = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0usize;
    for j in 0..k {
        let rows = base + usize::from(j < rem);
        out.push(Band { start, end: start + rows });
        start += rows;
    }
    out
}

/// Tap geometry of a sliceable operator along one split axis.
#[derive(Clone, Copy, Debug)]
pub(crate) enum SliceGeom {
    /// Elementwise along the axis: output index `j` reads input index `j`.
    Pointwise,
    /// Kernelled along a spatial axis: kernel extent, stride and the
    /// *full-geometry* leading padding (as the unsplit operator would
    /// compute it).
    Windowed { k: usize, stride: usize, pad: usize },
    /// Channel projection (`Conv2D` along `Channels`): reads its full
    /// input, writes an output-channel band via a weight-column band.
    /// Only valid at the head of a segment.
    ChanProject,
    /// Channel-parallel (depthwise conv, pooling, pointwise along
    /// `Channels`): channel band in = channel band out, weights/params
    /// banded by the same range.
    ChanParallel,
}

fn nhwc1(shape: &[usize]) -> bool {
    shape.len() == 4 && shape[0] == 1
}

/// Geometry of `op` along `axis`, or `None` if the operator cannot be
/// sliced that way (multi-input, non-spatial, or already a split
/// artifact).
pub(crate) fn slice_geom(g: &Graph, op: &Op, axis: SplitAxis) -> Option<SliceGeom> {
    if op.inputs.len() != 1 {
        return None;
    }
    let in_shape = &g.tensors[op.inputs[0]].shape;
    let out_shape = &g.tensors[op.output].shape;
    if !nhwc1(in_shape) || !nhwc1(out_shape) {
        return None;
    }
    if axis == SplitAxis::Channels {
        return match &op.kind {
            OpKind::Conv2D { .. } => Some(SliceGeom::ChanProject),
            OpKind::DepthwiseConv2D { .. }
            | OpKind::MaxPool2D { .. }
            | OpKind::AvgPool2D { .. }
            | OpKind::Relu
            | OpKind::Relu6
            | OpKind::BatchNorm { .. } => Some(SliceGeom::ChanParallel),
            _ => None,
        };
    }
    let d = axis.dim();
    let pick = |p: (usize, usize)| if axis == SplitAxis::Rows { p.0 } else { p.1 };
    match &op.kind {
        OpKind::Conv2D { kernel, stride, padding, .. }
        | OpKind::DepthwiseConv2D { kernel, stride, padding, .. }
        | OpKind::MaxPool2D { kernel, stride, padding }
        | OpKind::AvgPool2D { kernel, stride, padding } => Some(SliceGeom::Windowed {
            k: pick(*kernel),
            stride: pick(*stride),
            pad: pad_amounts(in_shape[d], pick(*kernel), pick(*stride), *padding, out_shape[d]),
        }),
        OpKind::Relu | OpKind::Relu6 | OpKind::BatchNorm { .. } => Some(SliceGeom::Pointwise),
        _ => None,
    }
}

/// Input band an output band `[out.start, out.end)` needs, clamped to the
/// real input extent `n_in` — taps falling outside are the full operator's
/// zero padding and stay implicit.
///
/// The clamp is a plain interval intersection of the band's tap range
/// `[out.start·stride − pad, (out.end−1)·stride + k − pad)` with the real
/// input `[0, n_in)`. When the receptive field falls *entirely* outside
/// the input (large kernel + small slice + SAME padding can do this), the
/// intersection is empty and the result is an explicit empty band anchored
/// at the nearest real index — the band is pad-only and needs no real
/// input. Earlier revisions clamped `lo` to `n_in − 1` and `hi` to at
/// least `lo + 1`, silently fabricating an inverted or 1-element band the
/// operator never reads; the rewriter now rejects pad-only bands
/// explicitly instead (see `apply_chain`).
pub(crate) fn in_band(geom: SliceGeom, n_in: usize, out: Band) -> Band {
    debug_assert!(out.end > out.start, "empty output band");
    match geom {
        // ChanProject only ever heads a segment (validated by the
        // rewriter), where the slab is the full input — its in-band is
        // never propagated.
        SliceGeom::Pointwise | SliceGeom::ChanParallel | SliceGeom::ChanProject => out,
        SliceGeom::Windowed { k, stride, pad } => {
            let lo_raw = (out.start * stride) as isize - pad as isize;
            let hi_raw = ((out.end - 1) * stride + k) as isize - pad as isize;
            // `hi_raw > lo_raw` always (the tap range spans at least `k`
            // elements), and clamping is monotone, so `hi >= lo`.
            let lo = lo_raw.clamp(0, n_in as isize) as usize;
            let hi = hi_raw.clamp(0, n_in as isize) as usize;
            Band { start: lo, end: hi }
        }
    }
}

/// Effective leading padding for computing an output band starting at
/// `out_start` against a slab whose first stored index is `in_start`.
/// Negative when the slab keeps elements above the band's first tap (the
/// chain head reads its full, unsliced input). Zero for non-windowed
/// geometry.
pub(crate) fn pad_eff(geom: SliceGeom, out_start: usize, in_start: usize) -> isize {
    match geom {
        SliceGeom::Windowed { stride, pad, .. } => {
            pad as isize + in_start as isize - (out_start * stride) as isize
        }
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Act, DType, GraphBuilder, Padding};

    #[test]
    fn partition_covers_exactly() {
        for (n, k) in [(7, 2), (48, 4), (5, 5), (10, 3)] {
            let bands = partition(n, k);
            assert_eq!(bands.len(), k);
            assert_eq!(bands[0].start, 0);
            assert_eq!(bands.last().unwrap().end, n);
            for w in bands.windows(2) {
                assert_eq!(w[0].end, w[1].start);
                assert!(w[0].rows() >= w[1].rows());
            }
        }
    }

    #[test]
    fn same_conv_band_includes_halo() {
        // 3x3 stride-1 SAME conv over 8 rows: pad = 1.
        let geom = SliceGeom::Windowed { k: 3, stride: 1, pad: 1 };
        // Top band [0,4): row 3's taps reach rows 2..5 → slab [0, 5).
        assert_eq!(in_band(geom, 8, Band { start: 0, end: 4 }), Band { start: 0, end: 5 });
        // Bottom band [4,8): taps reach rows 3..10 → slab [3, 8).
        assert_eq!(in_band(geom, 8, Band { start: 4, end: 8 }), Band { start: 3, end: 8 });
    }

    #[test]
    fn strided_conv_band() {
        // 3x3 stride-2 SAME over 8 rows → 4 out rows, pad total = 1, top 0.
        let geom = SliceGeom::Windowed { k: 3, stride: 2, pad: 0 };
        assert_eq!(in_band(geom, 8, Band { start: 0, end: 2 }), Band { start: 0, end: 5 });
        assert_eq!(in_band(geom, 8, Band { start: 2, end: 4 }), Band { start: 4, end: 8 });
    }

    /// Regression (PR-4 satellite): a kernel taller than the input with
    /// SAME padding. Every band's tap range must intersect-clamp against
    /// the real extent — no inverted or fabricated 1-element bands.
    #[test]
    fn tall_kernel_bands_clamp_to_real_extent() {
        // k=12 over 8 rows, stride 2, SAME: out 4, pad_total = 10, top 5.
        let geom = SliceGeom::Windowed { k: 12, stride: 2, pad: 5 };
        // Top band [0,2): taps -5..9 → real rows [0, 8) (k > n_in: the
        // slab is the whole input).
        assert_eq!(in_band(geom, 8, Band { start: 0, end: 2 }), Band { start: 0, end: 8 });
        // Bottom band [3,4): taps 1..13 → [1, 8).
        assert_eq!(in_band(geom, 8, Band { start: 3, end: 4 }), Band { start: 1, end: 8 });
        // k=7 over 2 rows, stride 1, SAME: out 2, pad_total = 5, top 2.
        let tiny = SliceGeom::Windowed { k: 7, stride: 1, pad: 2 };
        assert_eq!(in_band(tiny, 2, Band { start: 0, end: 1 }), Band { start: 0, end: 2 });
        assert_eq!(in_band(tiny, 2, Band { start: 1, end: 2 }), Band { start: 0, end: 2 });
    }

    /// A receptive field entirely inside the padding yields an explicit
    /// empty band (anchored at the nearest real index), not a fabricated
    /// 1-element band. Such geometry cannot arise from `pad_amounts`
    /// (leading pad <= k−1), but `in_band` must stay honest for any input
    /// — the rewriter turns the empty band into a clean error.
    #[test]
    fn pad_only_receptive_field_is_an_explicit_empty_band() {
        // All taps of out[0] fall in [-9, -2): before the input.
        let geom = SliceGeom::Windowed { k: 7, stride: 1, pad: 9 };
        let b = in_band(geom, 4, Band { start: 0, end: 1 });
        assert_eq!(b, Band { start: 0, end: 0 });
        assert_eq!(b.rows(), 0);
        // All taps of out[13] fall at rows 4..11, beyond the 4-row input:
        // anchored at n_in.
        let b = in_band(geom, 4, Band { start: 13, end: 14 });
        assert_eq!(b, Band { start: 4, end: 4 });
        assert_eq!(b.rows(), 0);
    }

    /// The clamp semantics hold on every axis: rows and cols share the
    /// windowed geometry (exercised above with asymmetric kernels via
    /// `slice_geom`); the channel axis has no taps, so a channel band is
    /// its own in-band even when a spatial kernel dwarfs the input.
    #[test]
    fn channel_bands_are_identity_even_with_tall_kernels() {
        for (n_in, band) in [(8usize, Band { start: 2, end: 5 }), (2, Band { start: 0, end: 2 })] {
            assert_eq!(in_band(SliceGeom::ChanParallel, n_in, band), band);
            assert_eq!(in_band(SliceGeom::Pointwise, n_in, band), band);
        }
    }

    #[test]
    fn pad_eff_signs() {
        let geom = SliceGeom::Windowed { k: 3, stride: 1, pad: 1 };
        // Top slice against its own slab: full padding preserved.
        assert_eq!(pad_eff(geom, 0, 0), 1);
        // Interior slice against its slab starting at its first tap row.
        assert_eq!(pad_eff(geom, 4, 3), 0);
        // Interior slice against the FULL input (chain head): negative.
        assert_eq!(pad_eff(geom, 4, 0), -3);
    }

    #[test]
    fn slice_geom_classifies_ops_along_rows() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 8, 8, 2], DType::F32);
        let c = b.conv2d("c", x, 4, (3, 3), (1, 1), Padding::Same, Act::Linear);
        let r = b.relu("r", c);
        let gap = b.global_avgpool("gap", r);
        let fc = b.dense("fc", gap, 2, Act::Linear);
        b.output(fc);
        let g = b.finish().unwrap();
        assert!(matches!(
            slice_geom(&g, g.op_by_name("c").unwrap(), SplitAxis::Rows),
            Some(SliceGeom::Windowed { k: 3, stride: 1, pad: 1 })
        ));
        assert!(matches!(
            slice_geom(&g, g.op_by_name("r").unwrap(), SplitAxis::Rows),
            Some(SliceGeom::Pointwise)
        ));
        assert!(slice_geom(&g, g.op_by_name("gap").unwrap(), SplitAxis::Rows).is_none());
        assert!(slice_geom(&g, g.op_by_name("fc").unwrap(), SplitAxis::Rows).is_none());
    }

    #[test]
    fn slice_geom_uses_horizontal_geometry_along_cols() {
        // Asymmetric kernel/stride: rows see (5, 1), cols see (3, 2).
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 12, 8, 2], DType::F32);
        let c = b.conv2d("c", x, 4, (5, 3), (1, 2), Padding::Same, Act::Linear);
        b.output(c);
        let g = b.finish().unwrap();
        let op = g.op_by_name("c").unwrap();
        assert!(matches!(
            slice_geom(&g, op, SplitAxis::Rows),
            Some(SliceGeom::Windowed { k: 5, stride: 1, pad: 2 })
        ));
        // SAME over W=8, kw=3, sw=2 → out 4, total pad = 1, low 0.
        assert!(matches!(
            slice_geom(&g, op, SplitAxis::Cols),
            Some(SliceGeom::Windowed { k: 3, stride: 2, pad: 0 })
        ));
    }

    #[test]
    fn slice_geom_classifies_channel_axis() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 8, 8, 4], DType::F32);
        let c = b.conv2d("c", x, 8, (3, 3), (1, 1), Padding::Same, Act::Relu6);
        let d = b.dwconv2d("d", c, (3, 3), (2, 2), Padding::Same, Act::Relu6);
        let m = b.maxpool("m", d, (2, 2), (2, 2), Padding::Valid);
        let gap = b.global_avgpool("gap", m);
        b.output(gap);
        let g = b.finish().unwrap();
        let geom = |n: &str| slice_geom(&g, g.op_by_name(n).unwrap(), SplitAxis::Channels);
        assert!(matches!(geom("c"), Some(SliceGeom::ChanProject)));
        assert!(matches!(geom("d"), Some(SliceGeom::ChanParallel)));
        assert!(matches!(geom("m"), Some(SliceGeom::ChanParallel)));
        assert!(geom("gap").is_none());
    }
}

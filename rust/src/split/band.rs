//! Row-band geometry: which input rows a slice needs (halo/overlap
//! accounting) and the effective padding its slab executes with.
//!
//! The invariant (cross-checked numerically in the interpreter tests):
//! executing an output band `[a, b)` against an input slab that starts at
//! logical row `in_start` with vertical padding
//! `pad_eff = pad_full − a·stride + in_start` takes *exactly* the taps the
//! full operator takes for those rows — out-of-slab taps coincide with the
//! full operator's out-of-image (zero-padding) taps, because the slab
//! covers every real row the band touches.

use crate::graph::{Graph, Op, OpKind};
use crate::interp::ops::pad_amounts;

/// A contiguous row range `[start, end)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Band {
    pub start: usize,
    pub end: usize,
}

impl Band {
    pub fn rows(&self) -> usize {
        self.end - self.start
    }
}

/// Partition `n` rows into `k` near-equal contiguous bands (the leading
/// `n % k` bands get the extra row). Requires `1 <= k <= n`.
pub fn partition(n: usize, k: usize) -> Vec<Band> {
    assert!(k >= 1 && k <= n, "cannot partition {n} rows into {k} bands");
    let base = n / k;
    let rem = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0usize;
    for j in 0..k {
        let rows = base + usize::from(j < rem);
        out.push(Band { start, end: start + rows });
        start += rows;
    }
    out
}

/// Vertical tap geometry of a sliceable operator.
#[derive(Clone, Copy, Debug)]
pub(crate) enum VertGeom {
    /// Elementwise: output row `j` reads input row `j`.
    Pointwise,
    /// Kernelled: kernel height, row stride and the *full-geometry* top
    /// padding (as the unsplit operator would compute it).
    Windowed { kh: usize, stride: usize, pad: usize },
}

fn nhwc1(shape: &[usize]) -> bool {
    shape.len() == 4 && shape[0] == 1
}

/// Vertical geometry of `op`, or `None` if the operator cannot be sliced
/// along rows (multi-input, non-spatial, or already a split artifact).
pub(crate) fn vert_geom(g: &Graph, op: &Op) -> Option<VertGeom> {
    if op.inputs.len() != 1 {
        return None;
    }
    let in_shape = &g.tensors[op.inputs[0]].shape;
    let out_shape = &g.tensors[op.output].shape;
    if !nhwc1(in_shape) || !nhwc1(out_shape) {
        return None;
    }
    match &op.kind {
        OpKind::Conv2D { kernel, stride, padding, .. }
        | OpKind::DepthwiseConv2D { kernel, stride, padding, .. } => Some(VertGeom::Windowed {
            kh: kernel.0,
            stride: stride.0,
            pad: pad_amounts(in_shape[1], kernel.0, stride.0, *padding, out_shape[1]),
        }),
        OpKind::MaxPool2D { kernel, stride, padding }
        | OpKind::AvgPool2D { kernel, stride, padding } => Some(VertGeom::Windowed {
            kh: kernel.0,
            stride: stride.0,
            pad: pad_amounts(in_shape[1], kernel.0, stride.0, *padding, out_shape[1]),
        }),
        OpKind::Relu | OpKind::Relu6 | OpKind::BatchNorm { .. } => Some(VertGeom::Pointwise),
        _ => None,
    }
}

/// Input rows an output band `[out.start, out.end)` needs, clamped to the
/// real input — taps falling outside are the full operator's zero padding
/// and stay implicit.
pub(crate) fn in_band(geom: VertGeom, h_in: usize, out: Band) -> Band {
    debug_assert!(out.end > out.start, "empty output band");
    match geom {
        VertGeom::Pointwise => out,
        VertGeom::Windowed { kh, stride, pad } => {
            let lo = ((out.start * stride) as isize - pad as isize).max(0) as usize;
            let lo = lo.min(h_in.saturating_sub(1));
            let hi_raw = ((out.end - 1) * stride + kh) as isize - pad as isize;
            let mut hi = hi_raw.clamp(1, h_in as isize) as usize;
            if hi <= lo {
                hi = lo + 1;
            }
            Band { start: lo, end: hi }
        }
    }
}

/// Effective vertical padding for computing output rows starting at
/// `out_start` against a slab whose first stored row is logical row
/// `in_start`. Negative when the slab keeps rows above the band's first
/// tap (the chain head reads its full, unsliced input).
pub(crate) fn pad_eff(geom: VertGeom, out_start: usize, in_start: usize) -> isize {
    match geom {
        VertGeom::Pointwise => 0,
        VertGeom::Windowed { stride, pad, .. } => {
            pad as isize + in_start as isize - (out_start * stride) as isize
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Act, DType, GraphBuilder, Padding};

    #[test]
    fn partition_covers_exactly() {
        for (n, k) in [(7, 2), (48, 4), (5, 5), (10, 3)] {
            let bands = partition(n, k);
            assert_eq!(bands.len(), k);
            assert_eq!(bands[0].start, 0);
            assert_eq!(bands.last().unwrap().end, n);
            for w in bands.windows(2) {
                assert_eq!(w[0].end, w[1].start);
                assert!(w[0].rows() >= w[1].rows());
            }
        }
    }

    #[test]
    fn same_conv_band_includes_halo() {
        // 3x3 stride-1 SAME conv over 8 rows: pad = 1.
        let geom = VertGeom::Windowed { kh: 3, stride: 1, pad: 1 };
        // Top band [0,4): row 3's taps reach rows 2..5 → slab [0, 5).
        assert_eq!(in_band(geom, 8, Band { start: 0, end: 4 }), Band { start: 0, end: 5 });
        // Bottom band [4,8): taps reach rows 3..10 → slab [3, 8).
        assert_eq!(in_band(geom, 8, Band { start: 4, end: 8 }), Band { start: 3, end: 8 });
    }

    #[test]
    fn strided_conv_band() {
        // 3x3 stride-2 SAME over 8 rows → 4 out rows, pad total = 1, top 0.
        let geom = VertGeom::Windowed { kh: 3, stride: 2, pad: 0 };
        assert_eq!(in_band(geom, 8, Band { start: 0, end: 2 }), Band { start: 0, end: 5 });
        assert_eq!(in_band(geom, 8, Band { start: 2, end: 4 }), Band { start: 4, end: 8 });
    }

    #[test]
    fn pad_eff_signs() {
        let geom = VertGeom::Windowed { kh: 3, stride: 1, pad: 1 };
        // Top slice against its own slab: full padding preserved.
        assert_eq!(pad_eff(geom, 0, 0), 1);
        // Interior slice against its slab starting at its first tap row.
        assert_eq!(pad_eff(geom, 4, 3), 0);
        // Interior slice against the FULL input (chain head): negative.
        assert_eq!(pad_eff(geom, 4, 0), -3);
    }

    #[test]
    fn vert_geom_classifies_ops() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 8, 8, 2], DType::F32);
        let c = b.conv2d("c", x, 4, (3, 3), (1, 1), Padding::Same, Act::Linear);
        let r = b.relu("r", c);
        let gap = b.global_avgpool("gap", r);
        let fc = b.dense("fc", gap, 2, Act::Linear);
        b.output(fc);
        let g = b.finish().unwrap();
        assert!(matches!(
            vert_geom(&g, g.op_by_name("c").unwrap()),
            Some(VertGeom::Windowed { kh: 3, stride: 1, pad: 1 })
        ));
        assert!(matches!(vert_geom(&g, g.op_by_name("r").unwrap()), Some(VertGeom::Pointwise)));
        assert!(vert_geom(&g, g.op_by_name("gap").unwrap()).is_none());
        assert!(vert_geom(&g, g.op_by_name("fc").unwrap()).is_none());
    }
}

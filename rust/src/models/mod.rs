//! Model zoo — the paper's evaluated networks plus synthetic generators.
//!
//! - [`figure1`] — the 7-operator example graph of Figure 1 with its exact
//!   byte sizes (the Appendix A tables are regenerated from it).
//! - [`mobilenet_v1_025`] — MobileNet-v1, width 0.25, 96×96×1 input, the
//!   TFLM person-detection model used in Table 1's right half. With int8
//!   tensors its activation total is 241.0 KB (paper: 241KB static
//!   allocation) and its peak working set is 55.3 KB (paper: 55KB) —
//!   the architecture is public, so these reproduce from first principles.
//! - [`swiftnet_cell`] — a SwiftNet-style branch-heavy NAS-cell network.
//!   The exact SwiftNet Cell architecture was never published (the paper
//!   cites the VWW contest submission repo), so this is a reconstruction
//!   calibrated to the published characteristics: ~250KB int8 parameters,
//!   many branched cells, default-order peak ≈351KB and optimal-order peak
//!   ≈301KB (see DESIGN.md substitution ledger).
//! - [`audionet`] — a keyword-spotting-style audio CNN whose tall-kernel
//!   front block makes the channel split axis strictly better than rows
//!   (the split planner's multi-axis showcase).
//! - [`streamnet`] — a streaming-vision front block whose fat stride-1
//!   stack leaves every materialized split plan stuck at the 2×output
//!   join floor; only streaming concat elision improves it (the
//!   join-elision showcase).
//! - [`tiny_cnn`] — a small branchy CNN for quickstarts and fast tests.
//! - [`synth`] — random DAG generators for property tests and the
//!   scheduler-scaling ablation.

pub mod synth;

use crate::graph::{Act, DType, Graph, GraphBuilder, Padding, TensorId};

/// The Figure-1 example computation graph (sizes in bytes, derived from the
/// Appendix A working-set tables; tensors are 1-D u8 so `bytes == elems`).
pub fn figure1() -> Graph {
    let mut b = GraphBuilder::new("figure1");
    let t0 = b.input("t0", &[1568], DType::U8);
    let t1 = b.synthetic("op1", &[t0], 3136, 0);
    let t2 = b.synthetic("op2", &[t1], 1568, 0);
    let t3 = b.synthetic("op3", &[t2], 512, 0);
    let t4 = b.synthetic("op4", &[t1], 512, 0);
    let t5 = b.synthetic("op5", &[t3], 256, 0);
    let t6 = b.synthetic("op6", &[t4], 256, 0);
    let t7 = b.synthetic("op7", &[t5, t6], 512, 0);
    b.output(t7);
    b.finish().expect("figure1 graph is valid")
}

/// MobileNet-v1 (width multiplier 0.25) person-detection network:
/// 96×96×1 input, 28 fused conv ops, global pool, 2-class head.
pub fn mobilenet_v1_025(dtype: DType) -> Graph {
    let mut b = GraphBuilder::new("mobilenet-v1-0.25-96");
    let x = b.input("input", &[1, 96, 96, 1], dtype);
    let mut t = b.conv2d("conv1", x, 8, (3, 3), (2, 2), Padding::Same, Act::Relu6);
    // (stride of the depthwise conv, output channels of the pointwise conv)
    let blocks: [(usize, usize); 13] = [
        (1, 16),
        (2, 32),
        (1, 32),
        (2, 64),
        (1, 64),
        (2, 128),
        (1, 128),
        (1, 128),
        (1, 128),
        (1, 128),
        (1, 128),
        (2, 256),
        (1, 256),
    ];
    for (i, &(s, cout)) in blocks.iter().enumerate() {
        let n = i + 1;
        t = b.dwconv2d(&format!("dw{n}"), t, (3, 3), (s, s), Padding::Same, Act::Relu6);
        t = b.conv2d(&format!("pw{n}"), t, cout, (1, 1), (1, 1), Padding::Same, Act::Relu6);
    }
    let gap = b.global_avgpool("gap", t);
    let fc = b.dense("fc", gap, 2, Act::Linear);
    let sm = b.softmax("softmax", fc);
    b.output(sm);
    b.finish().expect("mobilenet graph is valid")
}

/// One SwiftNet-style cell: two asymmetric branches over a shared input,
/// joined by a concat (the Figure-1 motif at scale).
///
/// ```text
///        ┌─ conv1x1(ca_mid) ─ dw3x3 ─ conv1x1(ca_out) ─┐
///   X ───┤                                             concat
///        └─ dw3x3 ─ conv1x1(cb_out) ──────────────────┘
/// ```
///
/// Branch A expands (`ca_mid > C_x`), so while it runs the big shared input
/// must be held for branch B under the as-built order; evaluating B first
/// trades that for the much smaller `cb_out` tensor — exactly the
/// reordering opportunity the paper exploits.
fn swift_cell(
    b: &mut GraphBuilder,
    name: &str,
    x: TensorId,
    ca_mid: usize,
    ca_out: usize,
    cb_out: usize,
) -> TensorId {
    let a1 = b.conv2d(&format!("{name}.a1"), x, ca_mid, (1, 1), (1, 1), Padding::Same, Act::Relu6);
    let a2 = b.dwconv2d(&format!("{name}.a2"), a1, (3, 3), (1, 1), Padding::Same, Act::Relu6);
    let a3 = b.conv2d(&format!("{name}.a3"), a2, ca_out, (1, 1), (1, 1), Padding::Same, Act::Relu6);
    let b1 = b.dwconv2d(&format!("{name}.b1"), x, (3, 3), (1, 1), Padding::Same, Act::Relu6);
    let b2 = b.conv2d(&format!("{name}.b2"), b1, cb_out, (1, 1), (1, 1), Padding::Same, Act::Relu6);
    b.concat(&format!("{name}.cat"), &[a3, b2])
}

/// Strided transition between cell stages: dw3x3 s2 + pointwise.
fn swift_transition(b: &mut GraphBuilder, name: &str, x: TensorId, cout: usize) -> TensorId {
    let d = b.dwconv2d(&format!("{name}.dw"), x, (3, 3), (2, 2), Padding::Same, Act::Relu6);
    b.conv2d(&format!("{name}.pw"), d, cout, (1, 1), (1, 1), Padding::Same, Act::Relu6)
}

/// SwiftNet-style cell network (reconstruction; see module docs).
/// Input 96×96×3 RGB, 2-class visual-wake-words head.
pub fn swiftnet_cell(dtype: DType) -> Graph {
    let mut b = GraphBuilder::new("swiftnet-cell");
    let x = b.input("input", &[1, 96, 96, 3], dtype);
    // Stem: 96×96×3 → 48×48×32.
    let stem = b.conv2d("stem", x, 32, (3, 3), (2, 2), Padding::Same, Act::Relu6);
    // Stage 1 (48×48): the memory bottleneck. Branch A expands 32→60
    // channels; its working set dominates the whole network.
    let c1 = swift_cell(&mut b, "c1", stem, 60, 40, 12); // 48×48×52
    let t1 = swift_transition(&mut b, "t1", c1, 64); // 24×24×64
    // Stage 2 (24×24): two cells.
    let c2 = swift_cell(&mut b, "c2", t1, 96, 64, 32); // 24×24×96
    let c3 = swift_cell(&mut b, "c3", c2, 96, 64, 32); // 24×24×96
    let t2 = swift_transition(&mut b, "t2", c3, 128); // 12×12×128
    // Stage 3 (12×12): three cells.
    let c4 = swift_cell(&mut b, "c4", t2, 96, 96, 32); // 12×12×128
    let c5 = swift_cell(&mut b, "c5", c4, 96, 96, 32);
    let c6 = swift_cell(&mut b, "c6", c5, 96, 96, 32);
    let t3 = swift_transition(&mut b, "t3", c6, 192); // 6×6×192
    // Stage 4 (6×6): parameter-heavy pointwise tail (this is where most of
    // the ~250KB of weights live, as in compact NAS models).
    let c7 = swift_cell(&mut b, "c7", t3, 160, 128, 64); // 6×6×192
    let p1 = b.conv2d("tail1", c7, 160, (1, 1), (1, 1), Padding::Same, Act::Relu6);
    let gap = b.global_avgpool("gap", p1);
    let fc = b.dense("fc", gap, 2, Act::Linear);
    let sm = b.softmax("softmax", fc);
    b.output(sm);
    b.finish().expect("swiftnet graph is valid")
}

/// Micro residual network (ResNet-style): three stages of residual blocks
/// with skip-connection `Add` ops — the §6 in-place-accumulation extension's
/// showcase (an `Add` whose skip input has no other consumer can accumulate
/// into it, eliminating the output buffer).
pub fn resnet_micro(dtype: DType) -> Graph {
    let mut b = GraphBuilder::new("resnet-micro");
    let x = b.input("input", &[1, 32, 32, 3], dtype);
    let mut t = b.conv2d("stem", x, 16, (3, 3), (1, 1), Padding::Same, Act::Relu);
    for (stage, &(c, stride)) in [(16usize, 1usize), (32, 2), (64, 2)].iter().enumerate() {
        // Downsample (and widen) at stage entry.
        if stride > 1 || c != 16 {
            t = b.conv2d(
                &format!("s{stage}.down"),
                t,
                c,
                (1, 1),
                (stride, stride),
                Padding::Same,
                Act::Linear,
            );
        }
        for blk in 0..2 {
            // Bottleneck residual block: the inner 3×3 runs at c/2
            // channels, so the skip-join `Add` step (skip + branch output +
            // sum) is the block's memory bottleneck — exactly where
            // in-place accumulation pays.
            let name = format!("s{stage}.b{blk}");
            let c1 =
                b.conv2d(&format!("{name}.c1"), t, c / 2, (3, 3), (1, 1), Padding::Same, Act::Relu);
            let c2 = b.conv2d(
                &format!("{name}.c2"),
                c1,
                c,
                (3, 3),
                (1, 1),
                Padding::Same,
                Act::Linear,
            );
            t = b.add(&format!("{name}.add"), c2, t);
        }
    }
    let gap = b.global_avgpool("gap", t);
    let fc = b.dense("fc", gap, 10, Act::Linear);
    let sm = b.softmax("softmax", fc);
    b.output(sm);
    b.finish().expect("resnet graph is valid")
}

/// Keyword-spotting-style audio CNN over a time×frequency input
/// (64 frames × 16 mel bins × 4 channels). The front block is the
/// classic DS-CNN shape: a channel-expanding conv with a tall temporal
/// kernel, a tall-kernel strided depthwise aggregation, and a pooled
/// transition. That geometry is the split planner's channel-axis
/// showcase: the fat `c1` intermediate is consumed by a 12×3 depthwise,
/// so row slabs carry a 10-row halo per slice while channel slabs carry
/// none — a channel-axis plan beats every row-only plan on peak SRAM
/// *and* pays zero recompute (see `benches/partial_exec.rs`).
pub fn audionet(dtype: DType) -> Graph {
    let mut b = GraphBuilder::new("audionet");
    let x = b.input("input", &[1, 64, 16, 4], dtype);
    let c1 = b.conv2d("c1", x, 32, (8, 3), (1, 1), Padding::Same, Act::Relu6);
    let d1 = b.dwconv2d("d1", c1, (12, 3), (2, 2), Padding::Same, Act::Relu6);
    let m1 = b.maxpool("m1", d1, (2, 2), (2, 2), Padding::Valid);
    let p1 = b.conv2d("p1", m1, 32, (1, 1), (1, 1), Padding::Same, Act::Relu6);
    let d2 = b.dwconv2d("d2", p1, (3, 3), (1, 1), Padding::Same, Act::Relu6);
    let p2 = b.conv2d("p2", d2, 32, (1, 1), (1, 1), Padding::Same, Act::Relu6);
    let gap = b.global_avgpool("gap", p2);
    let fc = b.dense("fc", gap, 4, Act::Linear);
    let sm = b.softmax("softmax", fc);
    b.output(sm);
    b.finish().expect("audionet graph is valid")
}

/// Streaming-vision front block: a cheap 2-channel input feeding a wide
/// stride-1 conv → depthwise stack that is pooled globally right after —
/// the streaming-concat-elision showcase. The whole network is a pure
/// chain whose two fat stride-1 tensors (`c1`, `d1`, 32 KB each at int8)
/// must coexist, so reordering saves nothing, and every *materialized*
/// split plan is stuck at the same floor: any segment's join output is
/// 32 KB, so `ConcatSlices` pays slabs + join = 2×32 KB — exactly the
/// reorder-only peak. Only join elision breaks the floor: write-through
/// channel slices stream `d1` into its buffer band by band (zero halo,
/// zero recompute), dropping the peak to input + one `c1` slab + the
/// join buffer (−34% with factor 4). Asserted in tests and tracked in
/// `benches/partial_exec.rs`.
pub fn streamnet(dtype: DType) -> Graph {
    let mut b = GraphBuilder::new("streamnet");
    let x = b.input("input", &[1, 32, 32, 2], dtype);
    let c1 = b.conv2d("c1", x, 32, (3, 3), (1, 1), Padding::Same, Act::Relu6);
    let d1 = b.dwconv2d("d1", c1, (3, 3), (1, 1), Padding::Same, Act::Relu6);
    let gap = b.global_avgpool("gap", d1);
    let fc = b.dense("fc", gap, 4, Act::Linear);
    let sm = b.softmax("softmax", fc);
    b.output(sm);
    b.finish().expect("streamnet graph is valid")
}

/// Small branchy CNN for quickstarts and fast integration tests
/// (8×8×2 input, one two-way branch, 3-class head).
pub fn tiny_cnn(dtype: DType) -> Graph {
    let mut b = GraphBuilder::new("tiny-cnn");
    let x = b.input("x", &[1, 8, 8, 2], dtype);
    let c1 = b.conv2d("c1", x, 4, (3, 3), (1, 1), Padding::Same, Act::Relu6);
    let dw = b.dwconv2d("dw", c1, (3, 3), (2, 2), Padding::Same, Act::Relu6);
    let pw = b.conv2d("pw", c1, 4, (1, 1), (2, 2), Padding::Same, Act::Relu6);
    let cat = b.concat("cat", &[dw, pw]);
    let gap = b.global_avgpool("gap", cat);
    let fc = b.dense("fc", gap, 3, Act::Linear);
    let sm = b.softmax("softmax", fc);
    b.output(sm);
    b.finish().expect("tiny graph is valid")
}

/// Every named model (CLI listing).
pub fn by_name(name: &str, dtype: DType) -> Option<Graph> {
    match name {
        "figure1" => Some(figure1()),
        "mobilenet" | "mobilenet-v1-0.25-96" => Some(mobilenet_v1_025(dtype)),
        "swiftnet" | "swiftnet-cell" => Some(swiftnet_cell(dtype)),
        "resnet" | "resnet-micro" => Some(resnet_micro(dtype)),
        "audionet" => Some(audionet(dtype)),
        "streamnet" => Some(streamnet(dtype)),
        "tiny" | "tiny-cnn" => Some(tiny_cnn(dtype)),
        _ => None,
    }
}

/// Names accepted by [`by_name`].
pub const MODEL_NAMES: [&str; 7] =
    ["figure1", "mobilenet", "swiftnet", "resnet", "audionet", "streamnet", "tiny"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{optimal, peak_of, simulate};

    #[test]
    fn figure1_reproduces_paper_peaks() {
        let g = figure1();
        assert_eq!(simulate(&g, &g.default_order()).peak_bytes, 5216);
        let (sched, _) = optimal(&g).unwrap();
        assert_eq!(sched.peak_bytes, 4960);
    }

    #[test]
    fn mobilenet_reproduces_table1_memory_numbers() {
        let g = mobilenet_v1_025(DType::I8);
        // Paper Table 1 (KB = 1000 B): static 241KB, dynamic 55KB.
        let static_bytes = g.activation_total();
        assert_eq!(static_bytes, 241_028, "static allocation (sum of activations)");
        let peak = peak_of(&g, &g.default_order());
        assert_eq!(peak, 55_296, "dynamic allocation (peak working set)");
        // The saving the paper reports: 186KB (241 − 55 in rounded KB).
        let kb = |b: usize| (b as f64 / 1000.0).round() as i64;
        assert_eq!(kb(static_bytes) - kb(peak), 186);
    }

    #[test]
    fn mobilenet_is_sequential_so_reordering_cannot_help() {
        let g = mobilenet_v1_025(DType::I8);
        let (sched, _) = optimal(&g).unwrap();
        assert_eq!(sched.peak_bytes, peak_of(&g, &g.default_order()));
    }

    #[test]
    fn mobilenet_shape_chain() {
        let g = mobilenet_v1_025(DType::I8);
        assert_eq!(g.tensor_by_name("conv1").unwrap().shape, vec![1, 48, 48, 8]);
        assert_eq!(g.tensor_by_name("pw1").unwrap().shape, vec![1, 48, 48, 16]);
        assert_eq!(g.tensor_by_name("pw13").unwrap().shape, vec![1, 3, 3, 256]);
        assert_eq!(g.tensor_by_name("softmax").unwrap().shape, vec![1, 2]);
        assert_eq!(g.n_ops(), 30);
    }

    #[test]
    fn mobilenet_macs_in_expected_range() {
        // MobileNet-0.25 @96 grayscale ≈ 7–8 M MACs.
        let g = mobilenet_v1_025(DType::I8);
        let m = g.total_macs();
        assert!((5_000_000..12_000_000).contains(&m), "macs = {m}");
    }

    #[test]
    fn swiftnet_reproduces_table1_shape() {
        let g = swiftnet_cell(DType::I8);
        let default_peak = peak_of(&g, &g.default_order());
        let (sched, _) = optimal(&g).unwrap();
        // Paper: 351KB default → 301KB optimal (KB = 1000 B). The exact
        // architecture is reconstructed, so we assert the calibrated
        // targets of this reconstruction and the ~50KB saving.
        assert_eq!(default_peak, 350_208);
        assert_eq!(sched.peak_bytes, 304_128);
        let saving_kb = (default_peak - sched.peak_bytes) / 1000;
        assert!((40..60).contains(&saving_kb), "saving = {saving_kb}KB");
    }

    #[test]
    fn swiftnet_has_about_250kb_of_parameters() {
        let g = swiftnet_cell(DType::I8);
        let kb = g.model_size() / 1000;
        assert!((220..290).contains(&kb), "params = {kb}KB");
    }

    #[test]
    fn swiftnet_is_branchy() {
        let g = swiftnet_cell(DType::I8);
        let branch_points = g
            .tensors
            .iter()
            .filter(|t| !t.is_weight)
            .filter(|t| {
                t.consumers.iter().filter(|&&c| g.ops[c].inputs.contains(&t.id)).count() > 1
            })
            .count();
        assert!(branch_points >= 6, "branch points = {branch_points}");
    }

    #[test]
    fn zoo_graphs_validate_and_roundtrip() {
        for name in MODEL_NAMES {
            let g = by_name(name, DType::I8).unwrap();
            g.validate().unwrap();
            let mf = crate::graph::serde::ModelFile::new(g.clone());
            let back = crate::graph::serde::ModelFile::from_json(&mf.to_json()).unwrap();
            assert_eq!(back.graph.n_ops(), g.n_ops(), "{name}");
            assert_eq!(back.graph.activation_total(), g.activation_total(), "{name}");
        }
    }

    #[test]
    fn resnet_inplace_add_saves_memory() {
        use crate::sched::{self, Opts};
        let g = resnet_micro(DType::I8);
        let base = sched::peak_of(&g, &g.default_order());
        let inplace = sched::peak_of_opts(&g, &g.default_order(), Opts::INPLACE);
        assert!(inplace < base, "in-place add must shrink the peak ({base} → {inplace})");
        // Every residual Add is eligible (skip inputs have one consumer).
        let accs = sched::inplace_accumulators(&g);
        let eligible = accs.iter().filter(|a| a.is_some()).count();
        assert_eq!(eligible, 6);
    }

    #[test]
    fn resnet_optimal_inplace_is_optimal_and_no_worse() {
        use crate::sched::{self, Opts};
        let g = resnet_micro(DType::I8);
        let (plain, _) = sched::optimal(&g).unwrap();
        let (inp, _) = sched::optimal_opts(&g, Opts::INPLACE).unwrap();
        assert!(inp.peak_bytes <= plain.peak_bytes);
        assert_eq!(inp.peak_bytes, sched::peak_of_opts(&g, &inp.order, Opts::INPLACE));
    }

    #[test]
    fn by_name_rejects_unknown() {
        assert!(by_name("resnet152", DType::I8).is_none());
    }

    #[test]
    fn streamnet_shapes_and_floor() {
        let g = streamnet(DType::I8);
        assert_eq!(g.tensor_by_name("c1").unwrap().shape, vec![1, 32, 32, 32]);
        assert_eq!(g.tensor_by_name("d1").unwrap().shape, vec![1, 32, 32, 32]);
        // Pure chain: the two fat stride-1 tensors must coexist, so
        // reordering cannot move the 64 KB floor.
        let default_peak = peak_of(&g, &g.default_order());
        let (sched, _) = optimal(&g).unwrap();
        assert_eq!(sched.peak_bytes, default_peak);
        assert_eq!(default_peak, 32_768 + 32_768);
    }

    #[test]
    fn audionet_shapes_and_floor() {
        let g = audionet(DType::I8);
        assert_eq!(g.tensor_by_name("c1").unwrap().shape, vec![1, 64, 16, 32]);
        assert_eq!(g.tensor_by_name("d1").unwrap().shape, vec![1, 32, 8, 32]);
        assert_eq!(g.tensor_by_name("m1").unwrap().shape, vec![1, 16, 4, 32]);
        // Pure chain: reordering alone cannot improve on the default
        // order, and the peak is the c1→d1 working set.
        let default_peak = peak_of(&g, &g.default_order());
        let (sched, _) = optimal(&g).unwrap();
        assert_eq!(sched.peak_bytes, default_peak);
        assert_eq!(default_peak, 32_768 + 8_192);
    }
}

//! Synthetic DAG generators for property tests and scheduler ablations.

use crate::graph::{DType, Graph, GraphBuilder, TensorId};
use crate::util::rng::Rng;

/// Random single-output DAG of `n_ops` synthetic operators; each consumes
/// 1–2 earlier tensors, all sinks become outputs. Mirrors the generator the
/// scheduler property tests use.
pub fn random_dag(rng: &mut Rng, n_ops: usize) -> Graph {
    let mut b = GraphBuilder::new("rand-dag");
    let mut tensors = vec![b.input("x", &[64 * (1 + rng.range(0, 8))], DType::U8)];
    for i in 0..n_ops {
        let n_in = if tensors.len() >= 2 && rng.chance(0.4) { 2 } else { 1 };
        let mut ins = Vec::new();
        while ins.len() < n_in {
            let t = *rng.pick(&tensors);
            if !ins.contains(&t) {
                ins.push(t);
            }
        }
        let bytes = 32 * (1 + rng.range(0, 64));
        tensors.push(b.synthetic(&format!("op{i}"), &ins, bytes, 1000));
    }
    let sinks: Vec<TensorId> = b
        .graph()
        .tensors
        .iter()
        .filter(|t| t.consumers.is_empty() && !t.is_weight)
        .map(|t| t.id)
        .collect();
    for s in sinks {
        b.output(s);
    }
    b.finish().expect("random dag is valid")
}

/// Series-parallel DAG: a chain of `depth` stages; each stage fans out into
/// `width` parallel branches (each a short chain) that rejoin. These are
/// the graphs where reordering freedom grows combinatorially — the
/// scheduler-scaling ablation sweeps `depth × width`.
pub fn series_parallel(rng: &mut Rng, depth: usize, width: usize) -> Graph {
    let mut b = GraphBuilder::new("series-parallel");
    let mut cur = b.input("x", &[256 + 64 * rng.range(0, 8)], DType::U8);
    for d in 0..depth {
        let mut joins = Vec::with_capacity(width);
        for w in 0..width {
            // Each branch: 1–3 chained ops with varying tensor sizes.
            let mut t = cur;
            let hops = 1 + rng.range(0, 3);
            for h in 0..hops {
                let bytes = 64 * (1 + rng.range(0, 32));
                t = b.synthetic(&format!("d{d}b{w}h{h}"), &[t], bytes, 500);
            }
            joins.push(t);
        }
        cur = if joins.len() == 1 {
            joins[0]
        } else {
            // Join with a synthetic N-ary combiner.
            let bytes = 64 * (1 + rng.range(0, 16));
            b.synthetic(&format!("d{d}join"), &joins, bytes, 500)
        };
    }
    b.output(cur);
    b.finish().expect("series-parallel dag is valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{bruteforce, optimal};

    #[test]
    fn random_dags_are_valid_and_schedulable() {
        let mut rng = Rng::new(7);
        for _ in 0..20 {
            let g = random_dag(&mut rng, 8);
            g.validate().unwrap();
            let (sched, _) = optimal(&g).unwrap();
            g.check_order(&sched.order).unwrap();
        }
    }

    #[test]
    fn series_parallel_shape() {
        let mut rng = Rng::new(3);
        let g = series_parallel(&mut rng, 3, 3);
        g.validate().unwrap();
        // depth 3, width 3: at least 3 joins + 9 branch ops.
        assert!(g.n_ops() >= 12);
        let (sched, _) = optimal(&g).unwrap();
        let bf = bruteforce(&g, 2_000_000);
        if let Some(bf) = bf {
            assert_eq!(sched.peak_bytes, bf.best.peak_bytes);
        }
    }

    #[test]
    fn series_parallel_offers_reordering_gains() {
        // Across seeds, the optimal schedule should beat the default
        // as-built order on at least some series-parallel graphs.
        let mut rng = Rng::new(42);
        let mut gains = 0;
        for _ in 0..20 {
            let g = series_parallel(&mut rng, 2, 3);
            let d = crate::sched::peak_of(&g, &g.default_order());
            let (o, _) = optimal(&g).unwrap();
            assert!(o.peak_bytes <= d);
            if o.peak_bytes < d {
                gains += 1;
            }
        }
        assert!(gains >= 5, "only {gains}/20 graphs improved");
    }
}

//! Synthetic DAG generators for property tests and scheduler ablations.

use crate::graph::{Act, DType, Graph, GraphBuilder, Padding, TensorId};
use crate::util::rng::Rng;

/// Random single-output DAG of `n_ops` synthetic operators; each consumes
/// 1–2 earlier tensors, all sinks become outputs. Mirrors the generator the
/// scheduler property tests use.
pub fn random_dag(rng: &mut Rng, n_ops: usize) -> Graph {
    let mut b = GraphBuilder::new("rand-dag");
    let mut tensors = vec![b.input("x", &[64 * (1 + rng.range(0, 8))], DType::U8)];
    for i in 0..n_ops {
        let n_in = if tensors.len() >= 2 && rng.chance(0.4) { 2 } else { 1 };
        let mut ins = Vec::new();
        while ins.len() < n_in {
            let t = *rng.pick(&tensors);
            if !ins.contains(&t) {
                ins.push(t);
            }
        }
        let bytes = 32 * (1 + rng.range(0, 64));
        tensors.push(b.synthetic(&format!("op{i}"), &ins, bytes, 1000));
    }
    let sinks: Vec<TensorId> = b
        .graph()
        .tensors
        .iter()
        .filter(|t| t.consumers.is_empty() && !t.is_weight)
        .map(|t| t.id)
        .collect();
    for s in sinks {
        b.output(s);
    }
    b.finish().expect("random dag is valid")
}

/// Series-parallel DAG: a chain of `depth` stages; each stage fans out into
/// `width` parallel branches (each a short chain) that rejoin. These are
/// the graphs where reordering freedom grows combinatorially — the
/// scheduler-scaling ablation sweeps `depth × width`.
pub fn series_parallel(rng: &mut Rng, depth: usize, width: usize) -> Graph {
    let mut b = GraphBuilder::new("series-parallel");
    let mut cur = b.input("x", &[256 + 64 * rng.range(0, 8)], DType::U8);
    for d in 0..depth {
        let mut joins = Vec::with_capacity(width);
        for w in 0..width {
            // Each branch: 1–3 chained ops with varying tensor sizes.
            let mut t = cur;
            let hops = 1 + rng.range(0, 3);
            for h in 0..hops {
                let bytes = 64 * (1 + rng.range(0, 32));
                t = b.synthetic(&format!("d{d}b{w}h{h}"), &[t], bytes, 500);
            }
            joins.push(t);
        }
        cur = if joins.len() == 1 {
            joins[0]
        } else {
            // Join with a synthetic N-ary combiner.
            let bytes = 64 * (1 + rng.range(0, 16));
            b.synthetic(&format!("d{d}join"), &joins, bytes, 500)
        };
    }
    b.output(cur);
    b.finish().expect("series-parallel dag is valid")
}

/// Deterministic layered CNN of exactly `n_ops` operators, for the
/// planner-scaling bench (100/300/1000 ops). An MBConv-style
/// expand→depthwise→contract stem (×4 channel expansion) followed by a
/// random walk over realistic block types — plain conv, depthwise+
/// pointwise pair, standalone ReLU, residual pair, stride-2 downsample —
/// on a 32×32×8 input, capped at 64 channels / 4×4 spatial, closed by
/// `global_avgpool → dense(10) → softmax`.
///
/// Two deliberate shape choices keep the graph *plannable*, so the
/// scaling bench's split-planner runs have real work to do:
///
/// - the stem's ×4-expanded intermediates are the fattest tensors in the
///   graph and sit interior to a short sliceable chain — exactly the
///   partial-execution sweet spot (a fat graph *input* would be
///   unsplittable: it stays fully resident under any banding);
/// - residual pairs only appear once the spatial extent has dropped to
///   ≤ 8: a residual `Add` keeps three same-shape tensors live at once
///   and no split can shrink that, so full-resolution residuals would
///   floor the peak at an unimprovable value.
///
/// Uses only [`Rng::range`] so `tools/schedule_mirror/mirror.py` can
/// regenerate it bit-exactly (same xoshiro stream, same names, same
/// shapes) — the mirror recomputes this generator's gated bench peaks
/// independently. Any change here must be made in lockstep with the
/// mirror's `layered`.
pub fn layered(rng: &mut Rng, n_ops: usize) -> Graph {
    assert!(n_ops >= 7, "layered graphs need the 3-op stem, a body and the 3-op tail");
    let mut b = GraphBuilder::new("layered");
    let mut cur = b.input("x", &[1, 32, 32, 8], DType::I8);
    let mut h = 32usize;
    let mut c = 8usize;
    cur = b.conv2d("stem.ex", cur, 4 * c, (1, 1), (1, 1), Padding::Same, Act::Relu);
    cur = b.dwconv2d("stem.dw", cur, (3, 3), (1, 1), Padding::Same, Act::Relu);
    cur = b.conv2d("stem.pw", cur, c, (1, 1), (1, 1), Padding::Same, Act::Linear);
    let body = n_ops - 6;
    let mut emitted = 0usize;
    let mut i = 0usize;
    while emitted < body {
        let left = body - emitted;
        let r = rng.range(0, 8);
        if r <= 2 || left == 1 {
            cur = b.conv2d(&format!("l{i}.conv"), cur, c, (3, 3), (1, 1), Padding::Same, Act::Relu);
            emitted += 1;
        } else if r <= 4 && left >= 2 {
            cur = b.dwconv2d(&format!("l{i}.dw"), cur, (3, 3), (1, 1), Padding::Same, Act::Relu);
            cur = b.conv2d(&format!("l{i}.pw"), cur, c, (1, 1), (1, 1), Padding::Same, Act::Relu);
            emitted += 2;
        } else if r == 5 {
            cur = b.relu(&format!("l{i}.relu"), cur);
            emitted += 1;
        } else if r == 6 && left >= 3 && h <= 8 {
            let a = b.conv2d(&format!("l{i}.ra"), cur, c, (3, 3), (1, 1), Padding::Same, Act::Relu);
            let z =
                b.conv2d(&format!("l{i}.rb"), a, c, (3, 3), (1, 1), Padding::Same, Act::Linear);
            cur = b.add(&format!("l{i}.add"), cur, z);
            emitted += 3;
        } else if h > 4 {
            h = h.div_ceil(2);
            c = (c * 2).min(64);
            cur = b.conv2d(&format!("l{i}.down"), cur, c, (3, 3), (2, 2), Padding::Same, Act::Relu);
            emitted += 1;
        } else {
            cur = b.conv2d(&format!("l{i}.conv"), cur, c, (3, 3), (1, 1), Padding::Same, Act::Relu);
            emitted += 1;
        }
        i += 1;
    }
    let gap = b.global_avgpool("gap", cur);
    let fc = b.dense("fc", gap, 10, Act::Linear);
    let sm = b.softmax("softmax", fc);
    b.output(sm);
    b.finish().expect("layered graph is valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{bruteforce, optimal};

    #[test]
    fn random_dags_are_valid_and_schedulable() {
        let mut rng = Rng::new(7);
        for _ in 0..20 {
            let g = random_dag(&mut rng, 8);
            g.validate().unwrap();
            let (sched, _) = optimal(&g).unwrap();
            g.check_order(&sched.order).unwrap();
        }
    }

    #[test]
    fn series_parallel_shape() {
        let mut rng = Rng::new(3);
        let g = series_parallel(&mut rng, 3, 3);
        g.validate().unwrap();
        // depth 3, width 3: at least 3 joins + 9 branch ops.
        assert!(g.n_ops() >= 12);
        let (sched, _) = optimal(&g).unwrap();
        let bf = bruteforce(&g, 2_000_000);
        if let Some(bf) = bf {
            assert_eq!(sched.peak_bytes, bf.best.peak_bytes);
        }
    }

    #[test]
    fn layered_has_exact_op_count_and_many_regions() {
        for n in [20usize, 100] {
            let mut rng = Rng::new(n as u64);
            let g = layered(&mut rng, n);
            g.validate().unwrap();
            assert_eq!(g.n_ops(), n);
            let (sched, _) = optimal(&g).unwrap();
            g.check_order(&sched.order).unwrap();
            // The generator is chain-heavy, so series decomposition must
            // find many independent regions (that's what the planner's
            // incremental fast path banks on).
            let regions = crate::sched::decompose(&g);
            assert!(regions.len() > n / 4, "{} regions for {} ops", regions.len(), n);
        }
    }

    #[test]
    fn series_parallel_offers_reordering_gains() {
        // Across seeds, the optimal schedule should beat the default
        // as-built order on at least some series-parallel graphs.
        let mut rng = Rng::new(42);
        let mut gains = 0;
        for _ in 0..20 {
            let g = series_parallel(&mut rng, 2, 3);
            let d = crate::sched::peak_of(&g, &g.default_order());
            let (o, _) = optimal(&g).unwrap();
            assert!(o.peak_bytes <= d);
            if o.peak_bytes < d {
                gains += 1;
            }
        }
        assert!(gains >= 5, "only {gains}/20 graphs improved");
    }
}

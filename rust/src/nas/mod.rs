//! Memory-aware neural architecture search (§6 of the paper).
//!
//! "Having a way of precisely computing peak memory usage for models with
//! complex computation graphs would benefit neural architecture search
//! (NAS) procedures." This module demonstrates that benefit: a random
//! search over a SwiftNet-style cell space where every candidate is scored
//! with **Algorithm 1's optimal-schedule peak** instead of the default-order
//! peak. Candidates that fit the SRAM budget *only when reordered* are
//! exactly the architectures a naive NAS would wrongly discard — the search
//! reports how many of its Pareto-optimal picks are in that class.
//!
//! Without training in the loop, model capacity (MACs) stands in as the
//! accuracy proxy (the standard practice for cost-model-guided NAS à la
//! MnasNet/SpArSe); the Pareto front maximizes MACs while minimizing peak
//! SRAM.

use crate::graph::{Act, DType, Graph, GraphBuilder, Padding, TensorId};
use crate::mcu::{Board, OverheadModel};
use crate::sched;
use crate::util::rng::Rng;

/// One sampled cell-network configuration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CellConfig {
    /// Stem output channels (48×48 feature map).
    pub stem: usize,
    /// Per stage: (cells, branch-A mid channels, branch-A out, branch-B out).
    pub stages: Vec<(usize, usize, usize, usize)>,
    /// Transition output channels between stages.
    pub transitions: Vec<usize>,
}

impl CellConfig {
    /// Sample a configuration from the search space.
    pub fn sample(rng: &mut Rng) -> CellConfig {
        let stem = 8 * rng.range(2, 7); // 16..48
        let mut stages = Vec::new();
        let mut transitions = Vec::new();
        let n_stages = rng.range(2, 5); // 2..4 stages
        for s in 0..n_stages {
            let cells = rng.range(1, 4);
            let mid = 8 * rng.range(2, 16); // 16..120
            let a_out = 8 * rng.range(2, 13);
            let b_out = 4 * rng.range(1, 9);
            stages.push((cells, mid, a_out, b_out));
            if s + 1 < n_stages {
                transitions.push(8 * rng.range(4, 25)); // 32..192
            }
        }
        CellConfig { stem, stages, transitions }
    }

    /// Materialize the configuration as a graph (96×96×3 input, 2 classes).
    pub fn build(&self, dtype: DType) -> Graph {
        let mut b = GraphBuilder::new("nas-candidate");
        let x = b.input("input", &[1, 96, 96, 3], dtype);
        let mut t = b.conv2d("stem", x, self.stem, (3, 3), (2, 2), Padding::Same, Act::Relu6);
        for (si, &(cells, mid, a_out, b_out)) in self.stages.iter().enumerate() {
            for ci in 0..cells {
                t = cell(&mut b, &format!("s{si}c{ci}"), t, mid, a_out, b_out);
            }
            if let Some(&tc) = self.transitions.get(si) {
                let d = b.dwconv2d(
                    &format!("t{si}.dw"),
                    t,
                    (3, 3),
                    (2, 2),
                    Padding::Same,
                    Act::Relu6,
                );
                t = b.conv2d(
                    &format!("t{si}.pw"),
                    d,
                    tc,
                    (1, 1),
                    (1, 1),
                    Padding::Same,
                    Act::Relu6,
                );
            }
        }
        let gap = b.global_avgpool("gap", t);
        let fc = b.dense("fc", gap, 2, Act::Linear);
        let sm = b.softmax("softmax", fc);
        b.output(sm);
        b.finish().expect("sampled config builds a valid graph")
    }
}

fn cell(
    b: &mut GraphBuilder,
    name: &str,
    x: TensorId,
    mid: usize,
    a_out: usize,
    b_out: usize,
) -> TensorId {
    let a1 = b.conv2d(&format!("{name}.a1"), x, mid, (1, 1), (1, 1), Padding::Same, Act::Relu6);
    let a2 = b.dwconv2d(&format!("{name}.a2"), a1, (3, 3), (1, 1), Padding::Same, Act::Relu6);
    let a3 = b.conv2d(&format!("{name}.a3"), a2, a_out, (1, 1), (1, 1), Padding::Same, Act::Relu6);
    let b1 = b.dwconv2d(&format!("{name}.b1"), x, (3, 3), (1, 1), Padding::Same, Act::Relu6);
    let b2 = b.conv2d(&format!("{name}.b2"), b1, b_out, (1, 1), (1, 1), Padding::Same, Act::Relu6);
    b.concat(&format!("{name}.cat"), &[a3, b2])
}

/// A scored candidate.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub config: CellConfig,
    /// Peak with the default (as-built) order.
    pub default_peak: usize,
    /// Peak with Algorithm 1's optimal order.
    pub optimal_peak: usize,
    /// Capacity proxy.
    pub macs: u64,
    /// Flash footprint (weights).
    pub params: usize,
    /// Framework overhead estimate.
    pub overhead: usize,
}

impl Candidate {
    /// Fits the board's SRAM when scheduled with the default order?
    pub fn fits_default(&self, board: &Board) -> bool {
        self.default_peak + self.overhead <= board.sram_bytes
    }

    /// Fits when optimally reordered?
    pub fn fits_optimal(&self, board: &Board) -> bool {
        self.optimal_peak + self.overhead <= board.sram_bytes
    }
}

/// Search outcome.
#[derive(Debug)]
pub struct SearchResult {
    /// All evaluated candidates.
    pub evaluated: Vec<Candidate>,
    /// Candidates on the (peak ↓, MACs ↑) Pareto front among those that fit
    /// the budget under the optimal schedule.
    pub pareto: Vec<Candidate>,
    /// How many feasible candidates would have been discarded by a
    /// default-order memory check (the §6 claim, quantified).
    pub rescued_by_reordering: usize,
}

/// Random search: sample `n` configs, score each with Algorithm 1, keep the
/// Pareto front of those fitting `board` (+`overhead`) and `flash` limits.
pub fn random_search(
    rng: &mut Rng,
    n: usize,
    board: &Board,
    overhead: &OverheadModel,
) -> SearchResult {
    let mut evaluated = Vec::with_capacity(n);
    for _ in 0..n {
        let config = CellConfig::sample(rng);
        let g = config.build(DType::I8);
        let default_peak = sched::peak_of(&g, &g.default_order());
        // NAS is exactly where scheduler speed matters: one DP solve per
        // candidate.
        let Ok((opt, _)) = sched::optimal(&g) else { continue };
        evaluated.push(Candidate {
            config,
            default_peak,
            optimal_peak: opt.peak_bytes,
            macs: g.total_macs(),
            params: g.model_size(),
            overhead: overhead.bytes(&g),
        });
    }

    let feasible: Vec<&Candidate> = evaluated
        .iter()
        .filter(|c| c.fits_optimal(board) && c.params + 60 * 1024 <= board.flash_bytes)
        .collect();
    let rescued = feasible.iter().filter(|c| !c.fits_default(board)).count();

    // Pareto: maximize MACs, minimize optimal peak.
    let mut pareto: Vec<Candidate> = Vec::new();
    for c in &feasible {
        let dominated = feasible.iter().any(|o| {
            (o.macs > c.macs && o.optimal_peak <= c.optimal_peak)
                || (o.macs >= c.macs && o.optimal_peak < c.optimal_peak)
        });
        if !dominated {
            pareto.push((*c).clone());
        }
    }
    pareto.sort_by_key(|c| c.optimal_peak);
    pareto.dedup_by(|a, b| a.optimal_peak == b.optimal_peak && a.macs == b.macs);

    SearchResult { evaluated, pareto, rescued_by_reordering: rescued }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcu::NUCLEO_F767ZI;

    #[test]
    fn sampled_configs_build_valid_graphs() {
        let mut rng = Rng::new(11);
        for _ in 0..10 {
            let c = CellConfig::sample(&mut rng);
            let g = c.build(DType::I8);
            g.validate().unwrap();
            assert!(g.n_ops() >= 8);
        }
    }

    #[test]
    fn search_is_deterministic_per_seed() {
        let r1 = random_search(&mut Rng::new(3), 10, &NUCLEO_F767ZI, &OverheadModel::default());
        let r2 = random_search(&mut Rng::new(3), 10, &NUCLEO_F767ZI, &OverheadModel::default());
        assert_eq!(r1.evaluated.len(), r2.evaluated.len());
        for (a, b) in r1.evaluated.iter().zip(&r2.evaluated) {
            assert_eq!(a.config, b.config);
            assert_eq!(a.optimal_peak, b.optimal_peak);
        }
    }

    #[test]
    fn pareto_front_is_non_dominated_and_sorted() {
        let r = random_search(&mut Rng::new(17), 40, &NUCLEO_F767ZI, &OverheadModel::default());
        for (i, a) in r.pareto.iter().enumerate() {
            for (j, b) in r.pareto.iter().enumerate() {
                if i == j {
                    continue;
                }
                let dominates = (b.macs > a.macs && b.optimal_peak <= a.optimal_peak)
                    || (b.macs >= a.macs && b.optimal_peak < a.optimal_peak);
                assert!(!dominates, "pareto member dominated");
            }
            if i > 0 {
                assert!(r.pareto[i - 1].optimal_peak <= a.optimal_peak);
            }
        }
    }

    #[test]
    fn optimal_peak_never_exceeds_default() {
        let r = random_search(&mut Rng::new(23), 25, &NUCLEO_F767ZI, &OverheadModel::default());
        for c in &r.evaluated {
            assert!(c.optimal_peak <= c.default_peak);
        }
    }

    #[test]
    fn reordering_rescues_candidates() {
        // Across a decent sample, some architectures must fit only when
        // reordered — the quantified §6 benefit.
        let r = random_search(&mut Rng::new(41), 60, &NUCLEO_F767ZI, &OverheadModel::default());
        assert!(
            r.rescued_by_reordering > 0,
            "expected some candidates feasible only via reordering"
        );
    }
}

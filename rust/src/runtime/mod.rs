//! PJRT runtime: load and execute AOT-compiled JAX/Pallas artifacts.
//!
//! The build-time Python pipeline (`python/compile/aot.py`) lowers each
//! model's forward pass — with the Pallas kernels inlined via
//! `interpret=True` — to **HLO text** (`artifacts/<model>.hlo.txt`).
//! HLO text, not a serialized `HloModuleProto`, is the interchange format:
//! jax ≥ 0.5 emits 64-bit instruction ids that the pinned xla_extension
//! 0.5.1 rejects, while the text parser reassigns ids cleanly.
//!
//! This module wraps the `xla` crate: CPU PJRT client → parse text →
//! compile once → execute many times. Weights are baked into the HLO as
//! constants (the Flash analogy: parameters are immutable at inference), so
//! an executable takes just the image tensor and returns the class
//! probabilities. Python never runs on this path.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::error::{anyhow, bail, Context, Result};

use crate::graph::Graph;
use crate::util::json::Json;

/// Shape + dtype signature of one artifact boundary tensor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// Parsed `artifacts/<model>.manifest.json` — written by `aot.py` alongside
/// the HLO so the Rust side can validate shapes before feeding buffers.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    /// Kernel backend used at lowering time ("pallas" | "jnp").
    pub kernels: String,
}

impl Manifest {
    pub fn from_json(src: &str) -> Result<Manifest> {
        let v = Json::parse(src).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let specs = |key: &str| -> Result<Vec<IoSpec>> {
            v.get(key)
                .as_arr()
                .ok_or_else(|| anyhow!("manifest missing {key}"))?
                .iter()
                .map(|s| {
                    Ok(IoSpec {
                        name: s.get("name").as_str().unwrap_or("").to_string(),
                        shape: s
                            .get("shape")
                            .as_arr()
                            .ok_or_else(|| anyhow!("bad shape"))?
                            .iter()
                            .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
                            .collect::<Result<_>>()?,
                        dtype: s.get("dtype").as_str().unwrap_or("f32").to_string(),
                    })
                })
                .collect()
        };
        Ok(Manifest {
            model: v.get("model").as_str().unwrap_or("").to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            kernels: v.get("kernels").as_str().unwrap_or("jnp").to_string(),
        })
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Manifest::from_json(&src)
    }

    /// Cross-check the manifest against the Rust model-zoo graph the
    /// artifact claims to implement (guards against zoo/exporter drift).
    pub fn check_against(&self, g: &Graph) -> Result<()> {
        if self.inputs.len() != g.inputs.len() {
            bail!("manifest has {} inputs, graph has {}", self.inputs.len(), g.inputs.len());
        }
        for (spec, &tid) in self.inputs.iter().zip(&g.inputs) {
            let t = &g.tensors[tid];
            if spec.shape != t.shape {
                bail!("input {} shape {:?} != graph {:?}", spec.name, spec.shape, t.shape);
            }
        }
        if self.outputs.len() != g.outputs.len() {
            bail!("manifest has {} outputs, graph has {}", self.outputs.len(), g.outputs.len());
        }
        for (spec, &tid) in self.outputs.iter().zip(&g.outputs) {
            let t = &g.tensors[tid];
            if spec.shape != t.shape {
                bail!("output {} shape {:?} != graph {:?}", spec.name, spec.shape, t.shape);
            }
        }
        Ok(())
    }
}

/// Compiled-executable handle: the real PJRT executable under
/// `--features pjrt`, an uninhabitable placeholder otherwise (the stub
/// [`Runtime::cpu`] fails before one could ever be constructed).
#[cfg(feature = "pjrt")]
type Exe = xla::PjRtLoadedExecutable;
#[cfg(not(feature = "pjrt"))]
type Exe = std::convert::Infallible;

/// A compiled model artifact resident on the PJRT client.
pub struct LoadedModel {
    pub name: String,
    pub manifest: Manifest,
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    exe: Exe,
}

/// The PJRT runtime: one CPU client, many compiled executables.
///
/// Built without the `pjrt` feature this is a stub: [`Runtime::cpu`]
/// returns an error explaining how to enable the backend, and the rest of
/// the crate (interpreter engine, scheduler, splitter) works unchanged.
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    models: HashMap<String, LoadedModel>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    #[cfg(feature = "pjrt")]
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        Ok(Runtime { client, models: HashMap::new() })
    }

    /// Stub: the PJRT backend is not compiled in.
    #[cfg(not(feature = "pjrt"))]
    pub fn cpu() -> Result<Runtime> {
        bail!(
            "PJRT backend not built: rebuild with `--features pjrt` \
             (requires the vendored `xla` crate stack)"
        )
    }

    pub fn platform(&self) -> String {
        #[cfg(feature = "pjrt")]
        {
            self.client.platform_name()
        }
        #[cfg(not(feature = "pjrt"))]
        {
            "unavailable (built without the pjrt feature)".to_string()
        }
    }

    /// Load + compile `artifacts/<name>.hlo.txt` (+ its manifest).
    #[cfg(feature = "pjrt")]
    pub fn load_artifact(&mut self, name: &str, dir: &Path) -> Result<&LoadedModel> {
        let hlo_path: PathBuf = dir.join(format!("{name}.hlo.txt"));
        let man_path: PathBuf = dir.join(format!("{name}.manifest.json"));
        let manifest = Manifest::load(&man_path)?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.models
            .insert(name.to_string(), LoadedModel { name: name.to_string(), manifest, exe });
        Ok(&self.models[name])
    }

    /// Stub: validates the manifest exists, then reports the missing
    /// backend (a stub `Runtime` cannot exist, but the method must).
    #[cfg(not(feature = "pjrt"))]
    pub fn load_artifact(&mut self, name: &str, dir: &Path) -> Result<&LoadedModel> {
        let man_path: PathBuf = dir.join(format!("{name}.manifest.json"));
        let _ = Manifest::load(&man_path)?;
        bail!("PJRT backend not built: cannot compile artifact {name:?}")
    }

    pub fn get(&self, name: &str) -> Option<&LoadedModel> {
        self.models.get(name)
    }

    pub fn loaded_names(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    /// Execute a loaded model on f32 inputs (shapes validated against the
    /// manifest). Returns one f32 vector per output.
    pub fn execute_f32(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let model =
            self.models.get(name).ok_or_else(|| anyhow!("model {name} not loaded"))?;
        model.execute_f32(inputs)
    }
}

impl LoadedModel {
    /// Execute on f32 inputs.
    #[cfg(feature = "pjrt")]
    pub fn execute_f32(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.manifest.inputs.len() {
            bail!(
                "model {} expects {} inputs, got {}",
                self.name,
                self.manifest.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (spec, data) in self.manifest.inputs.iter().zip(inputs) {
            let elems: usize = spec.shape.iter().product();
            if data.len() != elems {
                bail!("input {} expects {} elems, got {}", spec.name, elems, data.len());
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape input {}: {e:?}", spec.name))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unpack N outputs.
        let n_out = self.manifest.outputs.len();
        let parts = root.to_tuple().map_err(|e| anyhow!("decompose tuple: {e:?}"))?;
        if parts.len() != n_out {
            bail!("model {} returned {} outputs, manifest says {}", self.name, parts.len(), n_out);
        }
        parts
            .iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("read output: {e:?}")))
            .collect()
    }

    /// Stub: unreachable (a stub [`Runtime`] holds no models), kept so the
    /// API is feature-independent.
    #[cfg(not(feature = "pjrt"))]
    pub fn execute_f32(&self, _inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        match self.exe {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_roundtrip_and_check() {
        let src = r#"{
            "model": "tiny-cnn",
            "kernels": "pallas",
            "inputs": [{"name": "x", "shape": [1, 8, 8, 2], "dtype": "f32"}],
            "outputs": [{"name": "softmax", "shape": [1, 3], "dtype": "f32"}]
        }"#;
        let m = Manifest::from_json(src).unwrap();
        assert_eq!(m.model, "tiny-cnn");
        assert_eq!(m.kernels, "pallas");
        assert_eq!(m.inputs[0].shape, vec![1, 8, 8, 2]);
        let g = crate::models::tiny_cnn(crate::graph::DType::F32);
        m.check_against(&g).unwrap();
    }

    #[test]
    fn manifest_check_rejects_shape_drift() {
        let src = r#"{
            "model": "tiny-cnn",
            "inputs": [{"name": "x", "shape": [1, 16, 16, 2], "dtype": "f32"}],
            "outputs": [{"name": "softmax", "shape": [1, 3], "dtype": "f32"}]
        }"#;
        let m = Manifest::from_json(src).unwrap();
        let g = crate::models::tiny_cnn(crate::graph::DType::F32);
        assert!(m.check_against(&g).is_err());
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(Manifest::from_json("{}").is_err());
        assert!(Manifest::from_json("not json").is_err());
    }
}

//! In-tree substrate utilities.
//!
//! This environment vendors only the `xla` crate stack, so the facilities a
//! project would normally pull from crates.io are implemented here:
//!
//! - [`error`] — `anyhow`-style error value + context trait + macros
//!   (replaces `anyhow`).
//! - [`json`] — JSON parser/emitter (replaces `serde_json`) for the model
//!   format, artifact manifests and reports.
//! - [`rng`] — deterministic xoshiro256** PRNG (replaces `rand`).
//! - [`prop`] — property-test harness with seeds + coarse shrinking
//!   (replaces `proptest`).
//! - [`bench`] — mini-criterion benchmark runner + table printer
//!   (replaces `criterion`).
//! - [`stats`] — mean/σ/percentiles/log-histogram/linear-fit helpers.

pub mod bench;
pub mod bitset;
pub mod error;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

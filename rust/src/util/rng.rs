//! Deterministic pseudo-random number generation.
//!
//! The crates.io `rand` family is not vendored in this environment, so we
//! carry a small, well-known generator in-tree: `splitmix64` for seeding and
//! `xoshiro256**` for the stream (Blackman & Vigna, 2018). Determinism
//! matters more than statistical sophistication here — the RNG drives
//! synthetic DAG generation, property tests and workload traces, all of which
//! must be reproducible from a printed seed.

/// SplitMix64 step — used to expand a single `u64` seed into the four
/// words of xoshiro state (the construction recommended by the xoshiro
/// authors).
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    seed: u64,
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, seed }
    }

    /// The seed this generator was constructed with (for failure reports).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `u64` in `[0, bound)` via Lemire's multiply-shift with
    /// rejection (unbiased). `bound` must be non-zero.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "Rng::below(0)");
        // Rejection threshold for unbiased sampling.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "Rng::range: empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range(0, items.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.range(0, i + 1);
            items.swap(i, j);
        }
    }

    /// Derive an independent child generator (for per-case streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn range_endpoints() {
        let mut r = Rng::new(11);
        for _ in 0..100 {
            let v = r.range(5, 6);
            assert_eq!(v, 5);
        }
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(13);
        assert!(!(0..100).any(|_| r.chance(0.0)));
        assert!((0..100).all(|_| r.chance(1.0)));
    }
}

//! Property-based testing harness (proptest is not vendored).
//!
//! `check(name, cases, |rng| ...)` runs a closure against `cases`
//! independently-seeded RNGs. On failure it re-raises the panic annotated
//! with the case seed so the exact failing input can be replayed with
//! `replay(seed, ...)`. A coarse shrinking pass is supported for generators
//! that expose a size parameter: `check_sized` retries failing cases at
//! smaller sizes and reports the smallest size that still fails.

use std::panic::{catch_unwind, AssertUnwindSafe};

use super::rng::Rng;

/// Environment knob: `PROP_CASES` overrides the per-property case count.
fn case_count(default_cases: usize) -> usize {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default_cases)
}

/// Master seed: `PROP_SEED` makes the whole suite reproducible.
fn master_seed() -> u64 {
    std::env::var("PROP_SEED").ok().and_then(|v| v.parse().ok()).unwrap_or(0xC0FFEE)
}

/// Run `prop` against `cases` random cases. Panics (with the failing seed)
/// if any case panics.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Rng)) {
    let cases = case_count(cases);
    let mut master = Rng::new(master_seed() ^ hash_name(name));
    for case in 0..cases {
        let seed = master.next_u64();
        let mut rng = Rng::new(seed);
        let result = catch_unwind(AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(payload) = result {
            let msg = panic_message(&payload);
            panic!(
                "property '{name}' failed on case {case}/{cases} (replay seed: {seed:#x})\n  cause: {msg}"
            );
        }
    }
}

/// Replay a single case of a property by seed (used when debugging a
/// reported failure).
pub fn replay(seed: u64, mut prop: impl FnMut(&mut Rng)) {
    let mut rng = Rng::new(seed);
    prop(&mut rng);
}

/// Run `prop(rng, size)` for random sizes in `[min_size, max_size]`. When a
/// case fails, retries smaller sizes with the same seed to report the
/// smallest reproduction (coarse shrinking).
pub fn check_sized(
    name: &str,
    cases: usize,
    min_size: usize,
    max_size: usize,
    prop: impl Fn(&mut Rng, usize),
) {
    assert!(min_size <= max_size);
    let cases = case_count(cases);
    let mut master = Rng::new(master_seed() ^ hash_name(name));
    for case in 0..cases {
        let seed = master.next_u64();
        let size = Rng::new(seed).range(min_size, max_size + 1);
        let run = |sz: usize| {
            let mut rng = Rng::new(seed);
            // burn the size draw so the data stream is identical across sizes
            let _ = rng.range(min_size, max_size + 1);
            catch_unwind(AssertUnwindSafe(|| prop(&mut rng, sz)))
        };
        if let Err(payload) = run(size) {
            // Shrink: find the smallest size (same seed) that still fails.
            let mut smallest = size;
            let mut last_payload = payload;
            for sz in min_size..size {
                match run(sz) {
                    Err(p) => {
                        smallest = sz;
                        last_payload = p;
                        break;
                    }
                    Ok(()) => continue,
                }
            }
            let msg = panic_message(&last_payload);
            panic!(
                "property '{name}' failed on case {case}/{cases} at size {smallest} \
                 (replay seed: {seed:#x})\n  cause: {msg}"
            );
        }
    }
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a, enough to decorrelate property streams by name.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

fn panic_message(payload: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 50, |rng| {
            let a = rng.below(1000);
            let b = rng.below(1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = catch_unwind(AssertUnwindSafe(|| {
            check("always-fails", 3, |_rng| panic!("boom"));
        }));
        let msg = panic_message(&r.unwrap_err());
        assert!(msg.contains("replay seed"), "got: {msg}");
        assert!(msg.contains("boom"), "got: {msg}");
    }

    #[test]
    fn sized_property_shrinks() {
        // Fails for any size >= 5; shrinker should report size 5.
        let r = catch_unwind(AssertUnwindSafe(|| {
            check_sized("size-ge-5", 50, 1, 20, |_rng, size| {
                assert!(size < 5, "size too big");
            });
        }));
        let msg = panic_message(&r.unwrap_err());
        assert!(msg.contains("at size 5"), "got: {msg}");
    }

    #[test]
    fn replay_reproduces_stream() {
        let mut first = Vec::new();
        replay(0xDEAD, |rng| {
            for _ in 0..5 {
                first.push(rng.next_u64());
            }
        });
        let mut second = Vec::new();
        replay(0xDEAD, |rng| {
            for _ in 0..5 {
                second.push(rng.next_u64());
            }
        });
        assert_eq!(first, second);
    }
}

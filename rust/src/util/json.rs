//! Minimal JSON parser and emitter.
//!
//! `serde`/`serde_json` are not vendored in this environment, so the model
//! format, artifact manifests and bench reports use this small in-tree JSON
//! implementation. It supports the full JSON value model (objects, arrays,
//! strings with escapes, numbers, booleans, null) with precise error
//! positions; numbers are held as `f64` (adequate for tensor shapes and byte
//! sizes well below 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept in a `BTreeMap` so emission is
/// deterministic (stable diffs for golden files).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset and line/column for diagnostics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
    pub line: usize,
    pub col: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        let (mut line, mut col) = (1usize, 1usize);
        for &b in &self.src[..self.pos.min(self.src.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        Err(JsonError { msg: msg.into(), offset: self.pos, line, col })
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!(
                "expected '{}', found {:?}",
                b as char,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected character {:?}", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn keyword(&mut self, kw: &str, val: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(val)
        } else {
            self.err(format!("invalid literal, expected '{kw}'"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return self.err("expected ',' or '}' in object"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return self.err("expected ',' or ']' in array"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return self.err("unpaired high surrogate");
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return self.err("invalid low surrogate");
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        match ch {
                            Some(c) => out.push(c),
                            None => return self.err("invalid unicode escape"),
                        }
                    }
                    _ => return self.err("invalid escape sequence"),
                },
                Some(c) if c < 0x20 => return self.err("control character in string"),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return self.err("invalid utf-8 byte"),
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    match std::str::from_utf8(&self.src[start..self.pos]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => return self.err("invalid utf-8 sequence"),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = match self.bump() {
                Some(c @ b'0'..=b'9') => (c - b'0') as u32,
                Some(c @ b'a'..=b'f') => (c - b'a' + 10) as u32,
                Some(c @ b'A'..=b'F') => (c - b'A' + 10) as u32,
                _ => return self.err("invalid \\u escape"),
            };
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        match text.parse::<f64>() {
            Ok(v) => Ok(Json::Num(v)),
            Err(_) => self.err(format!("invalid number {text:?}")),
        }
    }
}

impl Json {
    /// Parse a complete JSON document; trailing non-whitespace is an error.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return p.err("trailing characters after document");
        }
        Ok(v)
    }

    // -- accessors -----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // -- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_usize(items: &[usize]) -> Json {
        Json::Arr(items.iter().map(|&v| Json::Num(v as f64)).collect())
    }

    pub fn arr_str(items: &[&str]) -> Json {
        Json::Arr(items.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // -- emission --------------------------------------------------------

    /// Compact single-line emission.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.emit(&mut out, None, 0);
        out
    }

    /// Pretty emission with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.emit(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn emit(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => emit_string(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.emit(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    emit_string(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.emit(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn emit_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\"A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\"A"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "{\"a\"}", "\"unterminated", "tru", "01x", "{,}"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn error_position_is_reported() {
        let e = Json::parse("{\n  \"a\": @\n}").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.msg.contains("unexpected"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"model":"mobilenet","tensors":[{"id":0,"bytes":9216},{"id":1,"bytes":18432}],"ok":true,"note":null}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(18432.0).to_string(), "18432");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn get_on_non_object_is_null() {
        assert_eq!(Json::Num(1.0).get("x"), &Json::Null);
    }

    #[test]
    fn as_usize_rejects_fraction_and_negative() {
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(-2.0).as_usize(), None);
        assert_eq!(Json::Num(7.0).as_usize(), Some(7));
    }

    #[test]
    fn deep_nesting_roundtrip() {
        let mut s = String::new();
        for _ in 0..100 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..100 {
            s.push(']');
        }
        let v = Json::parse(&s).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}

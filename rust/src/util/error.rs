//! Minimal `anyhow`-style error handling (the `anyhow` crate is not
//! vendored in this environment).
//!
//! Provides exactly the pieces the CLI, runtime and serving layers use: an
//! opaque [`Error`] any `std::error::Error` converts into, a [`Result`]
//! alias whose error type defaults to it, a [`Context`] extension trait,
//! and the [`anyhow!`]/[`bail!`] macros.
//!
//! `Error` deliberately does *not* implement `std::error::Error`: that is
//! what keeps the blanket `From<E: std::error::Error>` impl coherent with
//! the reflexive `From<T> for T` (the same trick `anyhow` itself uses).

use std::fmt;

/// Opaque error value: a flattened message chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (what `anyhow!` expands to).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend context to the message chain.
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` with a defaulted error type, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context()` / `.with_context()` to results.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Format an [`Error`] from format-string arguments.
#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => { $crate::util::error::Error::msg(format!($($t)*)) };
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::anyhow!($($t)*)) };
}

pub use crate::{anyhow, bail};

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Result<u32> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let v = io_err()?;
            Ok(v)
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad {} of {}", 3, "five");
        assert_eq!(e.to_string(), "bad 3 of five");
        fn bails() -> Result<()> {
            bail!("stop {}", 42)
        }
        assert_eq!(bails().unwrap_err().to_string(), "stop 42");
    }

    #[test]
    fn context_prepends() {
        let r: Result<u32> = io_err().with_context(|| "reading config");
        let msg = r.unwrap_err().to_string();
        assert!(msg.starts_with("reading config: "), "{msg}");
        let r2: Result<u32> = io_err().context("fixed");
        assert!(r2.unwrap_err().to_string().starts_with("fixed: "));
    }

    #[test]
    fn defaulted_result_alias_is_two_param() {
        let r: Result<u32, String> = Err("plain".into());
        assert_eq!(r.unwrap_err(), "plain");
    }
}

//! Mini-criterion: a self-contained benchmark runner.
//!
//! The `criterion` crate is not vendored in this environment, so `cargo
//! bench` targets (declared with `harness = false`) use this runner instead.
//! It provides warm-up, adaptive iteration counts, mean/σ/min/max reporting,
//! a `black_box` sink, and markdown-style result tables that the paper-table
//! benches print alongside their timing rows.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

use super::stats;

/// Re-exported opaque value sink (prevents the optimizer from deleting the
/// benched computation).
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// One measured benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Debug)]
pub struct Bencher {
    /// Wall-clock budget for the measurement phase of each benchmark.
    pub measure_time: Duration,
    /// Wall-clock budget for warm-up.
    pub warmup_time: Duration,
    /// Number of sample batches collected.
    pub samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            measure_time: Duration::from_millis(800),
            warmup_time: Duration::from_millis(200),
            samples: 20,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick preset for long-running end-to-end benches.
    pub fn quick() -> Self {
        Bencher {
            measure_time: Duration::from_millis(300),
            warmup_time: Duration::from_millis(50),
            samples: 8,
            results: Vec::new(),
        }
    }

    /// Run `f` repeatedly and record a timing row under `name`.
    /// The closure's return value is black-boxed.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Warm-up: also estimates the per-iteration cost.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup_time || warm_iters == 0 {
            black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_nanos() as f64 / warm_iters as f64;

        // Choose batch size so that one batch is ≥ ~50µs (amortizes timer
        // overhead) and the whole measurement fits the budget.
        let batch = ((50_000.0 / per_iter).ceil() as u64).max(1);
        let total_budget_ns = self.measure_time.as_nanos() as f64;
        let max_batches = (total_budget_ns / (per_iter * batch as f64)).ceil() as usize;
        let batches = self.samples.min(max_batches.max(1));

        let mut sample_ns: Vec<f64> = Vec::with_capacity(batches);
        let mut total_iters = 0u64;
        for _ in 0..batches {
            let t = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            let el = t.elapsed().as_nanos() as f64 / batch as f64;
            sample_ns.push(el);
            total_iters += batch;
        }

        let res = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: stats::mean(&sample_ns),
            stddev_ns: stats::stddev(&sample_ns),
            min_ns: stats::min(&sample_ns),
            max_ns: stats::max(&sample_ns),
        };
        println!(
            "bench  {:<44} {:>12}  ±{:>10}  ({} iters)",
            res.name,
            fmt_ns(res.mean_ns),
            fmt_ns(res.stddev_ns),
            res.iters
        );
        self.results.push(res.clone());
        res
    }

    /// All recorded results.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print a summary table of all recorded results.
    pub fn summary(&self) {
        println!();
        println!("{:<46} {:>12} {:>12} {:>12}", "benchmark", "mean", "min", "max");
        println!("{}", "-".repeat(86));
        for r in &self.results {
            println!(
                "{:<46} {:>12} {:>12} {:>12}",
                r.name,
                fmt_ns(r.mean_ns),
                fmt_ns(r.min_ns),
                fmt_ns(r.max_ns)
            );
        }
    }
}

/// Markdown-ish table printer used by the paper-table benches: fixed column
/// widths, header rule, right-aligned numeric columns.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        let mut rule = String::from("|");
        for w in &widths {
            rule.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        println!("{rule}");
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

/// Format a byte count the way the paper does (KB = 1024 B, one decimal).
pub fn fmt_kb(bytes: usize) -> String {
    format!("{:.1}KB", bytes as f64 / 1024.0)
}

/// Write a machine-readable benchmark report `BENCH_<name>.json` into the
/// current directory: named scalar metrics plus every recorded timing row.
/// The perf/memory trajectory across PRs is tracked from these files.
pub fn write_json_report(
    name: &str,
    metrics: &[(String, f64)],
    timings: &[BenchResult],
) -> std::io::Result<String> {
    write_json_report_to(std::path::Path::new("."), name, metrics, timings)
}

/// [`write_json_report`] into an explicit directory.
pub fn write_json_report_to(
    dir: &std::path::Path,
    name: &str,
    metrics: &[(String, f64)],
    timings: &[BenchResult],
) -> std::io::Result<String> {
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    let mut m: BTreeMap<String, Json> = BTreeMap::new();
    for (k, v) in metrics {
        m.insert(k.clone(), Json::Num(*v));
    }
    let rows: Vec<Json> = timings
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::Str(r.name.clone())),
                ("mean_ns", Json::Num(r.mean_ns)),
                ("stddev_ns", Json::Num(r.stddev_ns)),
                ("min_ns", Json::Num(r.min_ns)),
                ("max_ns", Json::Num(r.max_ns)),
                ("iters", Json::Num(r.iters as f64)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::Str(name.to_string())),
        ("metrics", Json::Obj(m)),
        ("timings", Json::Arr(rows)),
    ]);
    let path = dir.join(format!("BENCH_{name}.json"));
    std::fs::write(&path, doc.to_pretty())?;
    Ok(path.display().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher {
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(5),
            samples: 4,
            results: Vec::new(),
        };
        let r = b.bench("sum", || (0..1000u64).sum::<u64>());
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn table_prints_consistent_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["x".into(), "1".into()]);
        t.print(); // smoke: no panic
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ns(12.3), "12.3 ns");
        assert_eq!(fmt_ns(12_300.0), "12.30 µs");
        assert_eq!(fmt_kb(2048), "2.0KB");
    }

    #[test]
    fn json_report_roundtrips() {
        use crate::util::json::Json;
        let dir = std::env::temp_dir();
        let timings = [BenchResult {
            name: "x/y".into(),
            iters: 10,
            mean_ns: 1.5,
            stddev_ns: 0.1,
            min_ns: 1.0,
            max_ns: 2.0,
        }];
        let metrics = [("model.peak".to_string(), 55296.0)];
        let path = write_json_report_to(&dir, "unit_test", &metrics, &timings).unwrap();
        let src = std::fs::read_to_string(&path).unwrap();
        let v = Json::parse(&src).unwrap();
        assert_eq!(v.get("bench").as_str(), Some("unit_test"));
        assert_eq!(v.get("metrics").get("model.peak").as_f64(), Some(55296.0));
        assert_eq!(v.get("timings").as_arr().unwrap().len(), 1);
        let _ = std::fs::remove_file(&path);
    }
}

//! Small statistics helpers shared by the bench harness and the coordinator
//! metrics (mean/stddev, percentiles, simple linear fits for calibration).

/// Arithmetic mean of a sample. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator). Returns 0.0 for n < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Minimum of a sample; 0.0 when empty.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min).min(f64::INFINITY)
        .pipe_finite()
}

/// Maximum of a sample; 0.0 when empty.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max).pipe_finite()
}

trait PipeFinite {
    fn pipe_finite(self) -> f64;
}

impl PipeFinite for f64 {
    fn pipe_finite(self) -> f64 {
        if self.is_finite() {
            self
        } else {
            0.0
        }
    }
}

/// Percentile via linear interpolation on the sorted sample (`p` in [0,100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Online latency histogram for the serving coordinator: fixed log-spaced
/// buckets from 1µs to ~67s, O(1) record, approximate percentiles.
#[derive(Clone, Debug)]
pub struct LatencyHist {
    buckets: Vec<u64>,
    count: u64,
    sum_us: f64,
    max_us: f64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    const NBUCKETS: usize = 64; // bucket i covers [2^(i/2.46)...] — log spaced

    pub fn new() -> Self {
        LatencyHist { buckets: vec![0; Self::NBUCKETS], count: 0, sum_us: 0.0, max_us: 0.0 }
    }

    fn bucket_of(us: f64) -> usize {
        if us <= 1.0 {
            return 0;
        }
        // ~3.9 buckets per decade across 1µs..67s.
        let idx = (us.log2() * 2.46).floor() as usize;
        idx.min(Self::NBUCKETS - 1)
    }

    fn bucket_upper(i: usize) -> f64 {
        2f64.powf((i + 1) as f64 / 2.46)
    }

    pub fn record_us(&mut self, us: f64) {
        self.buckets[Self::bucket_of(us)] += 1;
        self.count += 1;
        self.sum_us += us;
        if us > self.max_us {
            self.max_us = us;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us / self.count as f64
        }
    }

    pub fn max_us(&self) -> f64 {
        self.max_us
    }

    /// Approximate percentile: upper edge of the bucket holding the p-th
    /// sample.
    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                return Self::bucket_upper(i).min(self.max_us.max(1.0));
            }
        }
        self.max_us
    }
}

/// Least-squares fit y = a + b*x; returns (a, b). Used to calibrate the MCU
/// cycle model against reference timing points.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let sx = xs.iter().sum::<f64>();
    let sy = ys.iter().sum::<f64>();
    let sxx = xs.iter().map(|x| x * x).sum::<f64>();
    let sxy = xs.iter().zip(ys).map(|(x, y)| x * y).sum::<f64>();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return (mean(ys), 0.0);
    }
    let b = (n * sxy - sx * sy) / denom;
    let a = (sy - b * sx) / n;
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn empty_samples_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(min(&[]), 0.0);
        assert_eq!(max(&[]), 0.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn latency_hist_percentiles_ordered() {
        let mut h = LatencyHist::new();
        for i in 1..=1000 {
            h.record_us(i as f64);
        }
        let p50 = h.percentile_us(50.0);
        let p99 = h.percentile_us(99.0);
        assert!(p50 <= p99, "p50={p50} p99={p99}");
        assert!(p50 > 300.0 && p50 < 900.0, "p50={p50}");
        assert_eq!(h.count(), 1000);
        assert!((h.mean_us() - 500.5).abs() < 1e-9);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0]; // y = 1 + 2x
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn linear_fit_degenerate_x() {
        let (a, b) = linear_fit(&[2.0, 2.0], &[1.0, 3.0]);
        assert_eq!(b, 0.0);
        assert!((a - 2.0).abs() < 1e-9);
    }
}

//! Fixed-capacity bit set over `Vec<u64>` words.
//!
//! Algorithm 1 memoizes on *sets of tensors*; those sets are the hash keys of
//! the DP table and the operands of ancestor checks, so they need O(1)-ish
//! hashing, fast union/difference, and cheap iteration. Word-packed bitsets
//! give all three. Capacity is fixed at construction (the graph's tensor
//! count) so equality/hash are well-defined across all sets of one graph.

use std::fmt;

/// A set of small integers `0..capacity`.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct BitSet {
    words: Vec<u64>,
    capacity: usize,
}

impl BitSet {
    /// Empty set with room for `capacity` elements.
    pub fn new(capacity: usize) -> Self {
        BitSet { words: vec![0; capacity.div_ceil(64)], capacity }
    }

    /// Set containing the given elements.
    pub fn from_iter(capacity: usize, items: impl IntoIterator<Item = usize>) -> Self {
        let mut s = BitSet::new(capacity);
        for i in items {
            s.insert(i);
        }
        s
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    #[inline]
    pub fn insert(&mut self, i: usize) {
        debug_assert!(i < self.capacity, "bit {i} out of capacity {}", self.capacity);
        self.words[i / 64] |= 1 << (i % 64);
    }

    #[inline]
    pub fn remove(&mut self, i: usize) {
        debug_assert!(i < self.capacity);
        self.words[i / 64] &= !(1 << (i % 64));
    }

    #[inline]
    pub fn contains(&self, i: usize) -> bool {
        if i >= self.capacity {
            return false;
        }
        self.words[i / 64] & (1 << (i % 64)) != 0
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// In-place union.
    pub fn union_with(&mut self, other: &BitSet) {
        debug_assert_eq!(self.capacity, other.capacity);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// In-place difference (`self \ other`).
    pub fn difference_with(&mut self, other: &BitSet) {
        debug_assert_eq!(self.capacity, other.capacity);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= !b;
        }
    }

    /// Does `self` intersect `other`?
    pub fn intersects(&self, other: &BitSet) -> bool {
        debug_assert_eq!(self.capacity, other.capacity);
        self.words.iter().zip(&other.words).any(|(a, b)| a & b != 0)
    }

    /// Is `self` a subset of `other`?
    pub fn is_subset(&self, other: &BitSet) -> bool {
        debug_assert_eq!(self.capacity, other.capacity);
        self.words.iter().zip(&other.words).all(|(a, b)| a & !b == 0)
    }

    /// Iterate elements in increasing order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut bits = w;
            std::iter::from_fn(move || {
                if bits == 0 {
                    None
                } else {
                    let tz = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    Some(wi * 64 + tz)
                }
            })
        })
    }

    /// Copy with element `i` inserted.
    pub fn with(&self, i: usize) -> BitSet {
        let mut s = self.clone();
        s.insert(i);
        s
    }

    /// Copy with element `i` removed.
    pub fn without(&self, i: usize) -> BitSet {
        let mut s = self.clone();
        s.remove(i);
        s
    }
}

impl fmt::Debug for BitSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{")?;
        for (n, i) in self.iter().enumerate() {
            if n > 0 {
                write!(f, ",")?;
            }
            write!(f, "{i}")?;
        }
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_remove_contains() {
        let mut s = BitSet::new(130);
        s.insert(0);
        s.insert(64);
        s.insert(129);
        assert!(s.contains(0) && s.contains(64) && s.contains(129));
        assert!(!s.contains(1) && !s.contains(128));
        s.remove(64);
        assert!(!s.contains(64));
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn iter_in_order() {
        let s = BitSet::from_iter(200, [5, 190, 63, 64, 0]);
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![0, 5, 63, 64, 190]);
    }

    #[test]
    fn union_difference() {
        let a = BitSet::from_iter(100, [1, 2, 3]);
        let b = BitSet::from_iter(100, [3, 4]);
        let mut u = a.clone();
        u.union_with(&b);
        assert_eq!(u.iter().collect::<Vec<_>>(), vec![1, 2, 3, 4]);
        let mut d = a.clone();
        d.difference_with(&b);
        assert_eq!(d.iter().collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn subset_intersects() {
        let a = BitSet::from_iter(70, [1, 65]);
        let b = BitSet::from_iter(70, [1, 2, 65]);
        assert!(a.is_subset(&b));
        assert!(!b.is_subset(&a));
        assert!(a.intersects(&b));
        let c = BitSet::from_iter(70, [3]);
        assert!(!a.intersects(&c));
    }

    #[test]
    fn hash_equality_for_same_contents() {
        use std::collections::HashSet;
        let mut set = HashSet::new();
        set.insert(BitSet::from_iter(64, [1, 5]));
        assert!(set.contains(&BitSet::from_iter(64, [5, 1])));
        assert!(!set.contains(&BitSet::from_iter(64, [1])));
    }

    #[test]
    fn with_without_are_copies() {
        let a = BitSet::from_iter(10, [1]);
        let b = a.with(2);
        assert!(!a.contains(2) && b.contains(2));
        let c = b.without(1);
        assert!(b.contains(1) && !c.contains(1));
    }

    #[test]
    fn empty_and_len() {
        let s = BitSet::new(0);
        assert!(s.is_empty());
        let s = BitSet::from_iter(10, []);
        assert!(s.is_empty());
        assert_eq!(s.len(), 0);
    }
}

//! Inference-graph optimization passes (§2.1 of the paper).
//!
//! "Modern deep learning frameworks optimise the network's computation graph
//! for inference in advance by e.g. fusing adjacent operators and folding
//! batch normalisation layers into preceding linear operations." These
//! passes implement exactly that, ahead of scheduling:
//!
//! - [`fuse_activations`] — standalone `Relu`/`Relu6` ops following a
//!   conv/dense with no other consumer are folded into the producer's fused
//!   activation, removing one op *and one SRAM tensor* per fusion (this is
//!   why fused graphs have smaller working sets).
//! - [`fold_batchnorm`] — `BatchNorm` ops following a conv/dense are folded
//!   into the preceding weights/bias (`w' = w·γ/√(σ²+ε)`,
//!   `b' = (b−μ)·γ/√(σ²+ε) + β`), removing the op, its SRAM tensor and its
//!   four parameter tensors.
//! - [`eliminate_dead_ops`] — removes operators whose results cannot reach
//!   any graph output (and their now-unused weights).
//!
//! Every pass rebuilds the graph (ids are re-densified) and returns a
//! [`TensorMap`] from old to new tensor ids so weight stores can be
//! remapped; [`remap_weights`] does that. Numeric equivalence of the
//! transformed graphs is covered by interpreter-level tests.

use std::collections::HashMap;

use super::{Act, Graph, Op, OpKind, Tensor, TensorId};

/// Old-tensor-id → new-tensor-id mapping produced by a rebuild. Tensors
/// removed by the pass are absent.
pub type TensorMap = HashMap<TensorId, TensorId>;

/// Copy `g` while dropping the ops in `drop` (their outputs are rewired to
/// `alias[out]` when provided) and applying `patch_kind` to surviving ops.
fn rebuild(
    g: &Graph,
    drop: &[bool],
    alias: &HashMap<TensorId, TensorId>,
    mut patch_kind: impl FnMut(&Op) -> OpKind,
    drop_weights_of_dropped: bool,
) -> (Graph, TensorMap) {
    // Resolve alias chains (a → b → c).
    let resolve = |mut t: TensorId| -> TensorId {
        let mut hops = 0;
        while let Some(&n) = alias.get(&t) {
            t = n;
            hops += 1;
            assert!(hops <= g.tensors.len(), "alias cycle");
        }
        t
    };

    // Which tensors survive: everything except dropped ops' outputs and
    // (optionally) their weights.
    let mut keep_tensor = vec![true; g.tensors.len()];
    for op in &g.ops {
        if drop[op.id] {
            keep_tensor[op.output] = false;
            if drop_weights_of_dropped {
                for &w in &op.weights {
                    keep_tensor[w] = false;
                }
            }
        }
    }
    // Weights only referenced by dropped ops die with them.

    let mut out = Graph::new(g.name.clone());
    let mut tmap: TensorMap = HashMap::new();
    for t in &g.tensors {
        if !keep_tensor[t.id] {
            continue;
        }
        let new_id = out.tensors.len();
        tmap.insert(t.id, new_id);
        out.tensors.push(Tensor {
            id: new_id,
            name: t.name.clone(),
            shape: t.shape.clone(),
            dtype: t.dtype,
            producer: None,
            consumers: Vec::new(),
            is_weight: t.is_weight,
        });
    }

    for op in &g.ops {
        if drop[op.id] {
            continue;
        }
        let new_id = out.ops.len();
        let inputs: Vec<TensorId> =
            op.inputs.iter().map(|&t| tmap[&resolve(t)]).collect();
        let weights: Vec<TensorId> = op.weights.iter().map(|&t| tmap[&t]).collect();
        let output = tmap[&op.output];
        out.tensors[output].producer = Some(new_id);
        for &t in inputs.iter().chain(&weights) {
            out.tensors[t].consumers.push(new_id);
        }
        out.ops.push(Op {
            id: new_id,
            name: op.name.clone(),
            kind: patch_kind(op),
            inputs,
            weights,
            output,
        });
    }

    out.inputs = g.inputs.iter().map(|&t| tmap[&resolve(t)]).collect();
    out.outputs = g.outputs.iter().map(|&t| tmap[&resolve(t)]).collect();
    (out, tmap)
}

fn is_fusible_producer(kind: &OpKind) -> bool {
    matches!(
        kind,
        OpKind::Conv2D { act: Act::Linear, .. }
            | OpKind::DepthwiseConv2D { act: Act::Linear, .. }
            | OpKind::Dense { act: Act::Linear }
    )
}

fn with_act(kind: &OpKind, act: Act) -> OpKind {
    match kind.clone() {
        OpKind::Conv2D { kernel, stride, padding, .. } => {
            OpKind::Conv2D { kernel, stride, padding, act }
        }
        OpKind::DepthwiseConv2D { kernel, stride, padding, .. } => {
            OpKind::DepthwiseConv2D { kernel, stride, padding, act }
        }
        OpKind::Dense { .. } => OpKind::Dense { act },
        other => other,
    }
}

/// Fuse standalone `Relu`/`Relu6` ops into their (linear) producers.
/// Returns the new graph, the tensor map, and how many ops were fused.
pub fn fuse_activations(g: &Graph) -> (Graph, TensorMap, usize) {
    let mut drop = vec![false; g.ops.len()];
    let mut alias: HashMap<TensorId, TensorId> = HashMap::new();
    let mut new_act: HashMap<usize, Act> = HashMap::new();

    for op in &g.ops {
        let act = match op.kind {
            OpKind::Relu => Act::Relu,
            OpKind::Relu6 => Act::Relu6,
            _ => continue,
        };
        let src = op.inputs[0];
        let Some(prod) = g.tensors[src].producer else { continue };
        // The producer's output must feed only this activation (otherwise
        // the pre-activation value is observable elsewhere).
        let act_consumers =
            g.tensors[src].consumers.iter().filter(|&&c| g.ops[c].inputs.contains(&src)).count();
        if act_consumers != 1 || g.outputs.contains(&src) {
            continue;
        }
        if !is_fusible_producer(&g.ops[prod].kind) || new_act.contains_key(&prod) {
            continue;
        }
        drop[op.id] = true;
        alias.insert(op.output, src);
        new_act.insert(prod, act);
    }

    let fused = new_act.len();
    let (out, tmap) = rebuild(
        g,
        &drop,
        &alias,
        |op| match new_act.get(&op.id) {
            Some(&act) => with_act(&op.kind, act),
            None => op.kind.clone(),
        },
        true,
    );
    (out, tmap, fused)
}

/// Fold `BatchNorm` ops into the preceding conv/dense. Returns the new
/// graph, the tensor map, the list of `(conv_op_new_name, bn_params)` folds
/// to apply to weight data (see [`fold_batchnorm_weights`]), and the fold
/// count.
pub fn fold_batchnorm(g: &Graph) -> (Graph, TensorMap, Vec<FoldedBn>, usize) {
    let mut drop = vec![false; g.ops.len()];
    let mut alias: HashMap<TensorId, TensorId> = HashMap::new();
    let mut folds: Vec<FoldedBn> = Vec::new();

    for op in &g.ops {
        let OpKind::BatchNorm { eps } = op.kind else { continue };
        let src = op.inputs[0];
        let Some(prod) = g.tensors[src].producer else { continue };
        let act_consumers =
            g.tensors[src].consumers.iter().filter(|&&c| g.ops[c].inputs.contains(&src)).count();
        if act_consumers != 1 || g.outputs.contains(&src) {
            continue;
        }
        // Only fold into linear producers whose activation is still linear
        // (BN after ReLU cannot fold).
        if !is_fusible_producer(&g.ops[prod].kind) {
            continue;
        }
        drop[op.id] = true;
        alias.insert(op.output, src);
        folds.push(FoldedBn {
            producer_name: g.ops[prod].name.clone(),
            gamma: op.weights[0],
            beta: op.weights[1],
            mean: op.weights[2],
            var: op.weights[3],
            eps,
        });
    }

    let n = folds.len();
    let (out, tmap) = rebuild(g, &drop, &alias, |op| op.kind.clone(), false);
    (out, tmap, folds, n)
}

/// A batch-norm fold: which producer absorbs which (old-graph) parameter
/// tensors.
#[derive(Clone, Debug)]
pub struct FoldedBn {
    pub producer_name: String,
    pub gamma: TensorId,
    pub beta: TensorId,
    pub mean: TensorId,
    pub var: TensorId,
    pub eps: f32,
}

/// Remove ops that cannot reach any graph output. Returns the new graph,
/// tensor map, and the number of removed ops.
pub fn eliminate_dead_ops(g: &Graph) -> (Graph, TensorMap, usize) {
    let mut live = vec![false; g.tensors.len()];
    let mut stack: Vec<TensorId> = g.outputs.clone();
    while let Some(t) = stack.pop() {
        if live[t] {
            continue;
        }
        live[t] = true;
        if let Some(p) = g.tensors[t].producer {
            for &i in &g.ops[p].inputs {
                stack.push(i);
            }
        }
    }
    let drop: Vec<bool> = g.ops.iter().map(|op| !live[op.output]).collect();
    let removed = drop.iter().filter(|&&d| d).count();
    let (out, tmap) = rebuild(g, &drop, &HashMap::new(), |op| op.kind.clone(), true);
    (out, tmap, removed)
}

/// Remap a weight store across a rebuild, dropping entries for removed
/// tensors.
pub fn remap_weights(
    ws: &crate::interp::WeightStore,
    tmap: &TensorMap,
) -> crate::interp::WeightStore {
    let mut out = crate::interp::WeightStore::default();
    for (old, data) in &ws.data {
        if let Some(&new) = tmap.get(old) {
            out.data.insert(new, data.clone());
        }
    }
    for (old, qp) in &ws.qparams {
        if let Some(&new) = tmap.get(old) {
            out.qparams.insert(new, *qp);
        }
    }
    out
}

/// Apply batch-norm folds to f32 weight data: for each fold, rescale the
/// producer's weights and bias in `ws` (already remapped to the new graph).
pub fn fold_batchnorm_weights(
    new_g: &Graph,
    ws: &mut crate::interp::WeightStore,
    old_ws: &crate::interp::WeightStore,
    folds: &[FoldedBn],
) {
    use crate::interp::TensorData;
    for fold in folds {
        let op = new_g.op_by_name(&fold.producer_name).expect("folded producer exists");
        let gamma = old_ws.data[&fold.gamma].as_f32().unwrap();
        let beta = old_ws.data[&fold.beta].as_f32().unwrap();
        let mean = old_ws.data[&fold.mean].as_f32().unwrap();
        let var = old_ws.data[&fold.var].as_f32().unwrap();
        let c = gamma.len();
        let scale: Vec<f32> =
            (0..c).map(|i| gamma[i] / (var[i] + fold.eps).sqrt()).collect();

        // Weights: last axis (cout / c) is the normalized channel for all
        // three producer kinds (HWIO conv, HWC dwconv, [in,out] dense).
        let w_id = op.weights[0];
        let w = ws.data.get_mut(&w_id).unwrap();
        if let TensorData::F32(wv) = w {
            let n = wv.len();
            assert_eq!(n % c, 0, "weight not divisible by channels");
            for (i, v) in wv.iter_mut().enumerate() {
                *v *= scale[i % c];
            }
        } else {
            panic!("batchnorm folding requires f32 weights");
        }
        let b_id = op.weights[1];
        let b = ws.data.get_mut(&b_id).unwrap();
        if let TensorData::F32(bv) = b {
            for i in 0..c {
                bv[i] = (bv[i] - mean[i]) * scale[i] + beta[i];
            }
        } else {
            panic!("batchnorm folding requires f32 bias");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, GraphBuilder, Padding};
    use crate::interp::{ExecConfig, Interpreter, TensorData, WeightStore};
    use crate::sched;

    fn unfused_cnn() -> Graph {
        let mut b = GraphBuilder::new("unfused");
        let x = b.input("x", &[1, 8, 8, 2], DType::F32);
        let c1 = b.conv2d("c1", x, 4, (3, 3), (1, 1), Padding::Same, Act::Linear);
        let r1 = b.relu6("r1", c1);
        let dw = b.dwconv2d("dw", r1, (3, 3), (2, 2), Padding::Same, Act::Linear);
        let r2 = b.relu("r2", dw);
        let pw = b.conv2d("pw", r1, 4, (1, 1), (2, 2), Padding::Same, Act::Linear);
        let cat = b.concat("cat", &[r2, pw]);
        let gap = b.global_avgpool("gap", cat);
        let fc = b.dense("fc", gap, 3, Act::Linear);
        let sm = b.softmax("sm", fc);
        b.output(sm);
        b.finish().unwrap()
    }

    #[test]
    fn fuse_removes_relu_ops_and_tensors() {
        let g = unfused_cnn();
        let (fused, _, n) = fuse_activations(&g);
        fused.validate().unwrap();
        assert_eq!(n, 2);
        assert_eq!(fused.n_ops(), g.n_ops() - 2);
        // c1 keeps Relu6, dw keeps Relu; pw stays linear (it feeds concat).
        match &fused.op_by_name("c1").unwrap().kind {
            OpKind::Conv2D { act, .. } => assert_eq!(*act, Act::Relu6),
            k => panic!("{k:?}"),
        }
        match &fused.op_by_name("dw").unwrap().kind {
            OpKind::DepthwiseConv2D { act, .. } => assert_eq!(*act, Act::Relu),
            k => panic!("{k:?}"),
        }
    }

    #[test]
    fn fusion_reduces_peak_memory() {
        let g = unfused_cnn();
        let (fused, _, _) = fuse_activations(&g);
        let before = sched::peak_of(&g, &g.default_order());
        let after = sched::peak_of(&fused, &fused.default_order());
        assert!(after < before, "fusion should shrink the working set ({before} → {after})");
    }

    #[test]
    fn fusion_preserves_numerics() {
        let g = unfused_cnn();
        let ws = WeightStore::seeded_f32(&g, 5);
        let (fused, tmap, _) = fuse_activations(&g);
        let ws_fused = remap_weights(&ws, &tmap);
        let input = TensorData::F32((0..128).map(|i| (i as f32 - 64.0) / 32.0).collect());
        let a = Interpreter::new(&g, ws, ExecConfig::with_capacity(1 << 20))
            .run(&[input.clone()])
            .unwrap();
        let b = Interpreter::new(&fused, ws_fused, ExecConfig::with_capacity(1 << 20))
            .run(&[input])
            .unwrap();
        assert_eq!(a.outputs, b.outputs);
    }

    #[test]
    fn fuse_skips_multi_consumer_preactivation() {
        // relu input also consumed by another op → cannot fuse.
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 4, 4, 2], DType::F32);
        let c = b.conv2d("c", x, 2, (1, 1), (1, 1), Padding::Same, Act::Linear);
        let r = b.relu("r", c);
        let other = b.relu6("other", c); // second consumer of c
        let cat = b.concat("cat", &[r, other]);
        b.output(cat);
        let g = b.finish().unwrap();
        let (fused, _, n) = fuse_activations(&g);
        assert_eq!(n, 0);
        assert_eq!(fused.n_ops(), g.n_ops());
    }

    fn bn_cnn() -> Graph {
        let mut b = GraphBuilder::new("bn");
        let x = b.input("x", &[1, 6, 6, 3], DType::F32);
        let c1 = b.conv2d("c1", x, 4, (3, 3), (1, 1), Padding::Same, Act::Linear);
        let bn1 = b.batchnorm("bn1", c1, 1e-3);
        let dw = b.dwconv2d("dw", bn1, (3, 3), (1, 1), Padding::Same, Act::Linear);
        let bn2 = b.batchnorm("bn2", dw, 1e-3);
        let gap = b.global_avgpool("gap", bn2);
        let fc = b.dense("fc", gap, 2, Act::Linear);
        b.output(fc);
        b.finish().unwrap()
    }

    #[test]
    fn batchnorm_folds_structurally() {
        let g = bn_cnn();
        let (folded, _, _, n) = fold_batchnorm(&g);
        folded.validate().unwrap();
        assert_eq!(n, 2);
        assert_eq!(folded.n_ops(), g.n_ops() - 2);
        assert!(folded.op_by_name("bn1").is_none());
        // BN params remain as (now-dead) weights? No — they were only
        // consumed by the BN ops, which are gone; they are unreferenced but
        // kept by the rebuild (drop_weights_of_dropped = false) so the fold
        // can read them; model_size shrinks only after remap. Structure OK:
        assert!(folded.tensor_by_name("c1").is_some());
    }

    #[test]
    fn batchnorm_fold_preserves_numerics() {
        let g = bn_cnn();
        let mut ws = WeightStore::seeded_f32(&g, 9);
        // Make BN params non-trivial: gamma ~ U(0.5, 1.5), var > 0.
        for op in &g.ops {
            if let OpKind::BatchNorm { .. } = op.kind {
                let c = g.tensors[op.weights[0]].elems();
                let mut rng = crate::util::rng::Rng::new(op.id as u64 + 77);
                let gamma: Vec<f32> = (0..c).map(|_| rng.f32_range(0.5, 1.5)).collect();
                let beta: Vec<f32> = (0..c).map(|_| rng.f32_range(-0.3, 0.3)).collect();
                let mean: Vec<f32> = (0..c).map(|_| rng.f32_range(-0.2, 0.2)).collect();
                let var: Vec<f32> = (0..c).map(|_| rng.f32_range(0.1, 2.0)).collect();
                ws.data.insert(op.weights[0], TensorData::F32(gamma));
                ws.data.insert(op.weights[1], TensorData::F32(beta));
                ws.data.insert(op.weights[2], TensorData::F32(mean));
                ws.data.insert(op.weights[3], TensorData::F32(var));
            }
        }
        let input = TensorData::F32((0..108).map(|i| (i as f32 - 50.0) / 25.0).collect());
        let base = Interpreter::new(&g, ws.clone(), ExecConfig::with_capacity(1 << 20))
            .run(&[input.clone()])
            .unwrap();

        let (folded, tmap, folds, _) = fold_batchnorm(&g);
        let mut ws_new = remap_weights(&ws, &tmap);
        fold_batchnorm_weights(&folded, &mut ws_new, &ws, &folds);
        let out = Interpreter::new(&folded, ws_new, ExecConfig::with_capacity(1 << 20))
            .run(&[input])
            .unwrap();
        let a = base.outputs[0].as_f32().unwrap();
        let b = out.outputs[0].as_f32().unwrap();
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn dead_op_elimination() {
        let mut b = GraphBuilder::new("dead");
        let x = b.input("x", &[64], DType::U8);
        let live = b.synthetic("live", &[x], 64, 0);
        let _dead = b.synthetic("dead", &[x], 64, 0);
        let out = b.synthetic("out", &[live], 64, 0);
        b.output(out);
        let g = b.finish().unwrap();
        let (cleaned, _, removed) = eliminate_dead_ops(&g);
        assert_eq!(removed, 1);
        assert_eq!(cleaned.n_ops(), 2);
        cleaned.validate().unwrap();
        assert!(cleaned.op_by_name("dead").is_none());
    }

    #[test]
    fn passes_compose_on_unfused_bn_network() {
        // conv → bn → relu chains: fold bn first, then fuse relu.
        let mut b = GraphBuilder::new("full");
        let x = b.input("x", &[1, 6, 6, 3], DType::F32);
        let c = b.conv2d("c", x, 4, (3, 3), (1, 1), Padding::Same, Act::Linear);
        let bn = b.batchnorm("bn", c, 1e-3);
        let r = b.relu6("r", bn);
        let gap = b.global_avgpool("gap", r);
        let fc = b.dense("fc", gap, 2, Act::Linear);
        b.output(fc);
        let g = b.finish().unwrap();

        let (g1, _, _, n_bn) = fold_batchnorm(&g);
        assert_eq!(n_bn, 1);
        let (g2, _, n_act) = fuse_activations(&g1);
        assert_eq!(n_act, 1);
        assert_eq!(g2.n_ops(), g.n_ops() - 2);
        match &g2.op_by_name("c").unwrap().kind {
            OpKind::Conv2D { act, .. } => assert_eq!(*act, Act::Relu6),
            k => panic!("{k:?}"),
        }
    }
}

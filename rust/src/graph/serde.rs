//! Model file (de)serialization.
//!
//! The paper's tool rewrites TensorFlow Lite flatbuffers to embed a chosen
//! operator execution order. Our equivalent model container is a JSON
//! document holding the graph plus an optional `execution_order` field; the
//! `mcu-reorder optimize` CLI writes that field, and the interpreter/runtime
//! honour it when present (falling back to the default as-built order).

use std::collections::BTreeMap;

use super::{Act, DType, Graph, Op, OpId, OpKind, Padding, SplitAxis, Tensor};
use crate::util::json::Json;

/// A graph plus an optional embedded execution order — the on-disk model.
#[derive(Clone, Debug)]
pub struct ModelFile {
    pub graph: Graph,
    pub execution_order: Option<Vec<OpId>>,
}

impl ModelFile {
    pub fn new(graph: Graph) -> Self {
        ModelFile { graph, execution_order: None }
    }

    /// The order the interpreter should run: embedded if present, else the
    /// as-built default.
    pub fn effective_order(&self) -> Vec<OpId> {
        self.execution_order.clone().unwrap_or_else(|| self.graph.default_order())
    }

    /// Serialize to pretty JSON.
    pub fn to_json(&self) -> String {
        graph_to_json(&self.graph, self.execution_order.as_deref()).to_pretty()
    }

    /// Parse from JSON, validating the graph and (when present) the
    /// embedded order.
    pub fn from_json(src: &str) -> Result<ModelFile, String> {
        let v = Json::parse(src).map_err(|e| e.to_string())?;
        let (graph, order) = graph_from_json(&v)?;
        graph.validate().map_err(|e| format!("invalid graph: {e}"))?;
        if let Some(ref o) = order {
            graph.check_order(o).map_err(|e| format!("invalid embedded order: {e}"))?;
        }
        Ok(ModelFile { graph, execution_order: order })
    }
}

fn padding_str(p: Padding) -> &'static str {
    match p {
        Padding::Same => "same",
        Padding::Valid => "valid",
    }
}

fn padding_from(s: &str) -> Result<Padding, String> {
    match s {
        "same" => Ok(Padding::Same),
        "valid" => Ok(Padding::Valid),
        other => Err(format!("unknown padding {other:?}")),
    }
}

fn pair_json(p: (usize, usize)) -> Json {
    Json::arr_usize(&[p.0, p.1])
}

fn pair_from(v: &Json, what: &str) -> Result<(usize, usize), String> {
    let arr = v.as_arr().ok_or_else(|| format!("{what}: expected [a,b]"))?;
    if arr.len() != 2 {
        return Err(format!("{what}: expected 2 elements"));
    }
    Ok((
        arr[0].as_usize().ok_or_else(|| format!("{what}[0] not usize"))?,
        arr[1].as_usize().ok_or_else(|| format!("{what}[1] not usize"))?,
    ))
}

fn kind_to_json(kind: &OpKind) -> (String, Json) {
    let mut attrs: BTreeMap<String, Json> = BTreeMap::new();
    let name = kind.name().to_string();
    match kind {
        OpKind::Conv2D { kernel, stride, padding, act }
        | OpKind::DepthwiseConv2D { kernel, stride, padding, act } => {
            attrs.insert("kernel".into(), pair_json(*kernel));
            attrs.insert("stride".into(), pair_json(*stride));
            attrs.insert("padding".into(), Json::Str(padding_str(*padding).into()));
            attrs.insert("act".into(), Json::Str(act.name().into()));
        }
        OpKind::MaxPool2D { kernel, stride, padding }
        | OpKind::AvgPool2D { kernel, stride, padding } => {
            attrs.insert("kernel".into(), pair_json(*kernel));
            attrs.insert("stride".into(), pair_json(*stride));
            attrs.insert("padding".into(), Json::Str(padding_str(*padding).into()));
        }
        OpKind::Dense { act } => {
            attrs.insert("act".into(), Json::Str(act.name().into()));
        }
        OpKind::BatchNorm { eps } => {
            attrs.insert("eps".into(), Json::Num(*eps as f64));
        }
        OpKind::Synthetic { macs } => {
            attrs.insert("macs".into(), Json::Num(*macs as f64));
        }
        OpKind::Partial { inner, axis, pad, offset } => {
            let (inner_kind, inner_attrs) = kind_to_json(inner);
            attrs.insert("inner_kind".into(), Json::Str(inner_kind));
            attrs.insert("inner_attrs".into(), inner_attrs);
            attrs.insert("axis".into(), Json::Str(axis.name().into()));
            attrs.insert("pad".into(), Json::Num(*pad as f64));
            attrs.insert("offset".into(), Json::Num(*offset as f64));
        }
        OpKind::PartialInto { inner, axis, pad, offset, len } => {
            let (inner_kind, inner_attrs) = kind_to_json(inner);
            attrs.insert("inner_kind".into(), Json::Str(inner_kind));
            attrs.insert("inner_attrs".into(), inner_attrs);
            attrs.insert("axis".into(), Json::Str(axis.name().into()));
            attrs.insert("pad".into(), Json::Num(*pad as f64));
            attrs.insert("offset".into(), Json::Num(*offset as f64));
            attrs.insert("len".into(), Json::Num(*len as f64));
        }
        OpKind::ConcatSlices { axis } => {
            attrs.insert("axis".into(), Json::Str(axis.name().into()));
        }
        _ => {}
    }
    (name, Json::Obj(attrs))
}

/// Split axis from an op's attrs (absent = `default`, for files written
/// by the row-only splitter).
fn axis_from(attrs: &Json, default: SplitAxis) -> Result<SplitAxis, String> {
    match attrs.get("axis").as_str() {
        None => Ok(default),
        Some(s) => SplitAxis::from_name(s).ok_or_else(|| format!("unknown split axis {s:?}")),
    }
}

fn kind_from_json(name: &str, attrs: &Json) -> Result<OpKind, String> {
    let geom = || -> Result<((usize, usize), (usize, usize), Padding), String> {
        Ok((
            pair_from(attrs.get("kernel"), "kernel")?,
            pair_from(attrs.get("stride"), "stride")?,
            padding_from(attrs.get("padding").as_str().unwrap_or(""))?,
        ))
    };
    let act = || -> Result<Act, String> {
        Act::from_name(attrs.get("act").as_str().unwrap_or("linear"))
            .ok_or_else(|| "bad act".to_string())
    };
    match name {
        "Conv2D" => {
            let (kernel, stride, padding) = geom()?;
            Ok(OpKind::Conv2D { kernel, stride, padding, act: act()? })
        }
        "DepthwiseConv2D" => {
            let (kernel, stride, padding) = geom()?;
            Ok(OpKind::DepthwiseConv2D { kernel, stride, padding, act: act()? })
        }
        "MaxPool2D" => {
            let (kernel, stride, padding) = geom()?;
            Ok(OpKind::MaxPool2D { kernel, stride, padding })
        }
        "AvgPool2D" => {
            let (kernel, stride, padding) = geom()?;
            Ok(OpKind::AvgPool2D { kernel, stride, padding })
        }
        "Dense" => Ok(OpKind::Dense { act: act()? }),
        "Add" => Ok(OpKind::Add),
        "Concat" => Ok(OpKind::Concat),
        "Relu" => Ok(OpKind::Relu),
        "Relu6" => Ok(OpKind::Relu6),
        "GlobalAvgPool" => Ok(OpKind::GlobalAvgPool),
        "BatchNorm" => {
            let eps = attrs.get("eps").as_f64().unwrap_or(1e-3) as f32;
            Ok(OpKind::BatchNorm { eps })
        }
        "Softmax" => Ok(OpKind::Softmax),
        "Reshape" => Ok(OpKind::Reshape),
        "Synthetic" => {
            let macs = attrs.get("macs").as_f64().unwrap_or(0.0) as u64;
            Ok(OpKind::Synthetic { macs })
        }
        "Partial" | "PartialInto" => {
            let inner_kind = attrs
                .get("inner_kind")
                .as_str()
                .ok_or_else(|| format!("{name} missing inner_kind"))?;
            if inner_kind == "Partial" || inner_kind == "PartialInto" {
                return Err(format!("{name} ops do not nest"));
            }
            let inner = kind_from_json(inner_kind, attrs.get("inner_attrs"))?;
            let axis = axis_from(attrs, SplitAxis::Rows)?;
            // Files written before the axis generalization stored the
            // effective padding under "pad_top" (rows was the only axis).
            let pad = attrs
                .get("pad")
                .as_f64()
                .or_else(|| attrs.get("pad_top").as_f64())
                .unwrap_or(0.0) as isize;
            let offset = attrs.get("offset").as_f64().unwrap_or(0.0) as usize;
            if name == "PartialInto" {
                let len = attrs
                    .get("len")
                    .as_usize()
                    .ok_or_else(|| "PartialInto missing len".to_string())?;
                return Ok(OpKind::PartialInto { inner: Box::new(inner), axis, pad, offset, len });
            }
            Ok(OpKind::Partial { inner: Box::new(inner), axis, pad, offset })
        }
        "ConcatSlices" => Ok(OpKind::ConcatSlices { axis: axis_from(attrs, SplitAxis::Rows)? }),
        // Legacy name from the row-only splitter.
        "ConcatRows" => Ok(OpKind::ConcatSlices { axis: SplitAxis::Rows }),
        other => Err(format!("unknown op kind {other:?}")),
    }
}

/// Graph → JSON document.
pub fn graph_to_json(g: &Graph, order: Option<&[OpId]>) -> Json {
    let tensors: Vec<Json> = g
        .tensors
        .iter()
        .map(|t| {
            Json::obj(vec![
                ("id", Json::Num(t.id as f64)),
                ("name", Json::Str(t.name.clone())),
                ("shape", Json::arr_usize(&t.shape)),
                ("dtype", Json::Str(t.dtype.name().into())),
                ("weight", Json::Bool(t.is_weight)),
            ])
        })
        .collect();
    let ops: Vec<Json> = g
        .ops
        .iter()
        .map(|o| {
            let (kind, attrs) = kind_to_json(&o.kind);
            Json::obj(vec![
                ("id", Json::Num(o.id as f64)),
                ("name", Json::Str(o.name.clone())),
                ("kind", Json::Str(kind)),
                ("attrs", attrs),
                ("inputs", Json::arr_usize(&o.inputs)),
                ("weights", Json::arr_usize(&o.weights)),
                ("output", Json::Num(o.output as f64)),
            ])
        })
        .collect();
    let mut fields = vec![
        ("format", Json::Str("mcu-reorder/v1".into())),
        ("name", Json::Str(g.name.clone())),
        ("tensors", Json::Arr(tensors)),
        ("ops", Json::Arr(ops)),
        ("inputs", Json::arr_usize(&g.inputs)),
        ("outputs", Json::arr_usize(&g.outputs)),
    ];
    if let Some(o) = order {
        fields.push(("execution_order", Json::arr_usize(o)));
    }
    Json::obj(fields)
}

fn usize_arr(v: &Json, what: &str) -> Result<Vec<usize>, String> {
    v.as_arr()
        .ok_or_else(|| format!("{what}: expected array"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| format!("{what}: expected usize")))
        .collect()
}

/// JSON document → graph (+ optional embedded order). Does not validate.
pub fn graph_from_json(v: &Json) -> Result<(Graph, Option<Vec<OpId>>), String> {
    if v.get("format").as_str() != Some("mcu-reorder/v1") {
        return Err("missing or unknown 'format' field (want mcu-reorder/v1)".into());
    }
    let name = v.get("name").as_str().unwrap_or("model").to_string();
    let mut g = Graph::new(name);

    for (i, tj) in v.get("tensors").as_arr().ok_or("missing tensors")?.iter().enumerate() {
        let id = tj.get("id").as_usize().ok_or("tensor missing id")?;
        if id != i {
            return Err(format!("tensor ids must be dense, got {id} at index {i}"));
        }
        let dtype = DType::from_name(tj.get("dtype").as_str().unwrap_or(""))
            .ok_or_else(|| format!("tensor {id}: bad dtype"))?;
        g.tensors.push(Tensor {
            id,
            name: tj.get("name").as_str().unwrap_or("").to_string(),
            shape: usize_arr(tj.get("shape"), "shape")?,
            dtype,
            producer: None,
            consumers: Vec::new(),
            is_weight: tj.get("weight").as_bool().unwrap_or(false),
        });
    }

    for (i, oj) in v.get("ops").as_arr().ok_or("missing ops")?.iter().enumerate() {
        let id = oj.get("id").as_usize().ok_or("op missing id")?;
        if id != i {
            return Err(format!("op ids must be dense, got {id} at index {i}"));
        }
        let kind = kind_from_json(oj.get("kind").as_str().unwrap_or(""), oj.get("attrs"))?;
        let inputs = usize_arr(oj.get("inputs"), "op inputs")?;
        let weights = usize_arr(oj.get("weights"), "op weights")?;
        let output = oj.get("output").as_usize().ok_or("op missing output")?;
        for &t in inputs.iter().chain(&weights).chain(std::iter::once(&output)) {
            if t >= g.tensors.len() {
                return Err(format!("op {id} references unknown tensor {t}"));
            }
        }
        g.tensors[output].producer = Some(id);
        for &t in inputs.iter().chain(&weights) {
            g.tensors[t].consumers.push(id);
        }
        g.ops.push(Op {
            id,
            name: oj.get("name").as_str().unwrap_or("").to_string(),
            kind,
            inputs,
            weights,
            output,
        });
    }

    g.inputs = usize_arr(v.get("inputs"), "inputs")?;
    g.outputs = usize_arr(v.get("outputs"), "outputs")?;

    let order = match v.get("execution_order") {
        Json::Null => None,
        o => Some(usize_arr(o, "execution_order")?),
    };
    Ok((g, order))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Act, GraphBuilder};

    fn sample() -> Graph {
        let mut b = GraphBuilder::new("sample");
        let x = b.input("x", &[1, 16, 16, 3], DType::I8);
        let c1 = b.conv2d("c1", x, 8, (3, 3), (2, 2), Padding::Same, Act::Relu6);
        let l = b.dwconv2d("dw", c1, (3, 3), (1, 1), Padding::Same, Act::Relu6);
        let r = b.conv2d("pw", c1, 8, (1, 1), (1, 1), Padding::Same, Act::Relu6);
        let cat = b.concat("cat", &[l, r]);
        let gap = b.global_avgpool("gap", cat);
        let fc = b.dense("fc", gap, 2, Act::Linear);
        let sm = b.softmax("sm", fc);
        b.output(sm);
        b.finish().unwrap()
    }

    #[test]
    fn roundtrip_preserves_structure() {
        let g = sample();
        let mf = ModelFile::new(g.clone());
        let json = mf.to_json();
        let back = ModelFile::from_json(&json).unwrap();
        assert_eq!(back.graph.n_ops(), g.n_ops());
        assert_eq!(back.graph.n_tensors(), g.n_tensors());
        assert_eq!(back.graph.model_size(), g.model_size());
        assert_eq!(back.graph.activation_total(), g.activation_total());
        for (a, b) in g.ops.iter().zip(&back.graph.ops) {
            assert_eq!(a.kind, b.kind, "op {} kind", a.name);
            assert_eq!(a.inputs, b.inputs);
            assert_eq!(a.weights, b.weights);
            assert_eq!(a.output, b.output);
        }
    }

    #[test]
    fn roundtrip_preserves_order() {
        let g = sample();
        let order = g.topo_order().unwrap();
        let mf = ModelFile { graph: g, execution_order: Some(order.clone()) };
        let back = ModelFile::from_json(&mf.to_json()).unwrap();
        assert_eq!(back.execution_order, Some(order));
    }

    #[test]
    fn rejects_bad_embedded_order() {
        let g = sample();
        let n = g.n_ops();
        let mf = ModelFile { graph: g, execution_order: Some((0..n).rev().collect()) };
        let json = mf.to_json();
        assert!(ModelFile::from_json(&json).is_err());
    }

    #[test]
    fn rejects_unknown_format() {
        assert!(ModelFile::from_json(r#"{"format":"bogus"}"#).is_err());
        assert!(ModelFile::from_json("not json").is_err());
    }

    #[test]
    fn effective_order_falls_back_to_default() {
        let g = sample();
        let n = g.n_ops();
        let mf = ModelFile::new(g);
        assert_eq!(mf.effective_order(), (0..n).collect::<Vec<_>>());
    }

    /// Regression (PR-4 satellite): model files written by the PR-1
    /// row-only splitter — `ConcatRows` joins and `Partial` ops carrying
    /// `pad_top` with no `axis` attribute — must still load, and
    /// re-serialize to the axis-generic names without loss.
    #[test]
    fn legacy_row_split_json_upgrades_without_loss() {
        let mut b = GraphBuilder::new("legacy");
        let x = b.input("x", &[1, 8, 8, 2], DType::F32);
        let c1 = b.conv2d("c1", x, 4, (3, 3), (1, 1), Padding::Same, Act::Relu6);
        let r = b.relu("r", c1);
        let gap = b.global_avgpool("gap", r);
        b.output(gap);
        let g = b.finish().unwrap();
        let seg = crate::split::SegmentSplit {
            ops: vec![0, 1],
            factor: 2,
            axis: SplitAxis::Rows,
            elide: false,
        };
        let res = crate::split::apply_segment(&g, &seg).unwrap();
        let modern = ModelFile::new(res.graph.clone()).to_json();

        // Downgrade the document to the legacy field/kind names.
        let mut json = graph_to_json(&res.graph, None);
        let mut downgraded = 0usize;
        if let Json::Obj(ref mut doc) = json {
            if let Some(Json::Arr(ops)) = doc.get_mut("ops") {
                for op in ops.iter_mut() {
                    let Json::Obj(op) = op else { continue };
                    let kind = op.get("kind").and_then(|k| k.as_str().map(str::to_string));
                    match kind.as_deref() {
                        Some("ConcatSlices") => {
                            op.insert("kind".into(), Json::Str("ConcatRows".into()));
                            op.insert("attrs".into(), Json::Obj(Default::default()));
                            downgraded += 1;
                        }
                        Some("Partial") => {
                            let Some(Json::Obj(attrs)) = op.get_mut("attrs") else {
                                panic!("Partial without attrs")
                            };
                            let pad = attrs.remove("pad").expect("pad attr");
                            attrs.insert("pad_top".into(), pad);
                            attrs.remove("axis");
                            downgraded += 1;
                        }
                        _ => {}
                    }
                }
            }
        }
        assert_eq!(downgraded, 2 * 2 + 1, "2 slices x 2 ops + 1 join");

        // Legacy loads, upgrades to the axis-generic kinds…
        let back = ModelFile::from_json(&json.to_pretty()).unwrap();
        for (a, b) in res.graph.ops.iter().zip(&back.graph.ops) {
            assert_eq!(a.kind, b.kind, "op {}", a.name);
        }
        // …and re-serializes byte-identically to the modern document.
        assert_eq!(back.to_json(), modern);
    }

    /// PartialInto (join-elided slices) round-trips with its band extent.
    #[test]
    fn elided_split_json_roundtrips() {
        let mut b = GraphBuilder::new("elided");
        let x = b.input("x", &[1, 8, 8, 2], DType::I8);
        let c1 = b.conv2d("c1", x, 4, (3, 3), (1, 1), Padding::Same, Act::Relu6);
        let r = b.relu6("r", c1);
        let gap = b.global_avgpool("gap", r);
        b.output(gap);
        let g = b.finish().unwrap();
        let seg = crate::split::SegmentSplit {
            ops: vec![0, 1],
            factor: 2,
            axis: SplitAxis::Rows,
            elide: true,
        };
        let res = crate::split::apply_segment(&g, &seg).unwrap();
        let back = ModelFile::from_json(&ModelFile::new(res.graph.clone()).to_json()).unwrap();
        assert_eq!(back.graph.n_ops(), res.graph.n_ops());
        let mut saw_elided = 0;
        for (a, b) in res.graph.ops.iter().zip(&back.graph.ops) {
            assert_eq!(a.kind, b.kind, "op {}", a.name);
            if matches!(a.kind, OpKind::PartialInto { .. }) {
                saw_elided += 1;
            }
        }
        assert_eq!(saw_elided, 2, "one write-through slice per band");
        assert_eq!(
            crate::sched::peak_of(&back.graph, &back.graph.default_order()),
            crate::sched::peak_of(&res.graph, &res.graph.default_order())
        );
    }

    #[test]
    fn rejects_dangling_tensor_reference() {
        let g = sample();
        let mut json = graph_to_json(&g, None);
        // Corrupt: op 0 output -> out-of-range tensor.
        if let Json::Obj(ref mut o) = json {
            if let Some(Json::Arr(ops)) = o.get_mut("ops") {
                if let Json::Obj(op0) = &mut ops[0] {
                    op0.insert("output".into(), Json::Num(9999.0));
                }
            }
        }
        assert!(ModelFile::from_json(&json.to_pretty()).is_err());
    }
}

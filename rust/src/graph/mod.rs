//! Computation-graph IR.
//!
//! A model is a DAG of single-output operators over tensors (§2.1 of the
//! paper). Tensors are either *activations* (produced at runtime, live in
//! SRAM) or *weights/constants* (baked into NOR-Flash and therefore excluded
//! from the working set, §2.2). Each operator lists activation inputs and
//! weight inputs separately so the schedulers only ever reason about
//! activations.
//!
//! The IR carries enough shape/dtype information to (a) account for memory
//! byte-exactly, (b) execute the graph in the micro-interpreter, and (c)
//! cross-check the AOT-compiled HLO artifacts' shapes.

mod builder;
pub mod serde;
pub mod transform;

pub use builder::GraphBuilder;

use std::collections::HashMap;

use crate::util::bitset::BitSet;

/// Index of a tensor within its graph.
pub type TensorId = usize;
/// Index of an operator within its graph.
pub type OpId = usize;

/// Element type of a tensor. MCU deployments quantize activations and
/// weights to `I8`; the PJRT execution path uses `F32`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I32,
    I8,
    U8,
}

impl DType {
    /// Bytes per element.
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 | DType::U8 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
            DType::I8 => "i8",
            DType::U8 => "u8",
        }
    }

    pub fn from_name(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "i32" => Some(DType::I32),
            "i8" => Some(DType::I8),
            "u8" => Some(DType::U8),
            _ => None,
        }
    }
}

/// Spatial padding mode for convolution/pooling.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Padding {
    /// Output spatial size = ceil(in / stride); zero-pads evenly.
    Same,
    /// No padding; output = floor((in - k) / stride) + 1.
    Valid,
}

/// Fused activation applied by a compute operator before writing its
/// output (MCU deployments fuse activations into the preceding op, so no
/// extra tensor is materialized — this matters for memory accounting).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    Linear,
    Relu,
    Relu6,
}

impl Act {
    pub fn name(self) -> &'static str {
        match self {
            Act::Linear => "linear",
            Act::Relu => "relu",
            Act::Relu6 => "relu6",
        }
    }

    pub fn from_name(s: &str) -> Option<Act> {
        match s {
            "linear" => Some(Act::Linear),
            "relu" => Some(Act::Relu),
            "relu6" => Some(Act::Relu6),
            _ => None,
        }
    }
}

/// Axis along which the split subsystem slices an operator.
///
/// `Rows`/`Cols` band the spatial H/W dimension of an NHWC tensor (with
/// halo overlap for windowed operators); `Channels` bands the output
/// channel dimension — channel slices partition the work *and* the weight
/// columns exactly, so they carry no halo and no recompute.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SplitAxis {
    Rows,
    Cols,
    Channels,
}

impl SplitAxis {
    /// Every axis, in the order the split search tries them.
    pub const ALL: [SplitAxis; 3] = [SplitAxis::Rows, SplitAxis::Cols, SplitAxis::Channels];

    /// Dimension index of this axis in an NHWC activation shape.
    pub fn dim(self) -> usize {
        match self {
            SplitAxis::Rows => 1,
            SplitAxis::Cols => 2,
            SplitAxis::Channels => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SplitAxis::Rows => "rows",
            SplitAxis::Cols => "cols",
            SplitAxis::Channels => "channels",
        }
    }

    pub fn from_name(s: &str) -> Option<SplitAxis> {
        match s {
            "rows" | "h" => Some(SplitAxis::Rows),
            "cols" | "w" => Some(SplitAxis::Cols),
            "channels" | "c" => Some(SplitAxis::Channels),
            _ => None,
        }
    }
}

/// Operator kind. Shapes follow NHWC with N == 1 (single-image MCU
/// inference).
#[derive(Clone, Debug, PartialEq)]
pub enum OpKind {
    /// Standard 2D convolution; weights `[kh, kw, cin, cout]`.
    Conv2D { kernel: (usize, usize), stride: (usize, usize), padding: Padding, act: Act },
    /// Depthwise 2D convolution (channel multiplier 1); weights `[kh, kw, c]`.
    DepthwiseConv2D { kernel: (usize, usize), stride: (usize, usize), padding: Padding, act: Act },
    /// Fully connected; weights `[in, out]`.
    Dense { act: Act },
    /// Elementwise addition of two tensors of identical shape.
    Add,
    /// Concatenation along the channel (last) axis.
    Concat,
    /// Rectified linear activation (elementwise).
    Relu,
    /// ReLU clipped at 6 (elementwise), as used by MobileNet.
    Relu6,
    /// 2D max pooling.
    MaxPool2D { kernel: (usize, usize), stride: (usize, usize), padding: Padding },
    /// 2D average pooling.
    AvgPool2D { kernel: (usize, usize), stride: (usize, usize), padding: Padding },
    /// Global average pooling over H and W → `[1, 1, 1, C]`.
    GlobalAvgPool,
    /// Batch normalization (inference): `y = γ·(x−μ)/√(σ²+ε) + β`;
    /// weights `[γ, β, μ, σ²]`, each `[C]`. Foldable into a preceding
    /// linear op (see [`transform::fold_batchnorm`]).
    BatchNorm { eps: f32 },
    /// Softmax over the last axis.
    Softmax,
    /// Shape-only view change (no data movement on MCU; modeled as a copy
    /// in the interpreter for simplicity).
    Reshape,
    /// Synthetic operator for generated DAGs: pure cost-model node with an
    /// explicit MAC count; executes as identity-ish mix in the interpreter.
    Synthetic { macs: u64 },
    /// Slab partial evaluation of an operator along `axis` — emitted by
    /// the [`crate::split`] subsystem, never by converters. Computes a
    /// contiguous band of `inner`'s output from a matching input slab.
    ///
    /// For `axis == Rows`/`Cols`, `pad` is the slab's effective padding
    /// along that axis (negative when the slab stores rows/columns above
    /// the band's first tap, i.e. the slab is the full unsliced input of
    /// the chain head); the orthogonal spatial padding follows `inner`.
    /// For `axis == Channels` (and split `Dense`), `pad` is 0 and
    /// `offset` is the band's first output channel/feature — the kernels
    /// read only that column band of the full weight/bias tensors. For
    /// spatial axes `offset` records the band's first output row/column
    /// (introspection/serde only).
    Partial { inner: Box<OpKind>, axis: SplitAxis, pad: isize, offset: usize },
    /// Concatenation along `axis`: joins the slabs of a split back into
    /// the full tensor. Slabs are stacked in input order; for 2-D `[1, n]`
    /// bands (split `Dense`) this degenerates to last-axis concatenation.
    /// All inputs share the output's quantization, so the join is a pure
    /// copy — no requantization, bit-exact.
    ConcatSlices { axis: SplitAxis },
    /// Join-elided slab evaluation (streaming concat elision): computes
    /// the output band `[offset, offset + len)` of `inner` along `axis`
    /// from the input slab (`inputs[0]`, with effective padding `pad` as
    /// in [`OpKind::Partial`]) and writes it *through* into its
    /// accumulator input (`inputs[1]` — absent for the first slice of a
    /// chain), whose buffer the output shares. The output is the full
    /// join tensor, partially filled; chaining `k` of these replaces the
    /// `k` final [`OpKind::Partial`] slices *and* the
    /// [`OpKind::ConcatSlices`] join, so the slabs are never materialized
    /// next to the join copy — the 2×output floor at the join collapses
    /// to 1×output. The schedulers account the sharing via
    /// [`crate::sched::elided_accumulators`], and the interpreter reuses
    /// the accumulator's arena handle.
    PartialInto { inner: Box<OpKind>, axis: SplitAxis, pad: isize, offset: usize, len: usize },
}

impl OpKind {
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Conv2D { .. } => "Conv2D",
            OpKind::DepthwiseConv2D { .. } => "DepthwiseConv2D",
            OpKind::Dense { .. } => "Dense",
            OpKind::Add => "Add",
            OpKind::Concat => "Concat",
            OpKind::Relu => "Relu",
            OpKind::Relu6 => "Relu6",
            OpKind::MaxPool2D { .. } => "MaxPool2D",
            OpKind::AvgPool2D { .. } => "AvgPool2D",
            OpKind::GlobalAvgPool => "GlobalAvgPool",
            OpKind::BatchNorm { .. } => "BatchNorm",
            OpKind::Softmax => "Softmax",
            OpKind::Reshape => "Reshape",
            OpKind::Synthetic { .. } => "Synthetic",
            OpKind::Partial { .. } => "Partial",
            OpKind::ConcatSlices { .. } => "ConcatSlices",
            OpKind::PartialInto { .. } => "PartialInto",
        }
    }
}

/// Dimension index `shape` bands along for a split `axis`: the NHWC
/// dimension for 4-D activations, the trailing (feature) dimension for
/// the 2-D `[1, n]` tensors of a split `Dense` (which always bands along
/// `Channels`). The single place this convention lives.
pub fn axis_dim_of(shape: &[usize], axis: SplitAxis) -> usize {
    if shape.len() == 4 {
        axis.dim()
    } else {
        shape.len().saturating_sub(1)
    }
}

/// Extent of `shape` along a split `axis` (see [`axis_dim_of`]).
pub fn axis_extent(shape: &[usize], axis: SplitAxis) -> usize {
    shape.get(axis_dim_of(shape, axis)).copied().unwrap_or(1)
}

/// A tensor: shape, dtype, and its role in the dataflow.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub id: TensorId,
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    /// Operator that produces this tensor; `None` for graph inputs and
    /// weights.
    pub producer: Option<OpId>,
    /// Operators that consume this tensor.
    pub consumers: Vec<OpId>,
    /// `true` for weights/constants (NOR-Flash resident; never in the
    /// working set).
    pub is_weight: bool,
}

impl Tensor {
    /// Total element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    /// Size in bytes (what the working-set accounting sums).
    pub fn bytes(&self) -> usize {
        self.elems() * self.dtype.size()
    }
}

/// A single-output operator.
#[derive(Clone, Debug, PartialEq)]
pub struct Op {
    pub id: OpId,
    pub name: String,
    pub kind: OpKind,
    /// Activation inputs (SRAM tensors).
    pub inputs: Vec<TensorId>,
    /// Weight inputs (Flash tensors, excluded from scheduling).
    pub weights: Vec<TensorId>,
    /// The single output tensor.
    pub output: TensorId,
}

impl Op {
    /// Multiply-accumulate count of this operator given its graph (for the
    /// MCU cycle model).
    pub fn macs(&self, g: &Graph) -> u64 {
        let out = &g.tensors[self.output];
        let out_elems = out.elems() as u64;
        match &self.kind {
            OpKind::Conv2D { kernel: (kh, kw), .. } => {
                let cin = g.tensors[self.inputs[0]].shape.last().copied().unwrap_or(1) as u64;
                out_elems * (*kh as u64) * (*kw as u64) * cin
            }
            OpKind::DepthwiseConv2D { kernel: (kh, kw), .. } => {
                out_elems * (*kh as u64) * (*kw as u64)
            }
            OpKind::Dense { .. } => {
                let cin = g.tensors[self.inputs[0]].elems() as u64;
                out_elems * cin
            }
            OpKind::Add | OpKind::Relu | OpKind::Relu6 | OpKind::Softmax => out_elems,
            OpKind::BatchNorm { .. } => 2 * out_elems,
            OpKind::MaxPool2D { kernel: (kh, kw), .. }
            | OpKind::AvgPool2D { kernel: (kh, kw), .. } => {
                out_elems * (*kh as u64) * (*kw as u64)
            }
            OpKind::GlobalAvgPool => g.tensors[self.inputs[0]].elems() as u64,
            OpKind::Concat | OpKind::Reshape | OpKind::ConcatSlices { .. } => 0,
            OpKind::Synthetic { macs } => *macs,
            // A partial op costs what its band costs; halo overlap between
            // slices shows up naturally as the sum over slice ops
            // exceeding the unsplit op's MACs (recompute overhead). For a
            // `Partial` the output tensor *is* the band; a `PartialInto`
            // output is the full join tensor, so its band is scaled out.
            OpKind::Partial { inner, .. } => self.partial_macs(g, inner, out_elems),
            OpKind::PartialInto { inner, .. } => {
                self.partial_macs(g, inner, self.band_elems(g) as u64)
            }
        }
    }

    /// MACs of evaluating `band_out_elems` output elements of `inner`.
    fn partial_macs(&self, g: &Graph, inner: &OpKind, band_out_elems: u64) -> u64 {
        match inner {
            OpKind::Conv2D { kernel: (kh, kw), .. } => {
                let cin = g.tensors[self.inputs[0]].shape.last().copied().unwrap_or(1) as u64;
                band_out_elems * (*kh as u64) * (*kw as u64) * cin
            }
            OpKind::DepthwiseConv2D { kernel: (kh, kw), .. } => {
                band_out_elems * (*kh as u64) * (*kw as u64)
            }
            OpKind::Dense { .. } => {
                let cin = g.tensors[self.inputs[0]].elems() as u64;
                band_out_elems * cin
            }
            OpKind::MaxPool2D { kernel: (kh, kw), .. }
            | OpKind::AvgPool2D { kernel: (kh, kw), .. } => {
                band_out_elems * (*kh as u64) * (*kw as u64)
            }
            OpKind::BatchNorm { .. } => 2 * band_out_elems,
            _ => band_out_elems,
        }
    }

    /// Elements of the output band this operator writes: the band
    /// `[offset, offset + len)` for a [`OpKind::PartialInto`] (its output
    /// tensor is the full join tensor), the whole output otherwise.
    pub fn band_elems(&self, g: &Graph) -> usize {
        let out = &g.tensors[self.output];
        match &self.kind {
            OpKind::PartialInto { axis, len, .. } => {
                out.elems() / axis_extent(&out.shape, *axis).max(1) * len
            }
            _ => out.elems(),
        }
    }

    /// Flash weight bytes this operator reads. The per-axis asymmetry of
    /// splitting shows up here: a row/column slice re-reads the *full*
    /// weight tensor (a k-way spatial split costs k× the flash weight
    /// traffic), while a channel slice addresses only the weight/bias
    /// column band `[offset, offset+band)` of the full tensor — channel
    /// splits partition weight traffic exactly. The band size is the
    /// output's last dim; the full column count is the weight tensor's
    /// last dim (HWIO/HWC/`[in,out]`/`[C]` alike).
    pub fn weight_bytes(&self, g: &Graph) -> u64 {
        let chan_band = match &self.kind {
            OpKind::Partial { axis: SplitAxis::Channels, .. } => {
                Some(g.tensors[self.output].shape.last().copied().unwrap_or(1))
            }
            OpKind::PartialInto { axis: SplitAxis::Channels, len, .. } => Some(*len),
            _ => None,
        };
        if let Some(band) = chan_band {
            self.weights
                .iter()
                .map(|&t| {
                    let wt = &g.tensors[t];
                    let full = wt.shape.last().copied().unwrap_or(1).max(1);
                    (wt.bytes() * band.min(full) / full) as u64
                })
                .sum()
        } else {
            self.weights.iter().map(|&t| g.tensors[t].bytes() as u64).sum()
        }
    }

    /// Bytes read + written by this operator (activation + weight
    /// traffic). A join-elided slice ([`OpKind::PartialInto`]) reads its
    /// input slab and writes only its band through the shared accumulator
    /// buffer — the accumulator input is carried, not copied, so it does
    /// not count as traffic (that is the join copy the elision removes).
    pub fn bytes_touched(&self, g: &Graph) -> u64 {
        if let OpKind::PartialInto { .. } = &self.kind {
            let read = g.tensors[self.inputs[0]].bytes();
            let written = self.band_elems(g) * g.tensors[self.output].dtype.size();
            return (read + written) as u64 + self.weight_bytes(g);
        }
        let read: usize = self.inputs.iter().map(|&t| g.tensors[t].bytes()).sum();
        (read + g.tensors[self.output].bytes()) as u64 + self.weight_bytes(g)
    }
}

/// Errors raised by graph validation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GraphError {
    DanglingTensor(TensorId),
    BadProducer(TensorId),
    WeightWithProducer(TensorId),
    MultipleProducers(TensorId),
    EmptyOutputs,
    CycleDetected,
    BadOrder(String),
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::DanglingTensor(t) => write!(f, "tensor {t} referenced but not defined"),
            GraphError::BadProducer(t) => write!(f, "tensor {t} producer link inconsistent"),
            GraphError::WeightWithProducer(t) => write!(f, "weight tensor {t} has a producer"),
            GraphError::MultipleProducers(t) => write!(f, "tensor {t} produced twice"),
            GraphError::EmptyOutputs => write!(f, "graph declares no outputs"),
            GraphError::CycleDetected => write!(f, "graph contains a cycle"),
            GraphError::BadOrder(m) => write!(f, "invalid execution order: {m}"),
        }
    }
}

impl std::error::Error for GraphError {}

/// The computation graph. Structural equality (`PartialEq`) is what the
/// beam planner's frontier dedup keys on: two states reached through
/// different rewrite interleavings compare equal exactly when every
/// tensor, op and boundary list matches.
#[derive(Clone, Debug, PartialEq)]
pub struct Graph {
    pub name: String,
    pub tensors: Vec<Tensor>,
    pub ops: Vec<Op>,
    /// Graph input tensors (activations with no producer).
    pub inputs: Vec<TensorId>,
    /// Graph output tensors (kept live until the end).
    pub outputs: Vec<TensorId>,
}

impl Graph {
    pub fn new(name: impl Into<String>) -> Self {
        Graph {
            name: name.into(),
            tensors: Vec::new(),
            ops: Vec::new(),
            inputs: Vec::new(),
            outputs: Vec::new(),
        }
    }

    pub fn n_tensors(&self) -> usize {
        self.tensors.len()
    }

    pub fn n_ops(&self) -> usize {
        self.ops.len()
    }

    /// The model's default (as-built) execution order — what a converter
    /// embeds in the flatbuffer; the baseline the paper improves on.
    pub fn default_order(&self) -> Vec<OpId> {
        (0..self.ops.len()).collect()
    }

    /// Total bytes of weights (NOR-Flash footprint, "model size").
    pub fn model_size(&self) -> usize {
        self.tensors.iter().filter(|t| t.is_weight).map(|t| t.bytes()).sum()
    }

    /// Total bytes of all activations (what a no-reuse static planner
    /// allocates, including graph inputs).
    pub fn activation_total(&self) -> usize {
        self.tensors.iter().filter(|t| !t.is_weight).map(|t| t.bytes()).sum()
    }

    /// Total multiply-accumulate count.
    pub fn total_macs(&self) -> u64 {
        self.ops.iter().map(|o| o.macs(self)).sum()
    }

    /// Structural validation: every link consistent, single producer per
    /// tensor, weights producer-free, DAG acyclic, outputs non-empty.
    pub fn validate(&self) -> Result<(), GraphError> {
        let n = self.tensors.len();
        let check = |t: TensorId| if t < n { Ok(()) } else { Err(GraphError::DanglingTensor(t)) };
        if self.outputs.is_empty() {
            return Err(GraphError::EmptyOutputs);
        }
        let mut produced: HashMap<TensorId, OpId> = HashMap::new();
        for op in &self.ops {
            for &t in op.inputs.iter().chain(&op.weights) {
                check(t)?;
            }
            check(op.output)?;
            if produced.insert(op.output, op.id).is_some() {
                return Err(GraphError::MultipleProducers(op.output));
            }
        }
        for t in &self.tensors {
            match (t.producer, produced.get(&t.id)) {
                (Some(p), Some(&q)) if p == q => {}
                (None, None) => {}
                _ => return Err(GraphError::BadProducer(t.id)),
            }
            if t.is_weight && t.producer.is_some() {
                return Err(GraphError::WeightWithProducer(t.id));
            }
            for &c in &t.consumers {
                let op = self.ops.get(c).ok_or(GraphError::BadProducer(t.id))?;
                if !op.inputs.contains(&t.id) && !op.weights.contains(&t.id) {
                    return Err(GraphError::BadProducer(t.id));
                }
            }
        }
        for &t in self.inputs.iter().chain(&self.outputs) {
            check(t)?;
        }
        // Acyclicity via Kahn's algorithm over ops.
        if self.topo_order().is_none() {
            return Err(GraphError::CycleDetected);
        }
        Ok(())
    }

    /// Some topological order of the ops (Kahn); `None` if cyclic.
    pub fn topo_order(&self) -> Option<Vec<OpId>> {
        let mut indeg = vec![0usize; self.ops.len()];
        for op in &self.ops {
            for &t in &op.inputs {
                if self.tensors[t].producer.is_some() {
                    indeg[op.id] += 1;
                }
            }
        }
        let mut ready: Vec<OpId> = indeg
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(i, _)| i)
            .collect();
        ready.reverse();
        let mut order = Vec::with_capacity(self.ops.len());
        while let Some(op) = ready.pop() {
            order.push(op);
            let out = self.ops[op].output;
            for &c in &self.tensors[out].consumers {
                if self.ops[c].inputs.contains(&out) {
                    indeg[c] -= 1;
                    if indeg[c] == 0 {
                        ready.push(c);
                    }
                }
            }
        }
        (order.len() == self.ops.len()).then_some(order)
    }

    /// Is `order` a valid complete topological execution order?
    pub fn check_order(&self, order: &[OpId]) -> Result<(), GraphError> {
        if order.len() != self.ops.len() {
            return Err(GraphError::BadOrder(format!(
                "length {} != op count {}",
                order.len(),
                self.ops.len()
            )));
        }
        let mut seen = vec![false; self.ops.len()];
        let mut have = vec![false; self.tensors.len()];
        for t in &self.tensors {
            if t.producer.is_none() {
                have[t.id] = true;
            }
        }
        for &op in order {
            if op >= self.ops.len() || seen[op] {
                return Err(GraphError::BadOrder(format!("op {op} repeated or out of range")));
            }
            seen[op] = true;
            for &t in &self.ops[op].inputs {
                if !have[t] {
                    return Err(GraphError::BadOrder(format!(
                        "op {op} ({}) consumes tensor {t} before it is produced",
                        self.ops[op].name
                    )));
                }
            }
            have[self.ops[op].output] = true;
        }
        Ok(())
    }

    /// Per-tensor ancestor sets over *activation* tensors: `anc[t]` contains
    /// every activation tensor that (transitively) feeds the producer of
    /// `t`. Used by Algorithm 1's "would have to be evaluated twice" check.
    pub fn tensor_ancestors(&self) -> Vec<BitSet> {
        let n = self.tensors.len();
        let mut anc: Vec<BitSet> = (0..n).map(|_| BitSet::new(n)).collect();
        let order = self.topo_order().expect("tensor_ancestors on cyclic graph");
        for &opid in &order {
            let op = &self.ops[opid];
            let out = op.output;
            let mut acc = BitSet::new(n);
            for &i in &op.inputs {
                acc.insert(i);
                acc.union_with(&anc[i]);
            }
            anc[out] = acc;
        }
        anc
    }

    /// Look up an op by name (test/CLI convenience).
    pub fn op_by_name(&self, name: &str) -> Option<&Op> {
        self.ops.iter().find(|o| o.name == name)
    }

    /// Look up a tensor by name.
    pub fn tensor_by_name(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|t| t.name == name)
    }

    /// GraphViz dot rendering (activations solid, weights dashed).
    pub fn to_dot(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("digraph \"{}\" {{\n  rankdir=TB;\n", self.name));
        for op in &self.ops {
            s.push_str(&format!(
                "  op{} [shape=box,label=\"#{} {}\\n{}\"];\n",
                op.id,
                op.id + 1,
                op.name,
                op.kind.name()
            ));
        }
        for t in &self.tensors {
            for &c in &t.consumers {
                let style = if t.is_weight { " [style=dashed]" } else { "" };
                let label = format!(" [label=\"{}B\"]", t.bytes());
                match t.producer {
                    Some(p) => s.push_str(&format!("  op{p} -> op{c}{label};\n")),
                    None if !t.is_weight => {
                        s.push_str(&format!(
                            "  in{} [shape=ellipse,label=\"{}\"];\n  in{} -> op{c}{label};\n",
                            t.id, t.name, t.id
                        ));
                    }
                    None => {
                        let _ = style;
                    }
                }
            }
        }
        s.push_str("}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        // in -> a -> {b, c} -> d(add)
        let mut b = GraphBuilder::new("diamond");
        let x = b.input("x", &[1, 4, 4, 2], DType::F32);
        let a = b.relu("a", x);
        let l = b.relu("l", a);
        let r = b.relu("r", a);
        let d = b.add("d", l, r);
        b.output(d);
        b.finish().unwrap()
    }

    #[test]
    fn diamond_validates() {
        let g = diamond();
        assert_eq!(g.n_ops(), 4);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn topo_order_is_valid() {
        let g = diamond();
        let order = g.topo_order().unwrap();
        g.check_order(&order).unwrap();
    }

    #[test]
    fn check_order_rejects_violations() {
        let g = diamond();
        // 'd' (op 3) before its inputs.
        assert!(g.check_order(&[3, 0, 1, 2]).is_err());
        // duplicate
        assert!(g.check_order(&[0, 0, 1, 2]).is_err());
        // short
        assert!(g.check_order(&[0, 1]).is_err());
    }

    #[test]
    fn ancestors_flow_through() {
        let g = diamond();
        let anc = g.tensor_ancestors();
        let x = g.tensor_by_name("x").unwrap().id;
        let a = g.tensor_by_name("a").unwrap().id;
        let d = g.tensor_by_name("d").unwrap().id;
        assert!(anc[d].contains(a));
        assert!(anc[d].contains(x));
        assert!(!anc[a].contains(d));
    }

    #[test]
    fn tensor_bytes() {
        let g = diamond();
        let x = g.tensor_by_name("x").unwrap();
        assert_eq!(x.elems(), 32);
        assert_eq!(x.bytes(), 128);
    }

    #[test]
    fn macs_of_add_are_elementwise() {
        let g = diamond();
        let d = g.op_by_name("d").unwrap();
        assert_eq!(d.macs(&g), 32);
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.size(), 4);
        assert_eq!(DType::I8.size(), 1);
        assert_eq!(DType::from_name("i8"), Some(DType::I8));
        assert_eq!(DType::from_name("nope"), None);
    }

    #[test]
    fn dot_renders() {
        let g = diamond();
        let dot = g.to_dot();
        assert!(dot.contains("digraph"));
        assert!(dot.contains("op0 -> op1"));
    }
}

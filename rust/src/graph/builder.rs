//! Fluent graph construction with shape inference.
//!
//! The model zoo builds networks through this API; output shapes and weight
//! tensor sizes are derived from the layer parameters so the byte-exact
//! memory accounting cannot drift from the architecture definition.

use super::{Act, DType, Graph, GraphError, Op, OpKind, Padding, Tensor, TensorId};

/// Incremental graph builder. Ops are appended in call order, which becomes
/// the graph's *default* execution order (the baseline schedule).
pub struct GraphBuilder {
    g: Graph,
}

fn conv_out_dim(input: usize, k: usize, stride: usize, padding: Padding) -> usize {
    match padding {
        Padding::Same => input.div_ceil(stride),
        Padding::Valid => {
            assert!(input >= k, "valid padding with input {input} < kernel {k}");
            (input - k) / stride + 1
        }
    }
}

impl GraphBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        GraphBuilder { g: Graph::new(name) }
    }

    // ---- tensors -------------------------------------------------------

    fn add_tensor(
        &mut self,
        name: String,
        shape: Vec<usize>,
        dtype: DType,
        is_weight: bool,
    ) -> TensorId {
        let id = self.g.tensors.len();
        self.g.tensors.push(Tensor {
            id,
            name,
            shape,
            dtype,
            producer: None,
            consumers: Vec::new(),
            is_weight,
        });
        id
    }

    /// Declare a graph input (activation, SRAM-resident).
    pub fn input(&mut self, name: &str, shape: &[usize], dtype: DType) -> TensorId {
        let id = self.add_tensor(name.to_string(), shape.to_vec(), dtype, false);
        self.g.inputs.push(id);
        id
    }

    /// Declare a weight/constant tensor (Flash-resident).
    pub fn weight(&mut self, name: &str, shape: &[usize], dtype: DType) -> TensorId {
        self.add_tensor(name.to_string(), shape.to_vec(), dtype, true)
    }

    /// Mark a tensor as a graph output.
    pub fn output(&mut self, t: TensorId) {
        self.g.outputs.push(t);
    }

    // ---- op plumbing ----------------------------------------------------

    fn add_op(
        &mut self,
        name: &str,
        kind: OpKind,
        inputs: Vec<TensorId>,
        weights: Vec<TensorId>,
        out_shape: Vec<usize>,
        out_dtype: DType,
    ) -> TensorId {
        let opid = self.g.ops.len();
        let out = self.add_tensor(name.to_string(), out_shape, out_dtype, false);
        self.g.tensors[out].producer = Some(opid);
        for &t in inputs.iter().chain(&weights) {
            self.g.tensors[t].consumers.push(opid);
        }
        self.g.ops.push(Op {
            id: opid,
            name: name.to_string(),
            kind,
            inputs,
            weights,
            output: out,
        });
        out
    }

    fn shape(&self, t: TensorId) -> &[usize] {
        &self.g.tensors[t].shape
    }

    fn dtype(&self, t: TensorId) -> DType {
        self.g.tensors[t].dtype
    }

    // ---- layers ---------------------------------------------------------

    /// 2D convolution with implicit weight + bias tensors.
    pub fn conv2d(
        &mut self,
        name: &str,
        input: TensorId,
        cout: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: Padding,
        act: Act,
    ) -> TensorId {
        let (n, h, w, cin) = nhwc(self.shape(input));
        let oh = conv_out_dim(h, kernel.0, stride.0, padding);
        let ow = conv_out_dim(w, kernel.1, stride.1, padding);
        let dt = self.dtype(input);
        let wt = self.weight(&format!("{name}.w"), &[kernel.0, kernel.1, cin, cout], dt);
        let bias = self.weight(&format!("{name}.b"), &[cout], DType::I32.pick_bias(dt));
        self.add_op(
            name,
            OpKind::Conv2D { kernel, stride, padding, act },
            vec![input],
            vec![wt, bias],
            vec![n, oh, ow, cout],
            dt,
        )
    }

    /// Depthwise 2D convolution (multiplier 1) with implicit weight + bias.
    pub fn dwconv2d(
        &mut self,
        name: &str,
        input: TensorId,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: Padding,
        act: Act,
    ) -> TensorId {
        let (n, h, w, c) = nhwc(self.shape(input));
        let oh = conv_out_dim(h, kernel.0, stride.0, padding);
        let ow = conv_out_dim(w, kernel.1, stride.1, padding);
        let dt = self.dtype(input);
        let wt = self.weight(&format!("{name}.w"), &[kernel.0, kernel.1, c], dt);
        let bias = self.weight(&format!("{name}.b"), &[c], DType::I32.pick_bias(dt));
        self.add_op(
            name,
            OpKind::DepthwiseConv2D { kernel, stride, padding, act },
            vec![input],
            vec![wt, bias],
            vec![n, oh, ow, c],
            dt,
        )
    }

    /// Fully-connected layer over a flattened input.
    pub fn dense(
        &mut self,
        name: &str,
        input: TensorId,
        out_features: usize,
        act: Act,
    ) -> TensorId {
        let in_features = self.g.tensors[input].elems();
        let dt = self.dtype(input);
        let wt = self.weight(&format!("{name}.w"), &[in_features, out_features], dt);
        let bias = self.weight(&format!("{name}.b"), &[out_features], DType::I32.pick_bias(dt));
        self.add_op(
            name,
            OpKind::Dense { act },
            vec![input],
            vec![wt, bias],
            vec![1, out_features],
            dt,
        )
    }

    /// Elementwise add; shapes must match.
    pub fn add(&mut self, name: &str, a: TensorId, b: TensorId) -> TensorId {
        assert_eq!(self.shape(a), self.shape(b), "add shape mismatch at {name}");
        let shape = self.shape(a).to_vec();
        let dt = self.dtype(a);
        self.add_op(name, OpKind::Add, vec![a, b], vec![], shape, dt)
    }

    /// Channel-axis concatenation of two or more tensors.
    pub fn concat(&mut self, name: &str, parts: &[TensorId]) -> TensorId {
        assert!(parts.len() >= 2, "concat needs >=2 inputs at {name}");
        let first = self.shape(parts[0]).to_vec();
        let mut c_total = 0;
        for &p in parts {
            let s = self.shape(p);
            assert_eq!(s.len(), first.len(), "concat rank mismatch at {name}");
            assert_eq!(
                &s[..s.len() - 1],
                &first[..first.len() - 1],
                "concat spatial mismatch at {name}"
            );
            c_total += s[s.len() - 1];
        }
        let mut shape = first;
        *shape.last_mut().unwrap() = c_total;
        let dt = self.dtype(parts[0]);
        self.add_op(name, OpKind::Concat, parts.to_vec(), vec![], shape, dt)
    }

    /// Elementwise ReLU.
    pub fn relu(&mut self, name: &str, input: TensorId) -> TensorId {
        let shape = self.shape(input).to_vec();
        let dt = self.dtype(input);
        self.add_op(name, OpKind::Relu, vec![input], vec![], shape, dt)
    }

    /// Elementwise ReLU6.
    pub fn relu6(&mut self, name: &str, input: TensorId) -> TensorId {
        let shape = self.shape(input).to_vec();
        let dt = self.dtype(input);
        self.add_op(name, OpKind::Relu6, vec![input], vec![], shape, dt)
    }

    /// 2D max pooling.
    pub fn maxpool(
        &mut self,
        name: &str,
        input: TensorId,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: Padding,
    ) -> TensorId {
        let (n, h, w, c) = nhwc(self.shape(input));
        let oh = conv_out_dim(h, kernel.0, stride.0, padding);
        let ow = conv_out_dim(w, kernel.1, stride.1, padding);
        let dt = self.dtype(input);
        self.add_op(
            name,
            OpKind::MaxPool2D { kernel, stride, padding },
            vec![input],
            vec![],
            vec![n, oh, ow, c],
            dt,
        )
    }

    /// 2D average pooling.
    pub fn avgpool(
        &mut self,
        name: &str,
        input: TensorId,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: Padding,
    ) -> TensorId {
        let (n, h, w, c) = nhwc(self.shape(input));
        let oh = conv_out_dim(h, kernel.0, stride.0, padding);
        let ow = conv_out_dim(w, kernel.1, stride.1, padding);
        let dt = self.dtype(input);
        self.add_op(
            name,
            OpKind::AvgPool2D { kernel, stride, padding },
            vec![input],
            vec![],
            vec![n, oh, ow, c],
            dt,
        )
    }

    /// Global average pool to `[1,1,1,C]`.
    pub fn global_avgpool(&mut self, name: &str, input: TensorId) -> TensorId {
        let (n, _, _, c) = nhwc(self.shape(input));
        let dt = self.dtype(input);
        self.add_op(name, OpKind::GlobalAvgPool, vec![input], vec![], vec![n, 1, 1, c], dt)
    }

    /// Inference batch normalization with implicit γ/β/μ/σ² weights.
    pub fn batchnorm(&mut self, name: &str, input: TensorId, eps: f32) -> TensorId {
        let shape = self.shape(input).to_vec();
        let c = *shape.last().expect("batchnorm needs a channel axis");
        let dt = self.dtype(input);
        let gamma = self.weight(&format!("{name}.gamma"), &[c], DType::F32);
        let beta = self.weight(&format!("{name}.beta"), &[c], DType::F32);
        let mean = self.weight(&format!("{name}.mean"), &[c], DType::F32);
        let var = self.weight(&format!("{name}.var"), &[c], DType::F32);
        self.add_op(
            name,
            OpKind::BatchNorm { eps },
            vec![input],
            vec![gamma, beta, mean, var],
            shape,
            dt,
        )
    }

    /// Softmax over the last axis.
    pub fn softmax(&mut self, name: &str, input: TensorId) -> TensorId {
        let shape = self.shape(input).to_vec();
        let dt = self.dtype(input);
        self.add_op(name, OpKind::Softmax, vec![input], vec![], shape, dt)
    }

    /// Reshape (element count must be preserved).
    pub fn reshape(&mut self, name: &str, input: TensorId, shape: &[usize]) -> TensorId {
        assert_eq!(
            self.g.tensors[input].elems(),
            shape.iter().product::<usize>(),
            "reshape element mismatch at {name}"
        );
        let dt = self.dtype(input);
        self.add_op(name, OpKind::Reshape, vec![input], vec![], shape.to_vec(), dt)
    }

    /// Synthetic op for generated DAGs: arbitrary inputs, explicit output
    /// byte size (as a `[bytes]` u8 tensor) and MAC count.
    pub fn synthetic(
        &mut self,
        name: &str,
        inputs: &[TensorId],
        out_bytes: usize,
        macs: u64,
    ) -> TensorId {
        self.add_op(
            name,
            OpKind::Synthetic { macs },
            inputs.to_vec(),
            vec![],
            vec![out_bytes],
            DType::U8,
        )
    }

    /// Validate and return the finished graph.
    pub fn finish(self) -> Result<Graph, GraphError> {
        self.g.validate()?;
        Ok(self.g)
    }

    /// Access the graph under construction (tests).
    pub fn graph(&self) -> &Graph {
        &self.g
    }
}

fn nhwc(shape: &[usize]) -> (usize, usize, usize, usize) {
    assert_eq!(shape.len(), 4, "expected NHWC shape, got {shape:?}");
    (shape[0], shape[1], shape[2], shape[3])
}

impl DType {
    /// Bias dtype convention: f32 models carry f32 biases, quantized models
    /// carry i32 biases (TFLite convention).
    fn pick_bias(self, activation: DType) -> DType {
        match activation {
            DType::F32 => DType::F32,
            _ => self,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shape_same_padding() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 96, 96, 1], DType::I8);
        let y = b.conv2d("c1", x, 8, (3, 3), (2, 2), Padding::Same, Act::Linear);
        assert_eq!(b.shape(y), &[1, 48, 48, 8]);
        b.output(y);
        let g = b.finish().unwrap();
        assert_eq!(g.tensor_by_name("c1").unwrap().bytes(), 48 * 48 * 8);
    }

    #[test]
    fn conv_shape_valid_padding() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 10, 10, 3], DType::F32);
        let y = b.conv2d("c", x, 4, (3, 3), (1, 1), Padding::Valid, Act::Linear);
        assert_eq!(b.shape(y), &[1, 8, 8, 4]);
    }

    #[test]
    fn dwconv_preserves_channels() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 48, 48, 8], DType::I8);
        let y = b.dwconv2d("dw", x, (3, 3), (1, 1), Padding::Same, Act::Linear);
        assert_eq!(b.shape(y), &[1, 48, 48, 8]);
    }

    #[test]
    fn concat_sums_channels() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 8, 8, 4], DType::I8);
        let l = b.relu("l", x);
        let r = b.relu("r", x);
        let c = b.concat("c", &[l, r]);
        assert_eq!(b.shape(c), &[1, 8, 8, 8]);
    }

    #[test]
    fn weights_are_flash_resident() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 8, 8, 4], DType::I8);
        let y = b.conv2d("c", x, 8, (1, 1), (1, 1), Padding::Same, Act::Linear);
        b.output(y);
        let g = b.finish().unwrap();
        // weight [1,1,4,8] = 32 B + bias 8*4 = 32 B
        assert_eq!(g.model_size(), 32 + 32);
        // activations: input 256 + output 512
        assert_eq!(g.activation_total(), 8 * 8 * 4 + 8 * 8 * 8);
    }

    #[test]
    fn dense_flattens() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 1, 1, 256], DType::I8);
        let y = b.dense("fc", x, 2, Act::Linear);
        assert_eq!(b.shape(y), &[1, 2]);
        b.output(y);
        let g = b.finish().unwrap();
        assert_eq!(g.op_by_name("fc").unwrap().macs(&g), 512);
    }

    #[test]
    fn global_avgpool_shape() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 3, 3, 128], DType::I8);
        let y = b.global_avgpool("gap", x);
        assert_eq!(b.shape(y), &[1, 1, 1, 128]);
    }

    #[test]
    #[should_panic(expected = "add shape mismatch")]
    fn add_rejects_mismatched_shapes() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 4, 4, 2], DType::F32);
        let y = b.input("y", &[1, 4, 4, 3], DType::F32);
        b.add("bad", x, y);
    }

    #[test]
    fn synthetic_bytes_are_exact() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1568], DType::U8);
        let y = b.synthetic("s", &[x], 3136, 1000);
        b.output(y);
        let g = b.finish().unwrap();
        assert_eq!(g.tensor_by_name("s").unwrap().bytes(), 3136);
        assert_eq!(g.op_by_name("s").unwrap().macs(&g), 1000);
    }
}

//! TFLite exporter: write a model back with a new execution order.
//!
//! The paper's tool embeds the optimal operator order into the TFLite
//! flatbuffer; in TFLite the subgraph's `operators` vector *is* the
//! execution order, so exporting = permuting that vector and
//! reserializing. Everything else — tensors, quantization, and above all
//! the weight buffers — is written back from the parsed [`Model`]
//! verbatim, so buffer payloads are byte-identical across the rewrite.
//! That invariant is proven, not assumed: [`crate::verify::verify_export`]
//! independently checks any exported file against its source (operator
//! permutation only, buffers byte-identical), and
//! `mcu-reorder verify --reordered` exposes the proof on the CLI.

use super::schema::Model;

type Result<T> = std::result::Result<T, String>;

/// A copy of `model` with its operators permuted into `operator_order`
/// (indices into the original operator vector; must be a permutation).
pub fn reorder(model: &Model, operator_order: &[usize]) -> Result<Model> {
    let n = model.subgraph.operators.len();
    let mut seen = vec![false; n];
    if operator_order.len() != n {
        return Err(format!(
            "operator order has {} entries, model has {n} operators",
            operator_order.len()
        ));
    }
    for &i in operator_order {
        if i >= n || seen[i] {
            return Err(format!("operator order entry {i} repeated or out of range"));
        }
        seen[i] = true;
    }
    let mut out = model.clone();
    out.subgraph.operators =
        operator_order.iter().map(|&i| model.subgraph.operators[i].clone()).collect();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::super::import::import;
    use super::super::schema::Model;
    use super::*;

    fn fixture_model() -> Model {
        // A 3-operator model (relu → relu → softmax over [1, 4]) built
        // through the schema layer directly.
        use super::super::schema::*;
        let t = |name: &str| TensorDef {
            shape: vec![1, 4],
            ttype: tensor_type::FLOAT32,
            buffer: 0,
            name: name.into(),
            quantization: Quantization::default(),
        };
        Model {
            version: 3,
            description: String::new(),
            operator_codes: vec![
                OperatorCode { builtin_code: builtin_op::RELU, version: 1 },
                OperatorCode { builtin_code: builtin_op::SOFTMAX, version: 1 },
            ],
            buffers: vec![vec![]],
            subgraph: SubGraphDef {
                name: "m".into(),
                tensors: vec![t("x"), t("a"), t("b"), t("y")],
                inputs: vec![0],
                outputs: vec![3],
                operators: vec![
                    OperatorDef {
                        opcode_index: 0,
                        inputs: vec![0],
                        outputs: vec![1],
                        options: BuiltinOptions::None,
                    },
                    OperatorDef {
                        opcode_index: 0,
                        inputs: vec![1],
                        outputs: vec![2],
                        options: BuiltinOptions::None,
                    },
                    OperatorDef {
                        opcode_index: 1,
                        inputs: vec![2],
                        outputs: vec![3],
                        options: BuiltinOptions::Softmax { beta: 1.0 },
                    },
                ],
            },
            metadata_buffer: vec![],
            metadata: vec![],
            signature_defs: vec![],
        }
    }

    #[test]
    fn reorder_permutes_and_preserves_buffers() {
        let m = fixture_model();
        let r = reorder(&m, &[0, 1, 2]).unwrap();
        assert_eq!(r, m);
        assert!(reorder(&m, &[0, 1]).is_err(), "short order rejected");
        assert!(reorder(&m, &[0, 0, 1]).is_err(), "duplicate rejected");
        assert!(reorder(&m, &[0, 1, 9]).is_err(), "out of range rejected");
    }

    #[test]
    fn imported_binding_contracts_defused_ops() {
        let m = fixture_model();
        let imp = import(&m).unwrap();
        assert_eq!(imp.graph.n_ops(), 3);
        // Identity graph order → identity operator order.
        assert_eq!(imp.operator_order(&[0, 1, 2]), vec![0, 1, 2]);
    }
}

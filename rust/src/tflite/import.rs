//! TFLite → `graph::Graph` importer.
//!
//! Maps the single subgraph of a parsed [`Model`] onto the in-memory IR:
//! tensors become [`Tensor`]s (constants with non-empty buffers are
//! Flash-resident weights), operators become [`Op`]s in file order (the
//! TFLite operator vector *is* the execution order, so the imported
//! graph's default order is the model's embedded schedule), and
//! per-tensor affine quantization becomes [`QuantParams`] in the
//! [`WeightStore`].
//!
//! **De-fusing contract.** TFLite fuses activations into the producing
//! kernel (`Conv2D` with `fused_activation_function = RELU6`); the
//! importer materializes them as explicit `Relu`/`Relu6` operators so the
//! scheduler sees every tensor the de-fused graph would hold. The
//! intermediate (pre-activation) tensor inherits the *output* tensor's
//! quantization, which makes the two forms bit-identical on the int8
//! path: the kernel requantizes into the output domain either way, and
//! the clamp commutes with it (validated in `interp` tests and the
//! `integration_tflite` golden tests).
//!
//! **Weight layouts.** TFLite stores conv filters OHWI
//! (`[cout, kh, kw, cin]`) and fully-connected filters `[out, in]`; the
//! IR and kernels use HWIO (`[kh, kw, cin, cout]`) and `[in, out]`. The
//! importer transposes the *decoded copies* handed to the interpreter —
//! the raw buffers in the [`Model`] are never touched, so the exporter
//! writes them back byte-identically.

use std::collections::HashMap;

use super::schema::{
    activation, builtin_op, padding, tensor_type, BuiltinOptions, Model, OperatorDef,
};
use crate::graph::{Act, DType, Graph, Op, OpKind, Padding, Tensor, TensorId};
use crate::interp::quant::QuantParams;
use crate::interp::{TensorData, WeightStore};

type Result<T> = std::result::Result<T, String>;

/// The result of an import: the IR graph, its weights + quantization, and
/// the binding back to the flatbuffer needed to re-export a new order.
#[derive(Clone, Debug)]
pub struct Imported {
    pub graph: Graph,
    pub weights: WeightStore,
    /// For each graph op: the index of the TFLite operator it was
    /// imported from, or `None` for de-fused activation ops (which have
    /// no operator of their own — they ride fused on their producer).
    pub op_binding: Vec<Option<usize>>,
}

impl Imported {
    /// Translate an execution order over *graph* ops into a permutation
    /// of the TFLite operator vector. De-fused activation ops are
    /// dropped: in the flatbuffer they execute fused inside their
    /// producer, which the order places. Any topological order of the
    /// de-fused graph contracts to a topological order of the fused one.
    pub fn operator_order(&self, graph_order: &[usize]) -> Vec<usize> {
        graph_order.iter().filter_map(|&op| self.op_binding[op]).collect()
    }
}

fn dtype_of(ttype: i8) -> Result<DType> {
    match ttype {
        tensor_type::FLOAT32 => Ok(DType::F32),
        tensor_type::INT32 => Ok(DType::I32),
        tensor_type::UINT8 => Ok(DType::U8),
        tensor_type::INT8 => Ok(DType::I8),
        other => Err(format!("unsupported tensor type {other}")),
    }
}

fn act_of(fused: i8) -> Result<Option<Act>> {
    match fused {
        activation::NONE => Ok(None),
        activation::RELU => Ok(Some(Act::Relu)),
        activation::RELU6 => Ok(Some(Act::Relu6)),
        other => Err(format!("unsupported fused activation {other}")),
    }
}

fn padding_of(p: i8) -> Result<Padding> {
    match p {
        padding::SAME => Ok(Padding::Same),
        padding::VALID => Ok(Padding::Valid),
        other => Err(format!("unsupported padding {other}")),
    }
}

fn decode_buffer(dtype: DType, bytes: &[u8], what: &str) -> Result<TensorData> {
    let esize = dtype.size();
    if bytes.len() % esize != 0 {
        return Err(format!(
            "{what}: buffer of {} bytes is not a whole number of {} elements",
            bytes.len(),
            dtype.name()
        ));
    }
    Ok(TensorData::from_bytes(dtype, bytes))
}

/// Importer working state.
struct Importer<'m> {
    model: &'m Model,
    g: Graph,
    ws: WeightStore,
    op_binding: Vec<Option<usize>>,
    /// Weight tensors already re-laid-out for the IR (guards against a
    /// filter consumed by two operators being transposed twice).
    relaid: HashMap<TensorId, &'static str>,
    /// Tensor count of the flatbuffer subgraph. File indices are bounded
    /// against this, not the live (growing) tensor list — a corrupt index
    /// must never silently bind to a synthesized `.preact` tensor.
    n_file_tensors: usize,
}

pub fn import(model: &Model) -> Result<Imported> {
    let sg = &model.subgraph;
    let mut g = Graph::new(if sg.name.is_empty() { "tflite" } else { sg.name.as_str() });

    let mut ws = WeightStore::default();
    for (i, t) in sg.tensors.iter().enumerate() {
        let dtype = dtype_of(t.ttype).map_err(|e| format!("tensor {} ({}): {e}", i, t.name))?;
        let shape: Vec<usize> = t
            .shape
            .iter()
            .map(|&d| {
                usize::try_from(d).map_err(|_| {
                    format!("tensor {} ({}): dynamic/negative dim {d} unsupported", i, t.name)
                })
            })
            .collect::<Result<_>>()?;
        let data = model
            .buffers
            .get(t.buffer)
            .ok_or_else(|| format!("tensor {} ({}): buffer {} out of range", i, t.name, t.buffer))?;
        let is_weight = t.buffer != 0 && !data.is_empty();
        let q = &t.quantization;
        if !q.scale.is_empty() {
            if q.scale.len() != 1 || q.zero_point.len() > 1 {
                return Err(format!(
                    "tensor {} ({}): per-channel quantization ({} scales) unsupported \
                     (per-tensor only)",
                    i,
                    t.name,
                    q.scale.len()
                ));
            }
            let scale = q.scale[0];
            if !(scale.is_finite() && scale > 0.0) {
                return Err(format!("tensor {} ({}): bad quant scale {scale}", i, t.name));
            }
            let zp = q.zero_point.first().copied().unwrap_or(0);
            let zp = i32::try_from(zp)
                .map_err(|_| format!("tensor {} ({}): zero point {zp} out of range", i, t.name))?;
            ws.qparams.insert(i, QuantParams::new(scale, zp));
        } else if dtype == DType::I8 {
            // An int8 tensor without affine parameters would make the
            // interpreter fall back to scale 1.0 and silently compute in
            // the wrong domain.
            return Err(format!(
                "tensor {} ({}): int8 tensor without quantization parameters",
                i, t.name
            ));
        }
        let elems: usize = shape.iter().product();
        if is_weight {
            let decoded = decode_buffer(dtype, data, &format!("tensor {} ({})", i, t.name))?;
            if decoded.len() != elems {
                return Err(format!(
                    "tensor {} ({}): buffer holds {} elements, shape {:?} wants {}",
                    i,
                    t.name,
                    decoded.len(),
                    shape,
                    elems
                ));
            }
            ws.data.insert(i, decoded);
        }
        g.tensors.push(Tensor {
            id: i,
            name: if t.name.is_empty() { format!("t{i}") } else { t.name.clone() },
            shape,
            dtype,
            producer: None,
            consumers: Vec::new(),
            is_weight,
        });
    }

    let n_file_tensors = g.tensors.len();
    let mut imp = Importer {
        model,
        g,
        ws,
        op_binding: Vec::new(),
        relaid: HashMap::new(),
        n_file_tensors,
    };
    for (oi, op) in sg.operators.iter().enumerate() {
        imp.import_operator(oi, op)
            .map_err(|e| format!("operator {oi} ({}): {e}", imp.opcode_name(op)))?;
    }

    for &t in &sg.inputs {
        imp.g.inputs.push(imp.tensor_index(t, "subgraph input")?);
    }
    for &t in &sg.outputs {
        imp.g.outputs.push(imp.tensor_index(t, "subgraph output")?);
    }

    imp.g.validate().map_err(|e| format!("imported graph invalid: {e}"))?;
    imp.g
        .check_order(&imp.g.default_order())
        .map_err(|e| format!("operators are not topologically ordered: {e}"))?;
    Ok(Imported { graph: imp.g, weights: imp.ws, op_binding: imp.op_binding })
}

impl Importer<'_> {
    fn opcode_name(&self, op: &OperatorDef) -> String {
        match self.model.operator_codes.get(op.opcode_index) {
            Some(c) => builtin_op::name(c.builtin_code),
            None => format!("bad opcode index {}", op.opcode_index),
        }
    }

    fn tensor_index(&self, t: i32, what: &str) -> Result<TensorId> {
        usize::try_from(t)
            .ok()
            .filter(|&i| i < self.n_file_tensors)
            .ok_or_else(|| format!("{what}: tensor index {t} out of range"))
    }

    fn shape_of(&self, t: TensorId) -> &[usize] {
        &self.g.tensors[t].shape
    }

    fn nhwc(&self, t: TensorId, what: &str) -> Result<(usize, usize, usize, usize)> {
        let s = self.shape_of(t);
        if s.len() != 4 {
            return Err(format!("{what}: expected NHWC shape, got {s:?}"));
        }
        Ok((s[0], s[1], s[2], s[3]))
    }

    /// Domain-preserving kernels (standalone relu, max-pool, global mean,
    /// reshape) write input-domain values unchanged; if the model declares
    /// a different output quantization the interpreter would silently
    /// produce values in the wrong domain — reject at import instead.
    fn require_same_qparams(&self, x: TensorId, out: TensorId, what: &str) -> Result<()> {
        match (self.ws.qparams.get(&x), self.ws.qparams.get(&out)) {
            (Some(a), Some(b)) if a != b => Err(format!(
                "{what}: output quantization (scale {}, zp {}) must equal the input's \
                 (scale {}, zp {}) — this kernel is domain-preserving",
                b.scale, b.zero_point, a.scale, a.zero_point
            )),
            _ => Ok(()),
        }
    }

    fn require_weight(&self, t: TensorId, what: &str) -> Result<()> {
        if !self.g.tensors[t].is_weight {
            return Err(format!("{what}: tensor {} is not a constant", self.g.tensors[t].name));
        }
        Ok(())
    }

    /// Re-lay-out a filter tensor for the IR: `role` is `"conv"` (OHWI →
    /// HWIO), `"dwconv"` (`[1,kh,kw,c]` → `[kh,kw,c]`, layout unchanged)
    /// or `"dense"` (`[out,in]` → `[in,out]`).
    fn relayout_filter(&mut self, t: TensorId, role: &'static str) -> Result<()> {
        if let Some(&prev) = self.relaid.get(&t) {
            if prev != role {
                return Err(format!(
                    "filter {} consumed both as {prev} and as {role}",
                    self.g.tensors[t].name
                ));
            }
            return Ok(());
        }
        let shape = self.g.tensors[t].shape.clone();
        let name = self.g.tensors[t].name.clone();
        match role {
            "conv" => {
                let [cout, kh, kw, cin]: [usize; 4] = shape
                    .as_slice()
                    .try_into()
                    .map_err(|_| format!("filter {name}: expected OHWI shape, got {shape:?}"))?;
                let data = self.ws.data.get(&t).ok_or("filter without data")?;
                let new = match data {
                    TensorData::F32(v) => TensorData::F32(transpose_ohwi(v, cout, kh, kw, cin)),
                    TensorData::I8(v) => TensorData::I8(transpose_ohwi(v, cout, kh, kw, cin)),
                    _ => return Err(format!("filter {name}: unsupported dtype")),
                };
                self.ws.data.insert(t, new);
                self.g.tensors[t].shape = vec![kh, kw, cin, cout];
            }
            "dwconv" => {
                let [one, kh, kw, c]: [usize; 4] = shape
                    .as_slice()
                    .try_into()
                    .map_err(|_| format!("filter {name}: expected 1HWC shape, got {shape:?}"))?;
                if one != 1 {
                    return Err(format!("depthwise filter {name}: leading dim {one} != 1"));
                }
                self.g.tensors[t].shape = vec![kh, kw, c];
            }
            "dense" => {
                let [out, inp]: [usize; 2] = shape
                    .as_slice()
                    .try_into()
                    .map_err(|_| format!("filter {name}: expected [out,in] shape, got {shape:?}"))?;
                let data = self.ws.data.get(&t).ok_or("filter without data")?;
                let new = match data {
                    TensorData::F32(v) => TensorData::F32(transpose_2d(v, out, inp)),
                    TensorData::I8(v) => TensorData::I8(transpose_2d(v, out, inp)),
                    _ => return Err(format!("filter {name}: unsupported dtype")),
                };
                self.ws.data.insert(t, new);
                self.g.tensors[t].shape = vec![inp, out];
            }
            _ => unreachable!(),
        }
        self.relaid.insert(t, role);
        Ok(())
    }

    /// Append an op producing `output`; links producer/consumer edges.
    fn push_op(
        &mut self,
        name: String,
        kind: OpKind,
        inputs: Vec<TensorId>,
        weights: Vec<TensorId>,
        output: TensorId,
        binding: Option<usize>,
    ) -> Result<()> {
        if self.g.tensors[output].producer.is_some() {
            return Err(format!("tensor {} produced twice", self.g.tensors[output].name));
        }
        let id = self.g.ops.len();
        self.g.tensors[output].producer = Some(id);
        for &t in inputs.iter().chain(&weights) {
            self.g.tensors[t].consumers.push(id);
        }
        self.g.ops.push(Op { id, name, kind, inputs, weights, output });
        self.op_binding.push(binding);
        Ok(())
    }

    /// Append `main_kind` for TFLite operator `oi`; when `fused` is an
    /// activation, route the result through a fresh intermediate tensor
    /// and a de-fused `Relu`/`Relu6` op (see module docs).
    #[allow(clippy::too_many_arguments)]
    fn push_with_act(
        &mut self,
        oi: usize,
        main_kind: OpKind,
        inputs: Vec<TensorId>,
        weights: Vec<TensorId>,
        output: TensorId,
        fused: Option<Act>,
    ) -> Result<()> {
        let out_name = self.g.tensors[output].name.clone();
        match fused {
            None => self.push_op(out_name, main_kind, inputs, weights, output, Some(oi)),
            Some(act) => {
                // Pre-activation intermediate: same shape/dtype/qparams as
                // the final output (the de-fusing contract).
                let mid = self.g.tensors.len();
                let (shape, dtype) =
                    (self.g.tensors[output].shape.clone(), self.g.tensors[output].dtype);
                self.g.tensors.push(Tensor {
                    id: mid,
                    name: format!("{out_name}.preact"),
                    shape,
                    dtype,
                    producer: None,
                    consumers: Vec::new(),
                    is_weight: false,
                });
                if let Some(q) = self.ws.qparams.get(&output).copied() {
                    self.ws.qparams.insert(mid, q);
                }
                let pre = format!("{out_name}.preact");
                self.push_op(pre, main_kind, inputs, weights, mid, Some(oi))?;
                let act_kind = match act {
                    Act::Relu => OpKind::Relu,
                    Act::Relu6 => OpKind::Relu6,
                    Act::Linear => unreachable!(),
                };
                self.push_op(out_name, act_kind, vec![mid], vec![], output, None)
            }
        }
    }

    fn single_output(&self, op: &OperatorDef) -> Result<TensorId> {
        if op.outputs.len() != 1 {
            return Err(format!("expected 1 output, got {}", op.outputs.len()));
        }
        self.tensor_index(op.outputs[0], "output")
    }

    fn input_at(&self, op: &OperatorDef, i: usize, what: &str) -> Result<TensorId> {
        let &idx = op
            .inputs
            .get(i)
            .ok_or_else(|| format!("{what}: missing input {i}"))?;
        if idx < 0 {
            return Err(format!("{what}: optional input {i} absent (required here)"));
        }
        self.tensor_index(idx, what)
    }

    fn check_spatial(
        &self,
        input: TensorId,
        output: TensorId,
        kernel: (usize, usize),
        stride: (usize, usize),
        pad: Padding,
        cout_expect: Option<usize>,
    ) -> Result<()> {
        let (_, ih, iw, _) = self.nhwc(input, "input")?;
        let (_, oh, ow, oc) = self.nhwc(output, "output")?;
        let dim = |i: usize, k: usize, s: usize| -> Result<usize> {
            Ok(match pad {
                Padding::Same => i.div_ceil(s),
                Padding::Valid => {
                    if i < k {
                        return Err(format!("valid padding with input {i} < kernel {k}"));
                    }
                    (i - k) / s + 1
                }
            })
        };
        let (eh, ew) = (dim(ih, kernel.0, stride.0)?, dim(iw, kernel.1, stride.1)?);
        if (oh, ow) != (eh, ew) {
            return Err(format!(
                "declared output {oh}x{ow} disagrees with computed {eh}x{ew}"
            ));
        }
        if let Some(c) = cout_expect {
            if oc != c {
                return Err(format!("declared output channels {oc} != filter's {c}"));
            }
        }
        Ok(())
    }

    #[allow(clippy::type_complexity)]
    fn geom(
        &self,
        stride_w: i32,
        stride_h: i32,
        kh: usize,
        kw: usize,
    ) -> Result<((usize, usize), (usize, usize))> {
        let sh = usize::try_from(stride_h).ok().filter(|&s| s > 0);
        let sw = usize::try_from(stride_w).ok().filter(|&s| s > 0);
        match (sh, sw, kh > 0 && kw > 0) {
            (Some(sh), Some(sw), true) => Ok(((kh, kw), (sh, sw))),
            _ => Err(format!("bad geometry: kernel {kh}x{kw}, stride {stride_h}x{stride_w}")),
        }
    }

    fn import_operator(&mut self, oi: usize, op: &OperatorDef) -> Result<()> {
        let code = self
            .model
            .operator_codes
            .get(op.opcode_index)
            .ok_or_else(|| format!("opcode index {} out of range", op.opcode_index))?
            .builtin_code;
        let output = self.single_output(op)?;
        match code {
            builtin_op::CONV_2D => {
                let &BuiltinOptions::Conv2D { padding, stride_w, stride_h, fused_activation } =
                    &op.options
                else {
                    return Err(format!("expected Conv2D options, got {:?}", op.options));
                };
                let x = self.input_at(op, 0, "conv input")?;
                let w = self.input_at(op, 1, "conv filter")?;
                let bias = self.input_at(op, 2, "conv bias")?;
                self.require_weight(w, "conv filter")?;
                self.require_weight(bias, "conv bias")?;
                self.relayout_filter(w, "conv")?;
                let ws = self.shape_of(w).to_vec(); // now HWIO
                let (kernel, stride) = self.geom(stride_w, stride_h, ws[0], ws[1])?;
                let pad = padding_of(padding)?;
                let (_, _, _, cin) = self.nhwc(x, "conv input")?;
                if ws[2] != cin {
                    return Err(format!("filter expects {} input channels, input has {cin}", ws[2]));
                }
                self.check_spatial(x, output, kernel, stride, pad, Some(ws[3]))?;
                let kind = OpKind::Conv2D { kernel, stride, padding: pad, act: Act::Linear };
                let act = act_of(fused_activation)?;
                self.push_with_act(oi, kind, vec![x], vec![w, bias], output, act)
            }
            builtin_op::DEPTHWISE_CONV_2D => {
                let &BuiltinOptions::DepthwiseConv2D {
                    padding,
                    stride_w,
                    stride_h,
                    depth_multiplier,
                    fused_activation,
                } = &op.options
                else {
                    return Err(format!("expected DepthwiseConv2D options, got {:?}", op.options));
                };
                if depth_multiplier != 1 {
                    return Err(format!("depth multiplier {depth_multiplier} unsupported (want 1)"));
                }
                let x = self.input_at(op, 0, "dwconv input")?;
                let w = self.input_at(op, 1, "dwconv filter")?;
                let bias = self.input_at(op, 2, "dwconv bias")?;
                self.require_weight(w, "dwconv filter")?;
                self.require_weight(bias, "dwconv bias")?;
                self.relayout_filter(w, "dwconv")?;
                let ws = self.shape_of(w).to_vec(); // now [kh, kw, c]
                let (kernel, stride) = self.geom(stride_w, stride_h, ws[0], ws[1])?;
                let pad = padding_of(padding)?;
                let (_, _, _, cin) = self.nhwc(x, "dwconv input")?;
                if ws[2] != cin {
                    return Err(format!("filter has {} channels, input has {cin}", ws[2]));
                }
                self.check_spatial(x, output, kernel, stride, pad, Some(cin))?;
                let kind =
                    OpKind::DepthwiseConv2D { kernel, stride, padding: pad, act: Act::Linear };
                let act = act_of(fused_activation)?;
                self.push_with_act(oi, kind, vec![x], vec![w, bias], output, act)
            }
            builtin_op::FULLY_CONNECTED => {
                let &BuiltinOptions::FullyConnected { fused_activation } = &op.options else {
                    return Err(format!("expected FullyConnected options, got {:?}", op.options));
                };
                let x = self.input_at(op, 0, "dense input")?;
                let w = self.input_at(op, 1, "dense filter")?;
                let bias = self.input_at(op, 2, "dense bias")?;
                self.require_weight(w, "dense filter")?;
                self.require_weight(bias, "dense bias")?;
                self.relayout_filter(w, "dense")?;
                let ws = self.shape_of(w).to_vec(); // now [in, out]
                let in_elems = self.g.tensors[x].elems();
                if ws[0] != in_elems {
                    return Err(format!(
                        "filter expects {} input features, input has {in_elems}",
                        ws[0]
                    ));
                }
                let out_elems = self.g.tensors[output].elems();
                if ws[1] != out_elems {
                    return Err(format!(
                        "filter yields {} features, output holds {out_elems}",
                        ws[1]
                    ));
                }
                let kind = OpKind::Dense { act: Act::Linear };
                let act = act_of(fused_activation)?;
                self.push_with_act(oi, kind, vec![x], vec![w, bias], output, act)
            }
            builtin_op::ADD => {
                let &BuiltinOptions::Add { fused_activation } = &op.options else {
                    return Err(format!("expected Add options, got {:?}", op.options));
                };
                let a = self.input_at(op, 0, "add lhs")?;
                let bb = self.input_at(op, 1, "add rhs")?;
                if self.shape_of(a) != self.shape_of(bb) {
                    return Err("broadcasting Add unsupported (shapes must match)".into());
                }
                let act = act_of(fused_activation)?;
                self.push_with_act(oi, OpKind::Add, vec![a, bb], vec![], output, act)
            }
            builtin_op::CONCATENATION => {
                let &BuiltinOptions::Concatenation { axis, fused_activation } = &op.options else {
                    return Err(format!("expected Concatenation options, got {:?}", op.options));
                };
                if op.inputs.len() < 2 {
                    return Err("concatenation needs >= 2 inputs".into());
                }
                let parts: Vec<TensorId> = (0..op.inputs.len())
                    .map(|i| self.input_at(op, i, "concat input"))
                    .collect::<Result<_>>()?;
                let rank = self.shape_of(parts[0]).len() as i32;
                if axis != rank - 1 && axis != -1 {
                    return Err(format!(
                        "concatenation along axis {axis} unsupported (channel axis {} only)",
                        rank - 1
                    ));
                }
                let mut c_total = 0;
                let leading = |s: &[usize]| s.split_last().map(|(_, l)| l.to_vec());
                let lead = leading(self.shape_of(parts[0]));
                for &p in &parts {
                    let s = self.shape_of(p);
                    if leading(s) != lead {
                        return Err("concat inputs disagree on leading dims".into());
                    }
                    c_total += s.last().copied().unwrap_or(0);
                }
                if self.shape_of(output).last().copied().unwrap_or(0) != c_total {
                    return Err("concat output channels != sum of inputs".into());
                }
                let act = act_of(fused_activation)?;
                self.push_with_act(oi, OpKind::Concat, parts, vec![], output, act)
            }
            builtin_op::MAX_POOL_2D | builtin_op::AVERAGE_POOL_2D => {
                let &BuiltinOptions::Pool2D {
                    padding,
                    stride_w,
                    stride_h,
                    filter_width,
                    filter_height,
                    fused_activation,
                } = &op.options
                else {
                    return Err(format!("expected Pool2D options, got {:?}", op.options));
                };
                let x = self.input_at(op, 0, "pool input")?;
                let kh = usize::try_from(filter_height).map_err(|_| "bad filter height")?;
                let kw = usize::try_from(filter_width).map_err(|_| "bad filter width")?;
                let (kernel, stride) = self.geom(stride_w, stride_h, kh, kw)?;
                let pad = padding_of(padding)?;
                self.check_spatial(x, output, kernel, stride, pad, None)?;
                let kind = if code == builtin_op::MAX_POOL_2D {
                    self.require_same_qparams(x, output, "max pool")?;
                    OpKind::MaxPool2D { kernel, stride, padding: pad }
                } else {
                    if self.g.tensors[output].dtype == DType::I8 {
                        return Err(
                            "int8 average pool unsupported (the i8 interpreter has no kernel)"
                                .into(),
                        );
                    }
                    OpKind::AvgPool2D { kernel, stride, padding: pad }
                };
                let act = act_of(fused_activation)?;
                self.push_with_act(oi, kind, vec![x], vec![], output, act)
            }
            builtin_op::MEAN => {
                let x = self.input_at(op, 0, "mean input")?;
                let axes_t = self.input_at(op, 1, "mean axes")?;
                self.require_weight(axes_t, "mean axes")?;
                let axes = match self.ws.data.get(&axes_t) {
                    Some(TensorData::I32(v)) => {
                        let mut a = v.clone();
                        a.sort_unstable();
                        a
                    }
                    _ => return Err("mean axes must be an i32 constant".into()),
                };
                if axes != [1, 2] {
                    return Err(format!(
                        "mean over axes {axes:?} unsupported (global spatial mean [1,2] only)"
                    ));
                }
                let (_, _, _, c) = self.nhwc(x, "mean input")?;
                if self.g.tensors[output].elems() != c {
                    return Err("mean output must hold one value per channel".into());
                }
                self.require_same_qparams(x, output, "mean")?;
                self.push_op(
                    self.g.tensors[output].name.clone(),
                    OpKind::GlobalAvgPool,
                    vec![x],
                    vec![],
                    output,
                    Some(oi),
                )
            }
            builtin_op::RELU | builtin_op::RELU6 => {
                let x = self.input_at(op, 0, "relu input")?;
                self.require_same_qparams(x, output, "relu")?;
                let kind = if code == builtin_op::RELU { OpKind::Relu } else { OpKind::Relu6 };
                let name = self.g.tensors[output].name.clone();
                self.push_op(name, kind, vec![x], vec![], output, Some(oi))
            }
            builtin_op::SOFTMAX => {
                let &BuiltinOptions::Softmax { beta } = &op.options else {
                    return Err(format!("expected Softmax options, got {:?}", op.options));
                };
                if beta != 1.0 {
                    return Err(format!("softmax beta {beta} unsupported (want 1.0)"));
                }
                let x = self.input_at(op, 0, "softmax input")?;
                // The i8 kernel writes the conventional domain regardless
                // of what the tensor declares — reject a mismatch rather
                // than compute values in a silently wrong domain.
                if let Some(q) = self.ws.qparams.get(&output) {
                    if (q.scale, q.zero_point) != (1.0 / 256.0, -128) {
                        return Err(format!(
                            "softmax output quantization (scale {}, zp {}) unsupported \
                             (the i8 kernel writes scale 1/256, zp -128)",
                            q.scale, q.zero_point
                        ));
                    }
                }
                self.push_op(
                    self.g.tensors[output].name.clone(),
                    OpKind::Softmax,
                    vec![x],
                    vec![],
                    output,
                    Some(oi),
                )
            }
            builtin_op::RESHAPE => {
                let x = self.input_at(op, 0, "reshape input")?;
                // The optional second input (the shape as a const tensor)
                // stays an unreferenced constant; the output tensor's
                // declared shape is authoritative.
                if self.g.tensors[x].elems() != self.g.tensors[output].elems() {
                    return Err("reshape changes element count".into());
                }
                self.require_same_qparams(x, output, "reshape")?;
                self.push_op(
                    self.g.tensors[output].name.clone(),
                    OpKind::Reshape,
                    vec![x],
                    vec![],
                    output,
                    Some(oi),
                )
            }
            other => Err(format!("unsupported builtin operator {}", builtin_op::name(other))),
        }
    }
}

/// OHWI `[cout, kh, kw, cin]` → HWIO `[kh, kw, cin, cout]`.
fn transpose_ohwi<T: Copy + Default>(
    v: &[T],
    cout: usize,
    kh: usize,
    kw: usize,
    cin: usize,
) -> Vec<T> {
    let mut out = vec![T::default(); v.len()];
    for oc in 0..cout {
        for y in 0..kh {
            for x in 0..kw {
                for ic in 0..cin {
                    out[((y * kw + x) * cin + ic) * cout + oc] =
                        v[((oc * kh + y) * kw + x) * cin + ic];
                }
            }
        }
    }
    out
}

/// `[rows, cols]` → `[cols, rows]`.
fn transpose_2d<T: Copy + Default>(v: &[T], rows: usize, cols: usize) -> Vec<T> {
    let mut out = vec![T::default(); v.len()];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = v[r * cols + c];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    use super::super::schema::{
        builtin_op, tensor_type, BuiltinOptions, Model, OperatorCode, OperatorDef, Quantization,
        SubGraphDef, TensorDef,
    };

    /// Tiny int8 `x → relu → y` model with chosen output scale.
    fn relu_model(out_scale: f32) -> Model {
        let t = |name: &str, scale: f32| TensorDef {
            shape: vec![1, 4],
            ttype: tensor_type::INT8,
            buffer: 0,
            name: name.into(),
            quantization: Quantization {
                scale: vec![scale],
                zero_point: vec![0],
                ..Default::default()
            },
        };
        Model {
            version: 3,
            description: String::new(),
            operator_codes: vec![OperatorCode { builtin_code: builtin_op::RELU, version: 1 }],
            buffers: vec![vec![]],
            subgraph: SubGraphDef {
                name: "m".into(),
                tensors: vec![t("x", 0.5), t("y", out_scale)],
                inputs: vec![0],
                outputs: vec![1],
                operators: vec![OperatorDef {
                    opcode_index: 0,
                    inputs: vec![0],
                    outputs: vec![1],
                    options: BuiltinOptions::None,
                }],
            },
            metadata_buffer: vec![],
            metadata: vec![],
            signature_defs: vec![],
        }
    }

    #[test]
    fn rejects_domain_preserving_qparams_mismatch() {
        let err = import(&relu_model(0.25)).unwrap_err();
        assert!(err.contains("domain-preserving"), "unexpected error: {err}");
        import(&relu_model(0.5)).expect("matching domains import fine");
    }

    #[test]
    fn rejects_out_of_range_tensor_indices() {
        // Indices are bounded by the *file's* tensor count, never by the
        // live list that grows with synthesized .preact tensors.
        let mut m = relu_model(0.5);
        m.subgraph.outputs = vec![2];
        let err = import(&m).unwrap_err();
        assert!(err.contains("out of range"), "unexpected error: {err}");
        let mut m = relu_model(0.5);
        m.subgraph.operators[0].inputs = vec![-2];
        assert!(import(&m).is_err());
    }

    #[test]
    fn transposes_are_inverses_of_layout() {
        // OHWI [2,1,1,3]: filter f[oc][ic]; HWIO index [ic*cout + oc].
        let ohwi = vec![10, 11, 12, 20, 21, 22];
        let hwio = transpose_ohwi(&ohwi, 2, 1, 1, 3);
        assert_eq!(hwio, vec![10, 20, 11, 21, 12, 22]);
        let t = transpose_2d(&[1, 2, 3, 4, 5, 6], 2, 3);
        assert_eq!(t, vec![1, 4, 2, 5, 3, 6]);
    }
}

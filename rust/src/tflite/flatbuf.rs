//! Minimal flatbuffer wire-format reader/writer.
//!
//! Implements exactly the subset of the flatbuffers binary format the
//! TFLite schema needs — tables with vtables, scalar fields, `uoffset`
//! indirections, vectors (scalar and table), and strings — with no
//! external crates, matching the in-tree `anyhow`/`json` precedent.
//!
//! The reader is fully bounds-checked and never panics on malformed or
//! truncated input: every access returns `Err` with a position-stamped
//! message, which the CLI surfaces as a clean nonzero exit. The writer
//! builds buffers back-to-front (the canonical flatbuffers algorithm):
//! objects are pushed into a reversed byte stack, alignment is tracked
//! relative to the buffer end, and `finish` reverses the stack after
//! prepending the root offset and file identifier.
//!
//! Wire format recap (little-endian throughout):
//! - file: `u32` root table offset (from buffer start), optional 4-byte
//!   file identifier at bytes 4..8;
//! - table: `i32` soffset to its vtable (`vtable_pos = table_pos - soffset`),
//!   then inline field data;
//! - vtable: `u16` vtable size, `u16` table size, then one `u16` per field
//!   slot holding the field's offset from the table start (0 = absent);
//! - vector: `u32` element count, then elements; string: `u32` byte count,
//!   bytes, NUL terminator;
//! - reference fields store a `u32` offset from the field position to the
//!   target object.

/// Reader errors are strings with byte positions baked in; the schema
/// layer wraps them with which table/field was being read.
pub type Result<T> = std::result::Result<T, String>;

// ---------------------------------------------------------------------------
// reader
// ---------------------------------------------------------------------------

/// Bounds-checked view over a flatbuffer byte slice.
pub struct Reader<'a> {
    buf: &'a [u8],
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    fn bytes(&self, pos: usize, n: usize) -> Result<&'a [u8]> {
        let end = pos
            .checked_add(n)
            .ok_or_else(|| format!("offset overflow at position {pos}"))?;
        self.buf
            .get(pos..end)
            .ok_or_else(|| format!("truncated: need bytes {pos}..{end}, have {}", self.buf.len()))
    }

    pub fn u8(&self, pos: usize) -> Result<u8> {
        Ok(self.bytes(pos, 1)?[0])
    }

    pub fn i8(&self, pos: usize) -> Result<i8> {
        Ok(self.u8(pos)? as i8)
    }

    pub fn u16(&self, pos: usize) -> Result<u16> {
        Ok(u16::from_le_bytes(self.bytes(pos, 2)?.try_into().unwrap()))
    }

    pub fn u32(&self, pos: usize) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(pos, 4)?.try_into().unwrap()))
    }

    pub fn i32(&self, pos: usize) -> Result<i32> {
        Ok(i32::from_le_bytes(self.bytes(pos, 4)?.try_into().unwrap()))
    }

    pub fn i64(&self, pos: usize) -> Result<i64> {
        Ok(i64::from_le_bytes(self.bytes(pos, 8)?.try_into().unwrap()))
    }

    pub fn f32(&self, pos: usize) -> Result<f32> {
        Ok(f32::from_le_bytes(self.bytes(pos, 4)?.try_into().unwrap()))
    }

    /// Position of the root table.
    pub fn root(&self) -> Result<Table> {
        let pos = self.u32(0)? as usize;
        Table::at(self, pos)
    }

    /// The 4-byte file identifier, if the buffer is long enough to carry
    /// one.
    pub fn identifier(&self) -> Option<&'a [u8]> {
        self.buf.get(4..8)
    }

    /// Follow a `uoffset` stored at `pos`.
    fn indirect(&self, pos: usize) -> Result<usize> {
        let off = self.u32(pos)? as usize;
        if off == 0 {
            return Err(format!("null forward offset at position {pos}"));
        }
        pos.checked_add(off)
            .ok_or_else(|| format!("forward offset overflow at position {pos}"))
    }

    /// Vector at `pos`: returns (element base position, element count).
    /// `elem_size` bounds-checks the payload up front so element reads
    /// can't run past the buffer.
    pub fn vector(&self, pos: usize, elem_size: usize) -> Result<(usize, usize)> {
        let n = self.u32(pos)? as usize;
        let base = pos + 4;
        let total = n
            .checked_mul(elem_size)
            .ok_or_else(|| format!("vector length overflow at position {pos}"))?;
        self.bytes(base, total)?;
        Ok((base, n))
    }

    /// String at `pos` (u32 length + bytes; terminator not included).
    pub fn string(&self, pos: usize) -> Result<String> {
        let (base, n) = self.vector(pos, 1)?;
        let bytes = self.bytes(base, n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| format!("non-UTF-8 string at {pos}"))
    }
}

/// A table position plus its resolved vtable.
#[derive(Clone, Copy, Debug)]
pub struct Table {
    pub pos: usize,
    vtable: usize,
    vtable_len: usize,
}

impl Table {
    /// Resolve the table at `pos`, validating its vtable.
    pub fn at(r: &Reader, pos: usize) -> Result<Table> {
        let soffset = r.i32(pos)? as i64;
        let vtable = (pos as i64)
            .checked_sub(soffset)
            .filter(|&v| v >= 0)
            .ok_or_else(|| format!("table at {pos}: vtable offset out of range"))? as usize;
        let vtable_len = r.u16(vtable)? as usize;
        if vtable_len < 4 || vtable_len % 2 != 0 {
            return Err(format!("table at {pos}: bad vtable size {vtable_len}"));
        }
        // Touch the last vtable byte so field lookups can't run out.
        r.u16(vtable + vtable_len - 2)?;
        Ok(Table { pos, vtable, vtable_len })
    }

    /// Position of field `id`'s inline data, or `None` if absent.
    pub fn field(&self, r: &Reader, id: u16) -> Result<Option<usize>> {
        let slot = 4 + 2 * id as usize;
        if slot + 2 > self.vtable_len {
            return Ok(None);
        }
        let off = r.u16(self.vtable + slot)? as usize;
        if off == 0 {
            return Ok(None);
        }
        Ok(Some(self.pos + off))
    }

    pub fn u8_field(&self, r: &Reader, id: u16, default: u8) -> Result<u8> {
        match self.field(r, id)? {
            Some(p) => r.u8(p),
            None => Ok(default),
        }
    }

    pub fn i8_field(&self, r: &Reader, id: u16, default: i8) -> Result<i8> {
        match self.field(r, id)? {
            Some(p) => r.i8(p),
            None => Ok(default),
        }
    }

    pub fn bool_field(&self, r: &Reader, id: u16, default: bool) -> Result<bool> {
        Ok(self.u8_field(r, id, default as u8)? != 0)
    }

    pub fn i32_field(&self, r: &Reader, id: u16, default: i32) -> Result<i32> {
        match self.field(r, id)? {
            Some(p) => r.i32(p),
            None => Ok(default),
        }
    }

    pub fn u32_field(&self, r: &Reader, id: u16, default: u32) -> Result<u32> {
        match self.field(r, id)? {
            Some(p) => r.u32(p),
            None => Ok(default),
        }
    }

    pub fn f32_field(&self, r: &Reader, id: u16, default: f32) -> Result<f32> {
        match self.field(r, id)? {
            Some(p) => r.f32(p),
            None => Ok(default),
        }
    }

    /// Follow a reference field (table, vector or string target position).
    pub fn offset_field(&self, r: &Reader, id: u16) -> Result<Option<usize>> {
        match self.field(r, id)? {
            Some(p) => Ok(Some(r.indirect(p)?)),
            None => Ok(None),
        }
    }

    pub fn table_field(&self, r: &Reader, id: u16) -> Result<Option<Table>> {
        match self.offset_field(r, id)? {
            Some(p) => Ok(Some(Table::at(r, p)?)),
            None => Ok(None),
        }
    }

    pub fn string_field(&self, r: &Reader, id: u16) -> Result<Option<String>> {
        match self.offset_field(r, id)? {
            Some(p) => Ok(Some(r.string(p)?)),
            None => Ok(None),
        }
    }

    /// Scalar vector field decoded with `get` per element.
    fn scalar_vec<T>(
        &self,
        r: &Reader,
        id: u16,
        elem_size: usize,
        get: impl Fn(&Reader, usize) -> Result<T>,
    ) -> Result<Vec<T>> {
        match self.offset_field(r, id)? {
            None => Ok(Vec::new()),
            Some(p) => {
                let (base, n) = r.vector(p, elem_size)?;
                (0..n).map(|i| get(r, base + i * elem_size)).collect()
            }
        }
    }

    pub fn i32_vec_field(&self, r: &Reader, id: u16) -> Result<Vec<i32>> {
        self.scalar_vec(r, id, 4, |r, p| r.i32(p))
    }

    pub fn f32_vec_field(&self, r: &Reader, id: u16) -> Result<Vec<f32>> {
        self.scalar_vec(r, id, 4, |r, p| r.f32(p))
    }

    pub fn i64_vec_field(&self, r: &Reader, id: u16) -> Result<Vec<i64>> {
        self.scalar_vec(r, id, 8, |r, p| r.i64(p))
    }

    /// Byte-vector field, sliced in one go (buffer payloads can be
    /// megabytes; `vector` has already bounds-checked the whole range).
    pub fn bytes_field(&self, r: &Reader, id: u16) -> Result<Vec<u8>> {
        match self.offset_field(r, id)? {
            None => Ok(Vec::new()),
            Some(p) => {
                let (base, n) = r.vector(p, 1)?;
                Ok(r.bytes(base, n)?.to_vec())
            }
        }
    }

    /// Vector-of-tables field: resolved element tables in order.
    pub fn tables_field(&self, r: &Reader, id: u16) -> Result<Vec<Table>> {
        match self.offset_field(r, id)? {
            None => Ok(Vec::new()),
            Some(p) => {
                let (base, n) = r.vector(p, 4)?;
                (0..n)
                    .map(|i| Table::at(r, r.indirect(base + i * 4)?))
                    .collect()
            }
        }
    }
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

/// End-offset of an object already written into the builder (distance
/// from the final buffer end to the object's first byte).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WPos(usize);

/// A present table field: id plus value. Absent fields are simply not
/// listed (their vtable slot stays 0), which is how flatbuffers encodes
/// defaults.
#[derive(Clone, Copy, Debug)]
pub enum FieldVal {
    U8(u8),
    I8(i8),
    Bool(bool),
    I32(i32),
    U32(u32),
    F32(f32),
    /// Reference to an already-written object (table/vector/string).
    Off(WPos),
}

/// Back-to-front flatbuffer builder.
#[derive(Default)]
pub struct Builder {
    /// Reversed byte stack: `rev[0]` is the final buffer's last byte.
    rev: Vec<u8>,
    max_align: usize,
}

impl Builder {
    pub fn new() -> Builder {
        Builder { rev: Vec::with_capacity(1024), max_align: 1 }
    }

    /// Pad so that after writing `extra` more bytes the position is
    /// `align`-aligned relative to the buffer end.
    fn prep(&mut self, align: usize, extra: usize) {
        self.max_align = self.max_align.max(align);
        while (self.rev.len() + extra) % align != 0 {
            self.rev.push(0);
        }
    }

    /// Push bytes that must appear in `bytes` order in the final buffer.
    fn push(&mut self, bytes: &[u8]) {
        self.rev.extend(bytes.iter().rev());
    }

    fn push_u16(&mut self, v: u16) {
        self.push(&v.to_le_bytes());
    }

    fn push_u32(&mut self, v: u32) {
        self.push(&v.to_le_bytes());
    }

    /// Write a forward reference to `target` (4 bytes at the current
    /// position).
    fn push_uoffset(&mut self, target: WPos) {
        debug_assert!(target.0 <= self.rev.len(), "forward reference to unwritten object");
        let v = (self.rev.len() + 4 - target.0) as u32;
        self.push_u32(v);
    }

    /// Byte vector (also used for buffer payloads).
    pub fn byte_vector(&mut self, data: &[u8]) -> WPos {
        self.prep(4, data.len() + 4);
        self.push(data);
        self.push_u32(data.len() as u32);
        WPos(self.rev.len())
    }

    pub fn string(&mut self, s: &str) -> WPos {
        self.prep(4, s.len() + 1 + 4);
        self.rev.push(0); // NUL terminator (last byte of the string)
        self.push(s.as_bytes());
        self.push_u32(s.len() as u32);
        WPos(self.rev.len())
    }

    pub fn i32_vector(&mut self, vals: &[i32]) -> WPos {
        self.prep(4, vals.len() * 4 + 4);
        for &v in vals.iter().rev() {
            self.push(&v.to_le_bytes());
        }
        self.push_u32(vals.len() as u32);
        WPos(self.rev.len())
    }

    pub fn f32_vector(&mut self, vals: &[f32]) -> WPos {
        self.prep(4, vals.len() * 4 + 4);
        for &v in vals.iter().rev() {
            self.push(&v.to_le_bytes());
        }
        self.push_u32(vals.len() as u32);
        WPos(self.rev.len())
    }

    pub fn i64_vector(&mut self, vals: &[i64]) -> WPos {
        // Canonical two-step vector prep: the *elements* must be
        // 8-aligned (and the buffer end 8-aligned overall), which puts
        // the u32 length word at 4 mod 8 — exactly how flatbuffers lays
        // out wide-element vectors.
        self.prep(4, vals.len() * 8);
        self.prep(8, vals.len() * 8);
        for &v in vals.iter().rev() {
            self.push(&v.to_le_bytes());
        }
        self.push_u32(vals.len() as u32);
        WPos(self.rev.len())
    }

    /// Vector of references to already-written objects.
    pub fn offset_vector(&mut self, targets: &[WPos]) -> WPos {
        self.prep(4, targets.len() * 4 + 4);
        for &t in targets.iter().rev() {
            self.push_uoffset(t);
        }
        self.push_u32(targets.len() as u32);
        WPos(self.rev.len())
    }

    /// Write a table from its present fields (any order; they are laid
    /// out by descending field id so ids ascend in the file). Each table
    /// gets its own vtable — no deduplication, slightly larger files but
    /// identical semantics.
    pub fn table(&mut self, fields: &[(u16, FieldVal)]) -> WPos {
        let start = self.rev.len();
        let mut sorted: Vec<&(u16, FieldVal)> = fields.iter().collect();
        sorted.sort_by_key(|(id, _)| std::cmp::Reverse(*id));
        let mut slots: Vec<(u16, usize)> = Vec::with_capacity(sorted.len());
        for &&(id, val) in &sorted {
            match val {
                FieldVal::U8(v) => {
                    self.prep(1, 0);
                    self.rev.push(v);
                }
                FieldVal::I8(v) => {
                    self.prep(1, 0);
                    self.rev.push(v as u8);
                }
                FieldVal::Bool(v) => {
                    self.prep(1, 0);
                    self.rev.push(v as u8);
                }
                FieldVal::I32(v) => {
                    self.prep(4, 0);
                    self.push(&v.to_le_bytes());
                }
                FieldVal::U32(v) => {
                    self.prep(4, 0);
                    self.push_u32(v);
                }
                FieldVal::F32(v) => {
                    self.prep(4, 0);
                    self.push(&v.to_le_bytes());
                }
                FieldVal::Off(t) => {
                    self.prep(4, 0);
                    self.push_uoffset(t);
                }
            }
            slots.push((id, self.rev.len()));
        }
        let n_slots = fields.iter().map(|&(id, _)| id as usize + 1).max().unwrap_or(0);
        let vtable_len = 4 + 2 * n_slots;
        // The vtable is emitted immediately before the table in the file,
        // so the soffset is simply its size.
        self.prep(4, 0);
        self.push(&(vtable_len as i32).to_le_bytes());
        let table_pos = self.rev.len();
        let table_len = table_pos - start;
        for id in (0..n_slots as u16).rev() {
            let off = slots
                .iter()
                .find(|&(fid, _)| *fid == id)
                .map(|&(_, fo)| (table_pos - fo) as u16)
                .unwrap_or(0);
            self.push_u16(off);
        }
        self.push_u16(table_len as u16);
        self.push_u16(vtable_len as u16);
        WPos(table_pos)
    }

    /// Finalize: prepend the root offset (and file identifier) and return
    /// the buffer in file order.
    pub fn finish(mut self, root: WPos, identifier: &[u8; 4]) -> Vec<u8> {
        let align = self.max_align.max(4);
        self.prep(align, 8);
        self.push(identifier);
        self.push_uoffset(root);
        self.rev.reverse();
        self.rev
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_table_roundtrip() {
        let mut b = Builder::new();
        let t = b.table(&[
            (0, FieldVal::U32(7)),
            (2, FieldVal::I32(-3)),
            (3, FieldVal::U8(9)),
            (5, FieldVal::F32(1.5)),
        ]);
        let buf = b.finish(t, b"TST0");
        let r = Reader::new(&buf);
        assert_eq!(r.identifier(), Some(&b"TST0"[..]));
        let root = r.root().unwrap();
        assert_eq!(root.u32_field(&r, 0, 0).unwrap(), 7);
        assert_eq!(root.i32_field(&r, 1, 42).unwrap(), 42, "absent field → default");
        assert_eq!(root.i32_field(&r, 2, 0).unwrap(), -3);
        assert_eq!(root.u8_field(&r, 3, 0).unwrap(), 9);
        assert_eq!(root.f32_field(&r, 5, 0.0).unwrap(), 1.5);
        assert_eq!(root.field(&r, 99).unwrap(), None, "beyond vtable → absent");
    }

    #[test]
    fn strings_vectors_and_nesting() {
        let mut b = Builder::new();
        let name = b.string("hello");
        let shape = b.i32_vector(&[1, 8, 8, 3]);
        let zps = b.i64_vector(&[-128]);
        let payload = b.byte_vector(&[1, 2, 3, 4, 5]);
        let inner = b.table(&[(0, FieldVal::Off(name)), (1, FieldVal::Off(shape))]);
        let inners = b.offset_vector(&[inner, inner]);
        let root = b.table(&[
            (0, FieldVal::Off(inners)),
            (1, FieldVal::Off(payload)),
            (2, FieldVal::Off(zps)),
        ]);
        let buf = b.finish(root, b"TST0");

        let r = Reader::new(&buf);
        let root = r.root().unwrap();
        let ts = root.tables_field(&r, 0).unwrap();
        assert_eq!(ts.len(), 2);
        for t in &ts {
            assert_eq!(t.string_field(&r, 0).unwrap().as_deref(), Some("hello"));
            assert_eq!(t.i32_vec_field(&r, 1).unwrap(), vec![1, 8, 8, 3]);
        }
        assert_eq!(root.bytes_field(&r, 1).unwrap(), vec![1, 2, 3, 4, 5]);
        assert_eq!(root.i64_vec_field(&r, 2).unwrap(), vec![-128]);
        assert_eq!(root.tables_field(&r, 7).unwrap().len(), 0, "absent vector → empty");
    }

    #[test]
    fn alignment_of_every_scalar_access() {
        // i64 vectors force 8-alignment of the whole buffer; make sure
        // interior objects stay aligned after the final reversal.
        let mut b = Builder::new();
        let zps = b.i64_vector(&[1, 2, 3]);
        let f = b.f32_vector(&[0.5]);
        let t = b.table(&[(0, FieldVal::Off(zps)), (1, FieldVal::Off(f))]);
        let buf = b.finish(t, b"TST0");
        assert_eq!(buf.len() % 8, 0);
        let r = Reader::new(&buf);
        let root = r.root().unwrap();
        let zp_pos = root.offset_field(&r, 0).unwrap().unwrap();
        assert_eq!((zp_pos + 4) % 8, 0, "i64 elements must be 8-aligned");
        assert_eq!(root.i64_vec_field(&r, 0).unwrap(), vec![1, 2, 3]);
        assert_eq!(root.f32_vec_field(&r, 1).unwrap(), vec![0.5]);
    }

    #[test]
    fn truncated_and_corrupt_buffers_error_cleanly() {
        // Empty, tiny, and garbage buffers must all error, never panic.
        for bad in [&[][..], &[1u8][..], &[255u8; 4][..], &[0u8; 16][..]] {
            let r = Reader::new(bad);
            assert!(r.root().is_err() || r.root().unwrap().field(&r, 0).is_err());
        }
        // A valid buffer truncated at every possible length errors cleanly.
        let mut b = Builder::new();
        let s = b.string("payload");
        let v = b.i32_vector(&[1, 2, 3]);
        let t = b.table(&[(0, FieldVal::Off(s)), (1, FieldVal::Off(v))]);
        let buf = b.finish(t, b"TST0");
        for cut in 0..buf.len() {
            let r = Reader::new(&buf[..cut]);
            // Any of these may fail; none may panic.
            if let Ok(root) = r.root() {
                let _ = root.string_field(&r, 0);
                let _ = root.i32_vec_field(&r, 1);
            }
        }
    }

    #[test]
    fn huge_vector_length_is_rejected() {
        // A vector whose claimed length overflows or exceeds the buffer
        // must be rejected up front.
        let mut b = Builder::new();
        let v = b.i32_vector(&[5]);
        let t = b.table(&[(0, FieldVal::Off(v))]);
        let mut buf = b.finish(t, b"TST0");
        let r = Reader::new(&buf);
        let root = r.root().unwrap();
        let vec_pos = root.offset_field(&r, 0).unwrap().unwrap();
        buf[vec_pos..vec_pos + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let r = Reader::new(&buf);
        let root = r.root().unwrap();
        assert!(root.i32_vec_field(&r, 0).is_err());
    }
}

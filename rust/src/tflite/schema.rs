//! TFLite schema bindings (the subset this repo covers).
//!
//! Mirrors `schema.fbs` v3 for the tables the importer/exporter touch:
//! `Model`, `OperatorCode`, `SubGraph`, `Tensor`, `QuantizationParameters`,
//! `Operator`, `Buffer`, and the builtin-options tables of the supported
//! operators. Parsing materializes an owned [`Model`] (buffers are kept as
//! raw bytes so the exporter can write them back byte-identically);
//! serialization is deterministic, so export → import → export is
//! byte-stable.

use super::flatbuf::{Builder, FieldVal, Reader, Result, Table, WPos};

/// `TensorType` enum values (schema.fbs).
pub mod tensor_type {
    pub const FLOAT32: i8 = 0;
    pub const INT32: i8 = 2;
    pub const UINT8: i8 = 3;
    pub const INT64: i8 = 4;
    pub const INT8: i8 = 9;
}

/// `BuiltinOperator` codes for the supported subset.
pub mod builtin_op {
    pub const ADD: i32 = 0;
    pub const AVERAGE_POOL_2D: i32 = 1;
    pub const CONCATENATION: i32 = 2;
    pub const CONV_2D: i32 = 3;
    pub const DEPTHWISE_CONV_2D: i32 = 4;
    pub const FULLY_CONNECTED: i32 = 9;
    pub const MAX_POOL_2D: i32 = 17;
    pub const RELU: i32 = 19;
    pub const RELU6: i32 = 21;
    pub const RESHAPE: i32 = 22;
    pub const SOFTMAX: i32 = 25;
    pub const MEAN: i32 = 40;

    pub fn name(code: i32) -> String {
        match code {
            ADD => "ADD".into(),
            AVERAGE_POOL_2D => "AVERAGE_POOL_2D".into(),
            CONCATENATION => "CONCATENATION".into(),
            CONV_2D => "CONV_2D".into(),
            DEPTHWISE_CONV_2D => "DEPTHWISE_CONV_2D".into(),
            FULLY_CONNECTED => "FULLY_CONNECTED".into(),
            MAX_POOL_2D => "MAX_POOL_2D".into(),
            RELU => "RELU".into(),
            RELU6 => "RELU6".into(),
            RESHAPE => "RESHAPE".into(),
            SOFTMAX => "SOFTMAX".into(),
            MEAN => "MEAN".into(),
            other => format!("builtin op {other}"),
        }
    }
}

/// `ActivationFunctionType` enum values.
pub mod activation {
    pub const NONE: i8 = 0;
    pub const RELU: i8 = 1;
    pub const RELU6: i8 = 3;
}

/// `Padding` enum values.
pub mod padding {
    pub const SAME: i8 = 0;
    pub const VALID: i8 = 1;
}

/// `BuiltinOptions` union type values for the supported subset.
pub mod options_type {
    pub const NONE: u8 = 0;
    pub const CONV_2D: u8 = 1;
    pub const DEPTHWISE_CONV_2D: u8 = 2;
    pub const POOL_2D: u8 = 5;
    pub const FULLY_CONNECTED: u8 = 8;
    pub const SOFTMAX: u8 = 9;
    pub const CONCATENATION: u8 = 10;
    pub const ADD: u8 = 11;
    pub const RESHAPE: u8 = 17;
    pub const REDUCER: u8 = 27;
}

/// Builtin options of a supported operator, decoded into plain fields.
#[derive(Clone, Debug, PartialEq)]
pub enum BuiltinOptions {
    None,
    Conv2D { padding: i8, stride_w: i32, stride_h: i32, fused_activation: i8 },
    DepthwiseConv2D {
        padding: i8,
        stride_w: i32,
        stride_h: i32,
        depth_multiplier: i32,
        fused_activation: i8,
    },
    Pool2D {
        padding: i8,
        stride_w: i32,
        stride_h: i32,
        filter_width: i32,
        filter_height: i32,
        fused_activation: i8,
    },
    FullyConnected { fused_activation: i8 },
    Softmax { beta: f32 },
    Concatenation { axis: i32, fused_activation: i8 },
    Add { fused_activation: i8 },
    Reshape { new_shape: Vec<i32> },
    Reducer { keep_dims: bool },
}

/// `QuantizationParameters` (per-tensor affine; `scale`/`zero_point` may
/// carry one entry per channel for per-channel-quantized weights, which
/// the importer rejects with a clear message).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Quantization {
    pub min: Vec<f32>,
    pub max: Vec<f32>,
    pub scale: Vec<f32>,
    pub zero_point: Vec<i64>,
    pub quantized_dimension: i32,
}

impl Quantization {
    pub fn is_empty(&self) -> bool {
        self.min.is_empty()
            && self.max.is_empty()
            && self.scale.is_empty()
            && self.zero_point.is_empty()
    }
}

/// `Tensor` table.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorDef {
    pub shape: Vec<i32>,
    pub ttype: i8,
    pub buffer: usize,
    pub name: String,
    pub quantization: Quantization,
}

/// `Operator` table.
#[derive(Clone, Debug, PartialEq)]
pub struct OperatorDef {
    pub opcode_index: usize,
    /// Tensor indices; `-1` marks an optional input that is absent.
    pub inputs: Vec<i32>,
    pub outputs: Vec<i32>,
    pub options: BuiltinOptions,
}

/// `OperatorCode` table. Readers take the max of the deprecated i8 code
/// and the extended i32 field (schema evolution for codes > 127).
#[derive(Clone, Debug, PartialEq)]
pub struct OperatorCode {
    pub builtin_code: i32,
    pub version: i32,
}

/// `SubGraph` table.
#[derive(Clone, Debug, PartialEq)]
pub struct SubGraphDef {
    pub name: String,
    pub tensors: Vec<TensorDef>,
    pub inputs: Vec<i32>,
    pub outputs: Vec<i32>,
    pub operators: Vec<OperatorDef>,
}

/// `Metadata` table entry (e.g. `min_runtime_version`); the payload lives
/// in `buffers`, which the exporter preserves verbatim.
#[derive(Clone, Debug, PartialEq)]
pub struct MetadataDef {
    pub name: String,
    pub buffer: usize,
}

/// `TensorMap` entry of a `SignatureDef`.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorMap {
    pub name: String,
    pub tensor_index: u32,
}

/// `SignatureDef` table. Reordering operators never renumbers tensors,
/// so signatures survive an export unchanged.
#[derive(Clone, Debug, PartialEq)]
pub struct SignatureDef {
    pub inputs: Vec<TensorMap>,
    pub outputs: Vec<TensorMap>,
    pub signature_key: String,
    pub subgraph_index: u32,
}

/// Owned `Model`: everything needed to rewrite the file. Buffer payloads
/// are raw bytes, preserved verbatim across import → export; metadata and
/// signature defs are carried through so a converter-produced model keeps
/// its runtime-version stamp and signature runners after `optimize`.
#[derive(Clone, Debug, PartialEq)]
pub struct Model {
    pub version: u32,
    pub description: String,
    pub operator_codes: Vec<OperatorCode>,
    pub buffers: Vec<Vec<u8>>,
    pub subgraph: SubGraphDef,
    pub metadata_buffer: Vec<i32>,
    pub metadata: Vec<MetadataDef>,
    pub signature_defs: Vec<SignatureDef>,
}

pub const FILE_IDENTIFIER: &[u8; 4] = b"TFL3";

// ---------------------------------------------------------------------------
// parse
// ---------------------------------------------------------------------------

fn parse_quantization(r: &Reader, t: Option<Table>) -> Result<Quantization> {
    let Some(t) = t else { return Ok(Quantization::default()) };
    Ok(Quantization {
        min: t.f32_vec_field(r, 0)?,
        max: t.f32_vec_field(r, 1)?,
        scale: t.f32_vec_field(r, 2)?,
        zero_point: t.i64_vec_field(r, 3)?,
        quantized_dimension: t.i32_field(r, 6, 0)?,
    })
}

fn parse_tensor(r: &Reader, t: Table) -> Result<TensorDef> {
    Ok(TensorDef {
        shape: t.i32_vec_field(r, 0)?,
        ttype: t.i8_field(r, 1, 0)?,
        buffer: t.u32_field(r, 2, 0)? as usize,
        name: t.string_field(r, 3)?.unwrap_or_default(),
        quantization: parse_quantization(r, t.table_field(r, 4)?)?,
    })
}

fn parse_options(r: &Reader, op: Table) -> Result<BuiltinOptions> {
    let ty = op.u8_field(r, 3, options_type::NONE)?;
    let t = op.table_field(r, 4)?;
    let need = |what: &str| -> Result<Table> {
        t.ok_or_else(|| format!("operator declares {what} options but carries none"))
    };
    Ok(match ty {
        options_type::NONE => BuiltinOptions::None,
        options_type::CONV_2D => {
            let t = need("Conv2D")?;
            // Dilation (fields 4/5, default 1) is outside the supported
            // subset; silently dropping it would import a model that
            // computes different values.
            let (dw, dh) = (t.i32_field(r, 4, 1)?, t.i32_field(r, 5, 1)?);
            if (dw, dh) != (1, 1) {
                return Err(format!("dilated convolution ({dh}x{dw}) unsupported"));
            }
            BuiltinOptions::Conv2D {
                padding: t.i8_field(r, 0, 0)?,
                stride_w: t.i32_field(r, 1, 0)?,
                stride_h: t.i32_field(r, 2, 0)?,
                fused_activation: t.i8_field(r, 3, 0)?,
            }
        }
        options_type::DEPTHWISE_CONV_2D => {
            let t = need("DepthwiseConv2D")?;
            let (dw, dh) = (t.i32_field(r, 5, 1)?, t.i32_field(r, 6, 1)?);
            if (dw, dh) != (1, 1) {
                return Err(format!("dilated depthwise convolution ({dh}x{dw}) unsupported"));
            }
            BuiltinOptions::DepthwiseConv2D {
                padding: t.i8_field(r, 0, 0)?,
                stride_w: t.i32_field(r, 1, 0)?,
                stride_h: t.i32_field(r, 2, 0)?,
                depth_multiplier: t.i32_field(r, 3, 0)?,
                fused_activation: t.i8_field(r, 4, 0)?,
            }
        }
        options_type::POOL_2D => {
            let t = need("Pool2D")?;
            BuiltinOptions::Pool2D {
                padding: t.i8_field(r, 0, 0)?,
                stride_w: t.i32_field(r, 1, 0)?,
                stride_h: t.i32_field(r, 2, 0)?,
                filter_width: t.i32_field(r, 3, 0)?,
                filter_height: t.i32_field(r, 4, 0)?,
                fused_activation: t.i8_field(r, 5, 0)?,
            }
        }
        options_type::FULLY_CONNECTED => {
            let t = need("FullyConnected")?;
            // weights_format (field 1): 0 = DEFAULT row-major [out, in];
            // SHUFFLED4x16INT8 would be silently misread as row-major.
            let wf = t.i8_field(r, 1, 0)?;
            if wf != 0 {
                return Err(format!("fully-connected weights format {wf} unsupported"));
            }
            BuiltinOptions::FullyConnected { fused_activation: t.i8_field(r, 0, 0)? }
        }
        options_type::SOFTMAX => {
            let t = need("Softmax")?;
            BuiltinOptions::Softmax { beta: t.f32_field(r, 0, 0.0)? }
        }
        options_type::CONCATENATION => {
            let t = need("Concatenation")?;
            BuiltinOptions::Concatenation {
                axis: t.i32_field(r, 0, 0)?,
                fused_activation: t.i8_field(r, 1, 0)?,
            }
        }
        options_type::ADD => {
            let t = need("Add")?;
            BuiltinOptions::Add { fused_activation: t.i8_field(r, 0, 0)? }
        }
        options_type::RESHAPE => {
            let t = need("Reshape")?;
            BuiltinOptions::Reshape { new_shape: t.i32_vec_field(r, 0)? }
        }
        options_type::REDUCER => {
            let t = need("Reducer")?;
            BuiltinOptions::Reducer { keep_dims: t.bool_field(r, 0, false)? }
        }
        other => return Err(format!("unsupported builtin options type {other}")),
    })
}

fn parse_operator(r: &Reader, t: Table) -> Result<OperatorDef> {
    Ok(OperatorDef {
        opcode_index: t.u32_field(r, 0, 0)? as usize,
        inputs: t.i32_vec_field(r, 1)?,
        outputs: t.i32_vec_field(r, 2)?,
        options: parse_options(r, t)?,
    })
}

fn parse_subgraph(r: &Reader, t: Table) -> Result<SubGraphDef> {
    let tensors = t
        .tables_field(r, 0)?
        .into_iter()
        .map(|tt| parse_tensor(r, tt))
        .collect::<Result<Vec<_>>>()?;
    let operators = t
        .tables_field(r, 3)?
        .into_iter()
        .map(|ot| parse_operator(r, ot))
        .collect::<Result<Vec<_>>>()?;
    Ok(SubGraphDef {
        name: t.string_field(r, 4)?.unwrap_or_default(),
        tensors,
        inputs: t.i32_vec_field(r, 1)?,
        outputs: t.i32_vec_field(r, 2)?,
        operators,
    })
}

impl Model {
    /// Parse a `.tflite` flatbuffer. Errors (never panics) on anything
    /// malformed, truncated, or outside the supported subset.
    pub fn parse(buf: &[u8]) -> Result<Model> {
        let r = Reader::new(buf);
        if r.len() < 8 {
            return Err(format!("not a TFLite flatbuffer: {} bytes", r.len()));
        }
        if r.identifier() != Some(&FILE_IDENTIFIER[..]) {
            return Err("missing TFL3 file identifier".into());
        }
        let root = r.root()?;
        let version = root.u32_field(&r, 0, 0)?;
        if version != 3 {
            return Err(format!("unsupported TFLite schema version {version} (want 3)"));
        }
        let operator_codes = root
            .tables_field(&r, 1)?
            .into_iter()
            .map(|t| {
                let deprecated = t.i8_field(&r, 0, 0)? as i32;
                let extended = t.i32_field(&r, 3, 0)?;
                Ok(OperatorCode {
                    builtin_code: deprecated.max(extended),
                    version: t.i32_field(&r, 2, 1)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let subgraphs = root.tables_field(&r, 2)?;
        if subgraphs.len() != 1 {
            return Err(format!("expected exactly 1 subgraph, found {}", subgraphs.len()));
        }
        let subgraph = parse_subgraph(&r, subgraphs[0])?;
        let buffers = root
            .tables_field(&r, 4)?
            .into_iter()
            .map(|t| t.bytes_field(&r, 0))
            .collect::<Result<Vec<_>>>()?;
        let metadata = root
            .tables_field(&r, 6)?
            .into_iter()
            .map(|t| {
                Ok(MetadataDef {
                    name: t.string_field(&r, 0)?.unwrap_or_default(),
                    buffer: t.u32_field(&r, 1, 0)? as usize,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let tensor_maps = |t: Table, id: u16| -> Result<Vec<TensorMap>> {
            t.tables_field(&r, id)?
                .into_iter()
                .map(|m| {
                    Ok(TensorMap {
                        name: m.string_field(&r, 0)?.unwrap_or_default(),
                        tensor_index: m.u32_field(&r, 1, 0)?,
                    })
                })
                .collect()
        };
        let signature_defs = root
            .tables_field(&r, 7)?
            .into_iter()
            .map(|t| {
                Ok(SignatureDef {
                    inputs: tensor_maps(t, 0)?,
                    outputs: tensor_maps(t, 1)?,
                    signature_key: t.string_field(&r, 2)?.unwrap_or_default(),
                    subgraph_index: t.u32_field(&r, 4, 0)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Model {
            version,
            description: root.string_field(&r, 3)?.unwrap_or_default(),
            operator_codes,
            buffers,
            subgraph,
            metadata_buffer: root.i32_vec_field(&r, 5)?,
            metadata,
            signature_defs,
        })
    }

    /// Serialize back to flatbuffer bytes. Deterministic; buffer payloads
    /// are written verbatim.
    pub fn serialize(&self) -> Vec<u8> {
        let mut owned = Builder::new();
        let b = &mut owned;

        let buffers: Vec<WPos> = self
            .buffers
            .iter()
            .map(|data| {
                if data.is_empty() {
                    b.table(&[])
                } else {
                    let v = b.byte_vector(data);
                    b.table(&[(0, FieldVal::Off(v))])
                }
            })
            .collect();
        let buffers = b.offset_vector(&buffers);

        let codes: Vec<WPos> = self
            .operator_codes
            .iter()
            .map(|c| {
                b.table(&[
                    (0, FieldVal::I8(c.builtin_code.clamp(0, 127) as i8)),
                    (2, FieldVal::I32(c.version)),
                    (3, FieldVal::I32(c.builtin_code)),
                ])
            })
            .collect();
        let codes = b.offset_vector(&codes);

        let tensors: Vec<WPos> = self.subgraph.tensors.iter().map(|t| write_tensor(b, t)).collect();
        let tensors = b.offset_vector(&tensors);
        let operators: Vec<WPos> =
            self.subgraph.operators.iter().map(|o| write_operator(b, o)).collect();
        let operators = b.offset_vector(&operators);
        let sg_inputs = b.i32_vector(&self.subgraph.inputs);
        let sg_outputs = b.i32_vector(&self.subgraph.outputs);
        let sg_name = b.string(&self.subgraph.name);
        let subgraph = b.table(&[
            (0, FieldVal::Off(tensors)),
            (1, FieldVal::Off(sg_inputs)),
            (2, FieldVal::Off(sg_outputs)),
            (3, FieldVal::Off(operators)),
            (4, FieldVal::Off(sg_name)),
        ]);
        let subgraphs = b.offset_vector(&[subgraph]);

        let description = b.string(&self.description);
        let mut root_fields = vec![
            (0, FieldVal::U32(self.version)),
            (1, FieldVal::Off(codes)),
            (2, FieldVal::Off(subgraphs)),
            (3, FieldVal::Off(description)),
            (4, FieldVal::Off(buffers)),
        ];
        if !self.metadata_buffer.is_empty() {
            let v = b.i32_vector(&self.metadata_buffer);
            root_fields.push((5, FieldVal::Off(v)));
        }
        if !self.metadata.is_empty() {
            let entries: Vec<WPos> = self
                .metadata
                .iter()
                .map(|m| {
                    let name = b.string(&m.name);
                    b.table(&[(0, FieldVal::Off(name)), (1, FieldVal::U32(m.buffer as u32))])
                })
                .collect();
            let v = b.offset_vector(&entries);
            root_fields.push((6, FieldVal::Off(v)));
        }
        if !self.signature_defs.is_empty() {
            let write_maps = |b: &mut Builder, maps: &[TensorMap]| {
                let entries: Vec<WPos> = maps
                    .iter()
                    .map(|m| {
                        let name = b.string(&m.name);
                        b.table(&[
                            (0, FieldVal::Off(name)),
                            (1, FieldVal::U32(m.tensor_index)),
                        ])
                    })
                    .collect();
                b.offset_vector(&entries)
            };
            let sigs: Vec<WPos> = self
                .signature_defs
                .iter()
                .map(|s| {
                    let inputs = write_maps(b, &s.inputs);
                    let outputs = write_maps(b, &s.outputs);
                    let key = b.string(&s.signature_key);
                    b.table(&[
                        (0, FieldVal::Off(inputs)),
                        (1, FieldVal::Off(outputs)),
                        (2, FieldVal::Off(key)),
                        (4, FieldVal::U32(s.subgraph_index)),
                    ])
                })
                .collect();
            let v = b.offset_vector(&sigs);
            root_fields.push((7, FieldVal::Off(v)));
        }
        let root = b.table(&root_fields);
        owned.finish(root, FILE_IDENTIFIER)
    }
}

fn write_tensor(b: &mut Builder, t: &TensorDef) -> WPos {
    let mut fields: Vec<(u16, FieldVal)> = Vec::new();
    let shape = b.i32_vector(&t.shape);
    fields.push((0, FieldVal::Off(shape)));
    if t.ttype != 0 {
        fields.push((1, FieldVal::I8(t.ttype)));
    }
    if t.buffer != 0 {
        fields.push((2, FieldVal::U32(t.buffer as u32)));
    }
    let name = b.string(&t.name);
    fields.push((3, FieldVal::Off(name)));
    if !t.quantization.is_empty() {
        let mut q: Vec<(u16, FieldVal)> = Vec::new();
        if !t.quantization.min.is_empty() {
            let v = b.f32_vector(&t.quantization.min);
            q.push((0, FieldVal::Off(v)));
        }
        if !t.quantization.max.is_empty() {
            let v = b.f32_vector(&t.quantization.max);
            q.push((1, FieldVal::Off(v)));
        }
        if !t.quantization.scale.is_empty() {
            let v = b.f32_vector(&t.quantization.scale);
            q.push((2, FieldVal::Off(v)));
        }
        if !t.quantization.zero_point.is_empty() {
            let v = b.i64_vector(&t.quantization.zero_point);
            q.push((3, FieldVal::Off(v)));
        }
        if t.quantization.quantized_dimension != 0 {
            q.push((6, FieldVal::I32(t.quantization.quantized_dimension)));
        }
        let qt = b.table(&q);
        fields.push((4, FieldVal::Off(qt)));
    }
    b.table(&fields)
}

fn write_operator(b: &mut Builder, o: &OperatorDef) -> WPos {
    let (ty, opts): (u8, Option<WPos>) = match &o.options {
        BuiltinOptions::None => (options_type::NONE, None),
        BuiltinOptions::Conv2D { padding, stride_w, stride_h, fused_activation } => {
            let t = b.table(&[
                (0, FieldVal::I8(*padding)),
                (1, FieldVal::I32(*stride_w)),
                (2, FieldVal::I32(*stride_h)),
                (3, FieldVal::I8(*fused_activation)),
            ]);
            (options_type::CONV_2D, Some(t))
        }
        BuiltinOptions::DepthwiseConv2D {
            padding,
            stride_w,
            stride_h,
            depth_multiplier,
            fused_activation,
        } => {
            let t = b.table(&[
                (0, FieldVal::I8(*padding)),
                (1, FieldVal::I32(*stride_w)),
                (2, FieldVal::I32(*stride_h)),
                (3, FieldVal::I32(*depth_multiplier)),
                (4, FieldVal::I8(*fused_activation)),
            ]);
            (options_type::DEPTHWISE_CONV_2D, Some(t))
        }
        BuiltinOptions::Pool2D {
            padding,
            stride_w,
            stride_h,
            filter_width,
            filter_height,
            fused_activation,
        } => {
            let t = b.table(&[
                (0, FieldVal::I8(*padding)),
                (1, FieldVal::I32(*stride_w)),
                (2, FieldVal::I32(*stride_h)),
                (3, FieldVal::I32(*filter_width)),
                (4, FieldVal::I32(*filter_height)),
                (5, FieldVal::I8(*fused_activation)),
            ]);
            (options_type::POOL_2D, Some(t))
        }
        BuiltinOptions::FullyConnected { fused_activation } => {
            let t = b.table(&[(0, FieldVal::I8(*fused_activation))]);
            (options_type::FULLY_CONNECTED, Some(t))
        }
        BuiltinOptions::Softmax { beta } => {
            let t = b.table(&[(0, FieldVal::F32(*beta))]);
            (options_type::SOFTMAX, Some(t))
        }
        BuiltinOptions::Concatenation { axis, fused_activation } => {
            let t = b.table(&[
                (0, FieldVal::I32(*axis)),
                (1, FieldVal::I8(*fused_activation)),
            ]);
            (options_type::CONCATENATION, Some(t))
        }
        BuiltinOptions::Add { fused_activation } => {
            let t = b.table(&[(0, FieldVal::I8(*fused_activation))]);
            (options_type::ADD, Some(t))
        }
        BuiltinOptions::Reshape { new_shape } => {
            let v = b.i32_vector(new_shape);
            let t = b.table(&[(0, FieldVal::Off(v))]);
            (options_type::RESHAPE, Some(t))
        }
        BuiltinOptions::Reducer { keep_dims } => {
            let t = b.table(&[(0, FieldVal::Bool(*keep_dims))]);
            (options_type::REDUCER, Some(t))
        }
    };
    let inputs = b.i32_vector(&o.inputs);
    let outputs = b.i32_vector(&o.outputs);
    let mut fields = vec![
        (0, FieldVal::U32(o.opcode_index as u32)),
        (1, FieldVal::Off(inputs)),
        (2, FieldVal::Off(outputs)),
    ];
    if let Some(t) = opts {
        fields.push((3, FieldVal::U8(ty)));
        fields.push((4, FieldVal::Off(t)));
    }
    b.table(&fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model() -> Model {
        Model {
            version: 3,
            description: "test model".into(),
            operator_codes: vec![
                OperatorCode { builtin_code: builtin_op::CONV_2D, version: 1 },
                OperatorCode { builtin_code: builtin_op::SOFTMAX, version: 1 },
            ],
            buffers: vec![vec![], vec![1, 2, 3, 4], vec![5, 6, 7, 8, 9, 10, 11, 12]],
            subgraph: SubGraphDef {
                name: "main".into(),
                tensors: vec![
                    TensorDef {
                        shape: vec![1, 4, 4, 1],
                        ttype: tensor_type::INT8,
                        buffer: 0,
                        name: "input".into(),
                        quantization: Quantization {
                            scale: vec![0.5],
                            zero_point: vec![-3],
                            ..Default::default()
                        },
                    },
                    TensorDef {
                        shape: vec![2, 1, 1, 1],
                        ttype: tensor_type::INT8,
                        buffer: 1,
                        name: "w".into(),
                        quantization: Quantization {
                            scale: vec![0.25],
                            zero_point: vec![0],
                            ..Default::default()
                        },
                    },
                    TensorDef {
                        shape: vec![1, 4, 4, 2],
                        ttype: tensor_type::INT8,
                        buffer: 0,
                        name: "out".into(),
                        quantization: Quantization {
                            scale: vec![0.125],
                            zero_point: vec![4],
                            ..Default::default()
                        },
                    },
                ],
                inputs: vec![0],
                outputs: vec![2],
                operators: vec![OperatorDef {
                    opcode_index: 0,
                    inputs: vec![0, 1, -1],
                    outputs: vec![2],
                    options: BuiltinOptions::Conv2D {
                        padding: padding::SAME,
                        stride_w: 1,
                        stride_h: 1,
                        fused_activation: activation::RELU6,
                    },
                }],
            },
            metadata_buffer: vec![2],
            metadata: vec![MetadataDef { name: "min_runtime_version".into(), buffer: 2 }],
            signature_defs: vec![SignatureDef {
                inputs: vec![TensorMap { name: "in".into(), tensor_index: 0 }],
                outputs: vec![TensorMap { name: "out".into(), tensor_index: 2 }],
                signature_key: "serving_default".into(),
                subgraph_index: 0,
            }],
        }
    }

    #[test]
    fn model_roundtrips_through_bytes() {
        let m = tiny_model();
        let bytes = m.serialize();
        let back = Model::parse(&bytes).expect("parse back");
        assert_eq!(back, m);
    }

    #[test]
    fn serialization_is_deterministic_and_stable() {
        let m = tiny_model();
        let a = m.serialize();
        let b = Model::parse(&a).unwrap().serialize();
        assert_eq!(a, b, "export → import → export must be byte-stable");
    }

    #[test]
    fn rejects_wrong_identifier_and_version() {
        let mut bytes = tiny_model().serialize();
        bytes[4..8].copy_from_slice(b"NOPE");
        assert!(Model::parse(&bytes).unwrap_err().contains("TFL3"));
        assert!(Model::parse(&[0u8; 6]).is_err());
    }

    #[test]
    fn truncation_never_panics() {
        let bytes = tiny_model().serialize();
        for cut in 0..bytes.len() {
            let _ = Model::parse(&bytes[..cut]);
        }
        // Random byte corruption must error or parse — never panic.
        let mut rng = crate::util::rng::Rng::new(7);
        for _ in 0..200 {
            let mut m = bytes.clone();
            let i = (rng.next_u64() as usize) % m.len();
            m[i] ^= (rng.next_u64() as u8) | 1;
            let _ = Model::parse(&m);
        }
    }
}

//! # mcu-reorder
//!
//! A production-style reproduction of *“Neural networks on microcontrollers:
//! saving memory at inference via operator reordering”* (Liberis & Lane,
//! 2019) as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate provides:
//!
//! - [`api`] — the library-level optimize facade: one [`api::OptimizeRequest`]
//!   → [`api::OptimizeReport`] pipeline shared by the CLI subcommands and the
//!   plan-serving coordinator, with versioned JSON serialization
//!   ([`api::SCHEMA_VERSION`]).
//! - [`graph`] — a computation-graph IR with byte-exact SRAM/Flash memory
//!   accounting and a JSON model container.
//! - [`sched`] — working-set simulation and the paper's Algorithm 1: a
//!   memoized dynamic program over tensor sets that finds the execution
//!   order minimizing peak SRAM usage, plus brute-force and greedy
//!   baselines.
//! - [`split`] — the partial-execution subsystem: spatial (row) operator
//!   splitting with byte-exact halo accounting, co-optimized with
//!   reordering. Breaks the single-operator working-set floor that
//!   reordering alone cannot cross (the Pex / patch-based-inference
//!   workload class) while keeping outputs bit-exact.
//! - [`alloc`] — SRAM arena allocators: the paper's dynamic allocator with
//!   post-operator compaction/defragmentation, the static no-reuse planner
//!   it replaces, and an offline lifetime-aware offset planner (§6).
//! - [`codegen`] — the AOT deployment backend: lowers a verified
//!   [`api::OptimizeReport`] into a freestanding C99 source + header with
//!   specialized per-operator loops, the static arena (sized to the
//!   certified peak) and weights baked in, plus a golden-equivalence
//!   harness that asserts bit-exactness against [`interp`].
//! - [`interp`] — a micro-interpreter that executes scheduled graphs inside
//!   a fixed-size arena through a handle table (no raw pointers across
//!   operators, so buffers may move during defragmentation).
//! - [`mcu`] — board profiles and first-order cycle/energy models used to
//!   reproduce the paper's execution-time and energy overhead numbers.
//! - [`models`] — the evaluated model zoo: the Figure-1 example graph,
//!   MobileNet-v1 0.25 person detection, a SwiftNet-style cell network, and
//!   synthetic DAG generators.
//! - [`runtime`] — PJRT loading/execution of the AOT-compiled JAX/Pallas
//!   artifacts (Python never runs at inference time).
//! - [`coordinator`] — the serving layer: a fleet-scale plan-serving
//!   service (LRU plan cache, admission control, TCP front-end) built on
//!   [`api`], plus the inference micro-batcher driving the runtime.
//! - [`trace`] — memory-timeline tracing and planner telemetry: a
//!   zero-cost-when-off event recorder threaded through `sched`, `alloc`,
//!   `interp` and `split`, with Chrome trace-event (Perfetto) export and
//!   an analytic-vs-measured peak audit.
//! - [`verify`] — proof-carrying plans: an independent static verifier
//!   (own interval/lifetime engine, zero shared accounting code with
//!   `sched`/`alloc`) that certifies schedule legality, arena soundness,
//!   split-rewrite geometry, quantization flow and export invariants
//!   behind every [`api::OptimizeReport`].
//! - [`util`] — in-tree substrates for JSON, RNG, property testing,
//!   benchmarking and error handling (their crates.io equivalents are not
//!   vendored here).

#![forbid(unsafe_code)]

pub mod alloc;
pub mod api;
pub mod codegen;
pub mod graph;
pub mod interp;
pub mod mcu;
pub mod models;
pub mod nas;
pub mod runtime;
pub mod coordinator;
pub mod sched;
pub mod split;
pub mod tflite;
pub mod trace;
pub mod util;
pub mod verify;

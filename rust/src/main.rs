//! `mcu-reorder` — command-line tool (the repo's analogue of the paper's
//! tflite-tools: analyze a model's memory profile, compute the optimal
//! operator order, embed it into the model file, and run/serve the
//! AOT-compiled artifact through PJRT).
//!
//! Exit codes are uniform across subcommands: 0 on success, 1 with a
//! one-line `error:` for runtime failures (unreadable files, planning or
//! verification failures), 2 for usage errors (unknown commands/flags,
//! missing required arguments, unparsable values).

#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use mcu_reorder::util::error::{anyhow, bail, Context, Result};

use mcu_reorder::api;
use mcu_reorder::coordinator::{self, Coordinator, ServeConfig};
use mcu_reorder::graph::serde::ModelFile;
use mcu_reorder::graph::{DType, Graph, SplitAxis};
use mcu_reorder::interp::{ExecConfig, Interpreter, TensorData, WeightStore};
use mcu_reorder::mcu::{CostModel, DeployReport, OverheadModel, NUCLEO_F767ZI};
use mcu_reorder::models;
use mcu_reorder::sched;
use mcu_reorder::trace;
use mcu_reorder::util::bench::Table;
use mcu_reorder::util::json::Json;

const USAGE: &str = "\
mcu-reorder — memory-optimal operator reordering for MCU inference
(reproduction of Liberis & Lane, 2019)

USAGE:
  mcu-reorder <command> [options]

COMMANDS:
  list                         List zoo models
  analyze   --model M          Working-set table + peaks + deploy verdict
            [--dtype i8|f32] [--order default|optimal|greedy|dfs] [--file F]
  import    MODEL.tflite       Import a TensorFlow Lite flatbuffer: map its
            [--json F]         subgraph onto the IR (de-fusing activations,
                               per-tensor quantization), report memory peaks
                               (file order vs reordered vs split/elided) and
                               the static/dynamic allocation plans;
                               optionally write the IR as model JSON for the
                               rest of the toolchain
  optimize  MODEL.tflite -o F  The paper's tool: embed the memory-optimal
            [--budget B]       execution order into a real TFLite model
            [--threads N]
                               (weight buffers byte-identical; reports
                               reorder-only vs split vs elided peaks — the
                               splits themselves are reported but cannot be
                               expressed in the flatbuffer)
  optimize  --model M --out F  Embed the optimal execution order into a
            [--dtype i8|f32]   model JSON file (like tflite-tools)
            Both optimize forms take --json [F]: structured output (peaks
            per mode, chosen order/plan, planner/cache telemetry) to
            stdout or F instead of text
  trace     <model|M.tflite>   Memory timeline of a schedule: ASCII chart,
            [--order O]        Chrome trace-event JSON for Perfetto
            [--format chrome|csv|json] [--out F]
            [--compare O2]     op-by-op diff of two schedules
            [--measured]       overlay the interpreter's measured arena
                               high-water as a second counter track
            [--audit]          assert measured == analytic peak across
                               {default,reordered,split,elided} × dtypes
                               (exits non-zero on any mismatch)
  split     --model M          Partial execution: beam-search operator
            [--dtype i8|f32] [--sram-budget B] [--max-factor K]
            [--rounds N] [--beam-width W] [--axes rows,cols,channels]
            [--no-elide] [--threads N] [--out F]
                               splitting over (segment, factor, axis) —
                               row/column slices are halo-exact, channel
                               slices partition weights with zero
                               recompute — co-optimized with Algorithm-1
                               reordering; joins are streamed away when
                               that lowers the peak (write-through slices,
                               no ConcatSlices copy; --no-elide reproduces
                               the materialized-join planner); reports the
                               peak-SRAM floor broken, the per-axis
                               overhead and the planner's work counters
                               (candidates scored/deduped, full-DP runs,
                               region-cache hits), optionally writing the
                               split model + schedule to F; --threads N
                               scores beam candidates on N threads with
                               bit-identical results
  verify    <model|M.tflite>   Proof-carrying plans: run the optimize
            [--model M|--file F] [--dtype i8|f32] [--budget B]
            [--board NAME] [--reorder-only] [--no-elide] [--threads N]
            [--reordered F.tflite] [--json [F]]
                               pipeline, then independently re-prove the
                               result with a static verifier that shares no
                               accounting code with the planners: schedule
                               legality + recomputed peaks, arena slot
                               soundness, split band/halo geometry, int8
                               domain flow, and export invariants.
                               --reordered F additionally proves an exported
                               flatbuffer is a pure operator permutation of
                               the source. Prints the certificate (or emits
                               it with --json); exits 1 when any property
                               family fails
  codegen   <model|M.tflite> -o F.c
            [--model M|--file F] [--dtype i8|f32] [--budget B]
            [--board NAME] [--reorder-only] [--no-elide] [--threads N]
            [--harness F]      AOT deployment backend: run the optimize
                               pipeline, certify it, and lower the plan to
                               a freestanding dependency-free C99 artifact
                               (F.c + F.h): one specialized function per
                               scheduled op (split bands with halo offsets
                               as compile-time constants), weights as
                               static const .rodata tables, one static
                               .bss arena sized exactly to the certified
                               peak with #define'd slot offsets, and a
                               <sym>_invoke(input, output) entry point.
                               --harness F additionally writes a
                               standalone main() that drives the artifact
                               with the audit input and byte-compares the
                               output against the Rust interpreter
  export    --model M --json F --weights F [--dtype f32]
                               Export graph JSON + seeded weights for the
                               AOT pipeline (python/compile/aot.py)
  run       --model M [--artifacts DIR] [--check] [--n N]
                               Execute the AOT artifact via PJRT
  serve     --model M [--engine pjrt|interp] [--artifacts DIR]
            [--port P] [--workers N]
                               Start the serving coordinator (TCP front-end)
  plan-serve [--port P] [--workers N] [--cache-cap N] [--queue-cap N]
            [--threads N]      Start the plan-serving coordinator: fleet
                               devices request reorder+split+elide plans per
                               (model, board, budget) over TCP; plans are
                               LRU-cached by model content hash and served
                               bit-identically to a fresh `optimize` run;
                               ARTIFACT downloads the reordered .tflite or
                               generated C for an already cached plan
                               (protocol: PLAN/GET/ARTIFACT/UPLOAD/STATS/
                               BOARDS/MODELS/QUIT; see README "Plan
                               serving")
  table1                       Reproduce the paper's Table 1
  sweep                        Fit matrix: zoo models × boards × orders
  nas       [--samples N] [--seed S]
                               §6: memory-aware architecture search scored
                               by Algorithm 1 (reports Pareto front and how
                               many candidates only fit when reordered)
  dot       --model M [--dtype i8]
                               GraphViz dump of a zoo model

Common analyze flags: --chart (ASCII memory plot), --csv FILE (trace dump),
--inplace (enable §6 in-place Add accumulation in the accounting).

Exit codes: 0 success · 1 runtime/verification failure · 2 usage error.
";

fn parse_args(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            let boolean = matches!(
                name,
                "check"
                    | "table"
                    | "chart"
                    | "inplace"
                    | "no-elide"
                    | "audit"
                    | "measured"
                    | "reorder-only"
            );
            if boolean {
                flags.insert(name.to_string(), "true".to_string());
            } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 1;
            } else if matches!(
                name,
                "out" | "json" | "file" | "csv" | "weights" | "reordered" | "harness"
            ) {
                // A path-valued flag with no value (trailing, or followed
                // by another flag) must not silently write to a file named
                // "true"; record an empty path so path consumers reject it
                // loudly. `optimize --json` deliberately reads the empty
                // value as "JSON to stdout".
                flags.insert(name.to_string(), String::new());
            } else {
                flags.insert(name.to_string(), "true".to_string());
            }
        } else if a == "-o" {
            // Short alias for --out (the tflite-tools convention). A
            // trailing `-o` records an empty path so the consumer can
            // reject it loudly instead of silently writing nothing.
            if i + 1 < args.len() {
                flags.insert("out".to_string(), args[i + 1].clone());
                i += 1;
            } else {
                flags.insert("out".to_string(), String::new());
            }
        } else {
            pos.push(a.clone());
        }
        i += 1;
    }
    (pos, flags)
}

/// Marker prefix `main()` classifies into exit code 2. Every subcommand
/// reports bad invocations through [`usage`] and runtime failures through
/// plain `anyhow!`, so the exit-code contract is uniform.
const USAGE_PREFIX: &str = "usage error: ";

/// A command-line usage error (exit code 2).
fn usage(msg: impl std::fmt::Display) -> mcu_reorder::util::error::Error {
    anyhow!("{USAGE_PREFIX}{msg}")
}

/// A path-valued flag; an explicitly empty value (a trailing flag with
/// nothing after it) is a usage error, not a silent no-op.
fn path_flag<'a>(
    flags: &'a HashMap<String, String>,
    name: &str,
    label: &str,
) -> Result<Option<&'a str>> {
    match flags.get(name).map(|s| s.as_str()) {
        Some("") => Err(usage(format!("{label} needs a path"))),
        other => Ok(other),
    }
}

fn out_flag(flags: &HashMap<String, String>) -> Result<Option<&str>> {
    path_flag(flags, "out", "-o/--out")
}

/// A numeric flag; an unparsable value is a usage error, not a panic or a
/// silently ignored setting.
fn num_flag<T: std::str::FromStr>(
    flags: &HashMap<String, String>,
    name: &str,
) -> Result<Option<T>> {
    match flags.get(name) {
        None => Ok(None),
        Some(s) => s
            .parse()
            .map(Some)
            .map_err(|_| usage(format!("--{name} needs a number, got {s:?}"))),
    }
}

fn dtype_flag(flags: &HashMap<String, String>, default: DType) -> Result<DType> {
    match flags.get("dtype").map(|s| s.as_str()) {
        None => Ok(default),
        Some(s) => DType::from_name(s).ok_or_else(|| usage(format!("unknown dtype {s:?}"))),
    }
}

/// Model source from `--model <zoo-name>` or `--file <model.json|.tflite>`.
fn source_from_flags(
    flags: &HashMap<String, String>,
    default_dtype: DType,
) -> Result<api::ModelSource> {
    if let Some(path) = path_flag(flags, "file", "--file")? {
        // `.tflite` loads through the flatbuffer frontend (the operator
        // vector is the embedded execution order, so the graph's default
        // order already reflects the file); anything else as model JSON.
        return Ok(api::ModelSource::from_path(path));
    }
    let name =
        flags.get("model").ok_or_else(|| usage("--model or --file required"))?;
    let dtype = dtype_flag(flags, default_dtype)?;
    Ok(api::ModelSource::Zoo { name: name.clone(), dtype })
}

/// Resolve a model graph from `--model <zoo-name>` or `--file <model.json>`.
fn load_graph(
    flags: &HashMap<String, String>,
    default_dtype: DType,
) -> Result<(Graph, Option<Vec<usize>>)> {
    let resolved = source_from_flags(flags, default_dtype)?.resolve()?;
    Ok((resolved.graph, resolved.embedded_order))
}

fn order_for(g: &Graph, spec: &str) -> Result<sched::Schedule> {
    Ok(match spec {
        "default" => {
            let order = g.default_order();
            let peak = sched::peak_of(g, &order);
            sched::Schedule { order, peak_bytes: peak }
        }
        "optimal" => sched::optimal(g).map_err(|e| anyhow!("{e}"))?.0,
        "greedy" => sched::greedy_min_increase(g),
        "dfs" => sched::greedy_depth_first(g),
        other => return Err(usage(format!("unknown order {other:?} (default|optimal|greedy|dfs)"))),
    })
}

fn cmd_list() {
    println!(
        "{:<12} {:>6} {:>8} {:>12} {:>12}",
        "model", "ops", "tensors", "params", "activations"
    );
    for name in models::MODEL_NAMES {
        let g = models::by_name(name, DType::I8).unwrap();
        println!(
            "{:<12} {:>6} {:>8} {:>10}B {:>10}B",
            name,
            g.n_ops(),
            g.n_tensors(),
            g.model_size(),
            g.activation_total()
        );
    }
}

fn cmd_analyze(flags: &HashMap<String, String>) -> Result<()> {
    let (g, embedded) = load_graph(flags, DType::I8)?;
    let opts = if flags.contains_key("inplace") {
        sched::Opts::INPLACE
    } else {
        sched::Opts::default()
    };
    let spec = flags.get("order").map(|s| s.as_str()).unwrap_or("default");
    let sched = if spec == "default" && embedded.is_some() {
        let order = embedded.unwrap();
        let peak = sched::peak_of_opts(&g, &order, opts);
        sched::Schedule { order, peak_bytes: peak }
    } else if spec == "optimal" && opts.inplace_add {
        sched::optimal_opts(&g, opts).map_err(|e| anyhow!("{e}"))?.0
    } else {
        order_for(&g, spec)?
    };
    let trace = sched::simulate_opts(&g, &sched.order, opts);
    println!("model: {}  ({} ops, {} tensors)", g.name, g.n_ops(), g.n_tensors());
    println!("order: {spec}\n");
    print!("{}", trace.render_table(&g));
    if flags.contains_key("chart") {
        println!();
        print!("{}", trace.render_chart(&g, 48));
    }
    if let Some(path) = path_flag(flags, "csv", "--csv")? {
        std::fs::write(path, trace.to_csv(&g)).with_context(|| format!("writing {path}"))?;
        println!("\nwrote memory trace to {path}");
    }
    println!();
    println!(
        "peak working set : {} B ({:.1} KB)",
        trace.peak_bytes,
        trace.peak_bytes as f64 / 1000.0
    );
    println!("model size       : {} B ({:.1} KB)", g.model_size(), g.model_size() as f64 / 1000.0);
    println!(
        "activation total : {} B ({:.1} KB)",
        g.activation_total(),
        g.activation_total() as f64 / 1000.0
    );
    let report = DeployReport::new(&g, trace.peak_bytes, &NUCLEO_F767ZI, &OverheadModel::default());
    println!(
        "deploy ({:>14}): peak + overhead = {} B of {} B SRAM → {}",
        report.board,
        report.total_sram(),
        NUCLEO_F767ZI.sram_bytes,
        if report.fits_sram { "FITS" } else { "DOES NOT FIT" }
    );
    Ok(())
}

/// Resolve the model path of a tflite-frontend command from the first
/// positional argument or `--file`.
fn tflite_path<'a>(
    pos: &'a [String],
    flags: &'a HashMap<String, String>,
) -> Result<Option<&'a str>> {
    if let Some(p) = pos.first() {
        return Ok(Some(p.as_str()));
    }
    path_flag(flags, "file", "--file")
}

fn is_tflite(path: &str) -> bool {
    path.ends_with(".tflite")
}

fn cmd_import(pos: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let path = tflite_path(pos, flags)?
        .ok_or_else(|| usage("mcu-reorder import MODEL.tflite [--json F]"))?;
    let report = api::OptimizeRequest::reorder_only(api::ModelSource::TflitePath(
        path.to_string(),
    ))
    .run()?;
    print!("{}", api::render_import(&report));
    if let Some(json_path) = path_flag(flags, "json", "--json")? {
        let mf = ModelFile::new(report.graph.clone());
        std::fs::write(json_path, mf.to_json()).with_context(|| format!("writing {json_path}"))?;
        println!("wrote IR model JSON to {json_path}");
    }
    Ok(())
}

/// `optimize --json` mode: `None` = human output; `Some(None)` = JSON to
/// stdout (bare `--json`); `Some(Some(path))` = JSON to a file.
fn json_mode(flags: &HashMap<String, String>) -> Option<Option<&str>> {
    flags.get("json").map(|v| match v.as_str() {
        "" | "true" => None,
        path => Some(path),
    })
}

/// Emit an `optimize --json` document to stdout or a file.
fn emit_json(doc: &Json, dest: Option<&str>) -> Result<()> {
    match dest {
        Some(path) => {
            std::fs::write(path, doc.to_pretty())
                .with_context(|| format!("writing {path}"))?;
        }
        None => println!("{}", doc.to_pretty()),
    }
    Ok(())
}

fn threads_flag(flags: &HashMap<String, String>) -> Result<usize> {
    Ok(num_flag(flags, "threads")?.unwrap_or(1))
}

/// `optimize` on a real TFLite flatbuffer: report reorder-only vs split vs
/// elided peaks and write the model back with the optimal operator order
/// embedded (buffers byte-identical).
fn cmd_optimize_tflite(path: &str, flags: &HashMap<String, String>) -> Result<()> {
    let budget: Option<usize> = match num_flag(flags, "budget")? {
        Some(b) => Some(b),
        None => num_flag(flags, "sram-budget")?,
    };
    let split_opts = mcu_reorder::split::SplitOptions {
        sram_budget: budget,
        ..Default::default()
    }
    .with_threads(threads_flag(flags)?);
    let report = api::OptimizeRequest {
        source: api::ModelSource::TflitePath(path.to_string()),
        budget,
        board: &NUCLEO_F767ZI,
        split: Some(split_opts),
        compare_materialized: true,
        trace: false,
    }
    .run()?;

    let json = json_mode(flags);
    if json.is_none() {
        print!("{}", api::render_optimize_tflite(&report));
    }

    let out = out_flag(flags)?;
    if let Some(out) = out {
        report.write_reordered_tflite(out)?;
        if json.is_none() {
            println!(
                "\nwrote {out}: operator order embedded, peak {} B → {} B (buffers byte-identical)",
                report.default_peak, report.reordered.peak_bytes
            );
        }
    } else if json.is_none() {
        println!("\n(no -o/--out given: nothing written)");
    }

    if let Some(dest) = json {
        emit_json(&api::optimize_tflite_json(&report, out), dest)?;
    }
    Ok(())
}

fn cmd_optimize(pos: &[String], flags: &HashMap<String, String>) -> Result<()> {
    if let Some(path) = tflite_path(pos, flags)?.filter(|p| is_tflite(p)) {
        return cmd_optimize_tflite(path, flags);
    }
    let source = source_from_flags(flags, DType::I8)?;
    source.resolve()?;
    let json = json_mode(flags);
    let out = out_flag(flags)?.ok_or_else(|| usage("optimize --model M needs --out F"))?;
    let report = api::OptimizeRequest::reorder_only(source).run()?;
    let mf = ModelFile {
        graph: report.graph.clone(),
        execution_order: Some(report.reordered.order.clone()),
    };
    std::fs::write(out, mf.to_json()).with_context(|| format!("writing {out}"))?;
    match json {
        None => print!("{}", api::render_optimize_model(&report, out)),
        Some(dest) => emit_json(&api::optimize_model_json(&report, out), dest)?,
    }
    Ok(())
}

/// Weights for `trace --measured/--audit`: zoo models are prepared in the
/// requested dtype (synthetic u8 graphs as-is; CNNs seeded f32 or
/// calibrated+quantized i8); `.tflite` files carry their own weights.
fn trace_prepared(flags: &HashMap<String, String>) -> Result<trace::audit::Prepared> {
    if let Some(path) = path_flag(flags, "file", "--file")? {
        if is_tflite(path) {
            let imp = mcu_reorder::tflite::load(path)?;
            let label = imp.graph.name.clone();
            return Ok(trace::audit::prepare_imported(imp, &label));
        }
        return Err(usage("--measured/--audit need weights: use a zoo model or a .tflite file"));
    }
    let name = flags.get("model").ok_or_else(|| usage("--model or --file required"))?;
    let dtype = dtype_flag(flags, DType::I8)?;
    let mut preps = trace_audit_err(trace::audit::prepare_zoo(name))?;
    let idx = preps.iter().position(|p| p.dtype == dtype.name()).unwrap_or(0);
    Ok(preps.swap_remove(idx))
}

fn trace_audit_err<T>(r: std::result::Result<T, String>) -> Result<T> {
    r.map_err(|e| anyhow!("{e}"))
}

/// `mcu-reorder trace`: render a schedule as a memory timeline — ASCII
/// chart by default, Chrome trace-event JSON (Perfetto), the per-op
/// live-set CSV the Python mirror diffs against, or the raw event stream.
/// `--compare` diffs two schedules op-by-op; `--measured` overlays the
/// interpreter's arena high-water; `--audit` gates on measured == analytic.
fn cmd_trace(pos: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let mut flags = flags.clone();
    if let Some(p) = pos.first() {
        if p.contains('.') && std::path::Path::new(p).extension().is_some() {
            flags.insert("file".to_string(), p.clone());
        } else {
            flags.insert("model".to_string(), p.clone());
        }
    }
    let (g, embedded) = load_graph(&flags, DType::I8)?;
    let spec = flags.get("order").map(|s| s.as_str()).unwrap_or("default");
    let schedule = if spec == "default" && embedded.is_some() {
        let order = embedded.unwrap();
        let peak = sched::peak_of(&g, &order);
        sched::Schedule { order, peak_bytes: peak }
    } else {
        order_for(&g, spec)?
    };
    let mt = sched::simulate(&g, &schedule.order);

    if let Some(cmp) = flags.get("compare") {
        let other = order_for(&g, cmp)?;
        let b = sched::simulate(&g, &other.order);
        println!("model: {}  A = {spec}, B = {cmp}\n", g.name);
        print!("{}", trace::schedule_diff(&g, &mt, &b));
        return Ok(());
    }

    let measured: Option<Vec<usize>> = if flags.contains_key("measured") {
        let p = trace_prepared(&flags)?;
        Some(trace_audit_err(trace::audit::measured_series(&p.graph, &p.ws, &schedule.order))?)
    } else {
        None
    };

    let emit = |content: String| -> Result<()> {
        match path_flag(&flags, "out", "--out")? {
            Some(path) => {
                std::fs::write(path, content).with_context(|| format!("writing {path}"))?;
                println!("wrote trace to {path}");
            }
            None => print!("{content}"),
        }
        Ok(())
    };
    match flags.get("format").map(|s| s.as_str()) {
        None => {
            println!("model: {}  order: {spec}  ({} ops)\n", g.name, g.n_ops());
            print!("{}", mt.render_chart(&g, 48));
            println!(
                "\npeak working set : {} B at step {} ({})",
                mt.peak_bytes,
                mt.peak_step,
                g.ops[mt.steps[mt.peak_step].op].name
            );
            if let Some(m) = &measured {
                let mm = m.last().copied().unwrap_or(0);
                println!(
                    "measured arena   : {} B high-water ({})",
                    mm,
                    if mm == mt.peak_bytes { "== analytic" } else { "≠ analytic!" }
                );
            }
        }
        Some("chrome") => {
            emit(trace::chrome::chrome_trace(&g, &mt, measured.as_deref()).to_pretty())?
        }
        Some("csv") => emit(trace::live_csv(&g, &mt))?,
        Some("json") => {
            let mut sink = trace::JsonSink::new();
            sched::simulate_traced(&g, &schedule.order, sched::Opts::default(), &mut sink);
            mcu_reorder::alloc::StaticPlan::best_fit_traced(&g, &schedule.order, &mut sink);
            let doc = Json::obj(vec![
                ("model", Json::Str(g.name.clone())),
                ("order", api::order_json(&schedule.order)),
                ("peak_bytes", Json::Num(mt.peak_bytes as f64)),
                ("peak_step", Json::Num(mt.peak_step as f64)),
                ("events", sink.into_json()),
            ]);
            emit(doc.to_pretty())?
        }
        Some(other) => return Err(usage(format!("unknown format {other:?} (chrome|csv|json)"))),
    }

    if flags.contains_key("audit") {
        let entries = if flags.contains_key("model") && !flags.contains_key("file") {
            trace_audit_err(trace::audit::audit_zoo_model(
                flags.get("model").unwrap(),
            ))?
        } else {
            trace::audit::audit_prepared(&trace_prepared(&flags)?)
        };
        println!();
        print!("{}", trace::audit::render(&entries));
        if !trace::audit::all_ok(&entries) {
            bail!("audit FAILED: measured arena high-water != analytic peak");
        }
        println!("audit ok: measured == analytic for all {} entries", entries.len());
    }
    Ok(())
}

fn cmd_split(flags: &HashMap<String, String>) -> Result<()> {
    let budget: Option<usize> = num_flag(flags, "sram-budget")?;
    let max_factor: usize = num_flag(flags, "max-factor")?.unwrap_or(4);
    let max_rounds: usize = num_flag(flags, "rounds")?.unwrap_or(3);
    let beam_width: usize = num_flag(flags, "beam-width")?.unwrap_or(2);
    // Unknown, duplicate and empty tokens are hard errors — a silently
    // dropped axis would quietly shrink the search space.
    let axes: Vec<SplitAxis> = match flags.get("axes") {
        None => SplitAxis::ALL.to_vec(),
        Some(spec) => mcu_reorder::split::parse_axes(spec).map_err(|e| usage(e))?,
    };
    let opts = mcu_reorder::split::SplitOptions {
        max_factor,
        sram_budget: budget,
        max_rounds,
        beam_width,
        axes,
        elide: !flags.contains_key("no-elide"),
        ..Default::default()
    }
    .with_threads(threads_flag(flags)?);

    let req = api::OptimizeRequest {
        source: source_from_flags(flags, DType::I8)?,
        budget,
        board: &NUCLEO_F767ZI,
        split: Some(opts),
        compare_materialized: false,
        trace: false,
    };
    let t0 = std::time::Instant::now();
    let report = req.run()?;
    let elapsed = t0.elapsed().as_secs_f64();

    print!("{}", api::render_split(&report, elapsed));
    if let Some(out) = out_flag(flags)? {
        let outcome = &report.split.as_ref().expect("split requested").outcome;
        let mf = ModelFile {
            graph: outcome.graph.clone(),
            execution_order: Some(outcome.schedule.order.clone()),
        };
        std::fs::write(out, mf.to_json()).with_context(|| format!("writing {out}"))?;
        println!("wrote split model + schedule to {out}");
    }
    Ok(())
}

fn cmd_export(flags: &HashMap<String, String>) -> Result<()> {
    let (g, _) = load_graph(flags, DType::F32)?;
    let json_path =
        path_flag(flags, "json", "--json")?.ok_or_else(|| usage("export needs --json F"))?;
    let weights_path = path_flag(flags, "weights", "--weights")?
        .ok_or_else(|| usage("export needs --weights F"))?;
    let seed: u64 = num_flag(flags, "seed")?.unwrap_or(42);

    let mf = ModelFile::new(g.clone());
    std::fs::write(json_path, mf.to_json()).with_context(|| format!("writing {json_path}"))?;

    // Weights: f32 little-endian, weight tensors in tensor-id order.
    let ws = WeightStore::seeded_f32(&g, seed);
    let mut blob: Vec<u8> = Vec::new();
    for t in &g.tensors {
        if !t.is_weight {
            continue;
        }
        let data = ws.data.get(&t.id).ok_or_else(|| anyhow!("missing weight {}", t.name))?;
        blob.extend_from_slice(&data.to_bytes());
    }
    std::fs::write(weights_path, &blob).with_context(|| format!("writing {weights_path}"))?;
    println!(
        "exported {} ({} weight bytes, seed {seed}) → {json_path}, {weights_path}",
        g.name,
        blob.len()
    );
    Ok(())
}

/// Deterministic synthetic input for a graph's single input tensor.
fn synthetic_input(g: &Graph) -> Vec<f32> {
    let n = g.tensors[g.inputs[0]].elems();
    (0..n).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect()
}

fn cmd_run(flags: &HashMap<String, String>) -> Result<()> {
    let name = flags.get("model").ok_or_else(|| usage("run needs --model M"))?.clone();
    let dir = PathBuf::from(flags.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into()));
    let n: usize = num_flag(flags, "n")?.unwrap_or(1);
    let g = models::by_name(&name, DType::F32)
        .ok_or_else(|| anyhow!("unknown model {name:?}"))?;

    let mut rt = mcu_reorder::runtime::Runtime::cpu()?;
    rt.load_artifact(&name, &dir)?;
    let manifest = rt.get(&name).unwrap().manifest.clone();
    manifest.check_against(&g)?;
    println!("platform: {}  model: {}  kernels: {}", rt.platform(), name, manifest.kernels);

    let input = synthetic_input(&g);
    let t = std::time::Instant::now();
    let mut out = Vec::new();
    for _ in 0..n {
        out = rt.execute_f32(&name, &[input.clone()])?;
    }
    let per = t.elapsed().as_secs_f64() / n as f64;
    println!("output[0] = {:?}", &out[0][..out[0].len().min(8)]);
    println!("{n} runs, {:.3} ms per inference (PJRT CPU)", per * 1e3);

    if flags.contains_key("check") {
        let ws = WeightStore::seeded_f32(&g, 42);
        let interp = Interpreter::new(&g, ws, ExecConfig::with_capacity(16 * 1024 * 1024));
        let r = interp.run(&[TensorData::F32(input)])?;
        let reference = r.outputs[0].as_f32().unwrap();
        let mut max_err = 0f32;
        for (a, b) in out[0].iter().zip(reference) {
            max_err = max_err.max((a - b).abs());
        }
        println!("check vs micro-interpreter: max |Δ| = {max_err:.2e}");
        if max_err > 1e-3 {
            bail!("PJRT output diverges from the reference interpreter");
        }
    }
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let name = flags.get("model").ok_or_else(|| usage("serve needs --model M"))?.clone();
    let engine = flags.get("engine").cloned().unwrap_or_else(|| "pjrt".into());
    let workers: usize = num_flag(flags, "workers")?.unwrap_or(2);
    let port: u16 = num_flag(flags, "port")?.unwrap_or(7878);

    let factory = match engine.as_str() {
        "pjrt" => {
            let dir = PathBuf::from(
                flags.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into()),
            );
            coordinator::pjrt_engine_factory(name.clone(), dir)
        }
        "interp" => {
            let g = models::by_name(&name, DType::F32)
                .ok_or_else(|| anyhow!("unknown model {name:?}"))?;
            coordinator::interp_engine_factory(g, 42, 16 * 1024 * 1024)
        }
        other => return Err(usage(format!("unknown engine {other:?} (pjrt|interp)"))),
    };
    let coord = Arc::new(Coordinator::start(
        ServeConfig { workers, ..Default::default() },
        factory,
    )?);
    println!("serving {name} ({engine}, {workers} workers) on 0.0.0.0:{port}");
    println!("protocol: one CSV line of {} floats per request", {
        let g = models::by_name(&name, DType::F32).unwrap();
        g.tensors[g.inputs[0]].elems()
    });
    coordinator::serve_tcp(coord, &format!("0.0.0.0:{port}"), None, |a| {
        println!("listening on {a}");
    })
}

fn cmd_plan_serve(flags: &HashMap<String, String>) -> Result<()> {
    let port: u16 = num_flag(flags, "port")?.unwrap_or(7879);
    let workers: usize = num_flag(flags, "workers")?.unwrap_or(2);
    let cache_cap: usize = num_flag(flags, "cache-cap")?.unwrap_or(128);
    let queue_cap: usize = num_flag(flags, "queue-cap")?.unwrap_or(64);
    let threads = threads_flag(flags)?;

    let cfg = coordinator::PlanServeConfig {
        workers,
        cache_cap,
        queue_cap,
        split: mcu_reorder::split::SplitOptions::default().with_threads(threads),
        ..Default::default()
    };
    let svc = coordinator::PlanService::start(cfg);
    println!(
        "plan-serving: {workers} planner worker(s), cache {cache_cap} plan(s), queue {queue_cap}"
    );
    println!(
        "protocol: PLAN <model> <board> [budget] | GET | ARTIFACT <TFLITE|C> | UPLOAD | STATS | BOARDS | MODELS"
    );
    coordinator::serve_plans_tcp(svc, &format!("0.0.0.0:{port}"), None, |a| {
        println!("listening on {a}");
    })
}

fn cmd_table1() -> Result<()> {
    // --- SwiftNet: default vs optimal operator order (memory only; the
    //     paper could not even run the default order on-device). ---
    let swift = models::swiftnet_cell(DType::I8);
    let swift_default = sched::peak_of(&swift, &swift.default_order());
    let (swift_opt, _) = sched::optimal(&swift).map_err(|e| anyhow!("{e}"))?;

    // --- MobileNet: static vs dynamic allocation. ---
    let mnet = models::mobilenet_v1_025(DType::I8);
    let static_bytes = mcu_reorder::alloc::StaticPlan::no_reuse(&mnet).arena_bytes;

    // Execute the i8 model in the arena to count real defrag traffic.
    let g_f32 = models::mobilenet_v1_025(DType::F32);
    let ws_f32 = WeightStore::seeded_f32(&g_f32, 42);
    let input = TensorData::F32(synthetic_input(&g_f32));
    let ranges = mcu_reorder::interp::calibrate(&g_f32, &ws_f32, &[input], 16 * 1024 * 1024)?;
    let ws_i8 = WeightStore::quantize_from(&mnet, &ws_f32, &ranges);
    let in_q = ws_i8.qparams[&mnet.inputs[0]];
    let qin = TensorData::I8(in_q.quantize(&synthetic_input(&g_f32)));
    let interp = Interpreter::new(&mnet, ws_i8, ExecConfig::with_capacity(256 * 1024));
    let run = interp.run(&[qin])?;

    let static_stats = mcu_reorder::alloc::AllocStats {
        high_water: static_bytes,
        ..Default::default()
    };
    let dynamic_stats = run.alloc.clone();

    let model = CostModel::calibrated(&mnet, &static_stats, &NUCLEO_F767ZI, 1.316, 728.0);
    let est_static = model.estimate(&mnet, &static_stats, &NUCLEO_F767ZI);
    let est_dyn = model.estimate(&mnet, &dynamic_stats, &NUCLEO_F767ZI);
    let est_swift = model.estimate(&swift, &dynamic_stats, &NUCLEO_F767ZI);

    let kb = |b: usize| format!("{:.0}KB", b as f64 / 1000.0);
    let mut t = Table::new(&[
        "",
        "SwiftNet default",
        "SwiftNet optimal",
        "MobileNet static",
        "MobileNet dynamic",
    ]);
    t.row(&[
        "Peak memory (excl. overheads)".into(),
        kb(swift_default),
        kb(swift_opt.peak_bytes),
        kb(static_bytes),
        kb(dynamic_stats.high_water),
    ]);
    t.row(&[
        "Execution time".into(),
        "N/A (doesn't fit)".into(),
        format!("{:.0} ms", est_swift.millis()),
        format!("{:.0} ms", est_static.millis()),
        format!(
            "{:.0} ms (+{:.2}%)",
            est_dyn.millis(),
            100.0 * (est_dyn.seconds / est_static.seconds - 1.0)
        ),
    ]);
    t.row(&[
        "Energy use".into(),
        "N/A (doesn't fit)".into(),
        format!("{:.0} mJ", est_swift.energy_mj),
        format!("{:.0} mJ", est_static.energy_mj),
        format!(
            "{:.0} mJ (+{:.2}%)",
            est_dyn.energy_mj,
            100.0 * (est_dyn.energy_mj / est_static.energy_mj - 1.0)
        ),
    ]);
    t.print();
    println!("\npaper (Table 1): 351KB/301KB; 241KB/55KB; 1316ms/1325ms (+0.68%); 728mJ/735mJ (+0.97%)");
    Ok(())
}

fn cmd_sweep() -> Result<()> {
    use mcu_reorder::mcu::boards::ALL_BOARDS;
    let overhead = OverheadModel::default();
    println!("fit matrix (peak + framework overhead vs board SRAM; d = default order, o = optimal)\n");
    let mut t = Table::new(&["model", "peak d/o", "overhead",
        "F767ZI 512K", "F446RE 128K", "H743ZI 1M", "Edge 384K"]);
    for name in models::MODEL_NAMES {
        if name == "figure1" {
            continue;
        }
        let g = models::by_name(name, DType::I8).unwrap();
        let d = sched::peak_of(&g, &g.default_order());
        let (o, _) = sched::optimal(&g).map_err(|e| anyhow!("{e}"))?;
        let ov = overhead.bytes(&g);
        let verdict = |board: &mcu_reorder::mcu::Board| {
            let fd = d + ov <= board.sram_bytes;
            let fo = o.peak_bytes + ov <= board.sram_bytes;
            match (fd, fo) {
                (true, true) => "fits".to_string(),
                (false, true) => "REORDER".to_string(),
                (false, false) => "no".to_string(),
                (true, false) => unreachable!("optimal can't be worse"),
            }
        };
        t.row(&[
            name.into(),
            format!("{:.0}/{:.0}KB", d as f64 / 1000.0, o.peak_bytes as f64 / 1000.0),
            format!("{:.0}KB", ov as f64 / 1000.0),
            verdict(ALL_BOARDS[0]),
            verdict(ALL_BOARDS[1]),
            verdict(ALL_BOARDS[2]),
            verdict(ALL_BOARDS[3]),
        ]);
    }
    t.print();
    println!("\nREORDER = fits only with the optimal operator order (the paper's scenario)");
    Ok(())
}

fn cmd_nas(flags: &HashMap<String, String>) -> Result<()> {
    let samples: usize = num_flag(flags, "samples")?.unwrap_or(60);
    let seed: u64 = num_flag(flags, "seed")?.unwrap_or(41);
    let mut rng = mcu_reorder::util::rng::Rng::new(seed);
    let t0 = std::time::Instant::now();
    let result = mcu_reorder::nas::random_search(
        &mut rng,
        samples,
        &NUCLEO_F767ZI,
        &OverheadModel::default(),
    );
    println!(
        "evaluated {} candidates in {:.2}s ({:.1} ms per Algorithm-1 solve incl. graph build)\n",
        result.evaluated.len(),
        t0.elapsed().as_secs_f64(),
        t0.elapsed().as_secs_f64() * 1e3 / result.evaluated.len() as f64
    );
    println!(
        "feasible only via reordering: {} candidates (would be discarded by a default-order check)\n",
        result.rescued_by_reordering
    );
    let mut t = Table::new(&["peak (opt)", "peak (default)", "MACs", "params", "stages"]);
    for c in &result.pareto {
        t.row(&[
            format!("{:.0}KB", c.optimal_peak as f64 / 1000.0),
            format!("{:.0}KB", c.default_peak as f64 / 1000.0),
            format!("{:.1}M", c.macs as f64 / 1e6),
            format!("{:.0}KB", c.params as f64 / 1000.0),
            format!("{:?}", c.config.stages.iter().map(|s| s.0).collect::<Vec<_>>()),
        ]);
    }
    println!("Pareto front (min peak SRAM, max capacity):");
    t.print();
    Ok(())
}

fn cmd_dot(flags: &HashMap<String, String>) -> Result<()> {
    let (g, _) = load_graph(flags, DType::I8)?;
    print!("{}", g.to_dot());
    Ok(())
}

/// `mcu-reorder verify`: run the optimize pipeline, then independently
/// re-prove every artifact with the static verifier and print (or emit as
/// JSON) the resulting [`mcu_reorder::verify::PlanCertificate`]. A failed
/// property family is a runtime failure (exit 1) carrying the verifier's
/// `family/code` diagnostic.
fn cmd_verify(pos: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let mut flags = flags.clone();
    if let Some(p) = pos.first() {
        // Positional argument: a path if it looks like a file, else a zoo
        // model name (same dispatch as `trace`).
        if p.contains('.') && std::path::Path::new(p).extension().is_some() {
            flags.insert("file".to_string(), p.clone());
        } else {
            flags.insert("model".to_string(), p.clone());
        }
    }
    let source = source_from_flags(&flags, DType::I8)?;
    let budget: Option<usize> = num_flag(&flags, "budget")?;
    let board = match flags.get("board") {
        None => &NUCLEO_F767ZI,
        Some(name) => mcu_reorder::mcu::boards::by_name(name).ok_or_else(|| {
            usage(format!("unknown board {name:?} (see `mcu-reorder sweep` for the list)"))
        })?,
    };
    let split = if flags.contains_key("reorder-only") {
        None
    } else {
        Some(
            mcu_reorder::split::SplitOptions {
                sram_budget: budget,
                elide: !flags.contains_key("no-elide"),
                ..Default::default()
            }
            .with_threads(threads_flag(&flags)?),
        )
    };
    let report = api::OptimizeRequest {
        source,
        budget,
        board,
        split,
        compare_materialized: false,
        trace: false,
    }
    .run()?;
    // run() already refuses to return an unverified report; certify again
    // here to obtain the certificate object itself — the CLI's output is
    // the proof, not just the plan.
    let cert = mcu_reorder::verify::certify_report(&report).map_err(|e| anyhow!("{e}"))?;

    if let Some(exported_path) = path_flag(&flags, "reordered", "--reordered")? {
        let src = report
            .tflite
            .as_ref()
            .ok_or_else(|| usage("--reordered needs a .tflite source model"))?;
        let exported = mcu_reorder::tflite::read_model(exported_path)?;
        let perm = mcu_reorder::verify::verify_export(&src.model, &exported)
            .map_err(|e| anyhow!("{exported_path}: {e}"))?;
        println!(
            "export ok: {exported_path} is a pure operator permutation of the source \
             ({} operators, buffers byte-identical)",
            perm.len()
        );
    }

    match json_mode(&flags) {
        None => print!("{}", cert.render()),
        Some(dest) => emit_json(&cert.to_json(), dest)?,
    }
    Ok(())
}

/// `mcu-reorder codegen`: run the optimize pipeline, certify it, and
/// lower the plan to a deployable C artifact ([`mcu_reorder::codegen`]).
/// The header lands next to the source (`F.c` → `F.h`); `--harness F`
/// additionally writes the golden-equivalence `main()`.
fn cmd_codegen(pos: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let mut flags = flags.clone();
    if let Some(p) = pos.first() {
        // Positional argument: a path if it looks like a file, else a zoo
        // model name (same dispatch as `trace`/`verify`).
        if p.contains('.') && std::path::Path::new(p).extension().is_some() {
            flags.insert("file".to_string(), p.clone());
        } else {
            flags.insert("model".to_string(), p.clone());
        }
    }
    let source = source_from_flags(&flags, DType::I8)?;
    let out = out_flag(&flags)?.ok_or_else(|| usage("codegen needs -o/--out FILE.c"))?;
    let stem = std::path::Path::new(out)
        .file_stem()
        .and_then(|s| s.to_str())
        .filter(|s| !s.is_empty())
        .ok_or_else(|| usage(format!("-o/--out needs a C file path, got {out:?}")))?;
    let budget: Option<usize> = num_flag(&flags, "budget")?;
    let board = match flags.get("board") {
        None => &NUCLEO_F767ZI,
        Some(name) => mcu_reorder::mcu::boards::by_name(name).ok_or_else(|| {
            usage(format!("unknown board {name:?} (see `mcu-reorder sweep` for the list)"))
        })?,
    };
    let split = if flags.contains_key("reorder-only") {
        None
    } else {
        Some(
            mcu_reorder::split::SplitOptions {
                sram_budget: budget,
                elide: !flags.contains_key("no-elide"),
                ..Default::default()
            }
            .with_threads(threads_flag(&flags)?),
        )
    };
    let report = api::OptimizeRequest {
        source,
        budget,
        board,
        split,
        compare_materialized: false,
        trace: false,
    }
    .run()?;
    let ws = mcu_reorder::codegen::weights_for_report(&report)?;
    let art = mcu_reorder::codegen::generate(&report, &ws, stem)?;

    let header_path = std::path::Path::new(out).with_extension("h");
    std::fs::write(out, &art.source).with_context(|| format!("writing {out}"))?;
    std::fs::write(&header_path, &art.header)
        .with_context(|| format!("writing {}", header_path.display()))?;
    println!(
        "codegen {} ({}): {} ops lowered, entry {}_invoke",
        report.model, art.dtype, art.n_ops, art.symbol
    );
    println!(
        "  arena  : {:>8} B static .bss (== certified plan peak)",
        art.arena_bytes
    );
    println!("  peak   : {:>8} B analytic working set", art.peak_bytes);
    println!("  rodata : {:>8} B weight tables", art.rodata_bytes);
    println!("  io     : {} -> {} elements", art.input_elems, art.output_elems);
    println!("wrote {out}, {}", header_path.display());
    if let Some(hp) = path_flag(&flags, "harness", "--harness")? {
        std::fs::write(hp, &art.harness).with_context(|| format!("writing {hp}"))?;
        println!("wrote {hp} (golden-equivalence harness; cc -std=c99 {out} {hp})");
    }
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = args[0].clone();
    let (pos, flags) = parse_args(&args[1..]);
    let result = match cmd.as_str() {
        "list" => {
            cmd_list();
            Ok(())
        }
        "analyze" => cmd_analyze(&flags),
        "import" => cmd_import(&pos, &flags),
        "optimize" => cmd_optimize(&pos, &flags),
        "trace" => cmd_trace(&pos, &flags),
        "verify" => cmd_verify(&pos, &flags),
        "codegen" => cmd_codegen(&pos, &flags),
        "split" => cmd_split(&flags),
        "export" => cmd_export(&flags),
        "run" => cmd_run(&flags),
        "serve" => cmd_serve(&flags),
        "plan-serve" => cmd_plan_serve(&flags),
        "table1" => cmd_table1(),
        "sweep" => cmd_sweep(),
        "nas" => cmd_nas(&flags),
        "dot" => cmd_dot(&flags),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(usage(format!("unknown command {other:?}\n{USAGE}"))),
    };
    if let Err(e) = result {
        // Uniform failure contract: one line on stderr, exit 2 for usage
        // errors, exit 1 for everything else (I/O, planning, verification).
        let msg = format!("{e:#}");
        eprintln!("error: {msg}");
        std::process::exit(if msg.starts_with(USAGE_PREFIX) { 2 } else { 1 });
    }
}

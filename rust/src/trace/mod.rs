//! Memory-timeline tracing and planner telemetry.
//!
//! The paper's claim is a memory number; this module is the lens that
//! turns the single scalar per model into an inspectable timeline. A
//! [`TraceSink`] is threaded through the four layers that compute memory
//! silently:
//!
//! - [`crate::sched::simulate_traced`] — per-op alloc/free/live-set
//!   events and elided-accumulator hits;
//! - [`crate::alloc::StaticPlan::best_fit_traced`] — slot placements
//!   (offset, lifetime, sharing root);
//! - [`crate::interp::Interpreter::run_traced`] — the *measured* arena
//!   high-water after every operator;
//! - [`crate::split::optimize_traced`] — beam-search telemetry
//!   (candidates scored/kept, prune reasons, wall time per phase).
//!
//! Tracing is zero-cost when off: every producer takes `&mut dyn
//! TraceSink`, checks [`TraceSink::enabled`] before constructing an
//! event, and the untraced entry points delegate with a [`NullSink`]
//! (whose `enabled()` is `false`, so no event is ever built).
//!
//! Exports: Chrome trace-event JSON ([`chrome::chrome_trace`], loadable
//! in Perfetto / `chrome://tracing`), a compact per-op live-set CSV
//! ([`live_csv`], diffed byte-for-byte against the Python DP mirror in
//! CI), and an op-by-op schedule diff ([`schedule_diff`]). The
//! load-bearing correctness payoff is [`audit`]: measured interpreter
//! high-water must equal the analytic `peak_of` on every zoo model and
//! both quantizations.

pub mod audit;
pub mod chrome;

use crate::graph::{Graph, OpId, TensorId};
use crate::sched::MemTrace;
use crate::util::json::Json;

/// One observability event. Byte counts are exact (the same accounting
/// the scheduler's tables are made of); search events carry enough to
/// reconstruct why the planner kept or dropped a move.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// A tensor became resident (scheduler accounting). `shared` marks an
    /// output that writes through an in-place accumulator's buffer and
    /// therefore contributes no new bytes at its step.
    TensorAlloc { step: usize, tensor: TensorId, name: String, bytes: usize, shared: bool },
    /// A tensor was reclaimed. Graph outputs (and anything still resident)
    /// are freed at `step == order.len()`, so every alloc has a free.
    TensorFree { step: usize, tensor: TensorId, name: String, bytes: usize },
    /// One executed step of the working-set simulation: the live-set byte
    /// total *during* the op (the Appendix-A "Usage" column).
    OpExec { step: usize, op: OpId, name: String, bytes: usize, elided: bool },
    /// An in-place accumulator hit: the op's output shares `acc`'s buffer,
    /// saving `saved_bytes` at this step.
    ElidedAccum { step: usize, op: OpId, name: String, acc: TensorId, saved_bytes: usize },
    /// Offline placement of one activation tensor by the best-fit planner.
    /// `root` is the tensor's storage-sharing representative (elided
    /// accumulator chains share one slot; `root == tensor` otherwise).
    SlotPlaced {
        tensor: TensorId,
        name: String,
        offset: usize,
        bytes: usize,
        start: usize,
        end: usize,
        root: TensorId,
    },
    /// Measured arena state after one interpreted operator: the dynamic
    /// allocator's high-water mark so far (what the audit compares to the
    /// analytic peak).
    ArenaOp { step: usize, op: OpId, name: String, high_water: usize },
    /// One scored beam-search move: `peak` is `None` when the rewrite or
    /// its schedule failed; `kept` moves strictly improved their state.
    Candidate {
        round: usize,
        segment: Vec<String>,
        factor: usize,
        axis: &'static str,
        elided: bool,
        peak: Option<usize>,
        kept: bool,
        reason: &'static str,
    },
    /// End-of-round beam summary: `scored` candidates expanded, `kept`
    /// survived generation pruning, `pool` states before truncation to the
    /// beam width, and the best peak so far.
    SearchRound { round: usize, scored: usize, kept: usize, pool: usize, best_peak: usize },
    /// Wall-clock of one named search phase (the measurement substrate for
    /// planner-scaling work).
    Phase { name: String, wall_ms: f64 },
    /// End-of-run planner work counters (one per [`crate::split::optimize_traced`]
    /// run): how the candidate stream split across outcome buckets, how
    /// many full-DP evaluations actually ran, and how the region memo
    /// performed. Mirrors [`crate::split::PlannerStats`].
    PlannerStats {
        scored: usize,
        deduped: usize,
        improved: usize,
        no_improve: usize,
        bounded: usize,
        apply_failed: usize,
        schedule_failed: usize,
        full_evals: usize,
        cache_lookups: usize,
        cache_hits: usize,
        cache_misses: usize,
        threads: usize,
    },
    /// One plan-cache probe in the serving coordinator
    /// ([`crate::coordinator::PlanService`]).
    PlanCacheLookup { model: String, board: String, hit: bool },
    /// An LRU eviction from the serving coordinator's plan cache; the
    /// fields name the evicted plan.
    PlanCacheEvict { model: String, board: String },
    /// A plan request shed by admission control (`depth` = queue depth at
    /// rejection time).
    PlanShed { depth: usize },
    /// The independent static verifier ([`crate::verify`]) certified a
    /// plan: `checks` property families examined, `peak_bytes` the peak it
    /// recomputed through its own interval engine.
    Verify { model: String, checks: usize, peak_bytes: usize, ok: bool },
}

impl Event {
    /// Stable discriminant name (the `"ev"` field of the JSON encoding).
    pub fn kind(&self) -> &'static str {
        match self {
            Event::TensorAlloc { .. } => "alloc",
            Event::TensorFree { .. } => "free",
            Event::OpExec { .. } => "op",
            Event::ElidedAccum { .. } => "elided",
            Event::SlotPlaced { .. } => "slot",
            Event::ArenaOp { .. } => "arena",
            Event::Candidate { .. } => "candidate",
            Event::SearchRound { .. } => "round",
            Event::Phase { .. } => "phase",
            Event::PlannerStats { .. } => "planner",
            Event::PlanCacheLookup { .. } => "plan_cache",
            Event::PlanCacheEvict { .. } => "plan_evict",
            Event::PlanShed { .. } => "plan_shed",
            Event::Verify { .. } => "verify",
        }
    }

    /// JSON encoding (one object per event; `"ev"` is [`Self::kind`]).
    pub fn to_json(&self) -> Json {
        let num = |v: usize| Json::Num(v as f64);
        let mut fields: Vec<(&str, Json)> = vec![("ev", Json::Str(self.kind().to_string()))];
        match self {
            Event::TensorAlloc { step, tensor, name, bytes, shared } => fields.extend([
                ("step", num(*step)),
                ("tensor", num(*tensor)),
                ("name", Json::Str(name.clone())),
                ("bytes", num(*bytes)),
                ("shared", Json::Bool(*shared)),
            ]),
            Event::TensorFree { step, tensor, name, bytes } => fields.extend([
                ("step", num(*step)),
                ("tensor", num(*tensor)),
                ("name", Json::Str(name.clone())),
                ("bytes", num(*bytes)),
            ]),
            Event::OpExec { step, op, name, bytes, elided } => fields.extend([
                ("step", num(*step)),
                ("op", num(*op)),
                ("name", Json::Str(name.clone())),
                ("bytes", num(*bytes)),
                ("elided", Json::Bool(*elided)),
            ]),
            Event::ElidedAccum { step, op, name, acc, saved_bytes } => fields.extend([
                ("step", num(*step)),
                ("op", num(*op)),
                ("name", Json::Str(name.clone())),
                ("acc", num(*acc)),
                ("saved_bytes", num(*saved_bytes)),
            ]),
            Event::SlotPlaced { tensor, name, offset, bytes, start, end, root } => fields
                .extend([
                    ("tensor", num(*tensor)),
                    ("name", Json::Str(name.clone())),
                    ("offset", num(*offset)),
                    ("bytes", num(*bytes)),
                    ("start", num(*start)),
                    ("end", num(*end)),
                    ("root", num(*root)),
                ]),
            Event::ArenaOp { step, op, name, high_water } => fields.extend([
                ("step", num(*step)),
                ("op", num(*op)),
                ("name", Json::Str(name.clone())),
                ("high_water", num(*high_water)),
            ]),
            Event::Candidate { round, segment, factor, axis, elided, peak, kept, reason } => {
                fields.extend([
                    ("round", num(*round)),
                    (
                        "segment",
                        Json::Arr(segment.iter().map(|s| Json::Str(s.clone())).collect()),
                    ),
                    ("factor", num(*factor)),
                    ("axis", Json::Str(axis.to_string())),
                    ("elided", Json::Bool(*elided)),
                    (
                        "peak",
                        match peak {
                            Some(p) => num(*p),
                            None => Json::Null,
                        },
                    ),
                    ("kept", Json::Bool(*kept)),
                    ("reason", Json::Str(reason.to_string())),
                ])
            }
            Event::SearchRound { round, scored, kept, pool, best_peak } => fields.extend([
                ("round", num(*round)),
                ("scored", num(*scored)),
                ("kept", num(*kept)),
                ("pool", num(*pool)),
                ("best_peak", num(*best_peak)),
            ]),
            Event::Phase { name, wall_ms } => fields.extend([
                ("name", Json::Str(name.clone())),
                ("wall_ms", Json::Num(*wall_ms)),
            ]),
            Event::PlannerStats {
                scored,
                deduped,
                improved,
                no_improve,
                bounded,
                apply_failed,
                schedule_failed,
                full_evals,
                cache_lookups,
                cache_hits,
                cache_misses,
                threads,
            } => fields.extend([
                ("scored", num(*scored)),
                ("deduped", num(*deduped)),
                ("improved", num(*improved)),
                ("no_improve", num(*no_improve)),
                ("bounded", num(*bounded)),
                ("apply_failed", num(*apply_failed)),
                ("schedule_failed", num(*schedule_failed)),
                ("full_evals", num(*full_evals)),
                ("cache_lookups", num(*cache_lookups)),
                ("cache_hits", num(*cache_hits)),
                ("cache_misses", num(*cache_misses)),
                ("threads", num(*threads)),
            ]),
            Event::PlanCacheLookup { model, board, hit } => fields.extend([
                ("model", Json::Str(model.clone())),
                ("board", Json::Str(board.clone())),
                ("hit", Json::Bool(*hit)),
            ]),
            Event::PlanCacheEvict { model, board } => fields.extend([
                ("model", Json::Str(model.clone())),
                ("board", Json::Str(board.clone())),
            ]),
            Event::PlanShed { depth } => fields.extend([("depth", num(*depth))]),
            Event::Verify { model, checks, peak_bytes, ok } => fields.extend([
                ("model", Json::Str(model.clone())),
                ("checks", num(*checks)),
                ("peak_bytes", num(*peak_bytes)),
                ("ok", Json::Bool(*ok)),
            ]),
        }
        Json::obj(fields)
    }
}

/// Where events go. Producers call [`Self::enabled`] before constructing
/// an event, so a disabled sink costs one virtual call per site and zero
/// allocations.
pub trait TraceSink {
    /// `false` skips event construction entirely (the zero-cost-when-off
    /// contract).
    fn enabled(&self) -> bool {
        true
    }
    fn record(&mut self, ev: Event);
}

/// Discards everything; `enabled()` is `false`. The default sink behind
/// every untraced entry point.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn enabled(&self) -> bool {
        false
    }
    fn record(&mut self, _ev: Event) {}
}

/// Buffers events in memory (tests, telemetry summaries, CLI `--format
/// json`).
#[derive(Clone, Debug, Default)]
pub struct VecSink {
    pub events: Vec<Event>,
}

impl VecSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Count events of one [`Event::kind`].
    pub fn count(&self, kind: &str) -> usize {
        self.events.iter().filter(|e| e.kind() == kind).count()
    }
}

impl TraceSink for VecSink {
    fn record(&mut self, ev: Event) {
        self.events.push(ev);
    }
}

/// Encodes each event to JSON as it arrives (streaming export; the
/// original `Event` is dropped after encoding).
#[derive(Clone, Debug, Default)]
pub struct JsonSink {
    rows: Vec<Json>,
}

impl JsonSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// The recorded stream as a JSON array.
    pub fn into_json(self) -> Json {
        Json::Arr(self.rows)
    }
}

impl TraceSink for JsonSink {
    fn record(&mut self, ev: Event) {
        self.rows.push(ev.to_json());
    }
}

/// Per-op live-set CSV keyed by tensor *names* (`step,op,bytes,resident`;
/// resident names sorted lexicographically, space-joined). Names — not
/// ids — are the portable identity: the TFLite importer and the Python DP
/// mirror assign different tensor ids to the same model, but agree on
/// names, so CI can diff this output byte-for-byte against
/// `tools/schedule_mirror/mirror.py --trace`.
pub fn live_csv(g: &Graph, trace: &MemTrace) -> String {
    let mut out = String::from("step,op,bytes,resident\n");
    for (i, step) in trace.steps.iter().enumerate() {
        let mut names: Vec<&str> =
            step.resident.iter().map(|&t| g.tensors[t].name.as_str()).collect();
        names.sort_unstable();
        out.push_str(&format!(
            "{},{},{},{}\n",
            i,
            g.ops[step.op].name,
            step.bytes,
            names.join(" ")
        ));
    }
    out
}

/// Op-by-op diff of two schedules of the same graph: per step, the op and
/// live bytes under each order plus the byte delta, with both peaks
/// marked. This is the `trace --compare` rendering.
pub fn schedule_diff(g: &Graph, a: &MemTrace, b: &MemTrace) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<5} {:<20} {:>10}  {:<20} {:>10} {:>10}\n",
        "step", "op (A)", "bytes (A)", "op (B)", "bytes (B)", "delta"
    ));
    let n = a.steps.len().max(b.steps.len());
    for i in 0..n {
        let (an, ab, am) = match a.steps.get(i) {
            Some(s) => {
                (g.ops[s.op].name.as_str(), s.bytes as i64, if i == a.peak_step { "*" } else { "" })
            }
            None => ("-", 0, ""),
        };
        let (bn, bb, bm) = match b.steps.get(i) {
            Some(s) => {
                (g.ops[s.op].name.as_str(), s.bytes as i64, if i == b.peak_step { "*" } else { "" })
            }
            None => ("-", 0, ""),
        };
        out.push_str(&format!(
            "{:<5} {:<20} {:>10}{} {:<20} {:>10}{} {:>+10}\n",
            i,
            an,
            ab,
            if am.is_empty() { " " } else { am },
            bn,
            bb,
            if bm.is_empty() { " " } else { bm },
            bb - ab
        ));
    }
    out.push_str(&format!(
        "peak: A = {} B (step {}), B = {} B (step {}), delta = {:+} B\n",
        a.peak_bytes,
        a.peak_step,
        b.peak_bytes,
        b.peak_step,
        b.peak_bytes as i64 - a.peak_bytes as i64
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched;

    #[test]
    fn nullsink_reports_disabled() {
        assert!(!NullSink.enabled());
        let mut s = NullSink;
        s.record(Event::Phase { name: "x".into(), wall_ms: 1.0 }); // no-op
    }

    #[test]
    fn vecsink_buffers_and_counts() {
        let mut s = VecSink::new();
        assert!(s.enabled());
        s.record(Event::Phase { name: "a".into(), wall_ms: 0.5 });
        s.record(Event::SearchRound { round: 0, scored: 3, kept: 1, pool: 2, best_peak: 100 });
        assert_eq!(s.events.len(), 2);
        assert_eq!(s.count("phase"), 1);
        assert_eq!(s.count("round"), 1);
    }

    #[test]
    fn event_json_roundtrips_through_parser() {
        let ev = Event::Candidate {
            round: 1,
            segment: vec!["c1".into(), "dw".into()],
            factor: 2,
            axis: "rows",
            elided: true,
            peak: Some(4096),
            kept: true,
            reason: "improved",
        };
        let j = Json::parse(&ev.to_json().to_string()).unwrap();
        assert_eq!(j.get("ev").as_str(), Some("candidate"));
        assert_eq!(j.get("peak").as_f64(), Some(4096.0));
        assert_eq!(j.get("segment").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn live_csv_is_name_keyed_and_sorted() {
        let g = sched::tests::figure1_graph();
        let trace = sched::simulate(&g, &g.default_order());
        let csv = live_csv(&g, &trace);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "step,op,bytes,resident");
        assert_eq!(lines.len(), trace.steps.len() + 1);
        // Step 2 (op3): resident = op1, op2, op3 at 5216 B.
        assert_eq!(lines[3], "2,op3,5216,op1 op2 op3");
    }

    #[test]
    fn schedule_diff_reports_both_peaks() {
        let g = sched::tests::figure1_graph();
        let a = sched::simulate(&g, &g.default_order());
        let b = sched::simulate(&g, &[0, 3, 5, 1, 2, 4, 6]);
        let d = schedule_diff(&g, &a, &b);
        assert!(d.contains("5216"));
        assert!(d.contains("4960"));
        assert!(d.contains("-256"));
    }
}

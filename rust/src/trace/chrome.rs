//! Chrome trace-event export (Perfetto / `chrome://tracing`).
//!
//! Encodes a working-set trace as the Trace Event Format's JSON object
//! form (`{"traceEvents": [...]}`): each operator is a duration event
//! (`ph: "X"`) on one timeline row, the analytic live-set bytes are a
//! counter track (`ph: "C"`, rendered as an area chart), the peak step
//! carries an instant event (`ph: "i"`), and — when a measured run is
//! supplied — the interpreter's arena high-water is a second counter
//! track, so analytic-vs-measured divergence is visible as the two area
//! charts peeling apart.
//!
//! Steps are mapped to synthetic time: 1 step = 1000 µs, so a schedule
//! reads left-to-right at one op per millisecond regardless of real
//! kernel cost (the timeline visualizes *memory*, not time).

use crate::graph::Graph;
use crate::sched::MemTrace;
use crate::util::json::Json;

/// Microseconds per execution step on the synthetic timeline.
const STEP_US: f64 = 1000.0;

fn ev(fields: Vec<(&str, Json)>) -> Json {
    Json::obj(fields)
}

fn meta(name: &str, key: &str, value: &str) -> Json {
    ev(vec![
        ("name", Json::Str(name.to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(0.0)),
        ("args", Json::obj(vec![(key, Json::Str(value.to_string()))])),
    ])
}

/// Build the Chrome trace-event document for one simulated schedule.
/// `measured` optionally carries the interpreter's per-op arena
/// high-water (same length as `trace.steps`) as a second counter track.
pub fn chrome_trace(g: &Graph, trace: &MemTrace, measured: Option<&[usize]>) -> Json {
    let mut events: Vec<Json> = Vec::with_capacity(trace.steps.len() * 2 + 8);
    events.push(meta("process_name", "name", &g.name));
    events.push(meta("thread_name", "name", "schedule"));

    for (i, step) in trace.steps.iter().enumerate() {
        let op = &g.ops[step.op];
        let ts = i as f64 * STEP_US;
        let resident: Vec<Json> = step
            .resident
            .iter()
            .map(|&t| Json::Str(g.tensors[t].name.clone()))
            .collect();
        // One duration slice per operator.
        events.push(ev(vec![
            ("name", Json::Str(op.name.clone())),
            ("cat", Json::Str("op".to_string())),
            ("ph", Json::Str("X".to_string())),
            ("ts", Json::Num(ts)),
            ("dur", Json::Num(STEP_US)),
            ("pid", Json::Num(0.0)),
            ("tid", Json::Num(0.0)),
            (
                "args",
                Json::obj(vec![
                    ("op", Json::Num(step.op as f64)),
                    ("bytes", Json::Num(step.bytes as f64)),
                    ("resident", Json::Arr(resident)),
                ]),
            ),
        ]));
        // The analytic live-set counter track.
        events.push(ev(vec![
            ("name", Json::Str("SRAM (analytic)".to_string())),
            ("ph", Json::Str("C".to_string())),
            ("ts", Json::Num(ts)),
            ("pid", Json::Num(0.0)),
            ("args", Json::obj(vec![("bytes", Json::Num(step.bytes as f64))])),
        ]));
        if let Some(m) = measured {
            events.push(ev(vec![
                ("name", Json::Str("arena high-water (measured)".to_string())),
                ("ph", Json::Str("C".to_string())),
                ("ts", Json::Num(ts)),
                ("pid", Json::Num(0.0)),
                ("args", Json::obj(vec![("bytes", Json::Num(m[i] as f64))])),
            ]));
        }
    }
    // Mark the peak op.
    events.push(ev(vec![
        (
            "name",
            Json::Str(format!(
                "peak: {} B at {}",
                trace.peak_bytes,
                g.ops[trace.steps[trace.peak_step].op].name
            )),
        ),
        ("cat", Json::Str("peak".to_string())),
        ("ph", Json::Str("i".to_string())),
        ("ts", Json::Num(trace.peak_step as f64 * STEP_US)),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(0.0)),
        ("s", Json::Str("p".to_string())),
    ]));

    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
        (
            "otherData",
            Json::obj(vec![
                ("model", Json::Str(g.name.clone())),
                ("peak_bytes", Json::Num(trace.peak_bytes as f64)),
                ("peak_step", Json::Num(trace.peak_step as f64)),
                ("steps", Json::Num(trace.steps.len() as f64)),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched;

    #[test]
    fn chrome_trace_has_expected_event_shapes() {
        let g = sched::tests::figure1_graph();
        let trace = sched::simulate(&g, &g.default_order());
        let doc = chrome_trace(&g, &trace, None);
        // Roundtrip through the parser: the export must be valid JSON.
        let j = Json::parse(&doc.to_pretty()).unwrap();
        let evs = j.get("traceEvents").as_arr().unwrap();
        // 2 metadata + (X + C) per step + 1 instant.
        assert_eq!(evs.len(), 2 + 2 * trace.steps.len() + 1);
        let phs: Vec<&str> = evs.iter().filter_map(|e| e.get("ph").as_str()).collect();
        assert_eq!(phs.iter().filter(|&&p| p == "X").count(), trace.steps.len());
        assert_eq!(phs.iter().filter(|&&p| p == "C").count(), trace.steps.len());
        assert_eq!(phs.iter().filter(|&&p| p == "i").count(), 1);
        assert_eq!(j.get("otherData").get("peak_bytes").as_f64(), Some(5216.0));
    }

    #[test]
    fn measured_overlay_adds_a_counter_track() {
        let g = sched::tests::figure1_graph();
        let trace = sched::simulate(&g, &g.default_order());
        let measured: Vec<usize> = trace.steps.iter().map(|s| s.bytes).collect();
        let doc = chrome_trace(&g, &trace, Some(&measured));
        let j = Json::parse(&doc.to_string()).unwrap();
        let evs = j.get("traceEvents").as_arr().unwrap();
        let measured_rows = evs
            .iter()
            .filter(|e| e.get("name").as_str() == Some("arena high-water (measured)"))
            .count();
        assert_eq!(measured_rows, trace.steps.len());
    }
}

//! Analytic-vs-measured peak cross-check.
//!
//! The scheduler's byte accounting ([`crate::sched::peak_of`]) and the
//! interpreter's dynamic arena ([`crate::interp`]) compute the same
//! quantity by entirely different mechanisms — one simulates live sets,
//! the other actually allocates, compacts and frees buffers. The audit
//! executes every model at an arena sized to *exactly* the analytic
//! peak and asserts the measured high-water equals it, across four
//! scheduling modes (`default`, `reordered`, `split`, `elided`) and
//! every quantization the model supports. Any drift — an accounting bug,
//! a leaked handle, fragmentation the compactor misses — fails the
//! equality, and the exact-capacity arena additionally proves the
//! analytic number is *sufficient*, not merely matched.
//!
//! CI runs this as a gating step over the whole zoo plus the imported
//! TFLite fixture (`mcu-reorder trace --audit`); the bench surfaces the
//! same table in `benches/partial_exec.rs` output.

use crate::alloc::CompactPolicy;
use crate::graph::{DType, Graph};
use crate::interp::{calibrate, ExecConfig, Interpreter, TensorData, WeightStore};
use crate::models;
use crate::sched;
use crate::split::{self, SplitOptions};
use crate::trace::{Event, VecSink};

/// A graph plus the weights needed to execute it (one per quantization).
pub struct Prepared {
    pub label: String,
    pub dtype: &'static str,
    pub graph: Graph,
    pub ws: WeightStore,
}

/// One audited (model, mode, dtype) cell.
#[derive(Clone, Debug)]
pub struct AuditEntry {
    pub model: String,
    pub mode: &'static str,
    pub dtype: &'static str,
    /// The scheduler's peak for the executed (graph, order).
    pub analytic: usize,
    /// The interpreter's arena high-water, or the execution error.
    pub measured: Result<usize, String>,
}

impl AuditEntry {
    /// Exact equality — the audit's pass condition.
    pub fn ok(&self) -> bool {
        self.measured.as_ref().is_ok_and(|&m| m == self.analytic)
    }
}

/// Deterministic synthetic inputs for `g` (the ramp the CLI/benches use;
/// i8 inputs are quantized through the store's input qparams so the
/// payload is in-domain).
pub fn inputs_for(g: &Graph, ws: &WeightStore) -> Result<Vec<TensorData>, String> {
    g.inputs
        .iter()
        .map(|&tid| {
            let t = &g.tensors[tid];
            let n = t.elems();
            let ramp: Vec<f32> = (0..n).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
            Ok(match t.dtype {
                DType::U8 => TensorData::U8((0..n).map(|i| (i % 251) as u8).collect()),
                DType::F32 => TensorData::F32(ramp),
                DType::I8 => match ws.qparams.get(&tid) {
                    Some(q) => TensorData::I8(q.quantize(&ramp)),
                    None => {
                        TensorData::I8((0..n).map(|i| ((i % 255) as i32 - 127) as i8).collect())
                    }
                },
                DType::I32 => return Err(format!("input {} has i32 dtype", t.name)),
            })
        })
        .collect()
}

/// Execute `(g, ws)` under `order` at an arena of exactly `analytic`
/// bytes; return the measured high-water.
fn run_at_exact_capacity(
    g: &Graph,
    ws: &WeightStore,
    order: &[usize],
    analytic: usize,
) -> Result<usize, String> {
    let inputs = inputs_for(g, ws)?;
    let cfg = ExecConfig {
        arena_bytes: analytic,
        policy: CompactPolicy::EveryOp,
        order: Some(order.to_vec()),
    };
    let interp = Interpreter::new(g, ws.clone(), cfg);
    let r = interp.run(&inputs).map_err(|e| e.to_string())?;
    Ok(r.alloc.high_water)
}

/// The measured arena high-water after each executed op (the Chrome
/// export's second counter track), via [`Interpreter::run_traced`].
pub fn measured_series(
    g: &Graph,
    ws: &WeightStore,
    order: &[usize],
) -> Result<Vec<usize>, String> {
    let inputs = inputs_for(g, ws)?;
    let cfg = ExecConfig {
        arena_bytes: sched::peak_of(g, order),
        policy: CompactPolicy::EveryOp,
        order: Some(order.to_vec()),
    };
    let interp = Interpreter::new(g, ws.clone(), cfg);
    let mut sink = VecSink::new();
    interp.run_traced(&inputs, &mut sink).map_err(|e| e.to_string())?;
    Ok(sink
        .events
        .iter()
        .filter_map(|e| match e {
            Event::ArenaOp { high_water, .. } => Some(*high_water),
            _ => None,
        })
        .collect())
}

/// Audit one prepared (graph, weights) pair across the four scheduling
/// modes. `split`/`elided` rewrite the graph with the quick beam preset
/// (the plan flavor is irrelevant to the audit; the accounting must hold
/// for *any* plan the planner emits) and carry the weights across via
/// [`split::SplitOutcome::remap_weights`].
pub fn audit_prepared(p: &Prepared) -> Vec<AuditEntry> {
    let g = &p.graph;
    let entry = |mode: &'static str, analytic: usize, measured: Result<usize, String>| {
        AuditEntry { model: p.label.clone(), mode, dtype: p.dtype, analytic, measured }
    };
    let mut out = Vec::with_capacity(4);

    let default_order = g.default_order();
    let analytic = sched::peak_of(g, &default_order);
    out.push(entry("default", analytic, run_at_exact_capacity(g, &p.ws, &default_order, analytic)));

    match sched::optimal(g) {
        Ok((s, _)) => {
            out.push(entry(
                "reordered",
                s.peak_bytes,
                run_at_exact_capacity(g, &p.ws, &s.order, s.peak_bytes),
            ));
        }
        Err(e) => out.push(entry("reordered", 0, Err(e.to_string()))),
    }

    for (mode, opts) in [
        ("split", SplitOptions::quick().materialized()),
        ("elided", SplitOptions::quick()),
    ] {
        match split::optimize(g, &opts) {
            Ok(o) => {
                let ws = o.remap_weights(&p.ws);
                let analytic = o.schedule.peak_bytes;
                out.push(entry(
                    mode,
                    analytic,
                    run_at_exact_capacity(&o.graph, &ws, &o.schedule.order, analytic),
                ));
            }
            Err(e) => out.push(entry(mode, 0, Err(e.to_string()))),
        }
    }
    out
}

/// Prepare a zoo model for auditing: synthetic byte graphs audit once as
/// `u8`; CNN models audit as `f32` (seeded weights) and `i8` (calibrated
/// on the f32 twin, then quantized — the deployment pipeline).
pub fn prepare_zoo(name: &str) -> Result<Vec<Prepared>, String> {
    let probe =
        models::by_name(name, DType::I8).ok_or_else(|| format!("unknown zoo model {name:?}"))?;
    if probe.inputs.iter().any(|&t| probe.tensors[t].dtype == DType::U8) {
        return Ok(vec![Prepared {
            label: name.to_string(),
            dtype: "u8",
            graph: probe,
            ws: WeightStore::default(),
        }]);
    }
    let g_f32 = models::by_name(name, DType::F32).unwrap();
    let ws_f32 = WeightStore::seeded_f32(&g_f32, 42);
    let cal_inputs = inputs_for(&g_f32, &ws_f32)?;
    let ranges =
        calibrate(&g_f32, &ws_f32, &cal_inputs, 1 << 24).map_err(|e| e.to_string())?;
    let ws_i8 = WeightStore::quantize_from(&probe, &ws_f32, &ranges);
    Ok(vec![
        Prepared { label: name.to_string(), dtype: "f32", graph: g_f32, ws: ws_f32 },
        Prepared { label: name.to_string(), dtype: "i8", graph: probe, ws: ws_i8 },
    ])
}

/// Prepare an imported TFLite model (quantization and weights come from
/// the flatbuffer itself).
pub fn prepare_imported(imp: crate::tflite::Imported, label: &str) -> Prepared {
    let dtype = match imp.graph.inputs.first().map(|&t| imp.graph.tensors[t].dtype) {
        Some(DType::F32) => "f32",
        Some(DType::U8) => "u8",
        _ => "i8",
    };
    Prepared { label: label.to_string(), dtype, graph: imp.graph, ws: imp.weights }
}

/// Audit a zoo model end to end (all quantizations × all modes).
pub fn audit_zoo_model(name: &str) -> Result<Vec<AuditEntry>, String> {
    let mut out = Vec::new();
    for p in prepare_zoo(name)? {
        out.extend(audit_prepared(&p));
    }
    Ok(out)
}

/// `true` iff every entry measured exactly its analytic peak.
pub fn all_ok(entries: &[AuditEntry]) -> bool {
    entries.iter().all(AuditEntry::ok)
}

/// Fixed-width report (`model mode dtype analytic measured verdict`).
pub fn render(entries: &[AuditEntry]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<12} {:<10} {:<5} {:>10} {:>10}  {}\n",
        "model", "mode", "dtype", "analytic", "measured", "verdict"
    ));
    for e in entries {
        let (measured, verdict) = match &e.measured {
            Ok(m) if e.ok() => (m.to_string(), "ok".to_string()),
            Ok(m) => (m.to_string(), format!("MISMATCH ({:+} B)", *m as i64 - e.analytic as i64)),
            Err(err) => ("-".to_string(), format!("ERROR: {err}")),
        };
        out.push_str(&format!(
            "{:<12} {:<10} {:<5} {:>10} {:>10}  {}\n",
            e.model, e.mode, e.dtype, e.analytic, measured, verdict
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure1_audits_exactly() {
        let entries = audit_zoo_model("figure1").unwrap();
        assert_eq!(entries.len(), 4); // u8 only: 4 modes
        assert!(all_ok(&entries), "{}", render(&entries));
        // default order of figure1 peaks at 5216, reordered at 4960.
        assert_eq!(entries[0].analytic, 5216);
        assert_eq!(entries[1].analytic, 4960);
    }

    #[test]
    fn tiny_audits_exactly_in_both_quantizations() {
        let entries = audit_zoo_model("tiny").unwrap();
        assert_eq!(entries.len(), 8); // {f32, i8} × 4 modes
        assert!(all_ok(&entries), "{}", render(&entries));
        // f32 peaks are exactly 4× the i8 peaks mode-for-mode when the
        // planner picks the same shape of plan; at minimum the default
        // mode must hold the 4× dtype ratio.
        let f32_default = &entries[0];
        let i8_default = &entries[4];
        assert_eq!(f32_default.analytic, 4 * i8_default.analytic);
    }

    #[test]
    fn measured_series_is_monotone_and_ends_at_peak() {
        let g = models::by_name("tiny", DType::F32).unwrap();
        let ws = WeightStore::seeded_f32(&g, 42);
        let order = g.default_order();
        let series = measured_series(&g, &ws, &order).unwrap();
        assert_eq!(series.len(), g.n_ops());
        assert!(series.windows(2).all(|w| w[0] <= w[1]), "high-water is monotone");
        assert_eq!(*series.last().unwrap(), sched::peak_of(&g, &order));
    }

    #[test]
    fn render_marks_mismatches() {
        let e = AuditEntry {
            model: "m".into(),
            mode: "default",
            dtype: "i8",
            analytic: 100,
            measured: Ok(96),
        };
        assert!(!e.ok());
        assert!(render(&[e]).contains("MISMATCH (-4 B)"));
    }
}

//! Offline (ahead-of-time) tensor placement.
//!
//! Two planners bracket the dynamic allocator:
//!
//! - [`StaticPlan::no_reuse`] — the baseline the paper measured against:
//!   every activation gets its own offset, no reuse. SRAM need = sum of all
//!   activation bytes (Table 1 "Static alloc.": 241KB for MobileNet).
//! - [`StaticPlan::best_fit`] — the §6 extension ("when the execution
//!   schedule is known in advance, optimal tensor buffer placement in
//!   memory may be precomputed"): lifetime-interval analysis + greedy
//!   best-fit-decreasing offset assignment (the strategy TFLM's
//!   `GreedyMemoryPlanner` later adopted). Needs no run-time compaction.
//!
//! Placements from either planner are proven sound after the fact by
//! [`crate::verify::verify_arena`], which re-derives lifetimes and
//! storage-sharing roots with its own interval engine — deliberately
//! sharing none of this module's accounting code.

use std::collections::HashMap;

use crate::graph::{Graph, OpId, TensorId};
use crate::trace::{Event, NullSink, TraceSink};

/// Storage-sharing roots induced by structural in-place accumulators
/// (streaming join elision): a [`crate::graph::OpKind::PartialInto`]
/// writes through its accumulator's buffer, so the whole accumulator
/// chain — intermediate `…#w{j}` tensors plus the final join tensor —
/// occupies ONE buffer. `root[t]` is the representative tensor of `t`'s
/// sharing group (`t` itself for ordinary tensors). The offline planners
/// place one slot per group and point every member at it; their lifetimes
/// deliberately overlap in both time and address.
pub fn storage_roots(g: &Graph) -> Vec<TensorId> {
    let mut root: Vec<TensorId> = (0..g.tensors.len()).collect();
    for (op, acc) in g.ops.iter().zip(crate::sched::elided_accumulators(g)) {
        if let Some(acc) = acc {
            // Resolve transitively (the accumulator may itself share).
            let mut r = acc;
            while root[r] != r {
                r = root[r];
            }
            root[op.output] = r;
        }
    }
    root
}

/// Production/death step of one activation tensor under a given order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Lifetime {
    pub tensor: TensorId,
    /// First step (index into the order) at which the tensor is resident.
    /// Graph inputs are resident from step 0.
    pub start: usize,
    /// Last step at which it is resident (inclusive). Graph outputs live to
    /// the final step.
    pub end: usize,
    pub bytes: usize,
}

/// Compute activation lifetimes under `order` (weights excluded).
pub fn plan_lifetimes(g: &Graph, order: &[OpId]) -> Vec<Lifetime> {
    g.check_order(order).expect("plan_lifetimes: invalid order");
    let n_steps = order.len();
    let step_of: HashMap<OpId, usize> =
        order.iter().enumerate().map(|(i, &o)| (o, i)).collect();
    let mut out = Vec::new();
    for t in &g.tensors {
        if t.is_weight {
            continue;
        }
        let start = match t.producer {
            Some(p) => step_of[&p],
            None => 0,
        };
        let mut end = if g.outputs.contains(&t.id) {
            n_steps.saturating_sub(1)
        } else {
            start
        };
        for &c in &t.consumers {
            if g.ops[c].inputs.contains(&t.id) {
                end = end.max(step_of[&c]);
            }
        }
        out.push(Lifetime { tensor: t.id, start, end, bytes: t.bytes() });
    }
    out
}

/// An offline placement: offsets for every activation tensor plus the
/// arena size it requires.
#[derive(Clone, Debug)]
pub struct StaticPlan {
    /// `tensor id → offset`; only activation tensors appear.
    pub offsets: HashMap<TensorId, usize>,
    /// Bytes of SRAM the plan needs (`max(offset + len)`).
    pub arena_bytes: usize,
    /// Human-readable name of the strategy (reports/benches).
    pub strategy: &'static str,
}

impl StaticPlan {
    /// Old-TFLM behaviour: all activations pre-allocated side by side.
    pub fn no_reuse(g: &Graph) -> StaticPlan {
        let mut offsets = HashMap::new();
        let mut cursor = 0usize;
        for t in &g.tensors {
            if t.is_weight {
                continue;
            }
            offsets.insert(t.id, cursor);
            cursor += t.bytes();
        }
        StaticPlan { offsets, arena_bytes: cursor, strategy: "static-no-reuse" }
    }

    /// Lifetime-aware greedy best-fit-decreasing placement for a known
    /// execution order.
    ///
    /// Tensors are placed largest-first; each goes to the lowest offset
    /// where it does not overlap (in address space) any already-placed
    /// tensor with an intersecting lifetime. Zero-byte tensors all sit at
    /// offset 0. Tensors in one storage-sharing group (a join-elided
    /// accumulator chain — see [`storage_roots`]) are placed as a single
    /// slot spanning the union of their lifetimes: every member gets the
    /// same offset, which is exactly the overlap the elision promises.
    pub fn best_fit(g: &Graph, order: &[OpId]) -> StaticPlan {
        Self::best_fit_traced(g, order, &mut NullSink)
    }

    /// [`Self::best_fit`] with an observability sink: emits one
    /// [`Event::SlotPlaced`] per activation tensor carrying its assigned
    /// offset, its *own* lifetime (not the merged group interval) and its
    /// storage-sharing root, so a trace shows both the placement and which
    /// tensors alias one slot.
    pub fn best_fit_traced(
        g: &Graph,
        order: &[OpId],
        sink: &mut dyn TraceSink,
    ) -> StaticPlan {
        let root = storage_roots(g);
        let lifetimes = plan_lifetimes(g, order);
        // Merge each sharing group into one lifetime interval (members
        // are equal-sized; the interval covers first producer to last
        // consumer of the chain).
        let mut merged: HashMap<TensorId, Lifetime> = HashMap::new();
        for &lt in &lifetimes {
            let r = root[lt.tensor];
            merged
                .entry(r)
                .and_modify(|m| {
                    m.start = m.start.min(lt.start);
                    m.end = m.end.max(lt.end);
                    m.bytes = m.bytes.max(lt.bytes);
                })
                .or_insert(Lifetime { tensor: r, ..lt });
        }
        let mut groups: Vec<Lifetime> = merged.into_values().collect();
        groups.sort_by(|a, b| b.bytes.cmp(&a.bytes).then(a.tensor.cmp(&b.tensor)));

        // placed: (offset, lifetime)
        let mut placed: Vec<(usize, Lifetime)> = Vec::new();
        let mut group_offset: HashMap<TensorId, usize> = HashMap::new();
        let mut arena = 0usize;

        for lt in groups {
            // Collect address intervals of time-overlapping tensors, sorted
            // by offset; first-fit the new tensor into the gaps.
            let mut busy: Vec<(usize, usize)> = placed
                .iter()
                .filter(|(_, other)| !(other.end < lt.start || other.start > lt.end))
                .map(|(off, other)| (*off, *off + other.bytes))
                .collect();
            busy.sort_unstable();
            let mut offset = 0usize;
            for (lo, hi) in busy {
                if lo >= offset + lt.bytes {
                    break; // fits in the gap before `lo`
                }
                offset = offset.max(hi);
            }
            group_offset.insert(lt.tensor, offset);
            arena = arena.max(offset + lt.bytes);
            placed.push((offset, lt));
        }
        let offsets: HashMap<TensorId, usize> = g
            .tensors
            .iter()
            .filter(|t| !t.is_weight)
            .map(|t| (t.id, group_offset[&root[t.id]]))
            .collect();
        if sink.enabled() {
            for lt in &lifetimes {
                sink.record(Event::SlotPlaced {
                    tensor: lt.tensor,
                    name: g.tensors[lt.tensor].name.clone(),
                    offset: offsets[&lt.tensor],
                    bytes: lt.bytes,
                    start: lt.start,
                    end: lt.end,
                    root: root[lt.tensor],
                });
            }
        }
        StaticPlan { offsets, arena_bytes: arena, strategy: "planned-best-fit" }
    }

    /// Verify no two simultaneously-live tensors overlap in address space
    /// and the plan stays within `arena_bytes`. Tensors of one
    /// storage-sharing group (join-elided accumulator chains) are
    /// *expected* to overlap — they are the same buffer — and are skipped
    /// pairwise.
    pub fn check_no_overlap(&self, g: &Graph, order: &[OpId]) -> Result<(), String> {
        let root = storage_roots(g);
        let lifetimes = plan_lifetimes(g, order);
        for (i, a) in lifetimes.iter().enumerate() {
            let ao = *self
                .offsets
                .get(&a.tensor)
                .ok_or_else(|| format!("tensor {} unplaced", a.tensor))?;
            if ao + a.bytes > self.arena_bytes {
                return Err(format!("tensor {} exceeds arena", a.tensor));
            }
            for b in &lifetimes[i + 1..] {
                let time_overlap = !(b.end < a.start || b.start > a.end);
                if !time_overlap || a.bytes == 0 || b.bytes == 0 {
                    continue;
                }
                if root[a.tensor] == root[b.tensor] {
                    continue; // same buffer by construction
                }
                let bo = self.offsets[&b.tensor];
                let addr_overlap = ao < bo + b.bytes && bo < ao + a.bytes;
                if addr_overlap {
                    return Err(format!(
                        "tensors {} and {} overlap in time and address",
                        a.tensor, b.tensor
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, GraphBuilder};
    use crate::sched::{peak_of, simulate};
    use crate::util::prop;

    #[test]
    fn lifetimes_of_figure1_default_order() {
        let g = crate::sched::tests::figure1_graph();
        let order = g.default_order();
        let lts = plan_lifetimes(&g, &order);
        let by_name = |name: &str| {
            let id = g.tensor_by_name(name).unwrap().id;
            *lts.iter().find(|l| l.tensor == id).unwrap()
        };
        // t1 (output of op1) is produced at step 0 and last consumed by
        // op4 at step 3.
        let t1 = by_name("op1");
        assert_eq!((t1.start, t1.end), (0, 3));
        // Graph input lives [0, 0] (only op1 consumes it).
        let t0 = by_name("t0");
        assert_eq!((t0.start, t0.end), (0, 0));
        // Output lives to the last step.
        let t7 = by_name("op7");
        assert_eq!((t7.start, t7.end), (6, 6));
    }

    #[test]
    fn no_reuse_equals_activation_total() {
        let g = crate::sched::tests::figure1_graph();
        let plan = StaticPlan::no_reuse(&g);
        assert_eq!(plan.arena_bytes, g.activation_total());
        plan.check_no_overlap(&g, &g.default_order()).unwrap();
    }

    #[test]
    fn best_fit_is_between_peak_and_total() {
        let g = crate::sched::tests::figure1_graph();
        let order = g.default_order();
        let plan = StaticPlan::best_fit(&g, &order);
        plan.check_no_overlap(&g, &order).unwrap();
        let peak = peak_of(&g, &order);
        assert!(plan.arena_bytes >= peak);
        assert!(plan.arena_bytes <= g.activation_total());
    }

    #[test]
    fn best_fit_reuses_memory_on_chains() {
        // Chain of equal-size tensors: plan should ping-pong two slots.
        let mut b = GraphBuilder::new("chain");
        let mut t = b.input("x", &[256], DType::U8);
        for i in 0..8 {
            t = b.synthetic(&format!("s{i}"), &[t], 256, 0);
        }
        b.output(t);
        let g = b.finish().unwrap();
        let plan = StaticPlan::best_fit(&g, &g.default_order());
        assert_eq!(plan.arena_bytes, 512, "chain should need exactly two slots");
    }

    #[test]
    fn prop_best_fit_never_overlaps_on_random_dags() {
        prop::check_sized("best-fit-no-overlap", 60, 3, 10, |rng, n| {
            let g = crate::sched::bruteforce::tests::random_dag(rng, n);
            let order = g.topo_order().unwrap();
            let plan = StaticPlan::best_fit(&g, &order);
            plan.check_no_overlap(&g, &order).unwrap();
            let peak = peak_of(&g, &order);
            assert!(plan.arena_bytes >= peak);
            assert!(plan.arena_bytes <= g.activation_total());
        });
    }

    /// Join-elided accumulator chains place as ONE slot: members share an
    /// offset, the checker accepts the intentional overlap, and the plan
    /// stays under the 2×output floor a materialized join would force.
    #[test]
    fn best_fit_overlaps_elided_accumulator_chains() {
        use crate::graph::{Act, Padding, SplitAxis};
        use crate::split::{apply_segment, SegmentSplit};
        let mut b = GraphBuilder::new("elide-plan");
        let x = b.input("x", &[1, 8, 8, 2], DType::I8);
        let c1 = b.conv2d("c1", x, 16, (3, 3), (1, 1), Padding::Same, Act::Relu6);
        let dw = b.dwconv2d("dw", c1, (3, 3), (1, 1), Padding::Same, Act::Relu6);
        b.output(dw);
        let g = b.finish().unwrap();
        let seg =
            SegmentSplit { ops: vec![0, 1], factor: 4, axis: SplitAxis::Rows, elide: true };
        let res = apply_segment(&g, &seg).unwrap();
        let (sched, _) = crate::sched::optimal(&res.graph).unwrap();

        // The whole accumulator chain shares one root…
        let root = storage_roots(&res.graph);
        let join = res.graph.tensor_by_name("dw").unwrap().id;
        let shared: Vec<TensorId> = (0..res.graph.n_tensors())
            .filter(|&t| root[t] == root[join])
            .collect();
        assert_eq!(shared.len(), 4, "3 intermediate accumulators + the join tensor");

        // …the plan gives every member the same offset…
        let plan = StaticPlan::best_fit(&res.graph, &sched.order);
        plan.check_no_overlap(&res.graph, &sched.order).unwrap();
        let off = plan.offsets[&join];
        for &t in &shared {
            assert_eq!(plan.offsets[&t], off, "tensor {t} not overlapped");
        }

        // …and the arena stays below what a materialized join would need.
        let join_bytes = res.graph.tensors[join].bytes();
        assert!(plan.arena_bytes >= sched.peak_bytes);
        assert!(
            plan.arena_bytes < 2 * join_bytes,
            "planned arena {} should undercut the 2x join floor {}",
            plan.arena_bytes,
            2 * join_bytes
        );
    }

    #[test]
    #[should_panic(expected = "invalid order")]
    fn lifetimes_reject_bad_order() {
        let g = crate::sched::tests::figure1_graph();
        plan_lifetimes(&g, &[1, 0, 2, 3, 4, 5, 6]);
    }
}

//! The paper's dynamic tensor-memory allocator with compaction.
//!
//! TensorFlow Lite assumes tensor buffers are contiguous and unfragmented;
//! the paper's trick is that because only the micro-interpreter holds
//! references (through a handle table — "C/C++ pointers to memory blocks are
//! not being remembered anywhere"), buffers may be *moved* between
//! operators. The defragmentation strategy is deliberately simple: after
//! every operator, slide all live buffers to the start of the arena,
//! preserving their order (§4).
//!
//! The arena here is a real `Vec<u8>`: compaction physically `memmove`s the
//! bytes so the micro-interpreter can execute actual kernels on top of it,
//! and the number of bytes moved is recorded — that traffic is what the MCU
//! cost model charges to reproduce the paper's +0.68% time / +0.97% energy
//! overhead measurement.

/// Handle to an allocated buffer. Stable across compaction (indexes the
/// handle table, not memory).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BufId(pub(crate) u32);

/// When the arena compacts live buffers to the start of the region.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompactPolicy {
    /// The paper's strategy: after every operator.
    EveryOp,
    /// Only when an allocation fails for lack of a contiguous hole
    /// (ablation: cheaper, but fragmentation spikes between compactions).
    OnDemand,
    /// Never compact (ablation: shows fragmentation-induced failures).
    Never,
}

/// Allocation failure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AllocError {
    /// Not enough total free bytes, even after compaction.
    OutOfMemory { requested: usize, free: usize, capacity: usize },
    /// Enough free bytes exist but no contiguous hole and the policy
    /// forbids compaction.
    Fragmented { requested: usize, largest_hole: usize, free: usize },
    /// Stale or double-freed handle.
    BadHandle(BufId),
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::OutOfMemory { requested, free, capacity } => write!(
                f,
                "out of memory: requested {requested}B, {free}B free of {capacity}B"
            ),
            AllocError::Fragmented { requested, largest_hole, free } => write!(
                f,
                "fragmented: requested {requested}B, largest hole {largest_hole}B ({free}B free total)"
            ),
            AllocError::BadHandle(h) => write!(f, "bad buffer handle {h:?}"),
        }
    }
}

impl std::error::Error for AllocError {}

/// Counters the MCU cost model consumes.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct AllocStats {
    /// Peak bytes of live buffers (the analytic working-set peak when the
    /// schedule frees eagerly).
    pub high_water: usize,
    /// Peak *address* used, i.e. `max(offset + len)` over time — equals
    /// `high_water` under `EveryOp` compaction, larger under fragmentation.
    pub address_high_water: usize,
    /// Total bytes physically moved by compaction (charged by the cost
    /// model).
    pub bytes_moved: usize,
    /// Number of compaction passes.
    pub compactions: usize,
    /// Number of allocations served.
    pub allocs: usize,
    /// Number of frees.
    pub frees: usize,
}

#[derive(Clone, Debug)]
struct Block {
    offset: usize,
    len: usize,
    live: bool,
}

/// Dynamic arena allocator with handle-indirected buffers.
pub struct DynamicArena {
    mem: Vec<u8>,
    /// Handle table: `BufId` → block. Dead entries keep their slot (handles
    /// are never reused within one inference; the table is reset per run).
    blocks: Vec<Block>,
    policy: CompactPolicy,
    live_bytes: usize,
    stats: AllocStats,
}

impl DynamicArena {
    /// A new arena of `capacity` bytes (the board's SRAM budget for tensor
    /// data).
    pub fn new(capacity: usize, policy: CompactPolicy) -> Self {
        DynamicArena {
            mem: vec![0; capacity],
            blocks: Vec::new(),
            policy,
            live_bytes: 0,
            stats: AllocStats::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.mem.len()
    }

    pub fn live_bytes(&self) -> usize {
        self.live_bytes
    }

    pub fn stats(&self) -> &AllocStats {
        &self.stats
    }

    pub fn policy(&self) -> CompactPolicy {
        self.policy
    }

    /// Reset for a fresh inference (keeps capacity and policy, clears
    /// stats and handles).
    pub fn reset(&mut self) {
        self.blocks.clear();
        self.live_bytes = 0;
        self.stats = AllocStats::default();
    }

    /// Live blocks sorted by offset (helper for placement/verification).
    fn live_sorted(&self) -> Vec<usize> {
        let mut idx: Vec<usize> =
            (0..self.blocks.len()).filter(|&i| self.blocks[i].live).collect();
        idx.sort_by_key(|&i| self.blocks[i].offset);
        idx
    }

    /// First-fit scan: smallest offset where `len` fits between live
    /// blocks. Returns `None` if no hole is large enough.
    fn find_hole(&self, len: usize) -> Option<usize> {
        let mut cursor = 0usize;
        for &i in &self.live_sorted() {
            let b = &self.blocks[i];
            if b.offset >= cursor + len {
                return Some(cursor);
            }
            cursor = cursor.max(b.offset + b.len);
        }
        (self.mem.len() >= cursor + len).then_some(cursor)
    }

    fn largest_hole(&self) -> usize {
        let mut cursor = 0usize;
        let mut largest = 0usize;
        for &i in &self.live_sorted() {
            let b = &self.blocks[i];
            largest = largest.max(b.offset.saturating_sub(cursor));
            cursor = cursor.max(b.offset + b.len);
        }
        largest.max(self.mem.len().saturating_sub(cursor))
    }

    /// Allocate `len` bytes; zero-length allocations are legal (empty
    /// tensors) and occupy no space.
    pub fn alloc(&mut self, len: usize) -> Result<BufId, AllocError> {
        let free = self.mem.len() - self.live_bytes;
        if len > free {
            return Err(AllocError::OutOfMemory {
                requested: len,
                free,
                capacity: self.mem.len(),
            });
        }
        let offset = match self.find_hole(len) {
            Some(o) => o,
            None => match self.policy {
                CompactPolicy::Never => {
                    return Err(AllocError::Fragmented {
                        requested: len,
                        largest_hole: self.largest_hole(),
                        free,
                    })
                }
                // OnDemand and EveryOp both compact to satisfy the request.
                _ => {
                    self.compact();
                    self.find_hole(len).expect("hole must exist after compaction")
                }
            },
        };
        let id = BufId(self.blocks.len() as u32);
        self.blocks.push(Block { offset, len, live: true });
        self.live_bytes += len;
        self.stats.allocs += 1;
        self.stats.high_water = self.stats.high_water.max(self.live_bytes);
        self.stats.address_high_water = self.stats.address_high_water.max(offset + len);
        Ok(id)
    }

    /// Free a buffer; the handle becomes invalid.
    pub fn free(&mut self, id: BufId) -> Result<(), AllocError> {
        let b = self.blocks.get_mut(id.0 as usize).ok_or(AllocError::BadHandle(id))?;
        if !b.live {
            return Err(AllocError::BadHandle(id));
        }
        b.live = false;
        self.live_bytes -= b.len;
        self.stats.frees += 1;
        Ok(())
    }

    /// Called by the interpreter after each operator; compacts when the
    /// policy says so (the paper's strategy).
    pub fn after_op(&mut self) {
        if self.policy == CompactPolicy::EveryOp {
            self.compact();
        }
    }

    /// Slide all live buffers to the start of the arena, preserving order
    /// (the paper's defragmentation strategy). Bytes are physically moved;
    /// the move volume is recorded for the cost model.
    pub fn compact(&mut self) {
        let order = self.live_sorted();
        let mut cursor = 0usize;
        for i in order {
            let (offset, len) = (self.blocks[i].offset, self.blocks[i].len);
            if offset != cursor && len > 0 {
                self.mem.copy_within(offset..offset + len, cursor);
                self.stats.bytes_moved += len;
            }
            self.blocks[i].offset = cursor;
            cursor += len;
        }
        self.stats.compactions += 1;
    }

    /// Read access to a buffer's bytes.
    pub fn get(&self, id: BufId) -> Result<&[u8], AllocError> {
        let b = self.blocks.get(id.0 as usize).ok_or(AllocError::BadHandle(id))?;
        if !b.live {
            return Err(AllocError::BadHandle(id));
        }
        Ok(&self.mem[b.offset..b.offset + b.len])
    }

    /// Write access to a buffer's bytes.
    pub fn get_mut(&mut self, id: BufId) -> Result<&mut [u8], AllocError> {
        let b = self.blocks.get(id.0 as usize).ok_or(AllocError::BadHandle(id))?;
        if !b.live {
            return Err(AllocError::BadHandle(id));
        }
        let (o, l) = (b.offset, b.len);
        Ok(&mut self.mem[o..o + l])
    }

    /// Current offset of a buffer (moves under compaction — for tests and
    /// diagnostics only; kernels must go through [`get`](Self::get)).
    pub fn offset_of(&self, id: BufId) -> Result<usize, AllocError> {
        let b = self.blocks.get(id.0 as usize).ok_or(AllocError::BadHandle(id))?;
        if !b.live {
            return Err(AllocError::BadHandle(id));
        }
        Ok(b.offset)
    }

    /// Copy `src` into buffer `id` (length must match exactly).
    pub fn write(&mut self, id: BufId, src: &[u8]) -> Result<(), AllocError> {
        let dst = self.get_mut(id)?;
        assert_eq!(dst.len(), src.len(), "arena write length mismatch");
        dst.copy_from_slice(src);
        Ok(())
    }

    /// Verify the live blocks are pairwise disjoint and in bounds
    /// (invariant check used by tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        let order = self.live_sorted();
        let mut prev_end = 0usize;
        for &i in &order {
            let b = &self.blocks[i];
            if b.offset < prev_end {
                return Err(format!("overlap at block {i}: offset {} < {}", b.offset, prev_end));
            }
            if b.offset + b.len > self.mem.len() {
                return Err(format!("block {i} out of bounds"));
            }
            prev_end = b.offset + b.len;
        }
        let live_sum: usize =
            self.blocks.iter().filter(|b| b.live).map(|b| b.len).sum();
        if live_sum != self.live_bytes {
            return Err(format!("live_bytes {} != sum {}", self.live_bytes, live_sum));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn alloc_free_roundtrip() {
        let mut a = DynamicArena::new(1024, CompactPolicy::EveryOp);
        let b1 = a.alloc(100).unwrap();
        let b2 = a.alloc(200).unwrap();
        assert_eq!(a.live_bytes(), 300);
        a.write(b1, &[7u8; 100]).unwrap();
        a.write(b2, &[9u8; 200]).unwrap();
        a.free(b1).unwrap();
        assert_eq!(a.live_bytes(), 200);
        assert_eq!(a.get(b2).unwrap(), &[9u8; 200][..]);
        assert!(a.get(b1).is_err());
    }

    #[test]
    fn double_free_rejected() {
        let mut a = DynamicArena::new(64, CompactPolicy::Never);
        let b = a.alloc(8).unwrap();
        a.free(b).unwrap();
        assert_eq!(a.free(b), Err(AllocError::BadHandle(b)));
    }

    #[test]
    fn compaction_preserves_contents_and_moves_to_front() {
        let mut a = DynamicArena::new(1000, CompactPolicy::Never);
        let b1 = a.alloc(100).unwrap();
        let b2 = a.alloc(100).unwrap();
        let b3 = a.alloc(100).unwrap();
        a.write(b1, &vec![1u8; 100]).unwrap();
        a.write(b2, &vec![2u8; 100]).unwrap();
        a.write(b3, &vec![3u8; 100]).unwrap();
        a.free(b2).unwrap();
        a.compact();
        assert_eq!(a.offset_of(b1).unwrap(), 0);
        assert_eq!(a.offset_of(b3).unwrap(), 100);
        assert_eq!(a.get(b1).unwrap(), &vec![1u8; 100][..]);
        assert_eq!(a.get(b3).unwrap(), &vec![3u8; 100][..]);
        assert_eq!(a.stats().bytes_moved, 100); // only b3 moved
        a.check_invariants().unwrap();
    }

    #[test]
    fn fragmentation_fails_without_compaction_but_succeeds_with() {
        // [100 live][100 freed][100 live][hole 100]: request 200 needs
        // compaction.
        let build = |policy| {
            let mut a = DynamicArena::new(400, policy);
            let b1 = a.alloc(100).unwrap();
            let b2 = a.alloc(100).unwrap();
            let b3 = a.alloc(100).unwrap();
            let _ = (b1, b3);
            a.free(b2).unwrap();
            a
        };
        let mut frozen = build(CompactPolicy::Never);
        match frozen.alloc(200) {
            Err(AllocError::Fragmented { largest_hole, .. }) => assert_eq!(largest_hole, 100),
            other => panic!("expected Fragmented, got {other:?}"),
        }
        let mut demand = build(CompactPolicy::OnDemand);
        let b = demand.alloc(200).unwrap();
        assert_eq!(demand.offset_of(b).unwrap(), 200);
        demand.check_invariants().unwrap();
    }

    #[test]
    fn out_of_memory_reported() {
        let mut a = DynamicArena::new(100, CompactPolicy::EveryOp);
        let _ = a.alloc(60).unwrap();
        match a.alloc(50) {
            Err(AllocError::OutOfMemory { requested, free, capacity }) => {
                assert_eq!((requested, free, capacity), (50, 40, 100));
            }
            other => panic!("expected OOM, got {other:?}"),
        }
    }

    #[test]
    fn zero_length_allocs_are_fine() {
        let mut a = DynamicArena::new(10, CompactPolicy::EveryOp);
        let z = a.alloc(0).unwrap();
        assert_eq!(a.get(z).unwrap().len(), 0);
        a.free(z).unwrap();
    }

    #[test]
    fn high_water_tracks_peak_live() {
        let mut a = DynamicArena::new(1000, CompactPolicy::EveryOp);
        let b1 = a.alloc(300).unwrap();
        let b2 = a.alloc(400).unwrap();
        a.free(b1).unwrap();
        let _b3 = a.alloc(200).unwrap();
        let _ = b2;
        assert_eq!(a.stats().high_water, 700);
    }

    #[test]
    fn prop_random_workload_never_overlaps() {
        prop::check("arena-invariants", 80, |rng| {
            let cap = 4096;
            let policy = *rng.pick(&[
                CompactPolicy::EveryOp,
                CompactPolicy::OnDemand,
                CompactPolicy::Never,
            ]);
            let mut a = DynamicArena::new(cap, policy);
            let mut live: Vec<(BufId, u8, usize)> = Vec::new();
            let mut stamp = 0u8;
            for _ in 0..200 {
                if live.is_empty() || rng.chance(0.6) {
                    let len = rng.range(1, 300);
                    match a.alloc(len) {
                        Ok(id) => {
                            stamp = stamp.wrapping_add(1);
                            a.write(id, &vec![stamp; len]).unwrap();
                            live.push((id, stamp, len));
                        }
                        Err(AllocError::OutOfMemory { .. })
                        | Err(AllocError::Fragmented { .. }) => {}
                        Err(e) => panic!("unexpected alloc error {e:?}"),
                    }
                } else {
                    let i = rng.range(0, live.len());
                    let (id, _, _) = live.swap_remove(i);
                    a.free(id).unwrap();
                }
                if rng.chance(0.2) {
                    a.after_op();
                }
                a.check_invariants().unwrap();
                // Contents survive arbitrary compaction.
                for &(id, stamp, len) in &live {
                    assert_eq!(a.get(id).unwrap(), &vec![stamp; len][..]);
                }
            }
        });
    }

    #[test]
    fn every_op_policy_keeps_address_high_water_at_live_peak() {
        let mut a = DynamicArena::new(2048, CompactPolicy::EveryOp);
        let b1 = a.alloc(500).unwrap();
        a.after_op();
        let b2 = a.alloc(500).unwrap();
        a.free(b1).unwrap();
        a.after_op();
        let _b3 = a.alloc(500).unwrap();
        let _ = b2;
        a.after_op();
        // With compaction after every op, addresses never exceed the live
        // peak (1000).
        assert_eq!(a.stats().address_high_water, 1000);
    }
}

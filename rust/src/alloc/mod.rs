//! SRAM arena allocation strategies (§4, §6 of the paper).
//!
//! The paper replaces TensorFlow Lite Micro's (then) static pre-allocation
//! of *all* tensor buffers with a dynamic allocator that reclaims dead
//! tensors and defragments by compaction after every operator. Because the
//! micro-interpreter addresses buffers through a handle table rather than
//! raw pointers, live buffers can be moved freely.
//!
//! Three strategies are provided:
//!
//! - [`DynamicArena`] — the paper's allocator: first-fit free list +
//!   post-operator compaction ([`CompactPolicy::EveryOp`]), or compaction
//!   only when an allocation would otherwise fail
//!   ([`CompactPolicy::OnDemand`], ablation), or never
//!   ([`CompactPolicy::Never`], shows fragmentation failures).
//! - [`StaticPlan::no_reuse`] — old TFLM behaviour: every tensor gets a
//!   distinct offset; needs `sum(all tensor bytes)` of SRAM (Table 1's
//!   "Static alloc." column).
//! - [`StaticPlan::best_fit`] — §6's "optimal tensor buffer placement may be
//!   precomputed": offline lifetime-aware offset assignment (greedy
//!   best-fit-decreasing over lifetime intervals), used to ablate how close
//!   run-time compaction gets to an offline plan.

mod arena;
mod planner;

pub use arena::{AllocError, AllocStats, BufId, CompactPolicy, DynamicArena};
pub use planner::{plan_lifetimes, storage_roots, Lifetime, StaticPlan};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, GraphBuilder};
    use crate::sched::simulate;

    /// End-to-end sanity: replay the Figure-1 default schedule through the
    /// dynamic arena and confirm its high-water mark equals the analytic
    /// peak from the scheduler.
    #[test]
    fn arena_high_water_matches_simulated_peak() {
        let g = crate::sched::tests::figure1_graph();
        let order = g.default_order();
        let trace = simulate(&g, &order);

        let mut arena = DynamicArena::new(64 * 1024, CompactPolicy::EveryOp);
        let n = g.tensors.len();
        let mut handles: Vec<Option<BufId>> = vec![None; n];
        let mut remaining = vec![0usize; n];
        for op in &g.ops {
            for &t in &op.inputs {
                remaining[t] += 1;
            }
        }
        // Graph inputs allocated up front.
        for &t in &g.inputs {
            handles[t] = Some(arena.alloc(g.tensors[t].bytes()).unwrap());
        }
        for &opid in &order {
            let op = &g.ops[opid];
            handles[op.output] = Some(arena.alloc(g.tensors[op.output].bytes()).unwrap());
            for &t in &op.inputs {
                remaining[t] -= 1;
                if remaining[t] == 0 && !g.outputs.contains(&t) {
                    arena.free(handles[t].take().unwrap());
                }
            }
            arena.after_op();
        }
        assert_eq!(arena.stats().high_water, trace.peak_bytes);
    }

    /// The no-reuse static plan needs exactly the activation total; the
    /// lifetime-aware plan needs no more than that and at least the peak.
    #[test]
    fn planner_bounds() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", &[1, 16, 16, 4], DType::I8);
        let c1 = b.conv2d(
            "c1",
            x,
            8,
            (3, 3),
            (2, 2),
            crate::graph::Padding::Same,
            crate::graph::Act::Linear,
        );
        let l = b.dwconv2d(
            "dw",
            c1,
            (3, 3),
            (1, 1),
            crate::graph::Padding::Same,
            crate::graph::Act::Linear,
        );
        let r = b.conv2d(
            "pw",
            c1,
            8,
            (1, 1),
            (1, 1),
            crate::graph::Padding::Same,
            crate::graph::Act::Linear,
        );
        let cat = b.concat("cat", &[l, r]);
        b.output(cat);
        let g = b.finish().unwrap();
        let order = g.default_order();
        let peak = simulate(&g, &order).peak_bytes;

        let no_reuse = StaticPlan::no_reuse(&g);
        assert_eq!(no_reuse.arena_bytes, g.activation_total());

        let planned = StaticPlan::best_fit(&g, &order);
        assert!(planned.arena_bytes >= peak, "plan below working-set peak");
        assert!(planned.arena_bytes <= no_reuse.arena_bytes);
        planned.check_no_overlap(&g, &order).unwrap();
    }
}

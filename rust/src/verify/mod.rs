//! Proof-carrying plans: an independent static verifier for schedules,
//! arenas, split rewrites and exported flatbuffers.
//!
//! Every claim the planning pipeline makes — "this order fits the budget",
//! "these slots never collide", "these slices reassemble the tensor" — is
//! backed, everywhere else in the crate, by the same accounting code that
//! produced the plan. On a microcontroller an aliasing or halo bug is not
//! a test failure, it is silent memory corruption; a deployable artifact
//! needs a checker that shares no code with the planner. This module is
//! that checker: it re-derives tensor lifetimes, residency, storage
//! sharing, band geometry and quantization flow **from the graph alone**,
//! with its own interval arithmetic, and never calls into
//! [`crate::sched`]'s simulation ([`crate::sched::simulate`] /
//! [`crate::sched::peak_of`] / [`crate::sched::elided_accumulators`]) or
//! [`crate::alloc`]'s lifetime/overlap accounting
//! ([`crate::alloc::StaticPlan::check_no_overlap`]). Plans constructed by
//! those modules are *inputs* here, never oracles.
//!
//! Five property families are proven into a [`PlanCertificate`]:
//!
//! 1. **Schedule legality** — the execution order is a permutation and a
//!    topological sort; every tensor's lifetime interval is consistent
//!    with its producer and consumers; the peak the planner claims equals
//!    the peak recomputed here.
//! 2. **Arena soundness** — every placed slot is in-bounds; no two
//!    simultaneously-live slots overlap; buffer aliasing is permitted
//!    only along `PartialInto` accumulator chains whose write bands are
//!    pairwise disjoint.
//! 3. **Split-rewrite soundness** — per-axis bands exactly tile the
//!    original tensor (no gap, no double-cover); halo slabs cover exactly
//!    the receptive field of their band intersected with the real input;
//!    channel/feature splits stay within the weight partition.
//! 4. **Quant/domain consistency** — the importer's int8 qparams flow
//!    rules (domain-preserving kernels keep their input's quantization,
//!    softmax writes scale 1/256 zp −128, scales finite and positive)
//!    re-checked on the (possibly rewritten) graph.
//! 5. **Export invariants** — the embedded operator order is a bijection
//!    onto the file's operators, and an exported flatbuffer differs from
//!    its source by an operator permutation only (buffers byte-identical).
//!
//! Rejections carry a distinct `family/code` pair plus a precise message,
//! exercised corruption-by-corruption in `rust/tests/integration_verify.rs`.

use std::collections::HashMap;

use crate::alloc::StaticPlan;
use crate::graph::{axis_dim_of, Graph, Op, OpId, OpKind, Padding, SplitAxis, TensorId};
use crate::interp::quant::QuantParams;
use crate::tflite::Model;
use crate::util::json::Json;

/// A verification failure: which property family failed, a stable
/// machine-readable code (one per corruption class), and a precise
/// human-readable diagnostic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VerifyError {
    pub family: &'static str,
    pub code: &'static str,
    pub msg: String,
}

impl std::fmt::Display for VerifyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "plan verification failed [{}/{}]: {}", self.family, self.code, self.msg)
    }
}

impl std::error::Error for VerifyError {}

fn fail(family: &'static str, code: &'static str, msg: impl Into<String>) -> VerifyError {
    VerifyError { family, code, msg: msg.into() }
}

/// One passed (or skipped) property family in a certificate.
#[derive(Clone, Debug)]
pub struct Check {
    pub family: &'static str,
    /// `"ok"` or `"skipped"` (a family that does not apply to this
    /// artifact — e.g. no split plan, no quantization).
    pub status: &'static str,
    pub detail: String,
}

impl Check {
    fn ok(family: &'static str, detail: impl Into<String>) -> Check {
        Check { family, status: "ok", detail: detail.into() }
    }

    fn skipped(family: &'static str, detail: impl Into<String>) -> Check {
        Check { family, status: "skipped", detail: detail.into() }
    }
}

/// The proof object: everything the verifier established about a plan.
/// Serialized (deterministically) next to the plan it certifies.
#[derive(Clone, Debug)]
pub struct PlanCertificate {
    pub model: String,
    pub content_hash: u64,
    pub n_ops: usize,
    pub n_tensors: usize,
    /// The best execution order that was verified (split schedule when a
    /// split plan was checked, the reorder-only optimum otherwise).
    pub order: Vec<OpId>,
    /// Peak working set of that order, recomputed independently here.
    pub peak_bytes: usize,
    /// Best-fit arena the verified placement needs.
    pub arena_bytes: usize,
    pub checks: Vec<Check>,
}

impl PlanCertificate {
    /// Deterministic JSON encoding (BTreeMap-backed object keys).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("content_hash", Json::Str(format!("{:016x}", self.content_hash))),
            ("n_ops", Json::Num(self.n_ops as f64)),
            ("n_tensors", Json::Num(self.n_tensors as f64)),
            ("order", Json::arr_usize(&self.order)),
            ("peak_bytes", Json::Num(self.peak_bytes as f64)),
            ("arena_bytes", Json::Num(self.arena_bytes as f64)),
            ("verified", Json::Bool(true)),
            (
                "checks",
                Json::Arr(
                    self.checks
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("family", Json::Str(c.family.to_string())),
                                ("status", Json::Str(c.status.to_string())),
                                ("detail", Json::Str(c.detail.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Human rendering for the `verify` CLI.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "verified: {} (hash {:016x}, {} ops, {} tensors)\n",
            self.model, self.content_hash, self.n_ops, self.n_tensors
        ));
        out.push_str(&format!(
            "peak {} B (recomputed independently), best-fit arena {} B\n",
            self.peak_bytes, self.arena_bytes
        ));
        for c in &self.checks {
            out.push_str(&format!("  {:<9} {:<8} {}\n", c.family, c.status, c.detail));
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Family 1: schedule legality (own interval/lifetime engine).
// ---------------------------------------------------------------------------

/// Independently derived facts about one `(graph, order)` pair: step
/// positions, lifetime intervals, storage-sharing roots and the peak.
/// This is the verifier's own computation — intentionally parallel to,
/// and sharing nothing with, `sched::simulate`/`alloc::plan_lifetimes`.
pub struct ScheduleFacts {
    /// `pos[op]` — the step at which `op` executes.
    pub pos: Vec<usize>,
    /// First step (inclusive) each tensor occupies SRAM.
    pub start: Vec<usize>,
    /// Last step (inclusive) each tensor occupies SRAM.
    pub end: Vec<usize>,
    /// Activation tensors that occupy SRAM at all (weights are
    /// flash-resident and never counted).
    pub counted: Vec<bool>,
    /// Storage-sharing representative: tensors along a `PartialInto`
    /// accumulator chain share one buffer and resolve to one root.
    pub root: Vec<TensorId>,
    /// Peak working set over all steps, from the interval model.
    pub peak_bytes: usize,
}

impl ScheduleFacts {
    /// Resolve a tensor to its storage-sharing root.
    pub fn find(&self, mut t: TensorId) -> TensorId {
        while self.root[t] != t {
            t = self.root[t];
        }
        t
    }
}

/// The verifier's own accumulator-eligibility rule (mirrors the written
/// contract of the scheduler, re-derived from the graph): a `PartialInto`
/// writes through its second input's buffer iff that tensor is consumed
/// exactly once as an activation input, is not a graph output, and has
/// the same byte size as the op's output.
fn accumulator_of(g: &Graph, op: &Op) -> Option<TensorId> {
    if !matches!(op.kind, OpKind::PartialInto { .. }) {
        return None;
    }
    let acc = *op.inputs.get(1)?;
    let reads =
        g.tensors[acc].consumers.iter().filter(|&&c| g.ops[c].inputs.contains(&acc)).count();
    let same_bytes = g.tensors[acc].bytes() == g.tensors[op.output].bytes();
    (reads == 1 && !g.outputs.contains(&acc) && same_bytes).then_some(acc)
}

/// Prove that `order` is a legal schedule of `g` and derive lifetime
/// facts: a permutation of the ops, topologically sorted, with every
/// tensor's interval spanning producer → last consumer (graph inputs from
/// step 0, graph outputs to the final step).
pub fn verify_schedule(g: &Graph, order: &[OpId]) -> Result<ScheduleFacts, VerifyError> {
    const FAM: &str = "schedule";
    let n = g.ops.len();
    if order.len() != n {
        return Err(fail(
            FAM,
            "order-length",
            format!("order has {} entries but the graph has {} ops", order.len(), n),
        ));
    }
    let mut pos = vec![usize::MAX; n];
    for (p, &o) in order.iter().enumerate() {
        if o >= n {
            return Err(fail(FAM, "order-out-of-range", format!("op id {o} out of range (ops 0..{n})")));
        }
        if pos[o] != usize::MAX {
            return Err(fail(
                FAM,
                "order-duplicate",
                format!("op {} ({o}) appears at steps {} and {p}", g.ops[o].name, pos[o]),
            ));
        }
        pos[o] = p;
    }
    for (p, &o) in order.iter().enumerate() {
        let op = &g.ops[o];
        for &t in op.inputs.iter().chain(&op.weights) {
            if let Some(prod) = g.tensors[t].producer {
                if pos[prod] > p {
                    return Err(fail(
                        FAM,
                        "order-not-topological",
                        format!(
                            "op {} (step {p}) reads {} before its producer {} runs (step {})",
                            op.name, g.tensors[t].name, g.ops[prod].name, pos[prod]
                        ),
                    ));
                }
            }
        }
    }

    // Storage-sharing roots along accumulator chains, walked in schedule
    // order so every chain resolves forward to its first buffer.
    let mut root: Vec<TensorId> = (0..g.tensors.len()).collect();
    let find = |root: &[TensorId], mut t: TensorId| {
        while root[t] != t {
            t = root[t];
        }
        t
    };
    for &o in order {
        if let Some(acc) = accumulator_of(g, &g.ops[o]) {
            root[g.ops[o].output] = find(&root, acc);
        }
    }

    // Lifetime intervals (inclusive): producer step (or 0 for graph
    // inputs) → last activation consumer (or the final step for outputs).
    let mut start = vec![0usize; g.tensors.len()];
    let mut end = vec![0usize; g.tensors.len()];
    let mut counted = vec![false; g.tensors.len()];
    for t in &g.tensors {
        if t.is_weight {
            continue;
        }
        let is_input = g.inputs.contains(&t.id);
        let s = match t.producer {
            Some(p) => pos[p],
            None if is_input => 0,
            None => continue, // dangling activation: unreachable in a validated graph
        };
        let mut e = s;
        for &c in &t.consumers {
            if g.ops[c].inputs.contains(&t.id) {
                e = e.max(pos[c]);
            }
        }
        if g.outputs.contains(&t.id) {
            e = n.saturating_sub(1);
        }
        counted[t.id] = true;
        start[t.id] = if is_input { 0 } else { s };
        end[t.id] = e;
    }

    // Peak: one contribution per storage group (chains share a buffer),
    // over the union of member intervals, via a step-indexed diff array.
    let mut groups: HashMap<TensorId, (usize, usize, usize)> = HashMap::new();
    for t in 0..g.tensors.len() {
        if !counted[t] {
            continue;
        }
        let r = find(&root, t);
        let bytes = g.tensors[r].bytes();
        let e = groups.entry(r).or_insert((bytes, start[t], end[t]));
        e.1 = e.1.min(start[t]);
        e.2 = e.2.max(end[t]);
    }
    let mut delta = vec![0i64; n + 1];
    for (bytes, s, e) in groups.values() {
        delta[*s] += *bytes as i64;
        delta[e + 1] -= *bytes as i64;
    }
    let mut cur = 0i64;
    let mut peak = 0i64;
    for d in &delta[..n] {
        cur += d;
        peak = peak.max(cur);
    }

    Ok(ScheduleFacts { pos, start, end, counted, root, peak_bytes: peak as usize })
}

/// Prove a claimed peak equals the independently recomputed one.
pub fn verify_peak(
    g: &Graph,
    order: &[OpId],
    claimed: usize,
    what: &str,
) -> Result<ScheduleFacts, VerifyError> {
    let facts = verify_schedule(g, order)?;
    if facts.peak_bytes != claimed {
        return Err(fail(
            "schedule",
            "peak-mismatch",
            format!(
                "{what}: planner claims a {claimed} B peak but the verifier recomputes {} B",
                facts.peak_bytes
            ),
        ));
    }
    Ok(facts)
}

// ---------------------------------------------------------------------------
// Family 2: arena soundness.
// ---------------------------------------------------------------------------

/// Prove a static placement sound against independently derived lifetimes:
/// every counted tensor has an in-bounds slot, simultaneously-live slots
/// never overlap, and aliasing is permitted only along accumulator chains
/// with pairwise-disjoint write bands.
pub fn verify_arena(
    g: &Graph,
    facts: &ScheduleFacts,
    plan: &StaticPlan,
) -> Result<(), VerifyError> {
    const FAM: &str = "arena";
    let live: Vec<TensorId> = (0..g.tensors.len()).filter(|&t| facts.counted[t]).collect();
    for &t in &live {
        let Some(&off) = plan.offsets.get(&t) else {
            return Err(fail(
                FAM,
                "slot-missing",
                format!("tensor {} has no slot in the {} plan", g.tensors[t].name, plan.strategy),
            ));
        };
        let bytes = g.tensors[t].bytes();
        if off + bytes > plan.arena_bytes {
            return Err(fail(
                FAM,
                "slot-out-of-bounds",
                format!(
                    "tensor {} at [{off}, {}) exceeds the {} B arena",
                    g.tensors[t].name,
                    off + bytes,
                    plan.arena_bytes
                ),
            ));
        }
    }
    for (i, &a) in live.iter().enumerate() {
        for &b in &live[i + 1..] {
            let time = facts.start[a] <= facts.end[b] && facts.start[b] <= facts.end[a];
            if !time {
                continue;
            }
            let (oa, ob) = (plan.offsets[&a], plan.offsets[&b]);
            let (ba, bb) = (g.tensors[a].bytes(), g.tensors[b].bytes());
            let space = oa < ob + bb && ob < oa + ba;
            if !space {
                continue;
            }
            let (na, nb) = (&g.tensors[a].name, &g.tensors[b].name);
            if facts.find(a) == facts.find(b) {
                if oa != ob || ba != bb {
                    return Err(fail(
                        FAM,
                        "alias-misaligned",
                        format!(
                            "chain-sharing tensors {na} and {nb} alias partially \
                             ([{oa}, {}) vs [{ob}, {})) — a shared buffer must coincide exactly",
                            oa + ba,
                            ob + bb
                        ),
                    ));
                }
            } else if oa == ob && ba == bb {
                return Err(fail(
                    FAM,
                    "alias-without-chain",
                    format!(
                        "tensors {na} and {nb} share slot [{oa}, {}) while both live \
                         (steps {}..={} vs {}..={}) but are not on an accumulator chain",
                        oa + ba,
                        facts.start[a],
                        facts.end[a],
                        facts.start[b],
                        facts.end[b]
                    ),
                ));
            } else {
                return Err(fail(
                    FAM,
                    "slot-overlap",
                    format!(
                        "slots [{oa}, {}) ({na}) and [{ob}, {}) ({nb}) overlap while both \
                         live (steps {}..={} vs {}..={})",
                        oa + ba,
                        ob + bb,
                        facts.start[a],
                        facts.end[a],
                        facts.start[b],
                        facts.end[b]
                    ),
                ));
            }
        }
    }

    // Aliasing legality along chains: every writer into a shared buffer
    // must band a distinct, disjoint region along one axis.
    let mut chains: HashMap<TensorId, Vec<(&Op, SplitAxis, usize, usize)>> = HashMap::new();
    for op in &g.ops {
        if let OpKind::PartialInto { axis, offset, len, .. } = op.kind {
            // Group writers by storage root: a non-sharing PartialInto is
            // its own root (group of one, skipped below), so only genuine
            // chains are band-checked.
            chains.entry(facts.find(op.output)).or_default().push((op, axis, offset, len));
        }
    }
    for (r, mut writers) in chains {
        if writers.len() < 2 {
            continue;
        }
        let axis = writers[0].1;
        if writers.iter().any(|w| w.1 != axis) {
            return Err(fail(
                "arena",
                "alias-band-overlap",
                format!(
                    "accumulator chain rooted at {} mixes write axes — bands are not comparable",
                    g.tensors[r].name
                ),
            ));
        }
        writers.sort_by_key(|w| w.2);
        for pair in writers.windows(2) {
            let (pa, pb) = (&pair[0], &pair[1]);
            if pa.2 + pa.3 > pb.2 {
                return Err(fail(
                    "arena",
                    "alias-band-overlap",
                    format!(
                        "chain writers {} ([{}, {})) and {} ([{}, {})) write overlapping \
                         bands of one shared buffer",
                        pa.0.name,
                        pa.2,
                        pa.2 + pa.3,
                        pb.0.name,
                        pb.2,
                        pb.2 + pb.3
                    ),
                ));
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Family 3: split-rewrite soundness.
// ---------------------------------------------------------------------------

/// The verifier's own tap geometry. `Same` padding recomputed from the
/// full (unsplit) extents exactly as a framework defines it.
fn leading_pad(n_in: usize, k: usize, stride: usize, padding: Padding, n_out: usize) -> usize {
    match padding {
        Padding::Valid => 0,
        Padding::Same => ((n_out - 1) * stride + k).saturating_sub(n_in) / 2,
    }
}

fn extent(shape: &[usize], axis: SplitAxis) -> usize {
    shape[axis_dim_of(shape, axis)]
}

/// Prove a split/elided rewrite sound against the graph it was derived
/// from: bands tile exactly, halo slabs cover the receptive field of
/// their band, slab shapes and weight partitions agree with provenance.
pub fn verify_split(
    original: &Graph,
    g: &Graph,
    sources: &[TensorId],
) -> Result<(), VerifyError> {
    const FAM: &str = "split";
    if sources.len() != g.tensors.len() {
        return Err(fail(
            FAM,
            "provenance-length",
            format!("{} provenance entries for {} tensors", sources.len(), g.tensors.len()),
        ));
    }
    for (t, &src) in sources.iter().enumerate() {
        if src >= original.tensors.len() {
            return Err(fail(
                FAM,
                "provenance-length",
                format!("tensor {} maps to out-of-range source {src}", g.tensors[t].name),
            ));
        }
    }

    // Write-through bands, grouped by the original tensor they tile.
    let mut into_bands: HashMap<TensorId, Vec<(&Op, SplitAxis, usize, usize)>> = HashMap::new();
    for op in &g.ops {
        match &op.kind {
            OpKind::Partial { inner, axis, pad, offset } => {
                let len = extent(&g.tensors[op.output].shape, *axis);
                check_slab_shape(original, g, sources, op, *axis, Some(len))?;
                check_slice_geometry(original, g, sources, op, inner, *axis, *pad, *offset, len)?;
            }
            OpKind::PartialInto { inner, axis, pad, offset, len } => {
                check_slab_shape(original, g, sources, op, *axis, None)?;
                check_slice_geometry(original, g, sources, op, inner, *axis, *pad, *offset, *len)?;
                into_bands
                    .entry(sources[op.output])
                    .or_default()
                    .push((op, *axis, *offset, *len));
            }
            OpKind::ConcatSlices { axis } => {
                let join = &g.tensors[op.output];
                let want = extent(&join.shape, *axis);
                let d = axis_dim_of(&join.shape, *axis);
                let mut covered = 0usize;
                for &s in &op.inputs {
                    let slab = &g.tensors[s];
                    if slab.shape.len() != join.shape.len()
                        || slab
                            .shape
                            .iter()
                            .enumerate()
                            .any(|(i, &v)| i != d && v != join.shape[i])
                    {
                        return Err(fail(
                            FAM,
                            "concat-cover",
                            format!(
                                "slab {} shape {:?} does not band join {} shape {:?} along {}",
                                slab.name,
                                slab.shape,
                                join.name,
                                join.shape,
                                axis.name()
                            ),
                        ));
                    }
                    covered += slab.shape[d];
                }
                if covered != want {
                    return Err(fail(
                        FAM,
                        "concat-cover",
                        format!(
                            "slabs of {} cover {covered} of {want} {} — the join does not \
                             reassemble the tensor",
                            join.name,
                            axis.name()
                        ),
                    ));
                }
            }
            _ => {}
        }
    }

    // Each chain of write-through slices must tile its original tensor
    // exactly: start at 0, contiguous, end at the full extent.
    for (src, mut bands) in into_bands {
        let axis = bands[0].1;
        let want = extent(&original.tensors[src].shape, axis);
        bands.sort_by_key(|b| b.2);
        let mut at = 0usize;
        for (op, _, offset, len) in &bands {
            if *offset > at {
                return Err(fail(
                    FAM,
                    "band-gap",
                    format!(
                        "write-through bands of {} leave [{at}, {offset}) uncovered \
                         (next writer {})",
                        original.tensors[src].name, op.name
                    ),
                ));
            }
            if *offset < at {
                return Err(fail(
                    FAM,
                    "band-overlap",
                    format!(
                        "write-through band [{offset}, {}) of {} double-covers [{offset}, {at}) \
                         of {}",
                        offset + len,
                        op.name,
                        original.tensors[src].name
                    ),
                ));
            }
            at = offset + len;
        }
        if at != want {
            return Err(fail(
                FAM,
                "band-extent",
                format!(
                    "write-through bands of {} cover {at} of {want} {}",
                    original.tensors[src].name,
                    axis.name()
                ),
            ));
        }
    }
    Ok(())
}

/// Slab shapes must band their source: a `Partial` output is the source
/// shape with the axis dim replaced by the band length; a `PartialInto`
/// output carries the source's full shape (it *is* the shared buffer).
fn check_slab_shape(
    original: &Graph,
    g: &Graph,
    sources: &[TensorId],
    op: &Op,
    axis: SplitAxis,
    band_len: Option<usize>,
) -> Result<(), VerifyError> {
    let out = &g.tensors[op.output];
    let src = &original.tensors[sources[op.output]];
    let mut want = src.shape.clone();
    if let Some(len) = band_len {
        let d = axis_dim_of(&want, axis);
        want[d] = len;
    }
    if out.shape != want || out.dtype != src.dtype {
        return Err(fail(
            "split",
            "slab-shape",
            format!(
                "slice {} output {} has shape {:?} ({}), want {:?} ({}) from source {}",
                op.name,
                out.name,
                out.shape,
                out.dtype.name(),
                want,
                src.dtype.name(),
                src.name
            ),
        ));
    }
    Ok(())
}

/// Halo/receptive-field soundness of one slice op: the input slab it
/// reads must hold exactly the real elements its output band taps, and
/// the recorded effective padding must place the slab correctly within
/// the full input.
#[allow(clippy::too_many_arguments)]
fn check_slice_geometry(
    original: &Graph,
    g: &Graph,
    sources: &[TensorId],
    op: &Op,
    inner: &OpKind,
    axis: SplitAxis,
    pad_rec: isize,
    offset: usize,
    len: usize,
) -> Result<(), VerifyError> {
    const FAM: &str = "split";
    let in_slab = &g.tensors[op.inputs[0]];
    let in_full = &original.tensors[sources[op.inputs[0]]];
    let out_full = &original.tensors[sources[op.output]];
    let slab_len = extent(&in_slab.shape, axis);
    let n_in = extent(&in_full.shape, axis);
    let n_out = extent(&out_full.shape, axis);

    if offset + len > n_out {
        return Err(fail(
            FAM,
            "band-extent",
            format!(
                "slice {} band [{offset}, {}) exceeds the {n_out} output {} of {}",
                op.name,
                offset + len,
                axis.name(),
                out_full.name
            ),
        ));
    }

    if axis == SplitAxis::Channels {
        return match inner {
            // Projection heads read the full input and band the weight
            // columns; the band must stay within the weight partition.
            OpKind::Conv2D { .. } | OpKind::Dense { .. } => {
                if slab_len != n_in || pad_rec != 0 {
                    return Err(fail(
                        FAM,
                        "halo-mismatch",
                        format!(
                            "channel projection {} must read its full input ({n_in} channels, \
                             pad 0) but reads {slab_len} with pad {pad_rec}",
                            op.name
                        ),
                    ));
                }
                let w = op.weights.first().map(|&w| &g.tensors[w]);
                if let Some(w) = w {
                    let cout = *w.shape.last().unwrap_or(&0);
                    if offset + len > cout {
                        return Err(fail(
                            FAM,
                            "weight-partition",
                            format!(
                                "slice {} selects weight columns [{offset}, {}) of {} but {} \
                                 has only {cout}",
                                op.name,
                                offset + len,
                                w.name,
                                w.name
                            ),
                        ));
                    }
                }
                Ok(())
            }
            // Channel-parallel ops map a channel band 1:1; no halo.
            OpKind::DepthwiseConv2D { .. }
            | OpKind::MaxPool2D { .. }
            | OpKind::AvgPool2D { .. }
            | OpKind::Relu
            | OpKind::Relu6
            | OpKind::BatchNorm { .. } => {
                if slab_len != len || pad_rec != 0 {
                    return Err(fail(
                        FAM,
                        "halo-mismatch",
                        format!(
                            "channel-parallel slice {} writes {len} channels but reads \
                             {slab_len} (pad {pad_rec}); channel bands map 1:1",
                            op.name
                        ),
                    ));
                }
                Ok(())
            }
            other => Err(fail(
                FAM,
                "slice-kind",
                format!("op {} ({}) cannot be sliced along channels", op.name, other.name()),
            )),
        };
    }

    match inner {
        OpKind::Conv2D { kernel, stride, padding, .. }
        | OpKind::DepthwiseConv2D { kernel, stride, padding, .. }
        | OpKind::MaxPool2D { kernel, stride, padding }
        | OpKind::AvgPool2D { kernel, stride, padding } => {
            let pick = |p: (usize, usize)| if axis == SplitAxis::Rows { p.0 } else { p.1 };
            let (k, s) = (pick(*kernel), pick(*stride));
            let pad_full = leading_pad(n_in, k, s, *padding, n_out) as isize;
            // The effective padding encodes where the slab starts in the
            // full input: pad_rec = pad_full + in_start − offset·stride.
            let in_start = pad_rec - pad_full + (offset * s) as isize;
            let in_end = in_start + slab_len as isize;
            // Taps of the band, clamped to the real input: everything the
            // full operator would read outside [0, n_in) is zero padding.
            let lo = ((offset * s) as isize - pad_full).clamp(0, n_in as isize);
            let hi = (((offset + len - 1) * s + k) as isize - pad_full).clamp(0, n_in as isize);
            if in_start < 0 || in_end > n_in as isize || lo < in_start || hi > in_end {
                return Err(fail(
                    FAM,
                    "halo-mismatch",
                    format!(
                        "slice {} band [{offset}, {}) needs input rows [{lo}, {hi}) of {} \
                         but its slab holds [{in_start}, {in_end}) (pad {pad_rec}, \
                         full-geometry pad {pad_full})",
                        op.name,
                        offset + len,
                        in_full.name
                    ),
                ));
            }
            Ok(())
        }
        OpKind::Relu | OpKind::Relu6 | OpKind::BatchNorm { .. } => {
            if pad_rec != 0 || slab_len != len {
                return Err(fail(
                    FAM,
                    "halo-mismatch",
                    format!(
                        "pointwise slice {} writes {len} {} but reads {slab_len} (pad \
                         {pad_rec}); pointwise bands map 1:1",
                        op.name,
                        axis.name()
                    ),
                ));
            }
            Ok(())
        }
        other => Err(fail(
            FAM,
            "slice-kind",
            format!(
                "op {} ({}) cannot be sliced along {}",
                op.name,
                other.name(),
                axis.name()
            ),
        )),
    }
}

// ---------------------------------------------------------------------------
// Family 4: quant/domain consistency.
// ---------------------------------------------------------------------------

/// Re-check the importer's int8 quantization flow rules on a (possibly
/// rewritten) graph: scales finite and positive, domain-preserving
/// kernels keep their input's qparams, slices and joins share their
/// source's domain, softmax writes the conventional i8 domain.
pub fn verify_quant(
    g: &Graph,
    qparams: &HashMap<TensorId, QuantParams>,
) -> Result<(), VerifyError> {
    const FAM: &str = "quant";
    if qparams.is_empty() {
        return Ok(());
    }
    for (&t, q) in qparams {
        if !(q.scale.is_finite() && q.scale > 0.0) {
            return Err(fail(
                FAM,
                "qparams-scale",
                format!(
                    "tensor {} has a non-positive/non-finite scale {}",
                    g.tensors.get(t).map(|t| t.name.as_str()).unwrap_or("?"),
                    q.scale
                ),
            ));
        }
    }
    let same = |a: TensorId, b: TensorId, what: &str| -> Result<(), VerifyError> {
        match (qparams.get(&a), qparams.get(&b)) {
            (Some(x), Some(y)) if x != y => Err(fail(
                FAM,
                "qparams-mismatch",
                format!(
                    "{what}: output {} (scale {}, zp {}) must keep the input {}'s domain \
                     (scale {}, zp {})",
                    g.tensors[b].name,
                    y.scale,
                    y.zero_point,
                    g.tensors[a].name,
                    x.scale,
                    x.zero_point
                ),
            )),
            (Some(_), None) | (None, Some(_)) => Err(fail(
                FAM,
                "qparams-missing",
                format!(
                    "{what}: one of {} / {} is quantized and the other is not",
                    g.tensors[a].name, g.tensors[b].name
                ),
            )),
            _ => Ok(()),
        }
    };
    for op in &g.ops {
        let inner = match &op.kind {
            OpKind::Partial { inner, .. } | OpKind::PartialInto { inner, .. } => inner.as_ref(),
            k => k,
        };
        match inner {
            OpKind::MaxPool2D { .. } | OpKind::GlobalAvgPool | OpKind::Relu | OpKind::Relu6
            | OpKind::Reshape => {
                same(op.inputs[0], op.output, inner.name())?;
            }
            OpKind::Softmax => {
                if g.tensors[op.output].dtype == crate::graph::DType::I8 {
                    match qparams.get(&op.output) {
                        Some(q) if (q.scale, q.zero_point) == (1.0 / 256.0, -128) => {}
                        Some(q) => {
                            return Err(fail(
                                FAM,
                                "qparams-softmax",
                                format!(
                                    "softmax {} output domain (scale {}, zp {}) must be \
                                     scale 1/256, zp -128",
                                    op.name, q.scale, q.zero_point
                                ),
                            ))
                        }
                        None => {
                            return Err(fail(
                                FAM,
                                "qparams-missing",
                                format!("i8 softmax {} output has no quantization", op.name),
                            ))
                        }
                    }
                }
            }
            _ => {}
        }
        // Slices of one tensor share one domain: a join reassembles its
        // slabs bit-for-bit, and a write-through slice reuses its
        // accumulator's buffer.
        if let OpKind::ConcatSlices { .. } = op.kind {
            for &s in &op.inputs {
                same(s, op.output, "concat-slices")?;
            }
        }
        if matches!(op.kind, OpKind::PartialInto { .. }) {
            if let Some(&acc) = op.inputs.get(1) {
                same(acc, op.output, "write-through slice")?;
            }
        }
    }
    Ok(())
}

/// Compose a weight store's qparams onto a rewritten graph through its
/// provenance map (slabs inherit the domain of the tensor they band).
pub fn remap_qparams(
    qparams: &HashMap<TensorId, QuantParams>,
    sources: &[TensorId],
) -> HashMap<TensorId, QuantParams> {
    sources
        .iter()
        .enumerate()
        .filter_map(|(t, src)| qparams.get(src).map(|q| (t, *q)))
        .collect()
}

// ---------------------------------------------------------------------------
// Family 5: export invariants.
// ---------------------------------------------------------------------------

/// Prove an embedded operator order is a bijection onto the file's
/// operator vector (every operator scheduled exactly once).
pub fn verify_operator_order(order: &[usize], n_operators: usize) -> Result<(), VerifyError> {
    const FAM: &str = "export";
    let mut seen = vec![false; n_operators];
    for &i in order {
        if i >= n_operators || seen[i] {
            return Err(fail(
                FAM,
                "export-order-not-bijective",
                format!(
                    "embedded order of {} entries is not a bijection onto {n_operators} \
                     operators (operator {i} {})",
                    order.len(),
                    if i >= n_operators { "out of range" } else { "scheduled twice" }
                ),
            ));
        }
        seen[i] = true;
    }
    if order.len() != n_operators {
        return Err(fail(
            FAM,
            "export-order-not-bijective",
            format!(
                "embedded order schedules {} of {n_operators} operators",
                order.len()
            ),
        ));
    }
    Ok(())
}

/// Prove an exported flatbuffer differs from its source by an operator
/// permutation only. Returns the permutation (`exported[i]` is
/// `original[perm[i]]`).
pub fn verify_export(original: &Model, exported: &Model) -> Result<Vec<usize>, VerifyError> {
    const FAM: &str = "export";
    let (a, b) = (&original.subgraph.operators, &exported.subgraph.operators);
    if a.len() != b.len() {
        return Err(fail(
            FAM,
            "export-count",
            format!("exported model has {} operators, source has {}", b.len(), a.len()),
        ));
    }
    if exported.buffers != original.buffers {
        let idx = exported
            .buffers
            .iter()
            .zip(&original.buffers)
            .position(|(x, y)| x != y)
            .map_or("count".to_string(), |i| format!("buffer {i}"));
        return Err(fail(
            FAM,
            "export-buffers-differ",
            format!("exported buffers are not byte-identical to the source ({idx})"),
        ));
    }
    if exported.subgraph.tensors != original.subgraph.tensors
        || exported.operator_codes != original.operator_codes
    {
        return Err(fail(
            FAM,
            "export-tensors-differ",
            "exported tensor/opcode tables differ from the source".to_string(),
        ));
    }
    let mut used = vec![false; a.len()];
    let mut perm = Vec::with_capacity(a.len());
    for (i, op) in b.iter().enumerate() {
        let Some(j) = (0..a.len()).find(|&j| !used[j] && a[j] == *op) else {
            return Err(fail(
                FAM,
                "export-not-permutation",
                format!(
                    "exported operator {i} (opcode {}) matches no unused source operator — \
                     the export is not a pure permutation",
                    op.opcode_index
                ),
            ));
        };
        used[j] = true;
        perm.push(j);
    }
    Ok(perm)
}

// ---------------------------------------------------------------------------
// The full certificate over an OptimizeReport.
// ---------------------------------------------------------------------------

/// Certify every artifact an [`crate::api::OptimizeReport`] carries:
/// schedule + peak for the default and reordered orders, a best-fit
/// placement on the base graph, the split rewrite (schedule, placement,
/// bands, halos) when one was planned, quantization flow when the model
/// is quantized, and export-order bijectivity when it came from a
/// flatbuffer. This runs on every `OptimizeRequest::run`, so no report —
/// CLI, coordinator or API — is produced unverified.
pub fn certify_report(report: &crate::api::OptimizeReport) -> Result<PlanCertificate, VerifyError> {
    let g = &report.graph;
    let mut checks = Vec::new();

    // 1. Schedule legality, default + reordered, peaks recomputed.
    let default_order =
        report.embedded_order.clone().unwrap_or_else(|| g.default_order());
    verify_peak(g, &default_order, report.default_peak, "default order")?;
    let facts = verify_peak(g, &report.reordered.order, report.reordered.peak_bytes, "reordered")?;
    checks.push(Check::ok(
        "schedule",
        format!(
            "default + reordered orders are topological; peaks {} / {} B recomputed",
            report.default_peak, report.reordered.peak_bytes
        ),
    ));

    // 2. Arena soundness of a best-fit placement on the base graph.
    let plan = StaticPlan::best_fit(g, &report.reordered.order);
    verify_arena(g, &facts, &plan)?;
    let mut arena_bytes = plan.arena_bytes;
    let mut best_order = report.reordered.order.clone();
    let mut best_peak = facts.peak_bytes;
    checks.push(Check::ok(
        "arena",
        format!("best-fit placement of {} slots in {} B, no live overlap", plan.offsets.len(), plan.arena_bytes),
    ));

    // 3. Split-rewrite soundness (+ its own schedule/arena proofs).
    match &report.split {
        Some(s) => {
            let sg = &s.outcome.graph;
            let sfacts = verify_peak(
                sg,
                &s.outcome.schedule.order,
                s.outcome.schedule.peak_bytes,
                "split schedule",
            )?;
            let splan = StaticPlan::best_fit(sg, &s.outcome.schedule.order);
            verify_arena(sg, &sfacts, &splan)?;
            verify_split(g, sg, &s.outcome.sources)?;
            arena_bytes = splan.arena_bytes;
            best_order = s.outcome.schedule.order.clone();
            best_peak = sfacts.peak_bytes;
            checks.push(Check::ok(
                "split",
                format!(
                    "{} segment step(s): bands tile, halos cover receptive fields, \
                     split peak {} B recomputed",
                    s.outcome.steps.len(),
                    s.outcome.schedule.peak_bytes
                ),
            ));
        }
        None => checks.push(Check::skipped("split", "no split plan in this report")),
    }

    // 4. Quant/domain flow, on the base and the rewritten graph.
    match &report.tflite {
        Some(src) if !src.imported.weights.qparams.is_empty() => {
            verify_quant(g, &src.imported.weights.qparams)?;
            if let Some(s) = &report.split {
                let remapped = remap_qparams(&src.imported.weights.qparams, &s.outcome.sources);
                verify_quant(&s.outcome.graph, &remapped)?;
            }
            checks.push(Check::ok(
                "quant",
                format!(
                    "{} quantized tensors: domain-preserving kernels, slices and joins \
                     keep their source domain",
                    src.imported.weights.qparams.len()
                ),
            ));
        }
        _ => checks.push(Check::skipped("quant", "model carries no quantization parameters")),
    }

    // 5. Export invariants: the reordered graph order must map onto the
    // file's operators bijectively.
    match &report.tflite {
        Some(src) => {
            let order = src.imported.operator_order(&report.reordered.order);
            verify_operator_order(&order, src.model.subgraph.operators.len())?;
            checks.push(Check::ok(
                "export",
                format!(
                    "reordered order is a bijection onto {} file operators",
                    src.model.subgraph.operators.len()
                ),
            ));
        }
        None => checks.push(Check::skipped("export", "not a .tflite source")),
    }

    Ok(PlanCertificate {
        model: report.model.clone(),
        content_hash: report.content_hash,
        n_ops: g.n_ops(),
        n_tensors: g.n_tensors(),
        order: best_order,
        peak_bytes: best_peak,
        arena_bytes,
        checks,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::sched;

    /// The verifier's interval engine must agree with the scheduler's
    /// working-set simulation on every zoo model and order — computed
    /// through entirely separate code paths.
    #[test]
    fn interval_peaks_match_the_simulator_across_the_zoo() {
        for name in models::MODEL_NAMES {
            let g = models::by_name(name, crate::graph::DType::I8).unwrap();
            for order in [g.default_order(), sched::optimal(&g).unwrap().0.order] {
                let facts = verify_schedule(&g, &order).unwrap();
                assert_eq!(
                    facts.peak_bytes,
                    sched::peak_of(&g, &order),
                    "{name}: verifier disagrees with the simulator"
                );
            }
        }
    }

    /// Figure-1 reference values, independently recomputed.
    #[test]
    fn figure1_reference_peaks() {
        let g = models::figure1();
        let d = verify_schedule(&g, &g.default_order()).unwrap();
        assert_eq!(d.peak_bytes, 5216);
        let (opt, _) = sched::optimal(&g).unwrap();
        let o = verify_schedule(&g, &opt.order).unwrap();
        assert_eq!(o.peak_bytes, 4960);
    }

    #[test]
    fn elided_split_peaks_match_the_simulator() {
        let g = models::streamnet(crate::graph::DType::I8);
        let opts = crate::split::SplitOptions::quick();
        let outcome = crate::split::optimize(&g, &opts).unwrap();
        let facts = verify_schedule(&outcome.graph, &outcome.schedule.order).unwrap();
        assert_eq!(facts.peak_bytes, outcome.schedule.peak_bytes);
        verify_split(&g, &outcome.graph, &outcome.sources).unwrap();
        let plan = StaticPlan::best_fit(&outcome.graph, &outcome.schedule.order);
        verify_arena(&outcome.graph, &facts, &plan).unwrap();
    }

    #[test]
    fn best_fit_placements_verify_across_the_zoo() {
        for name in models::MODEL_NAMES {
            let g = models::by_name(name, crate::graph::DType::I8).unwrap();
            let (opt, _) = sched::optimal(&g).unwrap();
            let facts = verify_schedule(&g, &opt.order).unwrap();
            let plan = StaticPlan::best_fit(&g, &opt.order);
            verify_arena(&g, &facts, &plan)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn operator_order_bijection() {
        verify_operator_order(&[2, 0, 1], 3).unwrap();
        assert_eq!(verify_operator_order(&[0, 0, 1], 3).unwrap_err().code, "export-order-not-bijective");
        assert_eq!(verify_operator_order(&[0, 1], 3).unwrap_err().code, "export-order-not-bijective");
        assert_eq!(verify_operator_order(&[0, 1, 3], 3).unwrap_err().code, "export-order-not-bijective");
    }
}

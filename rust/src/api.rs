//! The library-level optimize facade: [`OptimizeRequest`] → [`OptimizeReport`].
//!
//! Every front-end — the `import`/`optimize`/`split` CLI subcommands and the
//! plan-serving coordinator ([`crate::coordinator`]) — builds one
//! [`OptimizeRequest`] and calls [`OptimizeRequest::run`], so the planning
//! pipeline (resolve model → reorder DP → split/elide beam search → deploy
//! verdict) exists in exactly one place. The CLI renderers live here too
//! ([`render_import`], [`render_optimize_tflite`], [`render_split`], …) so
//! a cached plan serialized by the coordinator is bit-identical to what a
//! fresh CLI run would print.
//!
//! Serialization stability: every JSON document produced from an
//! [`OptimizeReport`] carries a `schema_version` field ([`SCHEMA_VERSION`]).
//! The number is bumped whenever a key is renamed, removed, or changes
//! meaning; adding new keys is not a bump. Coordinator clients and the
//! Python mirror check it to detect drift.

use crate::graph::serde::ModelFile;
use crate::graph::{DType, Graph, SplitAxis};
use crate::mcu::{
    Board, CostModel, DeployReport, OverheadModel, SplitOverhead, NUCLEO_F767ZI,
};
use crate::models;
use crate::sched;
use crate::split::{self, PlannerStats, SplitOptions, SplitOutcome, SplitStep};
use crate::trace::{Event, VecSink};
use crate::util::error::{anyhow, Context, Result};
use crate::util::json::Json;

/// Version of the `OptimizeReport` JSON encodings (the `optimize --json`
/// document, the coordinator's plan/summary documents). Bumped on any
/// incompatible change; additions of new keys are compatible.
pub const SCHEMA_VERSION: u64 = 1;

/// FNV-1a 64-bit hash — the crate's content fingerprint (same constants as
/// the TFLite fixture stamp). Used for model content hashes and option
/// fingerprints in plan-cache keys.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Where the model comes from. All variants resolve to the same
/// [`ResolvedModel`], so downstream planning is source-agnostic.
#[derive(Clone)]
pub enum ModelSource {
    /// A zoo model by name ([`crate::models::by_name`]).
    Zoo { name: String, dtype: DType },
    /// A `.tflite` flatbuffer on disk.
    TflitePath(String),
    /// A `.tflite` flatbuffer already in memory (coordinator uploads).
    TfliteBytes { label: String, bytes: std::sync::Arc<Vec<u8>> },
    /// A model JSON file ([`ModelFile`]) on disk.
    JsonPath(String),
    /// An already-built graph (embedders, tests).
    Graph(Graph),
}

impl ModelSource {
    /// Dispatch a `--file` path on its extension: `.tflite` loads through
    /// the flatbuffer frontend, anything else as model JSON.
    pub fn from_path(path: &str) -> ModelSource {
        if path.ends_with(".tflite") {
            ModelSource::TflitePath(path.to_string())
        } else {
            ModelSource::JsonPath(path.to_string())
        }
    }

    /// Human-readable source label (path, zoo name, or upload label).
    pub fn label(&self) -> &str {
        match self {
            ModelSource::Zoo { name, .. } => name,
            ModelSource::TflitePath(p) => p,
            ModelSource::TfliteBytes { label, .. } => label,
            ModelSource::JsonPath(p) => p,
            ModelSource::Graph(g) => &g.name,
        }
    }

    /// Load the model. Error messages match the historical CLI wording.
    pub fn resolve(&self) -> Result<ResolvedModel> {
        match self {
            ModelSource::Zoo { name, dtype } => {
                let g = models::by_name(name, *dtype).ok_or_else(|| {
                    anyhow!(
                        "unknown model {name:?}; try: {}",
                        models::MODEL_NAMES.join(", ")
                    )
                })?;
                Ok(ResolvedModel::plain(g, None, name.clone()))
            }
            ModelSource::TflitePath(path) => {
                let bytes =
                    std::fs::read(path).with_context(|| format!("reading {path}"))?;
                let model = crate::tflite::Model::parse(&bytes)
                    .map_err(|e| anyhow!("{path}: not a loadable TFLite model: {e}"))?;
                let imported =
                    crate::tflite::import(&model).map_err(|e| anyhow!("{path}: {e}"))?;
                Ok(ResolvedModel::tflite(model, imported, path.clone(), fnv64(&bytes)))
            }
            ModelSource::TfliteBytes { label, bytes } => {
                let model = crate::tflite::Model::parse(bytes)
                    .map_err(|e| anyhow!("{label}: not a loadable TFLite model: {e}"))?;
                let imported =
                    crate::tflite::import(&model).map_err(|e| anyhow!("{label}: {e}"))?;
                Ok(ResolvedModel::tflite(model, imported, label.clone(), fnv64(bytes)))
            }
            ModelSource::JsonPath(path) => {
                let src = std::fs::read_to_string(path)
                    .with_context(|| format!("reading {path}"))?;
                let mf = ModelFile::from_json(&src).map_err(|e| anyhow!("{e}"))?;
                Ok(ResolvedModel::plain(mf.graph, mf.execution_order, path.clone()))
            }
            ModelSource::Graph(g) => {
                Ok(ResolvedModel::plain(g.clone(), None, g.name.clone()))
            }
        }
    }
}

/// A retained `.tflite` source: the parsed flatbuffer plus the import
/// binding, kept so the optimized operator order can be written back
/// ([`OptimizeReport::write_reordered_tflite`]).
pub struct TfliteSource {
    pub model: crate::tflite::Model,
    pub imported: crate::tflite::Imported,
}

/// A loaded model, source-agnostic.
pub struct ResolvedModel {
    pub graph: Graph,
    /// Execution order embedded in the source file, if any (model JSON
    /// containers may carry one; `.tflite` operator order is already the
    /// graph's default order).
    pub embedded_order: Option<Vec<usize>>,
    /// Source label (path / zoo name / upload label).
    pub label: String,
    /// Flatbuffer operator count before activation de-fusing.
    pub file_operators: Option<usize>,
    /// FNV-1a of the model content: the raw flatbuffer bytes for `.tflite`
    /// sources (so an upload and the file it came from hash identically),
    /// canonical [`ModelFile`] JSON otherwise. The plan-cache identity of
    /// the model.
    pub content_hash: u64,
    /// Retained flatbuffer source, when the model came from one.
    pub tflite: Option<Box<TfliteSource>>,
}

impl ResolvedModel {
    fn plain(graph: Graph, embedded_order: Option<Vec<usize>>, label: String) -> ResolvedModel {
        let content_hash = fnv64(ModelFile::new(graph.clone()).to_json().as_bytes());
        ResolvedModel {
            graph,
            embedded_order,
            label,
            file_operators: None,
            content_hash,
            tflite: None,
        }
    }

    fn tflite(
        model: crate::tflite::Model,
        imported: crate::tflite::Imported,
        label: String,
        content_hash: u64,
    ) -> ResolvedModel {
        ResolvedModel {
            graph: imported.graph.clone(),
            embedded_order: None,
            label,
            file_operators: Some(model.subgraph.operators.len()),
            content_hash,
            tflite: Some(Box::new(TfliteSource { model, imported })),
        }
    }
}

/// One planning request: a model, an SRAM budget, and the knobs.
#[derive(Clone)]
pub struct OptimizeRequest {
    pub source: ModelSource,
    /// Peak-SRAM budget in bytes. Overrides `split.sram_budget` when a
    /// split search is configured; `None` plans without a target.
    pub budget: Option<usize>,
    /// Target board for the deploy verdict (overhead model + SRAM size).
    pub board: &'static Board,
    /// Split/elide beam search configuration; `None` = reorder only.
    pub split: Option<SplitOptions>,
    /// Additionally run the materialized-join twin of the split search
    /// (the `optimize MODEL.tflite` report shows both).
    pub compare_materialized: bool,
    /// Record planner telemetry events into [`OptimizeReport::events`].
    pub trace: bool,
}

impl OptimizeRequest {
    /// Full pipeline with default split options under `board`'s SRAM.
    pub fn new(source: ModelSource) -> OptimizeRequest {
        OptimizeRequest {
            source,
            budget: None,
            board: &NUCLEO_F767ZI,
            split: Some(SplitOptions::default()),
            compare_materialized: false,
            trace: false,
        }
    }

    /// Reorder-only request (no split search).
    pub fn reorder_only(source: ModelSource) -> OptimizeRequest {
        OptimizeRequest { split: None, ..OptimizeRequest::new(source) }
    }

    pub fn with_budget(mut self, budget: Option<usize>) -> Self {
        self.budget = budget;
        self
    }

    pub fn with_board(mut self, board: &'static Board) -> Self {
        self.board = board;
        self
    }

    pub fn with_split(mut self, split: Option<SplitOptions>) -> Self {
        self.split = split;
        self
    }

    pub fn with_trace(mut self, trace: bool) -> Self {
        self.trace = trace;
        self
    }

    /// Fingerprint of everything that affects the produced plan *except*
    /// the model content: schema version, board, budget, and every split
    /// knob. Together with [`ResolvedModel::content_hash`] this is the
    /// plan-cache key, so two requests with equal fingerprints and equal
    /// model hashes are guaranteed to produce bit-identical reports.
    pub fn options_fingerprint(&self) -> u64 {
        let split = match &self.split {
            None => "none".to_string(),
            Some(o) => {
                let axes: Vec<&str> = o.axes.iter().map(|a| a.name()).collect();
                format!(
                    "f{} s{} b{:?} r{} c{} w{} a[{}] e{} t{} {:?}",
                    o.max_factor,
                    o.max_segment,
                    o.sram_budget,
                    o.max_rounds,
                    o.max_candidates,
                    o.beam_width,
                    axes.join(","),
                    o.elide,
                    o.threads,
                    o.eval,
                )
            }
        };
        let key = format!(
            "v{}|board={}|budget={:?}|mat={}|split={}",
            SCHEMA_VERSION, self.board.name, self.budget, self.compare_materialized, split
        );
        fnv64(key.as_bytes())
    }

    /// Run the pipeline: resolve → Algorithm-1 reorder DP → optional
    /// split/elide beam search → static-arena and deploy accounting.
    pub fn run(&self) -> Result<OptimizeReport> {
        let resolved = self.source.resolve()?;
        let g = &resolved.graph;
        let default_order =
            resolved.embedded_order.clone().unwrap_or_else(|| g.default_order());
        let default_peak = sched::peak_of(g, &default_order);
        let (reordered, search) = sched::optimal(g).map_err(|e| anyhow!("{e}"))?;
        let static_arena_bytes = crate::alloc::StaticPlan::no_reuse(g).arena_bytes;

        let mut events: Vec<Event> = Vec::new();
        let mut materialized_peak = None;
        let split_report = match &self.split {
            None => None,
            Some(base) => {
                let mut opts = base.clone();
                if self.budget.is_some() {
                    opts.sram_budget = self.budget;
                }
                if self.compare_materialized {
                    let mat = split::optimize(g, &opts.clone().materialized())
                        .map_err(|e| anyhow!("{e}"))?;
                    materialized_peak = Some(mat.schedule.peak_bytes);
                }
                let outcome = if self.trace {
                    let mut sink = VecSink::new();
                    let o = split::optimize_traced(g, &opts, &mut sink)
                        .map_err(|e| anyhow!("{e}"))?;
                    events = sink.events;
                    o
                } else {
                    split::optimize(g, &opts).map_err(|e| anyhow!("{e}"))?
                };
                let overhead = SplitOverhead::measure(
                    &CostModel::cortex_m7_reference(),
                    g,
                    &outcome.graph,
                    self.board,
                );
                Some(SplitReport { outcome, overhead })
            }
        };

        let mut report = OptimizeReport {
            schema_version: SCHEMA_VERSION,
            model: g.name.clone(),
            source: resolved.label.clone(),
            graph: resolved.graph.clone(),
            embedded_order: resolved.embedded_order.clone(),
            file_operators: resolved.file_operators,
            content_hash: resolved.content_hash,
            default_peak,
            reordered,
            search,
            static_arena_bytes,
            budget: self.budget,
            board: self.board,
            split: split_report,
            materialized_peak,
            events,
            tflite: resolved.tflite,
            verified: false,
        };

        // Proof-carrying plans: no report leaves the facade unverified. The
        // certificate is recomputed by [`crate::verify`], which shares no
        // lifetime/peak accounting with the planners — a failure here is a
        // planner bug and aborts the request rather than serving the plan.
        let cert = crate::verify::certify_report(&report).map_err(|e| anyhow!("{e}"))?;
        report.verified = true;
        if self.trace {
            report.events.push(Event::Verify {
                model: report.model.clone(),
                checks: cert.checks.len(),
                peak_bytes: cert.peak_bytes,
                ok: true,
            });
        }
        Ok(report)
    }
}

/// Split-search result plus the modeled recompute/flash overheads of the
/// committed plan.
pub struct SplitReport {
    pub outcome: SplitOutcome,
    pub overhead: SplitOverhead,
}

/// Everything a front-end needs to render, serialize, or deploy the plan.
pub struct OptimizeReport {
    pub schema_version: u64,
    /// Graph name.
    pub model: String,
    /// Source label (path / zoo name / upload label).
    pub source: String,
    pub graph: Graph,
    pub embedded_order: Option<Vec<usize>>,
    pub file_operators: Option<usize>,
    pub content_hash: u64,
    /// Peak of the source's own execution order (file order for `.tflite`).
    pub default_peak: usize,
    /// The Algorithm-1 reorder-only optimum.
    pub reordered: sched::Schedule,
    pub search: sched::OptimalStats,
    /// Static no-reuse arena size (the allocator the paper replaces).
    pub static_arena_bytes: usize,
    pub budget: Option<usize>,
    pub board: &'static Board,
    pub split: Option<SplitReport>,
    /// Peak of the materialized-join split twin, when requested.
    pub materialized_peak: Option<usize>,
    /// Planner telemetry, when requested.
    pub events: Vec<Event>,
    /// Retained flatbuffer source, when the model came from one.
    pub tflite: Option<Box<TfliteSource>>,
    /// Every artifact in this report passed the independent static
    /// verifier ([`crate::verify::certify_report`]). Always `true` on a
    /// report returned by [`OptimizeRequest::run`]; the coordinator
    /// refuses to serve cached plans without it.
    pub verified: bool,
}

impl OptimizeReport {
    /// Lowest peak achieved by the pipeline (split optimum when a split
    /// search ran, reorder-only optimum otherwise).
    pub fn best_peak(&self) -> usize {
        match &self.split {
            Some(s) => s.outcome.schedule.peak_bytes,
            None => self.reordered.peak_bytes,
        }
    }

    /// Did the best peak meet the requested budget? `None` when no budget
    /// was requested.
    pub fn fits_budget(&self) -> Option<bool> {
        self.budget.map(|b| self.best_peak() <= b)
    }

    /// Deploy verdict at the reorder-only peak (the `import` rendering).
    pub fn deploy(&self) -> DeployReport {
        self.deploy_at(self.reordered.peak_bytes)
    }

    /// Deploy verdict at an arbitrary peak on the request's board.
    pub fn deploy_at(&self, peak_bytes: usize) -> DeployReport {
        DeployReport::new(&self.graph, peak_bytes, self.board, &OverheadModel::default())
    }

    /// The source flatbuffer re-serialized with the reorder-only optimal
    /// operator order embedded (buffers byte-identical). Errors unless the
    /// model came from a `.tflite` source. This is the deployable-artifact
    /// payload the coordinator's `ARTIFACT TFLITE` command serves.
    pub fn reordered_tflite_bytes(&self) -> Result<Vec<u8>> {
        let src = self
            .tflite
            .as_ref()
            .ok_or_else(|| anyhow!("model did not come from a .tflite source"))?;
        let order = src.imported.operator_order(&self.reordered.order);
        let reordered =
            crate::tflite::reorder(&src.model, &order).map_err(|e| anyhow!("{e}"))?;
        Ok(reordered.serialize())
    }

    /// Write the source flatbuffer back with the reorder-only optimal
    /// operator order embedded ([`Self::reordered_tflite_bytes`]).
    pub fn write_reordered_tflite(&self, out: &str) -> Result<()> {
        let bytes = self.reordered_tflite_bytes()?;
        std::fs::write(out, bytes).with_context(|| format!("writing {out}"))?;
        Ok(())
    }

    /// The full plan document the coordinator serves (`GET`). Canonical:
    /// a cached plan and a fresh run of the same request serialize to the
    /// same bytes.
    pub fn to_json(&self) -> Json {
        let mut peaks = vec![
            ("default", Json::Num(self.default_peak as f64)),
            ("reordered", Json::Num(self.reordered.peak_bytes as f64)),
        ];
        if let Some(s) = &self.split {
            peaks.push(("split", Json::Num(s.outcome.schedule.peak_bytes as f64)));
        }
        let (order, plan, planner) = match &self.split {
            Some(s) => (
                order_json(&s.outcome.schedule.order),
                steps_json(&s.outcome.steps),
                planner_json(&s.outcome.stats),
            ),
            None => (
                order_json(&self.reordered.order),
                steps_json(&[]),
                planner_json(&PlannerStats::default()),
            ),
        };
        let deploy = self.deploy_at(self.best_peak());
        Json::obj(vec![
            ("schema_version", Json::Num(self.schema_version as f64)),
            ("model", Json::Str(self.model.clone())),
            ("source", Json::Str(self.source.clone())),
            ("content_hash", Json::Str(format!("{:016x}", self.content_hash))),
            (
                "board",
                Json::obj(vec![
                    ("name", Json::Str(self.board.name.to_string())),
                    ("sram_bytes", Json::Num(self.board.sram_bytes as f64)),
                ]),
            ),
            (
                "budget",
                match self.budget {
                    Some(b) => Json::Num(b as f64),
                    None => Json::Null,
                },
            ),
            ("peaks", Json::obj(peaks)),
            ("order", order),
            ("plan", plan),
            ("planner", planner),
            (
                "search",
                Json::obj(vec![
                    ("states", Json::Num(self.search.states as f64)),
                    ("expansions", Json::Num(self.search.expansions as f64)),
                ]),
            ),
            ("static_arena", Json::Num(self.static_arena_bytes as f64)),
            ("verified", Json::Bool(self.verified)),
            (
                "deploy",
                Json::obj(vec![
                    ("overhead_bytes", Json::Num(deploy.overhead_bytes as f64)),
                    ("total_sram", Json::Num(deploy.total_sram() as f64)),
                    ("fits_sram", Json::Bool(deploy.fits_sram)),
                    ("fits_flash", Json::Bool(deploy.fits_flash)),
                ]),
            ),
        ])
    }

    /// One-line plan summary (the coordinator's `PLAN` reply).
    pub fn summary_json(&self) -> Json {
        let deploy = self.deploy_at(self.best_peak());
        Json::obj(vec![
            ("schema_version", Json::Num(self.schema_version as f64)),
            ("model", Json::Str(self.model.clone())),
            ("board", Json::Str(self.board.name.to_string())),
            (
                "budget",
                match self.budget {
                    Some(b) => Json::Num(b as f64),
                    None => Json::Null,
                },
            ),
            ("peak", Json::Num(self.best_peak() as f64)),
            ("reordered", Json::Num(self.reordered.peak_bytes as f64)),
            (
                "segments",
                Json::Num(self.split.as_ref().map(|s| s.outcome.steps.len()).unwrap_or(0)
                    as f64),
            ),
            ("fits_sram", Json::Bool(deploy.fits_sram)),
            (
                "budget_met",
                match self.fits_budget() {
                    Some(ok) => Json::Bool(ok),
                    None => Json::Null,
                },
            ),
            ("verified", Json::Bool(self.verified)),
        ])
    }
}

// ---------------------------------------------------------------------------
// JSON fragments shared by the CLI and the coordinator.
// ---------------------------------------------------------------------------

/// An execution order as a JSON array of op ids.
pub fn order_json(order: &[usize]) -> Json {
    Json::Arr(order.iter().map(|&o| Json::Num(o as f64)).collect())
}

/// Committed split steps as JSON.
pub fn steps_json(steps: &[SplitStep]) -> Json {
    Json::Arr(
        steps
            .iter()
            .map(|st| {
                Json::obj(vec![
                    (
                        "segment",
                        Json::Arr(st.segment.iter().map(|s| Json::Str(s.clone())).collect()),
                    ),
                    ("factor", Json::Num(st.factor as f64)),
                    ("axis", Json::Str(st.axis.name().to_string())),
                    ("elided", Json::Bool(st.elided)),
                    ("peak_before", Json::Num(st.peak_before as f64)),
                    ("peak_after", Json::Num(st.peak_after as f64)),
                ])
            })
            .collect(),
    )
}

/// Planner work counters for `optimize --json` / `split`: how much the
/// incremental fast path saved over naive full-DP candidate scoring.
pub fn planner_json(st: &PlannerStats) -> Json {
    Json::obj(vec![
        ("scored", Json::Num(st.scored as f64)),
        ("deduped", Json::Num(st.deduped as f64)),
        ("improved", Json::Num(st.improved as f64)),
        ("bounded", Json::Num(st.bounded as f64)),
        ("full_evals", Json::Num(st.full_evals as f64)),
        ("cache_lookups", Json::Num(st.cache_lookups as f64)),
        ("cache_hits", Json::Num(st.cache_hits as f64)),
        ("cache_misses", Json::Num(st.cache_misses as f64)),
        ("eval_ratio", Json::Num(st.eval_ratio())),
        ("threads", Json::Num(st.threads as f64)),
    ])
}

/// The `optimize MODEL.tflite --json` document. Requires a report produced
/// with `compare_materialized` and a split search (the CLI request shape).
pub fn optimize_tflite_json(r: &OptimizeReport, out: Option<&str>) -> Json {
    let split = r.split.as_ref().expect("optimize_tflite_json needs a split report");
    let mat_peak = r.materialized_peak.unwrap_or(split.outcome.schedule.peak_bytes);
    Json::obj(vec![
        ("schema_version", Json::Num(r.schema_version as f64)),
        ("model", Json::Str(r.model.clone())),
        ("source", Json::Str(r.source.clone())),
        (
            "peaks",
            Json::obj(vec![
                ("file", Json::Num(r.default_peak as f64)),
                ("reordered", Json::Num(r.reordered.peak_bytes as f64)),
                ("split", Json::Num(mat_peak as f64)),
                ("elided", Json::Num(split.outcome.schedule.peak_bytes as f64)),
            ]),
        ),
        (
            "budget",
            match r.budget {
                Some(b) => Json::Num(b as f64),
                None => Json::Null,
            },
        ),
        ("order", order_json(&r.reordered.order)),
        (
            "search",
            Json::obj(vec![
                ("states", Json::Num(r.search.states as f64)),
                ("expansions", Json::Num(r.search.expansions as f64)),
            ]),
        ),
        ("plan", steps_json(&split.outcome.steps)),
        ("planner", planner_json(&split.outcome.stats)),
        (
            "out",
            match out {
                Some(p) => Json::Str(p.to_string()),
                None => Json::Null,
            },
        ),
    ])
}

/// The `optimize --model M --json` document.
pub fn optimize_model_json(r: &OptimizeReport, out: &str) -> Json {
    Json::obj(vec![
        ("schema_version", Json::Num(r.schema_version as f64)),
        ("model", Json::Str(r.model.clone())),
        (
            "peaks",
            Json::obj(vec![
                ("default", Json::Num(r.default_peak as f64)),
                ("reordered", Json::Num(r.reordered.peak_bytes as f64)),
            ]),
        ),
        ("order", order_json(&r.reordered.order)),
        (
            "search",
            Json::obj(vec![
                ("states", Json::Num(r.search.states as f64)),
                ("expansions", Json::Num(r.search.expansions as f64)),
            ]),
        ),
        ("out", Json::Str(out.to_string())),
    ])
}

// ---------------------------------------------------------------------------
// CLI text renderers (byte-identical to the historical subcommand output).
// ---------------------------------------------------------------------------

/// The `import MODEL.tflite` report body (everything except the optional
/// `wrote IR model JSON to …` line, which depends on a CLI-side write).
pub fn render_import(r: &OptimizeReport) -> String {
    let g = &r.graph;
    let path = &r.source;
    let n_w = g.tensors.iter().filter(|t| t.is_weight).count();
    let mut out = String::new();
    out.push_str(&format!(
        "imported {path}: {} ({} operators → {} ops after de-fusing, {} tensors / {} weights)\n",
        g.name,
        r.file_operators.unwrap_or_else(|| g.n_ops()),
        g.n_ops(),
        g.n_tensors(),
        n_w,
    ));
    let dtype = g.inputs.first().map(|&t| g.tensors[t].dtype.name()).unwrap_or("?");
    out.push_str(&format!(
        "dtype: {}   model size: {} B   activation total: {} B   MACs: {}\n",
        dtype,
        g.model_size(),
        g.activation_total(),
        g.total_macs()
    ));
    out.push('\n');
    out.push_str(&format!("file-order peak       : {:>9} B\n", r.default_peak));
    out.push_str(&format!("reorder-only optimal  : {:>9} B\n", r.reordered.peak_bytes));
    out.push_str(&format!("static no-reuse arena : {:>9} B\n", r.static_arena_bytes));
    let report = r.deploy();
    out.push_str(&format!(
        "deploy ({:>14}): peak + overhead = {} B of {} B SRAM → {}\n",
        report.board,
        report.total_sram(),
        r.board.sram_bytes,
        if report.fits_sram { "FITS" } else { "DOES NOT FIT" }
    ));
    out
}

/// The `optimize MODEL.tflite` text body (peaks + plan + planner line; the
/// trailing `wrote …`/`nothing written` lines depend on CLI-side writes).
pub fn render_optimize_tflite(r: &OptimizeReport) -> String {
    let split = r.split.as_ref().expect("render_optimize_tflite needs a split report");
    let elided = &split.outcome;
    let mat_peak = r.materialized_peak.unwrap_or(elided.schedule.peak_bytes);
    let mut out = String::new();
    out.push_str(&format!("model: {} ({} ops de-fused)\n\n", r.model, r.graph.n_ops()));
    let verdict = |peak: usize| match r.budget {
        Some(b) if peak <= b => "  [budget MET]",
        Some(_) => "  [budget NOT met]",
        None => "",
    };
    out.push_str(&format!(
        "file-order peak       : {:>9} B{}\n",
        r.default_peak,
        verdict(r.default_peak)
    ));
    out.push_str(&format!(
        "reorder-only optimal  : {:>9} B{}  ({} states, {} expansions)\n",
        r.reordered.peak_bytes,
        verdict(r.reordered.peak_bytes),
        r.search.states,
        r.search.expansions
    ));
    out.push_str(&format!(
        "split+reorder         : {:>9} B{}  ({} segment(s))\n",
        mat_peak,
        verdict(mat_peak),
        elided.steps.len()
    ));
    out.push_str(&format!(
        "split+reorder, elided : {:>9} B{}  ({} segment(s), {} join(s) streamed)\n",
        elided.schedule.peak_bytes,
        verdict(elided.schedule.peak_bytes),
        elided.steps.len(),
        elided.elided_steps()
    ));
    for st in &elided.steps {
        out.push_str(&format!(
            "  split [{}] ×{} along {}{}: {} B → {} B\n",
            st.segment.join(" → "),
            st.factor,
            st.axis.name(),
            if st.elided { ", join elided" } else { "" },
            st.peak_before,
            st.peak_after
        ));
    }
    if !elided.steps.is_empty() {
        out.push_str(
            "  (splits are reported for planning; the flatbuffer stores the reordered\n   \
             model only — partial execution needs the interpreter/JSON pipeline)\n",
        );
    }
    let st = &elided.stats;
    out.push_str(&format!(
        "planner               : {} scored ({} deduped), {} full DP, cache {}/{} hit/miss, \
         {:.0}× vs naive, {} thread(s)\n",
        st.scored,
        st.deduped,
        st.full_evals,
        st.cache_hits,
        st.cache_misses,
        st.eval_ratio(),
        st.threads
    ));
    out
}

/// The `optimize --model M --out F` confirmation line.
pub fn render_optimize_model(r: &OptimizeReport, out: &str) -> String {
    format!(
        "wrote {out}: peak {} B → {} B ({} states, {} expansions)\n",
        r.default_peak, r.reordered.peak_bytes, r.search.states, r.search.expansions
    )
}

/// The `split --model M` report body (everything except the optional
/// `wrote split model + schedule to …` line). `elapsed_secs` is the
/// caller-measured search wall time.
pub fn render_split(r: &OptimizeReport, elapsed_secs: f64) -> String {
    let split = r.split.as_ref().expect("render_split needs a split report");
    let outcome = &split.outcome;
    let ov = &split.overhead;
    let mut out = String::new();
    out.push_str(&format!(
        "model: {}  ({} ops → {} after splitting)\n\n",
        r.model,
        r.graph.n_ops(),
        outcome.graph.n_ops()
    ));
    out.push_str(&format!("default order peak    : {:>9} B\n", r.default_peak));
    out.push_str(&format!("reorder-only optimal  : {:>9} B\n", outcome.base_peak));
    out.push_str(&format!(
        "split+reorder optimal : {:>9} B  ({} segment(s), {:.2}s search)\n",
        outcome.schedule.peak_bytes,
        outcome.steps.len(),
        elapsed_secs
    ));
    for st in &outcome.steps {
        out.push_str(&format!(
            "  split [{}] ×{} along {}{}: {} B → {} B\n",
            st.segment.join(" → "),
            st.factor,
            st.axis.name(),
            if st.elided { ", join elided" } else { "" },
            st.peak_before,
            st.peak_after
        ));
    }
    if outcome.steps.is_empty() {
        out.push_str("  (no split improved on reorder-only scheduling)\n");
    }
    let st = &outcome.stats;
    out.push_str(&format!(
        "planner               : {} scored ({} deduped), {} full DP, cache {}/{} hit/miss, \
         {:.0}× vs naive, {} thread(s)\n",
        st.scored,
        st.deduped,
        st.full_evals,
        st.cache_hits,
        st.cache_misses,
        st.eval_ratio(),
        st.threads
    ));
    out.push_str(&format!(
        "recompute overhead    : {:+.2}% MACs, modeled time ×{:.4}\n",
        100.0 * ov.recompute_frac(),
        ov.time_ratio
    ));
    for axis in SplitAxis::ALL {
        let frac = ov.recompute_frac_of(axis);
        if frac > 0.0 {
            out.push_str(&format!(
                "  recompute along {:<8}: {:+.2}% MACs\n",
                axis.name(),
                100.0 * frac
            ));
        }
    }
    out.push_str(&format!(
        "weight flash traffic  : ×{:.2} ({} B join copies, {} B elided)\n",
        ov.weight_traffic_ratio(),
        ov.join_bytes,
        ov.elided_join_bytes
    ));
    if outcome.elided_steps() > 0 {
        out.push_str(&format!(
            "join elision          : {}/{} segment join(s) streamed (no ConcatSlices copy)\n",
            outcome.elided_steps(),
            outcome.steps.len()
        ));
    }
    if let Some(b) = r.budget {
        out.push_str(&format!(
            "SRAM budget {} B     : {}\n",
            b,
            if outcome.schedule.peak_bytes <= b { "MET" } else { "NOT MET" }
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_depends_on_board_and_budget() {
        let req = OptimizeRequest::new(ModelSource::Zoo {
            name: "figure1".into(),
            dtype: DType::I8,
        });
        let base = req.options_fingerprint();
        let other_board = req.clone().with_board(&crate::mcu::STM32F446RE);
        assert_ne!(base, other_board.options_fingerprint());
        let other_budget = req.clone().with_budget(Some(4096));
        assert_ne!(base, other_budget.options_fingerprint());
        assert_eq!(base, req.clone().options_fingerprint());
    }

    #[test]
    fn zoo_resolve_hashes_content_not_name() {
        let a = ModelSource::Zoo { name: "figure1".into(), dtype: DType::I8 }
            .resolve()
            .unwrap();
        let b = ModelSource::Zoo { name: "tiny".into(), dtype: DType::I8 }
            .resolve()
            .unwrap();
        assert_ne!(a.content_hash, b.content_hash);
        let a2 = ModelSource::Zoo { name: "figure1".into(), dtype: DType::I8 }
            .resolve()
            .unwrap();
        assert_eq!(a.content_hash, a2.content_hash);
    }

    #[test]
    fn figure1_report_reproduces_paper_peaks() {
        let r = OptimizeRequest::reorder_only(ModelSource::Zoo {
            name: "figure1".into(),
            dtype: DType::I8,
        })
        .run()
        .unwrap();
        assert_eq!(r.default_peak, 5216);
        assert_eq!(r.reordered.peak_bytes, 4960);
        assert_eq!(r.best_peak(), 4960);
        assert_eq!(r.schema_version, SCHEMA_VERSION);
    }

    #[test]
    fn report_json_carries_schema_version() {
        let r = OptimizeRequest::new(ModelSource::Zoo {
            name: "figure1".into(),
            dtype: DType::I8,
        })
        .with_budget(Some(5000))
        .run()
        .unwrap();
        let doc = r.to_json();
        assert_eq!(doc.get("schema_version").as_f64(), Some(SCHEMA_VERSION as f64));
        let summary = r.summary_json();
        assert_eq!(summary.get("schema_version").as_f64(), Some(SCHEMA_VERSION as f64));
        assert_eq!(summary.get("budget_met").as_bool(), Some(true));
        // Every report leaving run() is proof-carrying.
        assert!(r.verified);
        assert_eq!(doc.get("verified").as_bool(), Some(true));
        assert_eq!(summary.get("verified").as_bool(), Some(true));
    }

    #[test]
    fn unknown_zoo_model_is_a_clean_error() {
        let err = ModelSource::Zoo { name: "nope".into(), dtype: DType::I8 }
            .resolve()
            .unwrap_err();
        assert!(format!("{err}").contains("unknown model"));
    }
}

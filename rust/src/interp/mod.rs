//! Micro-interpreter (the paper's modified TFLite-Micro execution engine).
//!
//! Executes a scheduled graph inside a fixed-size SRAM arena. All tensor
//! buffers are addressed through [`BufId`] handles resolved at each kernel
//! call — never across operators — so the [`DynamicArena`] is free to move
//! buffers during defragmentation (§4: "pointers to memory blocks are not
//! being remembered anywhere in the code").
//!
//! Two numeric paths mirror a real MCU deployment:
//! - **f32** — reference semantics; compared against the AOT-compiled PJRT
//!   artifacts in integration tests.
//! - **int8** — TFLite-style affine quantization with a calibration pass
//!   ([`calibrate`]); exercises the byte-exact arena accounting the paper's
//!   memory numbers are about.

pub mod ops;
pub mod quant;

use std::collections::HashMap;

use crate::alloc::{AllocError, AllocStats, BufId, CompactPolicy, DynamicArena};
use crate::graph::{Act, DType, Graph, OpId, OpKind, Padding, SplitAxis, Tensor, TensorId};
use crate::trace::{Event, NullSink, TraceSink};
use crate::util::rng::Rng;
use ops::Hwc;
use quant::QuantParams;

/// Typed tensor payload.
#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I8(Vec<i8>),
    I32(Vec<i32>),
    U8(Vec<u8>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I8(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::U8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            TensorData::F32(_) => DType::F32,
            TensorData::I8(_) => DType::I8,
            TensorData::I32(_) => DType::I32,
            TensorData::U8(_) => DType::U8,
        }
    }

    /// Little-endian byte serialization (the arena's storage format).
    pub fn to_bytes(&self) -> Vec<u8> {
        match self {
            TensorData::F32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            TensorData::I8(v) => v.iter().map(|&x| x as u8).collect(),
            TensorData::I32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            TensorData::U8(v) => v.clone(),
        }
    }

    /// Decode from little-endian bytes.
    pub fn from_bytes(dtype: DType, bytes: &[u8]) -> TensorData {
        match dtype {
            DType::F32 => TensorData::F32(
                bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            DType::I32 => TensorData::I32(
                bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            DType::I8 => TensorData::I8(bytes.iter().map(|&b| b as i8).collect()),
            DType::U8 => TensorData::U8(bytes.to_vec()),
        }
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            TensorData::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i8(&self) -> Option<&[i8]> {
        match self {
            TensorData::I8(v) => Some(v),
            _ => None,
        }
    }
}

/// Flash-resident parameters plus quantization metadata.
#[derive(Clone, Debug, Default)]
pub struct WeightStore {
    /// Weight tensor payloads, keyed by tensor id.
    pub data: HashMap<TensorId, TensorData>,
    /// Quantization parameters per tensor (weights *and* activations);
    /// empty for f32 graphs.
    pub qparams: HashMap<TensorId, QuantParams>,
}

impl WeightStore {
    /// Deterministic He-style random f32 weights for every weight tensor of
    /// `g` (bias ≈ 0). The same seed reproduces the same parameters — the
    /// AOT Python exporter uses an identical generator so the PJRT
    /// artifacts and the interpreter share weights.
    pub fn seeded_f32(g: &Graph, seed: u64) -> WeightStore {
        let mut ws = WeightStore::default();
        let mut rng = Rng::new(seed);
        for t in &g.tensors {
            if !t.is_weight {
                continue;
            }
            // BatchNorm statistics need specific distributions (γ around 1,
            // σ² strictly positive); everything else is He-style uniform.
            let vals: Vec<f32> = if t.name.ends_with(".gamma") {
                (0..t.elems()).map(|_| rng.f32_range(0.8, 1.2)).collect()
            } else if t.name.ends_with(".var") {
                (0..t.elems()).map(|_| rng.f32_range(0.5, 1.5)).collect()
            } else if t.name.ends_with(".beta") || t.name.ends_with(".mean") {
                (0..t.elems()).map(|_| rng.f32_range(-0.1, 0.1)).collect()
            } else {
                let is_bias = t.name.ends_with(".b");
                let fan_in = fan_in_of(t);
                let bound = if is_bias { 0.05 } else { (1.0 / fan_in as f32).sqrt() };
                (0..t.elems()).map(|_| rng.f32_range(-bound, bound)).collect()
            };
            ws.data.insert(t.id, TensorData::F32(vals));
        }
        ws
    }

    /// Quantize an f32 weight store to int8 for the structurally-identical
    /// i8 graph `g_i8` (same tensor order/names as the f32 graph used for
    /// calibration). `act_ranges` maps tensor names to observed (min, max).
    pub fn quantize_from(
        g_i8: &Graph,
        ws_f32: &WeightStore,
        act_ranges: &HashMap<String, (f32, f32)>,
    ) -> WeightStore {
        let mut ws = WeightStore::default();
        // Activation qparams from calibration ranges.
        for t in &g_i8.tensors {
            if t.is_weight {
                continue;
            }
            let (lo, hi) = act_ranges.get(&t.name).copied().unwrap_or((-1.0, 1.0));
            ws.qparams.insert(t.id, QuantParams::from_range(lo, hi));
        }
        // Weights: symmetric per-tensor; biases: i32 at s_in * s_w.
        for op in &g_i8.ops {
            if op.weights.is_empty() {
                continue;
            }
            let w_id = op.weights[0];
            let w_f = ws_f32.data[&w_id].as_f32().expect("f32 master weights");
            let absmax = w_f.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let w_q = QuantParams::symmetric(absmax.max(1e-6));
            ws.qparams.insert(w_id, w_q);
            ws.data.insert(w_id, TensorData::I8(w_q.quantize(w_f)));
            if op.weights.len() > 1 {
                let b_id = op.weights[1];
                let b_f = ws_f32.data[&b_id].as_f32().expect("f32 master bias");
                let s_in = ws.qparams[&op.inputs[0]].scale;
                let bias_scale = s_in * w_q.scale;
                ws.qparams.insert(b_id, QuantParams::new(bias_scale, 0));
                ws.data.insert(
                    b_id,
                    TensorData::I32(b_f.iter().map(|&b| (b / bias_scale).round() as i32).collect()),
                );
            }
        }
        ws
    }

    fn f32_of(&self, t: TensorId) -> &[f32] {
        self.data[&t].as_f32().expect("expected f32 weight")
    }

    fn i8_of(&self, t: TensorId) -> &[i8] {
        self.data[&t].as_i8().expect("expected i8 weight")
    }

    fn i32_of(&self, t: TensorId) -> &[i32] {
        match &self.data[&t] {
            TensorData::I32(v) => v,
            _ => panic!("expected i32 bias"),
        }
    }
}

/// Resolve the `(pad_y, pad_x)` pair of a `Partial` slice: the split axis
/// stores its effective padding on the op; the orthogonal spatial axis is
/// full-size on the slab, so its padding derives from the inner op's mode
/// exactly as the unsplit kernel would compute it.
pub(crate) fn partial_pads(
    axis: SplitAxis,
    pad: isize,
    ish: Hwc,
    osh: Hwc,
    kernel: (usize, usize),
    stride: (usize, usize),
    padding: Padding,
) -> (isize, isize) {
    let dy = ops::pad_amounts(ish.h, kernel.0, stride.0, padding, osh.h) as isize;
    let dx = ops::pad_amounts(ish.w, kernel.1, stride.1, padding, osh.w) as isize;
    match axis {
        SplitAxis::Rows => (pad, dx),
        SplitAxis::Cols => (dy, pad),
        SplitAxis::Channels => (dy, dx),
    }
}

/// Shape of the band a [`OpKind::PartialInto`] slice computes: the full
/// join shape with the split-axis extent replaced by `len` (dimension
/// selection shared with the IR via [`crate::graph::axis_dim_of`]).
pub(crate) fn band_shape_of(full: &[usize], axis: SplitAxis, len: usize) -> Vec<usize> {
    let mut s = full.to_vec();
    let d = crate::graph::axis_dim_of(&s, axis);
    s[d] = len;
    s
}

fn fan_in_of(t: &Tensor) -> usize {
    match t.shape.len() {
        4 => t.shape[0] * t.shape[1] * t.shape[2], // conv HWIO
        3 => t.shape[0] * t.shape[1],              // dwconv HWC
        2 => t.shape[0],                           // dense [in,out]
        _ => t.elems().max(1),
    }
}

/// Interpreter configuration.
#[derive(Clone, Debug)]
pub struct ExecConfig {
    /// SRAM bytes available for tensor data.
    pub arena_bytes: usize,
    /// Defragmentation policy.
    pub policy: CompactPolicy,
    /// Execution order; `None` uses the graph's default order.
    pub order: Option<Vec<OpId>>,
}

impl ExecConfig {
    pub fn with_capacity(arena_bytes: usize) -> Self {
        ExecConfig { arena_bytes, policy: CompactPolicy::EveryOp, order: None }
    }
}

/// Per-run outcome.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Payloads of the graph's output tensors, in `g.outputs` order.
    pub outputs: Vec<TensorData>,
    /// Arena counters (high-water, compaction traffic, …).
    pub alloc: AllocStats,
    /// Total multiply-accumulates executed.
    pub macs: u64,
}

/// Execution failure.
#[derive(Debug)]
pub enum ExecError {
    Alloc(AllocError),
    BadInput(String),
    Unsupported(String),
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::Alloc(e) => write!(f, "allocation failure: {e}"),
            ExecError::BadInput(m) => write!(f, "bad input: {m}"),
            ExecError::Unsupported(m) => write!(f, "unsupported: {m}"),
        }
    }
}

impl std::error::Error for ExecError {}

impl From<AllocError> for ExecError {
    fn from(e: AllocError) -> Self {
        ExecError::Alloc(e)
    }
}

/// The micro-interpreter.
pub struct Interpreter<'g> {
    g: &'g Graph,
    weights: WeightStore,
    config: ExecConfig,
}

impl<'g> Interpreter<'g> {
    pub fn new(g: &'g Graph, weights: WeightStore, config: ExecConfig) -> Self {
        Interpreter { g, weights, config }
    }

    pub fn weights(&self) -> &WeightStore {
        &self.weights
    }

    /// Run one inference.
    pub fn run(&self, inputs: &[TensorData]) -> Result<RunResult, ExecError> {
        Ok(self.run_inner(inputs, false, &mut NullSink)?.0)
    }

    /// Run one inference, additionally capturing every activation tensor
    /// (used by the int8 calibration pass).
    pub fn run_capture(
        &self,
        inputs: &[TensorData],
    ) -> Result<(RunResult, Vec<Option<TensorData>>), ExecError> {
        let (r, c) = self.run_inner(inputs, true, &mut NullSink)?;
        Ok((r, c.expect("capture requested")))
    }

    /// Run one inference with an observability sink: emits one
    /// [`Event::ArenaOp`] per executed operator carrying the dynamic
    /// arena's *measured* high-water mark after that op — the series the
    /// audit compares against the analytic working-set peak.
    pub fn run_traced(
        &self,
        inputs: &[TensorData],
        sink: &mut dyn TraceSink,
    ) -> Result<RunResult, ExecError> {
        Ok(self.run_inner(inputs, false, sink)?.0)
    }

    fn order(&self) -> Vec<OpId> {
        self.config.order.clone().unwrap_or_else(|| self.g.default_order())
    }

    #[allow(clippy::type_complexity)]
    fn run_inner(
        &self,
        inputs: &[TensorData],
        capture: bool,
        sink: &mut dyn TraceSink,
    ) -> Result<(RunResult, Option<Vec<Option<TensorData>>>), ExecError> {
        let g = self.g;
        let order = self.order();
        g.check_order(&order).map_err(|e| ExecError::BadInput(e.to_string()))?;
        if inputs.len() != g.inputs.len() {
            return Err(ExecError::BadInput(format!(
                "expected {} inputs, got {}",
                g.inputs.len(),
                inputs.len()
            )));
        }

        let mut arena = DynamicArena::new(self.config.arena_bytes, self.config.policy);
        let n = g.tensors.len();
        let mut handles: Vec<Option<BufId>> = vec![None; n];
        let mut remaining = vec![0usize; n];
        for op in &g.ops {
            for &t in &op.inputs {
                remaining[t] += 1;
            }
        }
        let mut is_output = vec![false; n];
        for &t in &g.outputs {
            is_output[t] = true;
        }
        // Streaming join elision: a `PartialInto` writes its band through
        // its accumulator's buffer, so the handle is transferred instead
        // of allocating a second full-size buffer — this is what keeps
        // the measured high-water at the analytic 1×output floor.
        let acc_of = crate::sched::elided_accumulators(g);
        let mut captured: Vec<Option<TensorData>> = vec![None; n];

        // Stage graph inputs into the arena.
        for (&tid, data) in g.inputs.iter().zip(inputs) {
            let t = &g.tensors[tid];
            if data.dtype() != t.dtype || data.len() != t.elems() {
                return Err(ExecError::BadInput(format!(
                    "input tensor {} expects {}x{}, got {}x{}",
                    t.name,
                    t.elems(),
                    t.dtype.name(),
                    data.len(),
                    data.dtype().name()
                )));
            }
            let h = arena.alloc(t.bytes())?;
            arena.write(h, &data.to_bytes())?;
            handles[tid] = Some(h);
            if capture {
                captured[tid] = Some(data.clone());
            }
        }

        let traced = sink.enabled();
        let mut macs = 0u64;
        for (step, &opid) in order.iter().enumerate() {
            let op = &g.ops[opid];
            let out_t = &g.tensors[op.output];
            // Read inputs out of the arena (copies: handles may move under
            // compaction triggered by the output allocation below).
            let in_data: Vec<TensorData> = op
                .inputs
                .iter()
                .map(|&t| {
                    let bytes = arena.get(handles[t].expect("input not resident"))?;
                    Ok(TensorData::from_bytes(g.tensors[t].dtype, bytes))
                })
                .collect::<Result<_, AllocError>>()?;
            let out_h = match acc_of[opid] {
                // The accumulator dies at this step by construction (sole
                // consumer); its buffer becomes the output's.
                Some(acc) => handles[acc].take().expect("accumulator not resident"),
                None => arena.alloc(out_t.bytes())?,
            };
            handles[op.output] = Some(out_h);

            let out_data = self.dispatch(op, &in_data)?;
            debug_assert_eq!(out_data.len(), out_t.elems(), "op {} output size", op.name);
            arena.write(out_h, &out_data.to_bytes())?;
            if capture {
                captured[op.output] = Some(out_data);
            }
            macs += op.macs(g);

            // Reclaim dead inputs (an accumulator's handle was already
            // transferred to the output above).
            for &t in &op.inputs {
                remaining[t] -= 1;
                if remaining[t] == 0 && !is_output[t] {
                    if let Some(h) = handles[t].take() {
                        arena.free(h)?;
                    }
                }
            }
            if remaining[op.output] == 0 && !is_output[op.output] {
                arena.free(handles[op.output].take().unwrap())?;
            }
            arena.after_op();
            if traced {
                sink.record(Event::ArenaOp {
                    step,
                    op: opid,
                    name: op.name.clone(),
                    high_water: arena.stats().high_water,
                });
            }
        }

        let outputs: Vec<TensorData> = g
            .outputs
            .iter()
            .map(|&t| {
                let bytes = arena.get(handles[t].expect("output not resident"))?;
                Ok(TensorData::from_bytes(g.tensors[t].dtype, bytes))
            })
            .collect::<Result<_, AllocError>>()?;

        let result = RunResult { outputs, alloc: arena.stats().clone(), macs };
        Ok((result, capture.then_some(captured)))
    }

    fn qp(&self, t: TensorId) -> QuantParams {
        self.weights
            .qparams
            .get(&t)
            .copied()
            .unwrap_or(QuantParams { scale: 1.0, zero_point: 0 })
    }

    /// Evaluate one output band of a sliced operator (f32): the shared
    /// kernel dispatch behind both [`OpKind::Partial`] (whose output
    /// tensor *is* the band) and [`OpKind::PartialInto`] (which computes
    /// the band into a scratch slab before writing it through). Returns
    /// the fused activation for the caller to apply.
    #[allow(clippy::too_many_arguments)]
    fn partial_band_f32(
        &self,
        op: &crate::graph::Op,
        inner: &OpKind,
        axis: SplitAxis,
        pad: isize,
        offset: usize,
        x: &[f32],
        band_shape: &[usize],
        out: &mut [f32],
    ) -> Result<Act, ExecError> {
        let g = self.g;
        let in_shape = &g.tensors[op.inputs[0]].shape;
        match inner {
            OpKind::Conv2D { kernel, stride, padding, act } => {
                let ish = Hwc::from_shape(in_shape);
                let osh = Hwc::from_shape(band_shape);
                let (pad_y, pad_x) = partial_pads(axis, pad, ish, osh, *kernel, *stride, *padding);
                let (c0, c_total) = match axis {
                    SplitAxis::Channels => (offset, g.tensors[op.weights[0]].shape[3]),
                    _ => (0, osh.c),
                };
                ops::conv2d_with_pads(
                    x,
                    ish,
                    self.weights.f32_of(op.weights[0]),
                    self.weights.f32_of(op.weights[1]),
                    out,
                    osh,
                    *kernel,
                    *stride,
                    pad_y,
                    pad_x,
                    c0,
                    c_total,
                );
                Ok(*act)
            }
            OpKind::DepthwiseConv2D { kernel, stride, padding, act } => {
                let ish = Hwc::from_shape(in_shape);
                let osh = Hwc::from_shape(band_shape);
                let (pad_y, pad_x) = partial_pads(axis, pad, ish, osh, *kernel, *stride, *padding);
                let (c0, c_total) = match axis {
                    SplitAxis::Channels => (offset, g.tensors[op.weights[0]].shape[2]),
                    _ => (0, ish.c),
                };
                ops::dwconv2d_with_pads(
                    x,
                    ish,
                    self.weights.f32_of(op.weights[0]),
                    self.weights.f32_of(op.weights[1]),
                    out,
                    osh,
                    *kernel,
                    *stride,
                    pad_y,
                    pad_x,
                    c0,
                    c_total,
                );
                Ok(*act)
            }
            OpKind::MaxPool2D { kernel, stride, padding } => {
                let ish = Hwc::from_shape(in_shape);
                let osh = Hwc::from_shape(band_shape);
                let (pad_y, pad_x) = partial_pads(axis, pad, ish, osh, *kernel, *stride, *padding);
                ops::maxpool2d_with_pads(x, ish, out, osh, *kernel, *stride, pad_y, pad_x);
                Ok(Act::Linear)
            }
            OpKind::AvgPool2D { kernel, stride, padding } => {
                let ish = Hwc::from_shape(in_shape);
                let osh = Hwc::from_shape(band_shape);
                let (pad_y, pad_x) = partial_pads(axis, pad, ish, osh, *kernel, *stride, *padding);
                ops::avgpool2d_with_pads(x, ish, out, osh, *kernel, *stride, pad_y, pad_x);
                Ok(Act::Linear)
            }
            OpKind::Dense { act } => {
                let n_cols = g.tensors[op.weights[0]].shape[1];
                ops::dense_cols(
                    x,
                    self.weights.f32_of(op.weights[0]),
                    self.weights.f32_of(op.weights[1]),
                    out,
                    offset,
                    n_cols,
                );
                Ok(*act)
            }
            // Pointwise slices: the band maps 1:1 onto the slab; only
            // BatchNorm's per-channel parameters need the channel-band
            // offset.
            OpKind::Relu => {
                ops::relu(x, out);
                Ok(Act::Linear)
            }
            OpKind::Relu6 => {
                ops::relu6(x, out);
                Ok(Act::Linear)
            }
            OpKind::BatchNorm { eps } => {
                let gamma = self.weights.f32_of(op.weights[0]);
                let beta = self.weights.f32_of(op.weights[1]);
                let mean = self.weights.f32_of(op.weights[2]);
                let var = self.weights.f32_of(op.weights[3]);
                let c = band_shape.last().copied().unwrap_or(1);
                let c0 = if axis == SplitAxis::Channels { offset } else { 0 };
                for (i, v) in x.iter().enumerate() {
                    let ch = c0 + i % c;
                    out[i] = gamma[ch] * (v - mean[ch]) / (var[ch] + eps).sqrt() + beta[ch];
                }
                Ok(Act::Linear)
            }
            other => Err(ExecError::Unsupported(format!("partial {} (f32)", other.name()))),
        }
    }

    /// [`Self::partial_band_f32`] for the int8 path. The band is computed
    /// straight into the output quantization domain (`out_q`), so the
    /// write-through of a join-elided slice is a pure placement.
    #[allow(clippy::too_many_arguments)]
    fn partial_band_i8(
        &self,
        op: &crate::graph::Op,
        inner: &OpKind,
        axis: SplitAxis,
        pad: isize,
        offset: usize,
        x: &[i8],
        band_shape: &[usize],
        out_q: QuantParams,
        out: &mut [i8],
    ) -> Result<Act, ExecError> {
        let g = self.g;
        let in_shape = &g.tensors[op.inputs[0]].shape;
        match inner {
            OpKind::Conv2D { kernel, stride, padding, act } => {
                let ish = Hwc::from_shape(in_shape);
                let osh = Hwc::from_shape(band_shape);
                let (pad_y, pad_x) = partial_pads(axis, pad, ish, osh, *kernel, *stride, *padding);
                let (c0, c_total) = match axis {
                    SplitAxis::Channels => (offset, g.tensors[op.weights[0]].shape[3]),
                    _ => (0, osh.c),
                };
                quant::conv2d_i8_with_pads(
                    x,
                    ish,
                    self.qp(op.inputs[0]),
                    self.weights.i8_of(op.weights[0]),
                    self.qp(op.weights[0]).scale,
                    self.weights.i32_of(op.weights[1]),
                    out,
                    osh,
                    out_q,
                    *kernel,
                    *stride,
                    pad_y,
                    pad_x,
                    c0,
                    c_total,
                );
                Ok(*act)
            }
            OpKind::DepthwiseConv2D { kernel, stride, padding, act } => {
                let ish = Hwc::from_shape(in_shape);
                let osh = Hwc::from_shape(band_shape);
                let (pad_y, pad_x) = partial_pads(axis, pad, ish, osh, *kernel, *stride, *padding);
                let (c0, c_total) = match axis {
                    SplitAxis::Channels => (offset, g.tensors[op.weights[0]].shape[2]),
                    _ => (0, ish.c),
                };
                quant::dwconv2d_i8_with_pads(
                    x,
                    ish,
                    self.qp(op.inputs[0]),
                    self.weights.i8_of(op.weights[0]),
                    self.qp(op.weights[0]).scale,
                    self.weights.i32_of(op.weights[1]),
                    out,
                    osh,
                    out_q,
                    *kernel,
                    *stride,
                    pad_y,
                    pad_x,
                    c0,
                    c_total,
                );
                Ok(*act)
            }
            OpKind::MaxPool2D { kernel, stride, padding } => {
                let ish = Hwc::from_shape(in_shape);
                let osh = Hwc::from_shape(band_shape);
                let (pad_y, pad_x) = partial_pads(axis, pad, ish, osh, *kernel, *stride, *padding);
                quant::maxpool2d_i8_with_pads(x, ish, out, osh, *kernel, *stride, pad_y, pad_x);
                Ok(Act::Linear)
            }
            OpKind::Dense { act } => {
                let n_cols = g.tensors[op.weights[0]].shape[1];
                quant::dense_cols_i8(
                    x,
                    self.qp(op.inputs[0]),
                    self.weights.i8_of(op.weights[0]),
                    self.qp(op.weights[0]).scale,
                    self.weights.i32_of(op.weights[1]),
                    out,
                    out_q,
                    offset,
                    n_cols,
                );
                Ok(*act)
            }
            // Pointwise slices map 1:1 onto their slab (the slab shares
            // its source tensor's qparams).
            OpKind::Relu => {
                quant::relu_i8(x, self.qp(op.inputs[0]), out);
                Ok(Act::Linear)
            }
            OpKind::Relu6 => {
                quant::relu6_i8(x, self.qp(op.inputs[0]), out);
                Ok(Act::Linear)
            }
            other => Err(ExecError::Unsupported(format!("partial {} (i8)", other.name()))),
        }
    }

    fn dispatch(
        &self,
        op: &crate::graph::Op,
        inputs: &[TensorData],
    ) -> Result<TensorData, ExecError> {
        let g = self.g;
        let out_t = &g.tensors[op.output];
        let in0_t = op.inputs.first().map(|&t| &g.tensors[t]);

        match out_t.dtype {
            DType::F32 => {
                let xs: Vec<&[f32]> = inputs
                    .iter()
                    .map(|d| d.as_f32().ok_or_else(|| ExecError::BadInput("dtype mix".into())))
                    .collect::<Result<_, _>>()?;
                let mut out = vec![0.0f32; out_t.elems()];
                let mut fused_act = Act::Linear;
                match &op.kind {
                    OpKind::Conv2D { kernel, stride, padding, act } => {
                        fused_act = *act;
                        ops::conv2d(
                        xs[0],
                        Hwc::from_shape(&in0_t.unwrap().shape),
                        self.weights.f32_of(op.weights[0]),
                        self.weights.f32_of(op.weights[1]),
                        &mut out,
                        Hwc::from_shape(&out_t.shape),
                        *kernel,
                        *stride,
                        *padding,
                        )
                    }
                    OpKind::DepthwiseConv2D { kernel, stride, padding, act } => {
                        fused_act = *act;
                        ops::dwconv2d(
                        xs[0],
                        Hwc::from_shape(&in0_t.unwrap().shape),
                        self.weights.f32_of(op.weights[0]),
                        self.weights.f32_of(op.weights[1]),
                        &mut out,
                        Hwc::from_shape(&out_t.shape),
                        *kernel,
                        *stride,
                        *padding,
                        )
                    }
                    OpKind::Dense { act } => {
                        fused_act = *act;
                        ops::dense(
                            xs[0],
                            self.weights.f32_of(op.weights[0]),
                            self.weights.f32_of(op.weights[1]),
                            &mut out,
                        )
                    }
                    OpKind::Add => ops::add(xs[0], xs[1], &mut out),
                    OpKind::Concat => {
                        let parts: Vec<(&[f32], Hwc)> = op
                            .inputs
                            .iter()
                            .zip(&xs)
                            .map(|(&t, x)| (*x, Hwc::from_shape(&g.tensors[t].shape)))
                            .collect();
                        ops::concat_channels(&parts, &mut out, Hwc::from_shape(&out_t.shape));
                    }
                    OpKind::Relu => ops::relu(xs[0], &mut out),
                    OpKind::Relu6 => ops::relu6(xs[0], &mut out),
                    OpKind::MaxPool2D { kernel, stride, padding } => ops::maxpool2d(
                        xs[0],
                        Hwc::from_shape(&in0_t.unwrap().shape),
                        &mut out,
                        Hwc::from_shape(&out_t.shape),
                        *kernel,
                        *stride,
                        *padding,
                    ),
                    OpKind::AvgPool2D { kernel, stride, padding } => ops::avgpool2d(
                        xs[0],
                        Hwc::from_shape(&in0_t.unwrap().shape),
                        &mut out,
                        Hwc::from_shape(&out_t.shape),
                        *kernel,
                        *stride,
                        *padding,
                    ),
                    OpKind::GlobalAvgPool => ops::global_avgpool(
                        xs[0],
                        Hwc::from_shape(&in0_t.unwrap().shape),
                        &mut out,
                    ),
                    OpKind::Softmax => ops::softmax(xs[0], &mut out),
                    OpKind::BatchNorm { eps } => {
                        let gamma = self.weights.f32_of(op.weights[0]);
                        let beta = self.weights.f32_of(op.weights[1]);
                        let mean = self.weights.f32_of(op.weights[2]);
                        let var = self.weights.f32_of(op.weights[3]);
                        let c = gamma.len();
                        for (i, v) in xs[0].iter().enumerate() {
                            let ch = i % c;
                            out[i] = gamma[ch] * (v - mean[ch])
                                / (var[ch] + eps).sqrt()
                                + beta[ch];
                        }
                    }
                    OpKind::Reshape => out.copy_from_slice(xs[0]),
                    OpKind::Synthetic { .. } => {
                        return Err(ExecError::Unsupported("synthetic op with f32 dtype".into()))
                    }
                    OpKind::Partial { inner, axis, pad, offset } => {
                        fused_act = self.partial_band_f32(
                            op,
                            inner,
                            *axis,
                            *pad,
                            *offset,
                            xs[0],
                            &out_t.shape,
                            &mut out,
                        )?;
                    }
                    OpKind::PartialInto { inner, axis, pad, offset, len } => {
                        // Streaming join elision: carry the accumulator's
                        // content forward (the same buffer at run time —
                        // see `run_inner`), compute the band into a
                        // scratch slab, then write it through at `offset`.
                        // The full-buffer carry is a host-side
                        // simplification of this reference interpreter
                        // (dispatch is pure over copied inputs); a real
                        // MCU kernel writes only the band in place, which
                        // is what `Op::bytes_touched` and the cost model
                        // charge.
                        if let Some(acc) = xs.get(1) {
                            out.copy_from_slice(acc);
                        }
                        let band_shape = band_shape_of(&out_t.shape, *axis, *len);
                        let mut band = vec![0.0f32; band_shape.iter().product()];
                        let act = self.partial_band_f32(
                            op,
                            inner,
                            *axis,
                            *pad,
                            *offset,
                            xs[0],
                            &band_shape,
                            &mut band,
                        )?;
                        match act {
                            Act::Linear => {}
                            Act::Relu => {
                                for v in band.iter_mut() {
                                    *v = v.max(0.0);
                                }
                            }
                            Act::Relu6 => {
                                for v in band.iter_mut() {
                                    *v = v.clamp(0.0, 6.0);
                                }
                            }
                        }
                        ops::write_band(&band, &band_shape, &mut out, &out_t.shape, *axis, *offset);
                    }
                    OpKind::ConcatSlices { axis } => {
                        let parts: Vec<(&[f32], &[usize])> = op
                            .inputs
                            .iter()
                            .zip(&xs)
                            .map(|(&t, x)| (*x, g.tensors[t].shape.as_slice()))
                            .collect();
                        ops::concat_slices(&parts, &mut out, &out_t.shape, *axis);
                    }
                }
                match fused_act {
                    Act::Linear => {}
                    Act::Relu => {
                        for v in out.iter_mut() {
                            *v = v.max(0.0);
                        }
                    }
                    Act::Relu6 => {
                        for v in out.iter_mut() {
                            *v = v.clamp(0.0, 6.0);
                        }
                    }
                }
                Ok(TensorData::F32(out))
            }
            DType::I8 => {
                let xs: Vec<&[i8]> = inputs
                    .iter()
                    .map(|d| d.as_i8().ok_or_else(|| ExecError::BadInput("dtype mix".into())))
                    .collect::<Result<_, _>>()?;
                let mut out = vec![0i8; out_t.elems()];
                let out_q = self.qp(op.output);
                let mut fused_act = Act::Linear;
                match &op.kind {
                    OpKind::Conv2D { kernel, stride, padding, act } => {
                        fused_act = *act;
                        quant::conv2d_i8(
                        xs[0],
                        Hwc::from_shape(&in0_t.unwrap().shape),
                        self.qp(op.inputs[0]),
                        self.weights.i8_of(op.weights[0]),
                        self.qp(op.weights[0]).scale,
                        self.weights.i32_of(op.weights[1]),
                        &mut out,
                        Hwc::from_shape(&out_t.shape),
                        out_q,
                        *kernel,
                        *stride,
                        *padding,
                        )
                    }
                    OpKind::DepthwiseConv2D { kernel, stride, padding, act } => {
                        fused_act = *act;
                        quant::dwconv2d_i8(
                        xs[0],
                        Hwc::from_shape(&in0_t.unwrap().shape),
                        self.qp(op.inputs[0]),
                        self.weights.i8_of(op.weights[0]),
                        self.qp(op.weights[0]).scale,
                        self.weights.i32_of(op.weights[1]),
                        &mut out,
                        Hwc::from_shape(&out_t.shape),
                        out_q,
                        *kernel,
                        *stride,
                        *padding,
                        )
                    }
                    OpKind::Dense { act } => {
                        fused_act = *act;
                        quant::dense_i8(
                            xs[0],
                            self.qp(op.inputs[0]),
                            self.weights.i8_of(op.weights[0]),
                            self.qp(op.weights[0]).scale,
                            self.weights.i32_of(op.weights[1]),
                            &mut out,
                            out_q,
                        )
                    }
                    OpKind::Add => quant::add_i8(
                        xs[0],
                        self.qp(op.inputs[0]),
                        xs[1],
                        self.qp(op.inputs[1]),
                        &mut out,
                        out_q,
                    ),
                    OpKind::Concat => {
                        // Requantize each part into the output domain.
                        let mut c_off = 0usize;
                        let oshape = Hwc::from_shape(&out_t.shape);
                        for (&t, x) in op.inputs.iter().zip(&xs) {
                            let ishape = Hwc::from_shape(&g.tensors[t].shape);
                            let iq = self.qp(t);
                            for y in 0..ishape.h {
                                for xw in 0..ishape.w {
                                    for ch in 0..ishape.c {
                                        let v = iq.dequantize_one(x[ishape.at(y, xw, ch)]);
                                        out[oshape.at(y, xw, c_off + ch)] = out_q.quantize_one(v);
                                    }
                                }
                            }
                            c_off += ishape.c;
                        }
                    }
                    OpKind::Relu => quant::relu_i8(xs[0], self.qp(op.inputs[0]), &mut out),
                    OpKind::Relu6 => quant::relu6_i8(xs[0], self.qp(op.inputs[0]), &mut out),
                    OpKind::MaxPool2D { kernel, stride, padding } => quant::maxpool2d_i8(
                        xs[0],
                        Hwc::from_shape(&in0_t.unwrap().shape),
                        &mut out,
                        Hwc::from_shape(&out_t.shape),
                        *kernel,
                        *stride,
                        *padding,
                    ),
                    OpKind::AvgPool2D { .. } => {
                        return Err(ExecError::Unsupported("i8 avgpool (unused in zoo)".into()))
                    }
                    OpKind::GlobalAvgPool => quant::global_avgpool_i8(
                        xs[0],
                        Hwc::from_shape(&in0_t.unwrap().shape),
                        self.qp(op.inputs[0]),
                        &mut out,
                    ),
                    OpKind::Softmax => quant::softmax_i8(xs[0], self.qp(op.inputs[0]), &mut out),
                    OpKind::BatchNorm { .. } => {
                        return Err(ExecError::Unsupported(
                            "i8 batchnorm (fold it first; see graph::transform)".into(),
                        ))
                    }
                    OpKind::Reshape => out.copy_from_slice(xs[0]),
                    OpKind::Synthetic { .. } => {
                        return Err(ExecError::Unsupported("synthetic op with i8 dtype".into()))
                    }
                    OpKind::Partial { inner, axis, pad, offset } => {
                        fused_act = self.partial_band_i8(
                            op,
                            inner,
                            *axis,
                            *pad,
                            *offset,
                            xs[0],
                            &out_t.shape,
                            out_q,
                            &mut out,
                        )?;
                    }
                    OpKind::PartialInto { inner, axis, pad, offset, len } => {
                        // Streaming join elision (see the f32 arm). The
                        // accumulator shares the output's qparams (both are
                        // bands of the same join tensor), so carrying it
                        // forward is a pure copy — bit-exact.
                        if let Some(acc) = xs.get(1) {
                            out.copy_from_slice(acc);
                        }
                        let band_shape = band_shape_of(&out_t.shape, *axis, *len);
                        let mut band = vec![0i8; band_shape.iter().product()];
                        let act = self.partial_band_i8(
                            op,
                            inner,
                            *axis,
                            *pad,
                            *offset,
                            xs[0],
                            &band_shape,
                            out_q,
                            &mut band,
                        )?;
                        match act {
                            Act::Linear => {}
                            Act::Relu => {
                                let lo = out_q.zero_point.clamp(-128, 127) as i8;
                                for v in band.iter_mut() {
                                    *v = (*v).max(lo);
                                }
                            }
                            Act::Relu6 => {
                                let lo = out_q.zero_point.clamp(-128, 127) as i8;
                                let hi = out_q.quantize_one(6.0).max(lo);
                                for v in band.iter_mut() {
                                    *v = (*v).clamp(lo, hi);
                                }
                            }
                        }
                        ops::write_band(&band, &band_shape, &mut out, &out_t.shape, *axis, *offset);
                    }
                    // The split subsystem gives every slab the qparams of
                    // the tensor it is a band of, so the join is a pure
                    // copy — no requantization, bit-exact.
                    OpKind::ConcatSlices { axis } => {
                        let parts: Vec<(&[i8], &[usize])> = op
                            .inputs
                            .iter()
                            .zip(&xs)
                            .map(|(&t, x)| (*x, g.tensors[t].shape.as_slice()))
                            .collect();
                        ops::concat_slices(&parts, &mut out, &out_t.shape, *axis);
                    }
                }
                match fused_act {
                    Act::Linear => {}
                    Act::Relu => {
                        let lo = out_q.zero_point.clamp(-128, 127) as i8;
                        for v in out.iter_mut() {
                            *v = (*v).max(lo);
                        }
                    }
                    Act::Relu6 => {
                        let lo = out_q.zero_point.clamp(-128, 127) as i8;
                        let hi = out_q.quantize_one(6.0).max(lo);
                        for v in out.iter_mut() {
                            *v = (*v).clamp(lo, hi);
                        }
                    }
                }
                Ok(TensorData::I8(out))
            }
            DType::U8 => {
                // Synthetic byte-mixing ops (generated DAGs).
                let xs: Vec<&[u8]> = inputs
                    .iter()
                    .map(|d| match d {
                        TensorData::U8(v) => Ok(v.as_slice()),
                        _ => Err(ExecError::BadInput("synthetic op expects u8".into())),
                    })
                    .collect::<Result<_, _>>()?;
                let mut out = vec![0u8; out_t.elems()];
                ops::synthetic_bytes(&xs, &mut out);
                Ok(TensorData::U8(out))
            }
            DType::I32 => Err(ExecError::Unsupported("i32 activations".into())),
        }
    }
}

/// Calibration: run the f32 interpreter on `inputs` and record per-tensor
/// (min, max) ranges by tensor name.
pub fn calibrate(
    g_f32: &Graph,
    ws_f32: &WeightStore,
    inputs: &[TensorData],
    arena_bytes: usize,
) -> Result<HashMap<String, (f32, f32)>, ExecError> {
    let interp = Interpreter::new(g_f32, ws_f32.clone(), ExecConfig::with_capacity(arena_bytes));
    let (_, captured) = interp.run_capture(inputs)?;
    let mut ranges = HashMap::new();
    for (tid, data) in captured.iter().enumerate() {
        if let Some(TensorData::F32(vals)) = data {
            let lo = vals.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            if lo.is_finite() && hi.is_finite() {
                ranges.insert(g_f32.tensors[tid].name.clone(), (lo, hi));
            }
        }
    }
    Ok(ranges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Act, GraphBuilder, Padding};

    /// Small branchy f32 CNN used across the interpreter tests.
    fn tiny_cnn(dtype: DType) -> Graph {
        let mut b = GraphBuilder::new("tiny");
        let x = b.input("x", &[1, 8, 8, 2], dtype);
        let c1 = b.conv2d("c1", x, 4, (3, 3), (1, 1), Padding::Same, Act::Relu6);
        let r1 = b.relu("r1", c1);
        let dw = b.dwconv2d("dw", r1, (3, 3), (2, 2), Padding::Same, Act::Relu6);
        let pw = b.conv2d("pw", r1, 4, (1, 1), (2, 2), Padding::Same, Act::Relu6);
        let cat = b.concat("cat", &[dw, pw]);
        let gap = b.global_avgpool("gap", cat);
        let fc = b.dense("fc", gap, 3, Act::Linear);
        let sm = b.softmax("sm", fc);
        b.output(sm);
        b.finish().unwrap()
    }

    fn ramp_input(n: usize) -> TensorData {
        TensorData::F32((0..n).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect())
    }

    #[test]
    fn f32_run_produces_probabilities() {
        let g = tiny_cnn(DType::F32);
        let ws = WeightStore::seeded_f32(&g, 42);
        let interp = Interpreter::new(&g, ws, ExecConfig::with_capacity(64 * 1024));
        let r = interp.run(&[ramp_input(128)]).unwrap();
        let probs = r.outputs[0].as_f32().unwrap();
        assert_eq!(probs.len(), 3);
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(r.macs > 0);
        assert!(r.alloc.high_water > 0);
    }

    #[test]
    fn runs_agree_across_execution_orders() {
        let g = tiny_cnn(DType::F32);
        let ws = WeightStore::seeded_f32(&g, 42);
        let input = ramp_input(128);

        let default = Interpreter::new(&g, ws.clone(), ExecConfig::with_capacity(64 * 1024))
            .run(&[input.clone()])
            .unwrap();
        let (sched, _) = crate::sched::optimal(&g).unwrap();
        let cfg = ExecConfig {
            arena_bytes: 64 * 1024,
            policy: CompactPolicy::EveryOp,
            order: Some(sched.order.clone()),
        };
        let optimal = Interpreter::new(&g, ws, cfg).run(&[input]).unwrap();
        assert_eq!(default.outputs, optimal.outputs, "reordering must not change outputs");
        assert!(optimal.alloc.high_water <= default.alloc.high_water);
    }

    #[test]
    fn arena_high_water_matches_analytic_peak() {
        let g = tiny_cnn(DType::F32);
        let ws = WeightStore::seeded_f32(&g, 7);
        let interp = Interpreter::new(&g, ws, ExecConfig::with_capacity(256 * 1024));
        let r = interp.run(&[ramp_input(128)]).unwrap();
        let peak = crate::sched::peak_of(&g, &g.default_order());
        assert_eq!(r.alloc.high_water, peak);
    }

    #[test]
    fn insufficient_arena_fails_cleanly() {
        let g = tiny_cnn(DType::F32);
        let ws = WeightStore::seeded_f32(&g, 7);
        let peak = crate::sched::peak_of(&g, &g.default_order());
        let interp = Interpreter::new(&g, ws, ExecConfig::with_capacity(peak - 1));
        match interp.run(&[ramp_input(128)]) {
            Err(ExecError::Alloc(_)) => {}
            other => panic!("expected alloc failure, got {other:?}"),
        }
    }

    #[test]
    fn exact_arena_capacity_suffices() {
        let g = tiny_cnn(DType::F32);
        let ws = WeightStore::seeded_f32(&g, 7);
        let peak = crate::sched::peak_of(&g, &g.default_order());
        let interp = Interpreter::new(&g, ws, ExecConfig::with_capacity(peak));
        interp.run(&[ramp_input(128)]).unwrap();
    }

    #[test]
    fn i8_path_tracks_f32_path() {
        let g_f32 = tiny_cnn(DType::F32);
        let ws_f32 = WeightStore::seeded_f32(&g_f32, 42);
        let input_f = ramp_input(128);
        let ranges = calibrate(&g_f32, &ws_f32, &[input_f.clone()], 256 * 1024).unwrap();
        let f32_out =
            Interpreter::new(&g_f32, ws_f32.clone(), ExecConfig::with_capacity(256 * 1024))
                .run(&[input_f.clone()])
                .unwrap();

        let g_i8 = tiny_cnn(DType::I8);
        let ws_i8 = WeightStore::quantize_from(&g_i8, &ws_f32, &ranges);
        let in_q = ws_i8.qparams[&g_i8.inputs[0]];
        let input_q = TensorData::I8(in_q.quantize(input_f.as_f32().unwrap()));
        let i8_out = Interpreter::new(&g_i8, ws_i8.clone(), ExecConfig::with_capacity(256 * 1024))
            .run(&[input_q])
            .unwrap();

        let probs_f = f32_out.outputs[0].as_f32().unwrap();
        let probs_q = quant::softmax_out_qparams().dequantize(i8_out.outputs[0].as_i8().unwrap());
        // Argmax agreement (when the f32 margin is decisive) + coarse
        // numeric agreement.
        let argmax = |v: &[f32]| {
            v.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
        };
        let mut sorted = probs_f.to_vec();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        if sorted[0] - sorted[1] > 0.1 {
            assert_eq!(argmax(probs_f), argmax(&probs_q));
        }
        for (a, b) in probs_f.iter().zip(&probs_q) {
            assert!((a - b).abs() < 0.15, "f32={a} i8={b}");
        }
    }

    #[test]
    fn i8_arena_is_quarter_of_f32() {
        let g_f32 = tiny_cnn(DType::F32);
        let g_i8 = tiny_cnn(DType::I8);
        let p_f = crate::sched::peak_of(&g_f32, &g_f32.default_order());
        let p_q = crate::sched::peak_of(&g_i8, &g_i8.default_order());
        assert_eq!(p_f, 4 * p_q);
    }

    #[test]
    fn rejects_wrong_input_shape() {
        let g = tiny_cnn(DType::F32);
        let ws = WeightStore::seeded_f32(&g, 7);
        let interp = Interpreter::new(&g, ws, ExecConfig::with_capacity(64 * 1024));
        match interp.run(&[TensorData::F32(vec![0.0; 10])]) {
            Err(ExecError::BadInput(_)) => {}
            other => panic!("expected BadInput, got {other:?}"),
        }
    }

    #[test]
    fn synthetic_graph_executes_deterministically() {
        let g = crate::sched::tests::figure1_graph();
        let ws = WeightStore::default();
        let input = TensorData::U8((0..1568).map(|i| (i % 251) as u8).collect());
        let cfg = ExecConfig::with_capacity(16 * 1024);
        let a = Interpreter::new(&g, ws.clone(), cfg.clone()).run(&[input.clone()]).unwrap();
        // Optimal order must produce identical bytes.
        let (sched, _) = crate::sched::optimal(&g).unwrap();
        let cfg2 = ExecConfig { order: Some(sched.order), ..cfg };
        let b = Interpreter::new(&g, ws, cfg2).run(&[input]).unwrap();
        assert_eq!(a.outputs, b.outputs);
        assert_eq!(a.alloc.high_water, 5216);
        assert_eq!(b.alloc.high_water, 4960);
    }

    /// Fused-activation graphs vs their de-fused twins (`Conv2D`+`Relu6`
    /// as separate ops — what the TFLite importer produces) must agree
    /// bit-exactly. This is the importer's de-fusing contract: the
    /// pre-activation tensor carries the output's quantization, so the
    /// clamp commutes with requantization.
    fn act_pair(dtype: DType, h: usize, w: usize, stride: usize, act: Act) -> (Graph, Graph) {
        let build = |defused: bool| {
            let mut b = GraphBuilder::new("pair");
            let x = b.input("x", &[1, h, w, 3], dtype);
            let (conv_act, dw_act) = if defused { (Act::Linear, Act::Linear) } else { (act, act) };
            let mut c = b.conv2d("c", x, 4, (3, 3), (stride, stride), Padding::Same, conv_act);
            if defused {
                c = match act {
                    Act::Relu => b.relu("c.act", c),
                    Act::Relu6 => b.relu6("c.act", c),
                    Act::Linear => c,
                };
            }
            let mut d = b.dwconv2d("d", c, (3, 3), (1, 1), Padding::Same, dw_act);
            if defused {
                d = match act {
                    Act::Relu => b.relu("d.act", d),
                    Act::Relu6 => b.relu6("d.act", d),
                    Act::Linear => d,
                };
            }
            let gap = b.global_avgpool("gap", d);
            let mut f = b.dense("f", gap, 3, if defused { Act::Linear } else { act });
            if defused {
                f = match act {
                    Act::Relu => b.relu("f.act", f),
                    Act::Relu6 => b.relu6("f.act", f),
                    Act::Linear => f,
                };
            }
            b.output(f);
            b.finish().unwrap()
        };
        (build(false), build(true))
    }

    fn pair_input(h: usize, w: usize) -> TensorData {
        TensorData::F32((0..h * w * 3).map(|i| ((i % 23) as f32 - 11.0) / 4.0).collect())
    }

    #[test]
    fn defused_activations_match_fused_f32_bit_exact() {
        // Odd sizes and stride 2 under SAME padding — the geometry the
        // importer's de-fusing has to survive unchanged.
        for (h, w, stride) in [(5, 7, 1), (9, 5, 2), (8, 8, 2)] {
            for act in [Act::Relu, Act::Relu6] {
                let (fused, defused) = act_pair(DType::F32, h, w, stride, act);
                // Identical weight streams: same weight-tensor order/shapes.
                let ws_f = WeightStore::seeded_f32(&fused, 11);
                let ws_d = WeightStore::seeded_f32(&defused, 11);
                let cfg = ExecConfig::with_capacity(1 << 20);
                let a = Interpreter::new(&fused, ws_f, cfg.clone())
                    .run(&[pair_input(h, w)])
                    .unwrap();
                let b = Interpreter::new(&defused, ws_d, cfg).run(&[pair_input(h, w)]).unwrap();
                assert_eq!(
                    a.outputs, b.outputs,
                    "f32 {h}x{w} s{stride} {act:?}: de-fused graph diverged"
                );
            }
        }
    }

    #[test]
    fn defused_activations_match_fused_i8_bit_exact() {
        for (h, w, stride) in [(5, 7, 1), (9, 5, 2), (8, 8, 2)] {
            for act in [Act::Relu, Act::Relu6] {
                let (fused_f32, defused_f32) = act_pair(DType::F32, h, w, stride, act);
                let (fused, defused) = act_pair(DType::I8, h, w, stride, act);
                // Seed per structure: weight-tensor *ids* differ between
                // the twins (extra act ops shift them) but the rng stream
                // only advances on weight tensors, so the values coincide.
                let ws_f32_f = WeightStore::seeded_f32(&fused_f32, 11);
                let ws_f32_d = WeightStore::seeded_f32(&defused_f32, 11);
                // Shared calibration ranges; the de-fused intermediate
                // ("c"/"d"/"f") carries the same range as the fused output,
                // and the act output ("c.act"…) shares it — the contract.
                let mut ranges = HashMap::new();
                for (name, lo, hi) in [
                    ("x", -3.0f32, 3.0f32),
                    ("c", -4.0, 4.0),
                    ("c.act", -4.0, 4.0),
                    ("d", -8.0, 8.0),
                    ("d.act", -8.0, 8.0),
                    ("gap", -8.0, 8.0),
                    ("f", -6.0, 6.0),
                    ("f.act", -6.0, 6.0),
                ] {
                    ranges.insert(name.to_string(), (lo, hi));
                }
                let ws_q_f = WeightStore::quantize_from(&fused, &ws_f32_f, &ranges);
                let ws_q_d = WeightStore::quantize_from(&defused, &ws_f32_d, &ranges);
                let in_q = ws_q_f.qparams[&fused.inputs[0]];
                assert_eq!(in_q, ws_q_d.qparams[&defused.inputs[0]]);
                let input = TensorData::I8(in_q.quantize(pair_input(h, w).as_f32().unwrap()));
                let cfg = ExecConfig::with_capacity(1 << 20);
                let a = Interpreter::new(&fused, ws_q_f, cfg.clone())
                    .run(&[input.clone()])
                    .unwrap();
                let b = Interpreter::new(&defused, ws_q_d, cfg).run(&[input]).unwrap();
                assert_eq!(
                    a.outputs, b.outputs,
                    "i8 {h}x{w} s{stride} {act:?}: de-fused graph diverged"
                );
            }
        }
    }

    #[test]
    fn tensordata_byte_roundtrip() {
        let f = TensorData::F32(vec![1.5, -2.25, 0.0]);
        assert_eq!(TensorData::from_bytes(DType::F32, &f.to_bytes()), f);
        let q = TensorData::I8(vec![-128, 0, 127]);
        assert_eq!(TensorData::from_bytes(DType::I8, &q.to_bytes()), q);
        let i = TensorData::I32(vec![i32::MIN, 7, i32::MAX]);
        assert_eq!(TensorData::from_bytes(DType::I32, &i.to_bytes()), i);
    }
}

//! Reference f32 kernels (NHWC, batch 1).
//!
//! These are the micro-interpreter's operator implementations — scalar
//! loops written for clarity and bit-level determinism, matching TFLite
//! reference-kernel semantics (SAME padding split low/high like
//! TensorFlow). They double as the ground truth the PJRT-executed HLO
//! artifacts are compared against in integration tests.

use crate::graph::{Padding, SplitAxis};

/// NHWC activation shape (N fixed at 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Hwc {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl Hwc {
    pub fn from_shape(shape: &[usize]) -> Hwc {
        assert_eq!(shape.len(), 4, "expected NHWC, got {shape:?}");
        assert_eq!(shape[0], 1, "batch must be 1");
        Hwc { h: shape[1], w: shape[2], c: shape[3] }
    }

    pub fn elems(&self) -> usize {
        self.h * self.w * self.c
    }

    #[inline]
    pub fn at(&self, y: usize, x: usize, ch: usize) -> usize {
        (y * self.w + x) * self.c + ch
    }
}

/// TensorFlow SAME padding: total = max((out-1)*stride + k - in, 0),
/// low half first.
pub fn pad_amounts(input: usize, k: usize, stride: usize, padding: Padding, out: usize) -> usize {
    match padding {
        Padding::Valid => 0,
        Padding::Same => {
            let total = ((out - 1) * stride + k).saturating_sub(input);
            total / 2
        }
    }
}

/// Standard 2D convolution. `weights` layout HWIO `[kh,kw,cin,cout]`,
/// `bias` length `cout`.
#[allow(clippy::too_many_arguments)]
pub fn conv2d(
    input: &[f32],
    in_shape: Hwc,
    weights: &[f32],
    bias: &[f32],
    out: &mut [f32],
    out_shape: Hwc,
    kernel: (usize, usize),
    stride: (usize, usize),
    padding: Padding,
) {
    let pad_y = pad_amounts(in_shape.h, kernel.0, stride.0, padding, out_shape.h) as isize;
    let pad_x = pad_amounts(in_shape.w, kernel.1, stride.1, padding, out_shape.w) as isize;
    conv2d_with_pads(
        input,
        in_shape,
        weights,
        bias,
        out,
        out_shape,
        kernel,
        stride,
        pad_y,
        pad_x,
        0,
        out_shape.c,
    );
}

/// [`conv2d`] with explicit padding offsets instead of a [`Padding`] mode.
/// Out-of-bounds taps are skipped (zero padding). A negative `pad_y` shifts
/// the tap window *down* into the input — how the split subsystem evaluates
/// an output band against a taller input slab.
///
/// The output channel band `[c0, c0 + out_shape.c)` is computed against
/// the *full* weight tensor `[kh, kw, cin, cout_total]` and full bias —
/// how a channel slice reads only its weight columns. Whole-tensor calls
/// pass `c0 = 0, cout_total = out_shape.c`. Per-channel accumulation
/// order is identical to the full kernel, so bands are bit-exact.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_with_pads(
    input: &[f32],
    in_shape: Hwc,
    weights: &[f32],
    bias: &[f32],
    out: &mut [f32],
    out_shape: Hwc,
    kernel: (usize, usize),
    stride: (usize, usize),
    pad_y: isize,
    pad_x: isize,
    c0: usize,
    cout_total: usize,
) {
    let (kh, kw) = kernel;
    let (sh, sw) = stride;
    let cin = in_shape.c;
    let cout = out_shape.c;
    debug_assert_eq!(input.len(), in_shape.elems());
    debug_assert_eq!(weights.len(), kh * kw * cin * cout_total);
    debug_assert_eq!(bias.len(), cout_total);
    debug_assert!(c0 + cout <= cout_total);
    debug_assert_eq!(out.len(), out_shape.elems());

    // Perf pass (mirrors the i8 kernels): accumulator row per output pixel,
    // contiguous weight rows in the innermost loop.
    let mut acc_row = vec![0.0f32; cout];
    for oy in 0..out_shape.h {
        for ox in 0..out_shape.w {
            acc_row.copy_from_slice(&bias[c0..c0 + cout]);
            for ky in 0..kh {
                let iy = (oy * sh + ky) as isize - pad_y;
                if iy < 0 || iy as usize >= in_shape.h {
                    continue;
                }
                for kx in 0..kw {
                    let ix = (ox * sw + kx) as isize - pad_x;
                    if ix < 0 || ix as usize >= in_shape.w {
                        continue;
                    }
                    let ibase = in_shape.at(iy as usize, ix as usize, 0);
                    let wbase = ((ky * kw + kx) * cin) * cout_total + c0;
                    for ic in 0..cin {
                        let iv = input[ibase + ic];
                        let wrow = &weights[wbase + ic * cout_total..][..cout];
                        for (a, &w) in acc_row.iter_mut().zip(wrow) {
                            *a += iv * w;
                        }
                    }
                }
            }
            let obase = out_shape.at(oy, ox, 0);
            out[obase..obase + cout].copy_from_slice(&acc_row);
        }
    }
}

/// Depthwise 2D convolution (multiplier 1). `weights` layout `[kh,kw,c]`,
/// `bias` length `c`.
#[allow(clippy::too_many_arguments)]
pub fn dwconv2d(
    input: &[f32],
    in_shape: Hwc,
    weights: &[f32],
    bias: &[f32],
    out: &mut [f32],
    out_shape: Hwc,
    kernel: (usize, usize),
    stride: (usize, usize),
    padding: Padding,
) {
    let pad_y = pad_amounts(in_shape.h, kernel.0, stride.0, padding, out_shape.h) as isize;
    let pad_x = pad_amounts(in_shape.w, kernel.1, stride.1, padding, out_shape.w) as isize;
    dwconv2d_with_pads(
        input,
        in_shape,
        weights,
        bias,
        out,
        out_shape,
        kernel,
        stride,
        pad_y,
        pad_x,
        0,
        in_shape.c,
    );
}

/// [`dwconv2d`] with explicit padding offsets (see [`conv2d_with_pads`]).
/// The channel band `[c0, c0 + in_shape.c)` runs against the full
/// `[kh, kw, c_total]` weights and full bias — depthwise channels are
/// independent, so a channel slab (input channels already banded) uses
/// only its own weight columns. Whole-tensor calls pass
/// `c0 = 0, c_total = in_shape.c`.
#[allow(clippy::too_many_arguments)]
pub fn dwconv2d_with_pads(
    input: &[f32],
    in_shape: Hwc,
    weights: &[f32],
    bias: &[f32],
    out: &mut [f32],
    out_shape: Hwc,
    kernel: (usize, usize),
    stride: (usize, usize),
    pad_y: isize,
    pad_x: isize,
    c0: usize,
    c_total: usize,
) {
    let (kh, kw) = kernel;
    let (sh, sw) = stride;
    let c = in_shape.c;
    debug_assert_eq!(out_shape.c, c);
    debug_assert_eq!(weights.len(), kh * kw * c_total);
    debug_assert_eq!(bias.len(), c_total);
    debug_assert!(c0 + c <= c_total);

    // Channels innermost: contiguous input and weight rows (perf pass).
    let mut acc_row = vec![0.0f32; c];
    for oy in 0..out_shape.h {
        for ox in 0..out_shape.w {
            acc_row.copy_from_slice(&bias[c0..c0 + c]);
            for ky in 0..kh {
                let iy = (oy * sh + ky) as isize - pad_y;
                if iy < 0 || iy as usize >= in_shape.h {
                    continue;
                }
                for kx in 0..kw {
                    let ix = (ox * sw + kx) as isize - pad_x;
                    if ix < 0 || ix as usize >= in_shape.w {
                        continue;
                    }
                    let ibase = in_shape.at(iy as usize, ix as usize, 0);
                    let irow = &input[ibase..ibase + c];
                    let wrow = &weights[(ky * kw + kx) * c_total + c0..][..c];
                    for ((a, &iv), &w) in acc_row.iter_mut().zip(irow).zip(wrow) {
                        *a += iv * w;
                    }
                }
            }
            let obase = out_shape.at(oy, ox, 0);
            out[obase..obase + c].copy_from_slice(&acc_row);
        }
    }
}

/// Fully connected: `weights` layout `[in, out]` (row-major), bias `[out]`.
pub fn dense(input: &[f32], weights: &[f32], bias: &[f32], out: &mut [f32]) {
    let n_out = out.len();
    dense_cols(input, weights, bias, out, 0, n_out);
}

/// Output-feature band of a fully-connected layer: computes features
/// `[col0, col0 + out.len())` of a dense layer whose full weight matrix is
/// `[in, n_cols]` row-major with a full-length bias. The accumulation order
/// per feature matches [`dense`] exactly, so bands are bit-identical to the
/// corresponding slice of the full output.
pub fn dense_cols(
    input: &[f32],
    weights: &[f32],
    bias: &[f32],
    out: &mut [f32],
    col0: usize,
    n_cols: usize,
) {
    let n_in = input.len();
    let n_out = out.len();
    debug_assert!(col0 + n_out <= n_cols, "band [{col0}, {}) exceeds {n_cols}", col0 + n_out);
    debug_assert_eq!(weights.len(), n_in * n_cols);
    debug_assert_eq!(bias.len(), n_cols);
    for o in 0..n_out {
        let mut acc = bias[col0 + o];
        for i in 0..n_in {
            acc += input[i] * weights[i * n_cols + col0 + o];
        }
        out[o] = acc;
    }
}

/// Elementwise addition.
pub fn add(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = a[i] + b[i];
    }
}

/// Channel-axis concat of equally-shaped-spatially inputs.
pub fn concat_channels(parts: &[(&[f32], Hwc)], out: &mut [f32], out_shape: Hwc) {
    debug_assert_eq!(out.len(), out_shape.elems());
    let mut c_off = 0usize;
    for (data, shape) in parts {
        debug_assert_eq!(shape.h, out_shape.h);
        debug_assert_eq!(shape.w, out_shape.w);
        for y in 0..shape.h {
            for x in 0..shape.w {
                let src = shape.at(y, x, 0);
                let dst = out_shape.at(y, x, c_off);
                out[dst..dst + shape.c].copy_from_slice(&data[src..src + shape.c]);
            }
        }
        c_off += shape.c;
    }
    debug_assert_eq!(c_off, out_shape.c);
}

/// Join the slabs of a split back into the full tensor along `axis`
/// (see [`crate::graph::OpKind::ConcatSlices`]). Works for any element
/// type because the join is a pure copy — the split subsystem gives every
/// slab the quantization of the tensor it is a band of, so no
/// requantization happens here (bit-exact for i8).
///
/// `parts` pairs each slab's data with its tensor shape. Non-NHWC shapes
/// (the 2-D `[1, n]` bands of a split `Dense`) degenerate to a flat
/// append, as do row slabs (contiguous bands of NHWC storage).
pub fn concat_slices<T: Copy>(
    parts: &[(&[T], &[usize])],
    out: &mut [T],
    out_shape: &[usize],
    axis: SplitAxis,
) {
    let flat = out_shape.len() != 4 || axis == SplitAxis::Rows;
    if flat {
        let mut cursor = 0usize;
        for (data, _) in parts {
            out[cursor..cursor + data.len()].copy_from_slice(data);
            cursor += data.len();
        }
        debug_assert_eq!(cursor, out.len(), "concat-slices size mismatch");
        return;
    }
    let (h, w, c) = (out_shape[1], out_shape[2], out_shape[3]);
    match axis {
        SplitAxis::Rows => unreachable!("handled by the flat path"),
        SplitAxis::Cols => {
            // Column slabs interleave per output row.
            for y in 0..h {
                let mut x_off = 0usize;
                for (data, shape) in parts {
                    let (wj, cj) = (shape[2], shape[3]);
                    debug_assert_eq!(cj, c);
                    let src = y * wj * cj;
                    let dst = (y * w + x_off) * c;
                    out[dst..dst + wj * cj].copy_from_slice(&data[src..src + wj * cj]);
                    x_off += wj;
                }
                debug_assert_eq!(x_off, w);
            }
        }
        SplitAxis::Channels => {
            // Channel slabs interleave per output pixel.
            for y in 0..h {
                for x in 0..w {
                    let mut c_off = 0usize;
                    for (data, shape) in parts {
                        let (wj, cj) = (shape[2], shape[3]);
                        debug_assert_eq!(wj, w);
                        let src = (y * wj + x) * cj;
                        let dst = (y * w + x) * c + c_off;
                        out[dst..dst + cj].copy_from_slice(&data[src..src + cj]);
                        c_off += cj;
                    }
                    debug_assert_eq!(c_off, c);
                }
            }
        }
    }
}

/// Write one band into the full tensor at `offset` along `axis` — the
/// write-through half of a join-elided slice (see
/// [`crate::graph::OpKind::PartialInto`]). Placement mirrors
/// [`concat_slices`] exactly (a chain of `write_band`s over a partition
/// reproduces the concat bit-for-bit); like the join it is a pure
/// placement, element type agnostic, no requantization.
pub fn write_band<T: Copy>(
    src: &[T],
    src_shape: &[usize],
    dst: &mut [T],
    dst_shape: &[usize],
    axis: SplitAxis,
    offset: usize,
) {
    if dst_shape.len() != 4 {
        // 2-D `[1, n]` bands of a split `Dense`: contiguous at `offset`.
        dst[offset..offset + src.len()].copy_from_slice(src);
        return;
    }
    let (h, w, c) = (dst_shape[1], dst_shape[2], dst_shape[3]);
    match axis {
        SplitAxis::Rows => {
            // Row bands are contiguous in NHWC storage.
            let start = offset * w * c;
            dst[start..start + src.len()].copy_from_slice(src);
        }
        SplitAxis::Cols => {
            let (wj, cj) = (src_shape[2], src_shape[3]);
            debug_assert_eq!(cj, c);
            for y in 0..h {
                let s = y * wj * cj;
                let d = (y * w + offset) * c;
                dst[d..d + wj * cj].copy_from_slice(&src[s..s + wj * cj]);
            }
        }
        SplitAxis::Channels => {
            let (wj, cj) = (src_shape[2], src_shape[3]);
            debug_assert_eq!(wj, w);
            for y in 0..h {
                for x in 0..w {
                    let s = (y * wj + x) * cj;
                    let d = (y * w + x) * c + offset;
                    dst[d..d + cj].copy_from_slice(&src[s..s + cj]);
                }
            }
        }
    }
}

/// ReLU.
pub fn relu(input: &[f32], out: &mut [f32]) {
    for i in 0..input.len() {
        out[i] = input[i].max(0.0);
    }
}

/// ReLU6.
pub fn relu6(input: &[f32], out: &mut [f32]) {
    for i in 0..input.len() {
        out[i] = input[i].clamp(0.0, 6.0);
    }
}

/// 2D max pooling.
#[allow(clippy::too_many_arguments)]
pub fn maxpool2d(
    input: &[f32],
    in_shape: Hwc,
    out: &mut [f32],
    out_shape: Hwc,
    kernel: (usize, usize),
    stride: (usize, usize),
    padding: Padding,
) {
    let pad_y = pad_amounts(in_shape.h, kernel.0, stride.0, padding, out_shape.h) as isize;
    let pad_x = pad_amounts(in_shape.w, kernel.1, stride.1, padding, out_shape.w) as isize;
    maxpool2d_with_pads(input, in_shape, out, out_shape, kernel, stride, pad_y, pad_x);
}

/// [`maxpool2d`] with explicit padding offsets (see [`conv2d_with_pads`]).
/// Out-of-bounds taps are ignored, exactly as in the full kernel.
#[allow(clippy::too_many_arguments)]
pub fn maxpool2d_with_pads(
    input: &[f32],
    in_shape: Hwc,
    out: &mut [f32],
    out_shape: Hwc,
    kernel: (usize, usize),
    stride: (usize, usize),
    pad_y: isize,
    pad_x: isize,
) {
    let (kh, kw) = kernel;
    let (sh, sw) = stride;
    for oy in 0..out_shape.h {
        for ox in 0..out_shape.w {
            for ch in 0..in_shape.c {
                let mut m = f32::NEG_INFINITY;
                for ky in 0..kh {
                    let iy = (oy * sh + ky) as isize - pad_y;
                    if iy < 0 || iy as usize >= in_shape.h {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * sw + kx) as isize - pad_x;
                        if ix < 0 || ix as usize >= in_shape.w {
                            continue;
                        }
                        m = m.max(input[in_shape.at(iy as usize, ix as usize, ch)]);
                    }
                }
                out[out_shape.at(oy, ox, ch)] = m;
            }
        }
    }
}

/// 2D average pooling (divisor = valid taps, TFLite-style).
#[allow(clippy::too_many_arguments)]
pub fn avgpool2d(
    input: &[f32],
    in_shape: Hwc,
    out: &mut [f32],
    out_shape: Hwc,
    kernel: (usize, usize),
    stride: (usize, usize),
    padding: Padding,
) {
    let pad_y = pad_amounts(in_shape.h, kernel.0, stride.0, padding, out_shape.h) as isize;
    let pad_x = pad_amounts(in_shape.w, kernel.1, stride.1, padding, out_shape.w) as isize;
    avgpool2d_with_pads(input, in_shape, out, out_shape, kernel, stride, pad_y, pad_x);
}

/// [`avgpool2d`] with explicit padding offsets. The divisor counts valid
/// taps only — identical to the full kernel, so bands divide by the same
/// counts the unsplit op would.
#[allow(clippy::too_many_arguments)]
pub fn avgpool2d_with_pads(
    input: &[f32],
    in_shape: Hwc,
    out: &mut [f32],
    out_shape: Hwc,
    kernel: (usize, usize),
    stride: (usize, usize),
    pad_y: isize,
    pad_x: isize,
) {
    let (kh, kw) = kernel;
    let (sh, sw) = stride;
    for oy in 0..out_shape.h {
        for ox in 0..out_shape.w {
            for ch in 0..in_shape.c {
                let mut acc = 0.0f32;
                let mut taps = 0usize;
                for ky in 0..kh {
                    let iy = (oy * sh + ky) as isize - pad_y;
                    if iy < 0 || iy as usize >= in_shape.h {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * sw + kx) as isize - pad_x;
                        if ix < 0 || ix as usize >= in_shape.w {
                            continue;
                        }
                        acc += input[in_shape.at(iy as usize, ix as usize, ch)];
                        taps += 1;
                    }
                }
                out[out_shape.at(oy, ox, ch)] = acc / taps.max(1) as f32;
            }
        }
    }
}

/// Global average pooling to `[1,1,1,C]`.
pub fn global_avgpool(input: &[f32], in_shape: Hwc, out: &mut [f32]) {
    debug_assert_eq!(out.len(), in_shape.c);
    let hw = (in_shape.h * in_shape.w) as f32;
    for ch in 0..in_shape.c {
        let mut acc = 0.0f32;
        for y in 0..in_shape.h {
            for x in 0..in_shape.w {
                acc += input[in_shape.at(y, x, ch)];
            }
        }
        out[ch] = acc / hw;
    }
}

/// Numerically-stable softmax over the whole slice (last-axis softmax for
/// `[1, n]` logits).
pub fn softmax(input: &[f32], out: &mut [f32]) {
    let m = input.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for i in 0..input.len() {
        out[i] = (input[i] - m).exp();
        sum += out[i];
    }
    for v in out.iter_mut() {
        *v /= sum;
    }
}

/// Synthetic operator body over raw bytes: deterministic, cheap mixing so
/// generated-DAG runs are reproducible and data-dependent.
pub fn synthetic_bytes(inputs: &[&[u8]], out: &mut [u8]) {
    for (i, o) in out.iter_mut().enumerate() {
        let mut acc = 0x9Eu8.wrapping_add(i as u8);
        for inp in inputs {
            if !inp.is_empty() {
                acc = acc.wrapping_mul(31).wrapping_add(inp[i % inp.len()]);
            }
        }
        *o = acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `write_band` over a partition reproduces `concat_slices`
    /// bit-for-bit on every axis — the invariant that makes join elision a
    /// pure placement change.
    #[test]
    fn write_band_chain_equals_concat_slices() {
        let out_shape = [1usize, 4, 6, 3];
        let n: usize = out_shape.iter().product();
        for (axis, cuts) in [
            (SplitAxis::Rows, vec![(0usize, 2usize), (2, 2)]),
            (SplitAxis::Cols, vec![(0, 2), (2, 3), (5, 1)]),
            (SplitAxis::Channels, vec![(0, 1), (1, 2)]),
        ] {
            let d = axis.dim();
            let mut parts_data: Vec<Vec<f32>> = Vec::new();
            let mut parts_shape: Vec<Vec<usize>> = Vec::new();
            for (i, &(_, len)) in cuts.iter().enumerate() {
                let mut shape = out_shape.to_vec();
                shape[d] = len;
                let elems: usize = shape.iter().product();
                parts_data.push((0..elems).map(|v| (v * 7 + i * 1000) as f32).collect());
                parts_shape.push(shape);
            }
            let parts: Vec<(&[f32], &[usize])> = parts_data
                .iter()
                .zip(&parts_shape)
                .map(|(d, s)| (d.as_slice(), s.as_slice()))
                .collect();
            let mut joined = vec![0.0f32; n];
            concat_slices(&parts, &mut joined, &out_shape, axis);
            let mut written = vec![0.0f32; n];
            for ((data, shape), &(off, _)) in parts_data.iter().zip(&parts_shape).zip(&cuts) {
                write_band(data, shape, &mut written, &out_shape, axis, off);
            }
            assert_eq!(joined, written, "axis {axis:?}");
        }
    }

    /// Dense `[1, n]` bands write flat at their feature offset.
    #[test]
    fn write_band_dense_is_flat() {
        let mut out = vec![0i8; 6];
        write_band(&[1i8, 2], &[1, 2], &mut out, &[1, 6], SplitAxis::Channels, 3);
        assert_eq!(out, vec![0, 0, 0, 1, 2, 0]);
    }

    #[test]
    fn conv2d_identity_kernel() {
        // 1x1 conv with identity weights passes channels through.
        let shape = Hwc { h: 2, w: 2, c: 2 };
        let input: Vec<f32> = (0..8).map(|v| v as f32).collect();
        // HWIO [1,1,2,2] identity.
        let weights = vec![1.0, 0.0, 0.0, 1.0];
        let bias = vec![0.0, 0.0];
        let mut out = vec![0.0; 8];
        conv2d(&input, shape, &weights, &bias, &mut out, shape, (1, 1), (1, 1), Padding::Same);
        assert_eq!(out, input);
    }

    #[test]
    fn conv2d_sums_channels() {
        let shape = Hwc { h: 1, w: 1, c: 3 };
        let input = vec![1.0, 2.0, 3.0];
        let weights = vec![1.0, 1.0, 1.0]; // [1,1,3,1] all ones
        let bias = vec![0.5];
        let out_shape = Hwc { h: 1, w: 1, c: 1 };
        let mut out = vec![0.0];
        conv2d(&input, shape, &weights, &bias, &mut out, out_shape, (1, 1), (1, 1), Padding::Valid);
        assert_eq!(out, vec![6.5]);
    }

    #[test]
    fn conv2d_same_padding_3x3_counts_taps() {
        // All-ones input & kernel, 1 channel: corner output = 4 taps,
        // edge = 6, centre = 9.
        let shape = Hwc { h: 3, w: 3, c: 1 };
        let input = vec![1.0; 9];
        let weights = vec![1.0; 9];
        let bias = vec![0.0];
        let mut out = vec![0.0; 9];
        conv2d(&input, shape, &weights, &bias, &mut out, shape, (3, 3), (1, 1), Padding::Same);
        assert_eq!(out, vec![4., 6., 4., 6., 9., 6., 4., 6., 4.]);
    }

    #[test]
    fn conv2d_stride2_shape() {
        let in_shape = Hwc { h: 4, w: 4, c: 1 };
        let out_shape = Hwc { h: 2, w: 2, c: 1 };
        let input: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let weights = vec![1.0]; // 1x1
        let bias = vec![0.0];
        let mut out = vec![0.0; 4];
        conv2d(
            &input,
            in_shape,
            &weights,
            &bias,
            &mut out,
            out_shape,
            (1, 1),
            (2, 2),
            Padding::Same,
        );
        assert_eq!(out, vec![0.0, 2.0, 8.0, 10.0]);
    }

    #[test]
    fn dwconv_channels_independent() {
        let shape = Hwc { h: 1, w: 2, c: 2 };
        let input = vec![1.0, 10.0, 2.0, 20.0]; // (y0x0: c0=1,c1=10), (y0x1: c0=2,c1=20)
        // kernel 1x2, per-channel weights: c0 = [1, 1], c1 = [0.5, 0.5]
        let weights = vec![1.0, 0.5, 1.0, 0.5]; // [ky=0][kx=0][c], [ky=0][kx=1][c]
        let bias = vec![0.0, 0.0];
        let out_shape = Hwc { h: 1, w: 1, c: 2 };
        let mut out = vec![0.0; 2];
        dwconv2d(
            &input,
            shape,
            &weights,
            &bias,
            &mut out,
            out_shape,
            (1, 2),
            (1, 1),
            Padding::Valid,
        );
        assert_eq!(out, vec![3.0, 15.0]);
    }

    #[test]
    fn dense_matvec() {
        let input = vec![1.0, 2.0];
        let weights = vec![1.0, 2.0, 3.0, 4.0]; // [[1,2],[3,4]] row-major [in,out]
        let bias = vec![0.1, 0.2];
        let mut out = vec![0.0; 2];
        dense(&input, &weights, &bias, &mut out);
        assert!((out[0] - 7.1).abs() < 1e-6); // 1*1+2*3+0.1
        assert!((out[1] - 10.2).abs() < 1e-6); // 1*2+2*4+0.2
    }

    #[test]
    fn concat_interleaves_channels() {
        let a = vec![1.0, 2.0, 3.0, 4.0]; // 2x1x2
        let b = vec![9.0, 8.0]; // 2x1x1
        let sa = Hwc { h: 2, w: 1, c: 2 };
        let sb = Hwc { h: 2, w: 1, c: 1 };
        let so = Hwc { h: 2, w: 1, c: 3 };
        let mut out = vec![0.0; 6];
        concat_channels(&[(&a, sa), (&b, sb)], &mut out, so);
        assert_eq!(out, vec![1.0, 2.0, 9.0, 3.0, 4.0, 8.0]);
    }

    #[test]
    fn relu_and_relu6() {
        let x = vec![-1.0, 0.5, 7.0];
        let mut r = vec![0.0; 3];
        relu(&x, &mut r);
        assert_eq!(r, vec![0.0, 0.5, 7.0]);
        relu6(&x, &mut r);
        assert_eq!(r, vec![0.0, 0.5, 6.0]);
    }

    #[test]
    fn maxpool_basic() {
        let shape = Hwc { h: 2, w: 2, c: 1 };
        let input = vec![1.0, 3.0, 2.0, 4.0];
        let out_shape = Hwc { h: 1, w: 1, c: 1 };
        let mut out = vec![0.0];
        maxpool2d(&input, shape, &mut out, out_shape, (2, 2), (2, 2), Padding::Valid);
        assert_eq!(out, vec![4.0]);
    }

    #[test]
    fn avgpool_divides_by_valid_taps() {
        // 3x3 input, 2x2 kernel stride 2, SAME → 2x2 out; bottom/right
        // cells average fewer taps.
        let shape = Hwc { h: 3, w: 3, c: 1 };
        let input = vec![1.0; 9];
        let out_shape = Hwc { h: 2, w: 2, c: 1 };
        let mut out = vec![0.0; 4];
        avgpool2d(&input, shape, &mut out, out_shape, (2, 2), (2, 2), Padding::Same);
        assert_eq!(out, vec![1.0; 4]);
    }

    #[test]
    fn global_avgpool_means() {
        let shape = Hwc { h: 2, w: 2, c: 2 };
        let input = vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0];
        let mut out = vec![0.0; 2];
        global_avgpool(&input, shape, &mut out);
        assert_eq!(out, vec![2.5, 25.0]);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let x = vec![1000.0, 1001.0];
        let mut out = vec![0.0; 2];
        softmax(&x, &mut out);
        assert!((out.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(out[1] > out[0]);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn synthetic_is_deterministic() {
        let a = vec![1u8, 2, 3];
        let b = vec![7u8; 5];
        let mut o1 = vec![0u8; 4];
        let mut o2 = vec![0u8; 4];
        synthetic_bytes(&[&a, &b], &mut o1);
        synthetic_bytes(&[&a, &b], &mut o2);
        assert_eq!(o1, o2);
        let mut o3 = vec![0u8; 4];
        synthetic_bytes(&[&b, &a], &mut o3);
        assert_ne!(o1, o3, "order-sensitive mixing");
    }
}

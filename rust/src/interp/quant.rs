//! Int8 quantized kernels (TFLite-style affine quantization).
//!
//! MCU deployments run int8: `real = scale * (q - zero_point)`. Weights are
//! quantized symmetrically (zero-point 0), biases are i32 with scale
//! `s_in * s_w`, and every activation tensor carries its own
//! [`QuantParams`]. Accumulation is i32; requantization uses f64 multipliers
//! (the fixed-point multiplier of a real MCU kernel introduces < 1 ULP
//! differences that don't matter for this reproduction and are covered by
//! the f32-vs-i8 tolerance tests).

use super::ops::{pad_amounts, Hwc};
use crate::graph::Padding;

/// Affine quantization parameters of one tensor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantParams {
    pub scale: f32,
    pub zero_point: i32,
}

impl QuantParams {
    pub fn new(scale: f32, zero_point: i32) -> Self {
        assert!(scale > 0.0, "quant scale must be positive");
        QuantParams { scale, zero_point }
    }

    /// Parameters covering the symmetric range `[-absmax, absmax]`.
    pub fn symmetric(absmax: f32) -> Self {
        QuantParams::new((absmax / 127.0).max(1e-8), 0)
    }

    /// Parameters covering `[lo, hi]` (asymmetric, i8 domain).
    pub fn from_range(lo: f32, hi: f32) -> Self {
        let lo = lo.min(0.0);
        let hi = hi.max(lo + 1e-6);
        let scale = (hi - lo) / 255.0;
        let zp = (-128.0 - lo / scale).round().clamp(-128.0, 127.0) as i32;
        QuantParams::new(scale, zp)
    }

    #[inline]
    pub fn quantize_one(&self, v: f32) -> i8 {
        ((v / self.scale).round() as i32 + self.zero_point).clamp(-128, 127) as i8
    }

    #[inline]
    pub fn dequantize_one(&self, q: i8) -> f32 {
        (q as i32 - self.zero_point) as f32 * self.scale
    }

    pub fn quantize(&self, vs: &[f32]) -> Vec<i8> {
        vs.iter().map(|&v| self.quantize_one(v)).collect()
    }

    pub fn dequantize(&self, qs: &[i8]) -> Vec<f32> {
        qs.iter().map(|&q| self.dequantize_one(q)).collect()
    }
}

/// TFLite-style fixed-point requantization multiplier:
/// `mult = frac · 2^e` with `frac ∈ [0.5, 1)`, stored as
/// `m = round(frac · 2^31)` and right-shift `sh = 31 − e`. Integer-only
/// rescaling is both what a real MCU kernel does and measurably faster
/// than per-element f64 (perf pass, EXPERIMENTS.md §Perf).
#[derive(Clone, Copy, Debug)]
pub struct FixedMult {
    /// `round(frac · 2^31)`, the 31-bit mantissa (`codegen` bakes it into
    /// the emitted requantization calls as a compile-time constant).
    pub m: i64,
    /// Right shift applied after the widening multiply.
    pub sh: u32,
}

impl FixedMult {
    pub fn new(mult: f64) -> FixedMult {
        assert!(mult > 0.0, "requantization multiplier must be positive");
        let mut e = 0i32;
        let mut frac = mult;
        while frac >= 1.0 {
            frac /= 2.0;
            e += 1;
        }
        while frac < 0.5 {
            frac *= 2.0;
            e -= 1;
        }
        let mut m = (frac * (1i64 << 31) as f64).round() as i64;
        if m == 1i64 << 31 {
            m >>= 1;
            e += 1;
        }
        let sh = 31 - e;
        assert!(sh >= 1, "multiplier too large for fixed-point requantization");
        FixedMult { m, sh: sh.min(63) as u32 }
    }

    /// `round(acc · mult)` in pure integer arithmetic.
    #[inline]
    pub fn apply(&self, acc: i32) -> i32 {
        let prod = acc as i64 * self.m;
        ((prod + (1i64 << (self.sh - 1))) >> self.sh) as i32
    }
}

#[inline]
fn requantize_fixed(acc: i32, fm: FixedMult, zp_out: i32) -> i8 {
    (fm.apply(acc) + zp_out).clamp(-128, 127) as i8
}

/// Reference f64 requantization (retained as the oracle for the
/// fixed-point path's unit tests).
#[cfg_attr(not(test), allow(dead_code))]
#[inline]
fn requantize(acc: i32, mult: f64, zp_out: i32) -> i8 {
    ((acc as f64 * mult).round() as i32 + zp_out).clamp(-128, 127) as i8
}

/// Quantized standard conv. Weight zero-point must be 0 (symmetric).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_i8(
    input: &[i8],
    in_shape: Hwc,
    in_q: QuantParams,
    weights: &[i8],
    w_scale: f32,
    bias: &[i32],
    out: &mut [i8],
    out_shape: Hwc,
    out_q: QuantParams,
    kernel: (usize, usize),
    stride: (usize, usize),
    padding: Padding,
) {
    let pad_y = pad_amounts(in_shape.h, kernel.0, stride.0, padding, out_shape.h) as isize;
    let pad_x = pad_amounts(in_shape.w, kernel.1, stride.1, padding, out_shape.w) as isize;
    conv2d_i8_with_pads(
        input, in_shape, in_q, weights, w_scale, bias, out, out_shape, out_q, kernel, stride,
        pad_y, pad_x, 0, out_shape.c,
    );
}

/// [`conv2d_i8`] with explicit padding offsets. Out-of-bounds taps are
/// skipped (integer-exact zero padding), so a row band computed against an
/// input slab is bit-identical to the corresponding rows of the full op —
/// the property the split subsystem's int8 validation relies on.
///
/// The output channel band `[c0, c0 + out_shape.c)` runs against the full
/// `[kh, kw, cin, cout_total]` weights and full bias (see the f32
/// `conv2d_with_pads`); per-channel accumulation and requantization are
/// independent, so channel bands are bit-exact too. Whole-tensor calls
/// pass `c0 = 0, cout_total = out_shape.c`.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_i8_with_pads(
    input: &[i8],
    in_shape: Hwc,
    in_q: QuantParams,
    weights: &[i8],
    w_scale: f32,
    bias: &[i32],
    out: &mut [i8],
    out_shape: Hwc,
    out_q: QuantParams,
    kernel: (usize, usize),
    stride: (usize, usize),
    pad_y: isize,
    pad_x: isize,
    c0: usize,
    cout_total: usize,
) {
    let (kh, kw) = kernel;
    let (sh, sw) = stride;
    let cin = in_shape.c;
    let cout = out_shape.c;
    debug_assert_eq!(weights.len(), kh * kw * cin * cout_total);
    debug_assert_eq!(bias.len(), cout_total);
    debug_assert!(c0 + cout <= cout_total);
    let fm = FixedMult::new((in_q.scale as f64) * (w_scale as f64) / (out_q.scale as f64));
    let zp_in = in_q.zero_point;

    // Hot loop structure (perf pass, EXPERIMENTS.md §Perf): one i32
    // accumulator row per output pixel, taps and input channels in the
    // outer loops so the innermost loop walks a *contiguous* weight row —
    // the strided `w[.. + ic*cout + oc]` access of the naive ordering was
    // the top bottleneck. The pointwise (1×1, stride 1) case — most of
    // MobileNet's MACs — skips the padding arithmetic entirely.
    let mut acc_row: Vec<i32> = vec![0; cout];
    if kh == 1 && kw == 1 && sh == 1 && sw == 1 && pad_y == 0 && pad_x == 0 {
        for p in 0..out_shape.h * out_shape.w {
            acc_row.copy_from_slice(&bias[c0..c0 + cout]);
            let ibase = p * cin;
            for ic in 0..cin {
                let iv = input[ibase + ic] as i32 - zp_in;
                if iv == 0 {
                    continue;
                }
                let wrow = &weights[ic * cout_total + c0..][..cout];
                for (a, &w) in acc_row.iter_mut().zip(wrow) {
                    *a += iv * w as i32;
                }
            }
            let orow = &mut out[p * cout..(p + 1) * cout];
            for (o, &a) in orow.iter_mut().zip(&acc_row) {
                *o = requantize_fixed(a, fm, out_q.zero_point);
            }
        }
        return;
    }

    for oy in 0..out_shape.h {
        for ox in 0..out_shape.w {
            acc_row.copy_from_slice(&bias[c0..c0 + cout]);
            for ky in 0..kh {
                let iy = (oy * sh + ky) as isize - pad_y;
                if iy < 0 || iy as usize >= in_shape.h {
                    continue;
                }
                for kx in 0..kw {
                    let ix = (ox * sw + kx) as isize - pad_x;
                    if ix < 0 || ix as usize >= in_shape.w {
                        continue;
                    }
                    let ibase = in_shape.at(iy as usize, ix as usize, 0);
                    let wbase = ((ky * kw + kx) * cin) * cout_total + c0;
                    for ic in 0..cin {
                        let iv = input[ibase + ic] as i32 - zp_in;
                        if iv == 0 {
                            continue;
                        }
                        let wrow = &weights[wbase + ic * cout_total..][..cout];
                        for (a, &w) in acc_row.iter_mut().zip(wrow) {
                            *a += iv * w as i32;
                        }
                    }
                }
            }
            let obase = out_shape.at(oy, ox, 0);
            let orow = &mut out[obase..obase + cout];
            for (o, &a) in orow.iter_mut().zip(&acc_row) {
                *o = requantize_fixed(a, fm, out_q.zero_point);
            }
        }
    }
}

/// Quantized depthwise conv.
#[allow(clippy::too_many_arguments)]
pub fn dwconv2d_i8(
    input: &[i8],
    in_shape: Hwc,
    in_q: QuantParams,
    weights: &[i8],
    w_scale: f32,
    bias: &[i32],
    out: &mut [i8],
    out_shape: Hwc,
    out_q: QuantParams,
    kernel: (usize, usize),
    stride: (usize, usize),
    padding: Padding,
) {
    let pad_y = pad_amounts(in_shape.h, kernel.0, stride.0, padding, out_shape.h) as isize;
    let pad_x = pad_amounts(in_shape.w, kernel.1, stride.1, padding, out_shape.w) as isize;
    dwconv2d_i8_with_pads(
        input, in_shape, in_q, weights, w_scale, bias, out, out_shape, out_q, kernel, stride,
        pad_y, pad_x, 0, in_shape.c,
    );
}

/// [`dwconv2d_i8`] with explicit padding offsets (see
/// [`conv2d_i8_with_pads`]). The channel band `[c0, c0 + in_shape.c)`
/// runs against the full `[kh, kw, c_total]` weights and full bias;
/// whole-tensor calls pass `c0 = 0, c_total = in_shape.c`.
#[allow(clippy::too_many_arguments)]
pub fn dwconv2d_i8_with_pads(
    input: &[i8],
    in_shape: Hwc,
    in_q: QuantParams,
    weights: &[i8],
    w_scale: f32,
    bias: &[i32],
    out: &mut [i8],
    out_shape: Hwc,
    out_q: QuantParams,
    kernel: (usize, usize),
    stride: (usize, usize),
    pad_y: isize,
    pad_x: isize,
    c0: usize,
    c_total: usize,
) {
    let (kh, kw) = kernel;
    let (sh, sw) = stride;
    let c = in_shape.c;
    debug_assert_eq!(weights.len(), kh * kw * c_total);
    debug_assert_eq!(bias.len(), c_total);
    debug_assert!(c0 + c <= c_total);
    let fm = FixedMult::new((in_q.scale as f64) * (w_scale as f64) / (out_q.scale as f64));

    // Perf pass: channels innermost so both the input row and the weight
    // tap row are walked contiguously (the naive channel-outer ordering
    // re-strided both arrays per element).
    let zp_in = in_q.zero_point;
    let mut acc_row: Vec<i32> = vec![0; c];
    for oy in 0..out_shape.h {
        for ox in 0..out_shape.w {
            acc_row.copy_from_slice(&bias[c0..c0 + c]);
            for ky in 0..kh {
                let iy = (oy * sh + ky) as isize - pad_y;
                if iy < 0 || iy as usize >= in_shape.h {
                    continue;
                }
                for kx in 0..kw {
                    let ix = (ox * sw + kx) as isize - pad_x;
                    if ix < 0 || ix as usize >= in_shape.w {
                        continue;
                    }
                    let ibase = in_shape.at(iy as usize, ix as usize, 0);
                    let irow = &input[ibase..ibase + c];
                    let wrow = &weights[(ky * kw + kx) * c_total + c0..][..c];
                    for ((a, &iv), &w) in acc_row.iter_mut().zip(irow).zip(wrow) {
                        *a += (iv as i32 - zp_in) * w as i32;
                    }
                }
            }
            let obase = out_shape.at(oy, ox, 0);
            let orow = &mut out[obase..obase + c];
            for (o, &a) in orow.iter_mut().zip(&acc_row) {
                *o = requantize_fixed(a, fm, out_q.zero_point);
            }
        }
    }
}

/// Quantized fully connected.
#[allow(clippy::too_many_arguments)]
pub fn dense_i8(
    input: &[i8],
    in_q: QuantParams,
    weights: &[i8],
    w_scale: f32,
    bias: &[i32],
    out: &mut [i8],
    out_q: QuantParams,
) {
    let n_out = out.len();
    dense_cols_i8(input, in_q, weights, w_scale, bias, out, out_q, 0, n_out);
}

/// Output-feature band of a quantized dense layer: features
/// `[col0, col0 + out.len())` against the full `[in, n_cols]` weight matrix
/// and full bias. Accumulation order matches [`dense_i8`], so bands are
/// bit-identical to the corresponding slice of the full output.
#[allow(clippy::too_many_arguments)]
pub fn dense_cols_i8(
    input: &[i8],
    in_q: QuantParams,
    weights: &[i8],
    w_scale: f32,
    bias: &[i32],
    out: &mut [i8],
    out_q: QuantParams,
    col0: usize,
    n_cols: usize,
) {
    let n_in = input.len();
    let n_out = out.len();
    debug_assert!(col0 + n_out <= n_cols);
    debug_assert_eq!(weights.len(), n_in * n_cols);
    debug_assert_eq!(bias.len(), n_cols);
    let fm = FixedMult::new((in_q.scale as f64) * (w_scale as f64) / (out_q.scale as f64));
    // Contiguous weight rows (perf pass): accumulate over outputs with the
    // input element hoisted.
    let mut acc: Vec<i32> = bias[col0..col0 + n_out].to_vec();
    for i in 0..n_in {
        let iv = input[i] as i32 - in_q.zero_point;
        if iv == 0 {
            continue;
        }
        let wrow = &weights[i * n_cols + col0..i * n_cols + col0 + n_out];
        for (a, &w) in acc.iter_mut().zip(wrow) {
            *a += iv * w as i32;
        }
    }
    for (o, &a) in out.iter_mut().zip(&acc) {
        *o = requantize_fixed(a, fm, out_q.zero_point);
    }
}

/// Quantized elementwise add (each operand requantized into the output
/// domain).
pub fn add_i8(
    a: &[i8],
    a_q: QuantParams,
    b: &[i8],
    b_q: QuantParams,
    out: &mut [i8],
    out_q: QuantParams,
) {
    let ma = (a_q.scale / out_q.scale) as f64;
    let mb = (b_q.scale / out_q.scale) as f64;
    for i in 0..out.len() {
        let av = (a[i] as i32 - a_q.zero_point) as f64 * ma;
        let bv = (b[i] as i32 - b_q.zero_point) as f64 * mb;
        out[i] = ((av + bv).round() as i32 + out_q.zero_point).clamp(-128, 127) as i8;
    }
}

/// Quantized ReLU: clamp below at the zero point (in/out share params).
pub fn relu_i8(input: &[i8], q: QuantParams, out: &mut [i8]) {
    let zp = q.zero_point.clamp(-128, 127) as i8;
    for i in 0..input.len() {
        out[i] = input[i].max(zp);
    }
}

/// Quantized ReLU6: clamp to `[zp, q(6.0)]`.
pub fn relu6_i8(input: &[i8], q: QuantParams, out: &mut [i8]) {
    let lo = q.zero_point.clamp(-128, 127) as i8;
    let hi = q.quantize_one(6.0).max(lo);
    for i in 0..input.len() {
        out[i] = input[i].clamp(lo, hi);
    }
}

/// Quantized max pooling (domain-preserving, no requantization needed).
#[allow(clippy::too_many_arguments)]
pub fn maxpool2d_i8(
    input: &[i8],
    in_shape: Hwc,
    out: &mut [i8],
    out_shape: Hwc,
    kernel: (usize, usize),
    stride: (usize, usize),
    padding: Padding,
) {
    let pad_y = pad_amounts(in_shape.h, kernel.0, stride.0, padding, out_shape.h) as isize;
    let pad_x = pad_amounts(in_shape.w, kernel.1, stride.1, padding, out_shape.w) as isize;
    maxpool2d_i8_with_pads(input, in_shape, out, out_shape, kernel, stride, pad_y, pad_x);
}

/// [`maxpool2d_i8`] with explicit padding offsets; out-of-bounds taps are
/// ignored exactly as in the full kernel.
#[allow(clippy::too_many_arguments)]
pub fn maxpool2d_i8_with_pads(
    input: &[i8],
    in_shape: Hwc,
    out: &mut [i8],
    out_shape: Hwc,
    kernel: (usize, usize),
    stride: (usize, usize),
    pad_y: isize,
    pad_x: isize,
) {
    let (kh, kw) = kernel;
    let (sh, sw) = stride;
    for oy in 0..out_shape.h {
        for ox in 0..out_shape.w {
            for ch in 0..in_shape.c {
                let mut m = i8::MIN;
                for ky in 0..kh {
                    let iy = (oy * sh + ky) as isize - pad_y;
                    if iy < 0 || iy as usize >= in_shape.h {
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * sw + kx) as isize - pad_x;
                        if ix < 0 || ix as usize >= in_shape.w {
                            continue;
                        }
                        m = m.max(input[in_shape.at(iy as usize, ix as usize, ch)]);
                    }
                }
                out[out_shape.at(oy, ox, ch)] = m;
            }
        }
    }
}

/// Quantized global average pooling (in/out share params; rounding to
/// nearest).
pub fn global_avgpool_i8(input: &[i8], in_shape: Hwc, q: QuantParams, out: &mut [i8]) {
    let hw = (in_shape.h * in_shape.w) as i64;
    for ch in 0..in_shape.c {
        let mut acc: i64 = 0;
        for y in 0..in_shape.h {
            for x in 0..in_shape.w {
                acc += input[in_shape.at(y, x, ch)] as i64 - q.zero_point as i64;
            }
        }
        let mean = (acc as f64 / hw as f64).round() as i32 + q.zero_point;
        out[ch] = mean.clamp(-128, 127) as i8;
    }
}

/// Quantized softmax: dequantize, stable softmax, requantize to the
/// conventional output domain `scale = 1/256, zp = -128`.
pub fn softmax_i8(input: &[i8], in_q: QuantParams, out: &mut [i8]) {
    let xs = in_q.dequantize(input);
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = xs.iter().map(|v| (v - m).exp()).collect();
    let sum: f32 = exps.iter().sum();
    for (o, e) in out.iter_mut().zip(&exps) {
        *o = (((e / sum) * 256.0).round() as i32 - 128).clamp(-128, 127) as i8;
    }
}

/// The conventional softmax output quantization.
pub fn softmax_out_qparams() -> QuantParams {
    QuantParams::new(1.0 / 256.0, -128)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_mult_matches_f64_requantize() {
        let mut rng = crate::util::rng::Rng::new(314);
        for _ in 0..3000 {
            let mult = rng.f64() * 0.499 + 1e-6; // typical requant range
            let acc = (rng.next_u64() as i32) % 2_000_000;
            let fm = FixedMult::new(mult);
            let a = requantize_fixed(acc, fm, -3);
            let b = requantize(acc, mult, -3);
            assert!(
                (a as i32 - b as i32).abs() <= 1,
                "mult={mult} acc={acc}: fixed={a} f64={b}"
            );
        }
    }

    #[test]
    fn fixed_mult_handles_extremes() {
        for mult in [1e-6, 0.25, 0.5, 0.999, 1.5] {
            let fm = FixedMult::new(mult);
            assert_eq!(fm.apply(0), 0);
            let v = fm.apply(1000);
            let want = (1000.0 * mult).round() as i32;
            assert!((v - want).abs() <= 1, "mult={mult}: {v} vs {want}");
        }
    }

    #[test]
    fn quantize_roundtrip_within_half_scale() {
        let q = QuantParams::from_range(-4.0, 4.0);
        for v in [-3.9f32, -1.0, 0.0, 0.5, 3.9] {
            let r = q.dequantize_one(q.quantize_one(v));
            assert!((r - v).abs() <= q.scale * 0.5 + 1e-6, "v={v} r={r}");
        }
    }

    #[test]
    fn symmetric_weights_have_zero_zp() {
        let q = QuantParams::symmetric(2.0);
        assert_eq!(q.zero_point, 0);
        assert_eq!(q.quantize_one(0.0), 0);
    }

    #[test]
    fn conv_i8_tracks_f32_reference() {
        use crate::interp::ops;
        let in_shape = Hwc { h: 4, w: 4, c: 2 };
        let out_shape = Hwc { h: 4, w: 4, c: 3 };
        let mut rng = crate::util::rng::Rng::new(99);
        let input_f: Vec<f32> = (0..in_shape.elems()).map(|_| rng.f32_range(-1.0, 1.0)).collect();
        let weights_f: Vec<f32> = (0..3 * 3 * 2 * 3).map(|_| rng.f32_range(-0.5, 0.5)).collect();
        let bias_f: Vec<f32> = (0..3).map(|_| rng.f32_range(-0.2, 0.2)).collect();

        let mut out_f = vec![0.0; out_shape.elems()];
        ops::conv2d(
            &input_f, in_shape, &weights_f, &bias_f, &mut out_f, out_shape,
            (3, 3), (1, 1), Padding::Same,
        );

        let in_q = QuantParams::from_range(-1.0, 1.0);
        let w_q = QuantParams::symmetric(0.5);
        let absmax = out_f.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let out_q = QuantParams::from_range(-absmax, absmax);
        let input_q = in_q.quantize(&input_f);
        let weights_q = w_q.quantize(&weights_f);
        let bias_scale = in_q.scale * w_q.scale;
        let bias_q: Vec<i32> = bias_f.iter().map(|&b| (b / bias_scale).round() as i32).collect();
        let mut out_i = vec![0i8; out_shape.elems()];
        conv2d_i8(
            &input_q, in_shape, in_q, &weights_q, w_q.scale, &bias_q, &mut out_i, out_shape,
            out_q, (3, 3), (1, 1), Padding::Same,
        );
        let out_deq = out_q.dequantize(&out_i);
        for (a, b) in out_f.iter().zip(&out_deq) {
            assert!((a - b).abs() < 6.0 * out_q.scale, "f32={a} i8={b}");
        }
    }

    #[test]
    fn add_i8_requantizes_operand_domains() {
        let a_q = QuantParams::from_range(-1.0, 1.0);
        let b_q = QuantParams::from_range(-2.0, 2.0);
        let o_q = QuantParams::from_range(-3.0, 3.0);
        let a = a_q.quantize(&[0.5, -0.25]);
        let b = b_q.quantize(&[1.0, 0.75]);
        let mut out = vec![0i8; 2];
        add_i8(&a, a_q, &b, b_q, &mut out, o_q);
        let got = o_q.dequantize(&out);
        assert!((got[0] - 1.5).abs() < 0.05);
        assert!((got[1] - 0.5).abs() < 0.05);
    }

    #[test]
    fn relu6_i8_clamps() {
        let q = QuantParams::from_range(-8.0, 8.0);
        let x = q.quantize(&[-3.0, 2.0, 7.5]);
        let mut out = vec![0i8; 3];
        relu6_i8(&x, q, &mut out);
        let got = q.dequantize(&out);
        assert!(got[0].abs() < 0.1);
        assert!((got[1] - 2.0).abs() < 0.1);
        assert!((got[2] - 6.0).abs() < 0.1);
    }

    #[test]
    fn softmax_i8_sums_to_about_one() {
        let in_q = QuantParams::from_range(-8.0, 8.0);
        let x = in_q.quantize(&[1.0, 2.0, 3.0]);
        let mut out = vec![0i8; 3];
        softmax_i8(&x, in_q, &mut out);
        let oq = softmax_out_qparams();
        let sum: f32 = oq.dequantize(&out).iter().sum();
        assert!((sum - 1.0).abs() < 0.05, "sum={sum}");
    }

    #[test]
    fn gap_i8_mean() {
        let q = QuantParams::new(0.1, 3);
        let shape = Hwc { h: 2, w: 2, c: 1 };
        let input = q.quantize(&[1.0, 2.0, 3.0, 4.0]);
        let mut out = vec![0i8; 1];
        global_avgpool_i8(&input, shape, q, &mut out);
        assert!((q.dequantize_one(out[0]) - 2.5).abs() < 0.1);
    }
}
